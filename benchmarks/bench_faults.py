"""Dropout sweep: FedTest robustness vs client availability
(EXPERIMENTS.md §Dropout-sweep, DESIGN.md §9).

The availability analogue of the coalition sweep: per-round Bernoulli
dropout at rate q thins both the aggregation simplex and the tester
committee, so the question is whether the score separation that
suppresses an attacker survives when a fraction of every round's
evidence goes missing. Each row runs the same defended scenario at a
drop rate and reports final accuracy, the attacker's final aggregate
weight, its suppression round (first round below 0.1) and the measured
mean ``dropped_fraction``. A ``straggler_deadline`` row probes the
non-uniform case (rank-spread finish times) at roughly matched drop
mass.

The attack is ``random_weights`` (as in the Sec. V-B power sweep):
its models score badly *regardless* of global convergence, so the
sweep isolates the availability effect. ``sign_flip`` would confound
it — once the easy smoke task saturates, flipped updates shrink to
harmlessness and the attacker legitimately regains weight.
"""
from __future__ import annotations

import jax

from benchmarks.common import FAST, emit
from repro.config import FedConfig, TrainConfig
from repro.configs import get_config
from repro.core import FederatedTrainer
from repro.data import MNIST_LIKE, make_federated_image_dataset
from repro.models import build_model

SUPPRESSION_BAR = 0.1


def _setup():
    # the reduced CNN on mild-skew shards: a dynamics measurement (who
    # gets the weight under missing evidence), not a perf one
    cfg = get_config("fedtest-cnn-mnist").replace(cnn_channels=(8, 16, 16),
                                                  cnn_hidden=32)
    model = build_model(cfg)
    users = 8
    data = make_federated_image_dataset(
        MNIST_LIKE, users, num_samples=4000, global_test=400, seed=1,
        partition_kwargs={"min_classes": 8, "max_classes": 10})
    tc = TrainConfig(optimizer="sgd", lr=0.1, schedule="constant",
                     batch_size=16, grad_clip=0.0, remat=False)
    return model, users, data, tc


def _run(model, users, data, tc, rounds, fault, rate, kwargs=None):
    fed = FedConfig(num_users=users, num_testers=5, num_malicious=2,
                    local_steps=10, attack="random_weights",
                    attack_scale=4.0, fault=fault, fault_rate=rate,
                    fault_kwargs=kwargs or {})
    trainer = FederatedTrainer(model, fed, tc, eval_batch=128)
    state = trainer.init(jax.random.PRNGKey(0))
    suppressed_at, dropped = None, []
    for r in range(rounds):
        state, metrics = trainer.run_round(state, data)
        mal_w = float(metrics["malicious_weight"])
        dropped.append(float(metrics["dropped_fraction"]))
        if suppressed_at is None and mal_w < SUPPRESSION_BAR:
            suppressed_at = r + 1
    acc = trainer.global_accuracy(state, data)
    return acc, mal_w, suppressed_at, sum(dropped) / len(dropped)


def dropout_sweep(fast: bool):
    model, users, data, tc = _setup()
    rounds = 8 if fast else 20
    rates = (0.0, 0.2) if fast else (0.0, 0.1, 0.2, 0.3, 0.4, 0.5)
    for q in rates:
        fault = "none" if q == 0.0 else "dropout"
        acc, mal_w, sup, mean_drop = _run(model, users, data, tc,
                                          rounds, fault, max(q, 0.1))
        emit(f"faults/dropout_q{q:g}", 0.0,
             f"final_acc={acc:.4f} final_malicious_weight={mal_w:.5f} "
             f"suppression_round={sup if sup else f'>{rounds}'} "
             f"mean_dropped_fraction={mean_drop:.3f}")
    # non-uniform availability at roughly the same drop mass as q=0.2
    acc, mal_w, sup, mean_drop = _run(model, users, data, tc, rounds,
                                      "straggler_deadline", 0.1,
                                      {"deadline": 2.5})
    emit("faults/straggler_deadline", 0.0,
         f"final_acc={acc:.4f} final_malicious_weight={mal_w:.5f} "
         f"suppression_round={sup if sup else f'>{rounds}'} "
         f"mean_dropped_fraction={mean_drop:.3f}")


def main(fast: bool = FAST):
    dropout_sweep(fast)


if __name__ == "__main__":
    main()
