"""Kernel micro-benchmarks (XLA paths on CPU; Pallas targets TPU and is
validated by the interpret-mode test sweeps)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import FAST, emit, timeit
from repro.kernels.decode_attention.ops import _decode_xla
from repro.kernels.flash_attention.ops import attention_xla
from repro.kernels.robust_combine.ops import robust_combine
from repro.kernels.ssd_scan.ops import _ssd_xla


def main(fast: bool = FAST):
    # flash attention (prefill-like)
    B, S, Hq, Hkv, D = (1, 512, 8, 2, 64) if fast else (2, 2048, 8, 2, 64)
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, S, Hq, D), jnp.bfloat16)
    k = jax.random.normal(ks[1], (B, S, Hkv, D), jnp.bfloat16)
    v = jax.random.normal(ks[2], (B, S, Hkv, D), jnp.bfloat16)
    fn = jax.jit(lambda q, k, v: attention_xla(q, k, v, causal=True,
                                               block_q=256, block_k=256))
    us = timeit(fn, q, k, v)
    flops = 4 * B * S * S * Hq * D
    emit(f"flash_attention/xla_S{S}", us,
         f"gflops={flops / (us / 1e6) / 1e9:.2f}")

    # decode attention
    T = 4096 if fast else 32768
    kc = jax.random.normal(ks[1], (B, T, Hkv, D), jnp.bfloat16)
    vc = jax.random.normal(ks[2], (B, T, Hkv, D), jnp.bfloat16)
    qd = jax.random.normal(ks[0], (B, Hq, D), jnp.bfloat16)
    lengths = jnp.full((B,), T)
    fn = jax.jit(lambda q, k, v, l: _decode_xla(q, k, v, l, block_k=1024))
    us = timeit(fn, qd, kc, vc, lengths)
    kv_bytes = 2 * B * T * Hkv * D * 2
    emit(f"decode_attention/xla_T{T}", us,
         f"kv_GBps={kv_bytes / (us / 1e6) / 1e9:.2f}")

    # SSD scan
    Bt, S2, H, P, G, N = (1, 512, 8, 64, 1, 64) if fast else \
        (1, 2048, 16, 64, 1, 128)
    ks = jax.random.split(jax.random.PRNGKey(1), 6)
    x = jax.random.normal(ks[0], (Bt, S2, H, P), jnp.bfloat16)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (Bt, S2, H)))
    A = -jnp.exp(jax.random.normal(ks[2], (H,)))
    Bm = jax.random.normal(ks[3], (Bt, S2, G, N), jnp.bfloat16)
    Cm = jax.random.normal(ks[4], (Bt, S2, G, N), jnp.bfloat16)
    Dv = jax.random.normal(ks[5], (H,))
    fn = jax.jit(lambda *a: _ssd_xla(*a, chunk=128)[0])
    us = timeit(fn, x, dt, A, Bm, Cm, Dv)
    emit(f"ssd_scan/xla_S{S2}", us, f"heads={H} state={N}")

    # robust combine (per-coordinate trimmed mean via sorting network vs
    # the jnp.sort oracle; the Pallas kernel targets TPU, validated by the
    # interpret-mode parity sweep in tests/test_kernels_robust.py)
    C, M = (16, 1 << 20) if fast else (16, 1 << 22)
    xr = jax.random.normal(jax.random.PRNGKey(2), (C, M), jnp.float32)
    for impl in ("network", "sort"):
        fn = jax.jit(lambda x, _i=impl: robust_combine(
            x, mode="trimmed_mean", trim_fraction=0.25, impl=_i))
        us = timeit(fn, xr, iters=3)
        gbps = C * M * 4 / (us / 1e6) / 1e9
        emit(f"robust_combine/{impl}_C{C}_M{M}", us,
             f"read_GBps={gbps:.2f}", gbps=round(gbps, 2))


if __name__ == "__main__":
    main()
