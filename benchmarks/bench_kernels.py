"""Kernel micro-benchmarks (XLA paths on CPU; Pallas targets TPU and is
validated by the interpret-mode test sweeps).

Every row carries ``gbps`` (effective bandwidth over the bytes the
kernel must touch) and ``roofline_frac`` — that bandwidth as a fraction
of the measured ``weighted_aggregate`` streaming reference, the
machine's realised memory roofline. Fractions, not wall times, are the
perf trajectory ``BENCH_kernels.json`` tracks across commits
(``tools/check_bench.py`` gates regressions >15%): a ratio of two
bandwidths measured on the same machine is far more stable across CI
hosts than an absolute latency. Compute-bound kernels (flash attention)
legitimately sit far below 1.0 — the gate cares about the *trajectory*,
not the absolute value.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import FAST, emit, timeit
from repro.kernels.decode_attention.ops import _decode_xla
from repro.kernels.dequant_aggregate.ops import dequant_aggregate
from repro.kernels.flash_attention.ops import attention_xla
from repro.kernels.robust_combine.ops import robust_combine
from repro.kernels.ssd_scan.ops import _ssd_xla
from repro.kernels.weighted_aggregate.ops import weighted_aggregate


def main(fast: bool = FAST):
    # --- weighted_aggregate: the streaming-bandwidth roofline reference
    C, M = (16, 1 << 20) if fast else (16, 1 << 22)
    xw = jax.random.normal(jax.random.PRNGKey(3), (C, M), jnp.float32)
    ww = jax.random.uniform(jax.random.PRNGKey(4), (C,))
    fn = jax.jit(lambda x, w: weighted_aggregate(x, w, impl="auto"))
    us = timeit(fn, xw, ww)
    ref_gbps = C * M * 4 / (us / 1e6) / 1e9
    emit(f"kernels/weighted_aggregate_C{C}_M{M}", us,
         f"read_GBps={ref_gbps:.2f}", gbps=round(ref_gbps, 2),
         roofline_frac=1.0)

    def frac(gbps: float) -> float:
        # 4 decimals: compute-bound kernels sit at ~0.01 of the stream
        # roofline, and the 15% regression gate needs resolution there
        return round(gbps / ref_gbps, 4)

    # --- flash attention (prefill-like; compute-bound, low fraction)
    B, S, Hq, Hkv, D = (1, 512, 8, 2, 64) if fast else (2, 2048, 8, 2, 64)
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, S, Hq, D), jnp.bfloat16)
    k = jax.random.normal(ks[1], (B, S, Hkv, D), jnp.bfloat16)
    v = jax.random.normal(ks[2], (B, S, Hkv, D), jnp.bfloat16)
    fn = jax.jit(lambda q, k, v: attention_xla(q, k, v, causal=True,
                                               block_q=256, block_k=256))
    us = timeit(fn, q, k, v)
    flops = 4 * B * S * S * Hq * D
    io_bytes = (2 * B * S * Hq * D + 2 * B * S * Hkv * D) * 2   # q,o + k,v
    gbps = io_bytes / (us / 1e6) / 1e9
    emit(f"flash_attention/xla_S{S}", us,
         f"gflops={flops / (us / 1e6) / 1e9:.2f} io_GBps={gbps:.2f}",
         gbps=round(gbps, 2), roofline_frac=frac(gbps))

    # --- decode attention (KV-cache-bandwidth bound)
    T = 4096 if fast else 32768
    kc = jax.random.normal(ks[1], (B, T, Hkv, D), jnp.bfloat16)
    vc = jax.random.normal(ks[2], (B, T, Hkv, D), jnp.bfloat16)
    qd = jax.random.normal(ks[0], (B, Hq, D), jnp.bfloat16)
    lengths = jnp.full((B,), T)
    fn = jax.jit(lambda q, k, v, l: _decode_xla(q, k, v, l, block_k=1024))
    us = timeit(fn, qd, kc, vc, lengths)
    kv_bytes = 2 * B * T * Hkv * D * 2
    gbps = kv_bytes / (us / 1e6) / 1e9
    emit(f"decode_attention/xla_T{T}", us, f"kv_GBps={gbps:.2f}",
         gbps=round(gbps, 2), roofline_frac=frac(gbps))

    # --- SSD scan
    Bt, S2, H, P, G, N = (1, 512, 8, 64, 1, 64) if fast else \
        (1, 2048, 16, 64, 1, 128)
    ks = jax.random.split(jax.random.PRNGKey(1), 6)
    x = jax.random.normal(ks[0], (Bt, S2, H, P), jnp.bfloat16)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (Bt, S2, H)))
    A = -jnp.exp(jax.random.normal(ks[2], (H,)))
    Bm = jax.random.normal(ks[3], (Bt, S2, G, N), jnp.bfloat16)
    Cm = jax.random.normal(ks[4], (Bt, S2, G, N), jnp.bfloat16)
    Dv = jax.random.normal(ks[5], (H,))
    fn = jax.jit(lambda *a: _ssd_xla(*a, chunk=128)[0])
    us = timeit(fn, x, dt, A, Bm, Cm, Dv)
    # x in + y out (bf16) + B/C projections (bf16) + dt (f32)
    io_bytes = (2 * Bt * S2 * H * P * 2 + 2 * Bt * S2 * G * N * 2
                + Bt * S2 * H * 4)
    gbps = io_bytes / (us / 1e6) / 1e9
    emit(f"ssd_scan/xla_S{S2}", us,
         f"heads={H} state={N} io_GBps={gbps:.2f}",
         gbps=round(gbps, 2), roofline_frac=frac(gbps))

    # --- dequant + aggregate (fused int8 server step, DESIGN.md §12):
    # reads C int8 payload rows + the [C, M/chunk] f32 scale grid and
    # writes one f32 row — a quarter of weighted_aggregate's traffic
    # for the same reduction, so its *bandwidth* roofline fraction is
    # what the gate tracks (Pallas path validated in interpret mode by
    # tests/test_compressors.py; this measures the XLA route)
    chunk = 256
    q8 = jax.random.randint(jax.random.PRNGKey(5), (C, M), -127, 128,
                            jnp.int8)
    sc = jax.random.uniform(jax.random.PRNGKey(6), (C, M // chunk),
                            jnp.float32, 1e-4, 1e-2)
    fn = jax.jit(lambda w, s, q: dequant_aggregate(w, s, q, chunk=chunk,
                                                   impl="auto"))
    us = timeit(fn, ww, sc, q8)
    io_bytes = C * M + C * (M // chunk) * 4 + M * 4    # q8 + scales + out
    gbps = io_bytes / (us / 1e6) / 1e9
    emit(f"kernels/dequant_aggregate_C{C}_M{M}", us,
         f"read_GBps={gbps:.2f}", gbps=round(gbps, 2),
         roofline_frac=frac(gbps))

    # --- robust combine (per-coordinate trimmed mean via sorting network
    # vs the jnp.sort oracle; the Pallas kernel targets TPU, validated by
    # the interpret-mode parity sweep in tests/test_kernels_robust.py)
    xr = jax.random.normal(jax.random.PRNGKey(2), (C, M), jnp.float32)
    for impl in ("network", "sort"):
        fn = jax.jit(lambda x, _i=impl: robust_combine(
            x, mode="trimmed_mean", trim_fraction=0.25, impl=_i))
        us = timeit(fn, xr, iters=3)
        gbps = C * M * 4 / (us / 1e6) / 1e9
        emit(f"robust_combine/{impl}_C{C}_M{M}", us,
             f"read_GBps={gbps:.2f}", gbps=round(gbps, 2),
             roofline_frac=frac(gbps))


if __name__ == "__main__":
    main()
