"""Benchmark harness — one module per paper table/figure + system benches.

Prints ``name,us_per_call,derived`` CSV rows. Fast mode is the default
(CPU-budget scales); set REPRO_BENCH_FULL=1 for paper-scale runs.

  PYTHONPATH=src python -m benchmarks.run            # everything
  PYTHONPATH=src python -m benchmarks.run kernels    # one suite

Suites listed in ``JSON_SUITES`` additionally dump their rows as
``BENCH_<suite>.json`` (machine-readable: name, us_per_call, and any
structured extras such as GB/s and roofline fraction) — the perf
trajectory artifact CI uploads per commit.
"""
import json
import sys
import time

from benchmarks import common

SUITES = [
    ("kernels", "benchmarks.bench_kernels"),          # kernel micro
    ("crosstest", "benchmarks.bench_crosstest"),      # K×N eval fast path
    ("aggregation", "benchmarks.bench_aggregation"),  # FedTest server op
    ("comm", "benchmarks.bench_comm"),                # Sec. V-A accounting
    ("population", "benchmarks.bench_population"),    # cohort N-sweep (§11)
    ("roofline", "benchmarks.bench_roofline"),        # dry-run artifacts
    ("score_power", "benchmarks.bench_score_power"),  # Sec. V-B ablation
    ("testers", "benchmarks.bench_testers"),          # Sec. V-C ablation
    ("faults", "benchmarks.bench_faults"),            # dropout sweep (§9)
    ("convergence", "benchmarks.bench_convergence"),  # Figs. 4-5
]

JSON_SUITES = {"aggregation", "kernels", "crosstest", "population",
               "comm"}


def main() -> int:
    want = set(sys.argv[1:])
    failed = []
    print("name,us_per_call,derived")
    for name, module in SUITES:
        if want and name not in want:
            continue
        common.ROWS.clear()
        t0 = time.time()
        try:
            mod = __import__(module, fromlist=["main"])
            mod.main()
        except Exception as e:  # keep the harness alive per-suite...
            print(f"{name}/ERROR,0,{e!r}", flush=True)
            failed.append(name)
        if name in JSON_SUITES and common.ROWS:
            path = f"BENCH_{name}.json"
            with open(path, "w") as f:
                json.dump(common.ROWS, f, indent=1)
            print(f"# wrote {path} ({len(common.ROWS)} rows)", flush=True)
        print(f"# suite {name} done in {time.time() - t0:.0f}s", flush=True)
    if failed:
        # ...but never exit 0: a crashed JSON suite would leave the
        # committed BENCH_*.json in the worktree and the perf gate
        # would silently compare the baseline against itself
        print(f"# FAILED suites: {failed}", flush=True)
    return 1 if failed else 0


if __name__ == '__main__':
    sys.exit(main())
