"""Communication-cost accounting (paper Sec. V-A): orthogonal-RB uplink
volume per round, D2D tester traffic, the pod-side ring vs all-gather
exchange volume for the distributed FedTest round, the *measured*
cohort-gather volume of the population tier (DESIGN.md §11) next to the
modelled dense exchange it replaces, and the *measured* per-client
payload bytes of every registered update compressor (DESIGN.md §12)
against the dense f32 delta on an LM-backbone update."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.configs import get_config
from repro.core.selection import rb_schedule


def main(fast: bool = True):
    model_bytes = 4 * get_config("fedtest-cnn").param_count()
    for N, K in [(10, 3), (20, 5), (50, 10)]:
        sched = rb_schedule(np.arange(K), num_users=N,
                            model_bytes=model_bytes)
        emit(f"comm/rb_N{N}_K{K}", 0.0,
             f"slots={sched['num_slots']} "
             f"uplink_MB={sched['uplink_bytes'] / 1e6:.2f} "
             f"d2d_MB={sched['d2d_bytes'] / 1e6:.2f}")

    # pod exchange volume per client for the cross-testing phase:
    #   ring: (N-1) x model in/out per device; all-gather: (N-1) x model in
    # but N x model peak memory. Same volume, different high-water mark.
    for arch in ("qwen2-0.5b", "qwen3-1.7b"):
        n = get_config(arch).param_count() * 2     # bf16
        for N in (8, 16):
            ring = (N - 1) * n
            emit(f"comm/pod_ring_{arch}_N{N}", 0.0,
                 f"exchange_GB_per_client={ring / 1e9:.2f} "
                 f"peak_mem_models=2 allgather_peak_models={N}")

    # measured bytes one population-tier round moves (DESIGN.md §11):
    # the *actual* ``.nbytes`` of the arrays a cohort round gathers —
    # C model uploads + the cohort's train batches + the K testers'
    # eval rows + the dense [N] score/mask vectors — next to the
    # modelled dense exchange at the same N, which is what the cohort
    # gather replaces. The dense rows above are closed-form; these are
    # summed off concrete device arrays so the accounting cannot drift
    # from the engine's real gather surface.
    from repro.data.population import make_synthetic_population
    from repro.models import build_model

    cfg = get_config("fedtest-mlp-mnist").replace(mlp_hidden=(32,))
    params = build_model(cfg).init(jax.random.PRNGKey(0))
    pbytes = sum(l.size * l.dtype.itemsize
                 for l in jax.tree_util.tree_leaves(params))
    K, eval_batch, local_steps, batch = 4, 8, 1, 4
    for N, C in [(10_000, 64), (100_000, 64)]:
        data = make_synthetic_population(N, per_client=16, global_test=64,
                                         server=64, seed=0)
        cx, cy = data.cohort_train(jnp.arange(C))
        bx, by = (cx[:, :local_steps * batch], cy[:, :local_steps * batch])
        tx, ty = data.tester_batches(jnp.arange(K), eval_batch)
        scores = jnp.zeros((N,), jnp.float32)
        batch_bytes = sum(int(a.nbytes) for a in (bx, by, tx, ty))
        state_bytes = 3 * int(scores.nbytes)    # scores + mask + losses
        gather = C * pbytes + batch_bytes + state_bytes
        dense_ring = (N - 1) * pbytes
        emit(f"comm/population_gather_N{N}_C{C}", 0.0,
             f"measured_MB={gather / 1e6:.2f} "
             f"dense_ring_MB={dense_ring / 1e6:.1f} "
             f"reduction={dense_ring / gather:.0f}x")

    # measured bytes one compressed exchange moves per client per round
    # (DESIGN.md §12): encode a real LM-backbone update through every
    # registered compressor and sum the *concrete payload leaves'*
    # ``.nbytes`` — not a closed-form model, so sparsity bookkeeping
    # (top-k indices), quantisation scale vectors and factor shapes all
    # bill their true wire cost. The dense baseline is the f32 flat
    # delta the identity path ships.
    from repro.config import reduce_for_smoke
    from repro.core.engine import flat_update_dim
    from repro.models import build_model
    from repro.strategies import COMPRESSORS

    lm_cfg = reduce_for_smoke(get_config("qwen2-0.5b")).replace(
        dtype="float32")
    lm_model = build_model(lm_cfg)
    dim = flat_update_dim(lm_model)
    # a synthetic but dense-spectrum update: the payload size of every
    # registered compressor is data-independent (fixed k / chunk grid /
    # rank), so any full-support vector measures the real wire cost
    update = jax.random.normal(jax.random.PRNGKey(0), (dim,),
                               jnp.float32) * 1e-2
    dense_bytes = int(update.nbytes)
    for name, kwargs in [("identity", {}), ("int8", {}),
                         ("topk", {"k": 0.05}),
                         ("lowrank", {"rank": 4})]:
        comp = COMPRESSORS.build(name, kwargs, dict(dim=dim))
        payload, _ = jax.jit(comp.encode)(jnp.zeros((dim,), jnp.float32),
                                          update)
        payload = jax.tree_util.tree_map(np.asarray, payload)
        measured = int(comp.payload_bytes(payload))
        emit(f"comm/compressor_{name}_{lm_cfg.name}", 0.0,
             f"dim={dim} measured_MB={measured / 1e6:.3f} "
             f"dense_MB={dense_bytes / 1e6:.3f} "
             f"reduction={dense_bytes / measured:.1f}x",
             measured_bytes=measured, dense_bytes=dense_bytes,
             bytes_reduction=round(dense_bytes / measured, 2))


if __name__ == "__main__":
    main()
