"""Communication-cost accounting (paper Sec. V-A): orthogonal-RB uplink
volume per round, D2D tester traffic, and the pod-side ring vs all-gather
exchange volume for the distributed FedTest round."""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit
from repro.configs import get_config
from repro.core.selection import rb_schedule


def main(fast: bool = True):
    model_bytes = 4 * get_config("fedtest-cnn").param_count()
    for N, K in [(10, 3), (20, 5), (50, 10)]:
        sched = rb_schedule(np.arange(K), num_users=N,
                            model_bytes=model_bytes)
        emit(f"comm/rb_N{N}_K{K}", 0.0,
             f"slots={sched['num_slots']} "
             f"uplink_MB={sched['uplink_bytes'] / 1e6:.2f} "
             f"d2d_MB={sched['d2d_bytes'] / 1e6:.2f}")

    # pod exchange volume per client for the cross-testing phase:
    #   ring: (N-1) x model in/out per device; all-gather: (N-1) x model in
    # but N x model peak memory. Same volume, different high-water mark.
    for arch in ("qwen2-0.5b", "qwen3-1.7b"):
        n = get_config(arch).param_count() * 2     # bf16
        for N in (8, 16):
            ring = (N - 1) * n
            emit(f"comm/pod_ring_{arch}_N{N}", 0.0,
                 f"exchange_GB_per_client={ring / 1e9:.2f} "
                 f"peak_mem_models=2 allgather_peak_models={N}")


if __name__ == "__main__":
    main()
