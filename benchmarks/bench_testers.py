"""Paper Sec. V-C ablation: number of testers K (and lying testers).
"Engaging all users as testers within the evaluation process is
unnecessary" — sweeps K and the lying-tester count."""
from __future__ import annotations

import jax

from benchmarks.common import FAST, emit
from repro.config import FedConfig, TrainConfig
from repro.configs import get_config
from repro.core import FederatedTrainer
from repro.data import MNIST_LIKE, make_federated_image_dataset
from repro.models import build_model


def main(fast: bool = FAST):
    cfg = get_config("fedtest-cnn-mnist")
    if fast:
        cfg = cfg.replace(cnn_channels=(8, 16, 16), cnn_hidden=32)
    model = build_model(cfg)
    users = 8
    data = make_federated_image_dataset(MNIST_LIKE, users,
                                        num_samples=4000, global_test=400,
                                        seed=2)
    tc = TrainConfig(optimizer="sgd", lr=0.1, schedule="constant",
                     batch_size=16, grad_clip=0.0, remat=False)
    rounds = 8 if fast else 30

    for K in (1, 2, 4, 8):
        fed = FedConfig(num_users=users, num_testers=K, num_malicious=2,
                        local_steps=10, attack="random_weights", attack_scale=4.0)
        trainer = FederatedTrainer(model, fed, tc, eval_batch=128)
        state = trainer.init(jax.random.PRNGKey(0))
        for _ in range(rounds):
            state, metrics = trainer.run_round(state, data)
        acc = trainer.global_accuracy(state, data)
        emit(f"testers/K{K}", 0.0,
             f"final_acc={acc:.4f} "
             f"malicious_weight={float(metrics['malicious_weight']):.5f}")

    for liars in (0, 1, 2):
        fed = FedConfig(num_users=users, num_testers=4, num_malicious=2,
                        local_steps=10, attack="random_weights", attack_scale=4.0,
                        lying_testers=liars)
        trainer = FederatedTrainer(model, fed, tc, eval_batch=128)
        state = trainer.init(jax.random.PRNGKey(0))
        for _ in range(rounds):
            state, metrics = trainer.run_round(state, data)
        acc = trainer.global_accuracy(state, data)
        emit(f"lying_testers/L{liars}", 0.0,
             f"final_acc={acc:.4f} "
             f"malicious_weight={float(metrics['malicious_weight']):.5f}")


if __name__ == "__main__":
    main()
