"""Paper Figs. 4 & 5: convergence of FedTest vs FedAvg vs accuracy-based,
with and without malicious (random-weight) users, on CIFAR-like and
MNIST-like synthetic data.

Emits one CSV row per (dataset, aggregator, malicious) curve; the derived
column carries the accuracy trajectory summary. Full curves are written to
experiments/convergence/*.json for EXPERIMENTS.md.
"""
from __future__ import annotations

import json
import os
import time

import jax

from benchmarks.common import FAST, emit
from repro.config import FedConfig, TrainConfig
from repro.configs import get_config
from repro.core import FederatedTrainer
from repro.data import CIFAR_LIKE, MNIST_LIKE, make_federated_image_dataset
from repro.models import build_model

OUT = "experiments/convergence"


def _setup(dataset: str, fast: bool):
    if dataset == "cifar_like":
        spec, arch = CIFAR_LIKE, "fedtest-cnn"
    else:
        spec, arch = MNIST_LIKE, "fedtest-cnn-mnist"
    cfg = get_config(arch)
    if fast:
        cfg = cfg.replace(cnn_channels=(8, 16, 16), cnn_hidden=32)
    users = 8 if fast else 20
    samples = 4000 if fast else 20000
    data = make_federated_image_dataset(spec, users, num_samples=samples,
                                        global_test=500 if fast else 2000,
                                        seed=0)
    return cfg, users, data


def run_curve(dataset: str, aggregator: str, malicious: int,
              rounds: int, fast: bool = FAST):
    cfg, users, data = _setup(dataset, fast)
    model = build_model(cfg)
    fed = FedConfig(num_users=users, num_testers=max(users // 4, 2),
                    num_malicious=malicious, local_steps=10,
                    attack="random_weights", attack_scale=4.0,
                    aggregator=aggregator)
    tc = TrainConfig(optimizer="sgd", lr=0.1, schedule="constant",
                     batch_size=16 if fast else 32, grad_clip=0.0,
                     remat=False)
    trainer = FederatedTrainer(model, fed, tc,
                               eval_batch=128 if fast else 256)
    t0 = time.time()
    _, hist = trainer.run(jax.random.PRNGKey(0), data, rounds=rounds)
    wall = time.time() - t0
    hist["wall_s"] = wall
    hist["dataset"] = dataset
    hist["aggregator"] = aggregator
    hist["malicious"] = malicious
    return hist


def rounds_to_reach(hist, target: float):
    for r, a in zip(hist["round"], hist["global_accuracy"]):
        if a >= target:
            return r
    return None


def main(fast: bool = FAST):
    os.makedirs(OUT, exist_ok=True)
    rounds = 12 if fast else 60
    scenarios = []
    for dataset, mal in [("cifar_like", 0), ("cifar_like", 3),
                         ("mnist_like", 0), ("mnist_like", 4)]:
        if fast:
            mal = min(mal, 2)
        for agg in ("fedtest", "fedavg", "accuracy_based"):
            scenarios.append((dataset, agg, mal))

    results = {}
    for dataset, agg, mal in scenarios:
        hist = run_curve(dataset, agg, mal, rounds, fast)
        results[f"{dataset}|{agg}|m{mal}"] = hist
        tag = f"{dataset}__{agg}__m{mal}"
        with open(os.path.join(OUT, tag + ".json"), "w") as f:
            json.dump(hist, f, indent=1)
        final = hist["global_accuracy"][-1]
        per_round_us = hist["wall_s"] / max(len(hist["round"]), 1) * 1e6
        emit(f"convergence/{tag}", per_round_us,
             f"final_acc={final:.4f} "
             f"acc@3={hist['global_accuracy'][min(2, rounds-1)]:.4f}")

    # paper-claim checks (derived summary rows)
    for dataset, mal in [("cifar_like", 3 if not fast else 2),
                         ("mnist_like", 4 if not fast else 2)]:
        ft = results[f"{dataset}|fedtest|m{mal}"]["global_accuracy"][-1]
        fa = results[f"{dataset}|fedavg|m{mal}"]["global_accuracy"][-1]
        ab = results[f"{dataset}|accuracy_based|m{mal}"][
            "global_accuracy"][-1]
        emit(f"claim/{dataset}_malicious_gap", 0.0,
             f"fedtest={ft:.4f} fedavg={fa:.4f} accuracy_based={ab:.4f} "
             f"fedtest_wins={ft > max(fa, ab)}")
    return results


if __name__ == "__main__":
    main()
