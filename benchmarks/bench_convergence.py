"""Paper Figs. 4 & 5: convergence of FedTest vs FedAvg vs accuracy-based,
with and without malicious (random-weight) users, on CIFAR-like and
MNIST-like synthetic data.

Emits one CSV row per (dataset, aggregator, malicious) curve; the derived
column carries the accuracy trajectory summary. Full curves are written to
experiments/convergence/*.json for EXPERIMENTS.md.

Also measures the **scanned multi-round driver's dispatch amortisation**
(DESIGN.md §2): per-round wall clock of ``rounds_per_call=8`` (one fused
``lax.scan`` program per 8-round chunk, donated state buffers) against
per-round dispatch, on a deliberately tiny round where the Python/XLA
dispatch overhead is visible next to the compute.
"""
from __future__ import annotations

import json
import os
import time

import jax

from benchmarks.common import FAST, emit
from repro.config import FedConfig, TrainConfig
from repro.configs import get_config
from repro.core import FederatedTrainer
from repro.data import CIFAR_LIKE, MNIST_LIKE, make_federated_image_dataset
from repro.models import build_model

OUT = "experiments/convergence"


def scan_amortisation(fast: bool = FAST, rounds_per_call: int = 8):
    """Per-round wall clock: scanned driver vs one dispatch per round."""
    cfg = get_config("fedtest-cnn-mnist").replace(cnn_channels=(2, 2, 2),
                                                  cnn_hidden=4)
    model = build_model(cfg)
    users = 2
    data = make_federated_image_dataset(MNIST_LIKE, users, num_samples=200,
                                        global_test=64, seed=0)
    fed = FedConfig(num_users=users, num_testers=1, local_steps=1,
                    attack="none")
    tc = TrainConfig(optimizer="sgd", lr=0.1, schedule="constant",
                     batch_size=2, grad_clip=0.0, remat=False)
    chunks = 8 if fast else 32
    rounds = chunks * rounds_per_call

    single = FederatedTrainer(model, fed, tc, eval_batch=16)
    state = single.init(jax.random.PRNGKey(0))
    state, m = single.run_round(state, data)            # compile
    jax.block_until_ready(m["local_loss"])
    t0 = time.perf_counter()
    for _ in range(rounds):
        state, m = single.run_round(state, data)
    jax.block_until_ready(m["local_loss"])
    us_single = (time.perf_counter() - t0) / rounds * 1e6

    scanned = FederatedTrainer(model, fed, tc, eval_batch=16,
                               rounds_per_call=rounds_per_call)
    state = scanned.init(jax.random.PRNGKey(0))
    state, m = scanned._scan_fn(state, data)            # compile
    jax.block_until_ready(m["local_loss"])
    t0 = time.perf_counter()
    for _ in range(chunks):
        state, m = scanned._scan_fn(state, data)
    jax.block_until_ready(m["local_loss"])
    us_scan = (time.perf_counter() - t0) / rounds * 1e6
    assert scanned.num_traces == 1, scanned.num_traces

    emit("convergence/scan_dispatch_rpc1", us_single, "per-round dispatch")
    emit(f"convergence/scan_dispatch_rpc{rounds_per_call}", us_scan,
         f"speedup_vs_rpc1={us_single / us_scan:.2f}x",
         speedup=round(us_single / us_scan, 3))
    return us_single, us_scan


def _setup(dataset: str, fast: bool):
    if dataset == "cifar_like":
        spec, arch = CIFAR_LIKE, "fedtest-cnn"
    else:
        spec, arch = MNIST_LIKE, "fedtest-cnn-mnist"
    cfg = get_config(arch)
    if fast:
        cfg = cfg.replace(cnn_channels=(8, 16, 16), cnn_hidden=32)
    users = 8 if fast else 20
    samples = 4000 if fast else 20000
    data = make_federated_image_dataset(spec, users, num_samples=samples,
                                        global_test=500 if fast else 2000,
                                        seed=0)
    return cfg, users, data


def run_curve(dataset: str, aggregator: str, malicious: int,
              rounds: int, fast: bool = FAST):
    cfg, users, data = _setup(dataset, fast)
    model = build_model(cfg)
    fed = FedConfig(num_users=users, num_testers=max(users // 4, 2),
                    num_malicious=malicious, local_steps=10,
                    attack="random_weights", attack_scale=4.0,
                    aggregator=aggregator)
    tc = TrainConfig(optimizer="sgd", lr=0.1, schedule="constant",
                     batch_size=16 if fast else 32, grad_clip=0.0,
                     remat=False)
    trainer = FederatedTrainer(model, fed, tc,
                               eval_batch=128 if fast else 256)
    t0 = time.time()
    _, hist = trainer.run(jax.random.PRNGKey(0), data, rounds=rounds)
    wall = time.time() - t0
    hist["wall_s"] = wall
    hist["dataset"] = dataset
    hist["aggregator"] = aggregator
    hist["malicious"] = malicious
    return hist


def rounds_to_reach(hist, target: float):
    for r, a in zip(hist["round"], hist["global_accuracy"]):
        if a >= target:
            return r
    return None


def main(fast: bool = FAST):
    os.makedirs(OUT, exist_ok=True)
    scan_amortisation(fast)
    rounds = 12 if fast else 60
    scenarios = []
    for dataset, mal in [("cifar_like", 0), ("cifar_like", 3),
                         ("mnist_like", 0), ("mnist_like", 4)]:
        if fast:
            mal = min(mal, 2)
        for agg in ("fedtest", "fedavg", "accuracy_based"):
            scenarios.append((dataset, agg, mal))

    results = {}
    for dataset, agg, mal in scenarios:
        hist = run_curve(dataset, agg, mal, rounds, fast)
        results[f"{dataset}|{agg}|m{mal}"] = hist
        tag = f"{dataset}__{agg}__m{mal}"
        with open(os.path.join(OUT, tag + ".json"), "w") as f:
            json.dump(hist, f, indent=1)
        final = hist["global_accuracy"][-1]
        per_round_us = hist["wall_s"] / max(len(hist["round"]), 1) * 1e6
        emit(f"convergence/{tag}", per_round_us,
             f"final_acc={final:.4f} "
             f"acc@3={hist['global_accuracy'][min(2, rounds-1)]:.4f}")

    # paper-claim checks (derived summary rows)
    for dataset, mal in [("cifar_like", 3 if not fast else 2),
                         ("mnist_like", 4 if not fast else 2)]:
        ft = results[f"{dataset}|fedtest|m{mal}"]["global_accuracy"][-1]
        fa = results[f"{dataset}|fedavg|m{mal}"]["global_accuracy"][-1]
        ab = results[f"{dataset}|accuracy_based|m{mal}"][
            "global_accuracy"][-1]
        emit(f"claim/{dataset}_malicious_gap", 0.0,
             f"fedtest={ft:.4f} fedavg={fa:.4f} accuracy_based={ab:.4f} "
             f"fedtest_wins={ft > max(fa, ab)}")
    return results


if __name__ == "__main__":
    main()
