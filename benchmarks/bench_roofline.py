"""Roofline summary rows derived from the dry-run artifacts
(experiments/dryrun/*.json). Emits one row per (arch, shape) single-pod
baseline; recomputes MODEL_FLOPS/useful ratio from the (fixed) analytic
param counts rather than trusting the values stored in older artifacts."""
from __future__ import annotations

import glob
import json
import os

from benchmarks.common import emit
from repro.config import INPUT_SHAPES
from repro.configs import get_config
from repro.roofline import model_flops

DRYRUN_DIR = "experiments/dryrun"


def main(fast: bool = True):
    files = sorted(glob.glob(os.path.join(DRYRUN_DIR, "*__single.json")))
    if not files:
        emit("roofline/none", 0.0, "no dryrun artifacts yet")
        return
    for f in files:
        rec = json.load(open(f))
        if rec.get("status") != "ok":
            emit(f"roofline/{rec['arch']}__{rec['shape']}", 0.0,
                 f"status={rec.get('status')}")
            continue
        r = rec["roofline"]
        cfg = get_config(rec["arch"])
        shape = INPUT_SHAPES[rec["shape"]]
        mf = model_flops(cfg, shape)
        useful = mf / rec["num_chips"] / max(
            rec["cost"]["flops_per_device"], 1.0)
        emit(f"roofline/{rec['arch']}__{rec['shape']}",
             max(r["compute_s"], r["memory_s"], r["collective_s"]) * 1e6,
             f"bottleneck={r['bottleneck']} "
             f"compute_s={r['compute_s']:.3e} "
             f"memory_s={r['memory_s']:.3e} "
             f"collective_s={r['collective_s']:.3e} "
             f"useful_ratio={useful:.3f}")


if __name__ == "__main__":
    main()
