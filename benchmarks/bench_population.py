"""Population-tier N-sweep: per-round cost flat in N (DESIGN.md §11).

Times one steady-state jitted round of the cohort engine
(:class:`~repro.core.engine.population.PopulationTrainer`, C = 64) over
N ∈ {10³, 10⁴, 10⁵} synthetic clients — :class:`~repro.data.population.
SyntheticPopulation` derives shards on gather, so no [N, ...] data
stack ever exists — next to dense :class:`~repro.core.engine.driver.
FederatedTrainer` reference rows at N ≤ 10³. The dense engine
replicates the [N, D] model stack every round, so its wall time and
model memory are linear in N where the population rows stay flat
(EXPERIMENTS.md §Population-bench); the in-bench assertion pins the
headline: the 10⁵-client round must cost < 3× the 10³-client round.

Each row carries ``clients`` / ``cohort`` / ``model_mem_bytes`` (the
per-device model high-water mark: C × params for the cohort engine,
N × params for dense). ``population/cohort_aggregate`` carries
``roofline_frac`` against the measured ``weighted_aggregate`` stream
reference — the row ``tools/check_bench.py`` gates; the wall-time
sweep rows ride in the artifact as the committed trajectory.
"""
from __future__ import annotations

import jax

from benchmarks.common import FAST, emit, timeit
from repro.config import FedConfig, TrainConfig
from repro.configs import get_config
from repro.core.engine.driver import FederatedTrainer
from repro.core.engine.population import PopulationTrainer
from repro.data.builders import make_federated_image_dataset
from repro.data.population import make_synthetic_population
from repro.data.synthetic import MNIST_LIKE
from repro.kernels.weighted_aggregate.ops import weighted_aggregate

COHORT = 64
POPULATIONS = (1_000, 10_000, 100_000)   # cohort-engine sweep
DENSE = (250, 1_000)                     # linear reference rows
K = 4                                    # testers
EVAL_BATCH = 8
BLOCK = 16                               # [K, block_C] eval tiles


def _param_bytes(params) -> int:
    return sum(l.size * l.dtype.itemsize
               for l in jax.tree_util.tree_leaves(params))


def _model():
    from repro.models import build_model
    cfg = get_config("fedtest-mlp-mnist").replace(mlp_hidden=(32,))
    return build_model(cfg)


def _train_cfg() -> TrainConfig:
    return TrainConfig(optimizer="sgd", lr=0.1, schedule="constant",
                       batch_size=4, grad_clip=0.0, remat=False)


def _time_population(n: int, model, iters: int):
    fed = FedConfig(num_users=n, num_testers=K, num_malicious=0,
                    attack="none", local_steps=1, cohort=COHORT,
                    participation=COHORT / n, rounds=1)
    data = make_synthetic_population(n, per_client=16, global_test=64,
                                     server=64, seed=0)
    trainer = PopulationTrainer(model, fed, _train_cfg(),
                                eval_batch=EVAL_BATCH,
                                crosstest_block=BLOCK,
                                testers_from_cohort=True)
    state = trainer.init(jax.random.PRNGKey(0))
    return timeit(trainer._round_fn, state, data, iters=iters)


def _time_dense(n: int, model, iters: int):
    fed = FedConfig(num_users=n, num_testers=K, num_malicious=0,
                    attack="none", local_steps=1, rounds=1)
    # iid partition so every client holds enough rows for the holdout
    # eval slice; ~45 rows/client keeps the [N, M, ...] stack modest
    data = make_federated_image_dataset(MNIST_LIKE, n,
                                        num_samples=45 * n,
                                        partition="iid", global_test=64,
                                        seed=0)
    trainer = FederatedTrainer(model, fed, _train_cfg(),
                               eval_batch=EVAL_BATCH)
    state = trainer.init(jax.random.PRNGKey(0))
    return timeit(trainer._round_fn, state, data, iters=iters)


def main(fast: bool = FAST):
    iters = 3 if fast else 5
    model = _model()
    pbytes = _param_bytes(model.init(jax.random.PRNGKey(0)))

    # the streaming-bandwidth roofline reference, measured on this host
    # back-to-back with the gated row (same idiom as bench_crosstest)
    C, M = (16, 1 << 20) if fast else (16, 1 << 22)
    xw = jax.random.normal(jax.random.PRNGKey(3), (C, M), jax.numpy.float32)
    ww = jax.random.uniform(jax.random.PRNGKey(4), (C,))
    fn = jax.jit(lambda x, w: weighted_aggregate(x, w, impl="auto"))
    us = timeit(fn, xw, ww)
    ref_gbps = C * M * 4 / (us / 1e6) / 1e9
    emit(f"population/stream_ref_C{C}_M{M}", us,
         f"read_GBps={ref_gbps:.2f}", gbps=round(ref_gbps, 2),
         roofline_frac=1.0)

    # the cohort engine's server op: one fused weighted sum over the
    # gathered [C, D] stack — the bandwidth-bound row the perf gate
    # tracks across commits
    Ma = (1 << 18) if fast else (1 << 20)
    xa = jax.random.normal(jax.random.PRNGKey(5), (COHORT, Ma),
                           jax.numpy.float32)
    wa = jax.random.uniform(jax.random.PRNGKey(6), (COHORT,))
    us = timeit(fn, xa, wa)
    gbps = COHORT * Ma * 4 / (us / 1e6) / 1e9
    emit(f"population/cohort_aggregate_C{COHORT}", us,
         f"read_GBps={gbps:.2f}", gbps=round(gbps, 2),
         roofline_frac=round(gbps / ref_gbps, 4))

    dense_us = {}
    for n in DENSE:
        us = _time_dense(n, model, iters)
        dense_us[n] = us
        emit(f"population/dense_N{n}", us,
             f"model_mem_MB={n * pbytes / 1e6:.1f}",
             clients=n, model_mem_bytes=n * pbytes)

    pop_us = {}
    for n in POPULATIONS:
        us = _time_population(n, model, iters)
        pop_us[n] = us
        emit(f"population/pop_N{n}_C{COHORT}", us,
             f"model_mem_MB={COHORT * pbytes / 1e6:.2f} "
             f"vs_dense_mem={n / COHORT:.0f}x",
             clients=n, cohort=COHORT,
             model_mem_bytes=COHORT * pbytes)

    # the headline: per-round cost flat in N across two decades where
    # the dense engine is linear by construction
    lo, hi = pop_us[POPULATIONS[0]], pop_us[POPULATIONS[-1]]
    emit(f"population/flatness_N{POPULATIONS[0]}_to_N{POPULATIONS[-1]}",
         hi, f"ratio={hi / lo:.2f}x_over_{POPULATIONS[-1] // POPULATIONS[0]}x_clients",
         ratio=round(hi / lo, 2))
    assert hi < 3.0 * lo, (
        f"population round not flat in N: {hi:.0f}us at "
        f"N={POPULATIONS[-1]} vs {lo:.0f}us at N={POPULATIONS[0]} "
        f"(ratio {hi / lo:.2f}x >= 3x)")
    assert pop_us[1_000] < dense_us[1_000], (
        f"cohort engine slower than dense at N=1000: "
        f"{pop_us[1_000]:.0f}us vs {dense_us[1_000]:.0f}us")


if __name__ == "__main__":
    main()
