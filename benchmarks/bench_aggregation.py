"""Server-side aggregation throughput (the FedTest hot-spot the
weighted_aggregate Pallas kernel targets on TPU; CPU numbers use the XLA
path, the kernel itself is validated in interpret mode).

Also sweeps **every registered aggregation strategy** by name: builds a
synthetic :class:`RoundContext` and times the jitted
``update_scores + weights`` computation, so any strategy added through
``repro.strategies`` gets per-round latency numbers for free.

The ``combine`` section benchmarks the second aggregation fast path —
the per-coordinate ``robust_combine`` sorting network — against both the
``jnp.sort`` oracle it must beat and the ``weighted_aggregate`` roofline
it should approach: the network reads the same ``C * M * 4`` bytes as
the weighted sum and does only ~C^2/2 row min/max ops on top, so its
effective bandwidth should land within ~2x of the weighted sum
(``roofline_frac`` in the emitted rows / ``BENCH_aggregation.json``),
while the general-sort path falls far behind.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import FAST, emit, timeit
from repro.core.scoring import init_scores
from repro.kernels.robust_combine.ops import robust_combine
from repro.kernels.weighted_aggregate.ops import weighted_aggregate
from repro.strategies import AGGREGATORS, RoundContext
from repro.utils import tree_weighted_sum


def strategy_weights_fn(agg):
    """Jittable (acc, scores, counts, updates, key) -> [N] weights.

    The :class:`RoundContext` is rebuilt inside the traced function (it
    carries the ``server_eval`` closure, which cannot cross the jit
    boundary as an argument); the server-eval stand-in is the tester
    consensus.
    """
    def weights_of(acc, scores, counts, updates, key):
        ctx = RoundContext(
            acc_matrix=acc, tester_ids=jnp.arange(acc.shape[0]),
            scores=scores, counts=counts, round_idx=scores.rounds_seen,
            key=key, updates=updates,
            server_eval=lambda: acc.mean(axis=0))
        scores2 = agg.update_scores(ctx)
        return agg.weights(ctx._replace(scores=scores2))
    return weights_of


def sweep_strategies(fast: bool = FAST):
    """Per-aggregator round-weight latency for every registered name."""
    shapes = [(8, 2, 1 << 14), (20, 5, 1 << 16)] if fast else \
        [(8, 2, 1 << 16), (20, 5, 1 << 18), (64, 8, 1 << 20)]
    for N, K, D in shapes:
        ks = jax.random.split(jax.random.PRNGKey(0), 3)
        acc = jax.random.uniform(ks[0], (K, N))
        scores = init_scores(N)
        counts = jnp.full((N,), 100.0)
        updates = jax.random.normal(ks[2], (N, D))
        for name in AGGREGATORS.names():
            agg = AGGREGATORS.build(name, defaults={"num_byzantine": 1})
            fn = jax.jit(strategy_weights_fn(agg))
            us = timeit(fn, acc, scores, counts, updates, ks[1])
            emit(f"aggregate/strategy_{name}_N{N}_D{D}", us, f"K={K}")


def sweep_robust_combine(fast: bool = FAST):
    """Coordinate-wise combine path vs sort oracle vs weighted-sum roofline.

    The acceptance sizes (C=16, M=2^22) run in both modes — they are the
    numbers the perf trajectory tracks in BENCH_aggregation.json.
    """
    sizes = [(8, 1 << 18), (16, 1 << 22)] if fast else \
        [(8, 1 << 20), (16, 1 << 22), (32, 1 << 22)]
    robust_impl = "pallas" if jax.default_backend() == "tpu" else "network"
    for C, M in sizes:
        x = jax.random.normal(jax.random.PRNGKey(0), (C, M), jnp.float32)
        w = jax.random.uniform(jax.random.PRNGKey(1), (C,))
        read_bytes = C * M * 4

        fn = jax.jit(lambda x, w: weighted_aggregate(x, w, impl="auto"))
        wagg_us = timeit(fn, x, w)
        wagg_gbps = read_bytes / (wagg_us / 1e6) / 1e9
        emit(f"aggregate/wagg_roofline_C{C}_M{M}", wagg_us,
             f"read_GBps={wagg_gbps:.2f}", gbps=round(wagg_gbps, 2),
             roofline_frac=1.0)

        for mode in ("trimmed_mean", "median"):
            fn = jax.jit(lambda x, _m=mode: robust_combine(
                x, mode=_m, trim_fraction=0.25, impl=robust_impl))
            us = timeit(fn, x)
            gbps = read_bytes / (us / 1e6) / 1e9
            frac = gbps / wagg_gbps
            emit(f"aggregate/robust_{mode}_{robust_impl}_C{C}_M{M}", us,
                 f"read_GBps={gbps:.2f} roofline_frac={frac:.2f}",
                 gbps=round(gbps, 2), roofline_frac=round(frac, 3))

        # the per-leaf jnp.sort baseline the network path must beat
        fn = jax.jit(lambda x: robust_combine(x, mode="trimmed_mean",
                                              trim_fraction=0.25,
                                              impl="sort"))
        us = timeit(fn, x, iters=3)
        gbps = read_bytes / (us / 1e6) / 1e9
        emit(f"aggregate/robust_trimmed_mean_sort_C{C}_M{M}", us,
             f"read_GBps={gbps:.2f} roofline_frac={gbps / wagg_gbps:.2f}",
             gbps=round(gbps, 2),
             roofline_frac=round(gbps / wagg_gbps, 3))


def main(fast: bool = FAST):
    sweep_strategies(fast)
    sweep_robust_combine(fast)
    sizes = [(8, 1 << 18), (20, 1 << 20)] if fast else \
        [(8, 1 << 20), (20, 1 << 22), (64, 1 << 22)]
    for C, M in sizes:
        x = jax.random.normal(jax.random.PRNGKey(0), (C, M), jnp.float32)
        w = jax.random.uniform(jax.random.PRNGKey(1), (C,))
        fn = jax.jit(lambda x, w: weighted_aggregate(x, w, impl="naive"))
        us = timeit(fn, x, w)
        gbps = C * M * 4 / (us / 1e6) / 1e9
        emit(f"aggregate/xla_C{C}_M{M}", us, f"read_GBps={gbps:.2f}",
             gbps=round(gbps, 2))

    # pytree path (stacked CNN-scale model)
    tree = {f"l{i}": jax.random.normal(jax.random.PRNGKey(i), (12, 64, 64))
            for i in range(8)}
    w = jax.nn.softmax(jnp.arange(12.0))
    fn = jax.jit(lambda t, w: tree_weighted_sum(t, w))
    us = timeit(fn, tree, w)
    emit("aggregate/pytree_12clients", us, "leaves=8")


if __name__ == "__main__":
    main()
