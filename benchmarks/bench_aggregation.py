"""Server-side aggregation throughput (the FedTest hot-spot the
weighted_aggregate Pallas kernel targets on TPU; CPU numbers use the XLA
path, the kernel itself is validated in interpret mode)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import FAST, emit, timeit
from repro.kernels.weighted_aggregate.ops import weighted_aggregate
from repro.utils import tree_weighted_sum


def main(fast: bool = FAST):
    sizes = [(8, 1 << 18), (20, 1 << 20)] if fast else \
        [(8, 1 << 20), (20, 1 << 22), (64, 1 << 22)]
    for C, M in sizes:
        x = jax.random.normal(jax.random.PRNGKey(0), (C, M), jnp.float32)
        w = jax.random.uniform(jax.random.PRNGKey(1), (C,))
        fn = jax.jit(lambda x, w: weighted_aggregate(x, w, impl="naive"))
        us = timeit(fn, x, w)
        gbps = C * M * 4 / (us / 1e6) / 1e9
        emit(f"aggregate/xla_C{C}_M{M}", us, f"read_GBps={gbps:.2f}")

    # pytree path (stacked CNN-scale model)
    tree = {f"l{i}": jax.random.normal(jax.random.PRNGKey(i), (12, 64, 64))
            for i in range(8)}
    w = jax.nn.softmax(jnp.arange(12.0))
    fn = jax.jit(lambda t, w: tree_weighted_sum(t, w))
    us = timeit(fn, tree, w)
    emit("aggregate/pytree_12clients", us, "leaves=8")


if __name__ == "__main__":
    main()
