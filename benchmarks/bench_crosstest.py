"""Cross-testing fast-path benchmark: the K×N eval matrix per round.

Sweeps {mlp, cnn, decoder} × N ∈ {8, 16, 32} clients at K = 4 testers
(EXPERIMENTS.md §Crosstest-bench) and times one full [K, N] accuracy
matrix through both dispatch models of DESIGN.md §10:

* ``reference`` — N sequential eval dispatches inside the tester vmap
  (the historical loop, kept as the parity oracle);
* ``batched``   — one fused [N, batch] forward per tester via vmap over
  the model axis.

Each batched row carries ``eval_GBps`` (bytes a tester sweep must touch:
K × (N × params + eval batch)) and ``roofline_frac`` against the
measured ``weighted_aggregate`` streaming reference — the fraction is
what ``tools/check_bench.py`` gates (>15% regression fails CI). The
``dispatches`` fields count trace-time ``eval_fn`` call sites, the
machine-checkable form of the ≥3× fewer-dispatches claim: batched
traces 1 eval per tester sweep where reference traces N.

LM eval routes through the kernel ops (``make_eval_fn`` defaults to
:func:`~repro.core.cross_testing.kernel_route_model`), so the decoder
rows measure the flash-attention path, not the naive oracle.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import FAST, emit, timeit
from repro.config import reduce_for_smoke
from repro.configs import get_config
from repro.core.cross_testing import cross_test_accuracies, make_eval_fn
from repro.kernels.weighted_aggregate.ops import weighted_aggregate

K = 4                       # testers per sweep
CLIENTS = (8, 16, 32)       # N sweep


def _param_bytes(params) -> int:
    return sum(l.size * l.dtype.itemsize
               for l in jax.tree_util.tree_leaves(params))


def _arch_case(arch: str, fast: bool):
    """(model, tester_x, tester_y, batch_bytes) for one sweep arch."""
    if arch == "decoder":
        cfg = reduce_for_smoke(get_config("qwen2-0.5b")).replace(
            dtype="float32")
        model = get_model(cfg)
        B, S = (2, 64) if fast else (8, 256)
        tx = jax.random.randint(jax.random.PRNGKey(1), (K, B, S), 0,
                                cfg.vocab_size)
        ty = jax.random.randint(jax.random.PRNGKey(2), (K, B, S), -1,
                                cfg.vocab_size)
        batch_bytes = tx.size * 4 + ty.size * 4
        return model, tx, ty, batch_bytes
    arch_id = "fedtest-mlp-mnist" if arch == "mlp" else "fedtest-cnn-mnist"
    cfg = get_config(arch_id)
    if fast and arch == "cnn":
        cfg = cfg.replace(cnn_channels=(4, 8), cnn_hidden=32)
    model = get_model(cfg)
    B = (32 if arch == "cnn" else 64) if fast else 512
    tx = jax.random.normal(
        jax.random.PRNGKey(1),
        (K, B, cfg.image_size, cfg.image_size, cfg.image_channels),
        jnp.float32)
    ty = jax.random.randint(jax.random.PRNGKey(2), (K, B), 0,
                            cfg.num_classes)
    batch_bytes = tx.size * 4 + ty.size * 4
    return model, tx, ty, batch_bytes


def get_model(cfg):
    from repro.models import build_model
    return build_model(cfg)


def main(fast: bool = FAST):
    # the streaming-bandwidth roofline reference, measured on this host
    # back-to-back with the eval rows (same rationale as bench_kernels)
    C, M = (16, 1 << 20) if fast else (16, 1 << 22)
    xw = jax.random.normal(jax.random.PRNGKey(3), (C, M), jnp.float32)
    ww = jax.random.uniform(jax.random.PRNGKey(4), (C,))
    fn = jax.jit(lambda x, w: weighted_aggregate(x, w, impl="auto"))
    us = timeit(fn, xw, ww)
    ref_gbps = C * M * 4 / (us / 1e6) / 1e9
    emit(f"crosstest/stream_ref_C{C}_M{M}", us,
         f"read_GBps={ref_gbps:.2f}", gbps=round(ref_gbps, 2),
         roofline_frac=1.0)

    for arch in ("mlp", "cnn", "decoder"):
        model, tx, ty, batch_bytes = _arch_case(arch, fast)
        eval_fn = make_eval_fn(model)
        for n in CLIENTS:
            keys = jax.random.split(jax.random.PRNGKey(0), n)
            stacked = jax.vmap(model.init)(keys)
            pbytes = _param_bytes(stacked) // n

            # trace-time dispatch counter: every eval_fn call site in the
            # traced sweep is one fused eval dispatch per tester
            calls = {"n": 0}

            def counted(p, x, y):
                calls["n"] += 1
                return eval_fn(p, x, y)

            results = {}
            for impl in ("reference", "batched"):
                calls["n"] = 0
                fn = jax.jit(lambda s, x, y, _i=impl: cross_test_accuracies(
                    counted, s, x, y, impl=_i))
                us = timeit(fn, stacked, tx, ty, iters=3)
                results[impl] = (us, calls["n"])

            ref_us, ref_disp = results["reference"]
            bat_us, bat_disp = results["batched"]
            # bytes one [K, N] sweep must touch: every tester reads all N
            # models plus its own eval batch
            sweep_bytes = K * (n * pbytes + batch_bytes)
            gbps = sweep_bytes / (bat_us / 1e6) / 1e9
            emit(f"crosstest/{arch}_N{n}_reference", ref_us,
                 f"dispatches={ref_disp}", dispatches=ref_disp)
            emit(f"crosstest/{arch}_N{n}", bat_us,
                 f"dispatches={bat_disp} speedup={ref_us / bat_us:.2f}x "
                 f"eval_GBps={gbps:.2f}",
                 dispatches=bat_disp, speedup=round(ref_us / bat_us, 2),
                 gbps=round(gbps, 2),
                 roofline_frac=round(gbps / ref_gbps, 4))
            assert ref_disp >= 3 * bat_disp, (
                f"{arch}_N{n}: batched path must cut eval dispatches "
                f">=3x (got {ref_disp} vs {bat_disp})")


if __name__ == "__main__":
    main()
