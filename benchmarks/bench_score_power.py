"""Paper Sec. V-B ablation: the accuracy exponent, plus the coalition
sweep of EXPERIMENTS.md §Coalition-sweep.

The power sweep raises accuracy to p and reports final accuracy and
malicious weight share under attack. The coalition sweep measures how
the suppression round — the first round where the coalition's aggregate
weight (``malicious_weight``) drops below 0.1 — scales with the
coalition size (1 → N/2) for the ``mutual_boost`` lying-tester coalition
(DESIGN.md §7) under each registered tester-selection policy that is
coalition-relevant (``uniform`` / ``score_weighted`` / ``coverage``)."""
from __future__ import annotations

import jax

from benchmarks.common import FAST, emit
from repro.config import FedConfig, TrainConfig
from repro.configs import get_config
from repro.core import FederatedTrainer
from repro.data import MNIST_LIKE, make_federated_image_dataset
from repro.models import build_model

SUPPRESSION_BAR = 0.1


def _setup(fast: bool, partition_kwargs=None):
    cfg = get_config("fedtest-cnn-mnist")
    if fast:
        cfg = cfg.replace(cnn_channels=(8, 16, 16), cnn_hidden=32)
    model = build_model(cfg)
    users = 8
    data = make_federated_image_dataset(
        MNIST_LIKE, users, num_samples=4000, global_test=400, seed=1,
        partition_kwargs=partition_kwargs)
    tc = TrainConfig(optimizer="sgd", lr=0.1, schedule="constant",
                     batch_size=16, grad_clip=0.0, remat=False)
    return model, users, data, tc


def power_sweep(fast: bool):
    # default paper-style skew — the historical Sec. V-B conditions
    model, users, data, tc = _setup(fast)
    rounds = 8 if fast else 30
    for power in (1.0, 2.0, 4.0, 8.0):
        fed = FedConfig(num_users=users, num_testers=2, num_malicious=2,
                        local_steps=10, attack="random_weights", attack_scale=4.0,
                        score_power=power)
        trainer = FederatedTrainer(model, fed, tc, eval_batch=128)
        state = trainer.init(jax.random.PRNGKey(0))
        for _ in range(rounds):
            state, metrics = trainer.run_round(state, data)
        acc = trainer.global_accuracy(state, data)
        emit(f"score_power/p{power:g}", 0.0,
             f"final_acc={acc:.4f} "
             f"malicious_weight={float(metrics['malicious_weight']):.5f}")


def coalition_sweep(fast: bool):
    """Suppression round vs coalition size (EXPERIMENTS.md
    §Coalition-sweep): mutual_boost members poison their models
    (random_weights) and lie for each other whenever they tester,
    against the defended preset scheme (trust consensus + consensus-
    clipped reports); the row reports the first round their aggregate
    weight drops below 0.1 and the weight reached by the final round.
    Expect suppression to slow with the coalition fraction and break
    once members can be the majority of a tester committee
    (DESIGN.md §7)."""
    import dataclasses

    from repro.configs import get_scenario

    # always the reduced CNN: this is a dynamics measurement (who gets
    # the weight), not a perf one — model scale only slows the answer.
    # Mild skew is the dynamics bar (EXPERIMENTS.md §Paper-validation).
    model, users, data, tc = _setup(
        True, partition_kwargs={"min_classes": 8, "max_classes": 10})
    rounds = 10 if fast else 20
    sizes = range(1, users // 2 + 1)
    selectors = ("uniform",) if fast else ("uniform", "score_weighted",
                                           "coverage")
    base = get_scenario("mutual_boost_vs_fedtest")
    for selector in selectors:
        for size in sizes:
            fed = dataclasses.replace(
                base, num_users=users, num_testers=5, num_malicious=size,
                coalition_size=size, selector=selector, local_steps=10)
            trainer = FederatedTrainer(model, fed, tc, eval_batch=128)
            state = trainer.init(jax.random.PRNGKey(0))
            suppressed_at = None
            for r in range(rounds):
                state, metrics = trainer.run_round(state, data)
                mal_w = float(metrics["malicious_weight"])
                if suppressed_at is None and mal_w < SUPPRESSION_BAR:
                    suppressed_at = r + 1
            emit(f"score_power/coalition_{selector}_c{size}", 0.0,
                 f"suppression_round="
                 f"{suppressed_at if suppressed_at else f'>{rounds}'} "
                 f"final_malicious_weight={mal_w:.5f}")


def main(fast: bool = FAST):
    power_sweep(fast)
    coalition_sweep(fast)


if __name__ == "__main__":
    main()
