"""Paper Sec. V-B ablation: the accuracy exponent. The paper raises
accuracy to the 4th power; this sweeps p and reports final accuracy and
malicious weight share under attack."""
from __future__ import annotations

import jax

from benchmarks.common import FAST, emit
from repro.config import FedConfig, TrainConfig
from repro.configs import get_config
from repro.core import FederatedTrainer
from repro.data import MNIST_LIKE, make_federated_image_dataset
from repro.models import build_model


def main(fast: bool = FAST):
    cfg = get_config("fedtest-cnn-mnist")
    if fast:
        cfg = cfg.replace(cnn_channels=(8, 16, 16), cnn_hidden=32)
    model = build_model(cfg)
    users = 8
    data = make_federated_image_dataset(MNIST_LIKE, users,
                                        num_samples=4000, global_test=400,
                                        seed=1)
    tc = TrainConfig(optimizer="sgd", lr=0.1, schedule="constant",
                     batch_size=16, grad_clip=0.0, remat=False)
    rounds = 8 if fast else 30
    for power in (1.0, 2.0, 4.0, 8.0):
        fed = FedConfig(num_users=users, num_testers=2, num_malicious=2,
                        local_steps=10, attack="random_weights", attack_scale=4.0,
                        score_power=power)
        trainer = FederatedTrainer(model, fed, tc, eval_batch=128)
        state = trainer.init(jax.random.PRNGKey(0))
        for _ in range(rounds):
            state, metrics = trainer.run_round(state, data)
        acc = trainer.global_accuracy(state, data)
        emit(f"score_power/p{power:g}", 0.0,
             f"final_acc={acc:.4f} "
             f"malicious_weight={float(metrics['malicious_weight']):.5f}")


if __name__ == "__main__":
    main()
