"""Shared benchmark helpers."""
from __future__ import annotations

import os
import time
from typing import List

import jax

FAST = os.environ.get("REPRO_BENCH_FULL", "0") != "1"

# structured copy of every emitted row, for JSON artifacts
# (benchmarks/run.py drains this per suite into BENCH_<suite>.json)
ROWS: List[dict] = []


def timeit(fn, *args, warmup: int = 1, iters: int = 5):
    """Median wall time per call in microseconds."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2] * 1e6


def emit(name: str, us_per_call: float, derived: str, **fields) -> None:
    """Print one CSV row and record it for the JSON artifact.

    ``fields`` are optional machine-readable extras (e.g. ``gbps=...``,
    ``roofline_frac=...``) carried into ``BENCH_<suite>.json``.
    """
    print(f"{name},{us_per_call:.1f},{derived}", flush=True)
    ROWS.append(dict(name=name, us_per_call=round(us_per_call, 1),
                     derived=derived, **fields))
