"""Quickstart: 60 seconds of FedTest on one CPU.

Runs a few federated rounds of the paper's scheme on synthetic MNIST-like
data with a malicious client, and prints how the server's scores expose
the attacker.

  PYTHONPATH=src python examples/quickstart.py
"""
import jax

from repro.config import FedConfig, TrainConfig
from repro.configs import get_config
from repro.core import FederatedTrainer
from repro.data import MNIST_LIKE, make_federated_image_dataset
from repro.models import build_model


def main():
    users, malicious = 6, 1
    cfg = get_config("fedtest-cnn-mnist").replace(cnn_channels=(8, 16, 16),
                                                  cnn_hidden=32)
    model = build_model(cfg)
    print(f"model: {cfg.name} ({model.param_count():,} params), "
          f"{users} users, {malicious} malicious (random weights)")

    data = make_federated_image_dataset(MNIST_LIKE, users,
                                        num_samples=3000, global_test=400)
    fed = FedConfig(num_users=users, num_testers=2,
                    num_malicious=malicious, local_steps=10,
                    score_power=4.0, aggregator="fedtest")
    tc = TrainConfig(optimizer="sgd", lr=0.1, schedule="constant",
                     batch_size=16, grad_clip=0.0, remat=False)
    trainer = FederatedTrainer(model, fed, tc, eval_batch=128)

    state = trainer.init(jax.random.PRNGKey(0))
    print(f"{'round':>5} {'glob acc':>9} {'mal weight':>11}   scores")
    for r in range(6):
        state, metrics = trainer.run_round(state, data)
        acc = trainer.global_accuracy(state, data)
        scores = " ".join(f"{s:.3f}" for s in metrics["scores"].tolist())
        print(f"{r + 1:>5} {acc:>9.4f} "
              f"{float(metrics['malicious_weight']):>11.5f}   [{scores}]")
    print("\nThe last client is malicious — its score (last entry) should "
          "collapse\nwhile honest clients keep high scores.")


if __name__ == "__main__":
    main()
