"""Quickstart: 60 seconds of FedTest on one CPU.

Runs a few federated rounds of the paper's scheme on synthetic MNIST-like
data with a malicious client, and prints how the server's scores expose
the attacker. Every aggregator / attack is a registered strategy — pick
any pair by name:

  PYTHONPATH=src python examples/quickstart.py
  PYTHONPATH=src python examples/quickstart.py krum scaled_update
  PYTHONPATH=src python examples/quickstart.py median label_flip_proxy
"""
import sys

import jax

from repro.config import FedConfig, TrainConfig
from repro.configs import get_config
from repro.core import FederatedTrainer
from repro.data import MNIST_LIKE, make_federated_image_dataset
from repro.models import build_model
from repro.strategies import AGGREGATORS, ATTACKS


def main():
    aggregator = sys.argv[1] if len(sys.argv) > 1 else "fedtest"
    attack = sys.argv[2] if len(sys.argv) > 2 else "random_weights"
    print(f"registered aggregators: {', '.join(AGGREGATORS.names())}")
    print(f"registered attacks:     {', '.join(ATTACKS.names())}")

    users, malicious = 6, 1
    cfg = get_config("fedtest-cnn-mnist").replace(cnn_channels=(8, 16, 16),
                                                  cnn_hidden=32)
    model = build_model(cfg)
    print(f"model: {cfg.name} ({model.param_count():,} params), "
          f"{users} users, {malicious} malicious "
          f"({attack} attack, {aggregator} aggregation)")

    data = make_federated_image_dataset(MNIST_LIKE, users,
                                        num_samples=3000, global_test=400)
    fed = FedConfig(num_users=users, num_testers=2,
                    num_malicious=malicious, local_steps=10,
                    score_power=4.0, aggregator=aggregator, attack=attack,
                    attack_scale=10.0 if attack == "scaled_update" else 1.0)
    tc = TrainConfig(optimizer="sgd", lr=0.1, schedule="constant",
                     batch_size=16, grad_clip=0.0, remat=False)
    trainer = FederatedTrainer(model, fed, tc, eval_batch=128)

    state = trainer.init(jax.random.PRNGKey(0))
    print(f"{'round':>5} {'glob acc':>9} {'mal weight':>11}   weights")
    for r in range(6):
        state, metrics = trainer.run_round(state, data)
        acc = trainer.global_accuracy(state, data)
        w = " ".join(f"{v:.3f}" for v in metrics["weights"].tolist())
        print(f"{r + 1:>5} {acc:>9.4f} "
              f"{float(metrics['malicious_weight']):>11.5f}   [{w}]")
    print(f"\nClients {trainer.attack.malicious_indices(users)} are "
          "malicious — their aggregation weight should collapse\nwhile "
          "honest clients keep high weight.")


if __name__ == "__main__":
    main()
