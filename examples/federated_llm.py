"""Federated fine-tuning of an assigned LM backbone with FedTest.

Each client holds a topic-skewed shard of a synthetic bigram language;
clients cross-test each other's checkpoints on their own held-out text
(token accuracy as the FedTest score), the server aggregates with the
moving-average accuracy^4 weights, and at the end the global model serves
greedy continuations.

  PYTHONPATH=src python examples/federated_llm.py --arch qwen2-0.5b
  PYTHONPATH=src python examples/federated_llm.py --arch mamba2-2.7b \\
      --malicious 1
"""
import argparse

import jax
import jax.numpy as jnp

from repro.config import FedConfig, TrainConfig, reduce_for_smoke
from repro.configs import get_config
from repro.core import FederatedTrainer
from repro.launch.train import make_lm_federated_dataset
from repro.models import build_model


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--users", type=int, default=4)
    ap.add_argument("--malicious", type=int, default=0)
    ap.add_argument("--rounds", type=int, default=6)
    ap.add_argument("--vocab", type=int, default=97)
    args = ap.parse_args()

    cfg = reduce_for_smoke(get_config(args.arch)).replace(
        dtype="float32", vocab_size=args.vocab)
    model = build_model(cfg)
    print(f"federated fine-tune: {cfg.name} "
          f"({model.param_count():,} params), "
          f"{args.users} clients, {args.malicious} malicious")

    data = make_lm_federated_dataset(args.vocab, args.users, seq_len=32,
                                     seqs_per_user=48)
    fed = FedConfig(num_users=args.users, num_testers=2,
                    num_malicious=args.malicious, local_steps=8,
                    attack="random_weights")
    tc = TrainConfig(optimizer="adamw", lr=2e-3, schedule="constant",
                     batch_size=16, grad_clip=1.0, remat=False)
    trainer = FederatedTrainer(model, fed, tc, eval_batch=32)

    state, hist = trainer.run(jax.random.PRNGKey(0), data,
                              rounds=args.rounds, verbose=True)

    # serve the federated model: greedy continuation of a held-out prefix
    prefix = data.global_x[:1, :12]
    _, cache = model.prefill(state.global_params, {"tokens": prefix},
                             cache_len=32)
    toks = prefix[:, -1:]
    generated = []
    for _ in range(12):
        logits, cache = model.decode_step(state.global_params, cache, toks)
        toks = jnp.argmax(logits[:, -1:], -1).astype(jnp.int32)
        generated.append(int(toks[0, 0]))
    truth = data.global_x[0, 12:24].tolist()
    hits = sum(g == t for g, t in zip(generated, truth))
    print(f"\nprefix    : {prefix[0].tolist()}")
    print(f"generated : {generated}")
    print(f"truth     : {truth}")
    print(f"greedy continuation matches {hits}/12 ground-truth tokens")


if __name__ == "__main__":
    main()
