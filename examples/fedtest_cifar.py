"""Paper reproduction driver (Figs. 4 & 5): FedTest vs FedAvg vs the
accuracy-based scheme on CIFAR-like / MNIST-like synthetic data, with and
without malicious users. This is the end-to-end training example — the
paper's experiment, at a CPU-friendly scale by default.

  PYTHONPATH=src python examples/fedtest_cifar.py --rounds 12
  PYTHONPATH=src python examples/fedtest_cifar.py --dataset mnist_like \\
      --malicious 4 --full
"""
import argparse

from benchmarks.bench_convergence import run_curve, rounds_to_reach


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", default="cifar_like",
                    choices=["cifar_like", "mnist_like"])
    ap.add_argument("--malicious", type=int, default=3)
    ap.add_argument("--rounds", type=int, default=12)
    ap.add_argument("--full", action="store_true",
                    help="paper-scale: 20 users, full CNN")
    args = ap.parse_args()

    curves = {}
    for agg in ("fedtest", "fedavg", "accuracy_based"):
        print(f"=== {agg} ({args.dataset}, {args.malicious} malicious) ===")
        hist = run_curve(args.dataset, agg, args.malicious, args.rounds,
                         fast=not args.full)
        curves[agg] = hist
        for r, a in zip(hist["round"], hist["global_accuracy"]):
            bar = "#" * int(a * 50)
            print(f"  round {r:3d}  {a:.4f} {bar}")

    print("\nfinal accuracies:")
    for agg, hist in curves.items():
        tgt = rounds_to_reach(hist, 0.6)
        print(f"  {agg:16s} {hist['global_accuracy'][-1]:.4f}"
              f"   rounds_to_0.6={tgt}")


if __name__ == "__main__":
    main()
