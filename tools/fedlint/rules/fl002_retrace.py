"""FL002 — retrace / trace-time hazards in jitted code.

The round engine's no-retrace guarantee (``FederatedTrainer.num_traces``)
holds only if traced functions never branch in *Python* on values that
are data-dependent on their traced parameters. This rule scans functions
that are demonstrably traced — decorated with ``jax.jit`` (directly or
via ``functools.partial``), or passed by name to ``jax.jit`` /
``jax.lax.scan`` / ``shard_map`` / ``jax.vmap`` / ``jax.grad`` — and
flags:

* ``if`` / ``while`` / ``assert`` whose condition is data-dependent on a
  traced parameter (static parameters named in ``static_argnames`` /
  ``static_argnums`` are exempt, as are ``.shape`` / ``.dtype`` /
  ``.ndim`` accesses and ``is None`` identity checks — those are
  trace-static);
* f-strings interpolating a traced value (forces concretisation or
  bakes a tracer repr into the program);
* mutable (non-hashable) defaults — list/dict/set — on parameters named
  in ``static_argnames`` (a TypeError at call time, or silent retraces
  when callers pass varying unhashable values).

Taint is a simple forward pass: traced parameters seed it, assignments
propagate it, static attribute reads (`x.shape[0]`, `len(x)`) launder it.
"""
from __future__ import annotations

import ast
from typing import Dict, Iterator, List, Optional, Set, Tuple

from tools.fedlint import astutil
from tools.fedlint.core import Diagnostic, ModuleContext, Rule

_TRACING_CALLS = {"jit", "scan", "shard_map", "vmap", "pmap", "grad",
                  "value_and_grad", "checkpoint", "remat"}
_STATIC_ATTRS = {"shape", "ndim", "dtype", "size", "aval", "sharding"}
_STATIC_CALLS = {"len", "isinstance", "hasattr", "getattr", "type",
                 "range"}
_MUTABLE_LITERALS = (ast.List, ast.Dict, ast.Set, ast.ListComp,
                     ast.DictComp, ast.SetComp)


def _jit_static_names(call: ast.Call, func: Optional[ast.FunctionDef]
                      ) -> Set[str]:
    """Parameter names made static by a ``jax.jit(...)`` call node."""
    static: Set[str] = set()
    names = astutil.keyword_arg(call, "static_argnames")
    if names is not None:
        static.update(astutil.str_constants(names))
    nums = astutil.keyword_arg(call, "static_argnums")
    if nums is not None and func is not None:
        pos = astutil.positional_param_names(func)
        for i in astutil.int_constants(nums):
            if 0 <= i < len(pos):
                static.add(pos[i])
    return static


def _traced_functions(ctx: ModuleContext
                      ) -> List[Tuple[ast.FunctionDef, Set[str], ast.Call]]:
    """(function, static-param-names, marking jit/scan call-or-None)."""
    by_name: Dict[str, ast.FunctionDef] = {
        f.name: f for f in astutil.iter_functions(ctx.tree)}
    out: List[Tuple[ast.FunctionDef, Set[str], Optional[ast.Call]]] = []
    seen: Set[str] = set()

    # decorator form: @jax.jit / @partial(jax.jit, static_argnames=...)
    for func in astutil.iter_functions(ctx.tree):
        for deco in func.decorator_list:
            call = deco if isinstance(deco, ast.Call) else None
            target = deco
            if call is not None:
                name = astutil.call_name(call)
                if name and astutil.last_segment(name) == "partial" \
                        and call.args:
                    target = call.args[0]
                else:
                    target = call.func
            name = astutil.dotted_name(target)
            if name and astutil.last_segment(name) == "jit":
                static = _jit_static_names(call, func) if call else set()
                out.append((func, static, call))
                seen.add(func.name)

    # reference form: jax.jit(f, ...) / lax.scan(body, ...) /
    # shard_map(f, ...) / jax.vmap(f)
    for call in astutil.iter_calls(ctx.tree):
        name = astutil.call_name(call)
        if not name or astutil.last_segment(name) not in _TRACING_CALLS:
            continue
        if not call.args:
            continue
        target = astutil.unwrap_partial(call.args[0])
        tname = astutil.dotted_name(target)
        if tname is None:
            continue
        fname = astutil.last_segment(tname)
        func = by_name.get(fname)
        if func is None or fname in seen:
            continue
        seen.add(fname)
        static = (_jit_static_names(call, func)
                  if astutil.last_segment(name) == "jit" else set())
        out.append((func, static, call))
    return out


def _expr_tainted(node: ast.expr, taint: Set[str]) -> bool:
    """Is the expression data-dependent on a tainted name?

    Static accessors (.shape/.dtype/…, len(), isinstance()) and
    ``is``/``is not`` comparisons launder taint — they are trace-static.
    """
    if node is None:
        return False
    if isinstance(node, ast.Name):
        return node.id in taint
    if isinstance(node, ast.Attribute):
        if node.attr in _STATIC_ATTRS:
            return False
        return _expr_tainted(node.value, taint)
    if isinstance(node, ast.Compare):
        if all(isinstance(op, (ast.Is, ast.IsNot)) for op in node.ops):
            return False
        return (_expr_tainted(node.left, taint)
                or any(_expr_tainted(c, taint) for c in node.comparators))
    if isinstance(node, ast.Call):
        name = astutil.call_name(node)
        if name and astutil.last_segment(name) in _STATIC_CALLS:
            return False
        # a method on a tainted receiver (x.sum(), x.mean()) returns
        # tainted data — the receiver lives in node.func, not the args
        return (_expr_tainted(node.func, taint)
                or any(_expr_tainted(a, taint) for a in node.args)
                or any(_expr_tainted(kw.value, taint)
                       for kw in node.keywords))
    if isinstance(node, ast.Subscript):
        return (_expr_tainted(node.value, taint)
                or _expr_tainted(node.slice, taint))
    if isinstance(node, (ast.BoolOp,)):
        return any(_expr_tainted(v, taint) for v in node.values)
    if isinstance(node, ast.BinOp):
        return (_expr_tainted(node.left, taint)
                or _expr_tainted(node.right, taint))
    if isinstance(node, ast.UnaryOp):
        return _expr_tainted(node.operand, taint)
    if isinstance(node, ast.IfExp):
        return (_expr_tainted(node.test, taint)
                or _expr_tainted(node.body, taint)
                or _expr_tainted(node.orelse, taint))
    if isinstance(node, (ast.Tuple, ast.List, ast.Set)):
        return any(_expr_tainted(e, taint) for e in node.elts)
    if isinstance(node, ast.Starred):
        return _expr_tainted(node.value, taint)
    return False


class RetraceHazards(Rule):
    rule_id = "FL002"
    name = "retrace-hazards"
    default_options = {"enabled": True}

    def check_module(self, ctx: ModuleContext) -> Iterator[Diagnostic]:
        for func, static, mark in _traced_functions(ctx):
            yield from self._check_traced(ctx, func, static)
            if mark is not None:
                yield from self._check_static_defaults(ctx, func, static)

    # -------------------------------------------------- mutable static defs
    def _check_static_defaults(self, ctx, func, static
                               ) -> Iterator[Diagnostic]:
        defaults = astutil._param_defaults(func)
        for name in static:
            default = defaults.get(name)
            if default is not None and isinstance(default,
                                                 _MUTABLE_LITERALS):
                yield ctx.diag(
                    default, self.rule_id,
                    f"static_argnames parameter {name!r} of "
                    f"{func.name}() has a non-hashable default "
                    f"({ast.unparse(default)[:40]}) — static arguments "
                    "must be hashable or every call retraces/raises")

    # ----------------------------------------------------- tainted branches
    def _check_traced(self, ctx, func: ast.FunctionDef, static: Set[str]
                      ) -> Iterator[Diagnostic]:
        taint: Set[str] = {
            p for p in astutil.param_names(func)
            if p not in static and p not in ("self", "cls")}
        yield from self._walk_block(ctx, func, func.body, taint)

    def _walk_block(self, ctx, func, stmts, taint: Set[str]
                    ) -> Iterator[Diagnostic]:
        for stmt in stmts:
            yield from self._walk_stmt(ctx, func, stmt, taint)

    def _walk_stmt(self, ctx, func, stmt, taint: Set[str]
                   ) -> Iterator[Diagnostic]:
        if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef)):
            # nested defs close over the traced scope: same taint, their
            # own non-self params are traced too (scan bodies etc.)
            inner = taint | {p for p in astutil.param_names(stmt)
                             if p not in ("self", "cls")}
            yield from self._walk_block(ctx, stmt, stmt.body, inner)
            return
        if isinstance(stmt, ast.If):
            if _expr_tainted(stmt.test, taint):
                yield ctx.diag(
                    stmt, self.rule_id,
                    f"Python `if` on a value data-dependent on traced "
                    f"parameters of {func.name}() — use jnp.where / "
                    "lax.cond, or hoist the decision pre-trace")
            yield from self._walk_block(ctx, func, stmt.body, set(taint))
            yield from self._walk_block(ctx, func, stmt.orelse, set(taint))
            return
        if isinstance(stmt, ast.While):
            if _expr_tainted(stmt.test, taint):
                yield ctx.diag(
                    stmt, self.rule_id,
                    f"Python `while` on a traced value in {func.name}() "
                    "— use lax.while_loop")
            yield from self._walk_block(ctx, func, stmt.body, set(taint))
            return
        if isinstance(stmt, ast.Assert):
            if _expr_tainted(stmt.test, taint):
                yield ctx.diag(
                    stmt, self.rule_id,
                    f"`assert` on a traced value in {func.name}() — "
                    "asserts on tracers either fail spuriously or are "
                    "silently trace-time-only; use checkify or assert "
                    "on static .shape/.dtype facts")
            return
        if isinstance(stmt, (ast.For, ast.AsyncFor)):
            if _expr_tainted(stmt.iter, taint):
                yield ctx.diag(
                    stmt, self.rule_id,
                    f"Python `for` over a traced value in {func.name}() "
                    "— use lax.scan / lax.fori_loop")
            loop_taint = set(taint)
            if _expr_tainted(stmt.iter, taint):
                loop_taint.update(astutil.assign_targets(stmt))
            yield from self._walk_block(ctx, func, stmt.body, loop_taint)
            yield from self._walk_block(ctx, func, stmt.orelse, loop_taint)
            return
        if isinstance(stmt, ast.Try):
            for block in (stmt.body, stmt.orelse, stmt.finalbody):
                yield from self._walk_block(ctx, func, block, taint)
            for handler in stmt.handlers:
                yield from self._walk_block(ctx, func, handler.body, taint)
            return
        if isinstance(stmt, (ast.With, ast.AsyncWith)):
            yield from self._walk_block(ctx, func, stmt.body, taint)
            return

        # taint propagation through plain assignments
        if isinstance(stmt, (ast.Assign, ast.AnnAssign, ast.AugAssign)):
            value = getattr(stmt, "value", None)
            targets = astutil.assign_targets(stmt)
            if value is not None:
                if _expr_tainted(value, taint) or (
                        isinstance(stmt, ast.AugAssign)
                        and any(t in taint for t in targets)):
                    taint.update(targets)
                else:
                    for t in targets:
                        taint.discard(t)
        # f-strings on tracers, anywhere in the statement
        for node in ast.walk(stmt):
            if isinstance(node, ast.JoinedStr):
                for part in node.values:
                    if isinstance(part, ast.FormattedValue) and \
                            _expr_tainted(part.value, taint):
                        yield ctx.diag(
                            node, self.rule_id,
                            f"f-string interpolates a traced value in "
                            f"{func.name}() — formatting a tracer bakes "
                            "its repr into the trace (or forces a "
                            "concretisation error); use jax.debug.print")
                        break
