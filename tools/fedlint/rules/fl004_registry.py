"""FL004 — strategy-registry protocol conformance.

The engine dispatches strategies structurally: ``driver.py`` calls
``selector.select(key, N, T, r, scores=...)``, ``program.py`` calls
``attack.apply(...)`` / ``aggregator.weights(...)`` — nothing type-checks
those shapes until a round actually runs with that strategy selected,
which for exotic entries may be never in CI. This rule checks every
class registered via ``@register(REGISTRY, "name")`` against the
protocol its registry implies, statically:

* ``SELECTORS`` — a concrete ``select`` somewhere on the (approximate)
  MRO, and the defining ``select`` must take ``scores`` as a
  *keyword-only* parameter (the engine always passes ``scores=...`` by
  keyword; a positional ``scores`` silently binds ``round_idx``).
* ``ATTACKS`` — a concrete ``corrupt`` **or** both ``apply`` and
  ``apply_local`` overridden; ``corrupt`` must accept ``ctx`` and
  ``client_idx`` (or ``**kwargs``) because the engine forwards both.
  Overriding only one of ``apply`` / ``apply_local`` is a warning: the
  two paths (batched vs per-client) then disagree on what the attack
  does — exactly the class of silent local/distributed divergence the
  parity suite exists to catch.
* ``AGGREGATORS`` — a concrete ``weights``; if the class defines
  ``combine`` as a method, it must declare a ``ctx`` parameter
  (``combine(self, ctx, updates)`` is the engine's call shape).
* ``COALITIONS`` — a concrete ``transform_reports`` accepting ``key``,
  ``acc``, ``tester_ids`` and ``ctx`` (or ``**kwargs``).
"""
from __future__ import annotations

import ast
from typing import Iterator, Optional

from tools.fedlint import astutil
from tools.fedlint.core import (ClassInfo, Diagnostic, ModuleContext,
                                Rule, WARNING)

_REGISTRY_KIND = {
    "AGGREGATORS": "aggregator",
    "ATTACKS": "attack",
    "SELECTORS": "selector",
    "COALITIONS": "coalition",
}


def _has_kwargs(func: ast.FunctionDef) -> bool:
    return func.args.kwarg is not None


def _accepts(func: ast.FunctionDef, name: str) -> bool:
    return name in astutil.param_names(func) or _has_kwargs(func)


def _concrete_method(ctx: ModuleContext, info: ClassInfo, method: str
                     ) -> Optional[ast.FunctionDef]:
    """The def the engine would dispatch to, if it is concrete."""
    found = ctx.project.find_method(info, method)
    if found is None:
        return None
    _, func = found
    if astutil.body_is_abstract(func):
        return None
    return func


def _own_method(info: ClassInfo, method: str) -> Optional[ast.FunctionDef]:
    for stmt in info.node.body:
        if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef)) \
                and stmt.name == method:
            return stmt
    return None


class RegistryConformance(Rule):
    rule_id = "FL004"
    name = "registry-conformance"
    default_options = {"enabled": True}

    def check_module(self, ctx: ModuleContext) -> Iterator[Diagnostic]:
        for node in ast.walk(ctx.tree):
            if not isinstance(node, ast.ClassDef):
                continue
            info = next(
                (i for i in ctx.project.classes.get(node.name, [])
                 if i.node is node), None)
            if info is None:
                continue
            for registry, entry in info.registries:
                kind = _REGISTRY_KIND.get(registry)
                if kind == "selector":
                    yield from self._check_selector(ctx, info, entry)
                elif kind == "attack":
                    yield from self._check_attack(ctx, info, entry)
                elif kind == "aggregator":
                    yield from self._check_aggregator(ctx, info, entry)
                elif kind == "coalition":
                    yield from self._check_coalition(ctx, info, entry)

    # --------------------------------------------------------------- selector
    def _check_selector(self, ctx, info: ClassInfo, entry: str
                        ) -> Iterator[Diagnostic]:
        func = _concrete_method(ctx, info, "select")
        if func is None:
            yield ctx.diag(
                info.node, self.rule_id,
                f"selector {entry!r} ({info.node.name}) has no concrete "
                "select() — the engine calls "
                "select(key, num_users, num_testers, round_idx, "
                "*, scores=None)")
            return
        if "scores" not in astutil.kwonly_param_names(func) \
                and not _has_kwargs(func):
            where = ("scores is positional"
                     if "scores" in astutil.positional_param_names(func)
                     else "scores is missing")
            yield ctx.diag(
                func, self.rule_id,
                f"selector {entry!r}: select() must take `scores` "
                f"keyword-only ({where}) — the engine passes "
                "scores=... by keyword; a positional `scores` binds "
                "round_idx instead")

    # ----------------------------------------------------------------- attack
    def _check_attack(self, ctx, info: ClassInfo, entry: str
                      ) -> Iterator[Diagnostic]:
        corrupt = _concrete_method(ctx, info, "corrupt")
        apply_own = _own_method(info, "apply")
        apply_local_own = _own_method(info, "apply_local")
        if corrupt is None and not (apply_own and apply_local_own):
            yield ctx.diag(
                info.node, self.rule_id,
                f"attack {entry!r} ({info.node.name}) defines neither a "
                "concrete corrupt() nor both apply()/apply_local() — "
                "one of the two surfaces the engine dispatches to must "
                "exist")
            return
        if corrupt is not None:
            missing = [p for p in ("ctx", "client_idx")
                       if not _accepts(corrupt, p)]
            if missing:
                yield ctx.diag(
                    corrupt, self.rule_id,
                    f"attack {entry!r}: corrupt() does not accept "
                    f"{', '.join(missing)} — the engine forwards "
                    "corrupt(key, trained, global_params, ctx=..., "
                    "client_idx=...)")
        if bool(apply_own) != bool(apply_local_own):
            side = "apply" if apply_own else "apply_local"
            other = "apply_local" if apply_own else "apply"
            yield ctx.diag(
                apply_own or apply_local_own, self.rule_id,
                f"attack {entry!r} overrides {side}() but not "
                f"{other}() — the batched and per-client paths now "
                "disagree on what the attack does; override both or "
                "express the attack through corrupt()",
                severity=WARNING)

    # -------------------------------------------------------------- aggregator
    def _check_aggregator(self, ctx, info: ClassInfo, entry: str
                          ) -> Iterator[Diagnostic]:
        weights = _concrete_method(ctx, info, "weights")
        if weights is None:
            yield ctx.diag(
                info.node, self.rule_id,
                f"aggregator {entry!r} ({info.node.name}) has no "
                "concrete weights() — the engine calls "
                "weights(acc, ctx) every round")
        combine = ctx.project.find_method(info, "combine")
        if combine is not None:
            _, func = combine
            if not astutil.body_is_abstract(func) \
                    and not _accepts(func, "ctx"):
                yield ctx.diag(
                    func, self.rule_id,
                    f"aggregator {entry!r}: combine() does not declare "
                    "`ctx` — the engine calls combine(ctx, updates)")

    # --------------------------------------------------------------- coalition
    def _check_coalition(self, ctx, info: ClassInfo, entry: str
                         ) -> Iterator[Diagnostic]:
        func = _concrete_method(ctx, info, "transform_reports")
        if func is None:
            yield ctx.diag(
                info.node, self.rule_id,
                f"coalition {entry!r} ({info.node.name}) has no concrete "
                "transform_reports() — the engine calls "
                "transform_reports(key, acc, tester_ids, ctx)")
            return
        missing = [p for p in ("key", "acc", "tester_ids", "ctx")
                   if not _accepts(func, p)]
        if missing:
            yield ctx.diag(
                func, self.rule_id,
                f"coalition {entry!r}: transform_reports() does not "
                f"accept {', '.join(missing)} — the engine passes all "
                "of key, acc, tester_ids, ctx")
