"""FL005 — buffer-donation safety.

``jax.jit(..., donate_argnums=...)`` hands the argument's device buffer
to the compiled computation: after the call the Python binding still
points at it, but the memory has been reused — touching it raises (on
TPU/GPU) or, worse, silently aliases under some backends. The engine's
fast path depends on this (``driver.py`` donates the carried state into
the scanned multi-round step), so the safe idiom is load-bearing:

    state, chunk = self._scan_fn(state, data)   # rebinds at the call

This rule finds donating call sites — a name bound to
``jax.jit(f, donate_argnums=...)``, a ``@partial(jax.jit, donate...)``
decorated function, or an inline ``jax.jit(f, donate...)(args)`` — and
flags any read of a donated argument *after* the donating call in the
same scope, until the name is rebound. Block structure is respected:
statements in sibling ``if``/``elif`` branches do not execute after the
call and are not flagged (``dryrun.py`` builds per-branch AOT chains
this way). Inside a loop the whole body re-executes, so a donated name
not rebound by the call statement itself is flagged even for reads
textually before the call.

``.lower(...)`` chains are exempt: lowering only traces avals — no real
buffer is donated until the compiled artifact is executed.
"""
from __future__ import annotations

import ast
from typing import Dict, Iterator, List, Optional, Sequence, Set, Tuple

from tools.fedlint import astutil
from tools.fedlint.core import Diagnostic, ModuleContext, Rule


def _is_donating_jit(call: ast.Call) -> bool:
    name = astutil.call_name(call)
    if not name or astutil.last_segment(name) != "jit":
        return False
    return (astutil.keyword_arg(call, "donate_argnums") is not None
            or astutil.keyword_arg(call, "donate_argnames") is not None)


def _donated_positions(jit_call: ast.Call) -> Tuple[List[int], List[str]]:
    nums_node = astutil.keyword_arg(jit_call, "donate_argnums")
    names_node = astutil.keyword_arg(jit_call, "donate_argnames")
    nums = astutil.int_constants(nums_node) if nums_node is not None else []
    names = (astutil.str_constants(names_node)
             if names_node is not None else [])
    return nums, names


def _dotted_assignments(tree: ast.Module) -> Dict[str, ast.expr]:
    """Single-target assignments, keyed by dotted target
    (``self._scan_fn`` included — driver.py binds its donating jit
    there)."""
    table: Dict[str, ast.expr] = {}
    for node in ast.walk(tree):
        if isinstance(node, ast.Assign) and len(node.targets) == 1:
            name = astutil.dotted_name(node.targets[0])
            if name:
                table[name] = node.value
    return table


def _decorated_donators(tree: ast.Module) -> Dict[str, ast.Call]:
    """function name -> donating jit call, for decorator form."""
    out: Dict[str, ast.Call] = {}
    for func in astutil.iter_functions(tree):
        for deco in func.decorator_list:
            if not isinstance(deco, ast.Call):
                continue
            name = astutil.call_name(deco)
            if name and astutil.last_segment(name) == "partial" \
                    and deco.args:
                inner_name = astutil.dotted_name(deco.args[0])
                if inner_name \
                        and astutil.last_segment(inner_name) == "jit" \
                        and _is_donating_jit_kw(deco):
                    out[func.name] = deco
            elif name and astutil.last_segment(name) == "jit" \
                    and _is_donating_jit(deco):
                out[func.name] = deco
    return out


def _is_donating_jit_kw(call: ast.Call) -> bool:
    return (astutil.keyword_arg(call, "donate_argnums") is not None
            or astutil.keyword_arg(call, "donate_argnames") is not None)


def _enclosing_scope(node: ast.AST) -> ast.AST:
    cur = node
    while cur is not None:
        cur = astutil.parent(cur)
        if isinstance(cur, (ast.FunctionDef, ast.AsyncFunctionDef,
                            ast.Module)):
            return cur
    return node


def _stmt_of(node: ast.AST) -> Optional[ast.stmt]:
    """The statement a node belongs to (its outermost stmt ancestor
    below the scope boundary)."""
    cur = node
    while cur is not None:
        if isinstance(cur, (ast.FunctionDef, ast.AsyncFunctionDef,
                            ast.Module)) and cur is not node:
            return None         # scope boundary — no stmt found
        if isinstance(cur, ast.stmt):
            return cur          # innermost statement wins
        cur = astutil.parent(cur)
    return None


def _blocks_of(stmt: ast.stmt) -> List[List[ast.stmt]]:
    blocks = []
    for field in ("body", "orelse", "finalbody"):
        sub = getattr(stmt, field, None)
        if isinstance(sub, list):
            blocks.append(sub)
    for handler in getattr(stmt, "handlers", []) or []:
        blocks.append(handler.body)
    return blocks


def _later_statements(scope_body: Sequence[ast.stmt], target: ast.stmt,
                      ) -> Tuple[List[ast.stmt], bool]:
    """Statements that (may) execute after ``target`` within the scope,
    respecting branch structure. Returns (stmts, found). Loop bodies
    containing the target contribute their whole body (it re-executes)."""

    def search(block: Sequence[ast.stmt]) -> Tuple[List[ast.stmt], bool]:
        for idx, stmt in enumerate(block):
            if stmt is target:
                return list(block[idx + 1:]), True
            if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef,
                                 ast.ClassDef)):
                continue        # separate scope
            for sub in _blocks_of(stmt):
                inner, found = search(sub)
                if found:
                    later = list(inner)
                    if isinstance(stmt, (ast.For, ast.AsyncFor,
                                         ast.While)):
                        # the loop body re-runs: everything in it —
                        # including the donating statement itself, which
                        # re-reads the dead buffer on iteration 2 unless
                        # the call rebinds it
                        later += [s for s in sub if s not in inner]
                    later += list(block[idx + 1:])
                    return later, True
        return [], False

    return search(list(scope_body))


def _reads_name(stmt: ast.stmt, name: str) -> Optional[ast.AST]:
    """A Load-context occurrence of ``name`` (dotted) in the statement,
    skipping nested scopes."""
    skip_ids: Set[int] = set()
    if isinstance(stmt, ast.Assign):
        for tgt in stmt.targets:
            for n in ast.walk(tgt):
                skip_ids.add(id(n))
    elif isinstance(stmt, (ast.AnnAssign,)):
        for n in ast.walk(stmt.target):
            skip_ids.add(id(n))
    for node in ast.walk(stmt):
        if id(node) in skip_ids:
            continue
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.Lambda)) and node is not stmt:
            continue
        if isinstance(node, (ast.Name, ast.Attribute)):
            if astutil.dotted_name(node) == name:
                par = astutil.parent(node)
                if isinstance(par, ast.Attribute):
                    continue    # inner part of a longer dotted chain
                return node
    return None


class DonationSafety(Rule):
    rule_id = "FL005"
    name = "donation-safety"
    default_options = {"enabled": True}

    def check_module(self, ctx: ModuleContext) -> Iterator[Diagnostic]:
        bindings = _dotted_assignments(ctx.tree)
        decorated = _decorated_donators(ctx.tree)

        for call in astutil.iter_calls(ctx.tree):
            jit_call = self._donating_jit_for(call, bindings, decorated)
            if jit_call is None:
                continue
            yield from self._check_call_site(ctx, call, jit_call)

    def _donating_jit_for(self, call: ast.Call, bindings, decorated
                          ) -> Optional[ast.Call]:
        """The donating jax.jit(...) behind this call site, if any."""
        func = call.func
        # .lower(...) is AOT tracing — no buffer donation happens
        if isinstance(func, ast.Attribute) and func.attr == "lower":
            return None
        # inline: jax.jit(f, donate_argnums=...)(args)
        if isinstance(func, ast.Call) and _is_donating_jit(func):
            return func
        name = astutil.dotted_name(func)
        if name is None:
            return None
        # bound: self._scan_fn = jax.jit(f, donate...); self._scan_fn(...)
        bound = bindings.get(name)
        if bound is not None:
            bound_call = bound
            if isinstance(bound_call, ast.IfExp):
                # driver.py: jit(...) if rounds>1 else None
                for side in (bound_call.body, bound_call.orelse):
                    if isinstance(side, ast.Call) \
                            and _is_donating_jit(side):
                        return side
            if isinstance(bound_call, ast.Call) \
                    and _is_donating_jit(bound_call):
                return bound_call
        # decorator form: @partial(jax.jit, donate...) def f; f(...)
        deco = decorated.get(astutil.last_segment(name))
        if deco is not None and astutil.last_segment(name) == name:
            return deco
        return None

    def _check_call_site(self, ctx: ModuleContext, call: ast.Call,
                         jit_call: ast.Call) -> Iterator[Diagnostic]:
        nums, kw_names = _donated_positions(jit_call)
        donated: List[str] = []
        for pos in nums:
            if 0 <= pos < len(call.args):
                name = astutil.dotted_name(call.args[pos])
                if name:
                    donated.append(name)
        for kw in call.keywords:
            if kw.arg in kw_names:
                name = astutil.dotted_name(kw.value)
                if name:
                    donated.append(name)
        if not donated:
            return

        stmt = _stmt_of(call)
        scope = _enclosing_scope(call)
        if stmt is None:
            return
        rebound_here = set(astutil.assign_targets(stmt))
        later, found = _later_statements(scope.body, stmt)
        if not found:
            return

        for name in donated:
            if name in rebound_here:
                continue        # state, out = fn(state, ...) — safe idiom
            for nxt in later:
                read = _reads_name(nxt, name)
                if read is not None:
                    yield ctx.diag(
                        read, self.rule_id,
                        f"{name!r} is read after being donated to the "
                        f"jitted call on line {stmt.lineno} "
                        "(donate_argnums/donate_argnames) — its buffer "
                        "is gone; rebind the result (`x, ... = fn(x, "
                        "...)`) or drop the donation")
                    break
                if name in astutil.assign_targets(nxt):
                    break       # rebound before any read
