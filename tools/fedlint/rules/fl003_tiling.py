"""FL003 — Pallas tiling invariants.

Activates on any module that calls ``pl.pallas_call`` (in the repo:
``src/repro/kernels/*/kernel.py``). Three checks per call site:

* **grid divisibility** — a grid dimension written ``X // B`` silently
  drops the remainder when ``B`` does not divide ``X``: the kernel never
  visits the tail elements and the reduction is simply wrong. The rule
  requires either static divisibility (when both sides resolve to
  constants), a trace-time guard (``assert X % B == 0``, the repo
  idiom), or explicit masking (``pl.cdiv`` grid + ``pl.when`` /
  ``@pl.when`` in the kernel body).
* **program_id rank** — ``pl.program_id(axis)`` with ``axis >= len(grid)``
  reads an undefined grid coordinate.
* **VMEM budget** — the per-step working set (sum over all BlockSpec
  block shapes x dtype width x 2 for pipeline double-buffering, plus
  VMEM scratch) must stay under ``vmem_budget_bytes`` (default 16 MiB, a
  TPU core's VMEM). Dimensions are resolved from literals, parameter
  defaults and module constants (``min(a, b)`` takes the resolvable
  bound); unresolvable dimensions assume ``assumed_dim`` lanes — the
  estimate is a static stand-in for what ``bench_roofline.py`` only
  measures at runtime.
"""
from __future__ import annotations

import ast
from typing import Iterator, List, Optional, Tuple

from tools.fedlint import astutil
from tools.fedlint.core import Diagnostic, ModuleContext, Rule, WARNING


def _is_pallas_call(call: ast.Call) -> bool:
    name = astutil.call_name(call)
    return bool(name) and astutil.last_segment(name) == "pallas_call"


def _resolve_local(name_node: ast.expr, func: Optional[ast.FunctionDef]
                   ) -> Optional[ast.expr]:
    """A local single-assignment value for a Name, else None."""
    if not isinstance(name_node, ast.Name) or func is None:
        return None
    table = astutil._constant_assignments(list(ast.walk(func)),
                                          stmts_are_nodes=True)
    return table.get(name_node.id)


def _enclosing_function(node: ast.AST) -> Optional[ast.FunctionDef]:
    while node is not None:
        node = astutil.parent(node)
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            return node
    return None


def _grid_elements(call: ast.Call, func: Optional[ast.FunctionDef]
                   ) -> Optional[List[ast.expr]]:
    grid = astutil.keyword_arg(call, "grid")
    if grid is None:
        return None
    if isinstance(grid, ast.Name):
        grid = _resolve_local(grid, func) or grid
    if isinstance(grid, (ast.Tuple, ast.List)):
        return list(grid.elts)
    if isinstance(grid, ast.Name):
        return None                       # unresolvable alias
    return [grid]                         # single-dim grid


def _block_specs(call: ast.Call, func: Optional[ast.FunctionDef]
                 ) -> List[ast.Call]:
    specs: List[ast.Call] = []
    for kw_name in ("in_specs", "out_specs"):
        node = astutil.keyword_arg(call, kw_name)
        if node is None:
            continue
        if isinstance(node, ast.Name):
            node = _resolve_local(node, func) or node
        items = node.elts if isinstance(node, (ast.Tuple, ast.List)) \
            else [node]
        for item in items:
            if isinstance(item, ast.Name):
                item = _resolve_local(item, func) or item
            if isinstance(item, ast.Call):
                name = astutil.call_name(item)
                if name and astutil.last_segment(name) == "BlockSpec":
                    specs.append(item)
    return specs


def _scratch_shapes(call: ast.Call, func: Optional[ast.FunctionDef]
                    ) -> List[ast.Call]:
    node = astutil.keyword_arg(call, "scratch_shapes")
    if node is None:
        return []
    if isinstance(node, ast.Name):
        node = _resolve_local(node, func) or node
    items = node.elts if isinstance(node, (ast.Tuple, ast.List)) else [node]
    return [i for i in items if isinstance(i, ast.Call)]


def _kernel_function(call: ast.Call, ctx: ModuleContext
                     ) -> Optional[ast.FunctionDef]:
    if not call.args:
        return None
    target = astutil.unwrap_partial(call.args[0])
    if isinstance(target, ast.Name):
        resolved = _resolve_local(target, _enclosing_function(call))
        if resolved is not None:
            target = astutil.unwrap_partial(resolved)
    name = astutil.dotted_name(target)
    if name is None:
        return None
    simple = astutil.last_segment(name)
    for func in astutil.iter_functions(ctx.tree):
        if func.name == simple:
            return func
    return None


def _divisibility_guards(func: Optional[ast.FunctionDef]
                         ) -> List[Tuple[str, str]]:
    """(dump(X), dump(B)) pairs guarded by ``assert/raise X % B == 0``."""
    guards: List[Tuple[str, str]] = []
    if func is None:
        return guards

    def compares(test: ast.expr) -> Iterator[ast.Compare]:
        if isinstance(test, ast.BoolOp):
            for v in test.values:
                yield from compares(v)
        elif isinstance(test, ast.Compare):
            yield test
        elif isinstance(test, ast.UnaryOp) and isinstance(test.op, ast.Not):
            yield from compares(test.operand)
        elif isinstance(test, ast.BinOp):
            yield ast.Compare(left=test, ops=[ast.NotEq()],
                              comparators=[ast.Constant(value=0)])

    def record(cmp: ast.Compare):
        # match `X % B == 0` / `X % B != 0` / bare `X % B` truthiness
        if isinstance(cmp.left, ast.BinOp) and isinstance(cmp.left.op,
                                                          ast.Mod):
            guards.append((ast.dump(cmp.left.left),
                           ast.dump(cmp.left.right)))

    for node in ast.walk(func):
        if isinstance(node, ast.Assert):
            for cmp in compares(node.test):
                record(cmp)
        elif isinstance(node, ast.If):
            # `if X % B: raise` / `if X % B != 0: raise` guard style
            if any(isinstance(s, ast.Raise) for s in node.body):
                for cmp in compares(node.test):
                    record(cmp)
    return guards


def _uses_masking(kernel: Optional[ast.FunctionDef]) -> bool:
    if kernel is None:
        return False
    for node in ast.walk(kernel):
        name = None
        if isinstance(node, ast.Call):
            name = astutil.call_name(node)
        elif isinstance(node, ast.Attribute):
            name = astutil.dotted_name(node)
        if name and astutil.last_segment(name) == "when":
            return True
    return False


class PallasTiling(Rule):
    rule_id = "FL003"
    name = "pallas-tiling"
    default_options = {
        "enabled": True,
        "vmem_budget_bytes": 16 * 1024 * 1024,
        "dtype_bytes": 4,         # kernels accumulate in fp32
        "assumed_dim": 32,        # stand-in for unresolvable dims (e.g. C)
        "double_buffer": 2,       # Pallas pipelines double-buffer blocks
    }

    def check_module(self, ctx: ModuleContext) -> Iterator[Diagnostic]:
        calls = [c for c in astutil.iter_calls(ctx.tree)
                 if _is_pallas_call(c)]
        for call in calls:
            yield from self._check_call(ctx, call)

    def _check_call(self, ctx: ModuleContext, call: ast.Call
                    ) -> Iterator[Diagnostic]:
        wrapper = _enclosing_function(call)
        kernel = _kernel_function(call, ctx)
        grid = _grid_elements(call, wrapper)
        resolver = astutil.ConstResolver(ctx.tree, wrapper)

        if grid is not None:
            yield from self._check_grid_divisibility(
                ctx, call, grid, wrapper, kernel, resolver)
            yield from self._check_program_id(ctx, call, len(grid), kernel)
        yield from self._check_vmem(ctx, call, wrapper, resolver)

    # ------------------------------------------------------- grid dividing
    def _check_grid_divisibility(self, ctx, call, grid, wrapper, kernel,
                                 resolver) -> Iterator[Diagnostic]:
        guards = _divisibility_guards(wrapper)
        masked = _uses_masking(kernel)
        for dim_idx, elem in enumerate(grid):
            expr = elem
            if isinstance(expr, ast.Name):
                expr = _resolve_local(expr, wrapper) or expr
            if isinstance(expr, ast.Call):
                name = astutil.call_name(expr)
                if name and astutil.last_segment(name) == "cdiv":
                    if not masked:
                        yield ctx.diag(
                            elem, self.rule_id,
                            f"grid dim {dim_idx} uses cdiv (ragged last "
                            "block) but the kernel body has no pl.when "
                            "masking — out-of-bounds lanes of the tail "
                            "block are read/written unguarded")
                    continue
            if isinstance(expr, ast.BinOp) and isinstance(expr.op,
                                                          ast.FloorDiv):
                num, den = expr.left, expr.right
                nval = resolver.resolve(num)
                dval = resolver.resolve(den)
                if nval is not None and dval is not None and dval != 0:
                    if nval % dval != 0:
                        yield ctx.diag(
                            elem, self.rule_id,
                            f"grid dim {dim_idx} = {ast.unparse(expr)} "
                            f"drops a remainder ({nval} % {dval} = "
                            f"{nval % dval}): the tail elements are "
                            "never visited — pad, mask, or assert "
                            "divisibility")
                    continue
                pair = (ast.dump(num), ast.dump(den))
                if pair not in guards and not masked:
                    yield ctx.diag(
                        elem, self.rule_id,
                        f"grid dim {dim_idx} = {ast.unparse(expr)} "
                        "floor-divides dynamically but nothing guards "
                        f"divisibility — add `assert "
                        f"{ast.unparse(num)} % {ast.unparse(den)} == 0` "
                        "(or mask the tail block with pl.when)")

    # -------------------------------------------------------- program_id
    def _check_program_id(self, ctx, call, rank: int, kernel
                          ) -> Iterator[Diagnostic]:
        if kernel is None:
            return
        for node in ast.walk(kernel):
            if not isinstance(node, ast.Call):
                continue
            name = astutil.call_name(node)
            if not name or astutil.last_segment(name) != "program_id":
                continue
            axis = None
            if node.args and isinstance(node.args[0], ast.Constant):
                axis = node.args[0].value
            kw = astutil.keyword_arg(node, "axis")
            if kw is not None and isinstance(kw, ast.Constant):
                axis = kw.value
            if isinstance(axis, int) and axis >= rank:
                yield ctx.diag(
                    node, self.rule_id,
                    f"pl.program_id({axis}) in {kernel.name}() but the "
                    f"grid has rank {rank} (axes 0..{rank - 1})")

    # ------------------------------------------------------------- VMEM
    def _check_vmem(self, ctx, call, wrapper, resolver
                    ) -> Iterator[Diagnostic]:
        budget = ctx.options["vmem_budget_bytes"]
        dtype_bytes = ctx.options["dtype_bytes"]
        assumed = ctx.options["assumed_dim"]
        dbuf = ctx.options["double_buffer"]

        total = 0
        approximate = False
        specs = _block_specs(call, wrapper)
        if not specs:
            return
        for spec in specs:
            shape = spec.args[0] if spec.args else \
                astutil.keyword_arg(spec, "block_shape")
            if shape is None:
                continue
            if isinstance(shape, ast.Name):
                shape = _resolve_local(shape, wrapper) or shape
            dims = shape.elts if isinstance(shape, (ast.Tuple, ast.List)) \
                else [shape]
            n = 1
            for d in dims:
                val = resolver.resolve(d)
                if val is None:
                    val = assumed
                    approximate = True
                n *= max(val, 1)
            total += n * dtype_bytes * dbuf
        for scratch in _scratch_shapes(call, wrapper):
            shape = scratch.args[0] if scratch.args else None
            if shape is None:
                continue
            dims = shape.elts if isinstance(shape, (ast.Tuple, ast.List)) \
                else [shape]
            n = 1
            for d in dims:
                val = resolver.resolve(d)
                if val is None:
                    val = assumed
                    approximate = True
                n *= max(val, 1)
            total += n * dtype_bytes      # scratch is not double-buffered

        if total > budget:
            approx = " (approximate: unresolved dims assumed " \
                f"{assumed})" if approximate else ""
            yield ctx.diag(
                call, self.rule_id,
                f"estimated VMEM working set ~{total / 2 ** 20:.1f} MiB "
                f"exceeds the {budget / 2 ** 20:.0f} MiB budget"
                f"{approx} — shrink the block shapes or stream over a "
                "larger grid",
                severity=WARNING if approximate else "error")
