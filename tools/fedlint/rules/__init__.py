"""fedlint rule registry — one module per invariant (DESIGN.md §8)."""
from __future__ import annotations

from typing import Iterable, List

from tools.fedlint.core import Rule
from tools.fedlint.rules.fl001_keys import KeyDiscipline
from tools.fedlint.rules.fl002_retrace import RetraceHazards
from tools.fedlint.rules.fl003_tiling import PallasTiling
from tools.fedlint.rules.fl004_registry import RegistryConformance
from tools.fedlint.rules.fl005_donation import DonationSafety

ALL_RULES = (KeyDiscipline, RetraceHazards, PallasTiling,
             RegistryConformance, DonationSafety)

RULES_BY_ID = {cls.rule_id: cls for cls in ALL_RULES}


def build_rules(enabled: Iterable[str]) -> List[Rule]:
    """Instantiate the requested rules, in FL001..FL005 order."""
    wanted = set(enabled)
    return [cls() for cls in ALL_RULES if cls.rule_id in wanted]
