"""FL001 — PRNG key discipline.

Two invariants (DESIGN.md §8):

* **No fixed key literals in library code.** Every key in ``src/`` must
  derive from an explicit seed (``FedConfig.seed``, a ``seed`` argument,
  ``args.seed``, …) so the ``round_keys`` schedule is the single source
  of randomness the three exchange backends replay bit-identically. A
  ``jax.random.PRNGKey(<literal>)`` buried in a strategy or model makes
  part of the schedule predictable and unkeyed by the run — exactly the
  coverage-selector bug PR 5 fixed by hand. Entry points (tests,
  benchmarks, examples) own their seeds, so the literal check is relaxed
  there by config (``allow_literal_keys``).

* **No key reuse.** A key consumed by two ``jax.random.*`` draws (or
  passed to two key-consuming helpers) without an intervening
  ``split`` / ``fold_in`` produces *correlated* streams — e.g. an attack
  corruption and a tester draw seeing identical randomness.
  Reassignment (``key, sub = jax.random.split(key)``) resets the count;
  ``fold_in`` is the sanctioned multi-derivation and never counts as a
  consume.
"""
from __future__ import annotations

import ast
import copy
import re
from typing import Dict, Iterator, List, Set, Tuple

from tools.fedlint import astutil
from tools.fedlint.core import Diagnostic, ModuleContext, Rule

# jax.random.* callees that do NOT consume their key argument: key
# constructors and the sanctioned derivation primitive.
_NON_CONSUMING = {"fold_in", "PRNGKey", "key", "wrap_key_data",
                  "key_data", "key_impl", "clone"}

# builtins never draw from a key — str(p.key) in a pytree-path walk is
# not a consume.
_BUILTINS = {"str", "repr", "format", "print", "len", "zip", "list",
             "tuple", "set", "dict", "sorted", "enumerate", "hash",
             "isinstance", "hasattr", "getattr", "type", "id", "min",
             "max", "sum", "map", "filter", "bool", "int", "float",
             "abs", "range", "reversed", "any", "all"}

# numpy's stateful Generators are reused by design; only jax keys are
# single-use, so `rng` is deliberately NOT key-like.
_KEYLIKE = re.compile(r"(^|_)(key|prngkey|subkey)s?$", re.IGNORECASE)

_COMPREHENSIONS = (ast.ListComp, ast.SetComp, ast.DictComp,
                   ast.GeneratorExp)

_NUMPY_ROOTS = {"np", "numpy", "onp", "scipy"}


def _is_jax_random_call(call: ast.Call) -> Tuple[bool, str]:
    name = astutil.call_name(call)
    if not name:
        return False, ""
    parts = name.split(".")
    if parts[0] in _NUMPY_ROOTS:    # np.random.* is stateful, not keyed
        return False, ""
    if "random" in parts[:-1]:
        return True, parts[-1]
    if parts[-1] == "PRNGKey":      # from jax.random import PRNGKey
        return True, "PRNGKey"
    return False, ""


def _block_terminates(stmts: List[ast.stmt]) -> bool:
    """Control flow cannot fall out of the bottom of this block."""
    for stmt in stmts:
        if isinstance(stmt, (ast.Return, ast.Raise, ast.Break,
                             ast.Continue)):
            return True
        if isinstance(stmt, ast.If) and stmt.orelse \
                and _block_terminates(stmt.body) \
                and _block_terminates(stmt.orelse):
            return True
    return False


def _keylike(name: str) -> bool:
    return bool(_KEYLIKE.search(name.rsplit(".", 1)[-1]))


class KeyDiscipline(Rule):
    rule_id = "FL001"
    name = "key-discipline"
    default_options = {
        "enabled": True,
        # entry-point trees (tests/benchmarks/examples) set this True:
        # literal seeds at construction sites are their idiom.
        "allow_literal_keys": False,
        "check_reuse": True,
        # tests deliberately reuse keys through helpers to assert
        # determinism; they turn this off (direct jax.random reuse is
        # still checked there).
        "check_helper_reuse": True,
        # repo-sanctioned derivation helpers: like fold_in, calling them
        # does not consume the key they derive from. The eval-batch
        # helpers (repro.core.cross_testing, DESIGN.md §10) fold_in the
        # EVAL_BATCH_STREAM constant before any draw, so handing them
        # the run key leaves it unconsumed.
        "non_consuming_helpers": ["round_keys", "sampled_eval_batches",
                                  "eval_batch_indices"],
        # names assigned from these constructors hold a *bundle* of
        # already-derived keys (RoundKeys); handing the bundle to the
        # engine's entry points is the schedule, not a reuse.
        "bundle_constructors": ["round_keys"],
    }

    # ------------------------------------------------------------- literals
    def _check_literals(self, ctx: ModuleContext) -> Iterator[Diagnostic]:
        for call in astutil.iter_calls(ctx.tree):
            is_rand, fn = _is_jax_random_call(call)
            if not is_rand or fn not in ("PRNGKey", "key"):
                continue
            if not call.args:
                continue
            seed_arg = call.args[0]
            if astutil.is_pure_constant(seed_arg):
                yield ctx.diag(
                    call, self.rule_id,
                    f"fixed PRNG key literal jax.random.{fn}"
                    f"({ast.unparse(seed_arg)}) in library code — derive "
                    "the key from an explicit seed (FedConfig.seed / a "
                    "seed argument) so the randomness is keyed by the "
                    "run, not by the source")
                continue
            idents = astutil.identifiers_in(seed_arg)
            if idents and not any("seed" in i.lower() for i in idents):
                yield ctx.diag(
                    call, self.rule_id,
                    f"jax.random.{fn}({ast.unparse(seed_arg)}) is not "
                    "derived from a seed — construction sites must "
                    "reference a seed value (…seed…) or take the key "
                    "from the caller")

    # ---------------------------------------------------------------- reuse
    def _check_reuse(self, ctx: ModuleContext) -> Iterator[Diagnostic]:
        for func in astutil.iter_functions(ctx.tree):
            # nested defs get their own visit via iter_functions; track
            # each function body in isolation (closures are not tainted).
            yield from self._check_function(ctx, func)

    def _check_function(self, ctx: ModuleContext, func: ast.FunctionDef
                        ) -> Iterator[Diagnostic]:
        state: Dict[str, int] = {}
        known_keys: Set[str] = set()
        seen: Set[Tuple[str, int]] = set()
        diags: List[Diagnostic] = []
        self._bundles: Set[str] = set()
        self._helper_reuse = ctx.options.get("check_helper_reuse", True)
        self._derivers = set(_NON_CONSUMING) | set(
            ctx.options.get("non_consuming_helpers", []))
        self._bundle_ctors = set(
            ctx.options.get("bundle_constructors", []))
        self._run_block(ctx, func.body, state, known_keys, seen, diags)
        yield from diags

    def _run_block(self, ctx, stmts, state, known, seen, diags) -> None:
        for stmt in stmts:
            self._run_stmt(ctx, stmt, state, known, seen, diags)

    def _run_stmt(self, ctx, stmt, state, known, seen, diags) -> None:
        if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef)):
            return          # separate scope, visited on its own
        if isinstance(stmt, ast.If):
            self._consume_expr(ctx, stmt.test, state, known, seen, diags)
            s_then = copy.deepcopy(state)
            s_else = copy.deepcopy(state)
            self._run_block(ctx, stmt.body, s_then, known, seen, diags)
            self._run_block(ctx, stmt.orelse, s_else, known, seen, diags)
            # a branch that returns/raises never merges back into the
            # fall-through path (dispatch ladders: `if a: return f(key)`)
            merge = []
            if not _block_terminates(stmt.body):
                merge.append(s_then)
            if not _block_terminates(stmt.orelse):
                merge.append(s_else)
            state.clear()
            for branch in merge:
                for name, count in branch.items():
                    state[name] = max(state.get(name, 0), count)
            return
        if isinstance(stmt, (ast.For, ast.AsyncFor)):
            self._consume_expr(ctx, stmt.iter, state, known, seen, diags)
            # two passes simulate repeated iterations: a key consumed in
            # the body without a per-iteration reassignment trips pass 2
            for _ in range(2):
                for name in astutil.assign_targets(stmt):
                    state[name] = 0
                self._run_block(ctx, stmt.body, state, known, seen, diags)
            self._run_block(ctx, stmt.orelse, state, known, seen, diags)
            return
        if isinstance(stmt, ast.While):
            for _ in range(2):
                self._consume_expr(ctx, stmt.test, state, known, seen,
                                   diags)
                self._run_block(ctx, stmt.body, state, known, seen, diags)
            self._run_block(ctx, stmt.orelse, state, known, seen, diags)
            return
        if isinstance(stmt, ast.Try):
            self._run_block(ctx, stmt.body, state, known, seen, diags)
            for handler in stmt.handlers:
                self._run_block(ctx, handler.body, state, known, seen,
                                diags)
            self._run_block(ctx, stmt.orelse, state, known, seen, diags)
            self._run_block(ctx, stmt.finalbody, state, known, seen, diags)
            return
        if isinstance(stmt, (ast.With, ast.AsyncWith)):
            for item in stmt.items:
                self._consume_expr(ctx, item.context_expr, state, known,
                                   seen, diags)
            for name in astutil.assign_targets(stmt):
                state[name] = 0
            self._run_block(ctx, stmt.body, state, known, seen, diags)
            return
        # leaf statements: evaluate expressions, then apply bindings
        for child in ast.iter_child_nodes(stmt):
            if isinstance(child, ast.expr):
                self._consume_expr(ctx, child, state, known, seen, diags)
        if isinstance(stmt, (ast.Assign, ast.AnnAssign, ast.AugAssign)):
            self._apply_assignment(stmt, state, known)
        elif isinstance(stmt, ast.Delete):
            for tgt in stmt.targets:
                name = astutil.dotted_name(tgt)
                if name:
                    state[name] = 0

    def _apply_assignment(self, stmt, state, known) -> None:
        value = getattr(stmt, "value", None)
        is_key_rhs = False
        is_bundle_rhs = False
        if isinstance(value, ast.Call):
            is_rand, fn = _is_jax_random_call(value)
            is_key_rhs = is_rand and fn in ("split", "fold_in", "PRNGKey",
                                            "key", "clone")
            cname = astutil.call_name(value)
            if cname and astutil.last_segment(cname) in self._bundle_ctors:
                is_bundle_rhs = True
        for name in astutil.assign_targets(stmt):
            state[name] = 0
            # rebinding `p` invalidates stale counts for `p.key` etc.
            prefix = name + "."
            for tracked in [t for t in state if t.startswith(prefix)]:
                state[tracked] = 0
            if is_key_rhs:
                known.add(name)
            if is_bundle_rhs:
                self._bundles.add(name)

    def _consume_expr(self, ctx, expr, state, known, seen, diags) -> None:
        if expr is None:
            return
        for call in astutil.iter_calls(expr):
            is_rand, fn = _is_jax_random_call(call)
            if is_rand:
                if fn in _NON_CONSUMING:
                    continue
                key_expr = (call.args[0] if call.args
                            else astutil.keyword_arg(call, "key"))
                self._consume(ctx, call, key_expr, state, known, seen,
                              diags, via=f"jax.random.{fn}")
                continue
            if not self._helper_reuse:
                continue
            callee = astutil.call_name(call)
            last = astutil.last_segment(callee) if callee else None
            if last in _BUILTINS or last in self._derivers:
                continue
            # a known key var handed to any other callable counts as one
            # consume — helpers (attack.apply, select_testers, …) draw
            # from it downstream
            for arg in list(call.args) + [kw.value for kw in call.keywords]:
                name = astutil.dotted_name(arg)
                if name is None or name in self._bundles:
                    continue
                if name in known or _keylike(name):
                    self._consume(ctx, call, arg, state, known, seen,
                                  diags, via=callee or "<call>")

    def _consume(self, ctx, call, key_expr, state, known, seen, diags,
                 via: str) -> None:
        if key_expr is None:
            return
        name = astutil.dotted_name(key_expr)
        if name is None:
            return
        inc = 1
        node = key_expr
        while node is not None:
            node = astutil.parent(node)
            if isinstance(node, _COMPREHENSIONS):
                # the body runs per element — a key from *outside* is
                # consumed repeatedly, but the comprehension's own loop
                # variable (k for k in split(key, n)) is fresh each time
                bound: Set[str] = set()
                for gen in node.generators:
                    for t in ast.walk(gen.target):
                        tn = astutil.dotted_name(t)
                        if tn:
                            bound.add(tn)
                if name not in bound:
                    inc = 2
                break
        state[name] = state.get(name, 0) + inc
        known.add(name)
        if state[name] >= 2:
            mark = (name, call.lineno)
            if mark in seen:
                return
            seen.add(mark)
            diags.append(ctx.diag(
                call, self.rule_id,
                f"PRNG key {name!r} is consumed more than once without "
                f"an intervening split/fold_in (reused here by {via}) — "
                "correlated streams break the round_keys discipline"))

    # ----------------------------------------------------------------- entry
    def check_module(self, ctx: ModuleContext) -> Iterator[Diagnostic]:
        if not ctx.options.get("allow_literal_keys", False):
            yield from self._check_literals(ctx)
        if ctx.options.get("check_reuse", True):
            yield from self._check_reuse(ctx)
