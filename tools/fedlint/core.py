"""fedlint core: diagnostics, suppressions, baseline, the lint driver.

fedlint is the repo's third CI gate (next to ``check_docs.py`` and
``check_bench.py``): a stdlib-``ast`` static pass that proves the
conventions the backend-parity guarantee rests on — PRNG key discipline,
no trace-time branching on traced values, Pallas tiling invariants,
strategy-protocol conformance, donation safety (DESIGN.md §8). It never
imports the code it checks, so it runs in milliseconds before the test
suite and on machines that cannot import the accelerator stack.

Suppression syntax (DESIGN.md §8):

* ``# fedlint: disable=FL001`` on the flagged line (comma-separate
  several ids, or ``disable=all``) silences that line;
* ``# fedlint: disable-file=FL003`` anywhere in a file silences the rule
  for the whole file.

A committed baseline (``tools/fedlint/baseline.json``) can grandfather
known findings; this repo commits an *empty* baseline — the gate is
strict from day one.
"""
from __future__ import annotations

import ast
import dataclasses
import fnmatch
import json
import re
from pathlib import Path
from typing import Any, Dict, Iterable, Iterator, List, Optional, Tuple

from tools.fedlint import astutil

ROOT = Path(__file__).resolve().parent.parent.parent
BASELINE_PATH = Path(__file__).resolve().parent / "baseline.json"

ERROR = "error"
WARNING = "warning"

_SUPPRESS = re.compile(
    r"#\s*fedlint:\s*(disable|disable-file)\s*=\s*"
    r"([A-Za-z0-9_,\s]+)")


@dataclasses.dataclass(frozen=True)
class Diagnostic:
    """One finding: ``path:line [RULE] severity: message``."""

    path: str           # repo-relative, forward slashes
    line: int
    rule: str           # FL001..FL005
    severity: str       # error | warning
    message: str

    def format(self) -> str:
        return (f"{self.path}:{self.line} [{self.rule}] "
                f"{self.severity}: {self.message}")

    def fingerprint(self) -> Tuple[str, str, str]:
        """Baseline identity: stable under unrelated line-number churn."""
        return (self.path, self.rule, self.message)

    def to_json(self) -> Dict[str, Any]:
        return dataclasses.asdict(self)


class ModuleContext:
    """Everything a rule sees for one file."""

    def __init__(self, path: Path, relpath: str, source: str,
                 tree: ast.Module, options: Dict[str, Any],
                 project: "ProjectIndex"):
        self.path = path
        self.relpath = relpath
        self.source = source
        self.lines = source.splitlines()
        self.tree = tree
        self.options = options
        self.project = project

    def diag(self, node_or_line, rule: str, message: str,
             severity: str = ERROR) -> Diagnostic:
        line = (node_or_line if isinstance(node_or_line, int)
                else getattr(node_or_line, "lineno", 1))
        return Diagnostic(path=self.relpath, line=line, rule=rule,
                          severity=severity, message=message)


class Rule:
    """A pluggable invariant check. Subclasses yield Diagnostics."""

    rule_id = "FL000"
    name = "base"
    default_options: Dict[str, Any] = {}

    def check_module(self, ctx: ModuleContext) -> Iterator[Diagnostic]:
        raise NotImplementedError


@dataclasses.dataclass
class ClassInfo:
    node: ast.ClassDef
    module: str                       # relpath of the defining file
    base_names: List[str]
    registries: List[Tuple[str, str]]  # (REGISTRY, "entry-name") pairs


class ProjectIndex:
    """Cross-file class index (FL004 resolves inheritance through it)."""

    def __init__(self):
        # simple class name -> list of ClassInfo (collisions kept)
        self.classes: Dict[str, List[ClassInfo]] = {}

    def add_module(self, relpath: str, tree: ast.Module) -> None:
        for node in ast.walk(tree):
            if not isinstance(node, ast.ClassDef):
                continue
            bases = [b for b in (astutil.dotted_name(base)
                                 for base in node.bases) if b]
            regs = []
            for deco in node.decorator_list:
                if not isinstance(deco, ast.Call):
                    continue
                name = astutil.call_name(deco)
                if name and astutil.last_segment(name) == "register" \
                        and len(deco.args) >= 2:
                    reg = astutil.dotted_name(deco.args[0])
                    entry = deco.args[1]
                    if reg and isinstance(entry, ast.Constant):
                        regs.append((astutil.last_segment(reg),
                                     str(entry.value)))
            info = ClassInfo(node=node, module=relpath,
                             base_names=[astutil.last_segment(b)
                                         for b in bases],
                             registries=regs)
            self.classes.setdefault(node.name, []).append(info)

    def lookup(self, name: str, prefer_module: Optional[str] = None
               ) -> Optional[ClassInfo]:
        infos = self.classes.get(name)
        if not infos:
            return None
        if prefer_module:
            for info in infos:
                if info.module == prefer_module:
                    return info
        return infos[0]

    def mro(self, info: ClassInfo, max_depth: int = 12) -> List[ClassInfo]:
        """Approximate linearisation: the class, then bases breadth-first
        (resolved by simple name; same-module definitions win)."""
        seen, order, queue = set(), [], [info]
        while queue and len(order) < max_depth:
            cur = queue.pop(0)
            if id(cur) in seen:
                continue
            seen.add(id(cur))
            order.append(cur)
            for base in cur.base_names:
                nxt = self.lookup(base, prefer_module=cur.module)
                if nxt is not None:
                    queue.append(nxt)
        return order

    def find_method(self, info: ClassInfo, method: str
                    ) -> Optional[Tuple[ClassInfo, ast.FunctionDef]]:
        """First def of ``method`` along the approximate MRO."""
        for cls in self.mro(info):
            for stmt in cls.node.body:
                if isinstance(stmt, (ast.FunctionDef,
                                     ast.AsyncFunctionDef)) \
                        and stmt.name == method:
                    return cls, stmt
        return None

    def class_attr(self, info: ClassInfo, attr: str
                   ) -> Optional[Tuple[ClassInfo, ast.expr]]:
        """First class-level ``attr = value`` along the approximate MRO."""
        for cls in self.mro(info):
            for stmt in cls.node.body:
                if isinstance(stmt, ast.Assign):
                    for tgt in stmt.targets:
                        if isinstance(tgt, ast.Name) and tgt.id == attr:
                            return cls, stmt.value
        return None

    def subclasses_of(self, root_name: str, info: ClassInfo) -> bool:
        return any(cls.node.name == root_name for cls in self.mro(info))


# --------------------------------------------------------------- suppressions
def parse_suppressions(source: str):
    """-> (``{line: {rule,...}}``, file-wide ``{rule,...}``)."""
    per_line: Dict[int, set] = {}
    per_file: set = set()
    for lineno, line in enumerate(source.splitlines(), start=1):
        for kind, ids in _SUPPRESS.findall(line):
            rules = {r.strip().upper() for r in ids.split(",") if r.strip()}
            if kind == "disable-file":
                per_file |= rules
            else:
                per_line.setdefault(lineno, set()).update(rules)
    return per_line, per_file


def is_suppressed(diag: Diagnostic, per_line: Dict[int, set],
                  per_file: set) -> bool:
    def match(rules: set) -> bool:
        return "ALL" in rules or diag.rule.upper() in rules

    if match(per_file):
        return True
    rules = per_line.get(diag.line)
    return bool(rules and match(rules))


# -------------------------------------------------------------------- baseline
def load_baseline(path: Path = BASELINE_PATH) -> List[Dict[str, Any]]:
    if not path.exists():
        return []
    return json.loads(path.read_text() or "[]")


def baseline_fingerprints(entries: Iterable[Dict[str, Any]]):
    return {(e["path"], e["rule"], e["message"]) for e in entries}


def write_baseline(diags: List[Diagnostic],
                   path: Path = BASELINE_PATH) -> None:
    entries = [{"path": d.path, "rule": d.rule, "message": d.message}
               for d in sorted(diags, key=lambda d: (d.path, d.rule,
                                                     d.line))]
    path.write_text(json.dumps(entries, indent=1) + "\n")


# ---------------------------------------------------------------------- driver
def collect_files(paths: Iterable[Path]) -> List[Path]:
    files: List[Path] = []
    for p in paths:
        if p.is_dir():
            files.extend(sorted(p.rglob("*.py")))
        elif p.suffix == ".py":
            files.append(p)
    # dedupe, keep order
    seen, out = set(), []
    for f in files:
        r = f.resolve()
        if r not in seen:
            seen.add(r)
            out.append(f)
    return out


def relpath_of(path: Path, root: Path = ROOT) -> str:
    try:
        return path.resolve().relative_to(root).as_posix()
    except ValueError:
        return path.as_posix()


def merged_options(config, rule: Rule, relpath: str) -> Dict[str, Any]:
    opts = dict(rule.default_options)
    opts.update(config.rule_options.get(rule.rule_id, {}))
    for pattern, overrides in config.path_overrides:
        if fnmatch.fnmatch(relpath, pattern):
            opts.update(overrides.get(rule.rule_id, {}))
    return opts


def lint_files(files: Iterable[Path], config=None, root: Path = ROOT
               ) -> List[Diagnostic]:
    """Run every enabled rule over ``files``; returns unsuppressed
    diagnostics (baseline filtering is the caller's concern)."""
    from tools.fedlint.config import DEFAULT_CONFIG
    from tools.fedlint.rules import build_rules
    config = config or DEFAULT_CONFIG
    rules = build_rules(config.enabled_rules)

    parsed: List[Tuple[Path, str, str, ast.Module]] = []
    index = ProjectIndex()
    diags: List[Diagnostic] = []
    for path in files:
        relpath = relpath_of(path, root)
        try:
            source = path.read_text()
            tree = ast.parse(source, filename=str(path))
        except (SyntaxError, UnicodeDecodeError) as e:
            diags.append(Diagnostic(
                path=relpath, line=getattr(e, "lineno", 1) or 1,
                rule="FL000", severity=ERROR,
                message=f"file does not parse: {e.msg if hasattr(e, 'msg') else e}"))
            continue
        astutil.attach_parents(tree)
        index.add_module(relpath, tree)
        parsed.append((path, relpath, source, tree))

    for path, relpath, source, tree in parsed:
        per_line, per_file = parse_suppressions(source)
        for rule in rules:
            opts = merged_options(config, rule, relpath)
            if not opts.get("enabled", True):
                continue
            ctx = ModuleContext(path, relpath, source, tree, opts, index)
            for diag in rule.check_module(ctx):
                if not is_suppressed(diag, per_line, per_file):
                    diags.append(diag)
    diags.sort(key=lambda d: (d.path, d.line, d.rule))
    return diags


def lint_paths(paths: Iterable[str], config=None, root: Path = ROOT
               ) -> List[Diagnostic]:
    files = collect_files([root / p if not Path(p).is_absolute()
                           else Path(p) for p in paths])
    return lint_files(files, config=config, root=root)
