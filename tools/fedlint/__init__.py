"""fedlint — AST-based invariant checker for this repo (DESIGN.md §8).

Public API::

    from tools.fedlint import lint_paths, DEFAULT_CONFIG
    diags = lint_paths(["src", "tests"])

Run as a CLI: ``python -m tools.fedlint [--json] [paths...]``.
"""
from tools.fedlint.core import (BASELINE_PATH, Diagnostic, ERROR, WARNING,
                                baseline_fingerprints, lint_files,
                                lint_paths, load_baseline, write_baseline)
from tools.fedlint.config import (DEFAULT_CONFIG, DEFAULT_PATHS,
                                  LintConfig, STRICT_CONFIG)

__all__ = [
    "BASELINE_PATH", "Diagnostic", "ERROR", "WARNING",
    "baseline_fingerprints", "lint_files", "lint_paths", "load_baseline",
    "write_baseline", "DEFAULT_CONFIG", "DEFAULT_PATHS", "LintConfig",
    "STRICT_CONFIG",
]
