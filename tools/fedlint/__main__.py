"""fedlint CLI: ``python -m tools.fedlint [paths...]``.

Exit status is 0 when no *error*-severity finding survives baseline
filtering (warnings print but never gate), 1 otherwise.
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path
from typing import List, Optional

from tools.fedlint.config import DEFAULT_CONFIG, DEFAULT_PATHS
from tools.fedlint.core import (BASELINE_PATH, ERROR, Diagnostic,
                                baseline_fingerprints, lint_paths,
                                load_baseline, write_baseline)


def run(argv: Optional[List[str]] = None) -> int:
    parser = argparse.ArgumentParser(
        prog="python -m tools.fedlint",
        description="AST invariant checker (FL001-FL005, DESIGN.md §8)")
    parser.add_argument("paths", nargs="*", default=None,
                        help=f"files/dirs to lint (default: "
                             f"{' '.join(DEFAULT_PATHS)})")
    parser.add_argument("--json", action="store_true",
                        help="emit findings as a JSON array on stdout")
    parser.add_argument("--baseline", default=str(BASELINE_PATH),
                        help="baseline file (default: committed baseline)")
    parser.add_argument("--no-baseline", action="store_true",
                        help="ignore the baseline entirely")
    parser.add_argument("--write-baseline", action="store_true",
                        help="write current findings as the new baseline "
                             "and exit 0")
    args = parser.parse_args(argv)

    paths = args.paths or DEFAULT_PATHS
    diags = lint_paths(paths, config=DEFAULT_CONFIG)

    if args.write_baseline:
        write_baseline(diags, Path(args.baseline))
        print(f"fedlint: wrote {len(diags)} finding(s) to "
              f"{args.baseline}")
        return 0

    if not args.no_baseline:
        known = baseline_fingerprints(load_baseline(Path(args.baseline)))
        diags = [d for d in diags if d.fingerprint() not in known]

    if args.json:
        print(json.dumps([d.to_json() for d in diags], indent=1))
    else:
        for d in diags:
            print(d.format())

    errors = [d for d in diags if d.severity == ERROR]
    if not args.json:
        warnings = len(diags) - len(errors)
        print(f"fedlint: {len(errors)} error(s), {warnings} warning(s) "
              f"across {len(paths)} path(s)", file=sys.stderr)
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(run())
