"""Shared AST helpers for the fedlint rules.

Everything here is stdlib-``ast`` only — fedlint runs in CI before any
heavyweight import and never imports the code it checks (a kernel file
that needs a TPU to import must still lint on a laptop).
"""
from __future__ import annotations

import ast
from typing import Any, Dict, Iterator, List, Optional, Tuple


def attach_parents(tree: ast.AST) -> None:
    """Annotate every node with ``.fedlint_parent`` (None at the root)."""
    tree.fedlint_parent = None  # type: ignore[attr-defined]
    for node in ast.walk(tree):
        for child in ast.iter_child_nodes(node):
            child.fedlint_parent = node  # type: ignore[attr-defined]


def parent(node: ast.AST) -> Optional[ast.AST]:
    return getattr(node, "fedlint_parent", None)


def dotted_name(node: ast.AST) -> Optional[str]:
    """``a.b.c`` for Name/Attribute chains, else None."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def call_name(call: ast.Call) -> Optional[str]:
    """Dotted name of a call's callee (``jax.random.split``), else None."""
    return dotted_name(call.func)


def last_segment(dotted: str) -> str:
    return dotted.rsplit(".", 1)[-1]


def keyword_arg(call: ast.Call, name: str) -> Optional[ast.expr]:
    for kw in call.keywords:
        if kw.arg == name:
            return kw.value
    return None


def iter_calls(tree: ast.AST) -> Iterator[ast.Call]:
    for node in ast.walk(tree):
        if isinstance(node, ast.Call):
            yield node


def iter_functions(tree: ast.AST) -> Iterator[ast.FunctionDef]:
    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            yield node


def str_constants(node: ast.expr) -> List[str]:
    """String elements of a tuple/list/single-string constant expr."""
    if isinstance(node, ast.Constant) and isinstance(node.value, str):
        return [node.value]
    if isinstance(node, (ast.Tuple, ast.List)):
        out = []
        for elt in node.elts:
            if isinstance(elt, ast.Constant) and isinstance(elt.value, str):
                out.append(elt.value)
        return out
    return []


def int_constants(node: ast.expr) -> List[int]:
    """Int elements of a tuple/list/single-int constant expr."""
    if isinstance(node, ast.Constant) and isinstance(node.value, int):
        return [node.value]
    if isinstance(node, (ast.Tuple, ast.List)):
        out = []
        for elt in node.elts:
            if isinstance(elt, ast.Constant) and isinstance(elt.value, int):
                out.append(elt.value)
        return out
    return []


def is_pure_constant(node: ast.expr) -> bool:
    """True when the expression is built only from literal constants."""
    if isinstance(node, ast.Constant):
        return True
    if isinstance(node, ast.BinOp):
        return is_pure_constant(node.left) and is_pure_constant(node.right)
    if isinstance(node, ast.UnaryOp):
        return is_pure_constant(node.operand)
    if isinstance(node, (ast.Tuple, ast.List)):
        return all(is_pure_constant(e) for e in node.elts)
    return False


def identifiers_in(node: ast.expr) -> List[str]:
    """All Name ids and Attribute attrs appearing in the expression."""
    out: List[str] = []
    for n in ast.walk(node):
        if isinstance(n, ast.Name):
            out.append(n.id)
        elif isinstance(n, ast.Attribute):
            out.append(n.attr)
    return out


class ConstResolver:
    """Best-effort static evaluation of integer dimension expressions.

    Resolution order for a bare name: local single-target assignments in
    the enclosing function, the enclosing function's keyword defaults,
    module-level constants. ``min(a, b)`` resolves to the minimum of its
    resolvable operands (an upper bound — exactly what a VMEM budget
    check needs). Anything else resolves to ``None``.
    """

    def __init__(self, module: ast.Module,
                 func: Optional[ast.FunctionDef] = None,
                 assumed: Optional[Dict[str, int]] = None):
        self.module_consts = _constant_assignments(module.body)
        self.local_consts: Dict[str, ast.expr] = {}
        self.param_defaults: Dict[str, ast.expr] = {}
        self.assumed = dict(assumed or {})
        if func is not None:
            self.local_consts = _constant_assignments(
                list(ast.walk(func)), stmts_are_nodes=True)
            self.param_defaults = _param_defaults(func)

    def resolve(self, node: ast.expr, depth: int = 0) -> Optional[int]:
        if depth > 8:
            return None
        if isinstance(node, ast.Constant) and isinstance(node.value, int):
            return node.value
        if isinstance(node, ast.Name):
            for table in (self.local_consts, self.param_defaults,
                          self.module_consts):
                if node.id in table:
                    expr = table[node.id]
                    if expr is node:      # self-reference guard
                        return None
                    return self.resolve(expr, depth + 1)
            if node.id in self.assumed:
                return self.assumed[node.id]
            return None
        if isinstance(node, ast.BinOp):
            lhs = self.resolve(node.left, depth + 1)
            rhs = self.resolve(node.right, depth + 1)
            if lhs is None or rhs is None:
                return None
            try:
                if isinstance(node.op, ast.Add):
                    return lhs + rhs
                if isinstance(node.op, ast.Sub):
                    return lhs - rhs
                if isinstance(node.op, ast.Mult):
                    return lhs * rhs
                if isinstance(node.op, ast.FloorDiv):
                    return lhs // rhs
                if isinstance(node.op, ast.Mod):
                    return lhs % rhs
                if isinstance(node.op, ast.Pow):
                    return lhs ** rhs
            except (ZeroDivisionError, OverflowError, ValueError):
                return None
            return None
        if isinstance(node, ast.Call):
            name = call_name(node)
            if name in ("min", "max") and node.args:
                vals = [self.resolve(a, depth + 1) for a in node.args]
                vals = [v for v in vals if v is not None]
                if vals:
                    return min(vals) if name == "min" else max(vals)
        return None


def _constant_assignments(stmts, stmts_are_nodes: bool = False
                          ) -> Dict[str, ast.expr]:
    """``name -> value-expr`` for single-target assignments.

    A name assigned more than once keeps its *last* assignment — for
    the ``block_m = min(block_m, M)`` clamp idiom the clamp is the value
    the kernel actually sees.
    """
    table: Dict[str, ast.expr] = {}
    nodes = stmts if stmts_are_nodes else list(stmts)
    for node in nodes:
        if isinstance(node, ast.Assign) and len(node.targets) == 1:
            tgt = node.targets[0]
            if isinstance(tgt, ast.Name):
                table[tgt.id] = node.value
            elif isinstance(tgt, ast.Tuple) \
                    and isinstance(node.value, ast.Tuple) \
                    and len(tgt.elts) == len(node.value.elts):
                # `M, N = 256, 128` unpacks element-wise
                for t, v in zip(tgt.elts, node.value.elts):
                    if isinstance(t, ast.Name):
                        table[t.id] = v
        elif isinstance(node, ast.AnnAssign) and node.value is not None:
            if isinstance(node.target, ast.Name):
                table[node.target.id] = node.value
    return table


def _param_defaults(func: ast.FunctionDef) -> Dict[str, ast.expr]:
    table: Dict[str, ast.expr] = {}
    args = func.args
    pos = args.posonlyargs + args.args
    for arg, default in zip(pos[len(pos) - len(args.defaults):],
                            args.defaults):
        table[arg.arg] = default
    for arg, default in zip(args.kwonlyargs, args.kw_defaults):
        if default is not None:
            table[arg.arg] = default
    return table


def param_names(func: ast.FunctionDef) -> List[str]:
    args = func.args
    names = [a.arg for a in args.posonlyargs + args.args]
    if args.vararg:
        names.append(args.vararg.arg)
    names += [a.arg for a in args.kwonlyargs]
    if args.kwarg:
        names.append(args.kwarg.arg)
    return names


def positional_param_names(func: ast.FunctionDef) -> List[str]:
    args = func.args
    return [a.arg for a in args.posonlyargs + args.args]


def kwonly_param_names(func: ast.FunctionDef) -> List[str]:
    return [a.arg for a in func.args.kwonlyargs]


def body_is_abstract(func: ast.FunctionDef) -> bool:
    """True for bodies that only ``raise NotImplementedError`` / ``...``
    (optionally after a docstring) — the protocol-base convention."""
    body = list(func.body)
    if body and isinstance(body[0], ast.Expr) and isinstance(
            body[0].value, ast.Constant) and isinstance(
            body[0].value.value, str):
        body = body[1:]
    if len(body) != 1:
        return False
    stmt = body[0]
    if isinstance(stmt, ast.Raise):
        exc = stmt.exc
        if isinstance(exc, ast.Call):
            exc = exc.func
        return dotted_name(exc) == "NotImplementedError" if exc else False
    if isinstance(stmt, ast.Expr) and isinstance(stmt.value, ast.Constant):
        return stmt.value.value is Ellipsis
    if isinstance(stmt, ast.Pass):
        return True
    return False


def unwrap_partial(node: ast.expr) -> ast.expr:
    """``functools.partial(f, ...)`` -> ``f`` (recursively)."""
    while isinstance(node, ast.Call):
        name = call_name(node)
        if name and last_segment(name) == "partial" and node.args:
            node = node.args[0]
        else:
            break
    return node


def assign_targets(stmt: ast.stmt) -> List[str]:
    """Dotted names (re)bound by an assignment-like statement."""
    out: List[str] = []

    def collect(tgt: ast.expr):
        if isinstance(tgt, (ast.Tuple, ast.List)):
            for e in tgt.elts:
                collect(e)
        elif isinstance(tgt, ast.Starred):
            collect(tgt.value)
        else:
            name = dotted_name(tgt)
            if name:
                out.append(name)

    if isinstance(stmt, ast.Assign):
        for tgt in stmt.targets:
            collect(tgt)
    elif isinstance(stmt, (ast.AugAssign, ast.AnnAssign)):
        collect(stmt.target)
    elif isinstance(stmt, (ast.For, ast.AsyncFor)):
        collect(stmt.target)
    elif isinstance(stmt, (ast.With, ast.AsyncWith)):
        for item in stmt.items:
            if item.optional_vars is not None:
                collect(item.optional_vars)
    return out
