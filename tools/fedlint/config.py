"""fedlint configuration: scan set, per-rule options, per-path overrides.

The defaults encode this repo's policy (DESIGN.md §8):

* library code (``src/``) is fully strict — a PRNG key literal anywhere
  in ``src`` is an error, because every key must flow from the one
  ``FedConfig.seed`` -> ``round_keys`` schedule that the three-backend
  parity guarantee replays;
* ``tests/``, ``benchmarks/`` and ``examples/`` are the *entry points*
  that own seeds, so a literal ``PRNGKey(0)`` there is the sanctioned
  construction site — FL001's literal check is relaxed, while the
  key-*reuse* check (two consumes without split/fold_in) stays on
  everywhere.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Tuple

# the trees the CI gate lints (launch lives inside src/repro/launch)
DEFAULT_PATHS = ["src", "benchmarks", "tests", "tools"]

_ALL_RULES = ("FL001", "FL002", "FL003", "FL004", "FL005")


@dataclasses.dataclass
class LintConfig:
    enabled_rules: Tuple[str, ...] = _ALL_RULES
    # global per-rule option overrides: {"FL003": {"vmem_budget_bytes": ...}}
    rule_options: Dict[str, Dict[str, Any]] = dataclasses.field(
        default_factory=dict)
    # (glob over repo-relative path, {rule_id: {option: value}}) — later
    # entries override earlier ones; merged on top of rule defaults.
    path_overrides: List[Tuple[str, Dict[str, Dict[str, Any]]]] = \
        dataclasses.field(default_factory=list)


DEFAULT_CONFIG = LintConfig(
    path_overrides=[
        # tests/benchmarks/examples own their seeds: literal PRNGKey
        # construction is the entry-point idiom there, reuse is still
        # checked.
        # tests additionally pass one key to several helpers *on
        # purpose* (determinism assertions: same key in → same params
        # out), so helper-reuse tracking is off there; reuse across two
        # direct jax.random draws stays an error everywhere.
        ("tests/*", {"FL001": {"allow_literal_keys": True,
                               "check_helper_reuse": False}}),
        ("tests/**/*", {"FL001": {"allow_literal_keys": True,
                                  "check_helper_reuse": False}}),
        ("benchmarks/*", {"FL001": {"allow_literal_keys": True,
                                    "check_helper_reuse": False}}),
        ("benchmarks/**/*", {"FL001": {"allow_literal_keys": True,
                                       "check_helper_reuse": False}}),
        ("examples/*", {"FL001": {"allow_literal_keys": True}}),
        # fedlint's own fixtures hold deliberate violations; the live
        # gate must not trip over them (tests lint them explicitly).
        ("tests/fedlint_fixtures/*", {r: {"enabled": False}
                                      for r in _ALL_RULES}),
    ],
)

# fixture runs in tests/test_fedlint.py use the strict config: every
# rule fully enabled everywhere, no path relaxations.
STRICT_CONFIG = LintConfig()
