#!/usr/bin/env python
"""Perf-trajectory gate: roofline-fraction regressions fail CI.

``benchmarks/run.py`` emits ``BENCH_<suite>.json`` artifacts whose rows
carry ``roofline_frac`` — each kernel's effective bandwidth as a
fraction of the measured ``weighted_aggregate`` streaming roofline.
Fractions are a ratio of two bandwidths measured back-to-back on the
same machine, so they transfer across CI hosts far better than wall
times; this checker compares the freshly emitted fractions against the
committed baseline and fails (exit 1) when any row regresses by more
than ``--tolerance`` (default 15%).

Rows whose baseline fraction sits below ``--min-frac`` (default 0.02)
are carried in the artifact but not gated: a compute-bound kernel at ~1%
of the stream roofline measures the host's flops/bandwidth balance, not
the code, and would flake across heterogeneous CI runners.

The baseline is read from git (``git show <ref>:BENCH_*.json``) because
the bench run overwrites the committed files in the worktree; the
default ref is ``auto`` — ``origin/main`` when that remote-tracking ref
exists, else ``HEAD`` (on a PR merge commit ``HEAD`` already carries the
PR's own BENCH files, so it would compare the run against itself);
``--baseline-dir`` reads plain files instead. Rows new in the
fresh run pass (no trajectory yet); rows that *disappear* while the
baseline still tracks them fail — a silently dropped series is how a
perf trajectory dies. Run from anywhere:

    PYTHONPATH=src python -m benchmarks.run aggregation kernels
    python tools/check_bench.py

CI runs both as the perf-regression step next to ``check_docs.py``.
"""
from __future__ import annotations

import argparse
import json
import subprocess
import sys
from pathlib import Path
from typing import Dict, List, Optional

ROOT = Path(__file__).resolve().parent.parent
DEFAULT_TOLERANCE = 0.15
# rows whose baseline fraction sits below this are reported but not
# gated: a compute-bound kernel at ~1% of the stream roofline measures
# the host's flops/bandwidth balance more than the code, so its
# fraction does not transfer across machines the way bandwidth-bound
# fractions (robust_combine, weighted_aggregate, decode) do
DEFAULT_MIN_FRAC = 0.02


def rows_by_name(rows: List[dict]) -> Dict[str, dict]:
    return {r["name"]: r for r in rows if isinstance(r, dict) and "name" in r}


def compare_rows(baseline: List[dict], fresh: List[dict],
                 tolerance: float = DEFAULT_TOLERANCE,
                 suite: str = "?",
                 min_frac: float = DEFAULT_MIN_FRAC) -> List[str]:
    """Regression errors between two row lists (the unit-testable core).

    Only rows carrying ``roofline_frac >= min_frac`` in the *baseline*
    participate: a fresh fraction below ``baseline * (1 - tolerance)``
    regresses, a tracked row missing from the fresh run is a dropped
    series. Sub-``min_frac`` rows ride along in the artifact but sit in
    the machine-noise regime and are not gated.
    """
    fresh_by = rows_by_name(fresh)
    errors = []
    for name, base in rows_by_name(baseline).items():
        base_frac = base.get("roofline_frac")
        if base_frac is None or base_frac < min_frac:
            continue
        new = fresh_by.get(name)
        if new is None:
            errors.append(f"{suite}: tracked row {name!r} disappeared "
                          "from the fresh run")
            continue
        new_frac = new.get("roofline_frac")
        if new_frac is None:
            errors.append(f"{suite}: row {name!r} lost its roofline_frac")
            continue
        floor = base_frac * (1.0 - tolerance)
        if new_frac < floor:
            errors.append(
                f"{suite}: {name} roofline_frac {new_frac:.3f} < "
                f"{floor:.3f} (baseline {base_frac:.3f} - {tolerance:.0%})")
    return errors


def baseline_from_git(name: str, ref: str,
                      cwd: Optional[Path] = None) -> Optional[List[dict]]:
    """``git show ref:name`` parsed, or None when absent at the ref."""
    proc = subprocess.run(["git", "show", f"{ref}:{name}"],
                          cwd=cwd or ROOT, capture_output=True, text=True)
    if proc.returncode != 0:
        return None
    return json.loads(proc.stdout)


def resolve_baseline_ref(ref: str = "auto",
                         cwd: Optional[Path] = None) -> str:
    """Resolve ``auto`` to the branch-point baseline.

    On a PR merge commit, ``HEAD`` already *contains* the PR's own
    freshly committed BENCH files, so diffing against HEAD compares the
    run with itself and the gate can never fire. ``auto`` therefore
    prefers ``origin/main`` (the base the PR diverged from) and only
    falls back to ``HEAD`` when no such remote-tracking ref exists
    (fresh clone without remotes, detached tarball checkouts).
    """
    if ref != "auto":
        return ref
    proc = subprocess.run(
        ["git", "rev-parse", "--verify", "--quiet", "origin/main"],
        cwd=cwd or ROOT, capture_output=True, text=True)
    if proc.returncode == 0 and proc.stdout.strip():
        return "origin/main"
    return "HEAD"


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--fresh-dir", default=str(ROOT),
                    help="directory holding the freshly emitted "
                         "BENCH_*.json (default: repo root)")
    ap.add_argument("--baseline-ref", default="auto",
                    help="git ref holding the committed baseline "
                         "(default: auto = origin/main when it exists, "
                         "else HEAD — on a PR merge commit HEAD would "
                         "compare the run against its own baseline)")
    ap.add_argument("--baseline-dir", default=None,
                    help="read baselines from plain files here instead "
                         "of git")
    ap.add_argument("--tolerance", type=float, default=DEFAULT_TOLERANCE,
                    help="allowed fractional regression (default 0.15)")
    ap.add_argument("--min-frac", type=float, default=DEFAULT_MIN_FRAC,
                    help="baseline roofline_frac below which a row is "
                         "reported but not gated (machine-noise regime; "
                         "default 0.02)")
    args = ap.parse_args(argv)

    fresh_files = sorted(Path(args.fresh_dir).glob("BENCH_*.json"))
    if not fresh_files:
        print(f"check_bench: no BENCH_*.json under {args.fresh_dir} — "
              "run `PYTHONPATH=src python -m benchmarks.run` first")
        return 1
    ref = resolve_baseline_ref(args.baseline_ref)
    errors, compared = [], 0
    for f in fresh_files:
        if args.baseline_dir:
            base_path = Path(args.baseline_dir) / f.name
            baseline = (json.loads(base_path.read_text())
                        if base_path.exists() else None)
        else:
            baseline = baseline_from_git(f.name, ref)
        if baseline is None:
            print(f"check_bench: {f.name} has no committed baseline — "
                  "skipping (first emission of this suite)")
            continue
        fresh = json.loads(f.read_text())
        errors += compare_rows(baseline, fresh, args.tolerance,
                               suite=f.name, min_frac=args.min_frac)
        compared += 1
    if errors:
        print("perf-regression gate FAILED:")
        for e in errors:
            print("  " + e)
        return 1
    print(f"perf-regression gate passed ({compared} baseline file(s), "
          f"tolerance {args.tolerance:.0%})")
    return 0


if __name__ == "__main__":
    sys.exit(main())
