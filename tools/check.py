#!/usr/bin/env python
"""Unified CI gate runner: ``python -m tools.check``.

Runs the repo's three gates with one diagnostic surface
(``file:line [RULE] severity: message``) and one exit code:

* **docs** — cross-reference consistency (``tools/check_docs.py``);
* **fedlint** — the AST invariant checker, FL001–FL005 (DESIGN.md §8);
* **bench** — roofline-fraction regression vs the git baseline
  (``tools/check_bench.py``; skipped unless ``BENCH_*.json`` artifacts
  are present, since the bench run is a separate CI step).

``--json`` emits a machine-readable report (uploaded as a CI artifact
next to the BENCH files). ``--only docs,fedlint`` restricts the set.
Exit status is 1 when any selected gate fails.
"""
from __future__ import annotations

import argparse
import contextlib
import io
import json
import sys
from pathlib import Path
from typing import Any, Dict, List, Optional

ROOT = Path(__file__).resolve().parent.parent
if str(ROOT) not in sys.path:
    sys.path.insert(0, str(ROOT))

from tools import check_bench, check_docs                    # noqa: E402
from tools.fedlint.config import DEFAULT_CONFIG, DEFAULT_PATHS  # noqa: E402
from tools.fedlint.core import (BASELINE_PATH, ERROR,           # noqa: E402
                                baseline_fingerprints, lint_paths,
                                load_baseline)

GATES = ("docs", "fedlint", "bench")


def run_docs() -> Dict[str, Any]:
    buf = io.StringIO()
    with contextlib.redirect_stdout(buf):
        code = check_docs.main()
    diagnostics = []
    for line in buf.getvalue().splitlines():
        line = line.strip()
        if line and not line.startswith(("docs-consistency",)):
            diagnostics.append({"path": line.split(":", 1)[0],
                                "line": 0, "rule": "DOCS",
                                "severity": "error", "message": line})
    return {"gate": "docs", "ok": code == 0, "diagnostics": diagnostics}


def run_fedlint(paths: Optional[List[str]] = None) -> Dict[str, Any]:
    diags = lint_paths(paths or DEFAULT_PATHS, config=DEFAULT_CONFIG)
    known = baseline_fingerprints(load_baseline(BASELINE_PATH))
    diags = [d for d in diags if d.fingerprint() not in known]
    errors = [d for d in diags if d.severity == ERROR]
    return {"gate": "fedlint", "ok": not errors,
            "diagnostics": [d.to_json() for d in diags]}


def run_bench() -> Dict[str, Any]:
    if not sorted(ROOT.glob("BENCH_*.json")):
        return {"gate": "bench", "ok": True, "skipped": True,
                "diagnostics": [],
                "note": "no BENCH_*.json present — bench gate runs in "
                        "its own CI step after benchmarks.run"}
    buf = io.StringIO()
    with contextlib.redirect_stdout(buf):
        code = check_bench.main([])
    diagnostics = []
    for line in buf.getvalue().splitlines():
        line = line.strip()
        if line.startswith(("perf-regression", "check_bench:")):
            continue
        if line:
            diagnostics.append({"path": "BENCH", "line": 0,
                                "rule": "BENCH", "severity": "error",
                                "message": line})
    return {"gate": "bench", "ok": code == 0, "diagnostics": diagnostics}


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m tools.check", description=__doc__.splitlines()[0])
    ap.add_argument("--only", default=",".join(GATES),
                    help="comma-separated subset of gates "
                         f"(default: {','.join(GATES)})")
    ap.add_argument("--json", action="store_true",
                    help="emit a JSON report on stdout")
    ap.add_argument("--json-out", default=None,
                    help="also write the JSON report to this path")
    args = ap.parse_args(argv)

    selected = [g.strip() for g in args.only.split(",") if g.strip()]
    unknown = [g for g in selected if g not in GATES]
    if unknown:
        print(f"tools.check: unknown gate(s) {unknown}; "
              f"choose from {GATES}", file=sys.stderr)
        return 2

    results = []
    for gate in selected:
        results.append({"docs": run_docs, "fedlint": run_fedlint,
                        "bench": run_bench}[gate]())

    report = {"ok": all(r["ok"] for r in results), "gates": results}
    if args.json_out:
        Path(args.json_out).write_text(json.dumps(report, indent=1) + "\n")
    if args.json:
        print(json.dumps(report, indent=1))
    else:
        for r in results:
            status = ("skipped" if r.get("skipped")
                      else "ok" if r["ok"] else "FAILED")
            print(f"[{r['gate']}] {status}")
            for d in r["diagnostics"]:
                print(f"  {d['path']}:{d['line']} [{d['rule']}] "
                      f"{d['severity']}: {d['message']}")
        verdict = "passed" if report["ok"] else "FAILED"
        print(f"tools.check: {verdict} "
              f"({', '.join(r['gate'] for r in results)})")
    return 0 if report["ok"] else 1


if __name__ == "__main__":
    sys.exit(main())
