#!/usr/bin/env python
"""Docs-consistency checker: no dangling cross-references.

Scans ``src/``, ``tests/``, ``benchmarks/``, ``tools/``, ``README.md``
and the top-level docs for references of the form

    DESIGN.md §3            EXPERIMENTS.md §Perf
    §Dry-run and §Roofline of EXPERIMENTS.md     (reversed order)
    ROADMAP.md              (bare file reference)

and fails (exit 1) when a referenced ``.md`` file does not exist at the
repo root, or a referenced ``§`` section has no matching heading. A
section token resolves iff some heading line (``#``-prefixed) of the
target file contains ``§<token>`` — e.g. ``## §3 · The pod mapping``
resolves ``DESIGN.md §3``. Run from anywhere:

    python tools/check_docs.py

CI runs this as the docs-consistency step; ``tests/test_docs.py`` runs it
in tier-1.
"""
from __future__ import annotations

import re
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent
SCAN_GLOBS = ["src/**/*.py", "tests/**/*.py", "benchmarks/**/*.py",
              "tools/**/*.py"]
SCAN_FILES = ["README.md", "DESIGN.md", "EXPERIMENTS.md", "ROADMAP.md"]

# forward: "DESIGN.md §3" / "EXPERIMENTS.md §Perf iteration A3" -> (file, tok)
FORWARD = re.compile(r"\b([A-Z][A-Z_]+\.md)(?:\s*§\s*([A-Za-z0-9][\w-]*))?")
# backward: "§Dry-run and §Roofline of EXPERIMENTS.md" (may span lines)
BACKWARD = re.compile(
    r"((?:§[\w-]+(?:\s+and\s+)?\s*)+)of\s+([A-Z][A-Z_]+\.md)")
SECTION_TOKEN = re.compile(r"§\s*([A-Za-z0-9][\w-]*)")


def headings(md_path: Path):
    """Set of §-tokens declared by the file's headings."""
    toks = set()
    for line in md_path.read_text().splitlines():
        if line.lstrip().startswith("#"):
            toks.update(SECTION_TOKEN.findall(line))
    return toks


def references(text: str):
    """Yield (md_name, token_or_None) for every cross-reference."""
    for m in BACKWARD.finditer(text):
        for tok in SECTION_TOKEN.findall(m.group(1)):
            yield m.group(2), tok
    for m in FORWARD.finditer(text):
        yield m.group(1), m.group(2)


def main() -> int:
    files = [ROOT / f for f in SCAN_FILES if (ROOT / f).exists()]
    for g in SCAN_GLOBS:
        files.extend(sorted(ROOT.glob(g)))
    section_cache = {}
    errors = []
    for f in files:
        text = f.read_text()
        for md_name, tok in references(text):
            target = ROOT / md_name
            rel = f.relative_to(ROOT)
            if not target.exists():
                errors.append(f"{rel}: reference to missing file {md_name}")
                continue
            if tok is None:
                continue
            if md_name not in section_cache:
                section_cache[md_name] = headings(target)
            if tok not in section_cache[md_name]:
                errors.append(
                    f"{rel}: {md_name} §{tok} — no heading in {md_name} "
                    f"contains §{tok}")
    if errors:
        print("docs-consistency check FAILED:")
        for e in sorted(set(errors)):
            print("  " + e)
        return 1
    print(f"docs-consistency check passed "
          f"({len(files)} files scanned)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
