"""Shared fixtures. NOTE: no XLA device-count flags here — smoke tests and
benches must see exactly 1 CPU device (dry-runs set their own flags in a
subprocess)."""
import jax
import jax.numpy as jnp
import pytest


@pytest.fixture(scope="session")
def rng():
    return jax.random.PRNGKey(0)


def make_lm_batch(cfg, B=2, S=16, key=0):
    ks = jax.random.split(jax.random.PRNGKey(key), 4)
    from repro.models.frontend_stub import stub_embeddings
    if cfg.family in ("cnn", "mlp"):
        return {
            "images": jax.random.normal(
                ks[0], (B, cfg.image_size, cfg.image_size,
                        cfg.image_channels)),
            "labels": jax.random.randint(ks[1], (B,), 0, cfg.num_classes)}
    b = {"tokens": jax.random.randint(ks[0], (B, S), 0, cfg.vocab_size),
         "labels": jax.random.randint(ks[1], (B, S), 0, cfg.vocab_size)}
    if cfg.family == "vlm":
        b["patches"] = stub_embeddings(cfg, B, ks[2], dtype=jnp.float32)
    if cfg.family == "encdec":
        b["frames"] = stub_embeddings(cfg, B, ks[2], dtype=jnp.float32)
    return b
