"""shard_map FedTest round on 8 host-platform devices (subprocess, so the
device-count flag never leaks into other tests). Both pod exchange
backends drive the unified ``repro.core.engine.RoundProgram``."""
import json
import os
import subprocess
import sys

import pytest

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import Mesh

from repro.config import FedConfig, TrainConfig
from repro.configs import get_config
from repro.core.engine import (
    make_allgather_round, make_distributed_round, round_keys)
from repro.core.scoring import init_scores
from repro.data import MNIST_LIKE, make_federated_image_dataset, \
    sample_client_batches
from repro.models import build_model

N = 8
mesh = Mesh(np.asarray(jax.devices()[:N]), ("clients",))
cfg = get_config("fedtest-cnn-mnist").replace(cnn_channels=(4, 8, 8),
                                              cnn_hidden=16)
model = build_model(cfg)
fed = FedConfig(num_users=N, num_testers=N, num_malicious=0, attack="none",
                local_steps=6)
tc = TrainConfig(optimizer="sgd", lr=0.1, schedule="constant",
                 batch_size=8, grad_clip=0.0, remat=False)
data = make_federated_image_dataset(MNIST_LIKE, N, num_samples=1600,
                                    global_test=200, seed=0)

round_fn = jax.jit(make_distributed_round(model, fed, tc, mesh))
ag_round_fn = jax.jit(make_allgather_round(model, fed, tc, mesh))

params = model.init(jax.random.PRNGKey(0))
scores = init_scores(N)
run_key = jax.random.PRNGKey(1)
key0 = jax.random.fold_in(run_key, 0)
bx, by = sample_client_batches(round_keys(key0).batch, data.train,
                               fed.local_steps, tc.batch_size)
tx = data.test.xs[:, :64]
ty = data.test.ys[:, :64]
r0 = jnp.asarray(0, jnp.int32)

new_global, new_scores, metrics = round_fn(
    params, scores, bx, by, tx, ty, key0, r0)
ag_global, ag_scores, ag_metrics = ag_round_fn(
    params, scores, bx, by, tx, ty, key0, r0)

# ring and all-gather paths must agree exactly (same math, diff schedule)
ring_w = np.asarray(metrics["weights"])
ag_w = np.asarray(ag_metrics["weights"])
max_w_err = float(np.abs(ring_w - ag_w).max())

leaf_err = max(
    float(np.abs(np.asarray(a, np.float32) - np.asarray(b, np.float32)).max())
    for a, b in zip(jax.tree_util.tree_leaves(new_global),
                    jax.tree_util.tree_leaves(ag_global)))

# and the global model must actually train across rounds (a handful of
# rounds: with 8 tiny clients the first rounds are noise-dominated)
g = new_global
s = new_scores
for r in range(1, 6):
    key = jax.random.fold_in(run_key, r)
    bx, by = sample_client_batches(round_keys(key).batch, data.train,
                                   fed.local_steps, tc.batch_size)
    g, s, metrics = round_fn(g, s, bx, by, tx, ty, key,
                             jnp.asarray(r, jnp.int32))

logits, _ = model.forward_train(g, {"images": data.global_x[:256]})
acc = float((jnp.argmax(logits, -1) == data.global_y[:256]).mean())

# --- adversarial pod round: a sign_flip attacker must be suppressed ----
# milder skew so the accuracy matrix separates honest from malicious
# (the ROADMAP-diagnosed remedy from the single-host dynamics tests)
adv_data = make_federated_image_dataset(
    MNIST_LIKE, N, num_samples=1600, global_test=200, seed=0,
    partition_kwargs={"min_classes": 8, "max_classes": 10})
adv_fed = FedConfig(num_users=N, num_testers=N, num_malicious=1,
                    attack="sign_flip", attack_scale=4.0, local_steps=6)
adv_round = jax.jit(make_distributed_round(model, adv_fed, tc, mesh,
                                           counts=adv_data.train.counts))
g = model.init(jax.random.PRNGKey(0))
s = init_scores(N)
atx = adv_data.test.xs[:, :64]
aty = adv_data.test.ys[:, :64]
adv_key = jax.random.PRNGKey(100)
mal_w = []
for r in range(8):
    key = jax.random.fold_in(adv_key, r)
    bx, by = sample_client_batches(round_keys(key).batch, adv_data.train,
                                   adv_fed.local_steps, tc.batch_size)
    g, s, m = adv_round(g, s, bx, by, atx, aty, key,
                        jnp.asarray(r, jnp.int32))
    mal_w.append(float(m["malicious_weight"]))

print(json.dumps({"max_w_err": max_w_err, "leaf_err": leaf_err,
                  "weights_sum": float(ring_w.sum()), "acc": acc,
                  "mal_w": mal_w}))
"""


def test_pod_builders_resolve_strategies_from_fed():
    """Both pod builders resolve the full strategy triple through the
    same ``resolve_strategies`` as the local backend."""
    from repro.config import FedConfig
    from repro.core.engine import resolve_strategies
    agg, atk, sel = resolve_strategies(FedConfig(participation=0.5))
    assert agg.name == "fedtest"
    agg, atk, sel = resolve_strategies(
        FedConfig(attack="sign_flip", num_malicious=2, num_users=8))
    assert atk.name == "sign_flip"
    assert atk.malicious_indices(8) == (6, 7)
    # an Aggregator instance passes through unchanged
    override, _, _ = resolve_strategies(FedConfig(), aggregator=agg)
    assert override is agg


def test_pod_builder_requires_server_data_for_server_eval():
    """Server-eval aggregators run on the pod only when the builder gets
    the replicated server set to close over."""
    import pytest as _pytest
    from repro.config import FedConfig, TrainConfig
    from repro.configs import get_config
    from repro.core.engine import make_pod_round
    from repro.models import build_model

    class FakeMesh:
        shape = {"clients": 4}

    cfg = get_config("fedtest-cnn-mnist").replace(cnn_channels=(4, 8, 8),
                                                  cnn_hidden=16)
    model = build_model(cfg)
    fed = FedConfig(num_users=4, num_testers=4, aggregator="accuracy_based")
    with _pytest.raises(ValueError, match="server"):
        make_pod_round(model, fed, TrainConfig(), FakeMesh())


def test_pod_builder_rejects_mismatched_client_count():
    """The pod pins one client per device; a FedConfig sized for a
    different federation must fail loudly at build time."""
    import pytest as _pytest
    from repro.config import FedConfig, TrainConfig
    from repro.configs import get_config
    from repro.core.engine import make_pod_round
    from repro.models import build_model

    class FakeMesh:
        shape = {"clients": 4}

    cfg = get_config("fedtest-cnn-mnist").replace(cnn_channels=(4, 8, 8),
                                                  cnn_hidden=16)
    model = build_model(cfg)
    fed = FedConfig(num_users=8, num_testers=4)
    with _pytest.raises(ValueError, match="num_users"):
        make_pod_round(model, fed, TrainConfig(), FakeMesh())


def test_apply_local_matches_stacked_apply():
    """Per-shard attack application corrupts each client bit-identically
    to the stacked apply (both fold the per-client key from the same
    base key) and is the identity elsewhere."""
    import jax
    import jax.numpy as jnp
    import numpy as np
    from repro.strategies import ATTACKS

    n = 5
    atk = ATTACKS.build("random_weights", {"placement": "first"},
                        {"num_malicious": 2, "scale": 1.5})
    g = {"w": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
         "b": jnp.ones((3,), jnp.float32)}
    key = jax.random.PRNGKey(0)
    stacked = jax.tree_util.tree_map(
        lambda x: (jnp.broadcast_to(x[None], (n,) + x.shape)
                   + 0.1 * jax.random.normal(key, (n,) + x.shape)), g)
    applied = atk.apply(key, stacked, g)
    for c in range(n):
        trained = jax.tree_util.tree_map(lambda a, _c=c: a[_c], stacked)
        local = atk.apply_local(key, trained, g, jnp.asarray(c), n)
        expect = jax.tree_util.tree_map(lambda a, _c=c: a[_c], applied)
        for a, b in zip(jax.tree_util.tree_leaves(local),
                        jax.tree_util.tree_leaves(expect)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    none = ATTACKS.build("none", {}, {"num_malicious": 3})
    trained = jax.tree_util.tree_map(lambda a: a[0], stacked)
    local = none.apply_local(key, trained, g, jnp.asarray(0), n)
    assert all((np.asarray(a) == np.asarray(b)).all() for a, b in
               zip(jax.tree_util.tree_leaves(local),
                   jax.tree_util.tree_leaves(trained)))


@pytest.mark.slow
def test_distributed_round_matches_allgather_trains_and_suppresses(tmp_path):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    proc = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                          capture_output=True, text=True, timeout=900)
    assert proc.returncode == 0, proc.stderr[-3000:]
    out = json.loads(proc.stdout.strip().splitlines()[-1])
    assert out["max_w_err"] < 1e-5
    assert out["leaf_err"] < 1e-4
    assert abs(out["weights_sum"] - 1.0) < 1e-4
    assert out["acc"] > 0.25
    # the fedtest aggregator must squeeze the sign_flip attacker's weight
    # below the paper's 5% bar once the score power kicks in
    assert out["mal_w"][-1] < 0.05, out["mal_w"]
    assert out["mal_w"][-1] < out["mal_w"][1]
