"""shard_map FedTest round on 8 host-platform devices (subprocess, so the
device-count flag never leaks into other tests)."""
import json
import os
import subprocess
import sys

import pytest

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import Mesh

from repro.config import FedConfig, TrainConfig
from repro.configs import get_config
from repro.core.distributed import (
    make_allgather_round, make_distributed_round, ring_cross_test)
from repro.core.cross_testing import cross_test_accuracies
from repro.core.scoring import init_scores
from repro.data import MNIST_LIKE, make_federated_image_dataset, \
    sample_client_batches
from repro.models import build_model

N = 8
mesh = Mesh(np.asarray(jax.devices()[:N]), ("clients",))
cfg = get_config("fedtest-cnn-mnist").replace(cnn_channels=(4, 8, 8),
                                              cnn_hidden=16)
model = build_model(cfg)
fed = FedConfig(num_users=N, num_testers=N, num_malicious=0, local_steps=6)
tc = TrainConfig(optimizer="sgd", lr=0.1, schedule="constant",
                 batch_size=8, grad_clip=0.0, remat=False)
data = make_federated_image_dataset(MNIST_LIKE, N, num_samples=1600,
                                    global_test=200, seed=0)

round_fn = make_distributed_round(model, fed, tc, mesh)
ag_round_fn = make_allgather_round(model, fed, tc, mesh)

params = model.init(jax.random.PRNGKey(0))
scores = init_scores(N)
bx, by = sample_client_batches(jax.random.PRNGKey(1), data.train,
                               fed.local_steps, tc.batch_size)
tx = data.test.xs[:, :64]
ty = data.test.ys[:, :64]
mask = jnp.ones((N,), jnp.float32)

new_global, new_scores, metrics = jax.jit(round_fn)(
    params, scores, bx, by, tx, ty, mask)
ag_global, ag_scores, ag_metrics = jax.jit(ag_round_fn)(
    params, scores, bx, by, tx, ty, mask)

# ring and all-gather paths must agree exactly (same math, diff schedule)
ring_w = np.asarray(metrics["weights"])
ag_w = np.asarray(ag_metrics["weights"])
max_w_err = float(np.abs(ring_w - ag_w).max())

leaf_err = max(
    float(np.abs(np.asarray(a, np.float32) - np.asarray(b, np.float32)).max())
    for a, b in zip(jax.tree_util.tree_leaves(new_global),
                    jax.tree_util.tree_leaves(ag_global)))

# and the global model must actually train across rounds (a handful of
# rounds: with 8 tiny clients the first rounds are noise-dominated)
g = new_global
s = new_scores
for r in range(2, 7):
    bx, by = sample_client_batches(jax.random.PRNGKey(r), data.train,
                                   fed.local_steps, tc.batch_size)
    g, s, metrics = jax.jit(round_fn)(g, s, bx, by, tx, ty, mask)

logits, _ = model.forward_train(g, {"images": data.global_x[:256]})
acc = float((jnp.argmax(logits, -1) == data.global_y[:256]).mean())

print(json.dumps({"max_w_err": max_w_err, "leaf_err": leaf_err,
                  "weights_sum": float(ring_w.sum()), "acc": acc}))
"""


def test_pod_path_rejects_participation_sampling():
    """Client sampling is single-host-only; the pod path must refuse the
    config loudly instead of silently training everyone."""
    from repro.config import FedConfig
    from repro.core.distributed import _resolve_aggregator
    with pytest.raises(ValueError, match="participation"):
        _resolve_aggregator(FedConfig(participation=0.5), None)


@pytest.mark.slow
def test_distributed_round_matches_allgather_and_trains(tmp_path):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    proc = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                          capture_output=True, text=True, timeout=900)
    assert proc.returncode == 0, proc.stderr[-3000:]
    out = json.loads(proc.stdout.strip().splitlines()[-1])
    assert out["max_w_err"] < 1e-5
    assert out["leaf_err"] < 1e-4
    assert abs(out["weights_sum"] - 1.0) < 1e-4
    assert out["acc"] > 0.25
