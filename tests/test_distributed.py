"""shard_map FedTest round on 8 host-platform devices (subprocess, so the
device-count flag never leaks into other tests)."""
import json
import os
import subprocess
import sys

import pytest

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import Mesh

from repro.config import FedConfig, TrainConfig
from repro.configs import get_config
from repro.core.distributed import (
    make_allgather_round, make_distributed_round, ring_cross_test)
from repro.core.cross_testing import cross_test_accuracies
from repro.core.scoring import init_scores
from repro.data import MNIST_LIKE, make_federated_image_dataset, \
    sample_client_batches
from repro.models import build_model

N = 8
mesh = Mesh(np.asarray(jax.devices()[:N]), ("clients",))
cfg = get_config("fedtest-cnn-mnist").replace(cnn_channels=(4, 8, 8),
                                              cnn_hidden=16)
model = build_model(cfg)
fed = FedConfig(num_users=N, num_testers=N, num_malicious=0, attack="none",
                local_steps=6)
tc = TrainConfig(optimizer="sgd", lr=0.1, schedule="constant",
                 batch_size=8, grad_clip=0.0, remat=False)
data = make_federated_image_dataset(MNIST_LIKE, N, num_samples=1600,
                                    global_test=200, seed=0)

round_fn = make_distributed_round(model, fed, tc, mesh)
ag_round_fn = make_allgather_round(model, fed, tc, mesh)

params = model.init(jax.random.PRNGKey(0))
scores = init_scores(N)
bx, by = sample_client_batches(jax.random.PRNGKey(1), data.train,
                               fed.local_steps, tc.batch_size)
tx = data.test.xs[:, :64]
ty = data.test.ys[:, :64]
mask = jnp.ones((N,), jnp.float32)
pmask = jnp.ones((N,), jnp.float32)

new_global, new_scores, metrics = jax.jit(round_fn)(
    params, scores, bx, by, tx, ty, mask, pmask)
ag_global, ag_scores, ag_metrics = jax.jit(ag_round_fn)(
    params, scores, bx, by, tx, ty, mask, pmask)

# ring and all-gather paths must agree exactly (same math, diff schedule)
ring_w = np.asarray(metrics["weights"])
ag_w = np.asarray(ag_metrics["weights"])
max_w_err = float(np.abs(ring_w - ag_w).max())

leaf_err = max(
    float(np.abs(np.asarray(a, np.float32) - np.asarray(b, np.float32)).max())
    for a, b in zip(jax.tree_util.tree_leaves(new_global),
                    jax.tree_util.tree_leaves(ag_global)))

# and the global model must actually train across rounds (a handful of
# rounds: with 8 tiny clients the first rounds are noise-dominated)
g = new_global
s = new_scores
for r in range(2, 7):
    bx, by = sample_client_batches(jax.random.PRNGKey(r), data.train,
                                   fed.local_steps, tc.batch_size)
    g, s, metrics = jax.jit(round_fn)(g, s, bx, by, tx, ty, mask, pmask)

logits, _ = model.forward_train(g, {"images": data.global_x[:256]})
acc = float((jnp.argmax(logits, -1) == data.global_y[:256]).mean())

# --- adversarial pod round: a sign_flip attacker must be suppressed ----
# milder skew so the accuracy matrix separates honest from malicious
# (the ROADMAP-diagnosed remedy from the single-host dynamics tests)
adv_data = make_federated_image_dataset(
    MNIST_LIKE, N, num_samples=1600, global_test=200, seed=0,
    partition_kwargs={"min_classes": 8, "max_classes": 10})
adv_fed = FedConfig(num_users=N, num_testers=N, num_malicious=1,
                    attack="sign_flip", attack_scale=4.0, local_steps=6)
adv_round = jax.jit(make_distributed_round(model, adv_fed, tc, mesh,
                                           counts=adv_data.train.counts))
g = model.init(jax.random.PRNGKey(0))
s = init_scores(N)
atx = adv_data.test.xs[:, :64]
aty = adv_data.test.ys[:, :64]
mal_w = []
for r in range(8):
    bx, by = sample_client_batches(jax.random.PRNGKey(100 + r),
                                   adv_data.train, adv_fed.local_steps,
                                   tc.batch_size)
    g, s, m = adv_round(g, s, bx, by, atx, aty, mask, pmask)
    mal_w.append(float(m["malicious_weight"]))

print(json.dumps({"max_w_err": max_w_err, "leaf_err": leaf_err,
                  "weights_sum": float(ring_w.sum()), "acc": acc,
                  "mal_w": mal_w}))
"""


def test_pod_path_accepts_participation_and_resolves_attacks():
    """PR 3 removed the single-host-only guards: client sampling and any
    registered attack now resolve on the pod path too."""
    from repro.config import FedConfig
    from repro.core.distributed import _resolve_aggregator, _resolve_attack
    agg = _resolve_aggregator(FedConfig(participation=0.5), None)
    assert agg.name == "fedtest"
    atk = _resolve_attack(FedConfig(attack="sign_flip", num_malicious=2,
                                    num_users=8))
    assert atk.name == "sign_flip"
    assert atk.malicious_indices(8) == (6, 7)


def test_pod_builder_requires_server_data_for_server_eval():
    """Server-eval aggregators run on the pod only when the builder gets
    the replicated server set to close over."""
    import numpy as np
    import pytest as _pytest
    from repro.config import FedConfig, TrainConfig
    from repro.configs import get_config
    from repro.core.distributed import _make_pod_round
    from repro.models import build_model

    class FakeMesh:
        shape = {"clients": 4}

    cfg = get_config("fedtest-cnn-mnist").replace(cnn_channels=(4, 8, 8),
                                                  cnn_hidden=16)
    model = build_model(cfg)
    fed = FedConfig(num_users=4, num_testers=4, aggregator="accuracy_based")
    with _pytest.raises(ValueError, match="server"):
        _make_pod_round(model, fed, TrainConfig(), FakeMesh(), "clients",
                        None, None, None, "ring")


def test_apply_local_matches_stacked_apply():
    """Per-shard attack application selects exactly the stacked apply's
    corruption for malicious slots and is the identity elsewhere."""
    import jax
    import jax.numpy as jnp
    import numpy as np
    from repro.strategies import ATTACKS

    atk = ATTACKS.build("sign_flip", {"placement": "first"},
                        {"num_malicious": 2, "scale": 1.5})
    g = {"w": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
         "b": jnp.ones((3,), jnp.float32)}
    key = jax.random.PRNGKey(0)
    trained = jax.tree_util.tree_map(
        lambda x: x + 0.1 * jax.random.normal(key, x.shape), g)
    n = 5
    for c in range(n):
        local = atk.apply_local(key, trained, g, jnp.asarray(c), n)
        expect = atk.corrupt(key, trained, g) if c in (0, 1) else trained
        for a, b in zip(jax.tree_util.tree_leaves(local),
                        jax.tree_util.tree_leaves(expect)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       atol=1e-6)
    none = ATTACKS.build("none", {}, {"num_malicious": 3})
    local = none.apply_local(key, trained, g, jnp.asarray(0), n)
    assert all((np.asarray(a) == np.asarray(b)).all() for a, b in
               zip(jax.tree_util.tree_leaves(local),
                   jax.tree_util.tree_leaves(trained)))


@pytest.mark.slow
def test_distributed_round_matches_allgather_trains_and_suppresses(tmp_path):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    proc = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                          capture_output=True, text=True, timeout=900)
    assert proc.returncode == 0, proc.stderr[-3000:]
    out = json.loads(proc.stdout.strip().splitlines()[-1])
    assert out["max_w_err"] < 1e-5
    assert out["leaf_err"] < 1e-4
    assert abs(out["weights_sum"] - 1.0) < 1e-4
    assert out["acc"] > 0.25
    # the fedtest aggregator must squeeze the sign_flip attacker's weight
    # below the paper's 5% bar once the score power kicks in
    assert out["mal_w"][-1] < 0.05, out["mal_w"]
    assert out["mal_w"][-1] < out["mal_w"][1]
