"""tools/fedlint — the AST invariant gate (DESIGN.md §8).

Three layers:

* a fixture matrix: for every rule FL001–FL005, the ``*_bad.py`` fixture
  must fire (with the expected findings) and the ``*_good.py`` fixture
  must stay silent, each linted with *only* that rule enabled;
* unit tests for the shared machinery (suppressions, baseline,
  path-scoped config, the CLI);
* the tier-1 gate itself: the live repo lints clean against the
  committed (empty) baseline.
"""
import json
import subprocess
import sys
from pathlib import Path

import pytest

ROOT = Path(__file__).resolve().parent.parent
if str(ROOT) not in sys.path:
    sys.path.insert(0, str(ROOT))

from tools.fedlint.config import (DEFAULT_CONFIG, DEFAULT_PATHS,  # noqa: E402
                                  LintConfig)
from tools.fedlint.core import (BASELINE_PATH, ERROR, WARNING,    # noqa: E402
                                baseline_fingerprints, lint_files,
                                lint_paths, load_baseline,
                                parse_suppressions, is_suppressed,
                                Diagnostic)

FIXTURES = ROOT / "tests" / "fedlint_fixtures"
ALL_RULES = ("FL001", "FL002", "FL003", "FL004", "FL005")


def lint_fixture(name: str, rule: str):
    cfg = LintConfig(enabled_rules=(rule,))
    return lint_files([FIXTURES / name], config=cfg, root=ROOT)


# ------------------------------------------------------------ fixture matrix
@pytest.mark.parametrize("rule,min_findings", [
    ("FL001", 4), ("FL002", 6), ("FL003", 5), ("FL004", 7), ("FL005", 4),
])
def test_bad_fixture_fires(rule, min_findings):
    diags = lint_fixture(f"{rule.lower()}_bad.py", rule)
    assert len(diags) >= min_findings, [d.format() for d in diags]
    assert all(d.rule == rule for d in diags)


@pytest.mark.parametrize("rule", list(ALL_RULES))
def test_good_fixture_is_silent(rule):
    diags = lint_fixture(f"{rule.lower()}_good.py", rule)
    assert diags == [], [d.format() for d in diags]


def test_fl001_catches_the_coverage_selector_bug():
    """The PR 5 bug class: a selector deriving its stream from
    PRNGKey(0) instead of the run seed must be flagged at the literal."""
    diags = lint_fixture("fl001_bad.py", "FL001")
    literal = [d for d in diags if "PRNGKey(0)" in d.message]
    assert literal, [d.format() for d in diags]
    source = (FIXTURES / "fl001_bad.py").read_text().splitlines()
    assert "jax.random.PRNGKey(0)" in source[literal[0].line - 1]
    # ... and the seed-derived twin of the same selector is clean
    good = lint_fixture("fl001_good.py", "FL001")
    assert not good


def test_fl001_pins_fault_mask_key_derivation():
    """DESIGN.md §9: an availability fault must derive its survival
    mask from the handed-in ``keys.fault`` stream (the round schedule),
    never from a fresh PRNGKey literal or a reused key — either breaks
    backend parity and bit-identical resume."""
    diags = lint_fixture("fl001_fault_bad.py", "FL001")
    msgs = "\n".join(d.message for d in diags)
    assert "PRNGKey(7)" in msgs, [d.format() for d in diags]
    assert len(diags) >= 2            # the literal AND the key reuse
    # the schedule-keyed twins of both faults are clean
    assert lint_fixture("fl001_fault_good.py", "FL001") == []


def test_fl001_pins_eval_cache_key_derivation():
    """DESIGN.md §10: a cross-round eval-batch cache must re-derive its
    gather indices from the handed-in run key via ``fold_in`` on every
    miss — a cache refilling from a PRNGKey literal (or double-drawing
    one key) makes the trajectory depend on the hit/miss pattern."""
    diags = lint_fixture("fl001_evalcache_bad.py", "FL001")
    msgs = "\n".join(d.message for d in diags)
    assert "PRNGKey(11)" in msgs, [d.format() for d in diags]
    assert len(diags) >= 2            # the literal AND the key reuse
    # the bucket-keyed fold_in cache (the shipped EvalBatchCache shape)
    # is clean
    assert lint_fixture("fl001_evalcache_good.py", "FL001") == []


def test_fl004_severity_split():
    """One-sided apply/apply_local override is a warning (does not
    gate); missing protocol surface is an error."""
    diags = lint_fixture("fl004_bad.py", "FL004")
    warnings = [d for d in diags if d.severity == WARNING]
    errors = [d for d in diags if d.severity == ERROR]
    assert any("one_sided" in d.message for d in warnings)
    assert len(errors) >= 6


def test_fl005_flags_the_unsafe_idioms_only():
    bad = lint_fixture("fl005_bad.py", "FL005")
    msgs = "\n".join(d.message for d in bad)
    assert "'state'" in msgs and "'params'" in msgs
    # the safe rebind / sibling-branch / .lower() idioms stay silent
    assert lint_fixture("fl005_good.py", "FL005") == []


# --------------------------------------------------------------- suppressions
def test_inline_and_file_suppressions(tmp_path):
    f = tmp_path / "mod.py"
    f.write_text(
        "import jax\n"
        "def a(shape):\n"
        "    k = jax.random.PRNGKey(0)  # fedlint: disable=FL001\n"
        "    return jax.random.normal(k, shape)\n"
        "def b(shape):\n"
        "    k = jax.random.PRNGKey(1)\n"
        "    return jax.random.normal(k, shape)\n")
    cfg = LintConfig(enabled_rules=("FL001",))
    diags = lint_files([f], config=cfg, root=tmp_path)
    assert len(diags) == 1 and diags[0].line == 6   # only b() fires

    f.write_text("# fedlint: disable-file=FL001\n" + f.read_text())
    assert lint_files([f], config=cfg, root=tmp_path) == []


def test_disable_all_token():
    per_line, per_file = parse_suppressions(
        "x = 1  # fedlint: disable=all\n")
    d = Diagnostic(path="p", line=1, rule="FL003", severity="error",
                   message="m")
    assert is_suppressed(d, per_line, per_file)


# ------------------------------------------------------------------- baseline
def test_baseline_grandfathers_by_fingerprint(tmp_path):
    f = tmp_path / "mod.py"
    f.write_text("import jax\n"
                 "def a(shape):\n"
                 "    return jax.random.normal(jax.random.PRNGKey(7), "
                 "shape)\n")
    cfg = LintConfig(enabled_rules=("FL001",))
    diags = lint_files([f], config=cfg, root=tmp_path)
    assert len(diags) == 1
    known = baseline_fingerprints([d.to_json() for d in diags])
    assert all(d.fingerprint() in known for d in diags)
    # fingerprints survive unrelated line churn (path/rule/message only)
    f.write_text("# a new leading comment\n" + f.read_text())
    moved = lint_files([f], config=cfg, root=tmp_path)
    assert moved[0].line != diags[0].line
    assert moved[0].fingerprint() in known


def test_committed_baseline_is_empty():
    assert load_baseline(BASELINE_PATH) == []


# ------------------------------------------------------- path-scoped config
def test_literal_keys_relaxed_for_tests_strict_for_src(tmp_path):
    code = ("import jax\n"
            "def f(shape):\n"
            "    return jax.random.normal(jax.random.PRNGKey(0), shape)\n")
    for rel in ("src/mod.py", "tests/test_mod.py"):
        p = tmp_path / rel
        p.parent.mkdir(parents=True, exist_ok=True)
        p.write_text(code)
    diags = lint_files([tmp_path / "src" / "mod.py",
                        tmp_path / "tests" / "test_mod.py"],
                       config=DEFAULT_CONFIG, root=tmp_path)
    assert [d.path for d in diags] == ["src/mod.py"]


# ------------------------------------------------------------------ the gate
def test_live_repo_lints_clean_vs_committed_baseline():
    """Tier-1: the whole repo is clean under the default config and the
    committed baseline (which is empty — the gate is strict)."""
    diags = lint_paths(DEFAULT_PATHS, config=DEFAULT_CONFIG, root=ROOT)
    known = baseline_fingerprints(load_baseline(BASELINE_PATH))
    fresh = [d for d in diags if d.fingerprint() not in known]
    errors = [d for d in fresh if d.severity == ERROR]
    assert errors == [], "\n".join(d.format() for d in errors)


def test_cli_json_mode():
    proc = subprocess.run(
        [sys.executable, "-m", "tools.fedlint", "--json",
         "tests/fedlint_fixtures"],
        cwd=ROOT, capture_output=True, text=True)
    # fixtures are disabled under the default config -> clean exit
    assert proc.returncode == 0, proc.stdout + proc.stderr
    assert json.loads(proc.stdout) == []


def test_unified_runner_smoke():
    proc = subprocess.run(
        [sys.executable, "-m", "tools.check", "--only", "fedlint",
         "--json"],
        cwd=ROOT, capture_output=True, text=True)
    assert proc.returncode == 0, proc.stdout + proc.stderr
    report = json.loads(proc.stdout)
    assert report["ok"] is True
    assert report["gates"][0]["gate"] == "fedlint"
