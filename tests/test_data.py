"""Data pipeline: partition properties, batch sampling, synthetic sets."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.data import (
    CIFAR_LIKE, MNIST_LIKE, ClientData, dirichlet_partition,
    make_federated_image_dataset, make_image_dataset, make_token_stream,
    paper_noniid_partition, sample_client_batches)
from repro.data.partition import build_client_arrays


@settings(max_examples=15, deadline=None)
@given(num_users=st.integers(2, 10), seed=st.integers(0, 1000))
def test_paper_partition_disjoint(num_users, seed):
    labels = np.random.default_rng(seed).integers(0, 10, size=600)
    parts = paper_noniid_partition(labels, num_users, seed=seed)
    seen = np.concatenate(parts) if parts else np.array([])
    assert len(seen) == len(set(seen.tolist()))          # disjoint
    assert all((p >= 0).all() and (p < 600).all() for p in parts)


def test_paper_partition_is_noniid():
    labels = np.random.default_rng(0).integers(0, 10, size=5000)
    parts = paper_noniid_partition(labels, 10, min_classes=2, max_classes=4,
                                   seed=0)
    for p in parts:
        classes = set(labels[p].tolist())
        assert 1 <= len(classes) <= 4                    # skewed classes


@settings(max_examples=10, deadline=None)
@given(alpha=st.sampled_from([0.1, 0.5, 5.0]), seed=st.integers(0, 100))
def test_dirichlet_partition_covers_everything(alpha, seed):
    labels = np.random.default_rng(seed).integers(0, 10, size=800)
    parts = dirichlet_partition(labels, 6, alpha=alpha, seed=seed)
    seen = sorted(np.concatenate(parts).tolist())
    assert seen == list(range(800))                      # exact cover


def test_build_client_arrays_counts():
    x = np.arange(40, dtype=np.float32).reshape(20, 2)
    y = np.arange(20, dtype=np.int32)
    parts = [np.array([0, 1, 2]), np.array([5]), np.arange(10, 18)]
    xs, ys, counts = build_client_arrays(x, y, parts)
    assert xs.shape[0] == 3 and xs.shape[1] == 8
    np.testing.assert_array_equal(counts, [3, 1, 8])
    np.testing.assert_array_equal(ys[1][:1], [5])


def test_sample_batches_respect_counts():
    xs = jnp.arange(3 * 10).reshape(3, 10, 1).astype(jnp.float32)
    ys = jnp.arange(3 * 10).reshape(3, 10)
    counts = jnp.array([2, 10, 5], jnp.int32)
    data = ClientData(xs, ys, counts)
    bx, by = sample_client_batches(jax.random.PRNGKey(0), data, steps=4,
                                   batch=16)
    assert bx.shape == (3, 4, 16, 1)
    # client 0 only ever sees its first 2 rows
    assert set(np.asarray(by[0]).ravel().tolist()) <= {0, 1}
    # client 2 only its first 5
    assert set(np.asarray(by[2]).ravel().tolist()) <= {20, 21, 22, 23, 24}


def test_synthetic_images_are_class_separable():
    """A nearest-prototype classifier must beat chance by a wide margin —
    otherwise the convergence experiments would be meaningless."""
    x, y = make_image_dataset(MNIST_LIKE, 600, seed=0)
    protos = np.stack([x[y == c].mean(0) for c in range(10)])
    dists = ((x[:, None] - protos[None]) ** 2).sum(axis=(2, 3, 4))
    acc = (dists.argmin(1) == y).mean()
    assert acc > 0.55, acc


def test_cifar_like_is_harder_than_mnist_like():
    accs = {}
    for name, spec in [("m", MNIST_LIKE), ("c", CIFAR_LIKE)]:
        x, y = make_image_dataset(spec, 600, seed=1)
        protos = np.stack([x[y == c].mean(0) for c in range(10)])
        dists = ((x[:, None] - protos[None]) ** 2).sum(axis=(2, 3, 4))
        accs[name] = (dists.argmin(1) == y).mean()
    assert accs["c"] < accs["m"]


def test_token_stream_bigram_structure():
    toks, topics = make_token_stream(97, 50, 64, num_topics=4, seed=0,
                                     noise=0.0)
    # noise-free stream follows next = prev * a + b (mod V) exactly
    assert toks.shape == (50, 64)
    diffs_consistent = 0
    for i in range(10):
        t = toks[i]
        # affine consistency: (t2 - t1*a) constant — check determinism by
        # regenerating
        toks2, _ = make_token_stream(97, 50, 64, num_topics=4, seed=0,
                                     noise=0.0)
        diffs_consistent += (toks2[i] == t).all()
    assert diffs_consistent == 10


def test_federated_dataset_shapes():
    data = make_federated_image_dataset(MNIST_LIKE, 6, num_samples=900,
                                        global_test=100, seed=0)
    assert data.train.num_clients == 6
    assert data.global_x.shape[0] == 100
    assert data.server_x.shape[0] == 90
    assert int(data.train.counts.min()) >= 1
    assert int(data.test.counts.min()) >= 1
