"""Sec. V-C trusted-tester mechanism end-to-end: with ``use_trust`` the
server down-weights testers whose reports deviate from consensus, so a
persistent liar loses influence over the scores."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.scoring import (
    ScoreState, combine_tester_reports, init_scores, update_scores,
    update_tester_trust)


def test_trust_converges_against_persistent_liar():
    n = 6
    state = init_scores(n)
    tester_ids = jnp.array([0, 1, 2])
    rng = np.random.default_rng(0)
    for _ in range(6):
        honest = jnp.asarray(
            np.clip(0.7 + 0.03 * rng.normal(size=(1, n)), 0, 1))
        acc = jnp.concatenate([
            jnp.asarray(rng.uniform(size=(1, n))),   # tester 0 lies
            honest, honest + 0.01], axis=0)
        state = update_tester_trust(state, acc, tester_ids)
    trust = np.asarray(state.tester_trust)
    assert trust[0] < 0.6 * trust[1]
    assert trust[0] < 0.6 * trust[2]


def test_trust_weighted_reports_ignore_liar():
    n = 4
    state = init_scores(n)
    tester_ids = jnp.array([0, 1])
    # tester 0 inverts accuracies, tester 1 honest
    acc = jnp.array([[0.1, 0.9, 0.1, 0.9],
                     [0.9, 0.1, 0.9, 0.1]])
    # after trust collapse for tester 0:
    state = state._replace(tester_trust=jnp.array([0.01, 1.0, 1.0, 1.0]))
    combined = np.asarray(combine_tester_reports(acc, tester_ids,
                                                 trust=state.tester_trust))
    np.testing.assert_allclose(combined, [0.892, 0.108, 0.892, 0.108],
                               atol=1e-2)


def test_trust_ignores_non_reporting_testers():
    """Client sampling: a report that was never sent can neither shift
    the consensus median nor move its sender's trust."""
    n = 4
    state = init_scores(n)
    tester_ids = jnp.array([0, 1])
    acc = jnp.array([[0.0, 1.0, 0.0, 1.0],    # tester 0 unsampled (noise)
                     [0.8, 0.2, 0.5, 0.6]])   # tester 1 honest
    row_mask = jnp.array([0.0, 1.0])
    new = update_tester_trust(state, acc, tester_ids, row_mask=row_mask)
    trust = np.asarray(new.tester_trust)
    # unsampled tester's trust is frozen at its prior value...
    assert trust[0] == pytest.approx(1.0)
    # ...its wild row is out of the consensus, so the sole reporting
    # tester agrees with itself perfectly
    assert trust[1] > 0.99
    # with no mask the phantom row drags the consensus midway and the
    # honest tester would lose trust for a report it fully agreed with
    unmasked = np.asarray(
        update_tester_trust(state, acc, tester_ids).tester_trust)
    assert unmasked[1] < trust[1] - 0.02


def test_trust_scores_update_uses_trust():
    n = 3
    state = init_scores(n)._replace(
        tester_trust=jnp.array([1.0, 0.0, 1.0]))
    acc = jnp.array([[0.8, 0.2, 0.5],     # trusted
                     [0.0, 1.0, 0.0],     # liar, zero trust
                     [0.8, 0.2, 0.5]])    # trusted
    state = update_scores(state, acc, jnp.array([0, 1, 2]), power=1.0,
                          use_trust=True, power_warmup_rounds=0)
    np.testing.assert_allclose(np.asarray(state.scores), [0.8, 0.2, 0.5],
                               atol=1e-6)
