"""Federated round engine (Algorithm 1) end-to-end behaviour."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import FedConfig, TrainConfig
from repro.configs import get_config
from repro.core import FederatedTrainer
from repro.core.cross_testing import cross_test_accuracies, make_eval_fn
from repro.data import MNIST_LIKE, make_federated_image_dataset
from repro.models import build_model


@pytest.fixture(scope="module")
def small_setup():
    cfg = get_config("fedtest-cnn-mnist").replace(
        cnn_channels=(8, 16, 16), cnn_hidden=32)
    model = build_model(cfg)
    data = make_federated_image_dataset(MNIST_LIKE, 6, num_samples=1800,
                                        global_test=300, seed=0)
    tc = TrainConfig(optimizer="sgd", lr=0.1, schedule="constant",
                     batch_size=16, grad_clip=0.0, remat=False)
    return cfg, model, data, tc


def test_round_metrics_and_weights(small_setup):
    cfg, model, data, tc = small_setup
    fed = FedConfig(num_users=6, num_testers=2, num_malicious=0,
                    local_steps=2)
    trainer = FederatedTrainer(model, fed, tc, eval_batch=64)
    state = trainer.init(jax.random.PRNGKey(0))
    state, metrics = trainer.run_round(state, data)
    w = np.asarray(metrics["weights"])
    assert w.shape == (6,)
    np.testing.assert_allclose(w.sum(), 1.0, atol=1e-5)
    assert int(state.round_idx) == 1
    assert np.isfinite(float(metrics["local_loss"]))


def test_fedtest_converges(small_setup):
    cfg, model, data, tc = small_setup
    fed = FedConfig(num_users=6, num_testers=2, num_malicious=0,
                    local_steps=10)
    trainer = FederatedTrainer(model, fed, tc, eval_batch=64)
    state, hist = trainer.run(jax.random.PRNGKey(0), data, rounds=5)
    assert hist["global_accuracy"][-1] > 0.45   # well above 10% chance


def test_fedtest_suppresses_malicious_weight(small_setup):
    cfg, model, data, tc = small_setup
    # the fixture's near-single-class shards make the K=2 accuracy matrix
    # a lottery (every local model predicts one constant class), so no
    # scoring function can separate honest from malicious — see ROADMAP.
    # Milder skew (every client holds >= 8 of 10 classes) plus a third
    # tester makes the cross-testing signal non-degenerate.
    data = make_federated_image_dataset(
        MNIST_LIKE, 6, num_samples=1800, global_test=300, seed=0,
        partition_kwargs={"min_classes": 8, "max_classes": 10})
    fed = FedConfig(num_users=6, num_testers=3, num_malicious=2,
                    local_steps=10, attack="random_weights", score_power=4.0)
    trainer = FederatedTrainer(model, fed, tc, eval_batch=64)
    state = trainer.init(jax.random.PRNGKey(1))
    for _ in range(6):
        state, metrics = trainer.run_round(state, data)
    # 2/6 clients are malicious; uniform would give them 1/3 total weight
    assert float(metrics["malicious_weight"]) < 0.05


def test_fedavg_cannot_suppress_malicious(small_setup):
    cfg, model, data, tc = small_setup
    fed = FedConfig(num_users=6, num_testers=2, num_malicious=2,
                    local_steps=2, attack="random_weights",
                    aggregator="fedavg")
    trainer = FederatedTrainer(model, fed, tc, eval_batch=64)
    state = trainer.init(jax.random.PRNGKey(1))
    state, metrics = trainer.run_round(state, data)
    # fedavg weights by sample count — malicious share stays at its data share
    assert float(metrics["malicious_weight"]) > 0.1


def test_accuracy_based_baseline_runs(small_setup):
    cfg, model, data, tc = small_setup
    fed = FedConfig(num_users=6, num_testers=2, num_malicious=1,
                    local_steps=10, aggregator="accuracy_based")
    trainer = FederatedTrainer(model, fed, tc, eval_batch=64)
    state = trainer.init(jax.random.PRNGKey(2))
    state, metrics = trainer.run_round(state, data)
    assert float(metrics["malicious_weight"]) < 0.2


def test_cross_testing_perfect_model_scores_one(small_setup):
    cfg, model, data, tc = small_setup

    class Oracle:
        cfg = model.cfg

        @staticmethod
        def forward_train(params, batch):
            logits = jax.nn.one_hot(batch.get("labels_hint"), 10) * 100.0
            return logits, jnp.zeros(())

    # direct matrix check with a synthetic eval_fn instead
    def eval_fn(p, x, y):
        return jnp.asarray(p, jnp.float32)          # "accuracy" = the param

    stacked = jnp.array([0.1, 0.5, 0.9])
    tx = jnp.zeros((2, 4, 1))
    ty = jnp.zeros((2, 4))
    acc = cross_test_accuracies(lambda p, x, y: eval_fn(p, x, y),
                                stacked, tx, ty)
    assert acc.shape == (2, 3)
    np.testing.assert_allclose(np.asarray(acc[0]), [0.1, 0.5, 0.9],
                               atol=1e-6)


def test_participation_sampling_zeroes_non_participants(small_setup):
    """FedConfig.participation < 1: Bernoulli client sampling per round —
    non-participants get exactly zero aggregation weight, the simplex is
    renormalised over the sampled subset, and the metric reports the
    realised rate. The sampled subset varies across rounds without
    retracing."""
    cfg, model, data, tc = small_setup
    fed = FedConfig(num_users=6, num_testers=2, num_malicious=0,
                    local_steps=2, participation=0.5, aggregator="uniform")
    trainer = FederatedTrainer(model, fed, tc, eval_batch=64)
    state = trainer.init(jax.random.PRNGKey(0))
    rates, masks = [], []
    for _ in range(4):
        state, metrics = trainer.run_round(state, data)
        w = np.asarray(metrics["weights"])
        rate = float(metrics["participation_rate"])
        rates.append(rate)
        masks.append(tuple(w > 0))
        # participants share weight uniformly; non-participants get zero
        np.testing.assert_allclose(w.sum(), 1.0, atol=1e-5)
        k = int(round(rate * 6))
        assert 1 <= k <= 6
        assert (w > 0).sum() == k
        np.testing.assert_allclose(w[w > 0], 1.0 / k, atol=1e-5)
    assert trainer.num_traces == 1
    assert len(set(masks)) > 1      # the subset actually resamples
    assert any(r < 1.0 for r in rates)


def test_full_participation_reports_rate_one(small_setup):
    cfg, model, data, tc = small_setup
    fed = FedConfig(num_users=6, num_testers=2, local_steps=2)
    trainer = FederatedTrainer(model, fed, tc, eval_batch=64)
    state = trainer.init(jax.random.PRNGKey(0))
    state, metrics = trainer.run_round(state, data)
    assert float(metrics["participation_rate"]) == 1.0


def test_lying_testers_tolerated(small_setup):
    """Sec. V-C: moving-average over all testers makes the impact of a few
    lying testers negligible."""
    cfg, model, data, tc = small_setup
    fed = FedConfig(num_users=6, num_testers=3, num_malicious=1,
                    local_steps=10, lying_testers=1)
    trainer = FederatedTrainer(model, fed, tc, eval_batch=64)
    state = trainer.init(jax.random.PRNGKey(3))
    for _ in range(3):
        state, metrics = trainer.run_round(state, data)
    assert float(metrics["malicious_weight"]) < 0.25
