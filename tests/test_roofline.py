"""Roofline analysis plumbing: HLO collective parsing + term math."""
import pytest

from repro.config import INPUT_SHAPES
from repro.configs import get_config
from repro.roofline import TPU_V5E, model_flops, parse_collectives
from repro.roofline.analysis import (
    _shape_bytes, collective_bytes_per_device, roofline_terms)

HLO = """
HloModule jit_step
ENTRY main {
  %p0 = bf16[128,256]{1,0} parameter(0)
  %ag = bf16[2048,256]{1,0} all-gather(%p0), dimensions={0}
  %ar = f32[1024]{0} all-reduce(%x), to_apply=%sum
  %rs = f32[64,32]{1,0} reduce-scatter(%y), dimensions={0}
  %a2a = bf16[16,64]{1,0} all-to-all(%z), dimensions={0}
  %cp = bf16[8,8]{1,0} collective-permute(%w), source_target_pairs={{0,1}}
  %ars = (f32[256]{0}, f32[256]{0}) all-reduce-start(%q), to_apply=%sum
  %ard = f32[256]{0} all-reduce-done(%ars)
  %dot = f32[4,4]{1,0} dot(%a, %b)
}
"""


def test_shape_bytes():
    assert _shape_bytes("bf16[128,256]{1,0}") == 128 * 256 * 2
    assert _shape_bytes("f32[1024]{0}") == 4096
    assert _shape_bytes("(f32[2]{0}, bf16[4]{0})") == 8 + 8


def test_parse_collectives_kinds_and_bytes():
    colls = parse_collectives(HLO)
    assert colls["all-gather"] == 2048 * 256 * 2
    assert colls["reduce-scatter"] == 64 * 32 * 4
    assert colls["all-to-all"] == 16 * 64 * 2
    assert colls["collective-permute"] == 8 * 8 * 2
    # sync all-reduce + the async -start pair (done line skipped)
    assert colls["all-reduce"] == 1024 * 4 + 2 * 256 * 4


def test_collective_factors():
    b = collective_bytes_per_device({"all-reduce": 100, "all-gather": 50})
    assert b == 2 * 100 + 50


def test_roofline_terms_bottleneck():
    t = roofline_terms(flops=197e12, bytes_accessed=819e9 * 2,
                       coll_bytes=50e9 * 0.5, chip=TPU_V5E, num_chips=256)
    assert abs(t["compute_s"] - 1.0) < 1e-6
    assert abs(t["memory_s"] - 2.0) < 1e-6
    assert abs(t["collective_s"] - 0.5) < 1e-6
    assert t["bottleneck"] == "memory"


def test_model_flops_train_vs_decode():
    cfg = get_config("qwen2-0.5b")
    tr = model_flops(cfg, INPUT_SHAPES["train_4k"])
    de = model_flops(cfg, INPUT_SHAPES["decode_32k"])
    assert tr > de * 1000
    n = cfg.param_count()
    assert abs(tr - 6 * n * 256 * 4096) / tr < 1e-6


def test_moe_uses_active_params():
    cfg = get_config("qwen3-moe-30b-a3b")
    f_active = model_flops(cfg, INPUT_SHAPES["train_4k"], active=True)
    f_total = model_flops(cfg, INPUT_SHAPES["train_4k"], active=False)
    assert f_active < 0.3 * f_total
