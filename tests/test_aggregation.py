"""Aggregation schemes + attacks + selection."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core.aggregation import (
    accuracy_based_weights, aggregate_models, fedavg_weights)
from repro.core.attacks import apply_attacks
from repro.core.selection import rb_schedule, select_testers


def _stack(n, key=0, shapes=((3, 4), (5,))):
    ks = jax.random.split(jax.random.PRNGKey(key), len(shapes))
    return {f"p{i}": jax.random.normal(k, (n,) + s)
            for i, (k, s) in enumerate(zip(ks, shapes))}


def test_fedavg_weights_proportional_to_counts():
    w = np.asarray(fedavg_weights(jnp.array([10, 30, 60])))
    np.testing.assert_allclose(w, [0.1, 0.3, 0.6], atol=1e-6)


@settings(max_examples=40, deadline=None)
@given(accs=st.lists(st.floats(0, 1), min_size=2, max_size=8))
def test_accuracy_weights_simplex(accs):
    w = np.asarray(accuracy_based_weights(jnp.asarray(accs)))
    assert (w >= 0).all()
    np.testing.assert_allclose(w.sum(), 1.0, atol=1e-5)


def test_aggregate_linearity():
    stacked = _stack(4)
    w = jnp.array([0.1, 0.2, 0.3, 0.4])
    agg = aggregate_models(stacked, w, impl="naive")
    manual = jax.tree_util.tree_map(
        lambda x: jnp.einsum("c,c...->...", w, x), stacked)
    for a, b in zip(jax.tree_util.tree_leaves(agg),
                    jax.tree_util.tree_leaves(manual)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)


def test_attack_replaces_only_last_m():
    stacked = _stack(5)
    global_params = jax.tree_util.tree_map(lambda x: x[0] * 0, stacked)
    out = apply_attacks(jax.random.PRNGKey(0), stacked, global_params,
                        num_malicious=2, attack="random_weights")
    for name in stacked:
        np.testing.assert_allclose(np.asarray(out[name][:3]),
                                   np.asarray(stacked[name][:3]))
        assert np.abs(np.asarray(out[name][3:])
                      - np.asarray(stacked[name][3:])).max() > 1e-3


def test_sign_flip_is_gradient_ascent():
    stacked = _stack(2)
    gp = jax.tree_util.tree_map(lambda x: jnp.zeros_like(x[0]), stacked)
    out = apply_attacks(jax.random.PRNGKey(0), stacked, gp,
                        num_malicious=1, attack="sign_flip", scale=1.0)
    for name in stacked:
        np.testing.assert_allclose(np.asarray(out[name][1]),
                                   -np.asarray(stacked[name][1]), atol=1e-5)


def test_none_attack_identity():
    stacked = _stack(3)
    gp = jax.tree_util.tree_map(lambda x: x[0], stacked)
    out = apply_attacks(jax.random.PRNGKey(0), stacked, gp,
                        num_malicious=2, attack="none")
    for name in stacked:
        np.testing.assert_allclose(np.asarray(out[name]),
                                   np.asarray(stacked[name]))


def test_tester_rotation():
    key = jax.random.PRNGKey(0)
    t1 = set(np.asarray(select_testers(key, 20, 5, 0)).tolist())
    t2 = set(np.asarray(select_testers(key, 20, 5, 1)).tolist())
    assert len(t1) == 5 and len(t2) == 5
    assert t1 != t2     # different rounds, (almost surely) different sets


def test_rb_schedule_accounting():
    sched = rb_schedule(np.array([2, 7]), num_users=10,
                        model_bytes=1000, acc_report_bytes=4)
    assert sched["num_slots"] == 10            # one orthogonal RB per user
    # 8 non-testers send the model; 2 testers send model + 10 accuracies
    assert sched["uplink_bytes"] == 8 * 1000 + 2 * (1000 + 40)
    # every non-tester's model reaches both testers over D2D
    assert sched["d2d_bytes"] == 1000 * 8 * 2
    users = [s["user"] for s in sched["slots"]]
    assert sorted(users) == list(range(10))
    # testers transmit in the last slots (Alg. 1 lines 10-12)
    assert {s["user"] for s in sched["slots"][-2:]} == {2, 7}
