"""Kernel-routed eval consistency (DESIGN.md §10).

The K×N cross-testing path always evaluates LMs through the kernel ops
(``flash_attention`` / ``decode_attention`` / ``ssd_scan`` via
:func:`~repro.core.cross_testing.kernel_route_model`), never the naive
small-shape oracle. That routing must be behaviour-preserving: on the
``benchmarks/bench_crosstest.py`` shapes the routed forward matches the
naive XLA forward to the same tolerance ``test_decode_consistency``
uses, and the resulting [K, N] accuracy matrices agree. The second half
pins the dispatch discipline itself: the batched eval under the scanned
driver traces the round body exactly once (``num_traces == 1``) — the
fast path may not buy its speed with retraces.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import FedConfig, TrainConfig, reduce_for_smoke
from repro.configs import get_config
from repro.core import FederatedTrainer
from repro.core.cross_testing import (cross_test_accuracies,
                                      kernel_route_model, make_eval_fn,
                                      resolve_eval_impl)
from repro.data import MNIST_LIKE, make_federated_image_dataset
from repro.models import build_model

# the attention and SSM sides of the kernel routing, on the fast-mode
# bench shapes (B, S) = (2, 64)
LM_ARCHS = ["qwen2-0.5b", "mamba2-2.7b"]
B, S = 2, 64
K, N = 2, 3


def _lm_case(arch):
    cfg = reduce_for_smoke(get_config(arch)).replace(dtype="float32")
    naive = build_model(cfg, attn_impl="naive", ssm_impl="naive")
    tx = jax.random.randint(jax.random.PRNGKey(1), (K, B, S), 0,
                            cfg.vocab_size)
    ty = jax.random.randint(jax.random.PRNGKey(2), (K, B, S), -1,
                            cfg.vocab_size)
    return naive, tx, ty


@pytest.mark.parametrize("arch", LM_ARCHS)
def test_routing_upgrades_naive(arch):
    cfg = reduce_for_smoke(get_config(arch)).replace(dtype="float32")
    naive = build_model(cfg, attn_impl="naive", ssm_impl="naive")
    routed = kernel_route_model(naive)
    impl = resolve_eval_impl()
    assert routed.attn_impl == impl, routed.attn_impl
    assert routed.ssm_impl == impl, routed.ssm_impl
    # explicit impl choices are respected, cnn/mlp pass through untouched
    pinned = build_model(cfg, attn_impl="xla", ssm_impl="xla")
    assert kernel_route_model(pinned) is pinned
    mlp = build_model(get_config("fedtest-mlp-mnist"))
    assert kernel_route_model(mlp) is mlp


@pytest.mark.parametrize("arch", LM_ARCHS)
def test_routed_forward_matches_naive(arch):
    naive, tx, ty = _lm_case(arch)
    routed = kernel_route_model(naive)
    p = naive.init(jax.random.PRNGKey(0))
    lg_naive, _ = jax.jit(naive.forward_train)(p, {"tokens": tx[0]})
    lg_routed, _ = jax.jit(routed.forward_train)(p, {"tokens": tx[0]})
    err = np.abs(np.asarray(lg_naive) - np.asarray(lg_routed)).max()
    assert err < 3e-4, (arch, err)


@pytest.mark.parametrize("arch", LM_ARCHS)
def test_routed_eval_matrix_matches_naive(arch):
    naive, tx, ty = _lm_case(arch)
    stacked = jax.vmap(naive.init)(jax.random.split(jax.random.PRNGKey(0),
                                                    N))
    mats = {}
    for label, route in (("routed", True), ("naive", False)):
        eval_fn = make_eval_fn(naive, route_kernels=route)
        fn = jax.jit(lambda s, x, y, _f=eval_fn: cross_test_accuracies(
            _f, s, x, y, impl="batched"))
        mats[label] = np.asarray(fn(stacked, tx, ty))
    # accuracy is an argmax statistic: a sub-3e-4 logit wobble on random
    # weights does not flip a vocab-sized argmax
    np.testing.assert_allclose(mats["routed"], mats["naive"], atol=1e-6,
                               err_msg=arch)
    assert mats["routed"].shape == (K, N)


def test_batched_eval_no_retrace_under_scan():
    """The batched fast path under the scanned multi-round driver (with
    the schedule-keyed eval-batch resampling active) must trace the
    round body exactly once across all rounds."""
    cfg = get_config("fedtest-mlp-mnist").replace(mlp_hidden=(32,))
    model = build_model(cfg)
    fed = FedConfig(num_users=4, num_testers=3, num_malicious=0,
                    attack="none", participation=0.75, local_steps=2,
                    crosstest_impl="batched", seed=0)
    tc = TrainConfig(optimizer="sgd", lr=0.1, schedule="constant",
                     batch_size=8, grad_clip=0.0, remat=False)
    data = make_federated_image_dataset(MNIST_LIKE, 4, num_samples=400,
                                        global_test=64, seed=0)
    trainer = FederatedTrainer(model, fed, tc, eval_batch=16,
                               rounds_per_call=2, eval_resample_every=2)
    _, history = trainer.run(jax.random.PRNGKey(0), data, rounds=4)
    assert trainer.num_traces == 1, trainer.num_traces
    assert history["round"][-1] == 4
