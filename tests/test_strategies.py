"""The pluggable strategy registry: aggregators x attacks x selectors.

Covers the registry contract (helpful KeyError, simplex invariant under
jit for every registered aggregator), the robust baselines down-weighting
an attacker end-to-end, attack placement correctness of the
``malicious_weight`` metric, and the no-retrace guarantee of pre-trace
strategy resolution.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import FedConfig, TrainConfig
from repro.configs import get_config, list_scenarios, get_scenario
from repro.core import FederatedTrainer
from repro.core.scoring import init_scores
from repro.data import MNIST_LIKE, make_federated_image_dataset
from repro.models import build_model
from repro.strategies import (
    AGGREGATORS, ATTACKS, SELECTORS, Aggregator, RoundContext, register)

N_USERS = 8


# ----------------------------------------------------------------- registry
def test_unknown_names_raise_keyerror_listing_registered():
    for registry, known in ((AGGREGATORS, "fedtest"),
                            (ATTACKS, "random_weights"),
                            (SELECTORS, "rotating")):
        with pytest.raises(KeyError) as e:
            registry.get("definitely_not_registered")
        msg = str(e.value)
        assert "definitely_not_registered" in msg
        assert known in msg          # the error lists what *is* registered


def test_fedconfig_validates_names_against_registries():
    with pytest.raises(KeyError, match="fedavg"):
        FedConfig(aggregator="nope")
    with pytest.raises(KeyError, match="sign_flip"):
        FedConfig(attack="nope")
    with pytest.raises(KeyError, match="round_robin"):
        FedConfig(selector="nope")


def test_unknown_user_kwargs_raise_typeerror():
    with pytest.raises(TypeError, match="bogus"):
        AGGREGATORS.build("krum", {"bogus": 1})


def test_duplicate_registration_rejected():
    with pytest.raises(ValueError, match="already registered"):
        register(AGGREGATORS, "fedtest")(object)


def test_custom_aggregator_via_decorator_resolves_from_config():
    name = "test_only_uniformish"
    if name not in AGGREGATORS:
        @register(AGGREGATORS, name)
        class Uniformish(Aggregator):
            def weights(self, ctx):
                n = ctx.num_users
                return jnp.full((n,), 1.0 / n)

    agg = AGGREGATORS.build(FedConfig(aggregator=name).aggregator)
    ctx = _synthetic_ctx(jax.random.PRNGKey(0), 5)
    np.testing.assert_allclose(np.asarray(agg.weights(ctx)),
                               np.full(5, 0.2), atol=1e-6)


def _synthetic_ctx(key, n, k=3, d=64):
    ks = jax.random.split(key, 3)
    return RoundContext(
        acc_matrix=jax.random.uniform(ks[0], (k, n)),
        tester_ids=jnp.arange(k),
        scores=init_scores(n),
        counts=jnp.arange(1.0, n + 1.0),
        round_idx=jnp.zeros((), jnp.int32),
        key=ks[1],
        updates=jax.random.normal(ks[2], (n, d)),
        server_eval=lambda: jax.random.uniform(ks[0], (n,)))


@pytest.mark.parametrize("name", sorted(AGGREGATORS.names()))
def test_every_registered_aggregator_returns_simplex_under_jit(name):
    agg = AGGREGATORS.build(name, defaults={"num_byzantine": 1})

    @jax.jit
    def weights_of(key):
        ctx = _synthetic_ctx(key, N_USERS)
        scores = agg.update_scores(ctx)
        return agg.weights(ctx._replace(scores=scores))

    for seed in (0, 1):
        w = np.asarray(weights_of(jax.random.PRNGKey(seed)))
        assert w.shape == (N_USERS,)
        assert (w >= -1e-6).all(), f"{name}: negative weight"
        np.testing.assert_allclose(w.sum(), 1.0, atol=1e-4,
                                   err_msg=f"{name}: not a simplex")


def test_update_aggregators_exclude_non_participants():
    """Client sampling reverts non-participants' slots to the global
    model, i.e. all-zero update rows. Zero rows have mutual distance 0 —
    left unmasked they would *win* Krum and drag the trimmed-mean /
    geometric-median consensus toward the origin. Every update-based
    aggregator must confine its statistic to ctx.participation."""
    n, d = 8, 16
    key = jax.random.PRNGKey(0)
    u = jax.random.normal(key, (n, d)) + 3.0     # honest cluster, off-origin
    part = jnp.asarray([1, 1, 0, 0, 1, 1, 1, 1], jnp.float32)
    u = u * part[:, None]                        # reverted slots: zero rows
    ctx = _synthetic_ctx(key, n)._replace(updates=u, participation=part)
    for name in ("krum", "trimmed_mean", "median"):
        agg = AGGREGATORS.build(name, defaults={"num_byzantine": 1})
        w = np.asarray(agg.weights(ctx))
        assert w[2] < 1e-6 and w[3] < 1e-6, (name, w)
        assert w[np.asarray(part) > 0].sum() > 0.99, (name, w)
    # krum in particular must not hand its one-hot to a zero row
    krum_w = np.asarray(AGGREGATORS.build(
        "krum", defaults={"num_byzantine": 1}).weights(ctx))
    assert krum_w.argmax() not in (2, 3)


# ------------------------------------------------------- attacks / placement
def test_attack_placement_drives_malicious_mask():
    atk = ATTACKS.build("random_weights",
                        {"placement": "first"},
                        {"num_malicious": 2, "scale": 1.0})
    assert atk.malicious_indices(6) == (0, 1)
    atk = ATTACKS.build("random_weights", {"indices": (1, 4)})
    assert atk.malicious_indices(6) == (1, 4)
    np.testing.assert_allclose(np.asarray(atk.malicious_mask(6)),
                               [0, 1, 0, 0, 1, 0])
    atk = ATTACKS.build("sign_flip", {}, {"num_malicious": 2})
    assert atk.malicious_indices(6) == (4, 5)
    # the no-op attack corrupts nobody, whatever num_malicious says
    atk = ATTACKS.build("none", {}, {"num_malicious": 3})
    assert atk.malicious_indices(6) == ()


def test_attack_apply_corrupts_exactly_the_malicious_set():
    stacked = {"p": jax.random.normal(jax.random.PRNGKey(0), (6, 4, 3))}
    gp = {"p": jnp.zeros((4, 3))}
    atk = ATTACKS.build("random_weights", {"indices": (0, 3)})
    out = atk.apply(jax.random.PRNGKey(1), stacked, gp)
    changed = [bool(np.abs(np.asarray(out["p"][c] - stacked["p"][c])).max()
                    > 1e-4) for c in range(6)]
    assert changed == [True, False, False, True, False, False]


# --------------------------------------------------------------- selectors
def test_selectors_return_valid_ids():
    key = jax.random.PRNGKey(0)
    for name in SELECTORS.names():
        sel = SELECTORS.build(name)
        ids = np.asarray(sel.select(key, 10, 4, jnp.asarray(2)))
        assert ids.shape == (4,)
        assert len(set(ids.tolist())) == 4
        assert ((ids >= 0) & (ids < 10)).all(), name


def test_round_robin_walks_the_ring():
    sel = SELECTORS.build("round_robin")
    key = jax.random.PRNGKey(0)
    seen = []
    for r in range(5):
        seen += np.asarray(sel.select(key, 10, 2, jnp.asarray(r))).tolist()
    assert seen == [0, 1, 2, 3, 4, 5, 6, 7, 8, 9]


# ---------------------------------------------------------------- end-to-end
@pytest.fixture(scope="module")
def smoke_setup():
    cfg = get_config("fedtest-cnn-mnist").replace(
        cnn_channels=(8, 16, 16), cnn_hidden=32)
    model = build_model(cfg)
    data = make_federated_image_dataset(MNIST_LIKE, N_USERS,
                                        num_samples=2400, global_test=300,
                                        seed=0)
    tc = TrainConfig(optimizer="sgd", lr=0.1, schedule="constant",
                     batch_size=16, grad_clip=0.0, remat=False)
    return model, data, tc


@pytest.mark.parametrize("aggregator", ["krum", "trimmed_mean", "median"])
def test_robust_aggregators_down_weight_random_attacker(smoke_setup,
                                                        aggregator):
    model, data, tc = smoke_setup
    fed = FedConfig(num_users=N_USERS, num_testers=3, num_malicious=2,
                    local_steps=2, aggregator=aggregator,
                    attack="random_weights")
    trainer = FederatedTrainer(model, fed, tc, eval_batch=64)
    state = trainer.init(jax.random.PRNGKey(0))
    for _ in range(2):
        state, metrics = trainer.run_round(state, data)
    # 2/8 malicious; uniform would give them 0.25 of the weight
    assert float(metrics["malicious_weight"]) < 0.05, aggregator


@pytest.mark.parametrize("attack,scale", [("label_flip_proxy", 1.0),
                                          ("scaled_update", 10.0)])
def test_new_attacks_run_jitted_and_fedtest_suppresses(smoke_setup, attack,
                                                       scale):
    model, data, tc = smoke_setup
    fed = FedConfig(num_users=N_USERS, num_testers=3, num_malicious=2,
                    local_steps=4, aggregator="fedtest", attack=attack,
                    attack_scale=scale)
    trainer = FederatedTrainer(model, fed, tc, eval_batch=64)
    state = trainer.init(jax.random.PRNGKey(0))
    # 8 rounds: label_flip_proxy's honest-magnitude updates take several
    # score-EMA rounds to fall below the 2/8 = 0.25 uniform share
    for _ in range(8):
        state, metrics = trainer.run_round(state, data)
    assert np.isfinite(float(metrics["local_loss"]))
    assert float(metrics["malicious_weight"]) < 0.25
    assert trainer.num_traces == 1


def test_malicious_weight_metric_respects_placement(smoke_setup):
    """The metric must track the attack's index set, not 'the last M'."""
    model, data, tc = smoke_setup
    fed = FedConfig(num_users=N_USERS, num_testers=3, num_malicious=2,
                    local_steps=2, aggregator="uniform",
                    attack="random_weights",
                    attack_kwargs={"placement": "first"})
    trainer = FederatedTrainer(model, fed, tc, eval_batch=64)
    assert trainer.attack.malicious_indices(N_USERS) == (0, 1)
    state = trainer.init(jax.random.PRNGKey(0))
    state, metrics = trainer.run_round(state, data)
    # uniform aggregation: the 2 attackers hold exactly 2/8 of the weight
    np.testing.assert_allclose(float(metrics["malicious_weight"]),
                               2.0 / N_USERS, atol=1e-5)


def test_no_retrace_across_rounds(smoke_setup):
    """Strategy resolution is pre-trace: N rounds -> one trace."""
    model, data, tc = smoke_setup
    fed = FedConfig(num_users=N_USERS, num_testers=3, num_malicious=2,
                    local_steps=2, aggregator="krum",
                    attack="random_weights")
    trainer = FederatedTrainer(model, fed, tc, eval_batch=64)
    state = trainer.init(jax.random.PRNGKey(0))
    for _ in range(3):
        state, _ = trainer.run_round(state, data)
    assert trainer.num_traces == 1


def test_scenarios_resolve():
    from repro.core.round import resolve_strategies
    for name in list_scenarios():
        agg, atk, sel = resolve_strategies(get_scenario(name))
        assert agg is not None and atk is not None and sel is not None
