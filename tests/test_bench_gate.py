"""tools/check_bench.py — the BENCH_*.json roofline-fraction CI gate."""
import importlib.util
import json
from pathlib import Path

_spec = importlib.util.spec_from_file_location(
    "check_bench",
    Path(__file__).resolve().parent.parent / "tools" / "check_bench.py")
check_bench = importlib.util.module_from_spec(_spec)
_spec.loader.exec_module(check_bench)


def row(name, frac=None, **extra):
    r = {"name": name, "us_per_call": 1.0, "derived": "", **extra}
    if frac is not None:
        r["roofline_frac"] = frac
    return r


def test_within_tolerance_passes():
    base = [row("k/a", 0.90), row("k/b", 0.50)]
    fresh = [row("k/a", 0.80), row("k/b", 0.47)]    # -11%, -6%
    assert check_bench.compare_rows(base, fresh, tolerance=0.15) == []


def test_regression_beyond_tolerance_fails():
    base = [row("k/a", 0.90)]
    fresh = [row("k/a", 0.70)]                      # -22%
    errs = check_bench.compare_rows(base, fresh, tolerance=0.15)
    assert len(errs) == 1 and "k/a" in errs[0]


def test_improvements_and_new_rows_pass():
    base = [row("k/a", 0.50)]
    fresh = [row("k/a", 0.95), row("k/new", 0.10)]
    assert check_bench.compare_rows(base, fresh) == []


def test_dropped_tracked_row_fails():
    base = [row("k/a", 0.90), row("k/b", 0.50)]
    fresh = [row("k/a", 0.90)]
    errs = check_bench.compare_rows(base, fresh)
    assert len(errs) == 1 and "disappeared" in errs[0]


def test_rows_without_fraction_are_ignored():
    base = [row("k/latency_only"), row("k/a", 0.9)]
    fresh = [row("k/a", 0.9)]                       # latency row dropped
    assert check_bench.compare_rows(base, fresh) == []


def test_noise_floor_rows_are_not_gated():
    """Compute-bound fractions below min_frac measure the host, not the
    code — reported in the artifact, never gated."""
    base = [row("k/flash", 0.005), row("k/stream", 0.90)]
    fresh = [row("k/flash", 0.001), row("k/stream", 0.89)]  # flash -80%
    assert check_bench.compare_rows(base, fresh) == []
    # raising min_frac pulls a row back into the gate
    errs = check_bench.compare_rows(base, fresh, min_frac=0.004)
    assert len(errs) == 1 and "k/flash" in errs[0]


def test_lost_fraction_field_fails():
    base = [row("k/a", 0.9)]
    fresh = [row("k/a")]
    errs = check_bench.compare_rows(base, fresh)
    assert len(errs) == 1 and "lost" in errs[0]


def test_main_end_to_end_with_baseline_dir(tmp_path):
    baseline = tmp_path / "base"
    fresh = tmp_path / "fresh"
    baseline.mkdir()
    fresh.mkdir()
    (baseline / "BENCH_kernels.json").write_text(
        json.dumps([row("k/a", 0.9)]))
    (fresh / "BENCH_kernels.json").write_text(
        json.dumps([row("k/a", 0.88)]))
    ok = check_bench.main(["--fresh-dir", str(fresh),
                           "--baseline-dir", str(baseline)])
    assert ok == 0
    (fresh / "BENCH_kernels.json").write_text(
        json.dumps([row("k/a", 0.30)]))
    assert check_bench.main(["--fresh-dir", str(fresh),
                             "--baseline-dir", str(baseline)]) == 1
    # a suite with no baseline yet passes (first emission)
    (fresh / "BENCH_other.json").write_text(json.dumps([row("o/a", 0.5)]))
    (fresh / "BENCH_kernels.json").write_text(
        json.dumps([row("k/a", 0.9)]))
    assert check_bench.main(["--fresh-dir", str(fresh),
                             "--baseline-dir", str(baseline)]) == 0
    # an empty fresh dir is an error (the bench never ran)
    empty = tmp_path / "empty"
    empty.mkdir()
    assert check_bench.main(["--fresh-dir", str(empty),
                             "--baseline-dir", str(baseline)]) == 1


def test_crosstest_rows_are_gated(tmp_path):
    """The crosstest suite's batched rows carry roofline_frac and sit in
    the same gate as the kernel rows: a synthetic >15% regression on a
    crosstest row fails, the dispatch-only reference rows (no fraction)
    are reported but never gated, and a tree with no crosstest baseline
    yet (the suite's first landing) passes."""
    base = [row("crosstest/stream_ref_C16_M1048576", 1.0),
            row("crosstest/mlp_N8_reference", dispatches=8),
            row("crosstest/mlp_N8", 0.30, dispatches=1, speedup=5.0)]
    fresh_ok = [row("crosstest/stream_ref_C16_M1048576", 1.0),
                row("crosstest/mlp_N8_reference", dispatches=8),
                row("crosstest/mlp_N8", 0.28, dispatches=1, speedup=4.6)]
    assert check_bench.compare_rows(base, fresh_ok,
                                    suite="crosstest") == []

    regressed = [row("crosstest/stream_ref_C16_M1048576", 1.0),
                 row("crosstest/mlp_N8_reference", dispatches=8),
                 row("crosstest/mlp_N8", 0.18, dispatches=1)]   # -40%
    errs = check_bench.compare_rows(base, regressed, suite="crosstest")
    assert len(errs) == 1 and "crosstest/mlp_N8" in errs[0]

    # first landing: baseline dir has kernels but no crosstest file
    baseline = tmp_path / "base"
    fresh = tmp_path / "fresh"
    baseline.mkdir()
    fresh.mkdir()
    (baseline / "BENCH_kernels.json").write_text(
        json.dumps([row("k/a", 0.9)]))
    (fresh / "BENCH_kernels.json").write_text(
        json.dumps([row("k/a", 0.9)]))
    (fresh / "BENCH_crosstest.json").write_text(json.dumps(regressed))
    assert check_bench.main(["--fresh-dir", str(fresh),
                             "--baseline-dir", str(baseline)]) == 0
    # ...and once the baseline exists the same regression gates
    (baseline / "BENCH_crosstest.json").write_text(json.dumps(base))
    assert check_bench.main(["--fresh-dir", str(fresh),
                             "--baseline-dir", str(baseline)]) == 1


def test_population_first_landing_then_gated(tmp_path):
    """BENCH_population.json lands with no committed baseline: a suite
    absent from the baseline ref must be treated as new-and-passing
    (``git show`` returns nothing -> the suite is skipped, not failed),
    and its gated ``cohort_aggregate`` row must start regressing the
    moment a baseline exists."""
    repo = tmp_path / "repo"
    repo.mkdir()
    _git(repo, "init", "-q")
    (repo / "BENCH_kernels.json").write_text(json.dumps([row("k/a", 0.9)]))
    _git(repo, "add", "-A")
    _git(repo, "commit", "-qm", "base without the population suite")
    # absent at the baseline ref -> None -> main() takes the
    # first-emission skip instead of a dropped-series failure
    assert check_bench.baseline_from_git("BENCH_population.json", "HEAD",
                                         cwd=repo) is None

    pop = [row("population/stream_ref_C16_M1048576", 1.0),
           row("population/cohort_aggregate_C64", 0.95),
           row("population/pop_N100000_C64", clients=100_000, cohort=64)]
    baseline = tmp_path / "base"
    fresh = tmp_path / "fresh"
    baseline.mkdir()
    fresh.mkdir()
    (baseline / "BENCH_kernels.json").write_text(
        json.dumps([row("k/a", 0.9)]))
    (fresh / "BENCH_kernels.json").write_text(json.dumps([row("k/a", 0.9)]))
    (fresh / "BENCH_population.json").write_text(json.dumps(pop))
    assert check_bench.main(["--fresh-dir", str(fresh),
                             "--baseline-dir", str(baseline)]) == 0

    # once committed, the baseline gates: a >15% aggregate-bandwidth
    # regression fails while the fraction-less wall-time rows ride along
    (baseline / "BENCH_population.json").write_text(json.dumps(pop))
    regressed = [row("population/stream_ref_C16_M1048576", 1.0),
                 row("population/cohort_aggregate_C64", 0.60),
                 row("population/pop_N100000_C64", clients=100_000,
                     cohort=64)]
    (fresh / "BENCH_population.json").write_text(json.dumps(regressed))
    assert check_bench.main(["--fresh-dir", str(fresh),
                             "--baseline-dir", str(baseline)]) == 1


def _git(repo, *args):
    import subprocess
    subprocess.run(["git", *args], cwd=repo, check=True,
                   capture_output=True,
                   env={"GIT_AUTHOR_NAME": "t", "GIT_AUTHOR_EMAIL": "t@t",
                        "GIT_COMMITTER_NAME": "t", "GIT_COMMITTER_EMAIL":
                        "t@t", "HOME": str(repo), "PATH": "/usr/bin:/bin"})


def test_baseline_ref_resolution(tmp_path):
    """`auto` prefers origin/main over HEAD: on a PR merge commit, HEAD
    already carries the PR's own BENCH files and would gate the run
    against itself."""
    repo = tmp_path / "repo"
    repo.mkdir()
    _git(repo, "init", "-q")
    (repo / "BENCH_kernels.json").write_text(json.dumps([row("k/a", 0.9)]))
    _git(repo, "add", "-A")
    _git(repo, "commit", "-qm", "base")

    # no origin/main yet -> fall back to HEAD
    assert check_bench.resolve_baseline_ref("auto", cwd=repo) == "HEAD"
    # an explicit ref is passed through untouched
    assert check_bench.resolve_baseline_ref("HEAD~3", cwd=repo) == "HEAD~3"

    # simulate the CI checkout: origin/main points at the base commit,
    # HEAD advances with a "PR" commit that rewrites the baseline
    _git(repo, "update-ref", "refs/remotes/origin/main", "HEAD")
    (repo / "BENCH_kernels.json").write_text(json.dumps([row("k/a", 0.3)]))
    _git(repo, "add", "-A")
    _git(repo, "commit", "-qm", "pr: regressed baseline")
    assert check_bench.resolve_baseline_ref("auto", cwd=repo) == "origin/main"

    # and the two refs genuinely disagree about the baseline content
    at_main = check_bench.baseline_from_git("BENCH_kernels.json",
                                            "origin/main", cwd=repo)
    at_head = check_bench.baseline_from_git("BENCH_kernels.json",
                                            "HEAD", cwd=repo)
    assert at_main[0]["roofline_frac"] == 0.9
    assert at_head[0]["roofline_frac"] == 0.3
