"""Property suite for the compressed update exchange (DESIGN.md §12).

Pins the wire-format algebra every compressor must satisfy before the
engine threads it: round-trip error bounds, the error-feedback
telescoping invariant (sum of decoded payloads + final residual ==
sum of raw updates), identity's exactness, dtype/shape preservation,
key-free determinism (FL001), trace stability across rounds, and the
fused ``dequant_aggregate`` kernel against its dequantise-then-reduce
oracle (interpret mode, so the Pallas path is exercised on CPU).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.kernels.dequant_aggregate.kernel import dequant_aggregate_pallas
from repro.kernels.dequant_aggregate.ops import dequant_aggregate
from repro.kernels.dequant_aggregate.ref import dequant_aggregate_ref
from repro.kernels.weighted_aggregate.ops import weighted_aggregate
from repro.strategies import COMPRESSORS

SPECS = [("identity", {}), ("topk", {"k": 0.05}), ("topk", {"k": 17}),
         ("int8", {}), ("int8", {"chunk": 64}),
         ("lowrank", {"rank": 2}), ("lowrank", {"rank": 4, "iters": 3})]


def build(name, kwargs, dim):
    return COMPRESSORS.build(name, kwargs, dict(dim=dim))


def make_update(dim, seed, scale=1e-2):
    return jax.random.normal(jax.random.PRNGKey(seed), (dim,),
                             jnp.float32) * scale


# ------------------------------------------------------------ registry
def test_registry_contents():
    assert {"identity", "topk", "int8", "lowrank"} <= set(
        COMPRESSORS.names())


def test_ctor_validation():
    with pytest.raises(ValueError):
        build("identity", {}, 0)
    with pytest.raises(ValueError):
        build("topk", {"k": 0.0}, 100)
    with pytest.raises(ValueError):
        build("int8", {"chunk": 0}, 100)
    with pytest.raises(ValueError):
        build("lowrank", {"rank": 0}, 100)


def test_non_vector_update_rejected():
    comp = build("identity", {}, 12)
    with pytest.raises(ValueError, match="flat"):
        comp.encode(jnp.zeros((12,)), jnp.zeros((3, 4)))


# ------------------------------------------------- shapes/dtypes/state
@pytest.mark.parametrize("name,kwargs", SPECS)
def test_shapes_dtypes_and_state(name, kwargs):
    dim = 777
    comp = build(name, kwargs, dim)
    state = comp.init_state(5)
    assert state.shape == (5, dim) and state.dtype == jnp.float32
    assert not np.asarray(state).any()
    payload, new_row = comp.encode(state[0], make_update(dim, 0))
    dec = comp.decode(payload)
    assert dec.shape == (dim,) and dec.dtype == jnp.float32
    assert new_row.shape == (dim,) and new_row.dtype == jnp.float32
    # the payload is strictly smaller than dense f32 for lossy formats
    if name != "identity":
        assert comp.payload_bytes(jax.device_get(payload)) < 4 * dim


# ------------------------------------------------------ identity exact
@settings(max_examples=12, deadline=None)
@given(dim=st.integers(1, 600), seed=st.integers(0, 2 ** 16))
def test_identity_exact_roundtrip(dim, seed):
    comp = build("identity", {}, dim)
    u = make_update(dim, seed)
    payload, residual = comp.encode(jnp.zeros((dim,), jnp.float32), u)
    np.testing.assert_array_equal(np.asarray(comp.decode(payload)),
                                  np.asarray(u))
    np.testing.assert_array_equal(np.asarray(residual), 0.0)
    # idempotent: re-encoding the decoded value round-trips bitwise
    payload2, _ = comp.encode(jnp.zeros((dim,), jnp.float32),
                              comp.decode(payload))
    np.testing.assert_array_equal(np.asarray(comp.decode(payload2)),
                                  np.asarray(u))


# ----------------------------------------------------- roundtrip error
@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2 ** 16), chunk=st.sampled_from([32, 256]))
def test_int8_roundtrip_error_bound(seed, chunk):
    """Per-chunk absmax scaling bounds the coordinate error by half a
    quantisation step: |x - dec| <= scale/2 = max|chunk| / 254."""
    dim = 1000
    comp = build("int8", {"chunk": chunk}, dim)
    u = make_update(dim, seed)
    payload, _ = comp.encode(jnp.zeros((dim,), jnp.float32), u)
    dec = np.asarray(comp.decode(payload))
    err = np.abs(np.asarray(u) - dec)
    pad = comp.padded_dim - dim
    bound = np.repeat(
        np.asarray(payload["scales"]), chunk)[:dim] * 0.5 + 1e-7
    assert (err <= bound).all(), float((err - bound).max())
    assert pad >= 0


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2 ** 16))
def test_topk_keeps_largest_and_zeroes_rest(seed):
    dim, k = 400, 20
    comp = build("topk", {"k": k}, dim)
    u = make_update(dim, seed)
    payload, residual = comp.encode(jnp.zeros((dim,), jnp.float32), u)
    dec = np.asarray(comp.decode(payload))
    assert (dec != 0).sum() <= k
    # the kept coordinates are shipped exactly, so the residual there
    # is zero and the dropped mass is exactly the dropped coordinates
    idx = np.asarray(payload["indices"])
    np.testing.assert_array_equal(dec[idx], np.asarray(u)[idx])
    np.testing.assert_array_equal(np.asarray(residual)[idx], 0.0)
    kept_min = np.abs(dec[idx]).min()
    dropped = np.delete(np.abs(np.asarray(u)), idx)
    assert dropped.max() <= kept_min + 1e-7


def test_lowrank_recovers_low_rank_signal():
    """A genuinely rank-1 update reconstructs to numerical accuracy."""
    comp = build("lowrank", {"rank": 2}, 900)
    a = jnp.sin(jnp.arange(30, dtype=jnp.float32) * 0.3)
    b = jnp.cos(jnp.arange(30, dtype=jnp.float32) * 0.7)
    u = (a[:, None] * b[None, :]).reshape(-1)
    payload, residual = comp.encode(jnp.zeros((900,), jnp.float32), u)
    np.testing.assert_allclose(np.asarray(comp.decode(payload)),
                               np.asarray(u), atol=1e-5)
    assert float(jnp.abs(residual).max()) < 1e-5


# --------------------------------------------------------- telescoping
@pytest.mark.parametrize("name,kwargs", SPECS)
def test_error_feedback_telescopes(name, kwargs):
    """sum_t decoded_t + residual_T == sum_t update_t: nothing the
    compressor drops is ever lost, it is only deferred."""
    dim, rounds = 601, 6
    comp = build(name, kwargs, dim)
    state = comp.init_state(1)[0]
    total_sent = jnp.zeros((dim,), jnp.float32)
    total_raw = jnp.zeros((dim,), jnp.float32)
    enc = jax.jit(comp.encode)
    for t in range(rounds):
        u = make_update(dim, 100 + t)
        payload, state = enc(state, u)
        total_sent = total_sent + comp.decode(payload)
        total_raw = total_raw + u
    np.testing.assert_allclose(np.asarray(total_sent + state),
                               np.asarray(total_raw), atol=1e-5)


@pytest.mark.parametrize("name,kwargs", SPECS)
def test_no_retrace_across_rounds(name, kwargs):
    """One trace serves every round: payload shapes are static in dim,
    so nothing about the round index leaks into the trace."""
    dim = 520
    comp = build(name, kwargs, dim)
    traces = {"n": 0}

    def enc(state, u):
        traces["n"] += 1
        return comp.encode(state, u)

    enc = jax.jit(enc)
    state = comp.init_state(1)[0]
    for t in range(4):
        _, state = enc(state, make_update(dim, t))
    assert traces["n"] == 1


@pytest.mark.parametrize("name,kwargs", SPECS)
def test_deterministic_and_key_free(name, kwargs):
    """FL001: encoding consumes no PRNG stream — the same input always
    produces the bitwise-same payload, with no key argument anywhere in
    the wire protocol."""
    dim = 333
    comp = build(name, kwargs, dim)
    u = make_update(dim, 9)
    s = jnp.zeros((dim,), jnp.float32)
    p1, r1 = comp.encode(s, u)
    p2, r2 = comp.encode(s, u)
    for a, b in zip(jax.tree_util.tree_leaves(p1),
                    jax.tree_util.tree_leaves(p2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    np.testing.assert_array_equal(np.asarray(r1), np.asarray(r2))


# ------------------------------------------- fused dequant_aggregate
@pytest.mark.parametrize("C,M,chunk,bm", [(4, 1024, 256, 512),
                                          (3, 512, 64, 128),
                                          (1, 256, 256, 256)])
def test_dequant_kernel_matches_ref(C, M, chunk, bm):
    w = jax.random.uniform(jax.random.PRNGKey(0), (C,))
    q = jax.random.randint(jax.random.PRNGKey(1), (C, M), -127, 128,
                           jnp.int8)
    s = jax.random.uniform(jax.random.PRNGKey(2), (C, M // chunk),
                           jnp.float32, 1e-4, 1e-2)
    ref = dequant_aggregate_ref(w, s, q, chunk)
    out = dequant_aggregate_pallas(w, s, q, chunk=chunk, block_m=bm,
                                   interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=1e-6, rtol=1e-6)


@settings(max_examples=10, deadline=None)
@given(c=st.integers(1, 6), nchunks=st.integers(1, 9),
       seed=st.integers(0, 2 ** 16))
def test_dequant_ops_pallas_route_matches_ref(c, nchunks, seed):
    """The ops padding path (M not a block multiple) stays exact."""
    chunk = 64
    M = nchunks * chunk
    w = jax.random.uniform(jax.random.PRNGKey(seed), (c,))
    q = jax.random.randint(jax.random.PRNGKey(seed + 1), (c, M),
                           -127, 128, jnp.int8)
    s = jax.random.uniform(jax.random.PRNGKey(seed + 2),
                           (c, nchunks), jnp.float32, 1e-4, 1e-2)
    ref = dequant_aggregate_ref(w, s, q, chunk)
    out = dequant_aggregate(w, s, q, chunk=chunk, impl="pallas",
                            block_m=128, interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=1e-6, rtol=1e-6)


def test_int8_aggregate_matches_decode_then_weighted_sum():
    """The fused server step is bitwise the dequantise-then-reduce
    composition it replaces (both accumulate f32 through the same
    einsum contraction)."""
    dim, C = 700, 5
    comp = COMPRESSORS.build("int8", {}, dict(dim=dim))
    states = comp.init_state(C)
    updates = jnp.stack([make_update(dim, 40 + i) for i in range(C)])
    payloads, _ = jax.vmap(comp.encode)(states, updates)
    decoded = jax.vmap(comp.decode)(payloads)
    w = jax.nn.softmax(jnp.arange(C, dtype=jnp.float32))
    fused = comp.aggregate(payloads, decoded, w, impl="naive")
    composed = weighted_aggregate(decoded, w, impl="naive")
    np.testing.assert_array_equal(np.asarray(fused),
                                  np.asarray(composed))
