"""Checkpoint round-trips."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager, load_pytree, save_pytree


def test_pytree_roundtrip(tmp_path):
    tree = {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
            "nested": {"b": jnp.ones((4,), jnp.bfloat16),
                       "c": jnp.array(7, jnp.int32)},
            "list": [jnp.zeros(2), jnp.full((1, 2), 3.0)]}
    path = str(tmp_path / "t.npz")
    save_pytree(tree, path)
    out = load_pytree(tree, path)
    for a, b in zip(jax.tree_util.tree_leaves(tree),
                    jax.tree_util.tree_leaves(out)):
        assert a.dtype == b.dtype
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32))


def test_manager_latest_and_gc(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    tree = {"w": jnp.zeros(3)}
    for step in (1, 2, 3, 4):
        mgr.save(step, jax.tree_util.tree_map(lambda x, s=step: x + s, tree))
    assert mgr.latest_step() == 4
    restored = mgr.restore(tree)
    np.testing.assert_allclose(np.asarray(restored["w"]), 4.0)
    # gc kept only the last 2
    assert mgr.latest_step() == 4
    import glob
    assert len(glob.glob(str(tmp_path / "ckpt_*.npz"))) == 2
