"""Checkpoint round-trips, manager durability and manifest guards."""
import glob

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import (CheckpointManager, check_manifest,
                              load_pytree, manifest_mismatches,
                              run_manifest, save_pytree)
from repro.core.engine import RoundState
from repro.core.scoring import ScoreState, init_scores


def test_pytree_roundtrip(tmp_path):
    tree = {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
            "nested": {"b": jnp.ones((4,), jnp.bfloat16),
                       "c": jnp.array(7, jnp.int32)},
            "list": [jnp.zeros(2), jnp.full((1, 2), 3.0)]}
    path = str(tmp_path / "t.npz")
    save_pytree(tree, path)
    out = load_pytree(tree, path)
    for a, b in zip(jax.tree_util.tree_leaves(tree),
                    jax.tree_util.tree_leaves(out)):
        assert a.dtype == b.dtype
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32))


def test_round_state_roundtrip(tmp_path):
    """The full RoundState — nested ScoreState with trust, int32
    scalars, the uint32 PRNG key — must survive save/restore exactly
    (the tentpole resume contract rests on this)."""
    n = 5
    scores = ScoreState(
        scores=jnp.linspace(0.1, 0.9, n),
        rounds_seen=jnp.asarray(11, jnp.int32),
        tester_trust=jnp.linspace(1.0, 0.2, n))
    state = RoundState(
        global_params={"dense": {"w": jnp.ones((3, 2), jnp.bfloat16),
                                 "b": jnp.zeros((2,))}},
        scores=scores,
        round_idx=jnp.asarray(7, jnp.int32),
        key=jax.random.PRNGKey(3))
    path = str(tmp_path / "state.npz")
    save_pytree(state, path)
    out = load_pytree(state, path)
    assert isinstance(out, RoundState) and isinstance(out.scores,
                                                     ScoreState)
    for a, b in zip(jax.tree_util.tree_leaves(state),
                    jax.tree_util.tree_leaves(out)):
        assert a.dtype == b.dtype
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))
    assert out.key.dtype == jnp.uint32
    assert int(out.round_idx) == 7


def test_manager_latest_and_gc(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    tree = {"w": jnp.zeros(3)}
    for step in (1, 2, 3, 4):
        mgr.save(step, jax.tree_util.tree_map(lambda x, s=step: x + s, tree))
    assert mgr.latest_step() == 4
    restored = mgr.restore(tree)
    np.testing.assert_allclose(np.asarray(restored["w"]), 4.0)
    # gc kept only the last 2
    assert mgr.latest_step() == 4
    assert len(glob.glob(str(tmp_path / "ckpt_*.npz"))) == 2


def test_manager_ignores_foreign_filenames(tmp_path):
    """Regression: a stray ``ckpt_*.npz`` whose name doesn't match the
    step pattern used to crash ``_gc``/``latest_step`` with an
    AttributeError on ``re.search(...) == None``."""
    mgr = CheckpointManager(str(tmp_path), keep=2)
    (tmp_path / "ckpt_tmp.npz").write_bytes(b"not a checkpoint")
    tree = {"w": jnp.zeros(3)}
    for step in (1, 2, 3):
        mgr.save(step, tree)     # save() runs _gc(); must not raise
    assert mgr.latest_step() == 3
    assert mgr.steps() == [2, 3]
    # the foreign file is left alone, not gc'd and not restorable
    assert (tmp_path / "ckpt_tmp.npz").exists()


def test_save_is_atomic_no_partial_files(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, {"w": jnp.arange(4.0)})
    leftovers = [f for f in glob.glob(str(tmp_path / "*"))
                 if "ckpt_00000001.npz" not in f]
    assert leftovers == []      # tmp file was replaced, not left behind


def test_restore_skips_corrupt_checkpoint(tmp_path):
    """A torn/corrupt newest checkpoint costs one cadence interval, not
    the run: restore warns and falls back to the previous step."""
    mgr = CheckpointManager(str(tmp_path), keep=3)
    tree = {"w": jnp.zeros(3)}
    mgr.save(1, jax.tree_util.tree_map(lambda x: x + 1, tree))
    mgr.save(2, jax.tree_util.tree_map(lambda x: x + 2, tree))
    (tmp_path / "ckpt_00000003.npz").write_bytes(b"torn write garbage")
    with pytest.warns(RuntimeWarning, match="skipping corrupt"):
        restored, step = mgr.restore_with_step(tree)
    assert step == 2
    np.testing.assert_allclose(np.asarray(restored["w"]), 2.0)
    # all checkpoints corrupt -> a clear error, not a crash
    (tmp_path / "ckpt_00000001.npz").write_bytes(b"x")
    (tmp_path / "ckpt_00000002.npz").write_bytes(b"x")
    with pytest.warns(RuntimeWarning):
        with pytest.raises(FileNotFoundError, match="no restorable"):
            mgr.restore(tree)


def test_restore_rejects_wrong_leaf_count(tmp_path):
    """A checkpoint from a different model refuses to load into the
    template instead of silently mis-assigning leaves."""
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, {"w": jnp.zeros(3), "b": jnp.zeros(2)})
    with pytest.raises(ValueError, match="leaves"):
        load_pytree({"w": jnp.zeros(3)}, str(tmp_path / "ckpt_00000001.npz"))
    with pytest.raises(ValueError, match="shape"):
        load_pytree({"w": jnp.zeros(4), "b": jnp.zeros(2)},
                    str(tmp_path / "ckpt_00000001.npz"))


def test_save_every_policy(tmp_path):
    mgr = CheckpointManager(str(tmp_path), save_every=3)
    tree = {"w": jnp.zeros(2)}
    saved = [s for s in range(10) if mgr.maybe_save(s, tree)]
    assert saved == [3, 6, 9]
    disabled = CheckpointManager(str(tmp_path / "off"), save_every=0)
    assert disabled.maybe_save(3, tree) is None


def test_manifest_roundtrip_and_refuse(tmp_path):
    from repro.config import FedConfig, TrainConfig
    from repro.configs import get_config
    cfg = get_config("fedtest-cnn-mnist")
    fed = FedConfig(num_users=4, num_testers=2, seed=0)
    tc = TrainConfig(optimizer="sgd", lr=0.1, schedule="constant",
                     batch_size=8, grad_clip=0.0, remat=False)
    m = run_manifest(cfg, fed, tc, use_trust=True)
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, {"w": jnp.zeros(2)}, manifest=m)
    assert manifest_mismatches(mgr.read_manifest(), m) == []
    check_manifest(mgr.read_manifest(), m)          # same run: fine
    # rounds is a run-length target, not identity: extending is allowed
    import dataclasses
    longer = run_manifest(cfg, dataclasses.replace(fed, rounds=999), tc,
                          use_trust=True)
    check_manifest(mgr.read_manifest(), longer)
    # but a different strategy config must refuse
    other = run_manifest(cfg, dataclasses.replace(fed, attack="sign_flip"),
                         tc, use_trust=True)
    with pytest.raises(ValueError, match="fed.attack"):
        check_manifest(mgr.read_manifest(), other)
    # a mismatched compressed-exchange config must refuse too: resuming
    # an int8 run with a dense trainer (or vice versa) would silently
    # drop / fabricate the error-feedback buffer (DESIGN.md §12)
    compressed = run_manifest(
        cfg, dataclasses.replace(fed, compressor="int8"), tc,
        use_trust=True)
    with pytest.raises(ValueError, match="fed.compressor"):
        check_manifest(mgr.read_manifest(), compressed)
    rechunked = run_manifest(
        cfg, dataclasses.replace(fed, compressor="int8",
                                 compressor_kwargs={"chunk": 64}),
        tc, use_trust=True)
    with pytest.raises(ValueError, match="fed.compressor_kwargs"):
        check_manifest(compressed, rechunked)
