"""Mandated per-arch smoke tests: a REDUCED variant of the same family
(<=2 layers, d_model<=512, <=4 experts) runs one forward/train step on CPU,
asserting output shapes and no NaNs."""
import jax
import jax.numpy as jnp
import pytest

from repro.config import TrainConfig, reduce_for_smoke
from repro.configs import get_config, list_configs
from repro.launch.steps import make_train_step
from repro.models import build_model
from repro.models.frontend_stub import stub_embeddings

from conftest import make_lm_batch

ARCHS = [a for a in list_configs() if a != "fedtest-cnn-mnist"]


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_shapes_and_finite(arch):
    cfg = reduce_for_smoke(get_config(arch)).replace(dtype="float32")
    model = build_model(cfg, max_target_positions=64)
    params = model.init(jax.random.PRNGKey(0))
    B, S = 2, 16
    batch = make_lm_batch(cfg, B, S)
    logits, aux = jax.jit(model.forward_train)(params, batch)
    if cfg.family in ("cnn", "mlp"):
        assert logits.shape == (B, cfg.num_classes)
    elif cfg.family == "vlm":
        assert logits.shape == (B, S + cfg.num_patches, cfg.vocab_size)
    else:
        assert logits.shape == (B, S, cfg.vocab_size)
    assert jnp.isfinite(logits.astype(jnp.float32)).all()
    assert jnp.isfinite(aux)


@pytest.mark.parametrize("arch", ARCHS)
def test_one_train_step_no_nans(arch):
    cfg = reduce_for_smoke(get_config(arch)).replace(dtype="float32")
    model = build_model(cfg, max_target_positions=64)
    params = model.init(jax.random.PRNGKey(0))
    tc = TrainConfig(optimizer="adamw", lr=1e-3, schedule="constant",
                     remat=False)
    step, opt = make_train_step(model, tc)
    opt_state = opt.init(params)
    batch = make_lm_batch(cfg, 2, 16)
    new_params, opt_state, metrics = jax.jit(step)(params, opt_state, batch)
    assert jnp.isfinite(metrics["loss"])
    for leaf in jax.tree_util.tree_leaves(new_params):
        assert jnp.isfinite(leaf.astype(jnp.float32)).all()
    # params actually moved
    moved = any(
        float(jnp.abs(a.astype(jnp.float32)
                      - b.astype(jnp.float32)).max()) > 0
        for a, b in zip(jax.tree_util.tree_leaves(params),
                        jax.tree_util.tree_leaves(new_params)))
    assert moved


@pytest.mark.parametrize("arch", ["qwen3-1.7b", "mamba2-2.7b",
                                  "jamba-1.5-large-398b",
                                  "granite-moe-1b-a400m"])
def test_loss_decreases_under_training(arch):
    cfg = reduce_for_smoke(get_config(arch)).replace(dtype="float32")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    tc = TrainConfig(optimizer="adamw", lr=3e-3, schedule="constant",
                     remat=False)
    step, opt = make_train_step(model, tc)
    opt_state = opt.init(params)
    batch = make_lm_batch(cfg, 2, 16)   # fixed batch: memorise it
    jstep = jax.jit(step)
    losses = []
    for _ in range(8):
        params, opt_state, metrics = jstep(params, opt_state, batch)
        losses.append(float(metrics["loss"]))
    assert losses[-1] < losses[0] * 0.9, losses


def test_remat_matches_no_remat():
    cfg = reduce_for_smoke(get_config("qwen3-1.7b")).replace(dtype="float32")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = make_lm_batch(cfg, 2, 16)
    l1, _ = model.loss(params, batch, remat=False)
    l2, _ = model.loss(params, batch, remat=True)
    assert abs(float(l1) - float(l2)) < 1e-5
