"""Mamba2 SSD kernel: chunked scan vs the sequential oracle, plus the
decode recurrence hand-off."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.ssd_scan.kernel import ssd_scan_pallas
from repro.kernels.ssd_scan.ops import _ssd_xla
from repro.kernels.ssd_scan.ref import ssd_decode_ref, ssd_ref

SHAPES = [
    # (Bt, S, H, P, G, N)
    (1, 64, 2, 16, 1, 8),
    (2, 128, 4, 32, 2, 16),
    (1, 96, 6, 16, 3, 8),     # H/G = 2, S not a power of two
]


def _inputs(shape, key=0):
    Bt, S, H, P, G, N = shape
    ks = jax.random.split(jax.random.PRNGKey(key), 6)
    x = jax.random.normal(ks[0], (Bt, S, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (Bt, S, H)))
    A = -jnp.exp(jax.random.normal(ks[2], (H,)))
    B = jax.random.normal(ks[3], (Bt, S, G, N))
    C = jax.random.normal(ks[4], (Bt, S, G, N))
    D = jax.random.normal(ks[5], (H,))
    return x, dt, A, B, C, D


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("chunk", [16, 32, 64])
def test_pallas_ssd_matches_sequential(shape, chunk):
    if shape[1] % chunk:
        pytest.skip("chunk must divide S for the Pallas grid")
    x, dt, A, B, C, D = _inputs(shape)
    yr, hr = ssd_ref(x, dt, A, B, C, D)
    yp, hp = ssd_scan_pallas(x, dt, A, B, C, D, chunk=chunk, interpret=True)
    np.testing.assert_allclose(np.asarray(yp), np.asarray(yr), atol=1e-3,
                               rtol=1e-3)
    np.testing.assert_allclose(np.asarray(hp), np.asarray(hr), atol=1e-3,
                               rtol=1e-3)


@pytest.mark.parametrize("shape", SHAPES)
def test_xla_chunked_matches_sequential(shape):
    x, dt, A, B, C, D = _inputs(shape, key=1)
    yr, hr = ssd_ref(x, dt, A, B, C, D)
    yx, hx = _ssd_xla(x, dt, A, B, C, D, chunk=32)
    np.testing.assert_allclose(np.asarray(yx), np.asarray(yr), atol=1e-3,
                               rtol=1e-3)
    np.testing.assert_allclose(np.asarray(hx), np.asarray(hr), atol=1e-3,
                               rtol=1e-3)


def test_bf16_inputs():
    shape = (1, 64, 2, 16, 1, 8)
    x, dt, A, B, C, D = _inputs(shape, key=2)
    yr, _ = ssd_ref(x, dt, A, B, C, D)
    yp, _ = ssd_scan_pallas(x.astype(jnp.bfloat16), dt, A,
                            B.astype(jnp.bfloat16),
                            C.astype(jnp.bfloat16), D, chunk=32,
                            interpret=True)
    np.testing.assert_allclose(np.asarray(yp, np.float32), np.asarray(yr),
                               atol=0.15, rtol=0.15)


def test_decode_recurrence_continues_scan():
    """State from the chunked scan feeds the decode step exactly."""
    shape = (2, 64, 4, 16, 2, 8)
    Bt, S, H, P, G, N = shape
    x, dt, A, B, C, D = _inputs(shape, key=3)
    y_all, h_all = ssd_ref(x, dt, A, B, C, D)
    # scan the first S-1 steps, then decode step S-1
    y_pre, h_pre = _ssd_xla(x[:, :S - 1], dt[:, :S - 1], A, B[:, :S - 1],
                            C[:, :S - 1], D, chunk=21)
    y_dec, h_dec = ssd_decode_ref(x[:, -1], dt[:, -1], A, B[:, -1],
                                  C[:, -1], D, h_pre)
    np.testing.assert_allclose(np.asarray(y_dec), np.asarray(y_all[:, -1]),
                               atol=1e-3, rtol=1e-3)
    np.testing.assert_allclose(np.asarray(h_dec), np.asarray(h_all),
                               atol=1e-3, rtol=1e-3)
