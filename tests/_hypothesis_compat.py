"""Fallback for the optional ``hypothesis`` test dependency.

``hypothesis`` is listed as an optional extra (requirements.txt); when it
is absent the property tests still run against a deterministic sample of
each strategy's domain instead of erroring at collection. Import from
here instead of from ``hypothesis`` directly::

    from _hypothesis_compat import given, settings, st

The fallback implements just the strategy surface this suite uses
(``floats``, ``integers``, ``sampled_from``, ``lists``); real hypothesis
is preferred automatically when installed.
"""
from __future__ import annotations

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

    import random

    class _Strategy:
        def __init__(self, sample):
            self._sample = sample

        def example(self, rng: random.Random):
            return self._sample(rng)

    class _St:
        """Deterministic stand-ins for the strategies the suite uses."""

        @staticmethod
        def floats(min_value=0.0, max_value=1.0):
            lo, hi = float(min_value), float(max_value)

            def sample(rng):
                # bias toward the boundaries, where the bugs live
                r = rng.random()
                if r < 0.15:
                    return lo
                if r < 0.3:
                    return hi
                return lo + (hi - lo) * rng.random()
            return _Strategy(sample)

        @staticmethod
        def integers(min_value, max_value):
            lo, hi = int(min_value), int(max_value)

            def sample(rng):
                r = rng.random()
                if r < 0.15:
                    return lo
                if r < 0.3:
                    return hi
                return rng.randint(lo, hi)
            return _Strategy(sample)

        @staticmethod
        def sampled_from(options):
            options = list(options)
            return _Strategy(lambda rng: rng.choice(options))

        @staticmethod
        def lists(elements, min_size=0, max_size=10):
            def sample(rng):
                n = rng.randint(min_size, max_size)
                return [elements.example(rng) for _ in range(n)]
            return _Strategy(sample)

    st = _St()

    def settings(max_examples: int = 20, **_ignored):
        """Records the example budget for the paired ``@given``."""
        def deco(fn):
            fn._max_examples = max_examples
            return fn
        return deco

    def given(**strategies):
        def deco(fn):
            # no functools.wraps: pytest must see run's (empty) signature,
            # not the strategy params, or it hunts for fixtures
            def run(*args, **kwargs):
                # @settings sits above @given, so it stamps `run`
                n = getattr(run, "_max_examples", 20)
                rng = random.Random(f"{fn.__module__}.{fn.__name__}")
                for _ in range(min(n, 20)):
                    drawn = {k: s.example(rng)
                             for k, s in strategies.items()}
                    fn(*args, **drawn, **kwargs)
            run.__name__ = fn.__name__
            run.__doc__ = fn.__doc__
            run.__module__ = fn.__module__
            return run
        return deco
