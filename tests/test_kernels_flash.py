"""Pallas flash-attention kernel: shape/dtype sweep vs the pure-jnp oracle
(interpret=True executes the kernel body on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention.kernel import flash_attention_pallas
from repro.kernels.flash_attention.ops import attention_xla, flash_attention
from repro.kernels.flash_attention.ref import attention_ref

SHAPES = [
    # (B, S, T, Hq, Hkv, D)
    (1, 128, 128, 4, 4, 32),     # MHA
    (2, 128, 128, 8, 2, 64),     # GQA 4x
    (1, 256, 256, 4, 1, 64),     # MQA
    (2, 64, 256, 4, 4, 32),      # cross-shaped (q shorter than kv)
]


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_pallas_matches_ref(shape, causal, dtype):
    B, S, T, Hq, Hkv, D = shape
    if causal and S != T:
        pytest.skip("causal requires S == T here")
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, S, Hq, D), jnp.float32).astype(dtype)
    k = jax.random.normal(ks[1], (B, T, Hkv, D), jnp.float32).astype(dtype)
    v = jax.random.normal(ks[2], (B, T, Hkv, D), jnp.float32).astype(dtype)
    ref = attention_ref(q.astype(jnp.float32), k.astype(jnp.float32),
                        v.astype(jnp.float32), causal=causal)
    out = flash_attention_pallas(q, k, v, causal=causal, block_q=64,
                                 block_k=64, interpret=True)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(out, np.float32), np.asarray(ref),
                               atol=tol, rtol=tol)


@pytest.mark.parametrize("window", [16, 64, 100])
def test_pallas_sliding_window(window):
    B, S, H, D = 2, 128, 4, 32
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(ks[0], (B, S, H, D))
    k = jax.random.normal(ks[1], (B, S, H, D))
    v = jax.random.normal(ks[2], (B, S, H, D))
    ref = attention_ref(q, k, v, causal=True, sliding_window=window)
    out = flash_attention_pallas(q, k, v, causal=True,
                                 sliding_window=window, block_q=32,
                                 block_k=32, interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5,
                               rtol=2e-5)


@pytest.mark.parametrize("block", [32, 64, 128])
def test_xla_blockwise_block_invariance(block):
    """The online-softmax result must not depend on the blocking."""
    B, S, H, D = 1, 128, 2, 32
    ks = jax.random.split(jax.random.PRNGKey(2), 3)
    q = jax.random.normal(ks[0], (B, S, H, D))
    k = jax.random.normal(ks[1], (B, S, H, D))
    v = jax.random.normal(ks[2], (B, S, H, D))
    ref = attention_ref(q, k, v, causal=True)
    out = attention_xla(q, k, v, causal=True, block_q=block, block_k=block)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5,
                               rtol=2e-5)


def test_q_offset_decode_chunk():
    """q_offset positions a query chunk inside a longer KV (chunked prefill)."""
    B, S, T, H, D = 1, 32, 128, 2, 16
    ks = jax.random.split(jax.random.PRNGKey(3), 3)
    k = jax.random.normal(ks[1], (B, T, H, D))
    v = jax.random.normal(ks[2], (B, T, H, D))
    qfull = jax.random.normal(ks[0], (B, T, H, D))
    full = attention_ref(qfull, k, v, causal=True)
    out = attention_xla(qfull[:, -S:], k, v, causal=True, q_offset=T - S,
                        block_q=16, block_k=32)
    np.testing.assert_allclose(np.asarray(out), np.asarray(full[:, -S:]),
                               atol=2e-5, rtol=2e-5)


def test_unroll_is_numerically_identical():
    B, S, H, D = 1, 64, 2, 16
    ks = jax.random.split(jax.random.PRNGKey(4), 3)
    q = jax.random.normal(ks[0], (B, S, H, D))
    k = jax.random.normal(ks[1], (B, S, H, D))
    v = jax.random.normal(ks[2], (B, S, H, D))
    a = attention_xla(q, k, v, causal=True, block_q=16, block_k=16)
    b = attention_xla(q, k, v, causal=True, block_q=16, block_k=16,
                      unroll=True)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-5)
