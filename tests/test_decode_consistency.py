"""Serving correctness: prefill + single-token decode must match the
teacher-forced forward for every family (dropless MoE routing)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import reduce_for_smoke
from repro.configs import get_config
from repro.models import build_model
from repro.models.frontend_stub import stub_embeddings

FAMS = ["qwen3-1.7b", "qwen2-0.5b", "qwen3-moe-30b-a3b",
        "granite-moe-1b-a400m", "mamba2-2.7b", "jamba-1.5-large-398b",
        "pixtral-12b", "whisper-base"]


@pytest.mark.parametrize("arch", FAMS)
def test_decode_matches_teacher_forced(arch):
    cfg = reduce_for_smoke(get_config(arch)).replace(dtype="float32")
    m = build_model(cfg, max_target_positions=64, attn_impl="naive",
                    moe_dropless=True)
    p = m.init(jax.random.PRNGKey(0))
    B, S = 2, 16
    off = cfg.num_patches if cfg.family == "vlm" else 0
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S + 2), 0,
                              cfg.vocab_size)
    extra = {}
    if cfg.family == "vlm":
        extra["patches"] = stub_embeddings(cfg, B, jax.random.PRNGKey(3),
                                           dtype=jnp.float32)
    if cfg.family == "encdec":
        extra["frames"] = stub_embeddings(cfg, B, jax.random.PRNGKey(3),
                                          dtype=jnp.float32)

    lg_full, _ = m.forward_train(p, {"tokens": toks, **extra})
    _, cache = m.prefill(p, {"tokens": toks[:, :S], **extra},
                         cache_len=off + S + 4)
    # two consecutive decode steps
    lg1, cache = m.decode_step(p, cache, toks[:, S:S + 1])
    lg2, cache = m.decode_step(p, cache, toks[:, S + 1:S + 2])
    # logits at position i predict token i+1: decode of toks[:, S] matches
    # teacher-forced position off+S, the next one off+S+1.
    err1 = np.abs(np.asarray(lg_full[:, off + S])
                  - np.asarray(lg1[:, 0])).max()
    err2 = np.abs(np.asarray(lg_full[:, off + S + 1])
                  - np.asarray(lg2[:, 0])).max()
    assert err1 < 3e-4, (arch, err1)
    assert err2 < 3e-4, (arch, err2)
    assert int(cache["length"][0]) == off + S + 2


def test_sliding_window_decode_consistency():
    """Dense arch with the long-context SWA variant: decode must equal the
    teacher-forced SWA forward."""
    cfg = reduce_for_smoke(get_config("qwen2-0.5b")).replace(dtype="float32")
    m = build_model(cfg, attn_impl="naive", sliding_window=8)
    p = m.init(jax.random.PRNGKey(0))
    B, S = 2, 24
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S + 1), 0,
                              cfg.vocab_size)
    lg_full, _ = m.forward_train(p, {"tokens": toks})
    _, cache = m.prefill(p, {"tokens": toks[:, :S]}, cache_len=S + 2)
    lg1, _ = m.decode_step(p, cache, toks[:, S:S + 1])
    err = np.abs(np.asarray(lg_full[:, S]) - np.asarray(lg1[:, 0])).max()
    assert err < 3e-4, err
