"""Cross-testing fast path (DESIGN.md §10): the batched dispatch model
must be **bitwise identical** to the per-client reference loop.

Three layers of pinning:

* matrix level — ``cross_test_accuracies(impl='batched')`` equals
  ``impl='reference'`` bit-for-bit on {mlp, cnn, decoder} stacked
  params under jit;
* engine level — a full :class:`FederatedTrainer` trajectory (weights,
  scores, malicious weight) is invariant to ``crosstest_impl`` at
  participation 1.0 *and* 0.75 — the sampled-subset rows exercise the
  frozen-score (``client_mask``) and masked-tester-row (``row_mask``)
  paths through the identical matrix;
* property level — accuracies live in [0, 1]; permuting the tester
  order permutes matrix rows without moving the combined scores; a
  fully-masked tester row never moves scores no matter what it
  contains; and the eval-batch cache is bit-insensitive to hit/miss
  (cold cache == warm cache == in-trace derivation).

The pod backends (ring hop overlap, allgather vmap) are pinned by the
``crosstest_impl`` axis of ``tests/test_pod_parity.py``.
"""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st
from repro.config import FedConfig, TrainConfig, reduce_for_smoke
from repro.configs import get_config
from repro.core import FederatedTrainer
from repro.core.cross_testing import (CROSSTEST_IMPLS, EvalBatchCache,
                                      cross_test_accuracies,
                                      make_eval_fn, sampled_eval_batches)
from repro.core.scoring import (combine_tester_reports, init_scores,
                                update_scores)
from repro.data import MNIST_LIKE, make_federated_image_dataset
from repro.models import build_model

K, N = 3, 4


@functools.lru_cache(maxsize=None)
def _case(arch):
    """(eval_fn, stacked_params [N,...], tx [K,B,...], ty) for one arch."""
    if arch == "decoder":
        cfg = reduce_for_smoke(get_config("qwen2-0.5b")).replace(
            dtype="float32")
        model = build_model(cfg)
        B, S = 2, 16
        tx = jax.random.randint(jax.random.PRNGKey(1), (K, B, S), 0,
                                cfg.vocab_size)
        # -1 labels exercise the valid-token mask in the LM eval
        ty = jax.random.randint(jax.random.PRNGKey(2), (K, B, S), -1,
                                cfg.vocab_size)
    else:
        arch_id = ("fedtest-mlp-mnist" if arch == "mlp"
                   else "fedtest-cnn-mnist")
        cfg = get_config(arch_id)
        cfg = (cfg.replace(mlp_hidden=(32, 32)) if arch == "mlp"
               else cfg.replace(cnn_channels=(4, 8), cnn_hidden=16))
        model = build_model(cfg)
        tx = jax.random.normal(
            jax.random.PRNGKey(1),
            (K, 16, cfg.image_size, cfg.image_size, cfg.image_channels))
        ty = jax.random.randint(jax.random.PRNGKey(2), (K, 16), 0,
                                cfg.num_classes)
    stacked = jax.vmap(model.init)(jax.random.split(jax.random.PRNGKey(0),
                                                    N))
    return make_eval_fn(model), stacked, tx, ty


# ------------------------------------------------------ matrix-level parity
@pytest.mark.parametrize("arch", ["mlp", "cnn", "decoder"])
def test_batched_matches_reference_bitwise(arch):
    eval_fn, stacked, tx, ty = _case(arch)
    mats = {}
    for impl in CROSSTEST_IMPLS:
        fn = jax.jit(lambda s, x, y, _i=impl: cross_test_accuracies(
            eval_fn, s, x, y, impl=_i))
        mats[impl] = np.asarray(fn(stacked, tx, ty))
        assert mats[impl].shape == (K, N), (arch, impl)
        assert np.all(mats[impl] >= 0.0) and np.all(mats[impl] <= 1.0)
    np.testing.assert_array_equal(mats["batched"], mats["reference"],
                                  err_msg=f"{arch}: fast path moved a bit")


def test_unknown_impl_rejected():
    eval_fn, stacked, tx, ty = _case("mlp")
    with pytest.raises(ValueError, match="crosstest impl"):
        cross_test_accuracies(eval_fn, stacked, tx, ty, impl="fused")


# ------------------------------------------------------ engine-level parity
@pytest.mark.parametrize("participation", [1.0, 0.75])
def test_trainer_trajectory_invariant_to_impl(participation):
    """Full local-backend trajectories must not depend on the dispatch
    model — at participation 0.75 the K=3 committee hits rounds where a
    selected tester is sampled out (row_mask) and non-participants'
    scores freeze (client_mask), all through the same [K, N] matrix."""
    cfg = get_config("fedtest-mlp-mnist").replace(mlp_hidden=(32,))
    model = build_model(cfg)
    tc = TrainConfig(optimizer="sgd", lr=0.1, schedule="constant",
                     batch_size=8, grad_clip=0.0, remat=False)
    data = make_federated_image_dataset(MNIST_LIKE, N, num_samples=800,
                                        global_test=128, seed=0)
    trajs = {}
    for impl in CROSSTEST_IMPLS:
        fed = FedConfig(num_users=N, num_testers=K, num_malicious=1,
                        attack="sign_flip", attack_scale=4.0,
                        participation=participation, local_steps=4,
                        crosstest_impl=impl, seed=0)
        trainer = FederatedTrainer(model, fed, tc, eval_batch=32)
        state = trainer.init(jax.random.PRNGKey(0))
        traj = []
        for _ in range(3):
            state, m = trainer.run_round(state, data)
            traj.append((np.asarray(m["weights"]),
                         np.asarray(m["scores"]),
                         np.asarray(m["malicious_weight"])))
        trajs[impl] = (traj, state)
    for r, (b, ref) in enumerate(zip(trajs["batched"][0],
                                     trajs["reference"][0])):
        for name, x, y in zip(("weights", "scores", "mal_w"), b, ref):
            np.testing.assert_array_equal(
                x, y, err_msg=f"{name} diverged at round {r} "
                              f"(participation={participation})")
    for la, lb in zip(jax.tree_util.tree_leaves(
                          trajs["batched"][1].global_params),
                      jax.tree_util.tree_leaves(
                          trajs["reference"][1].global_params)):
        np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))


# ---------------------------------------------------------- property tests
accs = st.lists(st.floats(0.0, 1.0), min_size=N, max_size=N)


@settings(max_examples=40, deadline=None)
@given(seed=st.integers(0, 2 ** 16))
def test_accuracies_bounded(seed):
    eval_fn, stacked, tx, ty = _case("mlp")
    k = jax.random.PRNGKey(seed)
    tx = tx + jax.random.normal(k, tx.shape)    # arbitrary inputs
    mat = np.asarray(cross_test_accuracies(eval_fn, stacked, tx, ty))
    assert np.all(mat >= 0.0) and np.all(mat <= 1.0)
    assert np.all(np.isfinite(mat))


@settings(max_examples=60, deadline=None)
@given(rows=st.lists(accs, min_size=K, max_size=K),
       seed=st.integers(0, 2 ** 16))
def test_tester_permutation_permutes_rows_only(rows, seed):
    """Reordering the testers permutes matrix rows; the combined score
    (a tester-mean) must not move."""
    mat = jnp.asarray(rows)                         # [K, N]
    perm = np.asarray(jax.random.permutation(jax.random.PRNGKey(seed), K))
    tester_ids = jnp.arange(K)
    base = combine_tester_reports(mat, tester_ids)
    shuf = combine_tester_reports(mat[perm], tester_ids[perm])
    np.testing.assert_allclose(np.asarray(shuf), np.asarray(base),
                               atol=1e-6)
    np.testing.assert_array_equal(np.asarray(mat[perm])[0],
                                  np.asarray(mat)[perm[0]])


@settings(max_examples=60, deadline=None)
@given(rows=st.lists(accs, min_size=K, max_size=K),
       garbage=st.floats(0.0, 1.0), row=st.integers(0, K - 1))
def test_fully_masked_tester_row_never_moves_scores(rows, garbage, row):
    """A tester whose row is masked out (non-reporting: sampled out or
    dropped) must not influence scores regardless of what its row says."""
    mat = jnp.asarray(rows)
    row_mask = jnp.ones((K,)).at[row].set(0.0)
    poisoned = mat.at[row].set(garbage)
    kw = dict(tester_ids=jnp.arange(K), row_mask=row_mask)
    s0 = update_scores(init_scores(N), mat, **kw)
    s1 = update_scores(init_scores(N), poisoned, **kw)
    np.testing.assert_array_equal(np.asarray(s0.scores),
                                  np.asarray(s1.scores))


_sampled = jax.jit(sampled_eval_batches, static_argnums=(2, 4))


@settings(max_examples=20, deadline=None)
@given(resample_every=st.integers(1, 4), seed=st.integers(0, 2 ** 16))
def test_eval_batch_cache_hit_miss_insensitive(resample_every, seed):
    """Cold cache, warm cache and the in-trace derivation must agree
    bitwise for every round — the cache key is the schedule bucket, the
    indices are always re-derived from the run key (FL001)."""
    data = make_federated_image_dataset(MNIST_LIKE, N, num_samples=400,
                                        global_test=64, seed=0)
    run_key = jax.random.PRNGKey(seed)
    warm = EvalBatchCache(resample_every)
    for r in range(6):
        cold = EvalBatchCache(resample_every)        # every call a miss
        cx, cy = cold.get(run_key, data.test, 8, r)
        wx, wy = warm.get(run_key, data.test, 8, r)
        sx, sy = _sampled(run_key, data.test, 8, r, resample_every)
        np.testing.assert_array_equal(np.asarray(cx), np.asarray(wx))
        np.testing.assert_array_equal(np.asarray(cy), np.asarray(wy))
        np.testing.assert_array_equal(np.asarray(wx), np.asarray(sx))
        np.testing.assert_array_equal(np.asarray(wy), np.asarray(sy))
    assert warm.misses == -(-6 // resample_every)   # one per bucket
    assert warm.hits + warm.misses == 6
