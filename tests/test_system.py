"""End-to-end behaviour tests for the FedTest system (the paper's claims
at test scale):

1. With random-weight adversaries, FedTest beats FedAvg clearly (Fig. 4/5).
2. The MoE layer conserves token mass (capacity == dropless at high cf).
3. The serving path generates on the synthetic bigram language after
   federated training (full-stack train -> serve check).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import FedConfig, TrainConfig, reduce_for_smoke
from repro.configs import get_config
from repro.core import FederatedTrainer
from repro.data import MNIST_LIKE, make_federated_image_dataset
from repro.models import build_model
from repro.models.moe import moe_apply, moe_init


@pytest.mark.slow
def test_fedtest_beats_fedavg_under_attack():
    cfg = get_config("fedtest-cnn-mnist").replace(cnn_channels=(8, 16, 16),
                                                  cnn_hidden=32)
    model = build_model(cfg)
    # milder skew than the default paper partition (>= 8 of 10 classes per
    # client) + 3 testers: with near-single-class shards the cross-testing
    # matrix is degenerate and no scoring can separate honest clients from
    # random-weights attackers (ROADMAP-diagnosed seed failure).
    data = make_federated_image_dataset(
        MNIST_LIKE, 6, num_samples=2400, global_test=400, seed=0,
        partition_kwargs={"min_classes": 8, "max_classes": 10})
    tc = TrainConfig(optimizer="sgd", lr=0.1, schedule="constant",
                     batch_size=16, grad_clip=0.0, remat=False)
    accs = {}
    for agg in ("fedtest", "fedavg"):
        fed = FedConfig(num_users=6, num_testers=3, num_malicious=2,
                        local_steps=10, attack="random_weights",
                        attack_scale=4.0, aggregator=agg)
        trainer = FederatedTrainer(model, fed, tc, eval_batch=64)
        _, hist = trainer.run(jax.random.PRNGKey(0), data, rounds=5)
        accs[agg] = hist["global_accuracy"][-1]
    assert accs["fedtest"] > accs["fedavg"] + 0.1, accs


def test_moe_capacity_equals_dropless_when_capacity_is_ample():
    cfg = reduce_for_smoke(get_config("granite-moe-1b-a400m")).replace(
        dtype="float32")
    p = moe_init(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model))
    y_cap, _ = moe_apply(p, cfg, x, group_size=8, capacity_factor=100.0)
    y_free, _ = moe_apply(p, cfg, x, dropless=True)
    np.testing.assert_allclose(np.asarray(y_cap), np.asarray(y_free),
                               atol=2e-4, rtol=2e-4)


def test_moe_capacity_drops_reduce_output_norm():
    cfg = reduce_for_smoke(get_config("granite-moe-1b-a400m")).replace(
        dtype="float32")
    p = moe_init(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 64, cfg.d_model))
    y_tight, _ = moe_apply(p, cfg, x, group_size=64, capacity_factor=0.25)
    y_ample, _ = moe_apply(p, cfg, x, group_size=64, capacity_factor=100.0)
    # tokens dropped under tight capacity -> strictly less FFN mass
    assert (np.linalg.norm(np.asarray(y_tight))
            < np.linalg.norm(np.asarray(y_ample)))


@pytest.mark.slow
def test_train_then_serve_full_stack():
    """Train a tiny dense LM on the synthetic bigram stream federatedly,
    then check the serving path on the trained weights."""
    from repro.launch.train import make_lm_federated_dataset
    cfg = reduce_for_smoke(get_config("qwen2-0.5b")).replace(
        dtype="float32", vocab_size=97)
    model = build_model(cfg)
    data = make_lm_federated_dataset(97, 4, seq_len=32, seqs_per_user=48,
                                     seed=0)
    fed = FedConfig(num_users=4, num_testers=2, num_malicious=0,
                    local_steps=12)
    tc = TrainConfig(optimizer="adamw", lr=3e-3, schedule="constant",
                     batch_size=16, grad_clip=1.0, remat=False)
    trainer = FederatedTrainer(model, fed, tc, eval_batch=32)
    state, hist = trainer.run(jax.random.PRNGKey(0), data, rounds=6)
    assert hist["global_accuracy"][-1] > 0.15   # >> 1/97 chance

    # serve: prefill a training prefix and decode one token
    toks = data.global_x[:2, :16]
    _, cache = model.prefill(state.global_params, {"tokens": toks},
                             cache_len=24)
    lg, cache = model.decode_step(state.global_params, cache,
                                  data.global_x[:2, 16:17])
    assert lg.shape == (2, 1, 97)
    assert int(cache["length"][0]) == 17
