"""Optimizers + schedules."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import TrainConfig
from repro.optim import make_optimizer, make_schedule


@pytest.mark.parametrize("name", ["sgd", "momentum", "adam", "adamw"])
def test_optimizer_minimises_quadratic(name):
    cfg = TrainConfig(optimizer=name, lr=0.1, schedule="constant",
                      weight_decay=0.0, grad_clip=0.0)
    opt = make_optimizer(cfg)
    params = {"w": jnp.array([3.0, -2.0])}
    state = opt.init(params)

    def loss(p):
        return jnp.sum(p["w"] ** 2)

    for _ in range(150):
        grads = jax.grad(loss)(params)
        params, state = opt.update(grads, state, params)
    assert float(loss(params)) < 1e-2


def test_grad_clip_bounds_update():
    cfg = TrainConfig(optimizer="sgd", lr=1.0, schedule="constant",
                      grad_clip=1.0)
    opt = make_optimizer(cfg)
    params = {"w": jnp.zeros(4)}
    state = opt.init(params)
    grads = {"w": jnp.full(4, 100.0)}
    new, _ = opt.update(grads, state, params)
    assert float(jnp.linalg.norm(new["w"])) <= 1.0 + 1e-5


def test_weight_decay_shrinks_params():
    base = TrainConfig(optimizer="adam", lr=0.01, schedule="constant",
                       grad_clip=0.0)
    wd = TrainConfig(optimizer="adamw", lr=0.01, weight_decay=0.5,
                     schedule="constant", grad_clip=0.0)
    p0 = {"w": jnp.full(3, 5.0)}
    grads = {"w": jnp.zeros(3)}
    for cfg, expect_shrink in [(base, False), (wd, True)]:
        opt = make_optimizer(cfg)
        p, s = p0, opt.init(p0)
        p, s = opt.update(grads, s, p)
        if expect_shrink:
            assert float(p["w"][0]) < 5.0
        else:
            np.testing.assert_allclose(np.asarray(p["w"]), 5.0, atol=1e-6)


def test_cosine_schedule_endpoints():
    cfg = TrainConfig(lr=1.0, schedule="cosine", total_steps=100)
    sched = make_schedule(cfg)
    assert abs(float(sched(0)) - 1.0) < 1e-6
    assert float(sched(100)) < 1e-6
    assert 0.4 < float(sched(50)) < 0.6


def test_warmup_cosine():
    cfg = TrainConfig(lr=1.0, schedule="linear_warmup_cosine",
                      warmup_steps=10, total_steps=100)
    sched = make_schedule(cfg)
    assert float(sched(0)) == 0.0
    assert float(sched(5)) < float(sched(10))
