"""Docs-consistency gate: every cross-reference in the tree resolves.

The repo's docstrings promise sections of DESIGN.md / EXPERIMENTS.md;
this runs ``tools/check_docs.py`` (the same script CI runs) so a renamed
heading or a reference to a section that never got written fails tier-1.
"""
import os
import subprocess
import sys

REPO = os.path.join(os.path.dirname(__file__), "..")


def test_no_dangling_doc_references():
    proc = subprocess.run(
        [sys.executable, os.path.join(REPO, "tools", "check_docs.py")],
        capture_output=True, text=True, timeout=60)
    assert proc.returncode == 0, proc.stdout + proc.stderr


def test_design_and_experiments_exist_with_cited_sections():
    """The sections the code cites by name must exist (smoke-level guard
    independent of the checker's regexes)."""
    design = open(os.path.join(REPO, "DESIGN.md")).read()
    exps = open(os.path.join(REPO, "EXPERIMENTS.md")).read()
    for tok in ("§2", "§3", "§5"):
        assert any(line.lstrip().startswith("#") and tok in line
                   for line in design.splitlines()), tok
    for tok in ("§Perf", "§Roofline", "§Dry-run", "§Paper-validation",
                "§Scenarios"):
        assert any(line.lstrip().startswith("#") and tok in line
                   for line in exps.splitlines()), tok
