"""Three-backend equivalence matrix for the unified round engine.

The tentpole contract of ``repro.core.engine`` (DESIGN.md §2 and §3): the
``local`` (vmap), ``ring`` and ``allgather`` (shard_map) exchange
backends drive one shared ``RoundProgram``, so replaying the same key
schedule across {no_attack, sign_flip, adaptive_scale} x
{participation 1.0, 0.75} — plus the coalition scenarios
{mutual_boost, sybil_split} x {participation 1.0, 0.75}
(DESIGN.md §7: the report transform runs on the replicated matrix, the
sybil split through the composed attack seam) and the availability
faults {dropout, straggler_deadline} (DESIGN.md §9: the survival mask
is derived from ``keys.fault`` inside the program) — must produce
**bit-identical** weights, scores and malicious-weight trajectories on
all three — the backends exchange models differently but score the
identical replicated accuracy matrix through identical code.

The pod rounds run in a subprocess (device-count flag) and replay the
single-host driver's exact per-round schedule: base key
``fold_in(state.key, round)``, the ``round_keys`` bundle derived from
it, batches sampled host-side from ``keys.batch``; tester ids and the
participation mask are derived *inside* the round by the program
itself, so nothing topology-side can drift.
"""
import json
import os
import subprocess
import sys

import numpy as np
import pytest

ROUNDS = 4
# (attack, participation, coalition, selector, fault, crosstest_impl):
# coalition scenarios run the mutual_boost report transform /
# sybil_split composed model attack with 2 of the 4 clients coordinated
# (attack "none" isolates the coalition machinery; the members still
# count as malicious); the score_weighted / coverage cases pin the
# scores= threading into Selector.select across backends (DESIGN.md §4);
# the fault rows pin the availability mask (DESIGN.md §9) — it is
# composed inside the shared program from keys.fault, so dropped clients
# must zero out identically on every exchange topology. The
# crosstest_impl axis (DESIGN.md §10) runs the same matrix through the
# batched fast path (the shipped default) and keeps "reference" rows so
# the serial dispatch schedule stays pinned across backends too — the
# batched == reference comparison itself is asserted below on the rows
# that differ only in impl.
CASES = [("none", 1.0, "none", "rotating", "none", "batched"),
         ("none", 0.75, "none", "rotating", "none", "batched"),
         ("sign_flip", 1.0, "none", "rotating", "none", "batched"),
         ("sign_flip", 0.75, "none", "rotating", "none", "batched"),
         ("adaptive_scale", 1.0, "none", "rotating", "none", "batched"),
         ("adaptive_scale", 0.75, "none", "rotating", "none", "batched"),
         ("none", 1.0, "mutual_boost", "rotating", "none", "batched"),
         ("none", 0.75, "mutual_boost", "rotating", "none", "batched"),
         ("none", 1.0, "sybil_split", "rotating", "none", "batched"),
         ("none", 0.75, "sybil_split", "rotating", "none", "batched"),
         ("none", 1.0, "mutual_boost", "score_weighted", "none",
          "batched"),
         ("none", 0.75, "none", "coverage", "none", "batched"),
         ("none", 1.0, "none", "rotating", "dropout", "batched"),
         ("sign_flip", 0.75, "none", "rotating", "dropout", "batched"),
         ("none", 1.0, "none", "rotating", "straggler_deadline",
          "batched"),
         ("none", 1.0, "none", "rotating", "none", "reference"),
         ("sign_flip", 0.75, "none", "rotating", "none", "reference"),
         ("none", 1.0, "none", "rotating", "dropout", "reference")]

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import json
import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import Mesh

from repro.config import FedConfig, TrainConfig
from repro.configs import get_config
from repro.core import FederatedTrainer
from repro.core.engine import (
    compose_fault_mask, make_allgather_round, make_distributed_round,
    participation_mask, resolve_fault, round_keys)
from repro.core.scoring import init_scores
from repro.data import MNIST_LIKE, make_federated_image_dataset, \
    sample_client_batches
from repro.models import build_model

N = 4
ROUNDS = %(rounds)d
CASES = %(cases)r
cfg = get_config("fedtest-cnn-mnist").replace(cnn_channels=(4, 8, 8),
                                              cnn_hidden=16)
model = build_model(cfg)
tc = TrainConfig(optimizer="sgd", lr=0.1, schedule="constant",
                 batch_size=8, grad_clip=0.0, remat=False)
data = make_federated_image_dataset(MNIST_LIKE, N, num_samples=1600,
                                    global_test=256, seed=0,
                                    partition_kwargs={"min_classes": 8,
                                                      "max_classes": 10})
mesh = Mesh(np.asarray(jax.devices()[:N]), ("clients",))
tx, ty = data.test.xs[:, :64], data.test.ys[:, :64]

results = {}
for attack, participation, coalition, selector, fault, impl in CASES:
    # a K < N committee makes the selector cases non-trivial (which
    # clients tester actually varies with the scores / schedule)
    fed = FedConfig(num_users=N,
                    num_testers=N if selector == "rotating" else 3,
                    num_malicious=0 if attack == "none" else 1,
                    attack=attack, attack_scale=4.0,
                    coalition=coalition,
                    coalition_size=0 if coalition == "none" else 2,
                    selector=selector, fault=fault, fault_rate=0.25,
                    participation=participation, local_steps=6,
                    crosstest_impl=impl, seed=0)

    # ---- local (vmap) backend via the single-host driver --------------
    trainer = FederatedTrainer(model, fed, tc, eval_batch=64)
    state = trainer.init(jax.random.PRNGKey(0))
    run_key = state.key
    traj = {"local": {"w": [], "s": [], "mal_w": [], "rate": [],
                      "drop": []},
            "ring": {"w": [], "s": [], "mal_w": [], "rate": [],
                     "drop": []},
            "allgather": {"w": [], "s": [], "mal_w": [], "rate": [],
                          "drop": []},
            "pmask": []}
    for r in range(ROUNDS):
        state, m = trainer.run_round(state, data)
        traj["local"]["w"].append(np.asarray(m["weights"]).tolist())
        traj["local"]["s"].append(np.asarray(m["scores"]).tolist())
        traj["local"]["mal_w"].append(float(m["malicious_weight"]))
        traj["local"]["rate"].append(float(m["participation_rate"]))
        traj["local"]["drop"].append(float(m["dropped_fraction"]))
        # replay the engine's own mask derivation to pin zero patterns
        keys = round_keys(jax.random.fold_in(run_key, r))
        pmask = (participation_mask(keys.part, N, participation)
                 if participation < 1.0 else jnp.ones((N,)))
        if fault != "none":
            alive = resolve_fault(fed).mask(keys.fault, N,
                                            jnp.asarray(r, jnp.int32))
            pmask = compose_fault_mask(pmask, alive)
        traj["pmask"].append(np.asarray(pmask).tolist())
    assert trainer.num_traces == 1, trainer.num_traces

    # ---- ring / allgather backends, replaying the same schedule -------
    pk, _ = jax.random.split(jax.random.PRNGKey(0))
    for exchange, make in [("ring", make_distributed_round),
                           ("allgather", make_allgather_round)]:
        round_fn = jax.jit(make(model, fed, tc, mesh,
                                counts=data.train.counts))
        g = model.init(pk)                  # same init as trainer.init
        s = init_scores(N)
        for r in range(ROUNDS):
            key = jax.random.fold_in(run_key, r)
            bx, by = sample_client_batches(round_keys(key).batch,
                                           data.train, fed.local_steps,
                                           tc.batch_size)
            g, s, m = round_fn(g, s, bx, by, tx, ty, key,
                               jnp.asarray(r, jnp.int32))
            traj[exchange]["w"].append(np.asarray(m["weights"]).tolist())
            traj[exchange]["s"].append(np.asarray(m["scores"]).tolist())
            traj[exchange]["mal_w"].append(float(m["malicious_weight"]))
            traj[exchange]["rate"].append(
                float(m["participation_rate"]))
            traj[exchange]["drop"].append(
                float(m["dropped_fraction"]))
    results["|".join(map(str, (attack, participation, coalition,
                               selector, fault, impl)))] = traj

print(json.dumps(results))
""" % {"rounds": ROUNDS, "cases": CASES}


@pytest.mark.slow
def test_three_backend_equivalence_matrix():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    proc = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                          capture_output=True, text=True, timeout=1500)
    assert proc.returncode == 0, proc.stderr[-3000:]
    results = json.loads(proc.stdout.strip().splitlines()[-1])

    for attack, participation, coalition, selector, fault, impl in CASES:
        traj = results["|".join(map(str, (attack, participation,
                                          coalition, selector, fault,
                                          impl)))]
        ref = traj["local"]
        for backend in ("ring", "allgather"):
            other = traj[backend]
            tag = (attack, participation, coalition, selector, fault,
                   impl, backend)
            for r in range(ROUNDS):
                # bit-identical round dynamics: the three backends run
                # the same program on the same replicated arrays
                np.testing.assert_array_equal(
                    np.asarray(ref["w"][r]), np.asarray(other["w"][r]),
                    err_msg=f"weights diverged {tag} round {r}")
                np.testing.assert_array_equal(
                    np.asarray(ref["s"][r]), np.asarray(other["s"][r]),
                    err_msg=f"scores diverged {tag} round {r}")
                assert ref["mal_w"][r] == other["mal_w"][r], (tag, r)
                assert ref["rate"][r] == other["rate"][r], (tag, r)
                assert ref["drop"][r] == other["drop"][r], (tag, r)

        for r in range(ROUNDS):
            w = np.asarray(ref["w"][r])
            pmask = np.asarray(traj["pmask"][r])
            # sampled-subset renormalisation: non-participants (sampled
            # out OR dropped by the fault) get *exactly* zero weight,
            # the rest renormalise to a simplex
            np.testing.assert_array_equal(w[pmask == 0.0], 0.0)
            assert abs(w.sum() - 1.0) < 1e-4, (attack, participation, r)
            if participation < 1.0 or fault != "none":
                assert ref["rate"][r] == pytest.approx(pmask.mean())

    # the adversarial cases actually engage the attacker: its weight
    # trajectory must differ from the honest run's last slot
    honest = results["none|1.0|none|rotating|none|batched"]["local"]["w"]
    flipped = results[
        "sign_flip|1.0|none|rotating|none|batched"]["local"]["w"]
    assert honest != flipped
    # ...and the coalition cases actually engage the coalition: both
    # the report transform (mutual_boost) and the composed model attack
    # (sybil_split) must move the dynamics off the honest trajectory,
    # and the members (clients 2, 3) must register as malicious weight
    for coalition in ("mutual_boost", "sybil_split"):
        coal = results[
            f"none|1.0|{coalition}|rotating|none|batched"]["local"]
        assert coal["w"] != honest, coalition
        assert any(m > 0.0 for m in coal["mal_w"]), coalition
    # ...and the fault rows actually drop someone at rate 0.25 over
    # 4 clients x 4 rounds (the composed mask is also pinned above via
    # the zero-weight pattern replay)
    for fault in ("dropout", "straggler_deadline"):
        faulty = results[
            f"none|1.0|none|rotating|{fault}|batched"]["local"]
        assert any(d > 0.0 for d in faulty["drop"]), fault
    # the crosstest_impl axis (DESIGN.md §10): rows that differ only in
    # the dispatch model must have bit-identical full trajectories on
    # every backend — the fast path may not move a single bit
    for key in ("none|1.0|none|rotating|none",
                "sign_flip|0.75|none|rotating|none",
                "none|1.0|none|rotating|dropout"):
        batched, reference = (results[f"{key}|batched"],
                              results[f"{key}|reference"])
        for backend in ("local", "ring", "allgather"):
            assert batched[backend] == reference[backend], (key, backend)


# --- compressed-exchange rows (DESIGN.md §12) --------------------------
# {int8, topk} x {sign_flip, mutual_boost} at participation 0.75, plus
# the identity reference per scenario: the three backends must stay
# bit-identical to *each other* on the compressed wire (weights, scores
# and malicious-weight trajectories, the same contract as the dense
# matrix), and the defence must survive compression — the compressed
# final-round malicious_weight stays within 2x of the uncompressed
# row's, so "FedTest still suppresses over a quantised/sparsified
# exchange" is a committed test, not a claim.
COMPRESSED_CASES = [
    (comp, ckw, attack, coalition)
    for comp, ckw in [("identity", {}), ("int8", {}),
                      ("topk", {"k": 0.05})]
    for attack, coalition in [("sign_flip", "none"),
                              ("none", "mutual_boost")]]

COMPRESSED_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import json
import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import Mesh

from repro.config import FedConfig, TrainConfig
from repro.configs import get_config
from repro.core import FederatedTrainer
from repro.core.engine import (
    init_comp_state, make_allgather_round, make_distributed_round,
    round_keys)
from repro.core.scoring import init_scores
from repro.data import MNIST_LIKE, make_federated_image_dataset, \
    sample_client_batches
from repro.models import build_model

N = 4
ROUNDS = %(rounds)d
CASES = %(cases)r
cfg = get_config("fedtest-cnn-mnist").replace(cnn_channels=(4, 8, 8),
                                              cnn_hidden=16)
model = build_model(cfg)
tc = TrainConfig(optimizer="sgd", lr=0.1, schedule="constant",
                 batch_size=8, grad_clip=0.0, remat=False)
data = make_federated_image_dataset(MNIST_LIKE, N, num_samples=1600,
                                    global_test=256, seed=0,
                                    partition_kwargs={"min_classes": 8,
                                                      "max_classes": 10})
mesh = Mesh(np.asarray(jax.devices()[:N]), ("clients",))
tx, ty = data.test.xs[:, :64], data.test.ys[:, :64]

results = {}
for comp_name, comp_kwargs, attack, coalition in CASES:
    fed = FedConfig(num_users=N, num_testers=N,
                    num_malicious=0 if attack == "none" else 1,
                    attack=attack, attack_scale=4.0,
                    coalition=coalition,
                    coalition_size=0 if coalition == "none" else 2,
                    participation=0.75, local_steps=6,
                    compressor=comp_name,
                    compressor_kwargs=comp_kwargs, seed=0)

    trainer = FederatedTrainer(model, fed, tc, eval_batch=64)
    state = trainer.init(jax.random.PRNGKey(0))
    run_key = state.key
    traj = {b: {"w": [], "s": [], "mal_w": []}
            for b in ("local", "ring", "allgather")}
    for r in range(ROUNDS):
        state, m = trainer.run_round(state, data)
        traj["local"]["w"].append(np.asarray(m["weights"]).tolist())
        traj["local"]["s"].append(np.asarray(m["scores"]).tolist())
        traj["local"]["mal_w"].append(float(m["malicious_weight"]))
    assert trainer.num_traces == 1, trainer.num_traces

    pk, _ = jax.random.split(jax.random.PRNGKey(0))
    for exchange, make in [("ring", make_distributed_round),
                           ("allgather", make_allgather_round)]:
        round_fn = jax.jit(make(model, fed, tc, mesh,
                                counts=data.train.counts))
        g = model.init(pk)
        s = init_scores(N)
        comp = init_comp_state(fed, model)   # None when identity
        for r in range(ROUNDS):
            key = jax.random.fold_in(run_key, r)
            bx, by = sample_client_batches(round_keys(key).batch,
                                           data.train, fed.local_steps,
                                           tc.batch_size)
            if comp is not None:
                g, s, comp, m = round_fn(g, s, comp, bx, by, tx, ty,
                                         key, jnp.asarray(r, jnp.int32))
            else:
                g, s, m = round_fn(g, s, bx, by, tx, ty, key,
                                   jnp.asarray(r, jnp.int32))
            traj[exchange]["w"].append(np.asarray(m["weights"]).tolist())
            traj[exchange]["s"].append(np.asarray(m["scores"]).tolist())
            traj[exchange]["mal_w"].append(float(m["malicious_weight"]))
    results["|".join(map(str, (comp_name, attack, coalition)))] = traj

print(json.dumps(results))
""" % {"rounds": ROUNDS, "cases": COMPRESSED_CASES}


@pytest.mark.slow
def test_compressed_backend_equivalence_and_suppression():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    proc = subprocess.run([sys.executable, "-c", COMPRESSED_SCRIPT],
                          env=env, capture_output=True, text=True,
                          timeout=1500)
    assert proc.returncode == 0, proc.stderr[-3000:]
    results = json.loads(proc.stdout.strip().splitlines()[-1])

    for comp_name, _ckw, attack, coalition in COMPRESSED_CASES:
        traj = results["|".join(map(str, (comp_name, attack, coalition)))]
        ref = traj["local"]
        for backend in ("ring", "allgather"):
            other = traj[backend]
            tag = (comp_name, attack, coalition, backend)
            for r in range(ROUNDS):
                np.testing.assert_array_equal(
                    np.asarray(ref["s"][r]), np.asarray(other["s"][r]),
                    err_msg=f"scores diverged {tag} round {r}")
                np.testing.assert_array_equal(
                    np.asarray(ref["w"][r]), np.asarray(other["w"][r]),
                    err_msg=f"weights diverged {tag} round {r}")
                assert ref["mal_w"][r] == other["mal_w"][r], (tag, r)

    # suppression survives the lossy wire: the compressed final-round
    # malicious weight stays within 2x of the identity row's (floored
    # at 0.05 absolute so a fully-suppressed baseline cannot demand
    # the impossible of a quantised run)
    for attack, coalition in [("sign_flip", "none"),
                              ("none", "mutual_boost")]:
        base = results[f"identity|{attack}|{coalition}"]["local"]
        bar = 2.0 * max(base["mal_w"][-1], 0.05)
        for comp_name in ("int8", "topk"):
            row = results[f"{comp_name}|{attack}|{coalition}"]["local"]
            assert row["mal_w"][-1] <= bar, (
                comp_name, attack, coalition, row["mal_w"][-1], bar)
