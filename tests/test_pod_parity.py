"""Single-host engine vs pod (shard_map) round parity.

The tentpole contract of the adversarial pod path (DESIGN.md §3): under
``sign_flip`` + ``participation=0.75`` both engines, driven by the same
seeds, must produce matching malicious-weight suppression and matching
sampled-subset renormalisation. The pod subprocess replays the
single-host engine's exact per-round key schedule (``fold_in(state.key,
round)`` then ``split(·, 4)`` / ``fold_in(·, 6)``) so both see identical
batches, tester sets and participation masks; sign_flip is key-free, so
the only remaining divergence is floating-point reassociation between the
vmap'd stack and the per-device psum — hence tight-but-not-bitwise
tolerances on the dynamics and a loose one on accuracy.
"""
import json
import os
import subprocess
import sys

import numpy as np
import pytest

ROUNDS = 8
SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import json
import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import Mesh

from repro.config import FedConfig, TrainConfig
from repro.configs import get_config
from repro.core import FederatedTrainer
from repro.core.distributed import make_distributed_round
from repro.core.round import participation_mask
from repro.core.scoring import init_scores
from repro.data import MNIST_LIKE, make_federated_image_dataset, \
    sample_client_batches
from repro.models import build_model
from repro.strategies import SELECTORS

N = 4
ROUNDS = %(rounds)d
cfg = get_config("fedtest-cnn-mnist").replace(cnn_channels=(4, 8, 8),
                                              cnn_hidden=16)
model = build_model(cfg)
fed = FedConfig(num_users=N, num_testers=N, num_malicious=1,
                attack="sign_flip", attack_scale=4.0, participation=0.75,
                local_steps=6, seed=0)
tc = TrainConfig(optimizer="sgd", lr=0.1, schedule="constant",
                 batch_size=8, grad_clip=0.0, remat=False)
data = make_federated_image_dataset(MNIST_LIKE, N, num_samples=1600,
                                    global_test=256, seed=0,
                                    partition_kwargs={"min_classes": 8,
                                                      "max_classes": 10})

# ---- single-host engine -------------------------------------------------
trainer = FederatedTrainer(model, fed, tc, eval_batch=64)
state = trainer.init(jax.random.PRNGKey(0))
host = {"w": [], "mal_w": [], "rate": []}
for r in range(ROUNDS):
    state, m = trainer.run_round(state, data)
    host["w"].append(np.asarray(m["weights"]).tolist())
    host["mal_w"].append(float(m["malicious_weight"]))
    host["rate"].append(float(m["participation_rate"]))
host_acc = trainer.global_accuracy(state, data, max_samples=256)

# ---- pod engine, replaying the identical key schedule -------------------
mesh = Mesh(np.asarray(jax.devices()[:N]), ("clients",))
round_fn = jax.jit(make_distributed_round(model, fed, tc, mesh,
                                          counts=data.train.counts))
selector = SELECTORS.build(fed.selector, fed.strategy_kwargs("selector"))

pk, rk = jax.random.split(jax.random.PRNGKey(0))
g = model.init(pk)                      # same init as trainer.init
s = init_scores(N)
tx, ty = data.test.xs[:, :64], data.test.ys[:, :64]
pod = {"w": [], "mal_w": [], "rate": [], "pmask": []}
for r in range(ROUNDS):
    key = jax.random.fold_in(rk, r)     # _round's fold_in(state.key, idx)
    k_batch, k_attack, k_test, k_lie = jax.random.split(key, 4)
    k_part = jax.random.fold_in(key, 6)
    bx, by = sample_client_batches(k_batch, data.train, fed.local_steps,
                                   tc.batch_size)
    tester_ids = selector.select(k_test, N, fed.num_testers, r)
    mask = jnp.zeros((N,), jnp.float32).at[tester_ids].set(1.0)
    pmask = participation_mask(k_part, N, fed.participation)
    g, s, m = round_fn(g, s, bx, by, tx, ty, mask, pmask)
    pod["w"].append(np.asarray(m["weights"]).tolist())
    pod["mal_w"].append(float(m["malicious_weight"]))
    pod["rate"].append(float(m["participation_rate"]))
    pod["pmask"].append(np.asarray(pmask).tolist())

logits, _ = model.forward_train(g, {"images": data.global_x[:256]})
pod_acc = float((jnp.argmax(logits, -1) == data.global_y[:256]).mean())

print(json.dumps({"host": host, "pod": pod,
                  "host_acc": host_acc, "pod_acc": pod_acc}))
""" % {"rounds": ROUNDS}


@pytest.mark.slow
def test_pod_round_matches_single_host_under_attack_and_sampling():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    proc = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                          capture_output=True, text=True, timeout=900)
    assert proc.returncode == 0, proc.stderr[-3000:]
    out = json.loads(proc.stdout.strip().splitlines()[-1])
    host, pod = out["host"], out["pod"]

    for r in range(ROUNDS):
        hw = np.asarray(host["w"][r])
        pw = np.asarray(pod["w"][r])
        pmask = np.asarray(pod["pmask"][r])
        # identical sampled subsets (same participation_mask key schedule)
        assert host["rate"][r] == pytest.approx(pod["rate"][r], abs=1e-6)
        # sampled-subset renormalisation: non-participants get *exactly*
        # zero weight on both engines, the rest renormalise to a simplex
        np.testing.assert_array_equal(pw[pmask == 0.0], 0.0)
        np.testing.assert_array_equal(hw[pmask == 0.0], 0.0)
        assert abs(pw.sum() - 1.0) < 1e-4
        assert abs(hw.sum() - 1.0) < 1e-4
        # matching round dynamics (float reassociation only)
        assert np.abs(pw - hw).max() < 0.08, (r, hw.tolist(), pw.tolist())
        assert abs(host["mal_w"][r] - pod["mal_w"][r]) < 0.08, r

    # matching malicious-weight suppression under the fedtest aggregator
    assert host["mal_w"][-1] < 0.05, host["mal_w"]
    assert pod["mal_w"][-1] < 0.05, pod["mal_w"]
    # and the trained global models land at comparable accuracy
    assert abs(out["host_acc"] - out["pod_acc"]) < 0.15, out
