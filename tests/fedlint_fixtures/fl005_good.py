"""FL005 good fixture: the rebind-at-the-call idiom, sibling branches,
and AOT .lower() chains (which donate nothing at trace time)."""
import functools

import jax


def rebind_at_call(step_fn, state, data):
    scan_fn = jax.jit(step_fn, donate_argnums=0)
    state, chunk = scan_fn(state, data)   # driver.py's safe idiom
    return state, chunk


def rebind_in_loop(step_fn, state, chunks):
    fn = jax.jit(step_fn, donate_argnums=0)
    outs = []
    for chunk in chunks:
        state, out = fn(state, chunk)     # fresh buffer every iteration
        outs.append(out)
    return state, outs


def sibling_branches(step_fn, params, opt_state, batch, mode):
    if mode == "donate":
        lowered = jax.jit(step_fn, donate_argnums=(0, 1)
                          ).lower(params, opt_state, batch)
        return lowered
    elif mode == "plain":
        # a sibling branch never runs after the donating call above
        return step_fn(params, opt_state, batch)
    return params


@functools.partial(jax.jit, donate_argnums=(0,))
def update(state, grads):
    return jax.tree_util.tree_map(lambda s, g: s - 0.1 * g, state, grads)


def rebound_decorated(state, grads):
    state = update(state, grads)
    return state
