"""FL001 bad fixture, fault edition: an availability fault whose
survival mask is NOT derived from the round schedule.

The contract (DESIGN.md §9): ``Fault.mask`` consumes ``keys.fault`` —
the ``fold_in(key, 7)`` member of the per-round bundle — so the drop
pattern replays identically across backends and across save/restore.
A fault minting its own key (or reusing one) silently breaks both
parity and bit-identical resume.
"""
import jax
import jax.numpy as jnp


class Dropout:
    """Drop pattern unkeyed by the run: fresh literal every round."""

    def __init__(self, rate: float = 0.1):
        self.rate = rate

    def mask(self, key, num_users, round_idx):
        fresh = jax.random.PRNGKey(7)                  # literal, not keys.fault
        keep = jax.random.bernoulli(fresh, 1.0 - self.rate, (num_users,))
        return keep.astype(jnp.float32)


class StragglerDeadline:
    """Reuses one key for two independent draws."""

    def __init__(self, deadline: float = 2.5):
        self.deadline = deadline

    def mask(self, key, num_users, round_idx):
        jitter = jax.random.exponential(key, (num_users,))   # consume 1
        tie = jax.random.uniform(key, (num_users,))          # consume 2 -> reuse
        return ((jitter + tie) <= self.deadline).astype(jnp.float32)
