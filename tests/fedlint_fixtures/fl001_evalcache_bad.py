"""FL001 bad fixture, eval-cache edition: a cross-round eval-batch
cache that breaks the key discipline of DESIGN.md §10.

The contract: cached tester eval batches must be a pure function of the
handed-in run key and the schedule bucket — the gather indices are
re-derived via ``fold_in`` on every miss. This cache instead mints a
fresh PRNG literal per refill and reuses one key for two independent
draws, so a cold cache, a warm cache and a restored run all sample
different batches: the cache *key* (hit/miss pattern) leaks into the
trajectory.
"""
import jax
import jax.numpy as jnp


class LeakyEvalBatchCache:
    """Refills from a literal key, then double-draws it."""

    def __init__(self, resample_every: int):
        self.resample_every = resample_every
        self._bucket = None
        self._idx = None

    def get(self, run_key, counts, eval_batch, round_idx):
        bucket = round_idx // self.resample_every
        if self._bucket == bucket and self._idx is not None:
            return self._idx
        fresh = jax.random.PRNGKey(11)          # literal, not the run key
        u = jax.random.uniform(fresh, (counts.shape[0], eval_batch))
        jitter = jax.random.uniform(fresh, (counts.shape[0], 1))  # reuse
        self._bucket = bucket
        self._idx = ((u + jitter) % 1.0 * counts[:, None]).astype(
            jnp.int32)
        return self._idx
