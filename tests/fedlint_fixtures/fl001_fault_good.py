"""FL001 good fixture, fault edition: survival masks derived from the
round schedule — ``mask`` consumes only the ``keys.fault`` stream it is
handed (split before any second draw), so drops replay identically
across backends and across save/restore (DESIGN.md §9)."""
import jax
import jax.numpy as jnp


class Dropout:
    def __init__(self, rate: float = 0.1):
        self.rate = rate

    def mask(self, key, num_users, round_idx):
        keep = jax.random.bernoulli(key, 1.0 - self.rate, (num_users,))
        return keep.astype(jnp.float32)


class StragglerDeadline:
    def __init__(self, deadline: float = 2.5):
        self.deadline = deadline

    def mask(self, key, num_users, round_idx):
        k_jitter, k_tie = jax.random.split(key)
        jitter = jax.random.exponential(k_jitter, (num_users,))
        tie = jax.random.uniform(k_tie, (num_users,))
        return ((jitter + tie) <= self.deadline).astype(jnp.float32)
