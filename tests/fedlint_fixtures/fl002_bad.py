"""FL002 bad fixture: Python control flow on traced values inside
jitted functions, f-strings on tracers, unhashable static defaults."""
import functools

import jax
import jax.numpy as jnp


@jax.jit
def branch_on_tracer(x):
    if x > 0:                      # traced comparison in Python `if`
        return x * 2
    return -x


@jax.jit
def loop_on_tracer(x):
    while x.sum() > 1.0:           # traced `while`
        x = x * 0.5
    return x


@jax.jit
def assert_on_tracer(x):
    assert x.sum() > 0             # traced assert
    return x


@jax.jit
def format_tracer(x):
    label = f"value={x}"           # tracer repr baked into the trace
    return x, label


@functools.partial(jax.jit, static_argnames=("cfg",))
def mutable_static(x, cfg=[1, 2, 3]):   # unhashable static default
    return x * cfg[0]


def scan_body(carry, x):
    if x > 0:                      # body is traced via lax.scan below
        carry = carry + x
    return carry, x


def run(xs):
    return jax.lax.scan(scan_body, jnp.float32(0), xs)
