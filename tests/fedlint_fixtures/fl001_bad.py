"""FL001 bad fixture: fixed key literals + key reuse.

The Coverage class reproduces the PR 5 coverage-selector bug verbatim in
shape: a strategy buried in library code building its stream from
``PRNGKey(0)`` instead of the run's seed.
"""
import jax


class Coverage:
    """The PR 5 bug pattern: selector randomness unkeyed by the run."""

    def select(self, key, num_users, num_testers, round_idx, *,
               scores=None):
        cycle = round_idx // num_users
        base = jax.random.fold_in(jax.random.PRNGKey(0), cycle)  # literal
        return jax.random.permutation(base, num_users)[:num_testers]


def unkeyed_noise(shape):
    key = jax.random.PRNGKey(42)                  # literal in library code
    return jax.random.normal(key, shape)


def correlated_draws(key, shape):
    a = jax.random.normal(key, shape)             # consume 1
    b = jax.random.uniform(key, shape)            # consume 2 -> reuse
    return a + b


def helper_reuse(key, attack, selector, num_users):
    bad = attack.apply(key, num_users)            # consume 1
    ids = selector.select(key, num_users)         # consume 2 -> reuse
    return bad, ids
