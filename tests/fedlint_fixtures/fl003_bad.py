"""FL003 bad fixture: remainder-dropping grids, out-of-rank program_id,
unmasked cdiv, VMEM blow-up."""
import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _drop_kernel(x_ref, o_ref):
    o_ref[...] = x_ref[...] * 2.0


def remainder_dropped(x):
    M = 100
    block_m = 8          # 100 % 8 != 0 -> the last 4 rows never visited
    return pl.pallas_call(
        _drop_kernel,
        grid=(M // block_m,),
        in_specs=[pl.BlockSpec((block_m,), lambda i: (i,))],
        out_specs=pl.BlockSpec((block_m,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
    )(x)


def _unguarded_kernel(x_ref, o_ref):
    o_ref[...] = x_ref[...] + 1.0


def unguarded_dynamic(x, block_m):
    M = x.shape[0]
    # no assert, no masking: silently wrong whenever block_m does not
    # divide M
    return pl.pallas_call(
        _unguarded_kernel,
        grid=(M // block_m,),
        in_specs=[pl.BlockSpec((block_m,), lambda i: (i,))],
        out_specs=pl.BlockSpec((block_m,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
    )(x)


def _axis_kernel(x_ref, o_ref):
    i = pl.program_id(0)
    j = pl.program_id(2)     # grid below is rank 1: axis 2 is undefined
    o_ref[...] = x_ref[...] + jnp.float32(i + j)


def bad_axis(x, block_m: int = 8):
    M = x.shape[0]
    assert M % block_m == 0
    return pl.pallas_call(
        _axis_kernel,
        grid=(M // block_m,),
        in_specs=[pl.BlockSpec((block_m,), lambda i: (i,))],
        out_specs=pl.BlockSpec((block_m,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
    )(x)


def _cdiv_kernel(x_ref, o_ref):
    o_ref[...] = x_ref[...]          # no pl.when: tail block unguarded


def ragged_unmasked(x, block_m: int = 8):
    M = x.shape[0]
    return pl.pallas_call(
        _cdiv_kernel,
        grid=(pl.cdiv(M, block_m),),
        in_specs=[pl.BlockSpec((block_m,), lambda i: (i,))],
        out_specs=pl.BlockSpec((block_m,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
    )(x)


def _huge_kernel(x_ref, o_ref):
    o_ref[...] = x_ref[...]


def vmem_blowup(x, block_m: int = 4096, block_n: int = 4096):
    M = x.shape[0]
    assert M % block_m == 0
    # 4096 x 4096 fp32 double-buffered = 256 MiB versus a 16 MiB budget
    return pl.pallas_call(
        _huge_kernel,
        grid=(M // block_m,),
        in_specs=[pl.BlockSpec((block_m, block_n), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
    )(x)
