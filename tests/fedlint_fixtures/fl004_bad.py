"""FL004 bad fixture: registered strategies that do not satisfy the
protocol their registry implies."""

AGGREGATORS = {}
ATTACKS = {}
SELECTORS = {}
COALITIONS = {}


def register(registry, name):
    def deco(cls):
        registry[name] = cls
        return cls
    return deco


@register(SELECTORS, "positional_scores")
class PositionalScores:
    # scores is positional: the engine's scores=... binds round_idx
    def select(self, key, num_users, num_testers, round_idx, scores=None):
        return list(range(num_testers))


@register(SELECTORS, "abstract_select")
class AbstractSelect:
    def select(self, key, num_users, num_testers, round_idx, *,
               scores=None):
        raise NotImplementedError


@register(ATTACKS, "no_ctx")
class NoCtxAttack:
    # corrupt() drops ctx/client_idx: the engine's forwarding call raises
    def corrupt(self, key, trained, global_params):
        return trained


@register(ATTACKS, "one_sided")
class OneSided:
    def corrupt(self, key, trained, global_params, ctx=None,
                client_idx=None):
        return trained

    def apply(self, key, stacked, global_params, ctx=None):
        return stacked * 0          # batched path disagrees with local


@register(AGGREGATORS, "no_weights")
class NoWeights:
    def update_scores(self, scores, acc):
        return scores


@register(AGGREGATORS, "ctxless_combine")
class CtxlessCombine:
    def weights(self, acc, ctx):
        return acc

    def combine(self, updates):     # engine calls combine(ctx, updates)
        return updates


@register(COALITIONS, "bad_transform")
class BadTransform:
    def transform_reports(self, acc):   # missing key/tester_ids/ctx
        return acc
