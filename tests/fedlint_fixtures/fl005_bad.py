"""FL005 bad fixture: donated buffers read after the donating call."""
import functools

import jax


def read_after_bound_call(step_fn, state, data):
    scan_fn = jax.jit(step_fn, donate_argnums=0)
    out = scan_fn(state, data)          # state's buffer donated here
    leftovers = state["acc"]            # read-after-donate
    return out, leftovers


def read_after_inline_call(step_fn, params, batch):
    new_params = jax.jit(step_fn, donate_argnums=(0,))(params, batch)
    delta = jax.tree_util.tree_map(lambda a, b: a - b,
                                   new_params, params)   # donated read
    return delta


@functools.partial(jax.jit, donate_argnums=(0,))
def update(state, grads):
    return jax.tree_util.tree_map(lambda s, g: s - 0.1 * g, state, grads)


def read_after_decorated(state, grads):
    new_state = update(state, grads)
    stale = state                        # donated read via decorator form
    return new_state, stale


def loop_without_rebind(step_fn, state, chunks):
    fn = jax.jit(step_fn, donate_argnums=0)
    outs = []
    for chunk in chunks:
        outs.append(fn(state, chunk))    # iteration 2 reuses dead buffer
    return outs
