"""FL004 good fixture: protocol-conformant registered strategies,
including conformance inherited through a base class."""

AGGREGATORS = {}
ATTACKS = {}
SELECTORS = {}
COALITIONS = {}


def register(registry, name):
    def deco(cls):
        registry[name] = cls
        return cls
    return deco


class SelectorBase:
    def select(self, key, num_users, num_testers, round_idx, *,
               scores=None):
        raise NotImplementedError


@register(SELECTORS, "kwonly_scores")
class KwonlyScores(SelectorBase):
    def select(self, key, num_users, num_testers, round_idx, *,
               scores=None):
        return list(range(num_testers))


class AttackBase:
    def corrupt(self, key, trained, global_params, ctx=None,
                client_idx=None):
        raise NotImplementedError

    def apply(self, key, stacked, global_params, ctx=None):
        return self.corrupt(key, stacked, global_params, ctx)

    def apply_local(self, key, trained, global_params, ctx=None,
                    client_idx=None):
        return self.corrupt(key, trained, global_params, ctx, client_idx)


@register(ATTACKS, "via_corrupt")
class ViaCorrupt(AttackBase):
    def corrupt(self, key, trained, global_params, ctx=None,
                client_idx=None):
        return trained


@register(ATTACKS, "both_sides")
class BothSides(AttackBase):
    def corrupt(self, key, trained, global_params, ctx=None,
                client_idx=None):
        return trained

    def apply(self, key, stacked, global_params, ctx=None):
        return stacked

    def apply_local(self, key, trained, global_params, ctx=None,
                    client_idx=None):
        return trained


@register(AGGREGATORS, "full")
class FullAggregator:
    def weights(self, acc, ctx):
        return acc

    def combine(self, ctx, updates):
        return updates


@register(COALITIONS, "good_transform")
class GoodTransform:
    def transform_reports(self, key, acc, tester_ids, ctx):
        return acc


@register(COALITIONS, "kwargs_transform")
class KwargsTransform:
    def transform_reports(self, key, acc, **kwargs):
        return acc
