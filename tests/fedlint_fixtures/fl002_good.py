"""FL002 good fixture: trace-static branching only."""
import functools

import jax
import jax.numpy as jnp


@jax.jit
def branch_on_shape(x):
    if x.ndim == 1:                    # .ndim is trace-static
        x = x[None, :]
    return jnp.where(x > 0, x * 2, -x)  # data branch stays in-graph


@jax.jit
def branch_on_none(x, bias=None):
    if bias is None:                   # identity check is trace-static
        return x
    return x + bias


@functools.partial(jax.jit, static_argnames=("steps",))
def static_loop(x, steps=3):
    for _ in range(steps):             # static python loop unrolls
        x = x * 0.5
    return x


@jax.jit
def checked(x):
    assert x.shape[0] > 0              # shape assert is trace-static
    return jax.lax.while_loop(lambda v: v.sum() > 1.0,
                              lambda v: v * 0.5, x)


def scan_body(carry, x):
    carry = carry + jnp.where(x > 0, x, 0.0)
    return carry, x


def run(xs):
    return jax.lax.scan(scan_body, jnp.float32(0), xs)
