"""FL001 good fixture, eval-cache edition: the schedule-bucket cache of
DESIGN.md §10 done right — the gather indices are a pure function of
the handed-in run key and the round bucket, re-derived with ``fold_in``
on every miss. No key is ever minted from a literal or stashed across
rounds, so cold cache == warm cache == in-trace derivation, bitwise."""
import jax
import jax.numpy as jnp

EVAL_BATCH_STREAM = 11


class BucketEvalBatchCache:
    def __init__(self, resample_every: int):
        self.resample_every = resample_every
        self._bucket = None
        self._idx = None

    def get(self, run_key, counts, eval_batch, round_idx):
        bucket = round_idx // self.resample_every
        if self._bucket == bucket and self._idx is not None:
            return self._idx
        k = jax.random.fold_in(
            jax.random.fold_in(run_key, EVAL_BATCH_STREAM), bucket)
        u = jax.random.uniform(k, (counts.shape[0], eval_batch))
        self._bucket = bucket
        self._idx = (u * counts[:, None]).astype(jnp.int32)
        return self._idx
