"""FL003 good fixture: guarded divisibility, masked cdiv tail, in-rank
program_id, modest blocks (mirrors the repo's kernel idiom)."""
import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _tile_kernel(x_ref, o_ref):
    i = pl.program_id(0)
    o_ref[...] = x_ref[...] + jnp.float32(i)


def guarded(x, block_m: int = 128):
    M = x.shape[0]
    block_m = min(block_m, M)
    assert M % block_m == 0, "block_m must divide M"
    return pl.pallas_call(
        _tile_kernel,
        grid=(M // block_m,),
        in_specs=[pl.BlockSpec((block_m,), lambda i: (i,))],
        out_specs=pl.BlockSpec((block_m,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
    )(x)


def _masked_kernel(n, block_m, x_ref, o_ref):
    i = pl.program_id(0)

    @pl.when(i * block_m < n)
    def _():
        o_ref[...] = x_ref[...]


def ragged_masked(x, block_m: int = 8):
    M = x.shape[0]
    kernel = functools.partial(_masked_kernel, M, block_m)
    return pl.pallas_call(
        kernel,
        grid=(pl.cdiv(M, block_m),),
        in_specs=[pl.BlockSpec((block_m,), lambda i: (i,))],
        out_specs=pl.BlockSpec((block_m,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
    )(x)


def _pair_kernel(x_ref, y_ref, o_ref):
    i = pl.program_id(0)
    j = pl.program_id(1)
    o_ref[...] = x_ref[...] * y_ref[...] + jnp.float32(i * j)


def static_divisible(x, y):
    M, N = 256, 128
    return pl.pallas_call(
        _pair_kernel,
        grid=(M // 64, N // 32),
        in_specs=[pl.BlockSpec((64, 32), lambda i, j: (i, j)),
                  pl.BlockSpec((64, 32), lambda i, j: (i, j))],
        out_specs=pl.BlockSpec((64, 32), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
    )(x, y)
