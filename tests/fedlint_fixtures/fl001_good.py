"""FL001 good fixture: seed-derived construction, split/fold_in before
every additional consume."""
import jax


class Coverage:
    def __init__(self, seed: int = 0):
        self.seed = seed

    def select(self, key, num_users, num_testers, round_idx, *,
               scores=None):
        cycle = round_idx // num_users
        base = jax.random.fold_in(jax.random.PRNGKey(self.seed), cycle)
        return jax.random.permutation(base, num_users)[:num_testers]


def seeded_noise(seed, shape):
    key = jax.random.PRNGKey(seed)
    return jax.random.normal(key, shape)


def split_draws(key, shape):
    k_a, k_b = jax.random.split(key)
    a = jax.random.normal(k_a, shape)
    b = jax.random.uniform(k_b, shape)
    return a + b


def folded_helpers(key, attack, selector, num_users):
    bad = attack.apply(jax.random.fold_in(key, 0), num_users)
    ids = selector.select(jax.random.fold_in(key, 1), num_users)
    return bad, ids


def rebound(key, shape):
    a = jax.random.normal(key, shape)
    key = jax.random.fold_in(key, 1)      # rebind resets the stream
    b = jax.random.uniform(key, shape)
    return a + b
