"""Population tier vs dense engine: bitwise equivalence + semantics.

The cohort engine's whole claim (DESIGN.md §11) is that gathering the
sampled cohort and scattering back is *invisible*: at small N the
population trainer must produce bit-identical trajectories to the dense
:class:`FederatedTrainer` — params, scores, weights, malicious weight,
losses and the accuracy matrix — under attacks, coalitions and partial
participation. These tests pin that matrix, the tiled cross-testing
path, mid-trajectory checkpoint resume, the cohort-buffer truncation
semantics, and the loud-refusal surface (oversized cohorts, update-
matrix aggregators, dense-only features).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager
from repro.config import FedConfig, TrainConfig
from repro.configs import get_config, scenario_for_population
from repro.core.engine import (FederatedTrainer, PopulationTrainer,
                               cohort_from_mask)
from repro.data import MNIST_LIKE, make_federated_image_dataset
from repro.data.population import DensePopulationData
from repro.models import build_model

N = 8


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("fedtest-cnn-mnist").replace(cnn_channels=(4, 8, 8),
                                                  cnn_hidden=16)
    model = build_model(cfg)
    data = make_federated_image_dataset(MNIST_LIKE, N, num_samples=800,
                                        global_test=200, seed=0)
    tc = TrainConfig(optimizer="sgd", lr=0.1, schedule="constant",
                     batch_size=8, grad_clip=0.0, remat=False)
    return model, data, tc


def _tree_equal(a, b) -> bool:
    la = jax.tree_util.tree_leaves(a)
    lb = jax.tree_util.tree_leaves(b)
    return len(la) == len(lb) and all(
        np.array_equal(np.asarray(x), np.asarray(y))
        for x, y in zip(la, lb))


# the equivalence matrix: attack/coalition regimes × sampling rates
CASES = {
    "no_attack": dict(attack="none"),
    "sign_flip": dict(attack="sign_flip", num_malicious=2),
    "mutual_boost": dict(attack="random_weights", num_malicious=2,
                         coalition="mutual_boost", coalition_size=2,
                         aggregator_kwargs={"use_trust": True,
                                            "trust_decay": 0.3,
                                            "report_clip": 0.2}),
}


@pytest.mark.parametrize("participation", [0.5, 0.75])
@pytest.mark.parametrize("case", sorted(CASES))
def test_cohort_matches_dense_bitwise(setup, case, participation):
    model, data, tc = setup
    fed = FedConfig(num_users=N, num_testers=3, local_steps=2,
                    participation=participation, cohort=N, **CASES[case])
    dense = FederatedTrainer(model, fed, tc, eval_batch=32)
    pop = PopulationTrainer(model, fed, tc, eval_batch=32)
    key = jax.random.PRNGKey(42)
    sd, sp = dense.init(key), pop.init(key)
    pd = DensePopulationData(data)
    for r in range(3):
        sd, md = dense.run_round(sd, data)
        sp, mp = pop.run_round(sp, pd)
        for name, a, b in [
            ("params", sd.global_params, sp.global_params),
            ("scores", sd.scores, sp.scores),
            ("weights", md["weights"], mp["weights"]),
            ("malicious_weight", md["malicious_weight"],
             mp["malicious_weight"]),
            ("local_loss", md["local_loss"], mp["local_loss"]),
            ("acc_matrix_mean", md["acc_matrix_mean"],
             mp["acc_matrix_mean"]),
        ]:
            assert _tree_equal(a, b), (
                f"{case} participation={participation} round {r}: "
                f"{name} diverged from the dense engine")


def test_tiled_crosstest_bitwise_matches_untiled(setup):
    model, data, tc = setup
    fed = FedConfig(num_users=N, num_testers=3, local_steps=2,
                    participation=0.75, cohort=N, attack="sign_flip",
                    num_malicious=2)
    a = PopulationTrainer(model, fed, tc, eval_batch=32)
    # block=3 does not divide C=8: exercises the wrap-padded last tile
    b = PopulationTrainer(model, fed, tc, eval_batch=32, crosstest_block=3)
    sa, sb = a.init(jax.random.PRNGKey(7)), b.init(jax.random.PRNGKey(7))
    pd = DensePopulationData(data)
    for _ in range(3):
        sa, _ = a.run_round(sa, pd)
        sb, _ = b.run_round(sb, pd)
    assert _tree_equal(sa, sb)


def test_population_checkpoint_resume_bit_identical(setup, tmp_path):
    model, data, tc = setup
    fed = FedConfig(num_users=N, num_testers=3, local_steps=2,
                    participation=0.5, cohort=4, attack="sign_flip",
                    num_malicious=2)
    pd = DensePopulationData(data)
    ref = PopulationTrainer(model, fed, tc, eval_batch=32)
    sA, _ = ref.run(jax.random.PRNGKey(0), pd, rounds=5, eval_every=5)

    mgr = CheckpointManager(str(tmp_path))
    first = PopulationTrainer(model, fed, tc, eval_batch=32)
    s2, _ = first.run(jax.random.PRNGKey(0), pd, rounds=2, eval_every=2)
    first.save_checkpoint(mgr, s2)
    fresh = PopulationTrainer(model, fed, tc, eval_batch=32)
    restored, step = fresh.restore_checkpoint(mgr)
    assert step == 2 and int(restored.round_idx) == 2
    sB, _ = fresh.run(None, pd, rounds=5, eval_every=5, state=restored)
    assert _tree_equal(sA, sB), (
        "mid-trajectory resume diverged from the uninterrupted run")


def test_testers_from_cohort_smoke(setup):
    model, data, tc = setup
    fed = FedConfig(num_users=N, num_testers=3, local_steps=1,
                    participation=0.5, cohort=4, attack="none")
    tr = PopulationTrainer(model, fed, tc, eval_batch=16,
                           testers_from_cohort=True)
    state = tr.init(jax.random.PRNGKey(1))
    pd = DensePopulationData(data)
    for _ in range(2):
        state, m = tr.run_round(state, pd)
    # cohort-recruited committees keep reports alive: the round's score
    # mass lands on the sampled clients instead of degenerating to zero
    assert float(jnp.sum(state.scores.scores)) > 0.0
    assert np.isfinite(float(m["acc_matrix_mean"]))


# ---------------------------------------------------------- cohort plan
def test_cohort_from_mask_untruncated_is_identity():
    mask = jnp.array([1., 0., 1., 1., 0., 0., 1., 0.])
    idx, valid, eff = cohort_from_mask(mask, 6)
    assert np.array_equal(np.asarray(idx), [0, 2, 3, 6, 8, 8])
    assert np.array_equal(np.asarray(valid), [1, 1, 1, 1, 0, 0])
    # when the draw fits the buffer the honoured mask IS the draw
    assert np.array_equal(np.asarray(eff), np.asarray(mask))


def test_cohort_from_mask_truncates_in_index_order():
    mask = jnp.array([1., 1., 0., 1., 1., 1.])
    idx, valid, eff = cohort_from_mask(mask, 3)
    assert np.array_equal(np.asarray(idx), [0, 1, 3])
    assert np.array_equal(np.asarray(valid), [1, 1, 1])
    # clients past the buffer revert to full non-sampled semantics
    assert np.array_equal(np.asarray(eff), [1, 1, 0, 1, 0, 0])


# --------------------------------------------------------- loud refusals
def test_cohort_larger_than_population_rejected():
    with pytest.raises(ValueError, match="cohort"):
        FedConfig(num_users=4, num_testers=2, cohort=5, participation=0.5)
    with pytest.raises(ValueError, match="cohort"):
        scenario_for_population("honest", population=4, cohort=8)


def test_cohort_with_full_participation_rejected():
    with pytest.raises(ValueError, match="participation"):
        FedConfig(num_users=8, cohort=4)


def test_coalition_indices_outside_population_rejected():
    with pytest.raises(ValueError, match="out of range"):
        FedConfig(num_users=8, num_testers=3,
                  coalition="mutual_boost",
                  coalition_kwargs={"indices": (2, 9)})


def test_population_refuses_update_matrix_aggregators(setup):
    model, _, tc = setup
    fed = FedConfig(num_users=N, num_testers=3, local_steps=2,
                    participation=0.5, cohort=4, aggregator="krum",
                    attack="none", num_malicious=2)
    with pytest.raises(ValueError, match="replication wall"):
        PopulationTrainer(model, fed, tc)


def test_population_refuses_eval_resample(setup):
    model, _, tc = setup
    fed = FedConfig(num_users=N, num_testers=3, local_steps=2,
                    participation=0.5, cohort=4, attack="none")
    with pytest.raises(ValueError, match="eval_resample"):
        PopulationTrainer(model, fed, tc, eval_resample_every=2)
