"""Durable-trainer service contract (DESIGN.md §9).

The tentpole guarantee: training N rounds straight and training k
rounds + save + restore + (N-k) rounds are THE SAME RUN — bitwise-equal
weights, scores, tester trust and malicious-weight trajectory. This
holds because the round body re-derives every key from the carried
``state.key`` and ``round_idx`` (``round_keys(fold_in(key, round))``),
so the only state that matters is exactly what the checkpoint stores.

The same must hold with availability faults active (the survival mask
comes from ``keys.fault``, part of the same schedule) and on the
ring/allgather exchange backends (subprocess, host-platform devices —
mirroring ``test_pod_parity.py``).
"""
import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager
from repro.config import FedConfig, TrainConfig
from repro.configs import get_config
from repro.core import FederatedTrainer
from repro.data import MNIST_LIKE, make_federated_image_dataset
from repro.launch.serve import load_serving_params
from repro.models import build_model


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("fedtest-cnn-mnist").replace(cnn_channels=(4, 8, 8),
                                                  cnn_hidden=16)
    model = build_model(cfg)
    data = make_federated_image_dataset(MNIST_LIKE, 6, num_samples=900,
                                        global_test=200, seed=0)
    tc = TrainConfig(optimizer="sgd", lr=0.1, schedule="constant",
                     batch_size=8, grad_clip=0.0, remat=False)
    fed = FedConfig(num_users=6, num_testers=3, num_malicious=2,
                    attack="sign_flip", attack_scale=4.0, rounds=12,
                    local_steps=4, seed=0)
    return cfg, model, data, tc, fed


def _trainer(model, fed, tc, **kw):
    return FederatedTrainer(model, fed, tc, eval_batch=64,
                            use_trust=True, **kw)


def _assert_states_equal(a, b):
    for la, lb in zip(jax.tree_util.tree_leaves(a),
                      jax.tree_util.tree_leaves(b)):
        np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))


# ----------------------------------------------------- resume identity
def test_resume_is_bit_identical(setup, tmp_path):
    """12 rounds straight == 6 + save + restore-in-a-fresh-trainer + 6:
    weights, scores, trust, rounds_seen, PRNG key, malicious_weight."""
    cfg, model, data, tc, fed = setup
    sA, hA = _trainer(model, fed, tc).run(jax.random.PRNGKey(0), data,
                                          rounds=12, eval_every=1)

    mgr = CheckpointManager(str(tmp_path))
    first = _trainer(model, fed, tc)
    s6, _ = first.run(jax.random.PRNGKey(0), data, rounds=6, eval_every=1)
    first.save_checkpoint(mgr, s6)

    fresh = _trainer(model, fed, tc)
    restored, step = fresh.restore_checkpoint(mgr)
    assert step == 6 and int(restored.round_idx) == 6
    sB, hB = fresh.run(None, data, rounds=12, eval_every=1,
                       state=restored)

    _assert_states_equal(sA, sB)
    assert int(sB.round_idx) == 12
    # the per-round trajectory matches too, not just the endpoint
    assert hA["malicious_weight"][6:] == hB["malicious_weight"]
    assert hA["global_accuracy"][6:] == hB["global_accuracy"]


def test_resume_bit_identical_under_faults(setup, tmp_path):
    """Faults draw from keys.fault — part of the same per-round key
    schedule — so a resumed run replays the identical drop pattern."""
    cfg, model, data, tc, fed = setup
    import dataclasses
    fed = dataclasses.replace(fed, fault="dropout", fault_rate=0.3)
    sA, _ = _trainer(model, fed, tc).run(jax.random.PRNGKey(0), data,
                                         rounds=10, eval_every=10)
    mgr = CheckpointManager(str(tmp_path))
    first = _trainer(model, fed, tc)
    s4, _ = first.run(jax.random.PRNGKey(0), data, rounds=4, eval_every=4)
    first.save_checkpoint(mgr, s4)
    fresh = _trainer(model, fed, tc)
    restored, _ = fresh.restore_checkpoint(mgr)
    sB, _ = fresh.run(None, data, rounds=10, eval_every=10,
                      state=restored)
    _assert_states_equal(sA, sB)


def test_resume_through_scanned_driver(setup, tmp_path):
    """The scanned multi-round driver resumes bit-identically with the
    single-round driver's trajectory (same body, same keys)."""
    cfg, model, data, tc, fed = setup
    sA, _ = _trainer(model, fed, tc).run(jax.random.PRNGKey(0), data,
                                         rounds=12, eval_every=12)
    mgr = CheckpointManager(str(tmp_path))
    first = _trainer(model, fed, tc, rounds_per_call=3)
    s6, _ = first.run(jax.random.PRNGKey(0), data, rounds=6, eval_every=6)
    first.save_checkpoint(mgr, s6)
    fresh = _trainer(model, fed, tc, rounds_per_call=3)
    restored, _ = fresh.restore_checkpoint(mgr)
    sB, _ = fresh.run(None, data, rounds=12, eval_every=12,
                      state=restored)
    _assert_states_equal(sA, sB)


def test_resume_bit_identical_with_compressor(setup, tmp_path):
    """Kill-and-resume over a compressed exchange (DESIGN.md §12): the
    per-client [N, D] error-feedback buffer is part of RoundState, so a
    restored run replays the identical compensated updates — weights,
    scores AND the feedback buffer itself are bitwise equal to the
    uninterrupted run."""
    cfg, model, data, tc, fed = setup
    import dataclasses
    fed = dataclasses.replace(fed, compressor="int8")
    sA, hA = _trainer(model, fed, tc).run(jax.random.PRNGKey(0), data,
                                          rounds=10, eval_every=1)
    assert sA.comp_state is not None and sA.comp_state.shape[0] == 6
    # a lossy wire actually engages the feedback path: residuals land
    assert np.abs(np.asarray(sA.comp_state)).max() > 0

    mgr = CheckpointManager(str(tmp_path))
    first = _trainer(model, fed, tc)
    s4, _ = first.run(jax.random.PRNGKey(0), data, rounds=4,
                      eval_every=1)
    first.save_checkpoint(mgr, s4)
    fresh = _trainer(model, fed, tc)
    restored, step = fresh.restore_checkpoint(mgr)
    assert step == 4
    # the checkpoint carried the buffer, not a re-zeroed template
    np.testing.assert_array_equal(np.asarray(restored.comp_state),
                                  np.asarray(s4.comp_state))
    sB, hB = fresh.run(None, data, rounds=10, eval_every=1,
                       state=restored)
    _assert_states_equal(sA, sB)      # includes comp_state leaf-wise
    np.testing.assert_array_equal(np.asarray(sA.comp_state),
                                  np.asarray(sB.comp_state))
    assert hA["malicious_weight"][4:] == hB["malicious_weight"]


# ------------------------------------------------- run() service hooks
def test_cadence_saves_during_run(setup, tmp_path):
    cfg, model, data, tc, fed = setup
    mgr = CheckpointManager(str(tmp_path), keep=10, save_every=2)
    tr = _trainer(model, fed, tc)
    tr.run(jax.random.PRNGKey(0), data, rounds=5, eval_every=5, ckpt=mgr)
    assert mgr.steps() == [2, 4]
    assert mgr.read_manifest() is not None   # written on first use


def test_should_stop_drains_cleanly(setup, tmp_path):
    """should_stop() ends the loop at a driver-call boundary; the
    returned state is at the completed round, resumable as usual."""
    cfg, model, data, tc, fed = setup
    calls = {"n": 0}

    def stop_after_two():
        calls["n"] += 1
        return calls["n"] > 2

    tr = _trainer(model, fed, tc)
    state, _ = tr.run(jax.random.PRNGKey(0), data, rounds=50,
                      eval_every=50, should_stop=stop_after_two)
    assert int(state.round_idx) == 2        # 2 rounds ran, then drained
    # saving at the actual completed index (not fed.rounds) keeps the
    # checkpoint resumable
    mgr = CheckpointManager(str(tmp_path))
    tr.save_checkpoint(mgr, state)
    assert mgr.latest_step() == 2


def test_state_dict_load_state_roundtrip(setup):
    cfg, model, data, tc, fed = setup
    tr = _trainer(model, fed, tc)
    state, _ = tr.run(jax.random.PRNGKey(0), data, rounds=2, eval_every=2)
    back = tr.load_state(tr.state_dict(state))
    _assert_states_equal(state, back)
    assert back.key.dtype == state.key.dtype
    assert back.scores.rounds_seen.dtype == jnp.int32


def test_restore_refuses_mismatched_run(setup, tmp_path):
    cfg, model, data, tc, fed = setup
    mgr = CheckpointManager(str(tmp_path))
    tr = _trainer(model, fed, tc)
    tr.save_checkpoint(mgr, tr.init(jax.random.PRNGKey(0)))
    import dataclasses
    other = _trainer(model, dataclasses.replace(fed, attack="none"), tc)
    with pytest.raises(ValueError, match="fed.attack"):
        other.restore_checkpoint(mgr)


# ------------------------------------------------------ fault dynamics
def test_targeted_fault_zeroes_weight_and_freezes_score(setup):
    """A dropped client contributes exactly zero aggregation weight and
    its score/rounds_seen freeze for the round (placement-aware
    ``targeted`` fault makes the drop set deterministic)."""
    cfg, model, data, tc, fed = setup
    import dataclasses
    fed = dataclasses.replace(fed, fault="targeted",
                              fault_kwargs={"size": 2,
                                            "placement": "first"})
    tr = _trainer(model, fed, tc)
    state = tr.init(jax.random.PRNGKey(0))
    s0 = np.asarray(state.scores.scores)
    new_state, m = tr.run_round(state, data)
    w = np.asarray(m["weights"])
    np.testing.assert_array_equal(w[:2], 0.0)
    assert w[2:].sum() == pytest.approx(1.0, abs=1e-4)
    s1 = np.asarray(new_state.scores.scores)
    np.testing.assert_array_equal(s1[:2], s0[:2])            # frozen
    assert float(m["dropped_fraction"]) == pytest.approx(2 / 6)


def test_dropped_fraction_zero_without_faults(setup):
    cfg, model, data, tc, fed = setup
    tr = _trainer(model, fed, tc)
    _, m = tr.run_round(tr.init(jax.random.PRNGKey(0)), data)
    assert float(m["dropped_fraction"]) == 0.0


# ------------------------------------------------------ serve read path
def test_serve_reads_latest_checkpoint(setup, tmp_path):
    cfg, model, data, tc, fed = setup
    mgr = CheckpointManager(str(tmp_path))
    with pytest.raises(FileNotFoundError):
        load_serving_params(mgr, model, wait_secs=0.0)
    tr = _trainer(model, fed, tc)
    state, _ = tr.run(jax.random.PRNGKey(0), data, rounds=2, eval_every=2)
    tr.save_checkpoint(mgr, state)
    params, step = load_serving_params(mgr, model, arch=cfg.name)
    assert step == 2
    _assert_states_equal(state.global_params, params)
    with pytest.raises(SystemExit, match="refusing"):
        load_serving_params(mgr, model, arch="some-other-arch")


# ------------------------------------- pod backends resume (subprocess)
POD_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import json
import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import Mesh

from repro.checkpoint import CheckpointManager, load_pytree, save_pytree
from repro.config import FedConfig, TrainConfig
from repro.configs import get_config
from repro.core.engine import (
    make_allgather_round, make_distributed_round, round_keys)
from repro.core.scoring import init_scores
from repro.data import MNIST_LIKE, make_federated_image_dataset, \
    sample_client_batches
from repro.models import build_model

N, ROUNDS, SPLIT = 4, 8, 4
cfg = get_config("fedtest-cnn-mnist").replace(cnn_channels=(4, 8, 8),
                                              cnn_hidden=16)
model = build_model(cfg)
fed = FedConfig(num_users=N, num_testers=N, num_malicious=1,
                attack="sign_flip", attack_scale=4.0, local_steps=4,
                fault="dropout", fault_rate=0.25, seed=0)
tc = TrainConfig(optimizer="sgd", lr=0.1, schedule="constant",
                 batch_size=8, grad_clip=0.0, remat=False)
data = make_federated_image_dataset(MNIST_LIKE, N, num_samples=1200,
                                    global_test=128, seed=0)
mesh = Mesh(np.asarray(jax.devices()[:N]), ("clients",))
tx, ty = data.test.xs[:, :64], data.test.ys[:, :64]
pk, run_key = jax.random.split(jax.random.PRNGKey(0))
ckpt_dir = %(ckpt_dir)r

out = {}
for exchange, make in [("ring", make_distributed_round),
                       ("allgather", make_allgather_round)]:
    round_fn = jax.jit(make(model, fed, tc, mesh,
                            counts=data.train.counts))

    def play(g, s, start, stop):
        for r in range(start, stop):
            key = jax.random.fold_in(run_key, r)
            bx, by = sample_client_batches(round_keys(key).batch,
                                           data.train, fed.local_steps,
                                           tc.batch_size)
            g, s, _ = round_fn(g, s, bx, by, tx, ty, key,
                               jnp.asarray(r, jnp.int32))
        return g, s

    gA, sA = play(model.init(pk), init_scores(N), 0, ROUNDS)

    # interrupted run: stop at SPLIT, checkpoint, restore, finish
    g, s = play(model.init(pk), init_scores(N), 0, SPLIT)
    mgr = CheckpointManager(os.path.join(ckpt_dir, exchange))
    mgr.save(SPLIT, {"g": g, "s": s})
    rest = mgr.restore({"g": g, "s": s})
    gB, sB = play(rest["g"], rest["s"], SPLIT, ROUNDS)

    same = all(bool(jnp.all(a == b)) for a, b in zip(
        jax.tree_util.tree_leaves((gA, sA)),
        jax.tree_util.tree_leaves((gB, sB))))
    out[exchange] = bool(same)
print(json.dumps(out))
"""


@pytest.mark.slow
def test_pod_backends_resume_bit_identical(tmp_path):
    """Ring and allgather runs interrupted at round 4, checkpointed
    through the manager and resumed, land bit-identically on the
    uninterrupted round-8 state — with a dropout fault active."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..",
                                     "src")
    script = POD_SCRIPT % {"ckpt_dir": str(tmp_path)}
    proc = subprocess.run([sys.executable, "-c", script], env=env,
                          capture_output=True, text=True, timeout=1500)
    assert proc.returncode == 0, proc.stderr[-3000:]
    out = json.loads(proc.stdout.strip().splitlines()[-1])
    assert out == {"ring": True, "allgather": True}
