"""Unified round engine: scanned multi-round driver + AttackContext seam.

The single-round and scanned drivers share one round body
(``RoundProgram.run`` on the local backend), so ``rounds_per_call > 1``
must reproduce the per-round trajectory bit-exactly while tracing the
body once; the :class:`AttackContext` threads the cross-testing signal
into ``Attack.corrupt`` so adaptive attacks (``adaptive_scale``) can
react to their own aggregation weight.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import FedConfig, TrainConfig
from repro.configs import get_config
from repro.core import FederatedTrainer
from repro.core.scoring import init_scores
from repro.data import MNIST_LIKE, make_federated_image_dataset
from repro.models import build_model
from repro.strategies import ATTACKS, Attack, register
from repro.strategies.base import AttackContext


@pytest.fixture(scope="module")
def tiny_setup():
    cfg = get_config("fedtest-cnn-mnist").replace(cnn_channels=(4, 8, 8),
                                                  cnn_hidden=16)
    model = build_model(cfg)
    data = make_federated_image_dataset(MNIST_LIKE, 4, num_samples=800,
                                        global_test=200, seed=0)
    tc = TrainConfig(optimizer="sgd", lr=0.1, schedule="constant",
                     batch_size=8, grad_clip=0.0, remat=False)
    return model, data, tc


# ------------------------------------------------------ scanned driver
def test_scanned_driver_matches_single_round_bitwise(tiny_setup):
    """lax.scan over rounds_per_call rounds == the same rounds dispatched
    one by one — same body, same keys, bit-identical final state."""
    model, data, tc = tiny_setup
    fed = FedConfig(num_users=4, num_testers=2, num_malicious=1,
                    local_steps=2, attack="sign_flip", attack_scale=4.0)
    single = FederatedTrainer(model, fed, tc, eval_batch=64)
    scanned = FederatedTrainer(model, fed, tc, eval_batch=64,
                               rounds_per_call=4)
    s_state, s_hist = single.run(jax.random.PRNGKey(0), data, rounds=8)
    c_state, c_hist = scanned.run(jax.random.PRNGKey(0), data, rounds=8)
    for a, b in zip(jax.tree_util.tree_leaves(s_state.global_params),
                    jax.tree_util.tree_leaves(c_state.global_params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    np.testing.assert_array_equal(np.asarray(s_state.scores.scores),
                                  np.asarray(c_state.scores.scores))
    assert int(c_state.round_idx) == 8
    # one fused program per chunk: the body traced exactly once
    assert scanned.num_traces == 1
    # chunk-boundary evals line up with the single-round driver's
    assert c_hist["round"] == [4, 8]
    for r, ga in zip(c_hist["round"], c_hist["global_accuracy"]):
        assert ga == pytest.approx(
            s_hist["global_accuracy"][s_hist["round"].index(r)])


def test_scanned_driver_remainder_rounds(tiny_setup):
    """rounds not divisible by rounds_per_call: the remainder falls back
    to the single-round driver (a second compiled program, one trace)."""
    model, data, tc = tiny_setup
    fed = FedConfig(num_users=4, num_testers=2, local_steps=2,
                    attack="none")
    trainer = FederatedTrainer(model, fed, tc, eval_batch=64,
                               rounds_per_call=3)
    state, hist = trainer.run(jax.random.PRNGKey(0), data, rounds=5)
    assert int(state.round_idx) == 5
    assert trainer.num_traces == 2          # scan body + single body


# --------------------------------------------------- AttackContext seam
def test_attack_context_reaches_corrupt(tiny_setup):
    """The engine hands every corruption the round's AttackContext."""
    model, data, tc = tiny_setup
    seen = {}

    name = "test_only_ctx_probe"
    if name not in ATTACKS:
        @register(ATTACKS, name)
        class CtxProbe(Attack):
            def corrupt(self, key, trained, global_params, ctx=None,
                        client_idx=None):
                seen["ctx"] = ctx
                seen["client_idx"] = client_idx
                return trained

    fed = FedConfig(num_users=4, num_testers=2, num_malicious=1,
                    local_steps=2, attack=name)
    trainer = FederatedTrainer(model, fed, tc, eval_batch=64)
    state = trainer.init(jax.random.PRNGKey(0))
    trainer.run_round(state, data)
    ctx = seen["ctx"]
    assert isinstance(ctx, AttackContext)
    assert ctx.num_users == 4
    assert ctx.scores.shape == (4,) and ctx.weights.shape == (4,)
    assert seen["client_idx"] == 3          # placement='last', m=1


def test_adaptive_scale_engages_on_weight_threshold():
    """adaptive_scale corrupts iff its own implied weight clears the
    threshold fraction of the uniform share."""
    atk = ATTACKS.build("adaptive_scale", {"weight_threshold": 0.5},
                        {"num_malicious": 1, "scale": 4.0})
    g = {"w": jnp.zeros((3,), jnp.float32)}
    trained = {"w": jnp.ones((3,), jnp.float32)}
    key = jax.random.PRNGKey(0)
    mk = lambda w: AttackContext(scores=jnp.asarray(w),
                                 weights=jnp.asarray(w),
                                 round_idx=jnp.zeros((), jnp.int32))
    # weight above 0.5/4: attack (sign-flip at scale 4 -> -4)
    hot = atk.corrupt(key, trained, g, mk([0.25, 0.25, 0.25, 0.25]), 3)
    np.testing.assert_allclose(np.asarray(hot["w"]), -4.0)
    # suppressed below the threshold: send the honest update
    cold = atk.corrupt(key, trained, g, mk([0.33, 0.33, 0.33, 0.01]), 3)
    np.testing.assert_allclose(np.asarray(cold["w"]), 1.0)
    # no context (legacy caller): unconditional corruption
    legacy = atk.corrupt(key, trained, g)
    np.testing.assert_allclose(np.asarray(legacy["w"]), -4.0)


def test_adaptive_scale_oscillates_against_fedtest(tiny_setup):
    """End-to-end: once FedTest suppresses the adaptive attacker it goes
    honest (its next corruption is withheld), so the engine runs jitted
    with no retrace and the malicious weight stays bounded."""
    model, data, tc = tiny_setup
    data = make_federated_image_dataset(
        MNIST_LIKE, 4, num_samples=800, global_test=200, seed=0,
        partition_kwargs={"min_classes": 8, "max_classes": 10})
    fed = FedConfig(num_users=4, num_testers=3, num_malicious=1,
                    local_steps=6, attack="adaptive_scale",
                    attack_scale=4.0)
    trainer = FederatedTrainer(model, fed, tc, eval_batch=64)
    state = trainer.init(jax.random.PRNGKey(1))
    mal_w = []
    for _ in range(6):
        state, metrics = trainer.run_round(state, data)
        mal_w.append(float(metrics["malicious_weight"]))
    assert trainer.num_traces == 1
    assert all(np.isfinite(mal_w))
    # the defence still caps the adaptive attacker below uniform share
    assert mal_w[-1] < 0.25, mal_w


# ------------------------------------------------- engine odds and ends
def test_lying_testers_run_on_every_backend_config(tiny_setup):
    """The unified program applies lies on the replicated [K, N] matrix,
    so lying_testers is no longer a single-host-only feature."""
    from jax.sharding import Mesh
    from repro.core.engine import make_pod_round

    model, data, tc = tiny_setup
    mesh = Mesh(np.asarray(jax.devices()[:1]), ("clients",))
    fed = FedConfig(num_users=1, num_testers=1, lying_testers=1,
                    local_steps=2)
    # builds without the historical ValueError; multi-device tracing is
    # exercised by the shard_map subprocess tests
    fn = make_pod_round(model, fed, tc, mesh)
    assert callable(fn)


def test_shared_eval_fn_is_hoisted(tiny_setup, monkeypatch):
    """make_eval_fn runs exactly once, in the program constructor — the
    round body and the global-accuracy closure must reuse that instance
    instead of rebuilding it per trace (the pre-unification bug)."""
    import repro.core.engine.program as program_mod
    model, data, tc = tiny_setup
    calls = []
    real = program_mod.make_eval_fn
    monkeypatch.setattr(program_mod, "make_eval_fn",
                        lambda m: (calls.append(1), real(m))[1])
    fed = FedConfig(num_users=4, num_testers=2, local_steps=2)
    trainer = FederatedTrainer(model, fed, tc, eval_batch=64)
    state = trainer.init(jax.random.PRNGKey(0))
    state, _ = trainer.run_round(state, data)
    acc = trainer.global_accuracy(state, data, max_samples=64)
    assert 0.0 <= acc <= 1.0
    assert len(calls) == 1, f"make_eval_fn built {len(calls)}x"
