"""Coalition adversaries + tester-selection strategies (DESIGN.md §7).

Covers the COALITIONS registry contract, the mutual_boost masked-matrix
report transform, the sybil-split scale arithmetic, the composed attack
seam (member ∪ independent malicious set), the end-to-end suppression of
the ``mutual_boost_vs_fedtest`` preset, and the new SELECTORS
(``uniform`` / ``score_weighted`` / ``coverage``) — mirroring the
``tests/test_strategies.py`` patterns (KeyError listing, under-jit
validity, no-retrace).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import FedConfig, TrainConfig
from repro.configs import get_config, get_scenario, scenario_for_pod
from repro.core import FederatedTrainer
from repro.core.scoring import clip_reports_to_consensus
from repro.data import MNIST_LIKE, make_federated_image_dataset
from repro.models import build_model
from repro.strategies import ATTACKS, COALITIONS, SELECTORS
from repro.strategies.base import AttackContext

N_USERS = 8


# ----------------------------------------------------------------- registry
def test_unknown_coalition_raises_keyerror_listing_registered():
    with pytest.raises(KeyError) as e:
        COALITIONS.get("definitely_not_registered")
    msg = str(e.value)
    assert "definitely_not_registered" in msg
    assert "mutual_boost" in msg and "sybil_split" in msg


def test_fedconfig_validates_coalition_name():
    with pytest.raises(KeyError, match="full_collusion"):
        FedConfig(coalition="nope")
    with pytest.raises(ValueError, match="coalition_size"):
        FedConfig(num_users=4, num_testers=2, coalition_size=4)
    # a named coalition with no members would silently measure nothing
    with pytest.raises(ValueError, match="needs members"):
        FedConfig(coalition="mutual_boost")
    # ...as would members with no named coalition
    with pytest.raises(ValueError, match="coalition="):
        FedConfig(coalition_size=2)
    # ...but members via kwargs are fine
    FedConfig(coalition="mutual_boost",
              coalition_kwargs={"indices": (1, 2)})
    # kwargs-based membership gets the same bounds checks as
    # coalition_size (no full-membership coalition, no stray indices)
    with pytest.raises(ValueError, match="members < N"):
        FedConfig(num_users=4, num_testers=2, coalition="mutual_boost",
                  coalition_kwargs={"size": 4})
    with pytest.raises(ValueError, match="out of range"):
        FedConfig(num_users=4, num_testers=2, coalition="mutual_boost",
                  coalition_kwargs={"indices": (10,)})


def test_member_placement_matches_attack_placement():
    coal = COALITIONS.build("mutual_boost",
                            {"size": 2, "placement": "first"})
    assert coal.members(6) == (0, 1)
    coal = COALITIONS.build("sybil_split", {"indices": (1, 4)})
    assert coal.members(6) == (1, 4)
    np.testing.assert_allclose(np.asarray(coal.member_mask(6)),
                               [0, 1, 0, 0, 1, 0])
    # the inactive coalition has no members whatever size says
    assert COALITIONS.build("none", {"size": 3}).members(6) == ()


# ------------------------------------------------------- composed attack seam
def test_compose_unions_malicious_sets_and_routes_corruption():
    """Coalition members ∪ independent attackers; the coalition's model
    attack takes precedence on members, the base attack keeps its own
    slots, report-only members stay model-honest but count as malicious."""
    base = ATTACKS.build("random_weights", {"indices": (0,)})
    sybil = COALITIONS.build("sybil_split",
                             {"indices": (4, 5), "scale": 8.0})
    composed = sybil.compose(base, 6)
    assert composed.malicious_indices(6) == (0, 4, 5)

    boost = COALITIONS.build("mutual_boost", {"indices": (4, 5)})
    composed = boost.compose(base, 6)
    assert composed.malicious_indices(6) == (0, 4, 5)
    stacked = {"p": jax.random.normal(jax.random.PRNGKey(0), (6, 4, 3))}
    gp = {"p": jnp.zeros((4, 3))}
    out = composed.apply(jax.random.PRNGKey(1), stacked, gp)
    changed = [bool(np.abs(np.asarray(out["p"][c] - stacked["p"][c])).max()
                    > 1e-4) for c in range(6)]
    # report-space-only members (4, 5) keep their honest models; the
    # independent attacker (0) is still corrupted
    assert changed == [True, False, False, False, False, False]


def test_inactive_coalition_compose_is_identity():
    base = ATTACKS.build("sign_flip", {}, {"num_malicious": 1})
    assert COALITIONS.build("none").compose(base, 6) is base


def test_sybil_split_scales_per_member_deviation_down():
    """Each member sends a 1/|C| share of the full-scale poison: the
    per-member deviation from the global model shrinks with the split
    while the coalition's summed deviation keeps the full scale."""
    gp = {"p": jnp.zeros((3, 2))}
    trained = {"p": jnp.ones((3, 2))}
    key = jax.random.PRNGKey(0)
    full = ATTACKS.build("scaled_collusion",
                         {"num_malicious": 1, "scale": 8.0})
    quarter = ATTACKS.build("scaled_collusion",
                            {"num_malicious": 4, "scale": 8.0})
    assert quarter.split == 4
    dev_full = np.asarray(full.corrupt(key, trained, gp)["p"])
    dev_quarter = np.asarray(quarter.corrupt(key, trained, gp)["p"])
    np.testing.assert_allclose(dev_quarter * 4.0, dev_full, rtol=1e-6)
    # sign-flip direction: the poison points against the honest update
    assert (dev_full < 0).all()


# ------------------------------------------------- mutual_boost transform
def _actx(scores):
    scores = jnp.asarray(scores, jnp.float32)
    n = scores.shape[0]
    from repro.core.scoring import ScoreState
    state = ScoreState(scores=scores, rounds_seen=jnp.ones((), jnp.int32),
                       tester_trust=jnp.ones((n,), jnp.float32))
    from repro.core.scoring import score_weights
    return AttackContext(scores=scores, weights=score_weights(state),
                         round_idx=jnp.ones((), jnp.int32))


def test_mutual_boost_masked_matrix_equation():
    """The DESIGN.md §7 transform: member tester rows report boost_to
    for members and deflate_to for the top-scoring honest clients;
    honest rows and untargeted entries pass through untouched."""
    n = 6
    coal = COALITIONS.build("mutual_boost",
                            {"indices": (4, 5), "boost_to": 0.9,
                             "deflate_to": 0.1, "deflate_top": 1})
    acc = jnp.full((3, n), 0.5)
    # testers: 4 (member, liar row), 0 and 1 (honest rows)
    tester_ids = jnp.asarray([4, 0, 1])
    # client 2 is the top-scoring honest client -> the defamation target
    ctx = _actx([0.3, 0.2, 0.8, 0.1, 0.9, 0.9])
    out = np.asarray(coal.transform_reports(jax.random.PRNGKey(0), acc,
                                            tester_ids, ctx))
    # liar row: members boosted, top-honest deflated, rest untouched
    np.testing.assert_allclose(out[0], [0.5, 0.5, 0.1, 0.5, 0.9, 0.9])
    # honest rows bit-identical
    np.testing.assert_allclose(out[1:], np.asarray(acc)[1:])
    # members are never the defamation target even with top scores
    assert out[0, 4] == pytest.approx(0.9) and out[0, 5] == pytest.approx(0.9)


def test_mutual_boost_deflate_top_zero_is_boost_only():
    """deflate_top=0 must mean no defamation at all, not top-1."""
    coal = COALITIONS.build("mutual_boost",
                            {"indices": (4, 5), "boost_to": 0.9,
                             "deflate_top": 0})
    acc = jnp.full((2, 6), 0.5)
    out = np.asarray(coal.transform_reports(
        jax.random.PRNGKey(0), acc, jnp.asarray([4, 0]),
        _actx([0.3, 0.2, 0.8, 0.1, 0.9, 0.9])))
    np.testing.assert_allclose(out[0], [0.5, 0.5, 0.5, 0.5, 0.9, 0.9])
    np.testing.assert_allclose(out[1], np.asarray(acc)[1])
    with pytest.raises(ValueError, match="deflate_top"):
        COALITIONS.build("mutual_boost", {"size": 2, "deflate_top": -1})


def test_legacy_selector_without_scores_kwarg_still_works():
    """Third-party selectors written against the pre-scores signature
    must keep working: the engine inspects the signature pre-trace and
    only forwards scores to policies that accept it."""
    from repro.strategies import SELECTORS, Selector, register

    name = "test_only_legacy_selector"
    if name not in SELECTORS:
        @register(SELECTORS, name)
        class Legacy(Selector):
            def select(self, key, num_users, num_testers, round_idx):
                return jnp.arange(num_testers, dtype=jnp.int32)

    from repro.core.engine.program import RoundProgram
    from repro.config import TrainConfig
    cfg = get_config("fedtest-cnn-mnist").replace(cnn_channels=(4, 8, 8),
                                                  cnn_hidden=16)
    program = RoundProgram(
        build_model(cfg),
        FedConfig(num_users=4, num_testers=2, selector=name),
        TrainConfig())
    assert not program._selector_takes_scores
    from repro.core.engine.program import round_keys
    ids, _ = program.select_round(round_keys(jax.random.PRNGKey(0)),
                                  jnp.zeros((), jnp.int32),
                                  scores=jnp.zeros(4))
    np.testing.assert_array_equal(np.asarray(ids), [0, 1])


def test_coverage_seed_threads_from_fedconfig():
    """resolve_strategies hands the run seed to schedule-based
    selectors: different seeds give different coverage schedules."""
    from repro.core.engine.program import resolve_strategies
    ids = {}
    for seed in (0, 1):
        _, _, sel = resolve_strategies(
            FedConfig(num_users=12, num_testers=3, selector="coverage",
                      seed=seed))
        assert sel.seed == seed
        ids[seed] = [np.asarray(sel.select(jax.random.PRNGKey(9), 12, 3,
                                           jnp.asarray(r))).tolist()
                     for r in range(4)]
    assert ids[0] != ids[1]


def test_mutual_boost_no_member_testing_is_identity():
    coal = COALITIONS.build("mutual_boost", {"indices": (4, 5)})
    acc = jax.random.uniform(jax.random.PRNGKey(0), (3, 6))
    out = coal.transform_reports(jax.random.PRNGKey(1), acc,
                                 jnp.asarray([0, 1, 2]),
                                 _actx(np.zeros(6)))
    np.testing.assert_array_equal(np.asarray(out), np.asarray(acc))


def test_report_clip_bounds_any_single_report():
    """Consensus winsorisation: a 1.0-boost / 0.0-smear row moves no
    report further than ``clip`` from the per-client median."""
    acc = jnp.asarray([[0.8, 0.1], [0.7, 0.1], [1.0, 0.0]])  # row 2 lies
    out = np.asarray(clip_reports_to_consensus(acc, 0.1))
    np.testing.assert_allclose(out[2], [0.9, 0.0], atol=1e-6)
    # honest reports near consensus are exact
    np.testing.assert_allclose(out[0], [0.8, 0.1], atol=1e-6)


# --------------------------------------------------------------- selectors
def test_new_selectors_return_valid_ids_under_jit():
    key = jax.random.PRNGKey(0)
    scores = jnp.asarray(np.linspace(0.1, 1.0, 10), jnp.float32)
    for name in ("uniform", "score_weighted", "coverage"):
        sel = SELECTORS.build(name)
        ids = np.asarray(jax.jit(
            lambda k, r: sel.select(k, 10, 4, r, scores=scores)
        )(key, jnp.asarray(2)))
        assert ids.shape == (4,)
        assert len(set(ids.tolist())) == 4, name
        assert ((ids >= 0) & (ids < 10)).all(), name


def test_score_weighted_prefers_high_scores():
    """Gumbel-top-k sampling ∝ scores: the top-scoring client testers
    far more often than the bottom one; the zero-score init degrades to
    a uniform draw (every client still reachable)."""
    sel = SELECTORS.build("score_weighted")
    scores = jnp.asarray([0.01] * 9 + [1.0], jnp.float32)
    hits = np.zeros(10)
    for r in range(64):
        ids = np.asarray(sel.select(jax.random.PRNGKey(r), 10, 3,
                                    jnp.asarray(r), scores=scores))
        hits[ids] += 1
    assert hits[9] > 55            # ~always selected
    assert hits[:9].max() < hits[9]
    # all-zero scores: uniform fallback still reaches everyone
    hits = np.zeros(10)
    for r in range(64):
        ids = np.asarray(sel.select(jax.random.PRNGKey(r), 10, 3,
                                    jnp.asarray(r),
                                    scores=jnp.zeros(10)))
        hits[ids] += 1
    assert (hits > 0).all()


def test_coverage_visits_every_client_within_ceil_n_over_k():
    for n, k in ((10, 4), (8, 2), (7, 3)):
        sel = SELECTORS.build("coverage")
        cycle = -(-n // k)
        seen = set()
        for r in range(cycle):
            seen.update(np.asarray(
                sel.select(jax.random.PRNGKey(0), n, k,
                           jnp.asarray(r))).tolist())
        assert seen == set(range(n)), (n, k)


def test_coverage_reshuffles_across_cycles():
    sel = SELECTORS.build("coverage")
    n, k = 12, 3
    cycle = n // k
    first = [np.asarray(sel.select(jax.random.PRNGKey(0), n, k,
                                   jnp.asarray(r))).tolist()
             for r in range(cycle)]
    second = [np.asarray(sel.select(jax.random.PRNGKey(0), n, k,
                                    jnp.asarray(cycle + r))).tolist()
              for r in range(cycle)]
    assert sorted(sum(first, [])) == sorted(sum(second, []))  # coverage
    assert first != second                                     # reshuffled


# ---------------------------------------------------------------- end-to-end
@pytest.fixture(scope="module")
def smoke_setup():
    cfg = get_config("fedtest-cnn-mnist").replace(
        cnn_channels=(8, 16, 16), cnn_hidden=32)
    model = build_model(cfg)
    data = make_federated_image_dataset(
        MNIST_LIKE, N_USERS, num_samples=2400, global_test=300, seed=0,
        partition_kwargs={"min_classes": 8, "max_classes": 10})
    tc = TrainConfig(optimizer="sgd", lr=0.1, schedule="constant",
                     batch_size=16, grad_clip=0.0, remat=False)
    return model, data, tc


def _refit(name, **overrides):
    """The presets refit to the 8-user test federation (the dynamics
    configuration of EXPERIMENTS.md §Paper-validation)."""
    fed = get_scenario(name)
    return dataclasses.replace(
        fed, num_users=N_USERS, num_testers=5,
        num_malicious=min(fed.num_malicious, 2), coalition_size=2,
        local_steps=6, **overrides)


def test_mutual_boost_preset_suppressed_by_round_8(smoke_setup):
    """The acceptance dynamics: the defended preset (trust consensus +
    consensus-clipped reports) drives the lying coalition's aggregate
    weight below 0.1 by round 8 (DESIGN.md §7)."""
    model, data, tc = smoke_setup
    fed = _refit("mutual_boost_vs_fedtest")
    trainer = FederatedTrainer(model, fed, tc, eval_batch=64)
    state = trainer.init(jax.random.PRNGKey(0))
    for _ in range(8):
        state, metrics = trainer.run_round(state, data)
    assert float(metrics["malicious_weight"]) < 0.1
    assert trainer.num_traces == 1


def test_sybil_split_preset_suppressed(smoke_setup):
    model, data, tc = smoke_setup
    fed = _refit("sybil_split_vs_fedtest")
    trainer = FederatedTrainer(model, fed, tc, eval_batch=64)
    # the composed seam reports the members as the malicious set
    assert trainer.attack.malicious_indices(N_USERS) == (6, 7)
    state = trainer.init(jax.random.PRNGKey(0))
    for _ in range(8):
        state, metrics = trainer.run_round(state, data)
    assert float(metrics["malicious_weight"]) < 0.1


def test_coalition_no_retrace_across_rounds(smoke_setup):
    """Coalition resolution is pre-trace like every other strategy: N
    rounds through the composed seam + report transform -> one trace;
    same for the score_weighted selector's scores threading."""
    model, data, tc = smoke_setup
    fed = _refit("full_collusion_vs_fedtest", selector="score_weighted")
    trainer = FederatedTrainer(model, fed, tc, eval_batch=64)
    state = trainer.init(jax.random.PRNGKey(0))
    for _ in range(3):
        state, _ = trainer.run_round(state, data)
    assert trainer.num_traces == 1


def test_scenario_for_pod_refits_coalition_by_fraction():
    fed = scenario_for_pod("mutual_boost_vs_fedtest", 4)
    assert fed.coalition_size == 1 and fed.num_malicious == 1
    fed = scenario_for_pod("mutual_boost_vs_fedtest", 8)
    assert fed.coalition_size == 2 and fed.num_malicious == 2
    # growing the pod grows both halves of the paired adversary
    fed = scenario_for_pod("mutual_boost_vs_fedtest", 40)
    assert fed.coalition_size == 8 and fed.num_malicious == 8
    fed = scenario_for_pod("sybil_split_vs_fedtest", 8)
    assert fed.coalition_size == 2 and fed.num_malicious == 0
    # non-coalition presets keep the historical clamp
    fed = scenario_for_pod("paper_random_weights", 4)
    assert fed.coalition_size == 0 and fed.num_malicious == 3
    # a 1-client pod cannot hold a coalition: the refit degrades to a
    # valid honest config instead of tripping the needs-members check
    fed = scenario_for_pod("mutual_boost_vs_fedtest", 1)
    assert fed.coalition == "none" and fed.coalition_size == 0


def test_scenario_for_pod_refits_kwargs_based_membership():
    """A scenario whose members come from coalition_kwargs (size= or
    indices=) must survive the pod refit: the refit takes over the
    membership (stale indices could out-range the smaller pod)."""
    import repro.configs.scenarios as sc
    sc.SCENARIOS["_test_kwargs_coalition"] = FedConfig(
        num_users=20, num_testers=5, attack="none",
        coalition="mutual_boost",
        coalition_kwargs={"indices": (17, 18, 19)})
    try:
        fed = scenario_for_pod("_test_kwargs_coalition", 4)
        assert fed.coalition == "mutual_boost"
        assert fed.coalition_size == 1            # 3/20 -> ~15% of 4
        kw = dict(fed.coalition_kwargs)
        assert "indices" not in kw and "size" not in kw
        # the refit config resolves to in-range members
        from repro.core.engine.program import resolve_coalition
        assert resolve_coalition(fed).members(4) == (3,)
    finally:
        del sc.SCENARIOS["_test_kwargs_coalition"]


def test_coalition_attack_corrupt_without_client_idx_degrades():
    """Legacy corrupt(key, trained, gp) calls (no client identity) fall
    back to the unconditional coordinated corruption instead of
    broadcasting a member mask into the leaves."""
    gp = {"p": jnp.zeros((3, 2))}
    trained = {"p": jnp.ones((3, 2))}
    key = jax.random.PRNGKey(0)
    sybil = COALITIONS.build("sybil_split", {"size": 2, "scale": 8.0})
    composed = sybil.compose(ATTACKS.build("none"), 6)
    want = sybil.model_attack().corrupt(key, trained, gp)
    got = composed.corrupt(key, trained, gp)
    np.testing.assert_array_equal(np.asarray(got["p"]),
                                  np.asarray(want["p"]))
    # report-only coalition: degrades to the base attack (here: none)
    boost = COALITIONS.build("mutual_boost", {"size": 2})
    got = boost.compose(ATTACKS.build("none"), 6).corrupt(key, trained, gp)
    np.testing.assert_array_equal(np.asarray(got["p"]),
                                  np.asarray(trained["p"]))


def test_fedtest_aggregator_validates_defence_kwargs():
    from repro.strategies import AGGREGATORS
    with pytest.raises(ValueError, match="report_clip"):
        AGGREGATORS.build("fedtest", {"report_clip": -0.2})
    with pytest.raises(ValueError, match="trust_decay"):
        AGGREGATORS.build("fedtest", {"trust_decay": 1.5})


def test_coalition_attack_reresolves_indices_per_size():
    """malicious_indices honors its num_users argument (the Attack
    contract) instead of returning the compose-time union."""
    base = ATTACKS.build("none")
    coal = COALITIONS.build("mutual_boost", {"size": 2})  # last-2
    composed = coal.compose(base, 8)
    assert composed.malicious_indices(8) == (6, 7)
    assert composed.malicious_indices(4) == (2, 3)
    np.testing.assert_allclose(np.asarray(composed.malicious_mask(4)),
                               [0, 0, 1, 1])
