"""Config registry: every assigned architecture loads with the exact
assigned hyper-parameters, and analytic param counts land in the right
ballpark for the named model sizes."""
import pytest

from repro.config import INPUT_SHAPES, reduce_for_smoke
from repro.configs import get_config, list_configs

ASSIGNED = {
    "whisper-base": dict(num_layers=6, d_model=512, num_heads=8,
                         num_kv_heads=8, d_ff=2048, vocab_size=51865),
    "qwen3-moe-30b-a3b": dict(num_layers=48, d_model=2048, num_heads=32,
                              num_kv_heads=4, d_ff=768, vocab_size=151936,
                              num_experts=128, num_experts_per_tok=8),
    "qwen3-1.7b": dict(num_layers=28, d_model=2048, num_heads=16,
                       num_kv_heads=8, d_ff=6144, vocab_size=151936,
                       qk_norm=True),
    "mamba2-2.7b": dict(num_layers=64, d_model=2560, d_ff=0,
                        vocab_size=50280, ssm_state=128),
    "qwen2-0.5b": dict(num_layers=24, d_model=896, num_heads=14,
                       num_kv_heads=2, d_ff=4864, vocab_size=151936,
                       qkv_bias=True),
    "qwen1.5-110b": dict(num_layers=80, d_model=8192, num_heads=64,
                         num_kv_heads=8, d_ff=49152, vocab_size=152064,
                         qkv_bias=True),
    "qwen2-72b": dict(num_layers=80, d_model=8192, num_heads=64,
                      num_kv_heads=8, d_ff=29568, vocab_size=152064,
                      qkv_bias=True),
    "jamba-1.5-large-398b": dict(num_layers=72, d_model=8192, num_heads=64,
                                 num_kv_heads=8, d_ff=24576,
                                 vocab_size=65536, num_experts=16,
                                 num_experts_per_tok=2),
    "pixtral-12b": dict(num_layers=40, d_model=5120, num_heads=32,
                        num_kv_heads=8, d_ff=14336, vocab_size=131072),
    "granite-moe-1b-a400m": dict(num_layers=24, d_model=1024, num_heads=16,
                                 num_kv_heads=8, d_ff=512, vocab_size=49155,
                                 num_experts=32, num_experts_per_tok=8),
}

# target total-param counts (fraction tolerance): sanity that the configs
# really describe the named model sizes
SIZES = {
    "qwen2-0.5b": (0.5e9, 0.45),
    "qwen3-1.7b": (1.7e9, 0.45),
    "mamba2-2.7b": (2.7e9, 0.35),
    "pixtral-12b": (12e9, 0.3),
    "qwen3-moe-30b-a3b": (30e9, 0.3),
    "qwen2-72b": (72e9, 0.25),
    "qwen1.5-110b": (110e9, 0.25),
    "jamba-1.5-large-398b": (398e9, 0.3),
}


@pytest.mark.parametrize("arch", sorted(ASSIGNED))
def test_assigned_hyperparams(arch):
    cfg = get_config(arch)
    for field, expected in ASSIGNED[arch].items():
        assert getattr(cfg, field) == expected, (arch, field)


def test_all_ids_resolve():
    for arch in list_configs():
        cfg = get_config(arch)
        assert cfg.name


@pytest.mark.parametrize("arch", sorted(SIZES))
def test_param_counts_match_model_size(arch):
    cfg = get_config(arch)
    target, tol = SIZES[arch]
    n = cfg.param_count()
    assert abs(n - target) / target < tol, (arch, n, target)


def test_moe_active_params_much_smaller():
    cfg = get_config("qwen3-moe-30b-a3b")
    assert cfg.active_param_count() < 0.25 * cfg.param_count()


def test_input_shapes_assigned():
    assert INPUT_SHAPES["train_4k"].seq_len == 4096
    assert INPUT_SHAPES["train_4k"].global_batch == 256
    assert INPUT_SHAPES["prefill_32k"].global_batch == 32
    assert INPUT_SHAPES["decode_32k"].global_batch == 128
    assert INPUT_SHAPES["long_500k"].seq_len == 524288


@pytest.mark.parametrize("arch", sorted(ASSIGNED))
def test_smoke_reduction_bounds(arch):
    cfg = reduce_for_smoke(get_config(arch))
    assert cfg.num_layers <= 2
    assert cfg.d_model <= 512
    assert cfg.num_experts <= 4
