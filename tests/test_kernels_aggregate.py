"""Weighted-aggregate kernel sweep + pytree aggregation properties."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.kernels.weighted_aggregate.kernel import weighted_aggregate_pallas
from repro.kernels.weighted_aggregate.ops import (
    aggregate_pytree, weighted_aggregate)
from repro.kernels.weighted_aggregate.ref import weighted_aggregate_ref


@pytest.mark.parametrize("C,M,bm", [(4, 1024, 256), (20, 4096, 1024),
                                    (3, 511, 128), (1, 64, 64)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_kernel_matches_ref(C, M, bm, dtype):
    x = jax.random.normal(jax.random.PRNGKey(0), (C, M),
                          jnp.float32).astype(dtype)
    w = jax.random.uniform(jax.random.PRNGKey(1), (C,))
    ref = weighted_aggregate_ref(x, w)
    out = weighted_aggregate(x, w, impl="pallas", block_m=bm,
                             interpret=True)
    tol = 2e-2 if dtype == jnp.bfloat16 else 1e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               atol=tol, rtol=tol)


@settings(max_examples=25, deadline=None)
@given(c=st.integers(1, 8), m=st.integers(1, 300),
       seed=st.integers(0, 2 ** 16))
def test_kernel_matches_ref_hypothesis(c, m, seed):
    x = jax.random.normal(jax.random.PRNGKey(seed), (c, m))
    w = jax.random.uniform(jax.random.PRNGKey(seed + 1), (c,))
    ref = weighted_aggregate_ref(x, w)
    out = weighted_aggregate(x, w, impl="pallas", block_m=64,
                             interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-5,
                               rtol=1e-5)


def test_pytree_onehot_weight_selects_client():
    tree = {"a": jax.random.normal(jax.random.PRNGKey(0), (4, 3, 5)),
            "b": jax.random.normal(jax.random.PRNGKey(1), (4, 7))}
    w = jnp.array([0.0, 1.0, 0.0, 0.0])
    agg = aggregate_pytree(tree, w, impl="naive")
    np.testing.assert_allclose(np.asarray(agg["a"]),
                               np.asarray(tree["a"][1]), atol=1e-6)
    np.testing.assert_allclose(np.asarray(agg["b"]),
                               np.asarray(tree["b"][1]), atol=1e-6)


def test_pytree_convexity_bounds():
    """A convex combination stays within the per-element min/max envelope."""
    x = jax.random.normal(jax.random.PRNGKey(2), (5, 64))
    w = jax.nn.softmax(jax.random.normal(jax.random.PRNGKey(3), (5,)))
    out = weighted_aggregate(x, w, impl="naive")
    assert (np.asarray(out) <= np.asarray(x.max(0)) + 1e-6).all()
    assert (np.asarray(out) >= np.asarray(x.min(0)) - 1e-6).all()
