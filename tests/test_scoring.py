"""FedTest scoring invariants (hypothesis property tests)."""
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core.scoring import (
    combine_tester_reports, init_scores, score_weights, update_scores,
    update_tester_trust)

accs = st.lists(st.floats(0.0, 1.0), min_size=2, max_size=12)


@settings(max_examples=60, deadline=None)
@given(a=accs, power=st.sampled_from([1.0, 2.0, 4.0]),
       decay=st.floats(0.0, 0.95))
def test_weights_form_a_simplex(a, power, decay):
    n = len(a)
    state = init_scores(n)
    acc = jnp.asarray(a)[None, :]
    state = update_scores(state, acc, jnp.arange(1), power=power,
                          decay=decay, power_warmup_rounds=0)
    w = np.asarray(score_weights(state))
    assert w.shape == (n,)
    assert (w >= 0).all()
    np.testing.assert_allclose(w.sum(), 1.0, atol=1e-5)


@settings(max_examples=60, deadline=None)
@given(a=accs, power=st.sampled_from([2.0, 4.0]))
def test_weights_monotone_in_accuracy(a, power):
    """Higher measured accuracy never gets a lower weight (round 1)."""
    n = len(a)
    state = update_scores(init_scores(n), jnp.asarray(a)[None, :],
                          jnp.arange(1), power=power, decay=0.5,
                          power_warmup_rounds=0)
    w = np.asarray(score_weights(state))
    order = np.argsort(a)
    assert (np.diff(w[order]) >= -1e-6).all()


def test_power_amplifies_separation():
    """The paper's p=4 crushes weak models harder than p=1 (Sec. V-B)."""
    a = jnp.array([[0.9, 0.3]])
    w1 = np.asarray(score_weights(update_scores(
        init_scores(2), a, jnp.arange(1), power=1.0,
        power_warmup_rounds=0)))
    w4 = np.asarray(score_weights(update_scores(
        init_scores(2), a, jnp.arange(1), power=4.0,
        power_warmup_rounds=0)))
    assert w4[0] > w1[0]
    assert w4[1] < w1[1]
    # p=4 ratio is the p=1 ratio to the 4th power
    np.testing.assert_allclose(w4[1] / w4[0], (w1[1] / w1[0]) ** 4,
                               rtol=1e-4)


def test_moving_average_weights_recent_rounds_more():
    """decay<0.5: a model that turns bad quickly loses its score."""
    state = init_scores(2)
    good = jnp.array([[0.9, 0.9]])
    bad = jnp.array([[0.9, 0.05]])
    state = update_scores(state, good, jnp.arange(1), power=4.0, decay=0.3,
                          power_warmup_rounds=0)
    first = float(state.scores[1])
    state = update_scores(state, bad, jnp.arange(1), power=4.0, decay=0.3,
                          power_warmup_rounds=0)
    second = float(state.scores[1])
    assert second < 0.4 * first


def test_first_round_uses_raw_powered_accuracy():
    state = update_scores(init_scores(3), jnp.array([[0.5, 1.0, 0.0]]),
                          jnp.arange(1), power=4.0, decay=0.9,
                          power_warmup_rounds=0)
    np.testing.assert_allclose(np.asarray(state.scores),
                               [0.5 ** 4, 1.0, 0.0], atol=1e-6)


def test_power_warmup_uses_exponent_one_first():
    """Cold-start guard: early rounds score with p=1 so evaluation luck is
    not amplified (Sec. V-B adaptive-exponent direction)."""
    state = update_scores(init_scores(2), jnp.array([[0.5, 0.1]]),
                          jnp.arange(1), power=4.0, decay=0.5,
                          power_warmup_rounds=1)
    np.testing.assert_allclose(np.asarray(state.scores), [0.5, 0.1],
                               atol=1e-6)
    state = update_scores(state, jnp.array([[0.5, 0.1]]), jnp.arange(1),
                          power=4.0, decay=0.5, power_warmup_rounds=1)
    np.testing.assert_allclose(np.asarray(state.scores),
                               [0.5 * 0.5 + 0.5 * 0.5 ** 4,
                               0.5 * 0.1 + 0.5 * 0.1 ** 4], atol=1e-6)


def test_zero_scores_fall_back_to_uniform():
    state = update_scores(init_scores(4), jnp.zeros((1, 4)),
                          jnp.arange(1), power=4.0,
                          power_warmup_rounds=0)
    np.testing.assert_allclose(np.asarray(score_weights(state)),
                               np.full(4, 0.25), atol=1e-6)


def test_combine_reports_mean_and_trust():
    acc = jnp.array([[0.8, 0.2], [0.4, 0.6]])
    plain = np.asarray(combine_tester_reports(acc, jnp.array([0, 1])))
    np.testing.assert_allclose(plain, [0.6, 0.4], atol=1e-6)
    trust = jnp.array([1.0, 0.0])
    trusted = np.asarray(combine_tester_reports(acc, jnp.array([0, 1]),
                                                trust=trust))
    np.testing.assert_allclose(trusted, [0.8, 0.2], atol=1e-6)


def test_lying_tester_loses_trust():
    state = init_scores(4)
    # tester 0 reports garbage; testers 1, 2 agree
    acc = jnp.array([[1.0, 0.0, 1.0, 0.0],
                     [0.5, 0.6, 0.55, 0.6],
                     [0.52, 0.58, 0.5, 0.62]])
    state = update_tester_trust(state, acc, jnp.array([0, 1, 2]))
    trust = np.asarray(state.tester_trust)
    assert trust[0] < trust[1]
    assert trust[0] < trust[2]
