"""robust_combine kernel sweep (Pallas interpret mode vs the jnp.sort
oracle) + the combine() aggregation fast path end-to-end."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.robust_combine.kernel import (
    oddeven_merge_pairs, robust_combine_pallas)
from repro.kernels.robust_combine.ops import (
    robust_combine, row_select_weights)
from repro.kernels.robust_combine.ref import robust_combine_ref


def _case(C, M, seed=0, ties=False):
    x = jax.random.normal(jax.random.PRNGKey(seed), (C, M), jnp.float32)
    if ties:
        # quantise hard so most columns contain duplicate client values
        x = jnp.round(x)
    return x


def _assert_matches_oracle(x, mask, mode, trim_fraction, block_m=128):
    w_row = row_select_weights(mask, mode=mode, trim_fraction=trim_fraction)
    ref = robust_combine_ref(x, mask, w_row)
    for impl, kw in (("network", {}),
                     ("pallas", {"block_m": block_m, "interpret": True})):
        out = robust_combine(x, mask=mask, mode=mode,
                             trim_fraction=trim_fraction, impl=impl, **kw)
        np.testing.assert_allclose(
            np.asarray(out), np.asarray(ref), atol=1e-5, rtol=1e-5,
            err_msg=f"{impl} C={x.shape[0]} mode={mode} trim={trim_fraction}")


# ------------------------------------------------------------ parity matrix
@pytest.mark.parametrize("C", [2, 3, 4, 7, 8, 16, 17])   # odd and even C
@pytest.mark.parametrize("mode,trim", [("trimmed_mean", 0.0),
                                       ("trimmed_mean", 0.2),
                                       ("trimmed_mean", 0.49),
                                       ("median", 0.0)])
def test_kernel_matches_sort_oracle(C, mode, trim):
    x = _case(C, 512, seed=C)
    mask = jnp.ones((C,), jnp.float32)
    _assert_matches_oracle(x, mask, mode, trim)


@pytest.mark.parametrize("C", [4, 5, 16])
def test_kernel_matches_oracle_with_ties(C):
    x = _case(C, 512, seed=C, ties=True)
    mask = jnp.ones((C,), jnp.float32)
    for mode, trim in (("trimmed_mean", 0.25), ("median", 0.0)):
        _assert_matches_oracle(x, mask, mode, trim)


@pytest.mark.parametrize("C", [3, 6, 16])
def test_kernel_matches_oracle_masked(C):
    """Gated clients (mask 0) must be excluded from the statistic."""
    x = _case(C, 384, seed=C + 100)
    mask = (jax.random.uniform(jax.random.PRNGKey(C), (C,)) > 0.4
            ).astype(jnp.float32)
    mask = mask.at[0].set(1.0)          # at least one participant
    for mode, trim in (("trimmed_mean", 0.0), ("trimmed_mean", 0.3),
                       ("median", 0.0)):
        _assert_matches_oracle(x, mask, mode, trim)


@pytest.mark.parametrize("M", [257, 511, 1000, 4096 + 3])
def test_non_divisible_d_padding_path(M):
    """Pallas pads M up to a block multiple and slices the result back."""
    x = _case(8, M, seed=M)
    mask = jnp.ones((8,), jnp.float32)
    _assert_matches_oracle(x, mask, "trimmed_mean", 0.25, block_m=256)


def test_median_equals_numpy_median():
    x = _case(7, 300, seed=3)
    out = robust_combine(x, mode="median", impl="network")
    np.testing.assert_allclose(np.asarray(out),
                               np.median(np.asarray(x), axis=0), atol=1e-6)


def test_trim_zero_is_masked_mean():
    x = _case(6, 200, seed=4)
    mask = jnp.array([1, 1, 0, 1, 0, 1], jnp.float32)
    out = robust_combine(x, mask=mask, mode="trimmed_mean",
                         trim_fraction=0.0, impl="network")
    kept = np.asarray(x)[np.asarray(mask) > 0]
    np.testing.assert_allclose(np.asarray(out), kept.mean(0), atol=1e-5,
                               rtol=1e-5)


def test_max_trim_degrades_to_median_neighbourhood():
    """trim ~ 0.5 keeps the middle 1-2 values, never an empty slice."""
    x = _case(9, 128, seed=5)
    out = robust_combine(x, mode="trimmed_mean", trim_fraction=0.49,
                         impl="network")
    med = robust_combine(x, mode="median", impl="network")
    np.testing.assert_allclose(np.asarray(out), np.asarray(med), atol=1e-5)


def test_sorting_network_sorts_all_01_inputs():
    """0-1 principle: a comparator network sorts every input iff it sorts
    every 0/1 input — exhaustive up to C=12."""
    import itertools
    for c in range(1, 13):
        pairs = oddeven_merge_pairs(c)
        for bits in itertools.product((0, 1), repeat=c):
            rows = list(bits)
            for i, j in pairs:
                if rows[i] > rows[j]:
                    rows[i], rows[j] = rows[j], rows[i]
            assert rows == sorted(rows), (c, bits)


def test_sorting_network_comparator_count_is_subquadratic():
    # Batcher odd-even mergesort: 63 comparators at C=16 (transposition
    # would need 120) — the margin that keeps the op bandwidth-bound
    assert len(oddeven_merge_pairs(16)) == 63
    assert len(oddeven_merge_pairs(32)) == 191


def test_all_zero_mask_yields_zero_update_not_sentinel():
    """A statistic over nobody degenerates to a zero combined update —
    the masked-row sentinel must never leak to the caller."""
    x = _case(4, 100, seed=6)
    zero = jnp.zeros((4,), jnp.float32)
    for mode in ("trimmed_mean", "median"):
        for impl in ("network", "sort"):
            out = np.asarray(robust_combine(x, mask=zero, mode=mode,
                                            impl=impl))
            np.testing.assert_array_equal(out, np.zeros(100, np.float32))


def test_row_select_weights_validation():
    mask = jnp.ones((4,), jnp.float32)
    with pytest.raises(ValueError, match="mode"):
        row_select_weights(mask, mode="nope")
    with pytest.raises(ValueError, match="trim_fraction"):
        row_select_weights(mask, trim_fraction=1.0)


def test_pallas_direct_call_block_alignment():
    x = _case(5, 1024, seed=9)
    mask = jnp.ones((5,), jnp.float32)
    w_row = row_select_weights(mask, mode="median")
    out = robust_combine_pallas(x, mask, w_row, block_m=256, interpret=True)
    np.testing.assert_allclose(np.asarray(out),
                               np.median(np.asarray(x), axis=0), atol=1e-5)


# ----------------------------------------------------- combine() round path
@pytest.fixture(scope="module")
def tiny_setup():
    from repro.config import TrainConfig
    from repro.configs import get_config
    from repro.data import MNIST_LIKE, make_federated_image_dataset
    from repro.models import build_model
    cfg = get_config("fedtest-cnn-mnist").replace(
        cnn_channels=(4, 8, 8), cnn_hidden=16)
    model = build_model(cfg)
    data = make_federated_image_dataset(MNIST_LIKE, 6, num_samples=900,
                                        global_test=120, seed=0)
    tc = TrainConfig(optimizer="sgd", lr=0.1, schedule="constant",
                     batch_size=8, grad_clip=0.0, remat=False)
    return model, data, tc


@pytest.mark.parametrize("aggregator", ["trimmed_mean_coord", "median_coord"])
def test_combine_round_no_retrace(tiny_setup, aggregator):
    """Multi-round run through the combine() fast path: one trace."""
    from repro.config import FedConfig
    from repro.core import FederatedTrainer
    model, data, tc = tiny_setup
    fed = FedConfig(num_users=6, num_testers=2, num_malicious=1,
                    local_steps=2, aggregator=aggregator)
    trainer = FederatedTrainer(model, fed, tc, eval_batch=32)
    state = trainer.init(jax.random.PRNGKey(0))
    for _ in range(3):
        state, metrics = trainer.run_round(state, data)
    assert trainer.num_traces == 1
    assert np.isfinite(float(metrics["local_loss"]))
    w = np.asarray(metrics["weights"])      # reporting gate, still a simplex
    np.testing.assert_allclose(w.sum(), 1.0, atol=1e-5)


def test_aggregate_models_combine_branch_matches_oracle():
    """global + unflatten(combine(updates)) == per-leaf jnp median."""
    from repro.core.aggregation import aggregate_models
    key = jax.random.PRNGKey(0)
    gp = {"a": jax.random.normal(key, (4, 3)),
          "b": {"c": jax.random.normal(jax.random.fold_in(key, 1), (7,))}}
    stacked = jax.tree_util.tree_map(
        lambda g: g[None] + jax.random.normal(
            jax.random.fold_in(key, g.size), (5,) + g.shape), gp)

    def flat_updates(stacked, gp):
        parts = jax.tree_util.tree_leaves(jax.tree_util.tree_map(
            lambda s, g: (s - g[None]).reshape(5, -1), stacked, gp))
        return jnp.concatenate(parts, axis=1)

    updates = flat_updates(stacked, gp)
    out = aggregate_models(
        stacked, None,
        combine_fn=lambda u: robust_combine(u, mode="median",
                                            impl="network"),
        updates=updates, global_params=gp)
    for o, g, s in zip(jax.tree_util.tree_leaves(out),
                       jax.tree_util.tree_leaves(gp),
                       jax.tree_util.tree_leaves(stacked)):
        want = np.asarray(g) + np.median(np.asarray(s - g[None]), axis=0)
        np.testing.assert_allclose(np.asarray(o), want, atol=1e-5, rtol=1e-5)


def test_score_gate_engages_from_cross_testing_signal():
    """The combine aggregators maintain FedTest scores themselves, so
    score_gate acts on a live signal: after one update_scores round a
    low-accuracy client is excluded from the order statistic."""
    from repro.strategies import AGGREGATORS
    from repro.strategies.base import RoundContext
    from repro.core.scoring import init_scores
    n, d, k = 5, 32, 3
    agg = AGGREGATORS.build("median_coord",
                            {"score_gate": 0.5, "power_warmup_rounds": 0})
    acc = jnp.full((k, n), 0.8).at[:, 4].set(0.05)   # client 4 near chance
    ctx = RoundContext(acc_matrix=acc, tester_ids=jnp.arange(k),
                       scores=init_scores(n), counts=jnp.ones((n,)),
                       round_idx=jnp.zeros((), jnp.int32),
                       key=jax.random.PRNGKey(0),
                       updates=jnp.zeros((n, d)))
    new_scores = agg.update_scores(ctx)
    assert float(new_scores.scores[4]) < float(new_scores.scores[0])
    gate = np.asarray(agg.gate_mask(ctx._replace(scores=new_scores)))
    np.testing.assert_allclose(gate, [1, 1, 1, 1, 0])


def test_combine_ignores_gated_out_attacker(tiny_setup):
    """A score-gated coordinate median excludes the masked client."""
    from repro.strategies import AGGREGATORS
    from repro.strategies.base import RoundContext
    from repro.core.scoring import init_scores
    n, d = 5, 64
    agg = AGGREGATORS.build("median_coord", {"score_gate": 0.5})
    updates = jnp.ones((n, d)) * jnp.arange(n, dtype=jnp.float32)[:, None]
    scores = init_scores(n)._replace(
        scores=jnp.array([1.0, 1.0, 1.0, 1.0, 0.01]))  # client 4 gated out
    ctx = RoundContext(acc_matrix=jnp.zeros((2, n)),
                       tester_ids=jnp.arange(2), scores=scores,
                       counts=jnp.ones((n,)),
                       round_idx=jnp.zeros((), jnp.int32),
                       key=jax.random.PRNGKey(0), updates=updates)
    out = np.asarray(agg.combine(ctx, updates))
    # median over clients {0, 1, 2, 3} -> 1.5 (client 4's value 4.0 is out)
    np.testing.assert_allclose(out, np.full(d, 1.5), atol=1e-6)
