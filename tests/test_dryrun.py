"""Dry-run machinery on the production mesh (subprocess: needs 512
host-platform placeholder devices, which must never leak into this
process)."""
import json
import os
import subprocess
import sys

import pytest

SCRIPT = r"""
import json
from repro.launch.dryrun import lower_one
rec = lower_one("mamba2-2.7b", "long_500k", multi_pod=False,
                extrapolate=False)
print(json.dumps({"status": rec["status"],
                  "chips": rec.get("num_chips"),
                  "coll": sum(rec.get("collectives", {}).values())}))
"""

SKIP_SCRIPT = r"""
import json
from repro.launch.dryrun import lower_one
rec = lower_one("whisper-base", "long_500k", multi_pod=False)
print(json.dumps(rec))
"""


@pytest.mark.slow
def test_dryrun_compiles_on_production_mesh():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    proc = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                          capture_output=True, text=True, timeout=600)
    assert proc.returncode == 0, proc.stderr[-3000:]
    out = json.loads(proc.stdout.strip().splitlines()[-1])
    assert out["status"] == "ok"
    assert out["chips"] == 256
    assert out["coll"] > 0          # sharded program must communicate


def test_whisper_long_context_is_skipped_with_reason():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    proc = subprocess.run([sys.executable, "-c", SKIP_SCRIPT], env=env,
                          capture_output=True, text=True, timeout=120)
    assert proc.returncode == 0, proc.stderr[-2000:]
    out = json.loads(proc.stdout.strip().splitlines()[-1])
    assert out["status"] == "skipped"
    assert "448" in out["reason"]
