"""Flash-decoding kernel sweep + the LSE shard-merge identity."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.decode_attention.kernel import decode_attention_pallas
from repro.kernels.decode_attention.ops import (
    _decode_xla, decode_attention, merge_partials)
from repro.kernels.decode_attention.ref import decode_attention_ref

SHAPES = [
    (2, 256, 8, 2, 32),      # (B, T, Hq, Hkv, D) GQA
    (1, 512, 4, 4, 64),      # MHA
    (3, 128, 16, 1, 32),     # MQA
]


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_pallas_decode_matches_ref(shape, dtype):
    B, T, Hq, Hkv, D = shape
    ks = jax.random.split(jax.random.PRNGKey(0), 4)
    q = jax.random.normal(ks[0], (B, Hq, D), jnp.float32).astype(dtype)
    k = jax.random.normal(ks[1], (B, T, Hkv, D), jnp.float32).astype(dtype)
    v = jax.random.normal(ks[2], (B, T, Hkv, D), jnp.float32).astype(dtype)
    lengths = jax.random.randint(ks[3], (B,), 1, T + 1)
    ro, rl = decode_attention_ref(q.astype(jnp.float32),
                                  k.astype(jnp.float32),
                                  v.astype(jnp.float32), lengths)
    po, pl = decode_attention_pallas(q, k, v, lengths, block_k=64,
                                     interpret=True)
    tol = 3e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(po, np.float32), np.asarray(ro),
                               atol=tol, rtol=tol)
    np.testing.assert_allclose(np.asarray(pl), np.asarray(rl), atol=tol,
                               rtol=tol)


@pytest.mark.parametrize("window", [32, 200])
def test_decode_window(window):
    B, T, Hq, Hkv, D = 2, 256, 4, 2, 32
    ks = jax.random.split(jax.random.PRNGKey(1), 4)
    q = jax.random.normal(ks[0], (B, Hq, D))
    k = jax.random.normal(ks[1], (B, T, Hkv, D))
    v = jax.random.normal(ks[2], (B, T, Hkv, D))
    lengths = jnp.array([256, 100])
    ro, _ = decode_attention_ref(q, k, v, lengths, window=window)
    po, _ = decode_attention_pallas(q, k, v, lengths, window=window,
                                    block_k=64, interpret=True)
    xo, _ = _decode_xla(q, k, v, lengths, window=window, block_k=64)
    np.testing.assert_allclose(np.asarray(po), np.asarray(ro), atol=2e-5,
                               rtol=2e-5)
    np.testing.assert_allclose(np.asarray(xo), np.asarray(ro), atol=2e-5,
                               rtol=2e-5)


@pytest.mark.parametrize("num_shards", [2, 4, 8])
def test_lse_merge_equals_unsharded(num_shards):
    """Flash-decoding: seq-sharded partials + LSE merge == full attention."""
    B, T, Hq, Hkv, D = 2, 512, 4, 2, 32
    ks = jax.random.split(jax.random.PRNGKey(2), 4)
    q = jax.random.normal(ks[0], (B, Hq, D))
    k = jax.random.normal(ks[1], (B, T, Hkv, D))
    v = jax.random.normal(ks[2], (B, T, Hkv, D))
    lengths = jnp.array([512, 300])
    ref, _ = decode_attention_ref(q, k, v, lengths)
    shard = T // num_shards
    outs, lses = [], []
    for s in range(num_shards):
        ls = jnp.clip(lengths - s * shard, 0, shard)
        o, l = _decode_xla(q, k[:, s * shard:(s + 1) * shard],
                           v[:, s * shard:(s + 1) * shard], ls, block_k=64)
        outs.append(o)
        lses.append(l)
    merged = merge_partials(jnp.stack(outs), jnp.stack(lses))
    np.testing.assert_allclose(np.asarray(merged), np.asarray(ref),
                               atol=2e-5, rtol=2e-5)
