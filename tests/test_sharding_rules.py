"""Sharding rule sets + divisibility guard (no multi-device mesh needed —
specs are pure metadata)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import Mesh, PartitionSpec as P

from repro.config import INPUT_SHAPES, reduce_for_smoke
from repro.configs import get_config
from repro.models import build_model
from repro.sharding import (guard_divisibility, make_ruleset,
                            param_spec_tree)


class FakeMesh:
    """Stand-in carrying just axis names + sizes (enough for the guard)."""
    def __init__(self, sizes):
        self.axis_names = tuple(sizes)
        self.devices = np.empty(tuple(sizes.values()))


def _specs_for(arch):
    cfg = reduce_for_smoke(get_config(arch))
    model = build_model(cfg)
    params = jax.eval_shape(lambda k: model.init(k),
                            jax.ShapeDtypeStruct((2,), jnp.uint32))
    return cfg, params, param_spec_tree(params, ("data", "model"))


def test_dense_param_specs():
    cfg, params, specs = _specs_for("qwen3-1.7b")
    slot = specs["layers"]["slot_0"]
    # stacked weights get a leading None then (fsdp, model) or (model, fsdp)
    assert slot["attn"]["wq"] == P(None, "data", "model")
    assert slot["attn"]["wo"] == P(None, "model", "data")
    assert slot["ffn"]["w_gate"] == P(None, "data", "model")
    assert slot["ffn"]["w_down"] == P(None, "model", "data")
    assert specs["embed"] == P("model", "data")
    # norms replicate
    assert slot["norm1"]["scale"] == P(None, None)


def test_moe_param_specs_expert_parallel():
    cfg, params, specs = _specs_for("granite-moe-1b-a400m")
    moe = specs["layers"]["slot_0"]["moe"]
    assert moe["w_gate"] == P(None, "model", "data", None)
    assert moe["w_down"] == P(None, "model", None, "data")
    assert moe["router"] == P(None, "data", None)


def test_multi_pod_fsdp_axes():
    cfg, params, _ = _specs_for("qwen2-0.5b")
    specs = param_spec_tree(params, ("pod", "data", "model"))
    assert specs["layers"]["slot_0"]["attn"]["wq"] == \
        P(None, ("pod", "data"), "model")


def test_divisibility_guard_drops_bad_axes():
    mesh = FakeMesh({"data": 16, "model": 16})
    spec = {"w": P("data", "model")}
    shapes = {"w": jax.ShapeDtypeStruct((24, 32), jnp.float32)}
    fixed = guard_divisibility(spec, shapes, mesh)
    assert fixed["w"] == P(None, "model")     # 24 % 16 != 0 -> dropped


def test_ruleset_decode_long_context():
    rules = make_ruleset(("data", "model"), kind="decode",
                         batch_divisible=False)
    assert rules["batch"] is None
    assert rules["kv_seq"] == ("data", "model")
    rules2 = make_ruleset(("data", "model"), kind="decode",
                          batch_divisible=True)
    assert rules2["batch"] == "data"
    assert rules2["kv_seq"] == "model"


def test_hints_noop_without_rules():
    from repro.sharding import shard_hint
    x = jnp.ones((4, 4))
    out = shard_hint(x, ("batch", "embed"))
    np.testing.assert_array_equal(np.asarray(out), np.asarray(x))
