"""Pytree <-> .npz serialization (path-keyed, restores exact structure)."""
from __future__ import annotations

import io
import json
import os
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


def save_pytree(tree: Any, path: str) -> None:
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    arrays = {}
    keys = []
    for i, (p, leaf) in enumerate(flat):
        k = f"leaf_{i}"
        arr = np.asarray(leaf)
        if arr.dtype.kind == "V" or str(arr.dtype) == "bfloat16":
            # numpy .npz cannot store ml_dtypes (bfloat16 etc.); bf16 -> f32
            # is exact and the loader casts back to the template dtype.
            arr = np.asarray(jnp.asarray(leaf).astype(jnp.float32))
        arrays[k] = arr
        keys.append(_path_str(p))
    meta = json.dumps({"treedef": str(treedef), "paths": keys})
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    with open(path, "wb") as f:
        np.savez(f, __meta__=np.frombuffer(meta.encode(), dtype=np.uint8),
                 **arrays)


def load_pytree(template: Any, path: str) -> Any:
    """Restore into the structure of ``template`` (shapes must match)."""
    with np.load(path) as z:
        flat_t, treedef = jax.tree_util.tree_flatten(template)
        leaves = []
        for i, t in enumerate(flat_t):
            arr = z[f"leaf_{i}"]
            leaves.append(jnp.asarray(arr).astype(t.dtype)
                          if hasattr(t, "dtype") else arr)
        return jax.tree_util.tree_unflatten(treedef, leaves)
