"""Pytree <-> .npz serialization (path-keyed, restores exact structure)."""
from __future__ import annotations

import json
import os
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


def save_pytree(tree: Any, path: Any) -> None:
    """Serialize ``tree`` to ``path`` — a filename or an open binary
    file object (the manager's atomic writer hands us the latter)."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    arrays = {}
    keys = []
    for i, (p, leaf) in enumerate(flat):
        k = f"leaf_{i}"
        arr = np.asarray(leaf)
        if arr.dtype.kind == "V" or str(arr.dtype) == "bfloat16":
            # numpy .npz cannot store ml_dtypes (bfloat16 etc.); bf16 -> f32
            # is exact and the loader casts back to the template dtype.
            arr = np.asarray(jnp.asarray(leaf).astype(jnp.float32))
        arrays[k] = arr
        keys.append(_path_str(p))
    meta = json.dumps({"treedef": str(treedef), "paths": keys,
                       "num_leaves": len(flat)})
    blob = np.frombuffer(meta.encode(), dtype=np.uint8)
    if hasattr(path, "write"):
        np.savez(path, __meta__=blob, **arrays)
        return
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    with open(path, "wb") as f:
        np.savez(f, __meta__=blob, **arrays)


def load_pytree(template: Any, path: str) -> Any:
    """Restore into the structure of ``template`` (shapes must match).

    Raises ``ValueError`` when the file's leaf count or shapes disagree
    with the template — the manager treats that as a corrupt/foreign
    checkpoint and falls back to an older step.
    """
    with np.load(path) as z:
        flat_t, treedef = jax.tree_util.tree_flatten(template)
        stored = sum(1 for k in z.files if k.startswith("leaf_"))
        if stored != len(flat_t):
            raise ValueError(
                f"checkpoint has {stored} leaves, template expects "
                f"{len(flat_t)} — wrong run or torn write")
        leaves = []
        for i, t in enumerate(flat_t):
            arr = z[f"leaf_{i}"]
            t_shape = getattr(t, "shape", None)
            if t_shape is not None and tuple(arr.shape) != tuple(t_shape):
                raise ValueError(
                    f"leaf_{i} shape {arr.shape} != template {t_shape}")
            leaves.append(jnp.asarray(arr).astype(t.dtype)
                          if hasattr(t, "dtype") else arr)
        return jax.tree_util.tree_unflatten(treedef, leaves)
