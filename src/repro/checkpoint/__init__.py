from repro.checkpoint.serialization import save_pytree, load_pytree
from repro.checkpoint.manager import CheckpointManager
from repro.checkpoint.manifest import (
    check_manifest, manifest_mismatches, run_manifest)

__all__ = ["save_pytree", "load_pytree", "CheckpointManager",
           "check_manifest", "manifest_mismatches", "run_manifest"]
