"""Round-state checkpoint manager for federated training runs.

Durability contract (DESIGN.md §9):

* **Atomic saves** — every checkpoint is written to a temporary file in
  the same directory and moved into place with ``os.replace``, so a
  crash (or SIGKILL) mid-write can never leave a truncated
  ``ckpt_*.npz`` masquerading as the latest step. The CI kill-and-resume
  row relies on this: the process is killed at an arbitrary point and
  the directory must still restore.
* **Corrupt-checkpoint skip** — ``restore`` walks the available steps
  newest-first and skips (with a warning) any checkpoint that fails to
  load or does not match the template, so one bad file degrades resume
  by ``save_every`` rounds instead of killing it.
* **Manifest guard** — ``save`` can attach a run manifest
  (:mod:`repro.checkpoint.manifest`); ``restore`` hands it back so the
  caller can refuse a mismatched run before touching the arrays.
* **Foreign files are ignored** — ``latest_step`` / ``_gc`` skip
  anything in the directory that does not match ``ckpt_<8 digits>.npz``
  (stray tmp files, editor droppings), instead of crashing on a
  non-matching ``re.search``.
"""
from __future__ import annotations

import glob
import json
import os
import re
import tempfile
import warnings
from typing import Any, Dict, List, Optional, Tuple

from repro.checkpoint.serialization import load_pytree, save_pytree

_CKPT_RE = re.compile(r"ckpt_(\d+)\.npz$")
MANIFEST_NAME = "manifest.json"


class CheckpointManager:
    """Keeps the ``keep`` newest round-state checkpoints in a directory.

    ``save_every`` is the cadence policy consumed by ``should_save`` /
    ``maybe_save`` — drivers call ``maybe_save(step, state)`` after
    every completed round (or scan chunk) and the manager decides
    whether ``step`` warrants a write (``save_every <= 0`` disables
    periodic saves; explicit ``save`` always writes).
    """

    def __init__(self, directory: str, keep: int = 3,
                 save_every: int = 0):
        self.directory = directory
        self.keep = int(keep)
        self.save_every = int(save_every)
        os.makedirs(directory, exist_ok=True)

    # ------------------------------------------------------------- paths
    def _path(self, step: int) -> str:
        return os.path.join(self.directory, f"ckpt_{step:08d}.npz")

    @property
    def manifest_path(self) -> str:
        return os.path.join(self.directory, MANIFEST_NAME)

    def steps(self) -> List[int]:
        """Sorted steps of every well-named checkpoint in the directory.

        Non-matching files (``ckpt_tmp.npz``, partial tmp writes) are
        skipped — a stray file must never crash gc or resume.
        """
        steps = []
        for f in glob.glob(os.path.join(self.directory, "ckpt_*.npz")):
            m = _CKPT_RE.search(f)
            if m:
                steps.append(int(m.group(1)))
        return sorted(steps)

    def latest_step(self) -> Optional[int]:
        steps = self.steps()
        return steps[-1] if steps else None

    # ------------------------------------------------------------- saves
    def _atomic_write(self, path: str, writer) -> None:
        """Write via tmp file + ``os.replace`` so readers (and crashes)
        never observe a partial file; the tmp name cannot collide with
        the ``ckpt_<digits>.npz`` pattern ``steps()`` recognises."""
        fd, tmp = tempfile.mkstemp(dir=self.directory, prefix="tmp_",
                                   suffix=".part")
        try:
            with os.fdopen(fd, "wb") as f:
                writer(f)
            os.replace(tmp, path)
        except BaseException:
            if os.path.exists(tmp):
                os.remove(tmp)
            raise

    def save(self, step: int, state: Any,
             manifest: Optional[Dict[str, Any]] = None) -> str:
        """Atomically write ``state`` as step ``step`` (+ the manifest
        on first save), then gc to the ``keep`` newest."""
        if manifest is not None:
            self.write_manifest(manifest)
        path = self._path(int(step))
        self._atomic_write(path, lambda f: save_pytree(state, f))
        self._gc()
        return path

    def should_save(self, step: int) -> bool:
        """The ``save_every`` cadence policy (step 0 never saves —
        nothing has happened yet)."""
        return (self.save_every > 0 and step > 0
                and step % self.save_every == 0)

    def maybe_save(self, step: int, state: Any,
                   manifest: Optional[Dict[str, Any]] = None
                   ) -> Optional[str]:
        """``save`` iff the cadence policy asks for it at ``step``."""
        if not self.should_save(step):
            return None
        return self.save(step, state, manifest=manifest)

    # ---------------------------------------------------------- manifest
    def write_manifest(self, manifest: Dict[str, Any]) -> str:
        payload = json.dumps(manifest, indent=1, sort_keys=True)
        self._atomic_write(self.manifest_path,
                           lambda f: f.write(payload.encode()))
        return self.manifest_path

    def read_manifest(self) -> Optional[Dict[str, Any]]:
        if not os.path.exists(self.manifest_path):
            return None
        with open(self.manifest_path) as f:
            return json.load(f)

    # ------------------------------------------------------------ restore
    def restore(self, template: Any, step: Optional[int] = None) -> Any:
        """Restore the newest loadable checkpoint (or exactly ``step``).

        Walking newest-first, a checkpoint that fails to deserialize
        into ``template`` is skipped with a warning — a torn or foreign
        file costs one cadence interval, not the run. Raises
        ``FileNotFoundError`` when nothing restorable remains.
        """
        state, found = self.restore_with_step(template, step)
        del found
        return state

    def restore_with_step(self, template: Any,
                          step: Optional[int] = None) -> Tuple[Any, int]:
        """Like :meth:`restore` but also returns the restored step."""
        if step is not None:
            candidates = [int(step)]
        else:
            candidates = list(reversed(self.steps()))
        if not candidates:
            raise FileNotFoundError(f"no checkpoints in {self.directory}")
        errors = []
        for s in candidates:
            path = self._path(s)
            try:
                return load_pytree(template, path), s
            except Exception as e:  # torn write / wrong run / foreign file
                errors.append(f"{os.path.basename(path)}: {e}")
                warnings.warn(
                    f"skipping corrupt checkpoint {path}: {e}",
                    RuntimeWarning, stacklevel=2)
        raise FileNotFoundError(
            f"no restorable checkpoint in {self.directory} "
            f"(tried {len(candidates)}):\n  " + "\n  ".join(errors))

    # ----------------------------------------------------------------- gc
    def _gc(self) -> None:
        for s in self.steps()[:-self.keep]:
            os.remove(self._path(s))
