"""Round-state checkpoint manager for federated training runs."""
from __future__ import annotations

import glob
import os
import re
from typing import Any, Optional

from repro.checkpoint.serialization import load_pytree, save_pytree


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)

    def _path(self, step: int) -> str:
        return os.path.join(self.directory, f"ckpt_{step:08d}.npz")

    def save(self, step: int, state: Any) -> str:
        path = self._path(step)
        save_pytree(state, path)
        self._gc()
        return path

    def latest_step(self) -> Optional[int]:
        steps = []
        for f in glob.glob(os.path.join(self.directory, "ckpt_*.npz")):
            m = re.search(r"ckpt_(\d+)\.npz$", f)
            if m:
                steps.append(int(m.group(1)))
        return max(steps) if steps else None

    def restore(self, template: Any, step: Optional[int] = None) -> Any:
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.directory}")
        return load_pytree(template, self._path(step))

    def _gc(self) -> None:
        steps = sorted(
            int(re.search(r"ckpt_(\d+)\.npz$", f).group(1))
            for f in glob.glob(os.path.join(self.directory, "ckpt_*.npz")))
        for s in steps[:-self.keep]:
            os.remove(self._path(s))
