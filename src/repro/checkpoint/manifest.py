"""Run manifests: the fingerprint that guards checkpoint resume.

A checkpoint is only as durable as the guarantee that it is restored
into the *same* run: the FedTest round state (scores, trust, the PRNG
round schedule) is meaningful only under the exact ``FedConfig`` —
strategies, placements, participation — and model architecture that
produced it. Restoring a trajectory into a run with, say, a different
``score_power`` or attack placement would silently continue a
*different* experiment while claiming bit-identical resume.

So every checkpoint directory carries a ``manifest.json`` written by the
first save: the full ``FedConfig`` / ``TrainConfig`` field dicts, the
architecture identity, and the trainer knobs that shape the traced round
(``use_trust``, the state's leaf structure). ``check_manifest`` compares
a saved manifest against the resuming run's and raises with the exact
mismatched fields (DESIGN.md §9).

Everything is JSON round-tripped before comparison, so tuple-vs-list
and int-vs-float artefacts of serialization can never produce a false
mismatch.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Any, Dict, List

MANIFEST_VERSION = 1


def _jsonable(obj: Any) -> Any:
    """Normalise through a JSON round-trip (tuples -> lists, key order)."""
    return json.loads(json.dumps(obj, sort_keys=True, default=str))


def run_manifest(model_cfg, fed, train_cfg, *, use_trust: bool = False,
                 extra: Dict[str, Any] = None) -> Dict[str, Any]:
    """The resume-compatibility fingerprint of a federated run.

    ``model_cfg`` / ``fed`` / ``train_cfg`` are the frozen config
    dataclasses; ``extra`` lets drivers pin additional identity (e.g.
    the dataset name). Wall-clock, output paths, checkpoint cadence and
    ``fed.rounds`` deliberately do NOT enter the manifest — they may
    differ between the interrupted and the resuming invocation
    (``rounds`` is the run-length target, not run identity: resuming a
    6-round checkpoint with ``--rounds 10`` trains it longer, it does
    not continue a different experiment).
    """
    fed_dict = dataclasses.asdict(fed)
    fed_dict.pop("rounds", None)
    manifest = {
        "manifest_version": MANIFEST_VERSION,
        "arch": model_cfg.name,
        "family": model_cfg.family,
        "model": dataclasses.asdict(model_cfg),
        "fed": fed_dict,
        "train": dataclasses.asdict(train_cfg),
        "use_trust": bool(use_trust),
    }
    if extra:
        manifest["extra"] = dict(extra)
    return _jsonable(manifest)


def manifest_mismatches(saved: Dict[str, Any], current: Dict[str, Any]
                        ) -> List[str]:
    """Dotted paths of every leaf where the two manifests disagree."""
    saved = _jsonable(saved)
    current = _jsonable(current)
    diffs: List[str] = []

    def walk(a: Any, b: Any, path: str) -> None:
        if isinstance(a, dict) and isinstance(b, dict):
            for k in sorted(set(a) | set(b)):
                walk(a.get(k), b.get(k), f"{path}.{k}" if path else str(k))
        elif a != b:
            diffs.append(f"{path}: saved={a!r} current={b!r}")

    walk(saved, current, "")
    return diffs


def check_manifest(saved: Dict[str, Any], current: Dict[str, Any]) -> None:
    """Refuse to resume a mismatched run (DESIGN.md §9).

    Raises ``ValueError`` listing every differing field; a checkpoint
    from a different config/arch must never silently continue.
    """
    diffs = manifest_mismatches(saved, current)
    if diffs:
        raise ValueError(
            "checkpoint manifest does not match this run — refusing to "
            "resume a different experiment:\n  " + "\n  ".join(diffs))
