"""Evaluation helpers."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp


def classify_accuracy(logits: jnp.ndarray, labels: jnp.ndarray):
    return jnp.mean(jnp.argmax(logits, -1) == labels)


def evaluate_classifier(model, params, x, y, batch: int = 512):
    """Batched global-test accuracy for image classifiers."""
    n = x.shape[0]
    correct = 0
    fwd = jax.jit(lambda p, bx: model.forward_train(p, {"images": bx})[0])
    for i in range(0, n, batch):
        bx, by = x[i:i + batch], y[i:i + batch]
        logits = fwd(params, bx)
        correct += int(jnp.sum(jnp.argmax(logits, -1) == by))
    return correct / n
