from repro.eval.metrics import classify_accuracy, evaluate_classifier

__all__ = ["classify_accuracy", "evaluate_classifier"]
