from repro.sharding.hints import (
    shard_hint, logical_rules, current_rules, spec_for)
from repro.sharding.rules import (
    RULESETS, param_spec_tree, make_ruleset, guard_divisibility)

__all__ = [
    "shard_hint", "logical_rules", "current_rules", "spec_for",
    "RULESETS", "param_spec_tree", "make_ruleset", "guard_divisibility",
]
