"""Concrete sharding rule sets: logical activation axes + per-param specs.

Activation rules (used by ``shard_hint`` inside model code) and parameter
PartitionSpecs (used as ``in_shardings`` by the launchers) are both derived
from the mesh axis names, so the same model code serves:

* single pod  — mesh ("data", "model") = (16, 16)
* multi pod   — mesh ("pod", "data", "model") = (2, 16, 16)

Parameter layout is FSDP-style: the "feature-out" dimension of each matmul
weight is sharded over ``model`` and the other large dimension over
(``pod``, ``data``), so 110B/398B optimizer state fits; XLA inserts the
per-layer all-gathers. Vectors and norm scales are replicated.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import numpy as np
from jax.sharding import PartitionSpec as P


def make_ruleset(axes: Tuple[str, ...], *, kind: str = "train",
                 batch_divisible: bool = True) -> Dict[str, object]:
    """Logical-axis -> mesh-axis rules for activations."""
    fsdp = tuple(a for a in axes if a != "model")
    fsdp = fsdp[0] if len(fsdp) == 1 else fsdp
    batch = fsdp if batch_divisible else None
    rules: Dict[str, object] = {
        "batch": batch,
        "seq": None,
        "embed": None,
        "heads": "model",
        "kv_heads": None,
        "kv_seq": "model",
        "mlp": "model",
        "vocab": "model",
        "expert": "model",
        "expert_group": batch,
    }
    if kind == "decode" and not batch_divisible:
        # long-context decode with batch=1: spread the KV over everything
        rules["kv_seq"] = tuple(a for a in axes)
    return rules


RULESETS = {"make": make_ruleset}


# --------------------------------------------------------------- param specs
_MATMUL_SPECS = {
    # name -> (spec by dim, from the *trailing* dims of the leaf)
    "wq": ("fsdp", "model"),
    "wk": ("fsdp", "model"),
    "wv": ("fsdp", "model"),
    "wo": ("model", "fsdp"),
    "w_gate": ("fsdp", "model"),
    "w_up": ("fsdp", "model"),
    "w_down": ("model", "fsdp"),
    "w_in": ("fsdp", "model"),
    "w_out": ("model", "fsdp"),
    "in_proj": ("fsdp", "model"),
    "out_proj": ("model", "fsdp"),
    "router": ("fsdp", None),
    "embed": ("model", "fsdp"),      # vocab over model
    "lm_head": ("fsdp", "model"),
    "dec_pos": (None, "fsdp"),
    "patch_proj": ("fsdp", None),
    "conv_w": (None, "model"),
}
_MOE_SPECS = {  # leading expert dim over model (expert parallelism)
    "w_gate": ("model", "fsdp", None),
    "w_up": ("model", "fsdp", None),
    "w_down": ("model", None, "fsdp"),
}


def _resolve(axis_tag: Optional[str], fsdp_axes):
    if axis_tag == "fsdp":
        return fsdp_axes
    return axis_tag


def _leaf_spec(path, leaf, fsdp_axes) -> P:
    names = [getattr(p, "key", getattr(p, "name", None)) for p in path]
    names = [n for n in names if isinstance(n, str)]
    leafname = names[-1] if names else ""
    in_moe = "moe" in names
    stacked = sum(1 for n in names
                  if n in ("layers", "encoder", "decoder")
                  or n.startswith("slot_"))
    # slot_k lives under layers -> exactly one leading stack axis
    n_stack = 1 if stacked else 0

    table = _MOE_SPECS if (in_moe and leafname in _MOE_SPECS) else _MATMUL_SPECS
    if leafname in table:
        tags = table[leafname]
        spec = [_resolve(t, fsdp_axes) for t in tags]
        ndim = leaf.ndim
        if n_stack and ndim == len(tags) + 1:
            spec = [None] + spec
        elif ndim != len(spec):
            spec = [None] * (ndim - len(spec)) + spec
        return P(*spec)
    # vectors / norms / biases / scalar banks: replicate
    return P(*([None] * leaf.ndim))


def param_spec_tree(params, axes: Tuple[str, ...]):
    """PartitionSpec pytree matching ``params`` (shape/dtype structs ok)."""
    fsdp = tuple(a for a in axes if a != "model")
    fsdp = fsdp[0] if len(fsdp) == 1 else (fsdp if fsdp else None)
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: _leaf_spec(path, leaf, fsdp), params)


def guard_divisibility(spec_tree, shape_tree, mesh):
    """Drop mesh axes from specs whenever they don't divide the dim."""
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))

    def _fix(spec: P, leaf) -> P:
        entries = tuple(spec) + (None,) * (leaf.ndim - len(spec))
        fixed = []
        for dim, ax in zip(leaf.shape, entries):
            if ax is None:
                fixed.append(None)
                continue
            axs = ax if isinstance(ax, tuple) else (ax,)
            size = int(np.prod([sizes[a] for a in axs]))
            fixed.append(ax if dim % size == 0 else None)
        return P(*fixed)

    return jax.tree_util.tree_map(
        _fix, spec_tree, shape_tree,
        is_leaf=lambda x: isinstance(x, P))
