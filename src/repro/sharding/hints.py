"""Logical-axis sharding hints.

Model code is written once, annotation-free of any concrete mesh: it tags
activations with *logical* axis names via ``shard_hint(x, ("batch", "seq",
"embed"))``. The launcher activates a rule set (logical name -> mesh axes)
with ``logical_rules(...)``; outside that context the hints are no-ops, so
the exact same model code runs on one CPU device in tests.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Dict, Optional, Sequence, Tuple, Union

import jax
from jax.sharding import PartitionSpec as P

Axis = Union[str, Tuple[str, ...], None]

_state = threading.local()


def current_rules() -> Optional[Dict[str, Axis]]:
    return getattr(_state, "rules", None)


@contextlib.contextmanager
def logical_rules(rules: Dict[str, Axis]):
    prev = current_rules()
    _state.rules = rules
    try:
        yield
    finally:
        _state.rules = prev


def spec_for(logical_axes: Sequence[Optional[str]],
             rules: Optional[Dict[str, Axis]] = None) -> P:
    rules = rules if rules is not None else (current_rules() or {})
    return P(*[rules.get(a) if a is not None else None
               for a in logical_axes])


def shard_hint(x, logical_axes: Sequence[Optional[str]]):
    rules = current_rules()
    if rules is None:
        return x
    assert len(logical_axes) == x.ndim, (logical_axes, x.shape)
    return jax.lax.with_sharding_constraint(x, spec_for(logical_axes, rules))
