"""ShapeDtypeStruct input specs + sharding specs for every
(architecture x input shape) combination — the dry-run's contract.

Nothing here allocates: specs are shape/dtype stand-ins; cache templates
come from ``jax.eval_shape`` over the real cache constructors.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.config import InputShape, ModelConfig, TrainConfig
from repro.models import build_model
from repro.models import decoder as dec_mod
from repro.models import encdec as encdec_mod
from repro.optim import make_optimizer
from repro.sharding import (guard_divisibility, make_ruleset,
                            param_spec_tree)

# sliding window applied to full-attention archs for the long_500k shape
LONG_CONTEXT_WINDOW = 16_384


def model_for(cfg: ModelConfig, shape: InputShape, *, unroll: bool = False):
    """Model variant serving this workload shape (DESIGN.md §5)."""
    kw: Dict = {"scan_unroll": unroll}
    if cfg.family == "encdec":
        kw["max_target_positions"] = shape.seq_len + 1
    if shape.name == "long_500k" and cfg.family in ("dense", "moe", "vlm"):
        kw["sliding_window"] = LONG_CONTEXT_WINDOW
    return build_model(cfg, **kw)


def supported(cfg: ModelConfig, shape: InputShape) -> Tuple[bool, str]:
    if shape.name == "long_500k" and not cfg.supports_long_context():
        return False, ("whisper decoder has a hard 448-position ceiling and "
                       "no sub-quadratic variant (DESIGN.md §5)")
    return True, ""


def input_specs(cfg: ModelConfig, shape: InputShape, *,
                dtype=jnp.bfloat16) -> Dict[str, jax.ShapeDtypeStruct]:
    """Batch specs for the *step function* of this shape's kind."""
    model = model_for(cfg, shape)
    B, S = shape.global_batch, shape.seq_len
    i32 = jnp.int32

    if shape.kind in ("train", "prefill"):
        batch: Dict[str, jax.ShapeDtypeStruct] = {}
        if cfg.family == "vlm":
            text = S - cfg.num_patches
            batch["patches"] = jax.ShapeDtypeStruct(
                (B, cfg.num_patches, cfg.d_model), model.dtype)
            batch["tokens"] = jax.ShapeDtypeStruct((B, text), i32)
            if shape.kind == "train":
                batch["labels"] = jax.ShapeDtypeStruct((B, text), i32)
        elif cfg.family == "encdec":
            batch["frames"] = jax.ShapeDtypeStruct(
                (B, cfg.encoder_seq, cfg.d_model), model.dtype)
            batch["tokens"] = jax.ShapeDtypeStruct((B, S), i32)
            if shape.kind == "train":
                batch["labels"] = jax.ShapeDtypeStruct((B, S), i32)
        else:
            batch["tokens"] = jax.ShapeDtypeStruct((B, S), i32)
            if shape.kind == "train":
                batch["labels"] = jax.ShapeDtypeStruct((B, S), i32)
        return batch

    # decode: one new token against a cache filled to capacity-1
    return {"tokens": jax.ShapeDtypeStruct((B, 1), i32)}


def cache_specs(cfg: ModelConfig, shape: InputShape):
    model = model_for(cfg, shape)
    B, cap = shape.global_batch, shape.seq_len
    if cfg.family == "encdec":
        enc = jax.ShapeDtypeStruct((B, cfg.encoder_seq, cfg.d_model),
                                   model.dtype)
        return jax.eval_shape(
            lambda e: encdec_mod.make_empty_cache(
                cfg, B, cap, model.dtype, e, length=cap - 1), enc)
    return jax.eval_shape(
        lambda: dec_mod.make_empty_cache(cfg, B, cap, model.dtype,
                                         length=cap - 1))


def params_and_opt_specs(cfg: ModelConfig, shape: InputShape,
                         train_cfg: Optional[TrainConfig] = None):
    """eval_shape templates for params (and optimizer state for training)."""
    model = model_for(cfg, shape)
    params = jax.eval_shape(
        lambda k: model.init(k), jax.ShapeDtypeStruct((2,), jnp.uint32))
    if shape.kind != "train":
        return params, None
    opt = make_optimizer(train_cfg or TrainConfig())
    opt_state = jax.eval_shape(lambda p: opt.init(p), params)
    return params, opt_state


# ------------------------------------------------------------- sharding specs
def batch_axes(mesh) -> Tuple[str, ...]:
    return tuple(a for a in mesh.axis_names if a != "model")


def activation_rules(cfg: ModelConfig, shape: InputShape, mesh):
    ba = batch_axes(mesh)
    n_batch_shards = int(np.prod([dict(zip(mesh.axis_names,
                                           mesh.devices.shape))[a]
                                  for a in ba]))
    divisible = shape.global_batch % n_batch_shards == 0
    return make_ruleset(mesh.axis_names, kind=shape.kind,
                        batch_divisible=divisible)


def batch_spec_tree(cfg: ModelConfig, shape: InputShape, mesh,
                    specs: Dict[str, jax.ShapeDtypeStruct]):
    rules = activation_rules(cfg, shape, mesh)
    b = rules["batch"]
    out = {}
    for name, s in specs.items():
        out[name] = P(*([b] + [None] * (len(s.shape) - 1)))
    return guard_divisibility(out, specs, mesh)


def cache_spec_tree(cfg: ModelConfig, shape: InputShape, mesh, cache):
    rules = activation_rules(cfg, shape, mesh)
    b, kvs = rules["batch"], rules["kv_seq"]

    def _spec(path, leaf):
        names = [getattr(p, "key", None) for p in path]
        leafname = next((n for n in reversed(names) if isinstance(n, str)),
                        "")
        if leafname == "length":
            return P(b)
        if "cross" in names:               # [L, B, T_enc, Hkv, dh]
            return P(None, b, None, None, None)
        if leafname in ("k", "v"):         # [L|P, B, cap, Hkv, dh]
            return P(None, b, kvs, None, None)
        if leafname == "conv":             # [P, B, W-1, conv_dim]
            return P(None, b, None, "model")
        if leafname == "ssm":              # [P, B, H, Pd, N]
            return P(None, b, "model", None, None)
        return P(*([None] * leaf.ndim))

    spec = jax.tree_util.tree_map_with_path(_spec, cache)
    return guard_divisibility(spec, cache, mesh)


def param_sharding_tree(cfg: ModelConfig, mesh, params):
    spec = param_spec_tree(params, mesh.axis_names)
    return guard_divisibility(spec, params, mesh)


def to_named(mesh, spec_tree):
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda x: isinstance(x, P))
