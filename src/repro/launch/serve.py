"""Batched serving driver: prefill a prompt batch, then decode tokens.

CPU-scale by default (reduced config); the full configs are exercised via
the dry-run. Serves any assigned decoder arch:

  PYTHONPATH=src python -m repro.launch.serve --arch qwen2-0.5b --smoke \\
      --batch 4 --prompt-len 64 --gen 16
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.config import reduce_for_smoke
from repro.configs import get_config
from repro.models import build_model
from repro.models.frontend_stub import stub_embeddings


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = reduce_for_smoke(cfg).replace(dtype="float32")
    if cfg.family == "cnn":
        raise SystemExit("cnn has no serving path")
    model = build_model(cfg, max_target_positions=args.prompt_len
                        + args.gen + 1)
    key = jax.random.PRNGKey(args.seed)
    params = model.init(key)

    B, S = args.batch, args.prompt_len
    batch = {"tokens": jax.random.randint(
        jax.random.fold_in(key, 1), (B, S), 0, cfg.vocab_size)}
    if cfg.family == "vlm":
        batch["patches"] = stub_embeddings(cfg, B, jax.random.fold_in(key, 2),
                                           dtype=model.dtype)
    if cfg.family == "encdec":
        batch["frames"] = stub_embeddings(cfg, B, jax.random.fold_in(key, 2),
                                          dtype=model.dtype)

    cap = S + args.gen + (cfg.num_patches if cfg.family == "vlm" else 0) + 1
    prefill = jax.jit(lambda p, b: model.prefill(p, b, cache_len=cap))
    decode = jax.jit(model.decode_step)

    t0 = time.time()
    logits, cache = prefill(params, batch)
    jax.block_until_ready(logits)
    t_prefill = time.time() - t0

    def sample(logits, k):
        if args.temperature <= 0:
            return jnp.argmax(logits[:, -1], -1)
        return jax.random.categorical(k, logits[:, -1] / args.temperature)

    # key itself already seeded model.init — draw the first token from a
    # folded stream (9; 10+i cover the rest of the generation loop)
    toks = sample(logits, jax.random.fold_in(key, 9))[:, None].astype(
        jnp.int32)
    out = [toks]
    t0 = time.time()
    for i in range(args.gen - 1):
        logits, cache = decode(params, cache, toks)
        toks = sample(logits, jax.random.fold_in(key, 10 + i)
                      )[:, None].astype(jnp.int32)
        out.append(toks)
    jax.block_until_ready(toks)
    t_decode = time.time() - t0

    gen = jnp.concatenate(out, axis=1)
    print(f"arch={cfg.name} batch={B} prompt={S} gen={args.gen}")
    print(f"prefill: {t_prefill*1e3:.1f} ms "
          f"({B*S/max(t_prefill,1e-9):.0f} tok/s)")
    print(f"decode : {t_decode*1e3:.1f} ms "
          f"({B*(args.gen-1)/max(t_decode,1e-9):.1f} tok/s)")
    print("sample tokens:", gen[0, :12].tolist())


if __name__ == "__main__":
    main()
