"""Batched serving driver: prefill a prompt batch, then decode tokens.

CPU-scale by default (reduced config); the full configs are exercised via
the dry-run. Serves any assigned decoder arch:

  PYTHONPATH=src python -m repro.launch.serve --arch qwen2-0.5b --smoke \\
      --batch 4 --prompt-len 64 --gen 16

Serve-while-training (DESIGN.md §9): with ``--ckpt-dir`` the server
polls the training run's :class:`~repro.checkpoint.CheckpointManager`
for the newest full-round-state checkpoint and serves its global
params — atomic saves guarantee it never reads a torn file:

  PYTHONPATH=src python -m repro.launch.serve --arch fedtest-mlp --smoke \\
      --ckpt-dir experiments/ckpt --wait-secs 60
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.checkpoint import CheckpointManager
from repro.config import reduce_for_smoke
from repro.configs import get_config
from repro.core.engine import RoundState
from repro.core.scoring import init_scores
from repro.models import build_model
from repro.models.frontend_stub import stub_embeddings


def load_serving_params(mgr: CheckpointManager, model, arch: str = None,
                        wait_secs: float = 0.0, poll_s: float = 0.5):
    """The serve-while-training read path: poll ``mgr`` until a
    checkpoint exists (up to ``wait_secs``), then restore the newest
    loadable one and return ``(global_params, step)``.

    The trainer checkpoints the complete ``RoundState``; the manifest
    written next to it carries the client count and architecture, so
    the reader rebuilds the state template without needing the
    training run's ``FedConfig``, and refuses to serve weights from a
    different arch.
    """
    deadline = time.time() + wait_secs
    while mgr.latest_step() is None:
        if time.time() >= deadline:
            raise FileNotFoundError(
                f"no checkpoint appeared in {mgr.directory} within "
                f"{wait_secs:.0f}s")
        time.sleep(poll_s)
    manifest = mgr.read_manifest() or {}
    saved_arch = manifest.get("arch")
    if arch is not None and saved_arch is not None and saved_arch != arch:
        raise SystemExit(
            f"checkpoint dir holds arch {saved_arch!r}, server was "
            f"asked to serve {arch!r} — refusing")
    num_users = int(manifest.get("fed", {}).get("num_users", 1))

    def abstract_state(key):
        pk, rk = jax.random.split(key)
        return RoundState(global_params=model.init(pk),
                          scores=init_scores(num_users),
                          round_idx=jnp.zeros((), jnp.int32), key=rk)

    template = jax.eval_shape(abstract_state,
                              jax.ShapeDtypeStruct((2,), jnp.uint32))
    state, step = mgr.restore_with_step(template)
    return state.global_params, step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--ckpt-dir", default=None,
                    help="serve the newest checkpoint from a (possibly "
                         "still-running) training run instead of fresh "
                         "init")
    ap.add_argument("--wait-secs", type=float, default=0.0,
                    help="poll --ckpt-dir this long for a first "
                         "checkpoint before giving up")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = reduce_for_smoke(cfg).replace(dtype="float32")
    if cfg.family == "cnn":
        raise SystemExit("cnn has no serving path")
    model = build_model(cfg, max_target_positions=args.prompt_len
                        + args.gen + 1)
    key = jax.random.PRNGKey(args.seed)
    if args.ckpt_dir:
        mgr = CheckpointManager(args.ckpt_dir)
        params, step = load_serving_params(mgr, model, arch=cfg.name,
                                           wait_secs=args.wait_secs)
        print(f"serving round-{step} weights from {args.ckpt_dir}")
    else:
        params = model.init(key)

    B, S = args.batch, args.prompt_len
    batch = {"tokens": jax.random.randint(
        jax.random.fold_in(key, 1), (B, S), 0, cfg.vocab_size)}
    if cfg.family == "vlm":
        batch["patches"] = stub_embeddings(cfg, B, jax.random.fold_in(key, 2),
                                           dtype=model.dtype)
    if cfg.family == "encdec":
        batch["frames"] = stub_embeddings(cfg, B, jax.random.fold_in(key, 2),
                                          dtype=model.dtype)

    cap = S + args.gen + (cfg.num_patches if cfg.family == "vlm" else 0) + 1
    prefill = jax.jit(lambda p, b: model.prefill(p, b, cache_len=cap))
    decode = jax.jit(model.decode_step)

    t0 = time.time()
    logits, cache = prefill(params, batch)
    jax.block_until_ready(logits)
    t_prefill = time.time() - t0

    def sample(logits, k):
        if args.temperature <= 0:
            return jnp.argmax(logits[:, -1], -1)
        return jax.random.categorical(k, logits[:, -1] / args.temperature)

    # key itself already seeded model.init — draw the first token from a
    # folded stream (9; 10+i cover the rest of the generation loop)
    toks = sample(logits, jax.random.fold_in(key, 9))[:, None].astype(
        jnp.int32)
    out = [toks]
    t0 = time.time()
    for i in range(args.gen - 1):
        logits, cache = decode(params, cache, toks)
        toks = sample(logits, jax.random.fold_in(key, 10 + i)
                      )[:, None].astype(jnp.int32)
        out.append(toks)
    jax.block_until_ready(toks)
    t_decode = time.time() - t0

    gen = jnp.concatenate(out, axis=1)
    print(f"arch={cfg.name} batch={B} prompt={S} gen={args.gen}")
    print(f"prefill: {t_prefill*1e3:.1f} ms "
          f"({B*S/max(t_prefill,1e-9):.0f} tok/s)")
    print(f"decode : {t_decode*1e3:.1f} ms "
          f"({B*(args.gen-1)/max(t_decode,1e-9):.1f} tok/s)")
    print("sample tokens:", gen[0, :12].tolist())


if __name__ == "__main__":
    main()
