import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) combo.

For each combination this script:
  1. builds the production mesh (single-pod 16x16 or multi-pod 2x16x16),
  2. constructs ShapeDtypeStruct stand-ins for params / optimizer state /
     batch / KV-cache (no allocation),
  3. jits the right step function with explicit in_shardings,
  4. ``.lower().compile()`` — any sharding mismatch, unsupported collective
     or compile-time OOM is a bug in the framework,
  5. records ``memory_analysis()`` / ``cost_analysis()`` / parsed
     per-device collective bytes into a JSON artifact for §Dry-run and
     §Roofline of EXPERIMENTS.md.

FLOPs/bytes accounting: XLA's cost analysis counts a while-loop (scan)
body once, NOT multiplied by trip count. Since layer stacks are scanned,
the script also compiles reduced-depth variants (2 and 4 scan iterations)
and extrapolates linearly — exact because scan iterations are identical.

Usage:
  python -m repro.launch.dryrun --arch qwen2-72b --shape train_4k --mesh single
  python -m repro.launch.dryrun --all --out experiments/dryrun --resume
"""
import argparse
import json
import time
import traceback

import jax
import jax.numpy as jnp

from repro.config import INPUT_SHAPES, TrainConfig
from repro.configs import get_config, list_configs
from repro.launch.mesh import make_production_mesh
from repro.launch.specs import (
    activation_rules, batch_spec_tree, cache_specs, cache_spec_tree,
    input_specs, model_for, param_sharding_tree, params_and_opt_specs,
    supported, to_named)
from repro.launch.steps import (
    make_decode_step, make_prefill_step, make_train_step)
from repro.roofline import TPU_V5E, model_flops, parse_collectives
from repro.roofline.analysis import (
    collective_bytes_per_device, roofline_terms)
from repro.sharding import logical_rules

ASSIGNED = [a for a in list_configs() if not a.startswith("fedtest-")]


def _layer_period(cfg) -> int:
    from repro.models.decoder import _period
    return _period(cfg) if cfg.family != "encdec" else 1


def _with_depth(cfg, n_units: int):
    """Reduced-depth variant of the same config (n_units scan iterations)."""
    period = _layer_period(cfg)
    kw = {"num_layers": n_units * period}
    if cfg.family == "encdec":
        kw["encoder_layers"] = n_units
    return cfg.replace(**kw)


def _lower_compile(cfg, shape, multi_pod, train_cfg=None,
                   rules_override=None, want_hlo=False, unroll=False):
    """One lower+compile; returns raw per-device cost numbers."""
    mesh = make_production_mesh(multi_pod=multi_pod)
    model = model_for(cfg, shape, unroll=unroll)
    train_cfg = train_cfg or TrainConfig()
    rules = dict(activation_rules(cfg, shape, mesh))
    if rules_override:
        rules.update(rules_override)

    params, opt_state = params_and_opt_specs(cfg, shape, train_cfg)
    p_spec = param_sharding_tree(cfg, mesh, params)
    batch = input_specs(cfg, shape)
    b_spec = batch_spec_tree(cfg, shape, mesh, batch)

    t0 = time.time()
    # jax.set_mesh is 0.5+; the Mesh context manager covers older jax
    set_mesh = getattr(jax, "set_mesh", None) or (lambda m: m)
    with set_mesh(mesh), logical_rules(rules):
        # NamedSharding works on every jax version; raw PartitionSpecs
        # in in_shardings need 0.5+
        named = lambda spec: to_named(mesh, spec)   # noqa: E731
        if shape.kind == "train":
            step, _ = make_train_step(model, train_cfg)
            o_spec = _opt_specs(opt_state, p_spec)
            lowered = jax.jit(step,
                              in_shardings=(named(p_spec), named(o_spec),
                                            named(b_spec)),
                              donate_argnums=(0, 1)).lower(
                params, opt_state, batch)
        elif shape.kind == "prefill":
            step = make_prefill_step(model, cache_len=shape.seq_len)
            lowered = jax.jit(step,
                              in_shardings=(named(p_spec),
                                            named(b_spec))).lower(
                params, batch)
        else:
            step = make_decode_step(model)
            cache = cache_specs(cfg, shape)
            c_spec = cache_spec_tree(cfg, shape, mesh, cache)
            lowered = jax.jit(step,
                              in_shardings=(named(p_spec), named(c_spec),
                                            named(b_spec)),
                              donate_argnums=(1,)).lower(
                params, cache, batch)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):     # jax<=0.4: one dict per device
        cost = cost[0] if cost else {}
    hlo = compiled.as_text()
    colls = parse_collectives(hlo)
    rec = {
        "flops": float(cost.get("flops", 0.0)),
        "bytes": float(cost.get("bytes accessed", 0.0)),
        "collectives": colls,
        "coll_bytes": collective_bytes_per_device(colls),
        "memory": {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "alias_bytes": getattr(mem, "alias_size_in_bytes", None),
        },
        "lower_s": round(t_lower, 1),
        "compile_s": round(t_compile, 1),
        "num_chips": mesh.devices.size,
    }
    if want_hlo:
        rec["hlo"] = hlo
    return rec


def extrapolated_costs(cfg, shape, multi_pod, train_cfg=None,
                       rules_override=None, n1: int = 2, n2: int = 4):
    """Linear depth extrapolation of flops / bytes / collective bytes."""
    period = _layer_period(cfg)
    units_full = (cfg.num_layers // period if cfg.family != "encdec"
                  else cfg.num_layers)
    f1 = _lower_compile(_with_depth(cfg, n1), shape, multi_pod, train_cfg,
                        rules_override, unroll=True)
    f2 = _lower_compile(_with_depth(cfg, n2), shape, multi_pod, train_cfg,
                        rules_override, unroll=True)
    out = {}
    for key in ("flops", "bytes", "coll_bytes"):
        delta = (f2[key] - f1[key]) / (n2 - n1)
        out[key] = f1[key] + (units_full - n1) * delta
        out[key + "_per_unit"] = delta
    colls = {}
    for op in set(f1["collectives"]) | set(f2["collectives"]):
        a, b = f1["collectives"].get(op, 0), f2["collectives"].get(op, 0)
        colls[op] = a + (units_full - n1) * (b - a) / (n2 - n1)
    out["collectives"] = colls
    out["extra_compile_s"] = f1["compile_s"] + f2["compile_s"]
    return out


def lower_one(arch: str, shape_name: str, multi_pod: bool,
              train_cfg=None, rules_override=None, want_hlo: bool = False,
              extrapolate: bool = True):
    cfg = get_config(arch)
    shape = INPUT_SHAPES[shape_name]
    ok, why = supported(cfg, shape)
    if not ok:
        return {"arch": arch, "shape": shape_name,
                "mesh": "multi" if multi_pod else "single",
                "status": "skipped", "reason": why}

    full = _lower_compile(cfg, shape, multi_pod, train_cfg, rules_override,
                          want_hlo=want_hlo)
    if extrapolate:
        costs = extrapolated_costs(cfg, shape, multi_pod, train_cfg,
                                   rules_override)
    else:
        costs = {k: full[k] for k in ("flops", "bytes", "coll_bytes",
                                      "collectives")}

    n_chips = full["num_chips"]
    terms = roofline_terms(costs["flops"], costs["bytes"],
                           costs["coll_bytes"], TPU_V5E, n_chips)
    mf = model_flops(cfg, shape)
    useful = mf / n_chips / max(costs["flops"], 1.0)

    rec = {
        "arch": arch, "shape": shape_name,
        "mesh": "multi" if multi_pod else "single",
        "status": "ok",
        "num_chips": n_chips,
        "lower_s": full["lower_s"], "compile_s": full["compile_s"],
        "memory": full["memory"],
        "cost": {"flops_per_device": costs["flops"],
                 "bytes_per_device": costs["bytes"],
                 "raw_full_compile_flops": full["flops"],
                 "extrapolated": extrapolate},
        "collectives": costs["collectives"],
        "collective_bytes_per_device": costs["coll_bytes"],
        "roofline": terms,
        "model_flops_global": mf,
        "useful_flops_ratio": useful,
        "params": cfg.param_count(),
        "active_params": cfg.active_param_count(),
    }
    if want_hlo:
        rec["hlo"] = full["hlo"]
    return rec


def _opt_specs(opt_state, p_spec):
    """m/v mirror param specs; scalar counters replicate."""
    from jax.sharding import PartitionSpec as P

    def build(node):
        if isinstance(node, dict):
            out = {}
            for k, v in node.items():
                if k in ("m", "v", "mu"):
                    out[k] = p_spec
                elif k == "step":
                    out[k] = P()
                else:
                    out[k] = build(v)
            return out
        return node

    return build(opt_state) if isinstance(opt_state, dict) else opt_state


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="single", choices=["single", "multi"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--resume", action="store_true",
                    help="skip combos whose artifact already exists")
    ap.add_argument("--no-extrapolate", action="store_true",
                    help="skip the depth-extrapolation compiles "
                         "(multi-pod runs only need compile success)")
    args = ap.parse_args()

    os.makedirs(args.out, exist_ok=True)
    combos = []
    if args.all:
        for arch in ASSIGNED:
            for shape in INPUT_SHAPES:
                for mesh in ("single", "multi"):
                    combos.append((arch, shape, mesh))
    else:
        combos = [(args.arch, args.shape, args.mesh)]

    for arch, shape, mesh in combos:
        tag = f"{arch}__{shape}__{mesh}".replace("/", "_")
        path = os.path.join(args.out, tag + ".json")
        if args.resume and os.path.exists(path):
            print(f"[skip existing] {tag}")
            continue
        print(f"[dryrun] {tag} ...", flush=True)
        try:
            # roofline extrapolation is a single-pod deliverable; the
            # multi-pod pass proves the "pod" axis shards & compiles.
            extrap = (mesh == "single") and not args.no_extrapolate
            rec = lower_one(arch, shape, mesh == "multi",
                            extrapolate=extrap)
        except Exception as e:  # a failure here is a framework bug
            rec = {"arch": arch, "shape": shape, "mesh": mesh,
                   "status": "error", "error": repr(e),
                   "traceback": traceback.format_exc()}
        with open(path, "w") as f:
            json.dump(rec, f, indent=1)
        status = rec["status"]
        extra = ""
        if status == "ok":
            r = rec["roofline"]
            extra = (f" compute={r['compute_s']:.2e}s "
                     f"mem={r['memory_s']:.2e}s "
                     f"coll={r['collective_s']:.2e}s "
                     f"bn={r['bottleneck']} "
                     f"useful={rec['useful_flops_ratio']:.2f} "
                     f"compile={rec['compile_s']}s")
        print(f"[{status}] {tag}{extra}", flush=True)


if __name__ == "__main__":
    main()
