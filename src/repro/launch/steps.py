"""Jitted step functions the launchers and dry-runs lower."""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.config import TrainConfig
from repro.optim import make_optimizer


def make_train_step(model, train_cfg: TrainConfig):
    opt = make_optimizer(train_cfg)

    def train_step(params, opt_state, batch
                   ) -> Tuple[Any, Any, Dict[str, jnp.ndarray]]:
        (loss, metrics), grads = jax.value_and_grad(
            model.loss, has_aux=True)(params, batch,
                                      remat=train_cfg.remat)
        params, opt_state = opt.update(grads, opt_state, params)
        metrics = dict(metrics, loss=loss)
        return params, opt_state, metrics

    return train_step, opt


def make_prefill_step(model, cache_len: int):
    def prefill_step(params, batch):
        return model.prefill(params, batch, cache_len=cache_len)
    return prefill_step


def make_decode_step(model):
    def decode_step(params, cache, batch):
        return model.decode_step(params, cache, batch["tokens"])
    return decode_step
