"""Build the §Dry-run and §Roofline markdown tables from the dry-run
artifacts (experiments/dryrun/*.json).

  PYTHONPATH=src python -m repro.launch.report > experiments/roofline.md
"""
from __future__ import annotations

import glob
import json
import os
import sys

from repro.config import INPUT_SHAPES
from repro.configs import get_config
from repro.roofline import model_flops

DRYRUN_DIR = "experiments/dryrun"
SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def fmt_bytes(n):
    if n is None:
        return "-"
    for unit in ("B", "KB", "MB", "GB", "TB"):
        if abs(n) < 1024:
            return f"{n:.1f}{unit}"
        n /= 1024
    return f"{n:.1f}PB"


def fmt_s(x):
    return f"{x:.2e}"


def load_all():
    recs = {}
    for f in glob.glob(os.path.join(DRYRUN_DIR, "*.json")):
        r = json.load(open(f))
        recs[(r["arch"], r["shape"], r["mesh"])] = r
    return recs


def dryrun_table(recs):
    lines = [
        "| arch | shape | mesh | status | chips | args/dev | temp/dev | "
        "compile | collectives |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    archs = sorted({a for a, _, _ in recs})
    for arch in archs:
        for shape in SHAPE_ORDER:
            for mesh in ("single", "multi"):
                r = recs.get((arch, shape, mesh))
                if r is None:
                    continue
                if r["status"] != "ok":
                    reason = r.get("reason", r.get("error", ""))[:60]
                    lines.append(f"| {arch} | {shape} | {mesh} | "
                                 f"{r['status']}: {reason} | | | | | |")
                    continue
                mem = r["memory"]
                colls = ", ".join(
                    f"{k}:{fmt_bytes(v)}"
                    for k, v in sorted(r["collectives"].items())) or "none"
                lines.append(
                    f"| {arch} | {shape} | {mesh} | ok | {r['num_chips']} "
                    f"| {fmt_bytes(mem['argument_bytes'])} "
                    f"| {fmt_bytes(mem['temp_bytes'])} "
                    f"| {r['compile_s']}s | {colls} |")
    return "\n".join(lines)


def roofline_table(recs):
    lines = [
        "| arch | shape | compute_s | memory_s | collective_s | bottleneck "
        "| MODEL_FLOPS | useful ratio | what would move the dominant term |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    hints = {
        ("memory", "train"): "increase arithmetic intensity: larger "
        "per-device batch, fuse optimizer, bf16 master weights",
        ("memory", "prefill"): "larger attention blocks / fused QKV to cut "
        "activation traffic",
        ("memory", "decode"): "batch more requests per chip; quantise KV "
        "cache to int8",
        ("collective", "train"): "shard params less over data (less "
        "all-gather) or overlap collectives with compute",
        ("collective", "prefill"): "reduce tensor-parallel degree for "
        "short-seq layers; overlap all-gathers",
        ("collective", "decode"): "keep params model-sharded only "
        "(no FSDP regather); merge per-layer all-reduces",
        ("compute", "train"): "near roofline — only kernel-level wins left",
        ("compute", "prefill"): "near roofline — kernel-level wins",
        ("compute", "decode"): "near roofline",
    }
    archs = sorted({a for a, _, _ in recs})
    for arch in archs:
        cfg = get_config(arch)
        for shape_name in SHAPE_ORDER:
            r = recs.get((arch, shape_name, "single"))
            if r is None or r["status"] != "ok":
                continue
            shape = INPUT_SHAPES[shape_name]
            mf = model_flops(cfg, shape)
            useful = mf / r["num_chips"] / max(
                r["cost"]["flops_per_device"], 1.0)
            t = r["roofline"]
            hint = hints.get((t["bottleneck"], shape.kind), "")
            lines.append(
                f"| {arch} | {shape_name} | {fmt_s(t['compute_s'])} "
                f"| {fmt_s(t['memory_s'])} | {fmt_s(t['collective_s'])} "
                f"| **{t['bottleneck']}** | {mf:.2e} | {useful:.3f} "
                f"| {hint} |")
    return "\n".join(lines)


def main():
    recs = load_all()
    if not recs:
        print("no artifacts found", file=sys.stderr)
        return
    n_ok = sum(1 for r in recs.values() if r["status"] == "ok")
    n_skip = sum(1 for r in recs.values() if r["status"] == "skipped")
    n_err = sum(1 for r in recs.values() if r["status"] == "error")
    print(f"## Dry-run matrix ({n_ok} ok / {n_skip} skipped / "
          f"{n_err} error of {len(recs)} artifacts)\n")
    print(dryrun_table(recs))
    print("\n## Roofline (single-pod v5e-256 baselines)\n")
    print(roofline_table(recs))


if __name__ == "__main__":
    main()
