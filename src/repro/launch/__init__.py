"""Launchers: production meshes, dry-runs, federated training, serving."""
