import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Perf hillclimb runner (EXPERIMENTS.md §Perf).

Re-lowers one (arch x shape) combination under a named variant — an
activation-rule override, a parameter-sharding mode, a model knob, or a
training knob — and reports the roofline-term deltas against whatever
baseline artifact exists in experiments/dryrun.

  PYTHONPATH=src python -m repro.launch.perf --arch qwen2-72b \\
      --shape decode_32k --variant tp_only_params
"""
import argparse
import json
import time

import jax

from repro.config import INPUT_SHAPES, TrainConfig
from repro.configs import get_config
from repro.launch import dryrun as dr
from repro.roofline import TPU_V5E
from repro.roofline.analysis import roofline_terms

# name -> dict(rules=..., model_kw=..., train_kw=..., params=...)
VARIANTS = {
    "baseline": {},
    # ---- decode-side ideas ----
    # serve with tensor-parallel-only params (no FSDP regather per step)
    "tp_only_params": {"params": "tp_only"},
    # KV cache sequence dim spread over BOTH axes
    "kv_seq_2d": {"rules": {"kv_seq": ("data", "model")}},
    # KV cache sharded over batch only (heads/seq replicated)
    "kv_batch_only": {"rules": {"kv_seq": None}},
    # ---- train-side ideas ----
    "no_remat": {"train_kw": {"remat": False}},
    "sgd_momentum": {"train_kw": {"optimizer": "momentum"}},
    # keep activations' embed dim sharded over model after each block
    "embed_sharded": {"rules": {"embed": "model"}},
    # ---- moe ideas ----
    "moe_group_256": {"model_kw": {"moe_group_size": 256}},
    "moe_group_1024": {"model_kw": {"moe_group_size": 1024}},
    "moe_group_2048": {"model_kw": {"moe_group_size": 2048}},
    # decode: drop the graph-level block scan (GSPMD cannot propagate the
    # kv_seq sharding through the [B,T,..]->[B,nk,bk,..] reshape and
    # re-gathers the cache); a single masked einsum keeps the cache sharded
    # and XLA emits the distributed-softmax psums instead. On real TPU the
    # in-kernel (Pallas) blocking provides the VMEM streaming.
    "decode_naive_attn": {"model_kw": {"attn_impl": "naive"}},
    # decode: keep expert weights stationary (fully sharded over
    # model x data via the expert FFN dim) so serving never re-gathers the
    # expert bank; tiny activation psums replace the 40GB+ weight gathers.
    "moe_stationary": {"params": "moe_stationary"},
    "serve_opt": {"model_kw": {"attn_impl": "naive"},
                  "params": "moe_stationary"},
    # train: Megatron-style sequence parallelism for the residual stream
    "seq_parallel": {"rules": {"seq": "model"}},
    # decode: heads replicated, KV cache stays sequence-sharded — the
    # q.K einsum then contracts locally per seq shard and XLA emits the
    # distributed-softmax psums (true flash-decoding layout). Combines the
    # naive-attn graph with head replication.
    "decode_flash_layout": {"model_kw": {"attn_impl": "naive"},
                            "rules": {"heads": None, "kv_heads": None}},
    "serve_opt2": {"model_kw": {"attn_impl": "naive"},
                   "rules": {"heads": None, "kv_heads": None},
                   "params": "moe_stationary"},
    # scatter (dynamic-update-slice) cache write instead of the one-hot
    # masked multiply — the write touches one row, sharding preserved
    "decode_dus": {"model_kw": {"cache_update": "dus"}},
    "decode_onehot": {"model_kw": {"cache_update": "onehot"}},
    "serve_opt3": {"model_kw": {"attn_impl": "naive",
                                "cache_update": "dus"}},
    # experts stationary AND the (much smaller) non-expert params kept
    # tensor-parallel-only: zero per-step weight gathers
    "serve_stationary_tp": {"params": "moe_stationary_tp"},
    # sequence-chunked cross-entropy: never materialise [B,S,V] fp32 logits
    "ce_chunked": {"model_kw": {"ce_chunk": 512}},
    "ce_chunked_noremat": {"model_kw": {"ce_chunk": 512},
                           "train_kw": {"remat": False}},
    # the "fits on v5e" configuration: residual stream sharded over model
    # (cuts the per-layer remat-saved activations 16x) + chunked CE
    "train_fit": {"rules": {"embed": "model"},
                  "model_kw": {"ce_chunk": 512}},
}


def remap_moe_stationary(spec_tree):
    """Expert banks fully sharded (E over model, FFN dim over data):
    w_gate/w_up [L,E,D,F] -> P(None, model, None, data);
    w_down      [L,E,F,D] -> P(None, model, data, None)."""
    from jax.sharding import PartitionSpec as P

    def walk(node, in_moe=False):
        if isinstance(node, dict):
            return {k: walk(v, in_moe or k == "moe") for k, v in
                    node.items()}
        return node

    def fix_tree(tree):
        if not isinstance(tree, dict):
            return tree
        out = {}
        for k, v in tree.items():
            if k == "moe" and isinstance(v, dict):
                new = dict(v)
                if "w_gate" in new:
                    new["w_gate"] = P(None, "model", None, "data")
                if "w_up" in new:
                    new["w_up"] = P(None, "model", None, "data")
                if "w_down" in new:
                    new["w_down"] = P(None, "model", "data", None)
                out[k] = new
            else:
                out[k] = fix_tree(v)
        return out

    return fix_tree(spec_tree)


def strip_fsdp_params(spec_tree):
    """Replace every non-'model' mesh axis in param specs with None."""
    from jax.sharding import PartitionSpec as P

    def fix(spec):
        out = []
        for entry in spec:
            if entry is None:
                out.append(None)
            elif isinstance(entry, tuple):
                kept = tuple(a for a in entry if a == "model")
                out.append(kept[0] if len(kept) == 1 else (kept or None))
            else:
                out.append(entry if entry == "model" else None)
        return P(*out)

    return jax.tree_util.tree_map(
        fix, spec_tree, is_leaf=lambda x: isinstance(x, P))


def run_variant(arch, shape_name, variant_name, extrapolate=True):
    v = VARIANTS[variant_name]
    cfg = get_config(arch)
    shape = INPUT_SHAPES[shape_name]
    train_cfg = TrainConfig(**v.get("train_kw", {}))
    rules_override = v.get("rules")
    model_kw = v.get("model_kw", {})
    param_mode = v.get("params", "fsdp")

    # monkey-patch the spec builders the dryrun module uses
    orig_model_for = dr.model_for
    orig_param_tree = dr.param_sharding_tree

    def model_for_patched(cfg_, shape_, unroll=False):
        return orig_model_for(cfg_, shape_, unroll=unroll).__class__(
            **{**orig_model_for(cfg_, shape_, unroll=unroll).__dict__,
               **model_kw})

    def param_tree_patched(cfg_, mesh, params):
        spec = orig_param_tree(cfg_, mesh, params)
        if param_mode == "tp_only":
            spec = strip_fsdp_params(spec)
        elif param_mode == "moe_stationary":
            spec = remap_moe_stationary(spec)
        elif param_mode == "moe_stationary_tp":
            spec = remap_moe_stationary(strip_fsdp_params(spec))
        return spec

    dr.model_for = model_for_patched
    dr.param_sharding_tree = param_tree_patched
    try:
        full = dr._lower_compile(cfg, shape, False, train_cfg,
                                 rules_override)
        if extrapolate:
            costs = dr.extrapolated_costs(cfg, shape, False, train_cfg,
                                          rules_override)
        else:
            costs = {k: full[k] for k in ("flops", "bytes", "coll_bytes",
                                          "collectives")}
    finally:
        dr.model_for = orig_model_for
        dr.param_sharding_tree = orig_param_tree

    terms = roofline_terms(costs["flops"], costs["bytes"],
                           costs["coll_bytes"], TPU_V5E,
                           full["num_chips"])
    return {"arch": arch, "shape": shape_name, "variant": variant_name,
            "roofline": terms, "memory": full["memory"],
            "collectives": costs["collectives"],
            "cost": {"flops_per_device": costs["flops"],
                     "bytes_per_device": costs["bytes"]},
            "collective_bytes_per_device": costs["coll_bytes"],
            "compile_s": full["compile_s"]}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--variant", required=True,
                    choices=sorted(VARIANTS))
    ap.add_argument("--out", default="experiments/perf")
    ap.add_argument("--no-extrapolate", action="store_true")
    args = ap.parse_args()

    rec = run_variant(args.arch, args.shape, args.variant,
                      extrapolate=not args.no_extrapolate)
    os.makedirs(args.out, exist_ok=True)
    tag = f"{args.arch}__{args.shape}__{args.variant}"
    with open(os.path.join(args.out, tag + ".json"), "w") as f:
        json.dump(rec, f, indent=1)
    r = rec["roofline"]
    print(f"[perf] {tag}: compute={r['compute_s']:.3e} "
          f"memory={r['memory_s']:.3e} collective={r['collective_s']:.3e} "
          f"bottleneck={r['bottleneck']}")

    # diff against the baseline dry-run artifact when present
    base_path = os.path.join("experiments/dryrun",
                             f"{args.arch}__{args.shape}__single.json")
    if os.path.exists(base_path) and args.variant != "baseline":
        base = json.load(open(base_path))
        if base.get("status") == "ok":
            b = base["roofline"]
            for k in ("compute_s", "memory_s", "collective_s"):
                delta = (r[k] - b[k]) / max(b[k], 1e-30) * 100
                print(f"   {k}: {b[k]:.3e} -> {r[k]:.3e}  ({delta:+.1f}%)")


if __name__ == "__main__":
    main()
