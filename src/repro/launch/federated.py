"""Pod-level federated training driver: FedTest via shard_map, one client
per device along the ``clients`` mesh axis.

This is the datacenter deployment path of DESIGN.md §3 (the single-host
``launch/train.py`` engine is the simulation path). On real hardware the
mesh axis maps onto TPU chips; in this container it runs on host-platform
placeholder devices:

  XLA_FLAGS=--xla_force_host_platform_device_count=8 PYTHONPATH=src \\
      python -m repro.launch.federated --clients 8 --rounds 4 \\
      --exchange ring
"""
from __future__ import annotations

import argparse
import json
import os
import time

import numpy as np


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--clients", type=int, default=8)
    ap.add_argument("--rounds", type=int, default=4)
    ap.add_argument("--local-steps", type=int, default=6)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--lr", type=float, default=0.1)
    ap.add_argument("--exchange", default="ring",
                    choices=["ring", "allgather"],
                    help="cross-testing model exchange schedule")
    ap.add_argument("--aggregator", default="fedtest",
                    help="repro.strategies.AGGREGATORS name (krum / "
                         "trimmed_mean / median all-gather flat updates; "
                         "trimmed_mean_coord / median_coord additionally "
                         "combine() them per-coordinate on the gathered "
                         "matrix, replicated across the pod)")
    ap.add_argument("--selector", default="rotating",
                    help="repro.strategies.SELECTORS name for the per-"
                         "round tester mask")
    ap.add_argument("--testers", type=int, default=None,
                    help="K testers per round (default: all clients)")
    ap.add_argument("--dataset", default="mnist_like",
                    choices=["mnist_like", "cifar_like"])
    ap.add_argument("--out", default="experiments/federated_pod")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    # the device count must be set before jax initialises
    if "--xla_force_host_platform_device_count" not in \
            os.environ.get("XLA_FLAGS", ""):
        os.environ["XLA_FLAGS"] = (
            os.environ.get("XLA_FLAGS", "")
            + f" --xla_force_host_platform_device_count={args.clients}")

    import jax
    import jax.numpy as jnp
    from jax.sharding import Mesh

    from repro.config import FedConfig, TrainConfig
    from repro.configs import get_config
    from repro.core.distributed import (
        make_allgather_round, make_distributed_round)
    from repro.core.scoring import init_scores
    from repro.data import (CIFAR_LIKE, MNIST_LIKE,
                            make_federated_image_dataset,
                            sample_client_batches)
    from repro.models import build_model

    N = args.clients
    if len(jax.devices()) < N:
        raise SystemExit(f"need {N} devices, have {len(jax.devices())}; "
                         "set XLA_FLAGS before running")
    mesh = Mesh(np.asarray(jax.devices()[:N]), ("clients",))

    arch = ("fedtest-cnn-mnist" if args.dataset == "mnist_like"
            else "fedtest-cnn")
    cfg = get_config(arch).replace(cnn_channels=(8, 16, 16), cnn_hidden=32)
    model = build_model(cfg)
    K = args.testers or N
    fed = FedConfig(num_users=N, num_testers=K, num_malicious=0,
                    aggregator=args.aggregator, selector=args.selector,
                    local_steps=args.local_steps)
    tc = TrainConfig(optimizer="sgd", lr=args.lr, schedule="constant",
                     batch_size=args.batch, grad_clip=0.0, remat=False)
    spec = MNIST_LIKE if args.dataset == "mnist_like" else CIFAR_LIKE
    data = make_federated_image_dataset(spec, N, num_samples=N * 250,
                                        global_test=400, seed=args.seed)

    make = (make_distributed_round if args.exchange == "ring"
            else make_allgather_round)
    round_fn = jax.jit(make(model, fed, tc, mesh,
                            counts=data.train.counts))
    from repro.strategies import SELECTORS
    selector = SELECTORS.build(fed.selector, fed.strategy_kwargs("selector"))

    params = model.init(jax.random.PRNGKey(args.seed))
    scores = init_scores(N)
    tx, ty = data.test.xs[:, :64], data.test.ys[:, :64]

    history = {"round": [], "acc": [], "local_loss": []}
    t0 = time.time()
    for r in range(args.rounds):
        tester_ids = selector.select(
            jax.random.fold_in(jax.random.PRNGKey(args.seed + 2), r),
            N, K, r)
        mask = jnp.zeros((N,), jnp.float32).at[tester_ids].set(1.0)
        bx, by = sample_client_batches(
            jax.random.fold_in(jax.random.PRNGKey(args.seed + 1), r),
            data.train, fed.local_steps, tc.batch_size)
        params, scores, metrics = round_fn(params, scores, bx, by, tx, ty,
                                           mask)
        logits, _ = model.forward_train(params,
                                        {"images": data.global_x[:400]})
        acc = float((jnp.argmax(logits, -1) == data.global_y[:400]).mean())
        history["round"].append(r + 1)
        history["acc"].append(acc)
        history["local_loss"].append(float(metrics["local_loss"]))
        print(f"round {r + 1}: global_acc={acc:.4f} "
              f"local_loss={float(metrics['local_loss']):.4f} "
              f"({args.exchange} exchange)", flush=True)
    history["wall_s"] = time.time() - t0

    os.makedirs(args.out, exist_ok=True)
    with open(os.path.join(args.out,
                           f"{args.dataset}__{args.exchange}.json"),
              "w") as f:
        json.dump(history, f, indent=1)


if __name__ == "__main__":
    main()
