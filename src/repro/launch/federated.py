"""Pod-level federated training driver: FedTest via shard_map, one client
per device along the ``clients`` mesh axis.

This is the datacenter deployment path of DESIGN.md §3 (the single-host
``launch/train.py`` driver is the simulation path); both routes drive
the *same* ``repro.core.engine.RoundProgram``, on the ring / allgather
exchange backends here and on the local vmap backend there. The full
adversarial scenario matrix runs on either: ``--attack`` /
``--malicious`` / ``--attack-scale`` resolve against the ``ATTACKS``
registry (corruption happens per device, before the model exchange) and
``--participation`` samples a client subset per round. On real hardware the mesh axis maps
onto TPU chips; in this container it runs on host-platform placeholder
devices:

  XLA_FLAGS=--xla_force_host_platform_device_count=8 PYTHONPATH=src \\
      python -m repro.launch.federated --clients 8 --rounds 4 \\
      --exchange ring --attack sign_flip --malicious 1 \\
      --participation 0.75

Named presets from ``repro.configs.scenarios`` run on the pod too —
``--scenario`` refits the preset to the device count
(``scenario_for_pod``); explicitly passed flags still override preset
fields, mirroring ``repro.launch.train``.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import time

import numpy as np

# FedConfig fields the CLI leaves unset fall back to these (argparse
# defaults are None so --scenario can tell "explicitly passed" apart)
_FED_CLI_DEFAULTS = dict(
    num_malicious=0, attack="none", attack_kwargs={}, attack_scale=1.0,
    aggregator="fedtest", selector="rotating", participation=1.0,
    coalition="none", coalition_kwargs={}, coalition_size=0,
    fault="none", fault_kwargs={}, fault_rate=0.1,
    local_steps=6)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--clients", type=int, default=8)
    ap.add_argument("--population", type=int, default=None,
                    help="run the population tier (DESIGN.md §11): N "
                         "simulated clients, per-round compute on the "
                         "sampled cohort only, the [C] cohort axis "
                         "GSPMD-sharded across the --clients devices")
    ap.add_argument("--cohort", type=int, default=None,
                    help="cohort slot capacity C for --population; must "
                         "divide evenly across --clients devices. The "
                         "Bernoulli sampling rate is refit to C/N. "
                         "Errors loudly when C > N")
    ap.add_argument("--testers-from-cohort", action="store_true",
                    help="population tier: recruit the round's testing "
                         "committee from the sampled cohort instead of "
                         "the whole population (at C << N a "
                         "population-wide tester almost never "
                         "participates, so every report row is masked "
                         "and scoring degenerates; DESIGN.md §11)")
    ap.add_argument("--rounds", type=int, default=4)
    ap.add_argument("--local-steps", type=int, default=None)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--lr", type=float, default=0.1)
    ap.add_argument("--exchange", default="ring",
                    choices=["ring", "allgather"],
                    help="cross-testing model exchange schedule "
                         "(EXPERIMENTS.md §Perf compares the two)")
    ap.add_argument("--scenario", default=None,
                    help="named FedConfig preset (repro.configs."
                         "scenarios), refitted to --clients devices; "
                         "explicit flags override preset fields")
    ap.add_argument("--aggregator", default=None,
                    help="repro.strategies.AGGREGATORS name (krum / "
                         "trimmed_mean / median all-gather flat updates; "
                         "trimmed_mean_coord / median_coord additionally "
                         "combine() them per-coordinate on the gathered "
                         "matrix, replicated across the pod)")
    ap.add_argument("--attack", default=None,
                    help="repro.strategies.ATTACKS name; corruption runs "
                         "per device before the model exchange")
    ap.add_argument("--malicious", type=int, default=None,
                    help="number of malicious clients (placement via "
                         "--attack-kwargs)")
    ap.add_argument("--attack-kwargs", default=None, type=json.loads,
                    help="JSON kwargs for the attack ctor, e.g. "
                         '\'{"placement": "first"}\'')
    ap.add_argument("--attack-scale", type=float, default=None)
    ap.add_argument("--participation", type=float, default=None,
                    help="per-round Bernoulli client-sampling fraction "
                         "R/N; non-sampled clients train nothing, report "
                         "nothing and get zero aggregation weight")
    ap.add_argument("--selector", default=None,
                    help="repro.strategies.SELECTORS name for the per-"
                         "round tester mask")
    ap.add_argument("--coalition", default=None,
                    help="repro.strategies.COALITIONS name "
                         "(DESIGN.md §7): coordinated members mount a "
                         "model attack and/or rewrite their tester rows "
                         "of the replicated accuracy matrix")
    ap.add_argument("--coalition-size", type=int, default=None,
                    help="number of coordinated members")
    ap.add_argument("--coalition-kwargs", default=None, type=json.loads,
                    help="JSON kwargs for the coalition ctor, e.g. "
                         '\'{"boost_to": 0.9}\'')
    ap.add_argument("--fault", default=None,
                    help="repro.strategies.FAULTS name (DESIGN.md §9): "
                         "availability fault ANDed into the "
                         "participation mask after tester selection")
    ap.add_argument("--fault-rate", type=float, default=None,
                    help="per-round drop probability for the fault model")
    ap.add_argument("--fault-kwargs", default=None, type=json.loads,
                    help="JSON kwargs for the fault ctor, e.g. "
                         '\'{"deadline": 2.0}\'')
    ap.add_argument("--compressor", default=None,
                    help="repro.strategies.COMPRESSORS name "
                         "(DESIGN.md §12): clients transmit encoded "
                         "deltas with per-client error feedback instead "
                         "of dense models; the round carries a "
                         "replicated [N, D] feedback buffer")
    ap.add_argument("--compressor-kwargs", default=None, type=json.loads,
                    help="JSON kwargs for the compressor ctor, e.g. "
                         '\'{"k": 0.05}\' (topk) or \'{"chunk": 256}\' '
                         "(int8)")
    ap.add_argument("--assert-malicious-below", type=float, default=None,
                    help="exit non-zero unless the final round's "
                         "malicious_weight is below this bar (the CI "
                         "coalition smoke gate)")
    ap.add_argument("--testers", type=int, default=None,
                    help="K testers per round (default: all clients)")
    ap.add_argument("--crosstest-impl", default=None,
                    choices=["batched", "reference"],
                    help="cross-testing dispatch model (DESIGN.md §10): "
                         "overlapped/batched fast path vs the reference "
                         "schedule (bit-identical)")
    ap.add_argument("--dataset", default="mnist_like",
                    choices=["mnist_like", "cifar_like"])
    ap.add_argument("--min-classes", type=int, default=None,
                    help="mildest shard skew: every client holds at "
                         "least this many classes (the dynamics bar of "
                         "EXPERIMENTS.md §Paper-validation uses 8 — with "
                         "near-single-class shards the tester accuracy "
                         "matrix is a lottery no scoring can separate)")
    ap.add_argument("--out", default="experiments/federated_pod")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    # the device count must be set before jax initialises
    if "--xla_force_host_platform_device_count" not in \
            os.environ.get("XLA_FLAGS", ""):
        os.environ["XLA_FLAGS"] = (
            os.environ.get("XLA_FLAGS", "")
            + f" --xla_force_host_platform_device_count={args.clients}")

    import jax
    import jax.numpy as jnp
    from jax.sharding import Mesh

    from repro.config import FedConfig, TrainConfig
    from repro.configs import get_config, scenario_for_pod
    from repro.core.engine import (
        init_comp_state, make_allgather_round, make_distributed_round,
        round_keys)
    from repro.core.scoring import init_scores
    from repro.data import (CIFAR_LIKE, MNIST_LIKE,
                            make_federated_image_dataset,
                            sample_client_batches)
    from repro.models import build_model

    N = args.clients
    if len(jax.devices()) < N:
        raise SystemExit(f"need {N} devices, have {len(jax.devices())}; "
                         "set XLA_FLAGS before running")
    mesh = Mesh(np.asarray(jax.devices()[:N]), ("clients",))

    if args.population is not None:
        _run_population(args, mesh)
        return

    arch = ("fedtest-cnn-mnist" if args.dataset == "mnist_like"
            else "fedtest-cnn")
    cfg = get_config(arch).replace(cnn_channels=(8, 16, 16), cnn_hidden=32)
    model = build_model(cfg)

    passed = dict(num_testers=args.testers, num_malicious=args.malicious,
                  local_steps=args.local_steps,
                  aggregator=args.aggregator,
                  attack=args.attack, attack_kwargs=args.attack_kwargs,
                  attack_scale=args.attack_scale,
                  participation=args.participation,
                  selector=args.selector,
                  coalition=args.coalition,
                  coalition_size=args.coalition_size,
                  coalition_kwargs=args.coalition_kwargs,
                  fault=args.fault, fault_kwargs=args.fault_kwargs,
                  fault_rate=args.fault_rate,
                  compressor=args.compressor,
                  compressor_kwargs=args.compressor_kwargs,
                  crosstest_impl=args.crosstest_impl,
                  seed=args.seed)
    passed = {f: v for f, v in passed.items() if v is not None}
    if args.scenario:
        # preset refitted to the device count; explicit flags override
        fed = dataclasses.replace(scenario_for_pod(args.scenario, N),
                                  **passed)
    else:
        defaults = dict(_FED_CLI_DEFAULTS, num_testers=N)
        fed = FedConfig(num_users=N, **{**defaults, **passed})
    tc = TrainConfig(optimizer="sgd", lr=args.lr, schedule="constant",
                     batch_size=args.batch, grad_clip=0.0, remat=False)
    spec = MNIST_LIKE if args.dataset == "mnist_like" else CIFAR_LIKE
    pkw = ({"min_classes": args.min_classes,
            "max_classes": spec.num_classes}
           if args.min_classes is not None else None)
    data = make_federated_image_dataset(spec, N, num_samples=N * 250,
                                        global_test=400, seed=args.seed,
                                        partition_kwargs=pkw)

    make = (make_distributed_round if args.exchange == "ring"
            else make_allgather_round)
    round_fn = jax.jit(make(model, fed, tc, mesh,
                            counts=data.train.counts,
                            server_data=(data.server_x[:256],
                                         data.server_y[:256])))

    params = model.init(jax.random.PRNGKey(args.seed))
    scores = init_scores(N)
    # compressed exchange (DESIGN.md §12): the round carries the
    # replicated [N, D] error-feedback buffer through the grown
    # round_fn signature; None (and the 8-arg form) when uncompressed
    comp = init_comp_state(fed, model)
    tx, ty = data.test.xs[:, :64], data.test.ys[:, :64]
    run_key = jax.random.PRNGKey(args.seed + 1)

    history = {"round": [], "acc": [], "local_loss": [],
               "malicious_weight": [], "participation_rate": [],
               "dropped_fraction": []}
    t0 = time.time()
    for r in range(args.rounds):
        # the engine derives the tester set and the participation mask
        # from the round key itself (repro.core.engine.round_keys); the
        # host only samples the training batches from the same bundle
        key = jax.random.fold_in(run_key, r)
        bx, by = sample_client_batches(round_keys(key).batch, data.train,
                                       fed.local_steps, tc.batch_size)
        if comp is not None:
            params, scores, comp, metrics = round_fn(
                params, scores, comp, bx, by, tx, ty, key,
                jnp.asarray(r, jnp.int32))
        else:
            params, scores, metrics = round_fn(
                params, scores, bx, by, tx, ty, key,
                jnp.asarray(r, jnp.int32))
        logits, _ = model.forward_train(params,
                                        {"images": data.global_x[:400]})
        acc = float((jnp.argmax(logits, -1) == data.global_y[:400]).mean())
        history["round"].append(r + 1)
        history["acc"].append(acc)
        history["local_loss"].append(float(metrics["local_loss"]))
        history["malicious_weight"].append(
            float(metrics["malicious_weight"]))
        history["participation_rate"].append(
            float(metrics["participation_rate"]))
        history["dropped_fraction"].append(
            float(metrics["dropped_fraction"]))
        print(f"round {r + 1}: global_acc={acc:.4f} "
              f"local_loss={float(metrics['local_loss']):.4f} "
              f"mal_w={float(metrics['malicious_weight']):.4f} "
              f"part={float(metrics['participation_rate']):.2f} "
              f"drop={float(metrics['dropped_fraction']):.2f} "
              f"({args.exchange} exchange)", flush=True)
    history["wall_s"] = time.time() - t0
    history["config"] = {"clients": N, "aggregator": fed.aggregator,
                         "attack": fed.attack,
                         "malicious": fed.num_malicious,
                         "attack_scale": fed.attack_scale,
                         "participation": fed.participation,
                         "coalition": fed.coalition,
                         "coalition_size": fed.coalition_size,
                         "fault": fed.fault, "fault_rate": fed.fault_rate,
                         "compressor": fed.compressor,
                         "scenario": args.scenario,
                         "exchange": args.exchange}

    os.makedirs(args.out, exist_ok=True)
    with open(os.path.join(args.out,
                           f"{args.dataset}__{args.exchange}.json"),
              "w") as f:
        json.dump(history, f, indent=1)

    if args.assert_malicious_below is not None:
        final = history["malicious_weight"][-1]
        if not final < args.assert_malicious_below:
            raise SystemExit(
                f"malicious_weight={final:.4f} did not drop below "
                f"{args.assert_malicious_below} after {args.rounds} "
                "rounds")
        print(f"assert ok: malicious_weight={final:.4f} < "
              f"{args.assert_malicious_below}")


def _run_population(args, mesh):
    """--population path: cohort engine, [C] axis sharded over the mesh.

    The pod path pins one client per device; the population tier
    instead shards the *cohort* stack across the same ``clients`` mesh
    axis via GSPMD (DESIGN.md §11), so N is decoupled from the device
    count. Cross-device reductions are not bitwise-stable, so this path
    is gated on adversary suppression (``--assert-malicious-below``),
    not bit-parity — the unsharded parity matrix lives in
    ``tests/test_population.py``.
    """
    import dataclasses as dc
    import jax

    from repro.config import FedConfig, TrainConfig
    from repro.configs import get_config, scenario_for_population
    from repro.core.engine import PopulationTrainer
    from repro.data import CIFAR_LIKE, MNIST_LIKE
    from repro.data.population import make_synthetic_population

    if args.cohort is None:
        raise SystemExit("--population requires --cohort")
    if args.cohort % args.clients != 0:
        raise SystemExit(
            f"--cohort {args.cohort} must divide evenly across "
            f"--clients {args.clients} devices for the cohort-axis "
            "sharding")

    passed = dict(num_testers=args.testers, num_malicious=args.malicious,
                  local_steps=args.local_steps,
                  aggregator=args.aggregator,
                  attack=args.attack, attack_kwargs=args.attack_kwargs,
                  attack_scale=args.attack_scale,
                  selector=args.selector,
                  coalition=args.coalition,
                  coalition_size=args.coalition_size,
                  coalition_kwargs=args.coalition_kwargs,
                  fault=args.fault, fault_kwargs=args.fault_kwargs,
                  fault_rate=args.fault_rate,
                  compressor=args.compressor,
                  compressor_kwargs=args.compressor_kwargs,
                  crosstest_impl=args.crosstest_impl,
                  rounds=args.rounds, seed=args.seed)
    passed = {f: v for f, v in passed.items() if v is not None}
    if args.scenario:
        # errors loudly on C > N; coalition membership refits inside
        # the population, so a preset's static member set can never
        # fall outside it
        fed = scenario_for_population(args.scenario, args.population,
                                      args.cohort)
        fed = dc.replace(fed, **passed)
    else:
        base = dict(_FED_CLI_DEFAULTS, num_testers=min(8, args.cohort))
        base.update(passed)
        base.update(num_users=args.population, cohort=args.cohort,
                    participation=(args.cohort / args.population
                                   if args.cohort < args.population
                                   else base.get("participation", 1.0)))
        fed = FedConfig(**base)

    spec = MNIST_LIKE if args.dataset == "mnist_like" else CIFAR_LIKE
    arch = ("fedtest-cnn-mnist" if args.dataset == "mnist_like"
            else "fedtest-cnn")
    cfg = get_config(arch).replace(cnn_channels=(8, 16, 16), cnn_hidden=32)
    from repro.models import build_model
    model = build_model(cfg)
    tc = TrainConfig(optimizer="sgd", lr=args.lr, schedule="constant",
                     batch_size=args.batch, grad_clip=0.0, remat=False)
    # derive-on-gather population data: construction cost independent
    # of N, only the cohort's shards ever exist on device
    data = make_synthetic_population(
        args.population, per_client=max(args.batch * 4, 64),
        image_size=spec.image_size, channels=spec.channels,
        num_classes=spec.num_classes, noise=spec.noise, seed=args.seed)

    trainer = PopulationTrainer(
        model, fed, tc, mesh=mesh, eval_batch=64,
        testers_from_cohort=args.testers_from_cohort)
    t0 = time.time()
    state, history = trainer.run(jax.random.PRNGKey(args.seed), data,
                                 verbose=True)
    history["wall_s"] = time.time() - t0
    history["config"] = {"population": args.population,
                         "cohort": args.cohort,
                         "devices": args.clients,
                         "aggregator": fed.aggregator,
                         "attack": fed.attack,
                         "malicious": fed.num_malicious,
                         "attack_scale": fed.attack_scale,
                         "participation": fed.participation,
                         "coalition": fed.coalition,
                         "coalition_size": fed.coalition_size,
                         "compressor": fed.compressor,
                         "scenario": args.scenario}

    os.makedirs(args.out, exist_ok=True)
    with open(os.path.join(args.out,
                           f"{args.dataset}__population.json"), "w") as f:
        json.dump(history, f, indent=1)

    if args.assert_malicious_below is not None:
        final = history["malicious_weight"][-1]
        if not final < args.assert_malicious_below:
            raise SystemExit(
                f"malicious_weight={final:.4f} did not drop below "
                f"{args.assert_malicious_below} after "
                f"{int(state.round_idx)} rounds")
        print(f"assert ok: malicious_weight={final:.4f} < "
              f"{args.assert_malicious_below}")


if __name__ == "__main__":
    main()
