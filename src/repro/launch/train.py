"""Federated training driver (the paper's training kind).

Aggregators, attacks and tester-selection policies are resolved by name
from :mod:`repro.strategies`, so every registered strategy is drivable
from this CLI without touching the engine.

Examples:
  # Fig. 4 reproduction (CIFAR-like, FedTest vs baselines):
  PYTHONPATH=src python -m repro.launch.train --dataset cifar_like \\
      --aggregator fedtest --users 20 --testers 5 --malicious 3 --rounds 60

  # robust baseline vs model-replacement, attackers in the first slots:
  PYTHONPATH=src python -m repro.launch.train --aggregator krum \\
      --attack scaled_update --attack-scale 10 --malicious 4 \\
      --attack-kwargs '{"placement": "first"}'

  # a named scenario preset (see repro.configs.scenarios):
  PYTHONPATH=src python -m repro.launch.train --scenario \\
      krum_vs_scaled_update --rounds 10

  # Federated fine-tuning of an assigned LM backbone (reduced for CPU):
  PYTHONPATH=src python -m repro.launch.train --arch qwen2-0.5b --smoke \\
      --dataset lm --rounds 3
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import signal
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import FedConfig, TrainConfig, reduce_for_smoke
from repro.configs import (
    get_config, get_scenario, list_scenarios, scenario_for_population)
from repro.core import FederatedTrainer, PopulationTrainer
from repro.data.population import DensePopulationData
from repro.strategies import AGGREGATORS, ATTACKS, COALITIONS, \
    COMPRESSORS, FAULTS, SELECTORS
from repro.checkpoint import CheckpointManager
from repro.data import (
    CIFAR_LIKE, MNIST_LIKE, make_federated_image_dataset, make_token_stream)
from repro.data.partition import build_client_arrays
from repro.data.pipeline import FederatedDataset, split_client_holdout
from repro.models import build_model


def make_lm_federated_dataset(vocab: int, num_users: int, seq_len: int = 64,
                              seqs_per_user: int = 64, seed: int = 0,
                              skew: float = 0.7) -> FederatedDataset:
    """Non-IID LM data: client i holds ``skew`` of its sequences from its
    own topic and the rest from a uniform topic mix (total disjointness
    would make the global task unlearnable under client drift)."""
    rng = np.random.default_rng(seed)
    toks, topics = make_token_stream(vocab, num_users * seqs_per_user * 2,
                                     seq_len + 1, num_topics=num_users,
                                     seed=seed)
    x = toks[:, :-1]
    y = toks[:, 1:]
    n = num_users * seqs_per_user
    by_topic = [list(np.flatnonzero(topics[:n] == t)) for t in
                range(num_users)]
    pool = list(range(n))
    rng.shuffle(pool)
    parts = []
    used = set()
    for u in range(num_users):
        own = [i for i in by_topic[u % num_users] if i not in used]
        take_own = int(seqs_per_user * skew)
        sel = own[:take_own]
        used.update(sel)
        fill = [i for i in pool if i not in used][:seqs_per_user - len(sel)]
        used.update(fill)
        parts.append(np.array(sel + fill, dtype=np.int64))
    xs, ys, counts = build_client_arrays(x[:n], y[:n], parts)
    train, test = split_client_holdout(xs, ys, counts, frac=0.25)
    return FederatedDataset(train=train, test=test,
                            global_x=jnp.asarray(x[n:n + 512]),
                            global_y=jnp.asarray(y[n:n + 512]),
                            server_x=jnp.asarray(x[n + 512:n + 768]),
                            server_y=jnp.asarray(y[n + 512:n + 768]))


# FedConfig fields the CLI leaves unset use these (the argparse flags
# default to None so --scenario can tell "explicitly passed" apart)
_FED_CLI_DEFAULTS = dict(
    num_users=20, num_testers=5, num_malicious=0, rounds=40,
    local_steps=10, score_power=4.0, score_decay=0.5,
    aggregator="fedtest", aggregator_kwargs={},
    attack="random_weights", attack_kwargs={}, attack_scale=1.0,
    selector="rotating", selector_kwargs={},
    coalition="none", coalition_kwargs={}, coalition_size=0,
    fault="none", fault_kwargs={}, fault_rate=0.1,
    compressor="identity", compressor_kwargs={}, seed=0)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="fedtest-cnn")
    ap.add_argument("--smoke", action="store_true",
                    help="reduce the arch for CPU-scale runs")
    ap.add_argument("--dataset", default="cifar_like",
                    choices=["cifar_like", "mnist_like", "lm"])
    ap.add_argument("--scenario", default=None, choices=list_scenarios(),
                    help="named FedConfig preset; flags set explicitly "
                         "on the CLI override preset fields")
    ap.add_argument("--aggregator", default=None,
                    choices=list(AGGREGATORS.names()))
    ap.add_argument("--agg-kwargs", default=None, type=json.loads,
                    help="JSON kwargs for the aggregator ctor")
    ap.add_argument("--users", type=int, default=None)
    ap.add_argument("--population", type=int, default=None,
                    help="run the population tier (DESIGN.md §11) over "
                         "this many clients: per-round compute touches "
                         "only the sampled cohort (--cohort), scores "
                         "stay dense [N]. Scenario presets are refit "
                         "via scenario_for_population")
    ap.add_argument("--cohort", type=int, default=None,
                    help="cohort slot capacity C for --population "
                         "(default: the whole population); the "
                         "Bernoulli sampling rate is refit to C/N. "
                         "Errors loudly when C > N")
    ap.add_argument("--testers-from-cohort", action="store_true",
                    help="population tier: recruit the round's testing "
                         "committee from the sampled cohort (at C << N "
                         "a population-wide tester almost never "
                         "participates and scoring degenerates; "
                         "DESIGN.md §11)")
    ap.add_argument("--testers", type=int, default=None)
    ap.add_argument("--malicious", type=int, default=None)
    ap.add_argument("--attack", default=None,
                    choices=list(ATTACKS.names()))
    ap.add_argument("--attack-kwargs", default=None, type=json.loads,
                    help="JSON kwargs for the attack ctor, e.g. "
                         '\'{"placement": "first"}\'')
    ap.add_argument("--attack-scale", type=float, default=None)
    ap.add_argument("--selector", default=None,
                    choices=list(SELECTORS.names()))
    ap.add_argument("--selector-kwargs", default=None, type=json.loads)
    ap.add_argument("--coalition", default=None,
                    choices=list(COALITIONS.names()),
                    help="coordinated multi-client adversary "
                         "(repro.strategies.COALITIONS; DESIGN.md §7); "
                         "size via --coalition-size")
    ap.add_argument("--coalition-size", type=int, default=None,
                    help="number of coordinated members (placement via "
                         "--coalition-kwargs)")
    ap.add_argument("--coalition-kwargs", default=None, type=json.loads,
                    help="JSON kwargs for the coalition ctor, e.g. "
                         '\'{"boost_to": 0.9, "deflate_top": 2}\'')
    ap.add_argument("--rounds", type=int, default=None)
    ap.add_argument("--rounds-per-call", type=int, default=1,
                    help=">1 routes steady-state training through the "
                         "scanned multi-round driver (lax.scan over this "
                         "many rounds per dispatch, donated state "
                         "buffers); global accuracy is evaluated at "
                         "chunk boundaries")
    ap.add_argument("--local-steps", type=int, default=None)
    ap.add_argument("--batch", type=int, default=32)
    ap.add_argument("--lr", type=float, default=0.05)
    ap.add_argument("--optimizer", default="sgd")
    ap.add_argument("--score-power", type=float, default=None)
    ap.add_argument("--score-decay", type=float, default=None)
    ap.add_argument("--samples", type=int, default=20000)
    ap.add_argument("--seed", type=int, default=None)
    ap.add_argument("--fault", default=None, choices=list(FAULTS.names()),
                    help="availability fault injected after tester "
                         "selection (repro.strategies.FAULTS; "
                         "DESIGN.md §9)")
    ap.add_argument("--fault-rate", type=float, default=None,
                    help="per-round drop probability offered to the "
                         "fault model (dropout)")
    ap.add_argument("--fault-kwargs", default=None, type=json.loads,
                    help="JSON kwargs for the fault ctor, e.g. "
                         '\'{"placement": "first", "size": 2}\'')
    ap.add_argument("--compressor", default=None,
                    choices=list(COMPRESSORS.names()),
                    help="compressed update exchange "
                         "(repro.strategies.COMPRESSORS; DESIGN.md §12):"
                         " clients transmit encoded deltas with "
                         "per-client error feedback instead of dense "
                         "models")
    ap.add_argument("--compressor-kwargs", default=None, type=json.loads,
                    help="JSON kwargs for the compressor ctor, e.g. "
                         '\'{"k": 0.05}\' (topk) or \'{"chunk": 256}\' '
                         "(int8)")
    ap.add_argument("--assert-malicious-below", type=float, default=None,
                    help="exit non-zero unless the final round's "
                         "malicious_weight is below this bar (the CI "
                         "dropout-suppression gate)")
    ap.add_argument("--crosstest-impl", default=None,
                    choices=["batched", "reference"],
                    help="cross-testing dispatch model (DESIGN.md §10): "
                         "one fused [N, batch] eval per tester vs the "
                         "per-client reference loop (bit-identical)")
    ap.add_argument("--eval-resample-every", type=int, default=0,
                    help="resample the schedule-keyed tester eval "
                         "batches every N rounds (0 = fixed prefix "
                         "slice, the legacy behaviour)")
    ap.add_argument("--out", default="experiments/train")
    ap.add_argument("--ckpt-dir", default=None,
                    help="checkpoint directory (final state is always "
                         "saved there; periodic saves via --ckpt-every)")
    ap.add_argument("--ckpt-every", type=int, default=0,
                    help="save the full round state every N completed "
                         "rounds (0 = final save only)")
    ap.add_argument("--resume", action="store_true",
                    help="restore the newest checkpoint from --ckpt-dir "
                         "and continue to --rounds; refuses a manifest "
                         "mismatch")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.dataset == "mnist_like" and args.arch == "fedtest-cnn":
        cfg = get_config("fedtest-cnn-mnist")
    if args.smoke:
        cfg = reduce_for_smoke(cfg).replace(dtype="float32")
    model = build_model(cfg)

    passed = dict(num_users=args.users, num_testers=args.testers,
                  num_malicious=args.malicious, rounds=args.rounds,
                  local_steps=args.local_steps,
                  score_power=args.score_power,
                  score_decay=args.score_decay,
                  aggregator=args.aggregator,
                  aggregator_kwargs=args.agg_kwargs,
                  attack=args.attack, attack_kwargs=args.attack_kwargs,
                  attack_scale=args.attack_scale,
                  selector=args.selector,
                  selector_kwargs=args.selector_kwargs,
                  coalition=args.coalition,
                  coalition_size=args.coalition_size,
                  coalition_kwargs=args.coalition_kwargs,
                  fault=args.fault, fault_kwargs=args.fault_kwargs,
                  fault_rate=args.fault_rate,
                  compressor=args.compressor,
                  compressor_kwargs=args.compressor_kwargs,
                  crosstest_impl=args.crosstest_impl,
                  seed=args.seed)
    passed = {f: v for f, v in passed.items() if v is not None}
    if args.cohort is not None and args.population is None:
        raise SystemExit("--cohort requires --population")
    if args.population is not None:
        # population tier (DESIGN.md §11): N comes from --population,
        # the sampling rate from the cohort budget
        if args.users is not None:
            raise SystemExit("--population replaces --users; pass one")
        if args.eval_resample_every:
            raise SystemExit("--eval-resample-every is a dense-driver "
                             "feature; the population tier gathers "
                             "tester rows directly")
        cohort = args.cohort or args.population
        if args.scenario:
            # scenario_for_population errors loudly on C > N and refits
            # coalition membership inside the population
            fed = scenario_for_population(args.scenario, args.population,
                                          cohort)
            fed = dataclasses.replace(
                fed, **{f: v for f, v in passed.items()
                        if f != "num_users"})
        else:
            base = {**_FED_CLI_DEFAULTS, **passed,
                    "num_users": args.population, "cohort": cohort}
            if cohort < args.population:
                base["participation"] = cohort / args.population
            fed = FedConfig(**base)
    elif args.scenario:
        # preset first; every explicitly-passed flag overrides it
        fed = dataclasses.replace(get_scenario(args.scenario), **passed)
    else:
        fed = FedConfig(**{**_FED_CLI_DEFAULTS, **passed})
    tc = TrainConfig(optimizer=args.optimizer, lr=args.lr,
                     schedule="constant", batch_size=args.batch,
                     grad_clip=0.0, remat=False)

    if args.dataset == "lm":
        data = make_lm_federated_dataset(cfg.vocab_size, fed.num_users,
                                         seed=fed.seed)
    else:
        spec = CIFAR_LIKE if args.dataset == "cifar_like" else MNIST_LIKE
        data = make_federated_image_dataset(spec, fed.num_users,
                                            num_samples=args.samples,
                                            seed=fed.seed)

    if args.population is not None:
        data = DensePopulationData(data)
        trainer = PopulationTrainer(
            model, fed, tc, rounds_per_call=args.rounds_per_call,
            testers_from_cohort=args.testers_from_cohort)
    else:
        trainer = FederatedTrainer(
            model, fed, tc, rounds_per_call=args.rounds_per_call,
            eval_resample_every=args.eval_resample_every)

    mgr = None
    if args.ckpt_dir:
        mgr = CheckpointManager(args.ckpt_dir,
                                save_every=args.ckpt_every)
    init_state = None
    if args.resume:
        if mgr is None:
            raise SystemExit("--resume requires --ckpt-dir")
        init_state, at = trainer.restore_checkpoint(mgr)
        print(f"resuming from round {at} in {args.ckpt_dir}")

    # SIGTERM drains the loop at the next driver-call boundary; the
    # state returned by run() is then saved below like any other exit,
    # so an orchestrator's soft kill never loses completed rounds.
    stop = {"flag": False}

    def _on_sigterm(signum, frame):
        stop["flag"] = True
        print("SIGTERM: finishing current chunk, then checkpointing")

    prev_handler = signal.signal(signal.SIGTERM, _on_sigterm)

    t0 = time.time()
    state, history = trainer.run(jax.random.PRNGKey(fed.seed), data,
                                 verbose=True, state=init_state,
                                 ckpt=mgr,
                                 should_stop=lambda: stop["flag"])
    signal.signal(signal.SIGTERM, prev_handler)

    completed = int(state.round_idx)   # NOT fed.rounds: the run may have
    if mgr is not None:                # stopped early (SIGTERM/resume)
        trainer.save_checkpoint(mgr, state, step=completed)
        print(f"checkpoint saved at round {completed} -> {args.ckpt_dir}")
    if stop["flag"]:
        raise SystemExit(f"interrupted at round {completed} (state saved)")

    history["wall_s"] = time.time() - t0
    history["config"] = {"arch": cfg.name, "dataset": args.dataset,
                         "aggregator": fed.aggregator,
                         "attack": fed.attack, "selector": fed.selector,
                         "coalition": fed.coalition,
                         "coalition_size": fed.coalition_size,
                         "fault": fed.fault, "fault_rate": fed.fault_rate,
                         "compressor": fed.compressor,
                         "scenario": args.scenario,
                         "users": fed.num_users, "testers": fed.num_testers,
                         "malicious": fed.num_malicious,
                         "cohort": fed.cohort,
                         "resumed": bool(args.resume)}

    os.makedirs(args.out, exist_ok=True)
    tag = (f"{cfg.name}__{args.dataset}__{fed.aggregator}"
           f"__{fed.attack}__m{fed.num_malicious}")
    with open(os.path.join(args.out, tag + ".json"), "w") as f:
        json.dump(history, f, indent=1)
    if history["global_accuracy"]:
        print(f"final accuracy: {history['global_accuracy'][-1]:.4f} "
              f"({history['wall_s']:.0f}s) -> {args.out}/{tag}.json")
    else:   # resumed past the target: nothing ran, nothing to report
        print(f"no rounds to run (already at {completed}/{fed.rounds})")

    if args.assert_malicious_below is not None:
        final = history["malicious_weight"][-1]
        if not final < args.assert_malicious_below:
            raise SystemExit(
                f"malicious_weight={final:.4f} did not drop below "
                f"{args.assert_malicious_below} after {completed} "
                "rounds")
        print(f"assert ok: malicious_weight={final:.4f} < "
              f"{args.assert_malicious_below}")


if __name__ == "__main__":
    main()
