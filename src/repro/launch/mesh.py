"""Production mesh construction.

``make_production_mesh`` is a FUNCTION (not a module-level constant) so
importing this module never touches jax device state — the dry-run script
must set XLA_FLAGS before the first jax call, and tests must keep seeing a
single CPU device.

Target hardware: TPU v5e pods. Single pod = 256 chips as (data=16,
model=16); multi-pod = 2 pods = 512 chips as (pod=2, data=16, model=16).
"""
from __future__ import annotations

import numpy as np

import jax
from jax.sharding import Mesh


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = int(np.prod(shape))
    devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(
            f"need {n} devices for mesh {shape}, have {len(devices)}; "
            "set XLA_FLAGS=--xla_force_host_platform_device_count=512 "
            "BEFORE any jax import (see launch/dryrun.py)")
    return Mesh(np.asarray(devices[:n]).reshape(shape), axes)


def make_host_mesh(shape=(1, 1), axes=("data", "model")) -> Mesh:
    """Tiny mesh over whatever devices exist (tests / examples)."""
    n = int(np.prod(shape))
    devices = jax.devices()[:n]
    return Mesh(np.asarray(devices).reshape(shape), axes)
