"""Feed-forward blocks: SwiGLU (qwen/jamba/pixtral) and GELU (whisper)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import dense_init
from repro.sharding import shard_hint
from repro.utils import key_iter


def swiglu_init(key, d_model: int, d_ff: int, dtype):
    ks = key_iter(key)
    return {
        "w_gate": dense_init(next(ks), (d_model, d_ff), dtype=dtype),
        "w_up": dense_init(next(ks), (d_model, d_ff), dtype=dtype),
        "w_down": dense_init(next(ks), (d_ff, d_model), dtype=dtype),
    }


def swiglu(p, x):
    h = jax.nn.silu((x @ p["w_gate"]).astype(jnp.float32)).astype(x.dtype)
    h = h * (x @ p["w_up"])
    h = shard_hint(h, ("batch", "seq", "mlp"))
    y = h @ p["w_down"]
    return shard_hint(y, ("batch", "seq", "embed"))


def gelu_mlp_init(key, d_model: int, d_ff: int, dtype):
    ks = key_iter(key)
    return {
        "w_in": dense_init(next(ks), (d_model, d_ff), dtype=dtype),
        "b_in": jnp.zeros((d_ff,), dtype),
        "w_out": dense_init(next(ks), (d_ff, d_model), dtype=dtype),
        "b_out": jnp.zeros((d_model,), dtype),
    }


def gelu_mlp(p, x):
    h = jax.nn.gelu((x @ p["w_in"] + p["b_in"]).astype(jnp.float32))
    h = shard_hint(h.astype(x.dtype), ("batch", "seq", "mlp"))
    return shard_hint(h @ p["w_out"] + p["b_out"], ("batch", "seq", "embed"))
