"""Feed-forward blocks: SwiGLU (qwen/jamba/pixtral) and GELU (whisper) —
plus the paper's MNIST fully-connected classifier (family ``mlp``), the
lightest cross-testing workload ``benchmarks/bench_crosstest.py`` sweeps."""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from repro.models.common import dense_init
from repro.sharding import shard_hint
from repro.utils import key_iter


def swiglu_init(key, d_model: int, d_ff: int, dtype):
    ks = key_iter(key)
    return {
        "w_gate": dense_init(next(ks), (d_model, d_ff), dtype=dtype),
        "w_up": dense_init(next(ks), (d_model, d_ff), dtype=dtype),
        "w_down": dense_init(next(ks), (d_ff, d_model), dtype=dtype),
    }


def swiglu(p, x):
    h = jax.nn.silu((x @ p["w_gate"]).astype(jnp.float32)).astype(x.dtype)
    h = h * (x @ p["w_up"])
    h = shard_hint(h, ("batch", "seq", "mlp"))
    y = h @ p["w_down"]
    return shard_hint(y, ("batch", "seq", "embed"))


def gelu_mlp_init(key, d_model: int, d_ff: int, dtype):
    ks = key_iter(key)
    return {
        "w_in": dense_init(next(ks), (d_model, d_ff), dtype=dtype),
        "b_in": jnp.zeros((d_ff,), dtype),
        "w_out": dense_init(next(ks), (d_ff, d_model), dtype=dtype),
        "b_out": jnp.zeros((d_model,), dtype),
    }


def gelu_mlp(p, x):
    h = jax.nn.gelu((x @ p["w_in"] + p["b_in"]).astype(jnp.float32))
    h = shard_hint(h.astype(x.dtype), ("batch", "seq", "mlp"))
    return shard_hint(h @ p["w_out"] + p["b_out"], ("batch", "seq", "embed"))


# --------------------------------------------- MNIST classifier (family mlp)
def init_mlp(cfg, key, dtype=jnp.float32) -> Dict:
    """Flattened-image classifier: image -> cfg.mlp_hidden -> classes."""
    ks = key_iter(key)
    dims = ((cfg.image_size * cfg.image_size * max(cfg.image_channels, 1),)
            + tuple(cfg.mlp_hidden) + (cfg.num_classes,))
    return {f"fc{i}": {"w": dense_init(next(ks), (dims[i], dims[i + 1]),
                                       dtype=dtype),
                       "b": jnp.zeros((dims[i + 1],), dtype)}
            for i in range(len(dims) - 1)}


def mlp_forward(p, cfg, images: jnp.ndarray) -> jnp.ndarray:
    """images [B, H, W, C] (or [B, D]) -> logits [B, num_classes]."""
    x = images.reshape(images.shape[0], -1)
    for i in range(len(cfg.mlp_hidden)):
        x = jax.nn.relu(x @ p[f"fc{i}"]["w"] + p[f"fc{i}"]["b"])
    last = len(cfg.mlp_hidden)
    return x @ p[f"fc{last}"]["w"] + p[f"fc{last}"]["b"]
