"""Top-k mixture-of-experts FFN with capacity-based einsum dispatch.

The dispatch follows the GShard/Switch GSPMD recipe: tokens are split into
groups of ``group_size``; inside a group each token's top-k experts get a
capacity slot (overflow drops to the residual path). Dispatch/combine are
one-hot einsums, which GSPMD partitions into all-to-alls when experts are
sharded over the ``model`` ("expert") mesh axis.

Capacity per group: C = ceil(top_k * group_size * capacity_factor / E).
The dispatch einsum cost is 2 * T * D * top_k * group_size * cf FLOPs —
independent of E and *linear in group_size*, which is why the group size is
kept small (it is a tunable hillclimb knob, see EXPERIMENTS.md §Perf).

Also emits the Switch-style load-balance auxiliary loss.
"""
from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.models.common import dense_init
from repro.sharding import shard_hint
from repro.utils import key_iter

DEFAULT_GROUP = 512
CAPACITY_FACTOR = 1.25


def moe_init(key, cfg, dtype, d_ff: int = 0):
    D = cfg.d_model
    F = d_ff or cfg.d_ff
    E = cfg.num_experts
    ks = key_iter(key)
    return {
        "router": dense_init(next(ks), (D, E), dtype=jnp.float32),
        "w_gate": dense_init(next(ks), (E, D, F), in_axis=1, dtype=dtype),
        "w_up": dense_init(next(ks), (E, D, F), in_axis=1, dtype=dtype),
        "w_down": dense_init(next(ks), (E, F, D), in_axis=1, dtype=dtype),
    }


def _capacity(group: int, top_k: int, E: int,
              cf: float = CAPACITY_FACTOR) -> int:
    return max(int(math.ceil(top_k * group * cf / E)), 1)


def moe_dropless(p, cfg, x) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Dropless routing: every expert runs on every token, combined with the
    (renormalised) top-k gates. Exact per-token routing independent of batch
    composition — used on the decode path where T is small and exactness
    matters more than the E/top_k compute overhead (see EXPERIMENTS.md
    §Roofline for the accounted waste)."""
    B, S, D = x.shape
    E, top_k = cfg.num_experts, cfg.num_experts_per_tok
    xt = x.reshape(B * S, D)
    logits = xt.astype(jnp.float32) @ p["router"]          # [T, E]
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(probs, top_k)
    gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True), 1e-9)
    gates = jnp.zeros_like(probs).at[
        jnp.arange(xt.shape[0])[:, None], expert_idx].set(gate_vals)

    h = jax.nn.silu(jnp.einsum("td,edf->tef", xt, p["w_gate"])
                    .astype(jnp.float32)).astype(x.dtype)
    h = h * jnp.einsum("td,edf->tef", xt, p["w_up"])
    ye = jnp.einsum("tef,efd->ted", h, p["w_down"])
    y = jnp.einsum("te,ted->td", gates.astype(x.dtype), ye)

    frac_tokens = jnp.mean((gates > 0).astype(jnp.float32), axis=0)
    frac_probs = jnp.mean(probs, axis=0)
    aux = E * jnp.sum(frac_tokens * frac_probs) * top_k
    return y.reshape(B, S, D), aux


def moe_apply(p, cfg, x, *, group_size: int = 0,
              dropless: bool = False,
              capacity_factor: float = CAPACITY_FACTOR
              ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x [B, S, D] -> (y [B, S, D], aux_loss scalar)."""
    if dropless:
        return moe_dropless(p, cfg, x)
    group_size = group_size or DEFAULT_GROUP
    B, S, D = x.shape
    E, top_k = cfg.num_experts, cfg.num_experts_per_tok
    T = B * S
    g = min(group_size, T)
    while T % g:
        g -= 1
    G = T // g
    C = _capacity(g, top_k, E, capacity_factor)

    xt = x.reshape(G, g, D)
    logits = (xt.astype(jnp.float32) @ p["router"])        # [G, g, E] fp32
    probs = jax.nn.softmax(logits, axis=-1)

    # top-k selection, positioned into capacity slots
    gate_vals, expert_idx = jax.lax.top_k(probs, top_k)    # [G, g, k]
    gate_vals = gate_vals / jnp.maximum(
        gate_vals.sum(-1, keepdims=True), 1e-9)            # renormalise

    onehot = jax.nn.one_hot(expert_idx, E, dtype=jnp.float32)  # [G,g,k,E]
    # slot position of token t's k-th choice within its expert queue
    pos_e = jnp.cumsum(onehot.reshape(G, g * top_k, E), axis=1
                       ).reshape(G, g, top_k, E) - 1.0
    pos = jnp.sum(pos_e * onehot, axis=-1)                 # [G,g,k] scalar slot
    keep = (pos < C).astype(jnp.float32)
    # one-hot over capacity slots, zeroed for dropped tokens. The [E]x[C]
    # outer products are contracted over k by the einsums below without ever
    # materialising a [g, k, E, C] intermediate.
    slot_oh = jax.nn.one_hot(pos.astype(jnp.int32), C,
                             dtype=jnp.float32) * keep[..., None]  # [G,g,k,C]
    dispatch = jnp.einsum("gtke,gtkc->gtec", onehot, slot_oh)      # [G,g,E,C]
    combine = jnp.einsum("gtke,gtkc->gtec",
                         onehot * gate_vals[..., None], slot_oh)

    dispatch = shard_hint(dispatch, ("expert_group", None, "expert", None))
    xe = jnp.einsum("gtec,gtd->gecd", dispatch.astype(x.dtype), xt)
    xe = shard_hint(xe, ("expert_group", "expert", None, None))

    h = jax.nn.silu(jnp.einsum("gecd,edf->gecf", xe, p["w_gate"])
                    .astype(jnp.float32)).astype(x.dtype)
    h = h * jnp.einsum("gecd,edf->gecf", xe, p["w_up"])
    ye = jnp.einsum("gecf,efd->gecd", h, p["w_down"])
    ye = shard_hint(ye, ("expert_group", "expert", None, None))

    y = jnp.einsum("gtec,gecd->gtd", combine.astype(x.dtype), ye)
    y = y.reshape(B, S, D)

    # Switch load-balance loss: E * sum_e f_e * P_e
    frac_tokens = jnp.mean(onehot[..., 0, :], axis=(0, 1)) if top_k == 1 \
        else jnp.mean(jnp.sum(onehot, axis=2), axis=(0, 1)) / top_k
    frac_probs = jnp.mean(probs, axis=(0, 1))
    aux = E * jnp.sum(frac_tokens * frac_probs)
    return shard_hint(y, ("batch", "seq", "embed")), aux
