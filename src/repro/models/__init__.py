"""Model zoo: composable JAX model definitions for all assigned families.

Everything is functional — params are plain pytrees (nested dicts), layer
stacks are stacked along a leading axis and driven by ``lax.scan`` so the
HLO stays compact for the 80-layer configs. ``repro.models.model`` exposes
the family-independent API the FL round engine and launchers consume:

    m = build_model(cfg)
    params = m.init(key)
    logits = m.forward_train(params, batch)     # [B, S, V]
    logits, cache = m.prefill(params, batch)
    logits, cache = m.decode_step(params, cache, tokens, positions)
"""
from repro.models.model import build_model, Model

__all__ = ["build_model", "Model"]
