"""Parameter accounting (exact, via ``jax.eval_shape`` over the real init).

``count_params_analytic(cfg)`` is used for the roofline MODEL_FLOPS terms:
dense archs use 6*N*D; MoE archs use 6*N_active*D where N_active replaces
each MoE layer's expert bank with top_k experts' worth of weights.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp


@functools.lru_cache(maxsize=None)
def _shapes(cfg):
    from repro.models.model import build_model
    m = build_model(cfg)
    tree = jax.eval_shape(lambda k: m.init(k), jax.ShapeDtypeStruct((2,),
                                                                    jnp.uint32))
    return tree


def _leaf_sizes_with_paths(tree):
    import math
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    for path, leaf in flat:
        name = "/".join(str(p) for p in path)
        yield name, math.prod(leaf.shape) if leaf.shape else 1


def count_params_analytic(cfg, active_only: bool = False) -> int:
    total = 0
    for name, size in _leaf_sizes_with_paths(_shapes(cfg)):
        is_expert = any(t in name for t in ("w_gate", "w_up", "w_down")) \
            and "moe" in name
        if active_only and is_expert and cfg.num_experts:
            size = size * cfg.num_experts_per_tok // cfg.num_experts
        total += size
    return total
