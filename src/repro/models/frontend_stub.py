"""Modality-frontend stubs (the assignment's single allowed carve-out).

The audio conv/mel feature extractor (whisper) and the ViT/projector
(pixtral) are NOT implemented; instead the framework consumes precomputed
frame/patch embeddings of the correct shape:

* audio:  [B, encoder_seq(1500), d_model]
* vision: [B, num_patches, d_model]

``stub_embeddings`` synthesises deterministic pseudo-embeddings for smoke
tests and examples; ``stub_spec`` gives the ShapeDtypeStruct used by
``input_specs()`` for the dry-runs.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def stub_shape(cfg, batch: int):
    if cfg.frontend == "audio":
        return (batch, cfg.encoder_seq, cfg.d_model)
    if cfg.frontend == "vision":
        return (batch, cfg.num_patches, cfg.d_model)
    raise ValueError(f"{cfg.name} has no frontend stub")


def stub_spec(cfg, batch: int, dtype=jnp.bfloat16):
    return jax.ShapeDtypeStruct(stub_shape(cfg, batch), dtype)


def stub_embeddings(cfg, batch: int, key=None, dtype=jnp.float32, *,
                    seed: int = 0):
    """Deterministic stand-in frontend activations.

    Callers that care about the stream pass ``key``; the ``seed``
    fallback keeps the key derivation explicit (FL001) instead of a
    buried ``PRNGKey(0)``.
    """
    key = key if key is not None else jax.random.PRNGKey(seed)
    return jax.random.normal(key, stub_shape(cfg, batch), jnp.float32
                             ).astype(dtype) * 0.02
