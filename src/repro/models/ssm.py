"""Mamba2 (SSD) block — full-sequence chunked scan and single-token decode.

Block layout follows the Mamba2 paper: fused in-projection producing
(z, x, B, C, dt), short causal depthwise conv over (x, B, C), softplus dt,
the SSD scan (``repro.kernels.ssd_scan``), gated RMSNorm, out-projection.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels.ssd_scan import ssd_scan
from repro.kernels.ssd_scan.ref import ssd_decode_ref
from repro.models.common import dense_init, rms_norm
from repro.sharding import shard_hint
from repro.utils import key_iter


def _dims(cfg):
    d_in = cfg.d_inner
    H = cfg.ssm_heads
    P = cfg.ssm_head_dim
    G = cfg.ssm_ngroups
    N = cfg.ssm_state
    conv_dim = d_in + 2 * G * N
    return d_in, H, P, G, N, conv_dim


def ssm_init(key, cfg, dtype):
    D = cfg.d_model
    d_in, H, P, G, N, conv_dim = _dims(cfg)
    W = cfg.ssm_conv_width
    ks = key_iter(key)
    proj_dim = 2 * d_in + 2 * G * N + H
    return {
        "in_proj": dense_init(next(ks), (D, proj_dim), dtype=dtype),
        "conv_w": (jax.random.normal(next(ks), (W, conv_dim), jnp.float32)
                   * (W ** -0.5)).astype(dtype),
        "conv_b": jnp.zeros((conv_dim,), dtype),
        "dt_bias": jnp.zeros((H,), jnp.float32),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, H, dtype=jnp.float32)),
        "D": jnp.ones((H,), jnp.float32),
        "norm_scale": {"scale": jnp.ones((d_in,), jnp.float32)},
        "out_proj": dense_init(next(ks), (d_in, D), dtype=dtype),
    }


def _split_proj(proj, cfg):
    d_in, H, P, G, N, conv_dim = _dims(cfg)
    z = proj[..., :d_in]
    rest = proj[..., d_in:d_in + conv_dim]
    dt = proj[..., d_in + conv_dim:]
    return z, rest, dt                          # rest = (x, B, C) pre-conv


def _split_conv_out(u, cfg):
    d_in, H, P, G, N, _ = _dims(cfg)
    x = u[..., :d_in]
    Bm = u[..., d_in:d_in + G * N]
    Cm = u[..., d_in + G * N:]
    return x, Bm, Cm


def _causal_conv_full(p, u):
    """Depthwise causal conv. u [B,S,C] -> [B,S,C]."""
    W = p["conv_w"].shape[0]
    pad = jnp.pad(u, ((0, 0), (W - 1, 0), (0, 0)))
    out = sum(pad[:, i:i + u.shape[1], :] * p["conv_w"][i]
              for i in range(W))
    return out + p["conv_b"]


def ssm_full(p, cfg, x, *, return_state: bool = False, impl: str = "auto",
             unroll: bool = False):
    """x [B,S,D] -> y [B,S,D] (+ (conv_state, ssm_state) for serve handoff)."""
    B, S, D = x.shape
    d_in, H, P, G, N, conv_dim = _dims(cfg)
    W = cfg.ssm_conv_width

    proj = x @ p["in_proj"]
    z, pre, dt_raw = _split_proj(proj, cfg)
    u = jax.nn.silu(_causal_conv_full(p, pre).astype(jnp.float32)
                    ).astype(x.dtype)
    xs, Bm, Cm = _split_conv_out(u, cfg)
    xs = shard_hint(xs.reshape(B, S, H, P), ("batch", "seq", "heads", None))
    Bm = Bm.reshape(B, S, G, N)
    Cm = Cm.reshape(B, S, G, N)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])

    y, state = ssd_scan(xs, dt, A, Bm, Cm, p["D"], chunk=cfg.ssm_chunk,
                        impl=impl, unroll=unroll)
    y = y.reshape(B, S, d_in)
    y = rms_norm(p["norm_scale"],
                 y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype),
                 cfg.norm_eps)
    out = shard_hint(y @ p["out_proj"], ("batch", "seq", "embed"))
    if return_state:
        conv_state = jnp.pad(pre, ((0, 0), (W - 1, 0), (0, 0)))[:, S:S + W - 1]
        return out, (conv_state, state)
    return out


def ssm_decode(p, cfg, x, conv_state, ssm_state
               ) -> Tuple[jnp.ndarray, Tuple[jnp.ndarray, jnp.ndarray]]:
    """Single-token recurrent step.

    x [B,1,D]; conv_state [B,W-1,conv_dim]; ssm_state [B,H,P,N] fp32.
    """
    B = x.shape[0]
    d_in, H, P, G, N, conv_dim = _dims(cfg)
    W = cfg.ssm_conv_width

    proj = x[:, 0] @ p["in_proj"]                  # [B, proj_dim]
    z, pre, dt_raw = _split_proj(proj, cfg)
    window = jnp.concatenate([conv_state, pre[:, None, :]], axis=1)  # [B,W,C]
    u = jnp.einsum("bwc,wc->bc", window, p["conv_w"]) + p["conv_b"]
    u = jax.nn.silu(u.astype(jnp.float32)).astype(x.dtype)
    xs, Bm, Cm = _split_conv_out(u, cfg)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])

    y, ssm_state = ssd_decode_ref(
        xs.reshape(B, H, P), dt, A, Bm.reshape(B, G, N), Cm.reshape(B, G, N),
        p["D"], ssm_state)
    y = y.reshape(B, d_in)
    y = rms_norm(p["norm_scale"],
                 y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype),
                 cfg.norm_eps)
    out = (y @ p["out_proj"])[:, None, :]
    return out, (window[:, 1:], ssm_state)
