"""Whisper-style encoder-decoder (audio frontend stubbed).

Encoder: bidirectional attention over precomputed mel-frame embeddings
(the conv feature extractor is the assignment's allowed stub) + sinusoidal
positions. Decoder: causal self-attention + cross-attention to the encoder
output + GELU MLP, learned absolute positions. LayerNorm throughout,
pre-norm residuals, tied embeddings.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.attention import (
    attention_decode, attention_full, attention_init,
    cross_attention_full, encode_memory_kv)
from repro.models.common import (
    embed_init, layer_norm, layer_norm_init, sinusoidal_positions)
from repro.models.mlp import gelu_mlp, gelu_mlp_init
from repro.sharding import shard_hint
from repro.utils import key_iter


def _enc_layer_init(key, cfg, dtype):
    ks = key_iter(key)
    return {
        "norm1": layer_norm_init(cfg.d_model),
        "attn": attention_init(next(ks), cfg, dtype),
        "norm2": layer_norm_init(cfg.d_model),
        "mlp": gelu_mlp_init(next(ks), cfg.d_model, cfg.d_ff, dtype),
    }


def _dec_layer_init(key, cfg, dtype):
    ks = key_iter(key)
    return {
        "norm1": layer_norm_init(cfg.d_model),
        "self_attn": attention_init(next(ks), cfg, dtype),
        "norm2": layer_norm_init(cfg.d_model),
        "cross_attn": attention_init(next(ks), cfg, dtype),
        "norm3": layer_norm_init(cfg.d_model),
        "mlp": gelu_mlp_init(next(ks), cfg.d_model, cfg.d_ff, dtype),
    }


def init_encdec(cfg, key, dtype, max_target_positions: int = 0) -> Dict:
    """``max_target_positions`` extends the learned position table beyond
    whisper's native 448 when an assigned shape demands it (see DESIGN.md)."""
    ks = key_iter(key)
    n_pos = max(cfg.decoder_max_position, max_target_positions)
    enc_keys = jax.random.split(next(ks), cfg.encoder_layers)
    dec_keys = jax.random.split(next(ks), cfg.num_layers)
    return {
        "embed": embed_init(next(ks), (cfg.vocab_size, cfg.d_model), dtype),
        "dec_pos": embed_init(next(ks), (n_pos, cfg.d_model), dtype),
        "encoder": jax.vmap(lambda k: _enc_layer_init(k, cfg, dtype))(enc_keys),
        "enc_final_norm": layer_norm_init(cfg.d_model),
        "decoder": jax.vmap(lambda k: _dec_layer_init(k, cfg, dtype))(dec_keys),
        "dec_final_norm": layer_norm_init(cfg.d_model),
    }


def encode(p, cfg, frames, *, attn_impl: str = "auto",
           unroll: bool = False) -> jnp.ndarray:
    """frames [B, T_enc, D] (stub embeddings) -> encoder states [B, T_enc, D]."""
    B, T, D = frames.shape
    pos = sinusoidal_positions(T, D).astype(frames.dtype)
    x = shard_hint(frames + pos[None], ("batch", "seq", "embed"))
    positions = jnp.broadcast_to(jnp.arange(T), (B, T))

    def body(x, lp):
        h = layer_norm(lp["norm1"], x, cfg.norm_eps)
        x = x + attention_full(lp["attn"], cfg, h, positions, causal=False,
                               use_rope=False, attn_impl=attn_impl,
                               unroll=unroll)
        h = layer_norm(lp["norm2"], x, cfg.norm_eps)
        x = x + gelu_mlp(lp["mlp"], h)
        return x, None

    x, _ = jax.lax.scan(body, x, p["encoder"], unroll=True if unroll else 1)
    return layer_norm(p["enc_final_norm"], x, cfg.norm_eps)


def _dec_embed(p, tokens, start: jnp.ndarray):
    B, S = tokens.shape
    pos_ids = start[:, None] + jnp.arange(S)[None]
    return p["embed"][tokens] + p["dec_pos"][pos_ids]


def decode_full(p, cfg, tokens, enc_states, *, want_cache: bool = False,
                cache_len: int = 0, attn_impl: str = "auto",
                remat: bool = False, unroll: bool = False
                ) -> Tuple[jnp.ndarray, jnp.ndarray, Optional[Dict]]:
    """Teacher-forced decoder pass (train / prefill)."""
    B, S = tokens.shape
    x = _dec_embed(p, tokens, jnp.zeros((B,), jnp.int32))
    x = shard_hint(x, ("batch", "seq", "embed"))
    positions = jnp.broadcast_to(jnp.arange(S), (B, S))

    def body(x, lp):
        h = layer_norm(lp["norm1"], x, cfg.norm_eps)
        if want_cache:
            y, (k, v) = attention_full(lp["self_attn"], cfg, h, positions,
                                       causal=True, use_rope=False,
                                       return_kv=True, attn_impl=attn_impl,
                                       unroll=unroll)
        else:
            y = attention_full(lp["self_attn"], cfg, h, positions,
                               causal=True, use_rope=False,
                               attn_impl=attn_impl, unroll=unroll)
        x = x + y
        h = layer_norm(lp["norm2"], x, cfg.norm_eps)
        mem_kv = encode_memory_kv(lp["cross_attn"], cfg, enc_states)
        x = x + cross_attention_full(lp["cross_attn"], cfg, h, mem_kv,
                                     attn_impl=attn_impl, unroll=unroll)
        h = layer_norm(lp["norm3"], x, cfg.norm_eps)
        x = x + gelu_mlp(lp["mlp"], h)
        cache = ({"k": k, "v": v, "xk": mem_kv[0], "xv": mem_kv[1]}
                 if want_cache else {})
        return x, cache

    if remat:
        body = jax.checkpoint(body, prevent_cse=False)
    x, caches = jax.lax.scan(body, x, p["decoder"],
                             unroll=True if unroll else 1)
    x = layer_norm(p["dec_final_norm"], x, cfg.norm_eps)
    logits = shard_hint(x @ p["embed"].T, ("batch", "seq", "vocab"))

    cache = None
    if want_cache:
        cap = max(cache_len, S)
        pad = ((0, 0), (0, 0), (0, cap - S), (0, 0), (0, 0))
        cache = {"self": {"k": jnp.pad(caches["k"], pad),
                          "v": jnp.pad(caches["v"], pad)},
                 "cross": {"k": caches["xk"], "v": caches["xv"]},
                 "length": jnp.full((B,), S, jnp.int32)}
    return logits, jnp.zeros((), jnp.float32), cache


def decode_step(p, cfg, cache, tokens, *, attn_impl: str = "auto",
                unroll: bool = False,
                cache_update: str = "dus") -> Tuple[jnp.ndarray, Dict]:
    """Single-token decode with self-attn KV cache + cross-attn to memory."""
    B = tokens.shape[0]
    positions = cache["length"]
    x = _dec_embed(p, tokens, positions)

    def body(carry, xs):
        x = carry
        lp, self_cache, cross_cache = xs
        h = layer_norm(lp["norm1"], x, cfg.norm_eps)
        y, (k, v) = attention_decode(lp["self_attn"], cfg, h, positions,
                                     self_cache["k"], self_cache["v"],
                                     positions + 1, use_rope=False,
                                     attn_impl=attn_impl, unroll=unroll,
                                     cache_update=cache_update)
        x = x + y
        h = layer_norm(lp["norm2"], x, cfg.norm_eps)
        x = x + cross_attention_full(
            lp["cross_attn"], cfg, h, (cross_cache["k"], cross_cache["v"]),
            attn_impl=attn_impl, unroll=unroll)
        h = layer_norm(lp["norm3"], x, cfg.norm_eps)
        x = x + gelu_mlp(lp["mlp"], h)
        return x, {"k": k, "v": v}

    x, new_caches = jax.lax.scan(
        body, x, (p["decoder"], cache["self"], cache["cross"]),
        unroll=True if unroll else 1)
    x = layer_norm(p["dec_final_norm"], x, cfg.norm_eps)
    logits = x @ p["embed"].T
    new_cache = {"self": new_caches, "cross": cache["cross"],
                 "length": cache["length"] + 1}
    return logits, new_cache


def make_empty_cache(cfg, batch: int, capacity: int, dtype,
                     enc_states: jnp.ndarray,
                     length: Optional[int] = None) -> Dict:
    L = cfg.num_layers
    shape = (L, batch, capacity, cfg.num_kv_heads, cfg.head_dim)
    ln = length if length is not None else 0
    return {"self": {"k": jnp.zeros(shape, dtype),
                     "v": jnp.zeros(shape, dtype)},
            "cross": {"k": jnp.zeros((L, batch, cfg.encoder_seq,
                                      cfg.num_kv_heads, cfg.head_dim), dtype),
                      "v": jnp.zeros((L, batch, cfg.encoder_seq,
                                      cfg.num_kv_heads, cfg.head_dim), dtype)},
            "length": jnp.full((batch,), ln, jnp.int32)}
