"""Unified decoder-only stack for dense / moe / ssm / hybrid / vlm families.

Layer stacks are homogeneous for dense/moe/ssm/vlm and are stacked along a
leading axis + driven by ``lax.scan`` (compact HLO for 80-layer configs).
The hybrid (Jamba) family scans over *periods* of ``attn_every`` layers —
each period is an unrolled mini-stack (7 mamba + 1 attention, alternating
dense/MoE FFN) whose slot params are stacked across periods.

Caches are pytrees with a leading layer (or period) axis so the same scans
drive prefill and decode.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.attention import (
    attention_decode, attention_full, attention_init)
from repro.models.common import embed_init, rms_norm, rms_norm_init
from repro.models.mlp import swiglu, swiglu_init
from repro.models.moe import moe_apply, moe_init
from repro.models.ssm import ssm_decode, ssm_full, ssm_init, _dims
from repro.sharding import shard_hint
from repro.utils import key_iter


# ----------------------------------------------------------------- layer slot
def slot_init(key, cfg, layer_idx: int, dtype) -> Dict[str, Any]:
    ks = key_iter(key)
    p: Dict[str, Any] = {"norm1": rms_norm_init(cfg.d_model)}
    if cfg.uses_attention(layer_idx):
        p["attn"] = attention_init(next(ks), cfg, dtype)
    else:
        p["mamba"] = ssm_init(next(ks), cfg, dtype)
    if cfg.family != "ssm":
        p["norm2"] = rms_norm_init(cfg.d_model)
        if cfg.uses_moe(layer_idx):
            p["moe"] = moe_init(next(ks), cfg, dtype)
        else:
            p["ffn"] = swiglu_init(next(ks), cfg.d_model, cfg.d_ff, dtype)
    return p


def slot_apply_full(p, cfg, x, positions, *, sliding_window, attn_impl,
                    ssm_impl, want_cache: bool, moe_dropless: bool = False,
                    unroll: bool = False, moe_group_size: int = 0):
    """Full-sequence layer. Returns (x, cache_slice, aux)."""
    h = rms_norm(p["norm1"], x, cfg.norm_eps)
    cache = {}
    if "attn" in p:
        if want_cache:
            y, (k, v) = attention_full(
                p["attn"], cfg, h, positions, causal=True,
                sliding_window=sliding_window, return_kv=True,
                attn_impl=attn_impl, unroll=unroll)
            cache = {"k": k, "v": v}
        else:
            y = attention_full(p["attn"], cfg, h, positions, causal=True,
                               sliding_window=sliding_window,
                               attn_impl=attn_impl, unroll=unroll)
    else:
        if want_cache:
            y, (conv_s, ssm_s) = ssm_full(p["mamba"], cfg, h,
                                          return_state=True, impl=ssm_impl,
                                          unroll=unroll)
            cache = {"conv": conv_s, "ssm": ssm_s}
        else:
            y = ssm_full(p["mamba"], cfg, h, impl=ssm_impl, unroll=unroll)
    x = x + y
    aux = jnp.zeros((), jnp.float32)
    if "norm2" in p:
        h = rms_norm(p["norm2"], x, cfg.norm_eps)
        if "moe" in p:
            y, aux = moe_apply(p["moe"], cfg, h, dropless=moe_dropless,
                               group_size=moe_group_size)
        else:
            y = swiglu(p["ffn"], h)
        x = x + y
    return x, cache, aux


def slot_apply_decode(p, cfg, x, positions, cache, *, sliding_window,
                      attn_impl, unroll: bool = False,
                      cache_update: str = "dus"):
    """Single-token layer step. Returns (x, new_cache_slice, aux)."""
    h = rms_norm(p["norm1"], x, cfg.norm_eps)
    if "attn" in p:
        y, (k, v) = attention_decode(
            p["attn"], cfg, h, positions, cache["k"], cache["v"],
            positions + 1, sliding_window=sliding_window,
            attn_impl=attn_impl, unroll=unroll, cache_update=cache_update)
        new_cache = {"k": k, "v": v}
    else:
        y, (conv_s, ssm_s) = ssm_decode(p["mamba"], cfg, h,
                                        cache["conv"], cache["ssm"])
        new_cache = {"conv": conv_s, "ssm": ssm_s}
    x = x + y
    aux = jnp.zeros((), jnp.float32)
    if "norm2" in p:
        h = rms_norm(p["norm2"], x, cfg.norm_eps)
        if "moe" in p:
            y, aux = moe_apply(p["moe"], cfg, h, dropless=True)
        else:
            y = swiglu(p["ffn"], h)
        x = x + y
    return x, new_cache, aux


# ------------------------------------------------------------------- periods
def _period(cfg) -> int:
    """Scan unit: 1 layer for homogeneous stacks, attn_every for hybrid."""
    if cfg.family == "hybrid":
        p = cfg.attn_every
        if cfg.has_moe:
            p = max(p, cfg.moe_every) if p % cfg.moe_every == 0 else \
                p * cfg.moe_every
        return p
    return 1


def init_decoder(cfg, key, dtype) -> Dict[str, Any]:
    ks = key_iter(key)
    period = _period(cfg)
    n_periods = cfg.num_layers // period
    assert cfg.num_layers % period == 0, (cfg.num_layers, period)

    # stacked slot params: dict slot_<s> -> stacked-over-periods params
    slots = {}
    for s in range(period):
        keys = jax.random.split(next(ks), n_periods)
        slots[f"slot_{s}"] = jax.vmap(
            lambda k, s=s: slot_init(k, cfg, s, dtype))(keys)

    p = {
        "embed": embed_init(next(ks), (cfg.vocab_size, cfg.d_model), dtype),
        "layers": slots,
        "final_norm": rms_norm_init(cfg.d_model),
    }
    if not cfg.tie_embeddings:
        p["lm_head"] = embed_init(next(ks), (cfg.d_model, cfg.vocab_size),
                                  dtype)
    if cfg.family == "vlm":
        p["patch_proj"] = embed_init(next(ks), (cfg.d_model, cfg.d_model),
                                     dtype)
    return p


def _logits(p, cfg, x):
    x = rms_norm(p["final_norm"], x, cfg.norm_eps)
    if cfg.tie_embeddings:
        logits = x @ p["embed"].T
    else:
        logits = x @ p["lm_head"]
    return shard_hint(logits, ("batch", "seq", "vocab"))


def _embed_inputs(p, cfg, tokens, prefix_embeds):
    x = p["embed"][tokens]
    if prefix_embeds is not None:
        pe = prefix_embeds.astype(x.dtype)
        if cfg.family == "vlm":
            pe = pe @ p["patch_proj"]
        x = jnp.concatenate([pe, x], axis=1)
    return shard_hint(x, ("batch", "seq", "embed"))


def decoder_forward(p, cfg, tokens, *, prefix_embeds=None,
                    want_cache: bool = False, cache_len: int = 0,
                    sliding_window: Optional[int] = None,
                    attn_impl: str = "auto", ssm_impl: str = "auto",
                    remat: bool = False, moe_dropless: bool = False,
                    unroll: bool = False, moe_group_size: int = 0,
                    return_hidden: bool = False
                    ) -> Tuple[jnp.ndarray, jnp.ndarray, Optional[Dict]]:
    """Full-sequence forward (train / prefill).

    Returns (logits [B,S,V], moe_aux scalar, cache|None). ``cache_len``
    pads KV caches up to a serving capacity >= S when want_cache.
    """
    x = _embed_inputs(p, cfg, tokens, prefix_embeds)
    B, S, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(S), (B, S))
    period = _period(cfg)
    n_periods = cfg.num_layers // period

    def body(carry, xs):
        x, aux_acc = carry
        slot_params = xs
        caches = {}
        for s in range(period):
            x, c, aux = slot_apply_full(
                jax.tree_util.tree_map(lambda a: a, slot_params[f"slot_{s}"]),
                cfg, x, positions, sliding_window=sliding_window,
                attn_impl=attn_impl, ssm_impl=ssm_impl,
                want_cache=want_cache, moe_dropless=moe_dropless,
                unroll=unroll, moe_group_size=moe_group_size)
            caches[f"slot_{s}"] = c
            aux_acc = aux_acc + aux
        return (x, aux_acc), caches

    if remat:
        body = jax.checkpoint(body, prevent_cse=False)

    (x, aux), caches = jax.lax.scan(
        body, (x, jnp.zeros((), jnp.float32)), p["layers"],
        unroll=True if unroll else 1)

    if return_hidden:
        x = rms_norm(p["final_norm"], x, cfg.norm_eps)
        return x, aux, None
    logits = _logits(p, cfg, x)

    cache = None
    if want_cache:
        cap = max(cache_len, S)
        def _pad_kv(a):  # [n_periods, B, S, Hkv, dh] -> capacity cap
            return jnp.pad(a, ((0, 0), (0, 0), (0, cap - S), (0, 0), (0, 0)))
        for s in list(caches):
            if caches[s] and "k" in caches[s]:
                caches[s] = {"k": _pad_kv(caches[s]["k"]),
                             "v": _pad_kv(caches[s]["v"])}
        cache = {"layers": caches,
                 "length": jnp.full((B,), S, jnp.int32)}
    return logits, aux, cache


def decoder_decode_step(p, cfg, cache, tokens, *,
                        sliding_window: Optional[int] = None,
                        attn_impl: str = "auto", unroll: bool = False,
                        cache_update: str = "dus"
                        ) -> Tuple[jnp.ndarray, Dict]:
    """One-token decode. tokens [B,1]; cache from ``decoder_forward`` or
    ``make_empty_cache``. Returns (logits [B,1,V], new_cache)."""
    B = tokens.shape[0]
    positions = cache["length"]                      # [B], next position
    x = p["embed"][tokens]
    x = shard_hint(x, ("batch", "seq", "embed"))
    period = _period(cfg)

    def body(carry, xs):
        x, aux_acc = carry
        slot_params, layer_cache = xs
        new_caches = {}
        for s in range(period):
            x, c, aux = slot_apply_decode(
                slot_params[f"slot_{s}"], cfg, x, positions,
                layer_cache[f"slot_{s}"], sliding_window=sliding_window,
                attn_impl=attn_impl, unroll=unroll,
                cache_update=cache_update)
            new_caches[f"slot_{s}"] = c
            aux_acc = aux_acc + aux
        return (x, aux_acc), new_caches

    (x, _), new_layer_caches = jax.lax.scan(
        body, (x, jnp.zeros((), jnp.float32)),
        (p["layers"], cache["layers"]), unroll=True if unroll else 1)

    logits = _logits(p, cfg, x)
    new_cache = {"layers": new_layer_caches, "length": cache["length"] + 1}
    return logits, new_cache


def make_empty_cache(cfg, batch: int, capacity: int, dtype,
                     length: Optional[int] = None) -> Dict:
    """Empty (or length-prefilled-shape) cache pytree for serving."""
    period = _period(cfg)
    n_periods = cfg.num_layers // period
    d_in, H, P, G, N, conv_dim = (_dims(cfg) if (cfg.family in ("ssm", "hybrid")
                                                 and cfg.ssm_state)
                                  else (0,) * 6)
    layers = {}
    for s in range(period):
        if cfg.uses_attention(s):
            layers[f"slot_{s}"] = {
                "k": jnp.zeros((n_periods, batch, capacity,
                                cfg.num_kv_heads, cfg.head_dim), dtype),
                "v": jnp.zeros((n_periods, batch, capacity,
                                cfg.num_kv_heads, cfg.head_dim), dtype),
            }
        else:
            layers[f"slot_{s}"] = {
                "conv": jnp.zeros((n_periods, batch, cfg.ssm_conv_width - 1,
                                   conv_dim), dtype),
                "ssm": jnp.zeros((n_periods, batch, H, P, N), jnp.float32),
            }
    ln = length if length is not None else 0
    return {"layers": layers,
            "length": jnp.full((batch,), ln, jnp.int32)}
