"""Shared building blocks: norms, rotary embeddings, initializers."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp


def dense_init(key, shape, in_axis: int = 0, dtype=jnp.float32):
    """Truncated-normal fan-in init (MaxText-style)."""
    fan_in = shape[in_axis]
    std = fan_in ** -0.5
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32)
            * std).astype(dtype)


def embed_init(key, shape, dtype=jnp.float32):
    return (jax.random.normal(key, shape, jnp.float32) * 0.02).astype(dtype)


# --------------------------------------------------------------------- norms
def rms_norm_init(dim: int):
    return {"scale": jnp.ones((dim,), jnp.float32)}


def rms_norm(p, x, eps: float = 1e-6):
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps) * p["scale"]
    return out.astype(x.dtype)


def layer_norm_init(dim: int):
    return {"scale": jnp.ones((dim,), jnp.float32),
            "bias": jnp.zeros((dim,), jnp.float32)}


def layer_norm(p, x, eps: float = 1e-5):
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    out = (xf - mu) * jax.lax.rsqrt(var + eps) * p["scale"] + p["bias"]
    return out.astype(x.dtype)


# --------------------------------------------------------------------- rope
def rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """Rotary embedding. x [..., S, H, D]; positions [..., S] (broadcast to B)."""
    D = x.shape[-1]
    half = D // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    angles = positions[..., None].astype(jnp.float32) * freqs  # [..., S, half]
    cos = jnp.cos(angles)[..., None, :]                        # [..., S, 1, half]
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    xf1, xf2 = x1.astype(jnp.float32), x2.astype(jnp.float32)
    out = jnp.concatenate([xf1 * cos - xf2 * sin,
                           xf2 * cos + xf1 * sin], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_positions(seq: int, dim: int) -> jnp.ndarray:
    """Whisper-style sinusoidal position table [seq, dim]."""
    half = dim // 2
    log_timescale = jnp.log(10_000.0) / max(half - 1, 1)
    inv = jnp.exp(-log_timescale * jnp.arange(half, dtype=jnp.float32))
    scaled = jnp.arange(seq, dtype=jnp.float32)[:, None] * inv[None, :]
    return jnp.concatenate([jnp.sin(scaled), jnp.cos(scaled)], axis=1)


def pick_norm(cfg):
    """Qwen/Mamba families use RMSNorm; Whisper uses LayerNorm."""
    if cfg.family == "encdec":
        return layer_norm_init, layer_norm
    return rms_norm_init, rms_norm


def softmax_cross_entropy(logits: jnp.ndarray, labels: jnp.ndarray,
                          ignore_index: int = -1):
    """Mean NLL over non-ignored labels. logits [..., V]; labels [...]."""
    valid = labels != ignore_index
    safe = jnp.where(valid, labels, 0)
    logz = jax.nn.logsumexp(logits.astype(jnp.float32), axis=-1)
    gold = jnp.take_along_axis(logits.astype(jnp.float32),
                               safe[..., None], axis=-1)[..., 0]
    nll = (logz - gold) * valid
    denom = jnp.maximum(valid.sum(), 1)
    return nll.sum() / denom


def token_accuracy(logits: jnp.ndarray, labels: jnp.ndarray,
                   ignore_index: int = -1):
    valid = labels != ignore_index
    pred = jnp.argmax(logits, axis=-1)
    correct = (pred == labels) & valid
    return correct.sum() / jnp.maximum(valid.sum(), 1)
