"""Family-independent model facade.

The FL round engine, launchers and dry-runs consume this API only:

    m = build_model(cfg)
    params = m.init(key)
    loss, metrics = m.loss(params, batch)
    logits, cache = m.prefill(params, batch, cache_len=...)
    logits, cache = m.decode_step(params, cache, tokens)

Batch conventions:
* LM families (dense/moe/ssm/hybrid): {"tokens": [B,S] i32, "labels": [B,S]}
* vlm:    + {"patches": [B,P,D]}; logits cover patches+text, labels must be
  -1 (ignored) on the patch prefix.
* encdec: {"frames": [B,T_enc,D], "tokens": [B,S], "labels": [B,S]}
* cnn/mlp: {"images": [B,H,W,C], "labels": [B]}
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.models import cnn as cnn_mod
from repro.models import decoder as dec_mod
from repro.models import encdec as encdec_mod
from repro.models import mlp as mlp_mod
from repro.models.common import softmax_cross_entropy, token_accuracy

_DTYPES = {"bfloat16": jnp.bfloat16, "float32": jnp.float32,
           "float16": jnp.float16}


@dataclasses.dataclass(frozen=True)
class Model:
    cfg: ModelConfig
    attn_impl: str = "auto"
    ssm_impl: str = "auto"
    sliding_window: Optional[int] = None   # long-context serving variant
    max_target_positions: int = 0          # encdec learned-pos extension
    moe_dropless: bool = False             # exact per-token routing
    scan_unroll: bool = False              # unroll layer scans (cost probes)
    moe_group_size: int = 0                # 0 = kernel default (512)
    cache_update: str = "dus"              # 'dus' (scatter) | 'onehot'
    ce_chunk: int = 0                      # >0: chunked cross-entropy

    @property
    def dtype(self):
        return _DTYPES[self.cfg.dtype]

    # ------------------------------------------------------------------ init
    def init(self, key) -> Dict[str, Any]:
        cfg = self.cfg
        if cfg.family == "cnn":
            return cnn_mod.init_cnn(cfg, key, self.dtype)
        if cfg.family == "mlp":
            return mlp_mod.init_mlp(cfg, key, self.dtype)
        if cfg.family == "encdec":
            return encdec_mod.init_encdec(
                cfg, key, self.dtype,
                max_target_positions=self.max_target_positions)
        return dec_mod.init_decoder(cfg, key, self.dtype)

    # --------------------------------------------------------------- forward
    def forward_train(self, params, batch, *, remat: bool = False
                      ) -> Tuple[jnp.ndarray, jnp.ndarray]:
        """Returns (logits, moe_aux)."""
        cfg = self.cfg
        if cfg.family == "cnn":
            return cnn_mod.cnn_forward(params, cfg, batch["images"]), \
                jnp.zeros((), jnp.float32)
        if cfg.family == "mlp":
            return mlp_mod.mlp_forward(params, cfg, batch["images"]), \
                jnp.zeros((), jnp.float32)
        if cfg.family == "encdec":
            enc = encdec_mod.encode(params, cfg, batch["frames"],
                                    attn_impl=self.attn_impl,
                                    unroll=self.scan_unroll)
            logits, aux, _ = encdec_mod.decode_full(
                params, cfg, batch["tokens"], enc, attn_impl=self.attn_impl,
                remat=remat, unroll=self.scan_unroll)
            return logits, aux
        logits, aux, _ = dec_mod.decoder_forward(
            params, cfg, batch["tokens"],
            prefix_embeds=batch.get("patches"),
            sliding_window=self.sliding_window, attn_impl=self.attn_impl,
            ssm_impl=self.ssm_impl, remat=remat,
            moe_dropless=self.moe_dropless, unroll=self.scan_unroll,
            moe_group_size=self.moe_group_size)
        return logits, aux

    # ------------------------------------------------------------------ loss
    def _chunked_ce(self, params, batch, *, remat: bool):
        """Sequence-chunked cross-entropy: the [B,S,V] fp32 logits tensor
        (tens of GB/device for 150k vocabs) is never materialised — the
        head matmul + softmax run per S-chunk inside a scan (§Perf C4)."""
        import jax
        from repro.models import decoder as dec_mod
        cfg = self.cfg
        hidden, aux, _ = dec_mod.decoder_forward(
            params, cfg, batch["tokens"],
            prefix_embeds=batch.get("patches"),
            sliding_window=self.sliding_window, attn_impl=self.attn_impl,
            ssm_impl=self.ssm_impl, remat=remat,
            moe_dropless=self.moe_dropless, unroll=self.scan_unroll,
            moe_group_size=self.moe_group_size, return_hidden=True)
        labels = batch["labels"]
        B, S, D = hidden.shape
        if labels.shape[1] != S:
            pad = S - labels.shape[1]
            labels = jnp.concatenate(
                [jnp.full((B, pad), -1, labels.dtype), labels], axis=1)
        head = params["embed"].T if cfg.tie_embeddings else             params["lm_head"]
        C = self.ce_chunk
        nc = S // C if S % C == 0 else 1
        C = S // nc
        hc = hidden.reshape(B, nc, C, D).transpose(1, 0, 2, 3)
        lc = labels.reshape(B, nc, C).transpose(1, 0, 2)

        def chunk(carry, xs):
            nll_sum, n_valid, n_correct = carry
            h, y = xs
            logits = (h @ head).astype(jnp.float32)
            valid = y != -1
            safe = jnp.where(valid, y, 0)
            logz = jax.nn.logsumexp(logits, axis=-1)
            gold = jnp.take_along_axis(logits, safe[..., None],
                                       axis=-1)[..., 0]
            nll_sum += jnp.sum((logz - gold) * valid)
            n_valid += valid.sum()
            n_correct += ((jnp.argmax(logits, -1) == y) & valid).sum()
            return (nll_sum, n_valid, n_correct), None

        (nll_sum, n_valid, n_correct), _ = jax.lax.scan(
            chunk, (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.int32),
                    jnp.zeros((), jnp.int32)), (hc, lc))
        nll = nll_sum / jnp.maximum(n_valid, 1)
        acc = n_correct / jnp.maximum(n_valid, 1)
        loss = nll + cfg.router_aux_coef * aux
        return loss, {"nll": nll, "accuracy": acc, "moe_aux": aux}

    def loss(self, params, batch, *, remat: bool = False
             ) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
        cfg = self.cfg
        if self.ce_chunk and cfg.family not in ("cnn", "mlp", "encdec"):
            return self._chunked_ce(params, batch, remat=remat)
        logits, aux = self.forward_train(params, batch, remat=remat)
        labels = batch["labels"]
        if cfg.family in ("cnn", "mlp"):
            onehot_nll = softmax_cross_entropy(logits, labels)
            acc = token_accuracy(logits, labels)
            return onehot_nll, {"nll": onehot_nll, "accuracy": acc}
        if cfg.family == "vlm" and labels.shape[1] != logits.shape[1]:
            pad = logits.shape[1] - labels.shape[1]
            labels = jnp.concatenate(
                [jnp.full((labels.shape[0], pad), -1, labels.dtype), labels],
                axis=1)
        nll = softmax_cross_entropy(logits, labels)
        acc = token_accuracy(logits, labels)
        loss = nll + cfg.router_aux_coef * aux
        return loss, {"nll": nll, "accuracy": acc, "moe_aux": aux}

    # --------------------------------------------------------------- serving
    def prefill(self, params, batch, *, cache_len: int = 0
                ) -> Tuple[jnp.ndarray, Dict]:
        cfg = self.cfg
        if cfg.family in ("cnn", "mlp"):
            raise ValueError(f"{cfg.family} has no serving path")
        if cfg.family == "encdec":
            enc = encdec_mod.encode(params, cfg, batch["frames"],
                                    attn_impl=self.attn_impl,
                                    unroll=self.scan_unroll)
            logits, _, cache = encdec_mod.decode_full(
                params, cfg, batch["tokens"], enc, want_cache=True,
                cache_len=cache_len, attn_impl=self.attn_impl,
                unroll=self.scan_unroll)
            return logits, cache
        logits, _, cache = dec_mod.decoder_forward(
            params, cfg, batch["tokens"],
            prefix_embeds=batch.get("patches"), want_cache=True,
            cache_len=cache_len, sliding_window=self.sliding_window,
            attn_impl=self.attn_impl, ssm_impl=self.ssm_impl,
            moe_dropless=self.moe_dropless, unroll=self.scan_unroll,
            moe_group_size=self.moe_group_size)
        return logits, cache

    def decode_step(self, params, cache, tokens
                    ) -> Tuple[jnp.ndarray, Dict]:
        cfg = self.cfg
        if cfg.family == "encdec":
            return encdec_mod.decode_step(params, cfg, cache, tokens,
                                          attn_impl=self.attn_impl,
                                          unroll=self.scan_unroll,
                                          cache_update=self.cache_update)
        return dec_mod.decoder_decode_step(
            params, cfg, cache, tokens, sliding_window=self.sliding_window,
            attn_impl=self.attn_impl, unroll=self.scan_unroll,
            cache_update=self.cache_update)

    def make_cache(self, params, batch_size: int, capacity: int, *,
                   length: Optional[int] = None,
                   enc_states: Optional[jnp.ndarray] = None) -> Dict:
        cfg = self.cfg
        if cfg.family == "encdec":
            assert enc_states is not None
            cache = encdec_mod.make_empty_cache(
                cfg, batch_size, capacity, self.dtype, enc_states,
                length=length)
            # fill cross-attn K/V from the encoder states
            def per_layer(lp):
                from repro.models.attention import encode_memory_kv
                return encode_memory_kv(lp["cross_attn"], cfg, enc_states)
            xk, xv = jax.lax.map(per_layer, params["decoder"])
            cache["cross"] = {"k": xk, "v": xv}
            return cache
        return dec_mod.make_empty_cache(cfg, batch_size, capacity,
                                        self.dtype, length=length)

    def param_count(self, params=None) -> int:
        if params is None:
            return self.cfg.param_count()
        return sum(x.size for x in jax.tree_util.tree_leaves(params))


def build_model(cfg: ModelConfig, **kw) -> Model:
    return Model(cfg=cfg, **kw)
