"""GQA attention block (full-sequence and single-token decode paths)."""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels.decode_attention import decode_attention, merge_partials
from repro.kernels.flash_attention import flash_attention
from repro.models.common import dense_init, rms_norm, rope
from repro.sharding import shard_hint
from repro.utils import key_iter


def attention_init(key, cfg, dtype):
    D, Hq, Hkv, dh = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    ks = key_iter(key)
    p = {
        "wq": dense_init(next(ks), (D, Hq * dh), dtype=dtype),
        "wk": dense_init(next(ks), (D, Hkv * dh), dtype=dtype),
        "wv": dense_init(next(ks), (D, Hkv * dh), dtype=dtype),
        "wo": dense_init(next(ks), (Hq * dh, D), dtype=dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((Hq * dh,), dtype)
        p["bk"] = jnp.zeros((Hkv * dh,), dtype)
        p["bv"] = jnp.zeros((Hkv * dh,), dtype)
    if cfg.qk_norm:
        p["q_norm"] = {"scale": jnp.ones((dh,), jnp.float32)}
        p["k_norm"] = {"scale": jnp.ones((dh,), jnp.float32)}
    return p


def _project_qkv(p, cfg, x, positions, use_rope: bool):
    B, S, D = x.shape
    Hq, Hkv, dh = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    q = x @ p["wq"]
    k = x @ p["wk"]
    v = x @ p["wv"]
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(B, S, Hq, dh)
    k = k.reshape(B, S, Hkv, dh)
    v = v.reshape(B, S, Hkv, dh)
    if cfg.qk_norm:
        q = rms_norm(p["q_norm"], q, cfg.norm_eps)
        k = rms_norm(p["k_norm"], k, cfg.norm_eps)
    if use_rope:
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)
    q = shard_hint(q, ("batch", "seq", "heads", None))
    k = shard_hint(k, ("batch", "seq", "kv_heads", None))
    v = shard_hint(v, ("batch", "seq", "kv_heads", None))
    return q, k, v


def attention_full(p, cfg, x, positions, *, causal=True,
                   sliding_window: Optional[int] = None,
                   use_rope: bool = True, return_kv: bool = False,
                   attn_impl: str = "auto", unroll: bool = False):
    """Full-sequence path (training / prefill). x [B,S,D] -> y [B,S,D]."""
    B, S, D = x.shape
    q, k, v = _project_qkv(p, cfg, x, positions, use_rope)
    o = flash_attention(q, k, v, causal=causal,
                        sliding_window=sliding_window, impl=attn_impl,
                        unroll=unroll)
    o = shard_hint(o, ("batch", "seq", "heads", None))
    y = o.reshape(B, S, -1) @ p["wo"]
    y = shard_hint(y, ("batch", "seq", "embed"))
    if return_kv:
        return y, (k, v)
    return y


def cross_attention_full(p, cfg, x, memory_kv, *, attn_impl: str = "auto",
                         unroll: bool = False):
    """Decoder cross-attention against precomputed encoder K/V."""
    B, S, D = x.shape
    Hq, dh = cfg.num_heads, cfg.head_dim
    q = x @ p["wq"]
    if cfg.qkv_bias:
        q = q + p["bq"]
    q = q.reshape(B, S, Hq, dh)
    k, v = memory_kv
    o = flash_attention(q, k, v, causal=False, impl=attn_impl,
                        unroll=unroll)
    y = o.reshape(B, S, -1) @ p["wo"]
    return shard_hint(y, ("batch", "seq", "embed"))


def encode_memory_kv(p, cfg, memory):
    """Project encoder output once into cross-attention K/V."""
    B, T, D = memory.shape
    Hkv, dh = cfg.num_kv_heads, cfg.head_dim
    k = memory @ p["wk"]
    v = memory @ p["wv"]
    if cfg.qkv_bias:
        k, v = k + p["bk"], v + p["bv"]
    return (k.reshape(B, T, Hkv, dh), v.reshape(B, T, Hkv, dh))


def _cache_write_onehot(cache, new, positions):
    """Masked-multiply cache write (baseline): touches the WHOLE cache
    (3x full-cache traffic) and, under a sequence-sharded cache, makes
    GSPMD replicate it — see EXPERIMENTS.md §Perf iteration A3."""
    oh = jnp.arange(cache.shape[1])[None, :] == positions[:, None]  # [B,T]
    ohc = oh[..., None, None].astype(cache.dtype)
    return cache * (1 - ohc) + new * ohc


def _cache_write_dus(cache, new, positions):
    """Scatter cache write: a vmapped dynamic-update-slice lowers to a
    scatter that only touches one row per sequence and keeps the cache's
    sharding intact."""
    def upd(c, n, pos):
        return jax.lax.dynamic_update_slice(c, n.astype(c.dtype), (pos, 0, 0))
    return jax.vmap(upd)(cache, new, positions)


def attention_decode(p, cfg, x, positions, kcache, vcache, lengths, *,
                     sliding_window: Optional[int] = None,
                     use_rope: bool = True,
                     attn_impl: str = "auto",
                     unroll: bool = False,
                     cache_update: str = "dus") -> Tuple[jnp.ndarray, tuple]:
    """Single-token decode. x [B,1,D]; caches [B,T,Hkv,dh]; positions [B].

    Writes the new K/V at ``positions`` then attends the first
    ``lengths = positions + 1`` entries via the flash-decoding op.
    """
    B = x.shape[0]
    q, k, v = _project_qkv(p, cfg, x, positions[:, None], use_rope)
    write = _cache_write_dus if cache_update == "dus" else \
        _cache_write_onehot
    kcache = write(kcache, k, positions)
    vcache = write(vcache, v, positions)
    kcache = shard_hint(kcache, ("batch", "kv_seq", "kv_heads", None))
    vcache = shard_hint(vcache, ("batch", "kv_seq", "kv_heads", None))
    out, _lse = decode_attention(q[:, 0], kcache, vcache, positions + 1,
                                 window=sliding_window, impl=attn_impl,
                                 unroll=unroll)
    y = out.reshape(B, 1, -1) @ p["wo"]
    return shard_hint(y, ("batch", "seq", "embed")), (kcache, vcache)
