"""The paper's own model (Sec. III): three conv layers + two fully-connected
layers + softmax, "ideally suited for an image classification problem".

Used for the faithful Fig. 4 / Fig. 5 reproductions (CIFAR-10-like and
MNIST-like synthetic data).
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from repro.models.common import dense_init
from repro.utils import key_iter


def init_cnn(cfg, key, dtype=jnp.float32) -> Dict:
    ks = key_iter(key)
    chans = (cfg.image_channels,) + tuple(cfg.cnn_channels)
    p: Dict = {}
    for i in range(len(cfg.cnn_channels)):
        fan_in = 3 * 3 * chans[i]
        p[f"conv{i}"] = {
            "w": (jax.random.truncated_normal(
                next(ks), -2, 2, (3, 3, chans[i], chans[i + 1]), jnp.float32)
                * fan_in ** -0.5).astype(dtype),
            "b": jnp.zeros((chans[i + 1],), dtype),
        }
    # spatial size after len(channels) stride-2 maxpools
    s = cfg.image_size
    for _ in cfg.cnn_channels:
        s = (s + 1) // 2
    flat = s * s * cfg.cnn_channels[-1]
    p["fc1"] = {"w": dense_init(next(ks), (flat, cfg.cnn_hidden), dtype=dtype),
                "b": jnp.zeros((cfg.cnn_hidden,), dtype)}
    p["fc2"] = {"w": dense_init(next(ks), (cfg.cnn_hidden, cfg.num_classes),
                                dtype=dtype),
                "b": jnp.zeros((cfg.num_classes,), dtype)}
    return p


def _maxpool2(x):
    B, H, W, C = x.shape
    ph, pw = (-H) % 2, (-W) % 2
    if ph or pw:
        x = jnp.pad(x, ((0, 0), (0, ph), (0, pw), (0, 0)),
                    constant_values=-jnp.inf)
    H2, W2 = x.shape[1] // 2, x.shape[2] // 2
    x = x.reshape(B, H2, 2, W2, 2, C)
    return x.max(axis=(2, 4))


def cnn_forward(p, cfg, images: jnp.ndarray) -> jnp.ndarray:
    """images [B, H, W, C] -> logits [B, num_classes]."""
    x = images
    for i in range(len(cfg.cnn_channels)):
        x = jax.lax.conv_general_dilated(
            x, p[f"conv{i}"]["w"], window_strides=(1, 1), padding="SAME",
            dimension_numbers=("NHWC", "HWIO", "NHWC"))
        x = jax.nn.relu(x + p[f"conv{i}"]["b"])
        x = _maxpool2(x)
    x = x.reshape(x.shape[0], -1)
    x = jax.nn.relu(x @ p["fc1"]["w"] + p["fc1"]["b"])
    return x @ p["fc2"]["w"] + p["fc2"]["b"]
