"""granite-moe-1b-a400m [moe] — 32 experts top-8. [hf:ibm-granite/granite-3.0-1b-a400m-base]

Assigned: 24L d_model=1024 16H (GQA kv=8) d_ff=512 (per expert)
vocab=49155, MoE 32e top-8.
"""
from repro.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="granite-moe-1b-a400m",
        family="moe",
        num_layers=24,
        d_model=1024,
        num_heads=16,
        num_kv_heads=8,
        head_dim=64,
        d_ff=512,                   # per-expert FFN width
        vocab_size=49155,
        num_experts=32,
        num_experts_per_tok=8,
        moe_every=1,
        rope_theta=10_000.0,
        max_position=4_096,
        tie_embeddings=True,
        source="hf:ibm-granite/granite-3.0-1b-a400m-base model card",
    )
