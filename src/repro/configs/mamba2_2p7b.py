"""mamba2-2.7b [ssm] — SSD (state-space duality), attention-free. [arXiv:2405.21060]

Assigned: 64L d_model=2560 (attn-free) d_ff=0 vocab=50280, ssm_state=128.
"""
from repro.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="mamba2-2.7b",
        family="ssm",
        num_layers=64,
        d_model=2560,
        d_ff=0,
        vocab_size=50280,
        ssm_state=128,
        ssm_head_dim=64,
        ssm_expand=2,
        ssm_conv_width=4,
        ssm_chunk=256,
        ssm_ngroups=1,
        norm_eps=1e-5,
        tie_embeddings=True,
        source="arXiv:2405.21060 (Mamba2), 2.7B size",
    )
