"""The paper's MNIST fully-connected classifier (Fig. 4 experiments)."""
from repro.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="fedtest-mlp-mnist",
        family="mlp",
        num_layers=2,
        d_model=0,
        image_size=28,
        image_channels=1,
        mlp_hidden=(200, 200),
        num_classes=10,
        dtype="float32",
        source="FedTest paper Sec. IV (MNIST experiments)",
    )
