"""qwen1.5-110b [dense] — QKV bias. [hf:Qwen/Qwen1.5 family card]

Assigned: 80L d_model=8192 64H (GQA kv=8) d_ff=49152 vocab=152064.
"""
from repro.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen1.5-110b",
        family="dense",
        num_layers=80,
        d_model=8192,
        num_heads=64,
        num_kv_heads=8,
        head_dim=128,
        d_ff=49152,
        vocab_size=152064,
        qkv_bias=True,
        rope_theta=1_000_000.0,
        max_position=32_768,
        source="hf:Qwen/Qwen1.5-110B model card",
    )
