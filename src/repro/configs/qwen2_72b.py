"""qwen2-72b [dense] — GQA, QKV bias. [arXiv:2407.10671]

Assigned: 80L d_model=8192 64H (GQA kv=8) d_ff=29568 vocab=152064.
"""
from repro.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen2-72b",
        family="dense",
        num_layers=80,
        d_model=8192,
        num_heads=64,
        num_kv_heads=8,
        head_dim=128,
        d_ff=29568,
        vocab_size=152064,
        qkv_bias=True,
        rope_theta=1_000_000.0,
        max_position=131_072,
        source="arXiv:2407.10671 (Qwen2), 72B size",
    )
