"""The paper's own model (Sec. III): 3 conv layers + 2 FC + softmax,
for CIFAR-10-shaped inputs. This is the faithful-reproduction model used in
the Fig. 4 convergence/robustness experiments.
"""
from repro.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="fedtest-cnn",
        family="cnn",
        num_layers=3,               # conv layers
        d_model=0,
        image_size=32,
        image_channels=3,
        cnn_channels=(32, 64, 64),
        cnn_hidden=128,
        num_classes=10,
        dtype="float32",
        source="FedTest paper Sec. III (3 conv + 2 FC, CIFAR-10)",
    )
