"""pixtral-12b [vlm] — pixtral-ViT + mistral-nemo decoder. [hf:mistralai/Pixtral-12B-2409]

Assigned: 40L d_model=5120 32H (GQA kv=8) d_ff=14336 vocab=131072.
The ViT vision encoder + projector is a STUB: ``input_specs`` provides
precomputed patch embeddings (B, num_patches, d_model) interleaved with text.
"""
from repro.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="pixtral-12b",
        family="vlm",
        num_layers=40,
        d_model=5120,
        num_heads=32,
        num_kv_heads=8,
        head_dim=128,
        d_ff=14336,
        vocab_size=131072,
        num_patches=1024,           # one 1024-patch image per sample
        frontend="vision",
        rope_theta=1_000_000.0,
        max_position=131_072,
        source="hf:mistralai/Pixtral-12B-2409 model card",
    )
