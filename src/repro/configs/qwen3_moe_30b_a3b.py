"""qwen3-moe-30b-a3b [moe] — 128 experts top-8. [hf:Qwen/Qwen3-30B-A3B]

Assigned: 48L d_model=2048 32H (GQA kv=4) d_ff=768 (per expert)
vocab=151936, MoE 128e top-8, qk_norm.
"""
from repro.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen3-moe-30b-a3b",
        family="moe",
        num_layers=48,
        d_model=2048,
        num_heads=32,
        num_kv_heads=4,
        head_dim=128,
        d_ff=768,                   # per-expert FFN width
        vocab_size=151936,
        num_experts=128,
        num_experts_per_tok=8,
        moe_every=1,
        qk_norm=True,
        rope_theta=1_000_000.0,
        max_position=131_072,
        source="hf:Qwen/Qwen3-30B-A3B model card",
    )
