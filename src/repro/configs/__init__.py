"""Assigned architecture configs (+ the paper's own CNN).

Each module defines ``config() -> ModelConfig`` with the exact assigned
hyper-parameters, citing its source. ``get_config(arch_id)`` resolves the
CLI ``--arch`` id (dashes allowed) to the config.
"""
from repro.configs.registry import ARCH_IDS, get_config, list_configs
from repro.configs.scenarios import (
    SCENARIOS, get_scenario, list_scenarios, scenario_for_pod,
    scenario_for_population)

__all__ = ["get_config", "list_configs", "ARCH_IDS",
           "get_scenario", "list_scenarios", "scenario_for_pod",
           "scenario_for_population", "SCENARIOS"]
