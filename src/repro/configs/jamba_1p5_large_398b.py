"""jamba-1.5-large-398b [hybrid] — Mamba+attn 1:7 interleave, MoE. [arXiv:2403.19887]

Assigned: 72L d_model=8192 64H (GQA kv=8) d_ff=24576 (per expert),
vocab=65536, MoE 16e top-2. One attention layer per 8-layer period
(the remaining 7 are Mamba); MoE FFN every other layer.
"""
from repro.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="jamba-1.5-large-398b",
        family="hybrid",
        num_layers=72,
        d_model=8192,
        num_heads=64,
        num_kv_heads=8,
        head_dim=128,
        d_ff=24576,                 # per-expert FFN width
        vocab_size=65536,
        num_experts=16,
        num_experts_per_tok=2,
        moe_every=2,
        moe_offset=1,
        attn_every=8,               # 1:7 attention:mamba interleave
        attn_offset=4,              # attention sits mid-period (Jamba layout)
        ssm_state=16,               # Jamba uses small-state Mamba layers
        ssm_head_dim=64,
        ssm_expand=2,
        ssm_conv_width=4,
        ssm_chunk=256,
        rope_theta=1_000_000.0,
        max_position=262_144,
        norm_eps=1e-5,
        source="arXiv:2403.19887 + Jamba-1.5 card (398B total params)",
    )
