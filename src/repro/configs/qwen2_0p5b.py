"""qwen2-0.5b [dense] — GQA, QKV bias. [arXiv:2407.10671]

Assigned: 24L d_model=896 14H (GQA kv=2) d_ff=4864 vocab=151936.
"""
from repro.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen2-0.5b",
        family="dense",
        num_layers=24,
        d_model=896,
        num_heads=14,
        num_kv_heads=2,
        head_dim=64,
        d_ff=4864,
        vocab_size=151936,
        qkv_bias=True,
        rope_theta=1_000_000.0,
        max_position=131_072,
        tie_embeddings=True,
        source="arXiv:2407.10671 (Qwen2), 0.5B size",
    )
