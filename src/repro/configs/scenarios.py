"""Named federated scenarios: (aggregator x attack x selector) presets.

A scenario is a fully-specified :class:`FedConfig` — the strategy
registry's analogue of the arch registry. ``--scenario`` in
``repro.launch.train`` resolves these by name; individual CLI flags still
override single fields on top of the preset. The pod driver
(``repro.launch.federated``) resolves the same presets through
:func:`scenario_for_pod`, which refits the client-count-dependent fields
to the device count, so every scenario runs on either engine
(EXPERIMENTS.md §Scenarios).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List

from repro.config import FedConfig

SCENARIOS: Dict[str, FedConfig] = {
    # the paper's headline experiments (Sec. V / Fig. 4)
    "honest": FedConfig(
        num_users=20, num_testers=5, num_malicious=0, attack="none",
        rounds=60),
    "paper_random_weights": FedConfig(
        num_users=20, num_testers=5, num_malicious=3,
        attack="random_weights", rounds=60),
    "paper_lying_testers": FedConfig(
        num_users=20, num_testers=5, num_malicious=3,
        attack="random_weights", lying_testers=2, rounds=60),
    # robust-baseline comparisons opened by the strategy registry
    "krum_vs_scaled_update": FedConfig(
        num_users=20, num_testers=5, num_malicious=4,
        aggregator="krum", attack="scaled_update", attack_scale=10.0,
        rounds=60),
    "trimmed_mean_vs_label_flip": FedConfig(
        num_users=20, num_testers=5, num_malicious=4,
        aggregator="trimmed_mean", attack="label_flip_proxy", rounds=60),
    "median_vs_spread_attack": FedConfig(
        num_users=20, num_testers=5, num_malicious=4, aggregator="median",
        attack="random_weights", attack_kwargs={"placement": "spread"},
        rounds=60),
    "fixed_testers": FedConfig(
        num_users=20, num_testers=5, num_malicious=3,
        attack="random_weights", selector="fixed", rounds=60),
    # per-coordinate defences on the combine() fast path
    "coord_trimmed_mean_vs_scaled_update": FedConfig(
        num_users=20, num_testers=5, num_malicious=4,
        aggregator="trimmed_mean_coord",
        aggregator_kwargs={"trim_fraction": 0.25},
        attack="scaled_update", attack_scale=10.0, rounds=60),
    "coord_median_score_gated": FedConfig(
        num_users=20, num_testers=5, num_malicious=4,
        aggregator="median_coord", aggregator_kwargs={"score_gate": 0.2},
        attack="random_weights", rounds=60),
    # client sampling (participation R/N < 1, Sec. III notation)
    "partial_participation": FedConfig(
        num_users=20, num_testers=5, num_malicious=3,
        attack="random_weights", participation=0.5, rounds=60),
    # the combined adversarial + sampling setting every exchange backend
    # must agree on (the equivalence matrix's configuration,
    # EXPERIMENTS.md §Scenarios)
    "sign_flip_partial_participation": FedConfig(
        num_users=20, num_testers=5, num_malicious=1, attack="sign_flip",
        participation=0.75, rounds=60),
    # adaptive attacker reading its own weight through the AttackContext
    # seam: corrupts only while the federation still buys its update
    # (the ROADMAP's cross-testing-aware adversary, DESIGN.md §2)
    "adaptive_scale_vs_fedtest": FedConfig(
        num_users=20, num_testers=5, num_malicious=3,
        attack="adaptive_scale", attack_scale=4.0,
        attack_kwargs={"weight_threshold": 0.5}, rounds=60),
    # --- coalition adversaries (DESIGN.md §7) -------------------------
    # lying-tester coalition: members poison their models (independent
    # random_weights over the same slots) AND, whenever selected to
    # test, boost each other / defame the top-scoring honest clients.
    # Plain score averaging LOSES to this coalition (the boosts keep the
    # poison flowing and the defamation grinds the honest scores down);
    # the preset therefore runs the Sec. V-C tester-trust consensus with
    # a fast forgetting rate plus consensus-clipped reports, which bound
    # a member's report influence from round 1 (DESIGN.md §7).
    "mutual_boost_vs_fedtest": FedConfig(
        num_users=20, num_testers=5, num_malicious=4,
        attack="random_weights", coalition="mutual_boost",
        coalition_size=4,
        aggregator_kwargs={"use_trust": True, "trust_decay": 0.3,
                           "report_clip": 0.2},
        rounds=60),
    # sybil coalition splitting one scale-8 sign-flip poison so each
    # member's update stays at an inconspicuous scale-2 magnitude;
    # model-space only, so plain fedtest scoring suppresses it
    "sybil_split_vs_fedtest": FedConfig(
        num_users=20, num_testers=5, num_malicious=0, attack="none",
        coalition="sybil_split", coalition_size=4, attack_scale=8.0,
        rounds=60),
    # the combined worst case: split poisoning + mutual boosting
    "full_collusion_vs_fedtest": FedConfig(
        num_users=20, num_testers=5, num_malicious=0, attack="none",
        coalition="full_collusion", coalition_size=4, attack_scale=8.0,
        aggregator_kwargs={"use_trust": True, "trust_decay": 0.3,
                           "report_clip": 0.2},
        rounds=60),
    # --- compressed exchange variants (DESIGN.md §12) -----------------
    # the equivalence-matrix configuration over a quantised wire: does
    # the defence survive when every exchanged update round-trips
    # through int8 per-chunk quantisation with error feedback?
    "int8_sign_flip_partial_participation": FedConfig(
        num_users=20, num_testers=5, num_malicious=1, attack="sign_flip",
        participation=0.75, compressor="int8", rounds=60),
    # top-k sparsification (5% of coordinates per round) against the
    # lying-tester coalition — the sparsest wire the suppression claims
    # are committed for
    "topk_mutual_boost_vs_fedtest": FedConfig(
        num_users=20, num_testers=5, num_malicious=4,
        attack="random_weights", coalition="mutual_boost",
        coalition_size=4, compressor="topk",
        compressor_kwargs={"k": 0.05},
        aggregator_kwargs={"use_trust": True, "trust_decay": 0.3,
                           "report_clip": 0.2},
        rounds=60),
    # rank-4 delta factorisation under the adaptive attacker
    "lowrank_adaptive_scale": FedConfig(
        num_users=20, num_testers=5, num_malicious=3,
        attack="adaptive_scale", attack_scale=4.0,
        attack_kwargs={"weight_threshold": 0.5},
        compressor="lowrank", compressor_kwargs={"rank": 4}, rounds=60),
}


def get_scenario(name: str) -> FedConfig:
    if name not in SCENARIOS:
        raise KeyError(f"unknown scenario {name!r}; known: "
                       f"{sorted(SCENARIOS)}")
    return SCENARIOS[name]


def list_scenarios() -> List[str]:
    return sorted(SCENARIOS)


def scenario_for_pod(name: str, num_clients: int) -> FedConfig:
    """Refit a named preset onto a pod with ``num_clients`` devices.

    The pod path pins one client per device along the ``clients`` mesh
    axis, so ``num_users`` must equal the device count; the tester count
    and malicious count are clamped to stay valid at that size (a 20-user
    preset with 3 attackers becomes 3 attackers on 8 devices, 1 on 2).
    A coalition refits by *fraction* — a 4-of-20 coalition stays a ~20%
    coalition at any device count (1 member on 4 devices, 2 on 8) — and
    drags the paired independent attack's ``num_malicious`` down with it
    when the preset sizes them together, so the refit scenario keeps the
    preset's malicious fraction and means the same thing on either
    engine (DESIGN.md §7). The coalition is floored at one member (an
    empty coalition would deactivate the scenario), so on very small
    pods (2 devices) that floor can exceed the preset's fraction and
    reach the committee-majority breakdown regime DESIGN.md §7
    documents — suppression claims only transfer to pods where the
    refit coalition stays a committee minority. Every other knob —
    aggregator, attack, scales, participation, selector, coalition
    behaviour — carries over unchanged.
    """
    fed = get_scenario(name)
    num_mal = min(fed.num_malicious, max(num_clients - 1, 0))
    coal = 0
    ckw = dict(fed.coalition_kwargs)
    if fed.coalition != "none":
        # membership may come from coalition_size OR coalition_kwargs
        # (size= / indices=) — the same three forms FedConfig validates
        members = (fed.coalition_size or int(ckw.get("size") or 0)
                   or len(ckw.get("indices") or ()))
        coal = max(1, round(members * num_clients / fed.num_users))
        coal = min(coal, max(num_clients - 1, 0))
        # the refit owns membership: stale explicit size/indices from
        # the preset would override (or out-range) the refit placement
        ckw.pop("size", None)
        ckw.pop("indices", None)
        if fed.num_malicious == members:
            # the preset paired the independent attack with the
            # coalition over the same slots (equal sizes); keep them
            # paired after the refit, in both grow and shrink
            # directions. Unpaired attacks keep their own clamp.
            num_mal = coal
    return dataclasses.replace(
        fed, num_users=num_clients,
        num_testers=min(fed.num_testers, num_clients),
        num_malicious=num_mal,
        # a 1-client pod cannot hold a coalition (members < N): drop the
        # name with the members or FedConfig rejects the vacuous config
        coalition=fed.coalition if coal else "none",
        coalition_kwargs=ckw, coalition_size=coal)


def scenario_for_population(name: str, population: int, cohort: int
                            ) -> FedConfig:
    """Refit a named preset onto the population tier (DESIGN.md §11).

    Reuses :func:`scenario_for_pod`'s size refit — ``num_users`` becomes
    the population, testers/malicious clamp, coalitions rescale by
    fraction (so a preset's static member set can never land outside
    the population) — then sets the cohort capacity and refits the
    Bernoulli sampling rate to ``cohort / population`` so the expected
    per-round cohort matches the buffer. A preset's own partial
    participation is *replaced*, not composed: on the population tier
    the sampling rate **is** the cohort budget, and keeping a dense
    preset's 0.75 at N = 10⁴ would oversubscribe a C = 64 buffer ~100×
    (truncation would then bias toward low client indices). Raises
    loudly when ``cohort > population``.
    """
    if not 1 <= cohort <= population:
        raise ValueError(
            f"cohort={cohort} must be in [1, population={population}] — "
            "a cohort larger than the population gathers clients that "
            "do not exist")
    fed = scenario_for_pod(name, population)
    if cohort < population:
        fed = dataclasses.replace(fed, cohort=cohort,
                                  participation=cohort / population)
    else:
        fed = dataclasses.replace(fed, cohort=cohort)
    return fed
