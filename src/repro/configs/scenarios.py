"""Named federated scenarios: (aggregator x attack x selector) presets.

A scenario is a fully-specified :class:`FedConfig` — the strategy
registry's analogue of the arch registry. ``--scenario`` in
``repro.launch.train`` resolves these by name; individual CLI flags still
override single fields on top of the preset. The pod driver
(``repro.launch.federated``) resolves the same presets through
:func:`scenario_for_pod`, which refits the client-count-dependent fields
to the device count, so every scenario runs on either engine
(EXPERIMENTS.md §Scenarios).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List

from repro.config import FedConfig

SCENARIOS: Dict[str, FedConfig] = {
    # the paper's headline experiments (Sec. V / Fig. 4)
    "honest": FedConfig(
        num_users=20, num_testers=5, num_malicious=0, attack="none",
        rounds=60),
    "paper_random_weights": FedConfig(
        num_users=20, num_testers=5, num_malicious=3,
        attack="random_weights", rounds=60),
    "paper_lying_testers": FedConfig(
        num_users=20, num_testers=5, num_malicious=3,
        attack="random_weights", lying_testers=2, rounds=60),
    # robust-baseline comparisons opened by the strategy registry
    "krum_vs_scaled_update": FedConfig(
        num_users=20, num_testers=5, num_malicious=4,
        aggregator="krum", attack="scaled_update", attack_scale=10.0,
        rounds=60),
    "trimmed_mean_vs_label_flip": FedConfig(
        num_users=20, num_testers=5, num_malicious=4,
        aggregator="trimmed_mean", attack="label_flip_proxy", rounds=60),
    "median_vs_spread_attack": FedConfig(
        num_users=20, num_testers=5, num_malicious=4, aggregator="median",
        attack="random_weights", attack_kwargs={"placement": "spread"},
        rounds=60),
    "fixed_testers": FedConfig(
        num_users=20, num_testers=5, num_malicious=3,
        attack="random_weights", selector="fixed", rounds=60),
    # per-coordinate defences on the combine() fast path
    "coord_trimmed_mean_vs_scaled_update": FedConfig(
        num_users=20, num_testers=5, num_malicious=4,
        aggregator="trimmed_mean_coord",
        aggregator_kwargs={"trim_fraction": 0.25},
        attack="scaled_update", attack_scale=10.0, rounds=60),
    "coord_median_score_gated": FedConfig(
        num_users=20, num_testers=5, num_malicious=4,
        aggregator="median_coord", aggregator_kwargs={"score_gate": 0.2},
        attack="random_weights", rounds=60),
    # client sampling (participation R/N < 1, Sec. III notation)
    "partial_participation": FedConfig(
        num_users=20, num_testers=5, num_malicious=3,
        attack="random_weights", participation=0.5, rounds=60),
    # the combined adversarial + sampling setting every exchange backend
    # must agree on (the equivalence matrix's configuration,
    # EXPERIMENTS.md §Scenarios)
    "sign_flip_partial_participation": FedConfig(
        num_users=20, num_testers=5, num_malicious=1, attack="sign_flip",
        participation=0.75, rounds=60),
    # adaptive attacker reading its own weight through the AttackContext
    # seam: corrupts only while the federation still buys its update
    # (the ROADMAP's cross-testing-aware adversary, DESIGN.md §2)
    "adaptive_scale_vs_fedtest": FedConfig(
        num_users=20, num_testers=5, num_malicious=3,
        attack="adaptive_scale", attack_scale=4.0,
        attack_kwargs={"weight_threshold": 0.5}, rounds=60),
}


def get_scenario(name: str) -> FedConfig:
    if name not in SCENARIOS:
        raise KeyError(f"unknown scenario {name!r}; known: "
                       f"{sorted(SCENARIOS)}")
    return SCENARIOS[name]


def list_scenarios() -> List[str]:
    return sorted(SCENARIOS)


def scenario_for_pod(name: str, num_clients: int) -> FedConfig:
    """Refit a named preset onto a pod with ``num_clients`` devices.

    The pod path pins one client per device along the ``clients`` mesh
    axis, so ``num_users`` must equal the device count; the tester count
    and malicious count are clamped to stay valid at that size (a 20-user
    preset with 3 attackers becomes 3 attackers on 8 devices, 1 on 2).
    Every other knob — aggregator, attack, scales, participation,
    selector — carries over unchanged, so the scenario means the same
    thing on either engine.
    """
    fed = get_scenario(name)
    return dataclasses.replace(
        fed, num_users=num_clients,
        num_testers=min(fed.num_testers, num_clients),
        num_malicious=min(fed.num_malicious, max(num_clients - 1, 0)))
