"""The paper's model for MNIST-shaped inputs (Fig. 5 experiments)."""
from repro.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="fedtest-cnn-mnist",
        family="cnn",
        num_layers=3,
        d_model=0,
        image_size=28,
        image_channels=1,
        cnn_channels=(32, 64, 64),
        cnn_hidden=128,
        num_classes=10,
        dtype="float32",
        source="FedTest paper Sec. IV (MNIST experiments)",
    )
