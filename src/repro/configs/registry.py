"""Arch-id -> ModelConfig registry."""
from __future__ import annotations

import importlib
from typing import Dict, List

from repro.config import ModelConfig

# CLI id -> module name under repro.configs
ARCH_IDS: Dict[str, str] = {
    "whisper-base": "whisper_base",
    "qwen3-moe-30b-a3b": "qwen3_moe_30b_a3b",
    "qwen3-1.7b": "qwen3_1p7b",
    "mamba2-2.7b": "mamba2_2p7b",
    "qwen2-0.5b": "qwen2_0p5b",
    "qwen1.5-110b": "qwen1p5_110b",
    "qwen2-72b": "qwen2_72b",
    "jamba-1.5-large-398b": "jamba_1p5_large_398b",
    "pixtral-12b": "pixtral_12b",
    "granite-moe-1b-a400m": "granite_moe_1b_a400m",
    # the paper's own model (CIFAR-10 CNN, Sec. III)
    "fedtest-cnn": "fedtest_cnn",
    "fedtest-cnn-mnist": "fedtest_cnn_mnist",
    "fedtest-mlp-mnist": "fedtest_mlp_mnist",
}


def get_config(arch_id: str) -> ModelConfig:
    if arch_id not in ARCH_IDS:
        raise KeyError(f"unknown arch {arch_id!r}; known: {sorted(ARCH_IDS)}")
    mod = importlib.import_module(f"repro.configs.{ARCH_IDS[arch_id]}")
    return mod.config()


def list_configs() -> List[str]:
    return sorted(ARCH_IDS)
