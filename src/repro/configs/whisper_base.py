"""whisper-base [audio] — enc-dec, conv frontend stubbed. [arXiv:2212.04356]

Assigned: 6L d_model=512 8H (GQA kv=8) d_ff=2048 vocab=51865.
The mel-spectrogram + conv feature extractor is a STUB: ``input_specs``
provides precomputed frame embeddings of shape (B, 1500, 512).
"""
from repro.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="whisper-base",
        family="encdec",
        num_layers=6,               # decoder layers
        encoder_layers=6,
        encoder_seq=1500,           # 30 s of audio at 50 frames/s
        d_model=512,
        num_heads=8,
        num_kv_heads=8,             # MHA (GQA with kv = heads)
        head_dim=64,
        d_ff=2048,
        vocab_size=51865,
        decoder_max_position=448,
        max_position=448,
        qkv_bias=True,              # whisper uses biases on q/v/out
        frontend="audio",
        norm_eps=1e-5,
        tie_embeddings=True,
        source="arXiv:2212.04356 (Whisper), base size",
    )
