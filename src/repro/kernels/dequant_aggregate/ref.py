"""Oracle for the fused dequantise-aggregate: sum_c w_c * dequant(q_c).

The dequantisation mirrors ``Int8.decode`` op-for-op (reshape to chunks,
multiply by the per-chunk scale) and the reduction mirrors
``weighted_aggregate_ref`` (f32 einsum), so routing int8 aggregation
through this ref is bitwise-identical to decode-then-weighted-sum.
"""
from __future__ import annotations

import jax.numpy as jnp


def dequant_aggregate_ref(w: jnp.ndarray, scales: jnp.ndarray,
                          q: jnp.ndarray, chunk: int) -> jnp.ndarray:
    """w [C]; scales [C, M/chunk]; q [C, M] int8 -> [M] f32."""
    C, M = q.shape
    dec = (q.astype(jnp.float32).reshape(C, M // chunk, chunk)
           * scales.astype(jnp.float32)[:, :, None]).reshape(C, M)
    return jnp.einsum("c,cm->m", w.astype(jnp.float32), dec)
