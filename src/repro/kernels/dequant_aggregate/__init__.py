from repro.kernels.dequant_aggregate.ops import dequant_aggregate

__all__ = ["dequant_aggregate"]
