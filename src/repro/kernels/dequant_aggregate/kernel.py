"""Pallas TPU kernel: fused int8 dequantise + score-weighted reduction.

The server holds C compressed client payloads — ``q [C, M]`` int8 codes
and ``scales [C, M / chunk]`` f32 per-chunk absmax scales — and needs
``sum_c w_c * dequant(q_c)``. Doing that in two XLA ops would round-trip
the dequantised f32 ``[C, M]`` stack through HBM (4x the int8 bytes);
this kernel fuses both in one VMEM pass so the reduction streams the
*compressed* representation, staying bandwidth-bound like
``weighted_aggregate`` but at the int8 byte count (DESIGN.md §12).

Grid is 1-D over ``M // block_m``; each step streams a ``[C, block_m]``
int8 tile plus its ``[C, block_m / chunk]`` scale columns through VMEM,
dequantises on the VPU, and reduces with fp32 accumulation. The
dequantisation is bitwise-identical to ``Int8.decode`` (same reshape,
same multiply), so the pallas and naive paths agree exactly wherever
the platform's f32 arithmetic does.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _dqagg_kernel(w_ref, s_ref, q_ref, o_ref, *, chunk: int):
    q = q_ref[...].astype(jnp.float32)            # [C, block_m]
    s = s_ref[...].astype(jnp.float32)            # [C, block_m / chunk]
    c, bm = q.shape
    dec = (q.reshape(c, bm // chunk, chunk)
           * s[:, :, None]).reshape(c, bm)
    w = w_ref[...].astype(jnp.float32)            # [C, 1]
    o_ref[...] = jnp.sum(dec * w, axis=0, keepdims=True)


@functools.partial(jax.jit,
                   static_argnames=("chunk", "block_m", "interpret"))
def dequant_aggregate_pallas(w: jnp.ndarray, scales: jnp.ndarray,
                             q: jnp.ndarray, *, chunk: int,
                             block_m: int = 4096,
                             interpret: bool = False) -> jnp.ndarray:
    """w [C]; scales [C, M/chunk]; q [C, M] int8 -> [M] f32.

    ``M % block_m == 0`` and ``block_m % chunk == 0`` so every grid step
    sees whole chunks (the ops wrapper pads).
    """
    C, M = q.shape
    block_m = min(block_m, M)
    assert M % block_m == 0, (M, block_m)
    assert block_m % chunk == 0, (block_m, chunk)
    out = pl.pallas_call(
        functools.partial(_dqagg_kernel, chunk=chunk),
        grid=(M // block_m,),
        in_specs=[
            pl.BlockSpec((C, 1), lambda mi: (0, 0)),
            pl.BlockSpec((C, block_m // chunk), lambda mi: (0, mi)),
            pl.BlockSpec((C, block_m), lambda mi: (0, mi)),
        ],
        out_specs=pl.BlockSpec((1, block_m), lambda mi: (0, mi)),
        out_shape=jax.ShapeDtypeStruct((1, M), jnp.float32),
        interpret=interpret,
    )(w.reshape(C, 1), scales, q)
    return out[0]
