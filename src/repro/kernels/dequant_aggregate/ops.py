"""Public fused dequantise-aggregate op (int8 payload reduction)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.dequant_aggregate.kernel import dequant_aggregate_pallas
from repro.kernels.dequant_aggregate.ref import dequant_aggregate_ref


def dequant_aggregate(w: jnp.ndarray, scales: jnp.ndarray,
                      q: jnp.ndarray, *, chunk: int = 256,
                      impl: str = "auto", block_m: int = 4096,
                      interpret: bool = False) -> jnp.ndarray:
    """w [C]; scales [C, M/chunk]; q [C, M] int8 -> [M] f32.

    ``M`` must be a whole number of chunks (the Int8 compressor pads at
    encode time); the pallas path additionally pads M up to a block
    multiple with zero codes, which contribute exact +0.0f.
    """
    C, M = q.shape
    if M % chunk != 0:
        raise ValueError(f"M={M} must be a multiple of chunk={chunk}")
    if scales.shape != (C, M // chunk):
        raise ValueError(
            f"scales shape {scales.shape} != {(C, M // chunk)}")
    if impl == "auto":
        impl = "pallas" if jax.default_backend() == "tpu" else "naive"
    if impl == "naive":
        return dequant_aggregate_ref(w, scales, q, chunk)
    bm = min(block_m, max(M, chunk))
    bm = max(chunk, (bm // chunk) * chunk)
    pad = (-M) % bm
    if pad:
        q = jnp.pad(q, ((0, 0), (0, pad)))
        scales = jnp.pad(scales, ((0, 0), (0, pad // chunk)))
    out = dequant_aggregate_pallas(w, scales, q, chunk=chunk,
                                   block_m=bm, interpret=interpret)
    return out[:M]
