"""Pallas TPU kernels for the framework's compute hot-spots.

Each kernel lives in its own subpackage with three modules:

* ``kernel.py`` — the ``pl.pallas_call`` body with explicit BlockSpec VMEM
  tiling (TPU is the target; validated on CPU with ``interpret=True``).
* ``ops.py``    — the jit'd public wrapper with backend dispatch
  (``pallas`` on TPU, memory-bounded pure-XLA path elsewhere).
* ``ref.py``    — the pure-jnp oracle used by the allclose test sweeps.

Kernels:
* ``flash_attention``    — blockwise causal/sliding-window GQA attention.
* ``decode_attention``   — single-token flash-decoding with LSE outputs for
  cross-shard softmax merging.
* ``ssd_scan``           — Mamba2 SSD chunked scan (state passed across the
  sequential chunk grid dimension in VMEM scratch).
* ``weighted_aggregate`` — the FedTest server's score-weighted N-way model
  reduction.
* ``robust_combine``     — per-coordinate trimmed-mean / median over the
  client axis via a fixed-C odd-even sorting network (the
  ``Aggregator.combine()`` fast path), with an optional client mask.
"""
