from repro.kernels.ssd_scan.ops import ssd_scan

__all__ = ["ssd_scan"]
