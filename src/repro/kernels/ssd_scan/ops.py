"""Public SSD-scan op with backend dispatch.

``impl='xla'`` runs the same chunked algorithm as the Pallas kernel with a
``lax.scan`` over chunks (intra-chunk quadratic form + carried [P,N] state),
so its HLO is memory-bounded and representative for the dry-runs.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.ssd_scan.kernel import ssd_scan_pallas
from repro.kernels.ssd_scan.ref import ssd_ref

def _divisor_block(n: int, target: int) -> int:
    """Largest divisor of n that is <= target (keeps block loops exact)."""
    b = min(target, n)
    while n % b:
        b -= 1
    return b


@functools.partial(jax.jit, static_argnames=("chunk", "unroll"))
def _ssd_xla(x, dt, A, B, C, D, *, chunk: int = 128, unroll: bool = False):
    Bt, S, H, P = x.shape
    G, N = B.shape[2], B.shape[3]
    rep = H // G
    if unroll:
        chunk = max(chunk, (S + 7) // 8)
    chunk = _divisor_block(S, chunk)
    nc = S // chunk

    xf = x.astype(jnp.float32).reshape(Bt, nc, chunk, H, P)
    dtf = dt.astype(jnp.float32).reshape(Bt, nc, chunk, H)
    Bf = B.astype(jnp.float32).reshape(Bt, nc, chunk, G, N)
    Cf = C.astype(jnp.float32).reshape(Bt, nc, chunk, G, N)
    Af = A.astype(jnp.float32)
    Df = D.astype(jnp.float32)

    # chunk-major for scanning
    xs = (xf.transpose(1, 0, 2, 3, 4), dtf.transpose(1, 0, 2, 3),
          Bf.transpose(1, 0, 2, 3, 4), Cf.transpose(1, 0, 2, 3, 4))

    idx = jnp.arange(chunk)
    lower = idx[:, None] >= idx[None, :]

    def head_group(a):
        # [..., G, N] -> [..., H, N]
        return jnp.repeat(a, rep, axis=-2)

    def chunk_step(h, inp):
        xb, dtb, Bb, Cb = inp          # [Bt,Q,H,P],[Bt,Q,H],[Bt,Q,G,N]x2
        Bh = head_group(Bb)            # [Bt,Q,H,N]
        Ch = head_group(Cb)
        dA = dtb * Af                   # [Bt,Q,H]
        cum = jnp.cumsum(dA, axis=1)    # inclusive
        CB = jnp.einsum("bqhn,bkhn->bhqk", Ch, Bh)
        rel = cum[:, :, None, :] - cum[:, None, :, :]   # [Bt,q,k,H]
        # mask BEFORE exp: above-diagonal rel is large-positive (cum is
        # decreasing), and exp(+big)=inf would poison the backward pass
        # through the where.
        rel = jnp.where(lower[None, :, :, None], rel, -1e30)
        Lmat = jnp.exp(rel) * dtb[:, None, :, :]
        y = jnp.einsum("bhqk,bqkh,bkhp->bqhp", CB, Lmat, xb)
        y += jnp.exp(cum)[..., None] * jnp.einsum("bqhn,bhpn->bqhp", Ch, h)
        y += Df[None, None, :, None] * xb
        w = jnp.exp(cum[:, -1:, :] - cum) * dtb          # [Bt,Q,H]
        h = (jnp.exp(cum[:, -1, :])[..., None, None] * h
             + jnp.einsum("bqhp,bqhn->bhpn", xb * w[..., None], Bh))
        return h, y

    h0 = jnp.zeros((Bt, H, P, N), jnp.float32)
    h, ys = jax.lax.scan(chunk_step, h0, xs, unroll=True if unroll else 1)
    y = ys.transpose(1, 0, 2, 3, 4).reshape(Bt, S, H, P).astype(x.dtype)
    return y, h


def ssd_scan(x, dt, A, B, C, D, *, chunk: int = 128, impl: str = "auto",
             interpret: bool = False, unroll: bool = False):
    """Mamba2 SSD scan. Returns (y, final_state)."""
    if impl == "auto":
        impl = "pallas" if jax.default_backend() == "tpu" else "xla"
    if impl == "pallas":
        return ssd_scan_pallas(x, dt, A, B, C, D, chunk=chunk,
                               interpret=interpret)
    if impl == "xla":
        return _ssd_xla(x, dt, A, B, C, D, chunk=chunk, unroll=unroll)
    if impl == "naive":
        return ssd_ref(x, dt, A, B, C, D)
    raise ValueError(impl)
