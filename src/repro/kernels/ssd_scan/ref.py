"""Sequential-oracle for the Mamba2 SSD recurrence.

    h_t = exp(dt_t * A_h) * h_{t-1} + dt_t * (x_t outer B_t)     h in R^{P x N}
    y_t = h_t @ C_t + D_h * x_t

Shapes: x [Bt,S,H,P]; dt [Bt,S,H] (post-softplus); A [H] (negative);
B, C [Bt,S,G,N] (G state groups shared across H//G heads); D [H].
Returns (y [Bt,S,H,P], final_state [Bt,H,P,N]).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def ssd_ref(x, dt, A, B, C, D, init_state=None):
    Bt, S, H, P = x.shape
    G, N = B.shape[2], B.shape[3]
    rep = H // G
    Bh = jnp.repeat(B, rep, axis=2).astype(jnp.float32)   # [Bt,S,H,N]
    Ch = jnp.repeat(C, rep, axis=2).astype(jnp.float32)
    xf = x.astype(jnp.float32)
    dtf = dt.astype(jnp.float32)

    if init_state is None:
        init_state = jnp.zeros((Bt, H, P, N), jnp.float32)

    def step(h, inp):
        xt, dtt, Bt_, Ct_ = inp                     # [Bt,H,P],[Bt,H],[Bt,H,N]x2
        decay = jnp.exp(dtt * A)[..., None, None]   # [Bt,H,1,1]
        upd = dtt[..., None, None] * xt[..., :, None] * Bt_[..., None, :]
        h = decay * h + upd                          # [Bt,H,P,N]
        y = jnp.einsum("bhpn,bhn->bhp", h, Ct_) + D[None, :, None] * xt
        return h, y

    xs = (xf.transpose(1, 0, 2, 3), dtf.transpose(1, 0, 2),
          Bh.transpose(1, 0, 2, 3), Ch.transpose(1, 0, 2, 3))
    h, ys = jax.lax.scan(step, init_state, xs)
    y = ys.transpose(1, 0, 2, 3).astype(x.dtype)
    return y, h


def ssd_decode_ref(x, dt, A, B, C, D, state):
    """Single-token recurrent update. x [Bt,H,P]; dt [Bt,H]; B,C [Bt,G,N];
    state [Bt,H,P,N] -> (y [Bt,H,P], new_state)."""
    H = x.shape[1]
    G = B.shape[1]
    rep = H // G
    Bh = jnp.repeat(B, rep, axis=1).astype(jnp.float32)
    Ch = jnp.repeat(C, rep, axis=1).astype(jnp.float32)
    xf = x.astype(jnp.float32)
    dtf = dt.astype(jnp.float32)
    decay = jnp.exp(dtf * A)[..., None, None]
    state = decay * state + dtf[..., None, None] * xf[..., :, None] * Bh[..., None, :]
    y = jnp.einsum("bhpn,bhn->bhp", state, Ch) + D[None, :, None] * xf
    return y.astype(x.dtype), state
