"""Pallas TPU kernel for the Mamba2 SSD chunked scan.

Grid ``(Bt, H, num_chunks)`` with the chunk dimension innermost
(sequential on TPU): the running SSM state ``[P, N]`` lives in fp32 VMEM
scratch and is carried across chunk steps. Within a chunk the duality is
exploited — a ``[Q, Q]`` masked-decay attention-like matmul (MXU-friendly)
instead of a length-Q recurrence. B/C state groups (``G <= H``) are mapped
to heads via BlockSpec index maps, never materialised per-head in HBM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(a_ref, d_ref, x_ref, dt_ref, b_ref, c_ref,
                y_ref, state_out_ref, h_ref, *, chunk: int,
                num_chunks: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    A = a_ref[0]                                   # scalar (negative)
    Dh = d_ref[0]
    xb = x_ref[0, 0].astype(jnp.float32)           # [Q, P]
    dtb = dt_ref[0, 0].astype(jnp.float32)         # [Q, 1]
    Bb = b_ref[0, 0].astype(jnp.float32)           # [Q, N]
    Cb = c_ref[0, 0].astype(jnp.float32)           # [Q, N]

    dA = dtb * A                                   # [Q, 1]
    cum = jnp.cumsum(dA, axis=0)                   # [Q, 1] inclusive
    h0 = h_ref[...]                                # [P, N]

    # intra-chunk (the "duality" quadratic form)
    CB = jax.lax.dot_general(Cb, Bb, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)  # [Q, Q]
    rel = cum - cum.T                               # cum_i - cum_j
    i = jax.lax.broadcasted_iota(jnp.int32, CB.shape, 0)
    j = jax.lax.broadcasted_iota(jnp.int32, CB.shape, 1)
    rel = jnp.where(i >= j, rel, -1e30)             # mask before exp
    L = jnp.exp(rel) * dtb.T                        # [Q, Q]
    y = jax.lax.dot_general(CB * L, xb, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)   # [Q, P]

    # inter-chunk contribution from the carried state
    y += jnp.exp(cum) * jax.lax.dot_general(
        Cb, h0, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)            # [Q, P]

    y_ref[0, 0] = (y + Dh * xb).astype(y_ref.dtype)

    # state update: h <- exp(cum_Q) h + sum_j exp(cum_Q - cum_j) dt_j x_j B_j^T
    w = jnp.exp(cum[-1:] - cum) * dtb                  # [Q, 1]
    h_new = jnp.exp(cum[-1, 0]) * h0 + jax.lax.dot_general(
        xb * w, Bb, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)            # [P, N]
    h_ref[...] = h_new

    @pl.when(ci == num_chunks - 1)
    def _emit_state():
        state_out_ref[0, 0] = h_new


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan_pallas(x, dt, A, B, C, D, *, chunk: int = 128,
                    interpret: bool = False):
    """Chunked SSD scan. Shapes as in ``ref.ssd_ref``.

    Returns (y [Bt,S,H,P], final_state [Bt,H,P,N] fp32).
    """
    Bt, S, H, P = x.shape
    G, N = B.shape[2], B.shape[3]
    rep = H // G
    chunk = min(chunk, S)
    assert S % chunk == 0, (S, chunk)
    nc = S // chunk

    xh = x.transpose(0, 2, 1, 3)                     # [Bt, H, S, P]
    dth = dt.transpose(0, 2, 1)[..., None]           # [Bt, H, S, 1]
    Bg = B.transpose(0, 2, 1, 3)                     # [Bt, G, S, N]
    Cg = C.transpose(0, 2, 1, 3)

    grid = (Bt, H, nc)
    kernel = functools.partial(_ssd_kernel, chunk=chunk, num_chunks=nc)

    y, state = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1,), lambda b, h, ci: (h,),
                         memory_space=pltpu.SMEM),
            pl.BlockSpec((1,), lambda b, h, ci: (h,),
                         memory_space=pltpu.SMEM),
            pl.BlockSpec((1, 1, chunk, P), lambda b, h, ci: (b, h, ci, 0)),
            pl.BlockSpec((1, 1, chunk, 1), lambda b, h, ci: (b, h, ci, 0)),
            pl.BlockSpec((1, 1, chunk, N),
                         lambda b, h, ci, _rep=rep: (b, h // _rep, ci, 0)),
            pl.BlockSpec((1, 1, chunk, N),
                         lambda b, h, ci, _rep=rep: (b, h // _rep, ci, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, chunk, P), lambda b, h, ci: (b, h, ci, 0)),
            pl.BlockSpec((1, 1, P, N), lambda b, h, ci: (b, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Bt, H, S, P), x.dtype),
            jax.ShapeDtypeStruct((Bt, H, P, N), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((P, N), jnp.float32)],
        interpret=interpret,
    )(A.astype(jnp.float32), D.astype(jnp.float32), xh, dth, Bg, Cg)
    return y.transpose(0, 2, 1, 3), state
