"""Public flash-attention op with backend dispatch.

* ``impl='pallas'``  — the TPU Pallas kernel (interpret-mode on CPU).
* ``impl='xla'``     — memory-bounded blockwise online-softmax attention in
  pure XLA (double ``lax.scan`` over q/kv blocks). This is what the model
  zoo lowers for the dry-runs: per-step intermediates are
  ``[B, H, block_q, block_k]`` instead of the quadratic ``[B, H, S, T]``.
* ``impl='naive'``   — the ref oracle (small shapes / tests only).
* ``impl='auto'``    — pallas on TPU, xla elsewhere.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.kernel import flash_attention_pallas
from repro.kernels.flash_attention.ref import attention_ref

NEG_INF = -1e30

def _divisor_block(n: int, target: int) -> int:
    """Largest divisor of n that is <= target (keeps block loops exact)."""
    b = min(target, n)
    while n % b:
        b -= 1
    return b


def _default_backend() -> str:
    return "pallas" if jax.default_backend() == "tpu" else "xla"


@functools.partial(
    jax.jit, static_argnames=("causal", "sliding_window", "scale",
                              "q_offset", "block_q", "block_k", "unroll"))
def attention_xla(q, k, v, *, causal=True, sliding_window=None, scale=None,
                  q_offset=0, block_q=512, block_k=512, unroll=False):
    """Blockwise online-softmax attention, pure XLA. Same layout as ref.

    ``unroll=True`` (dry-run cost probes only) unrolls the block loops so
    XLA cost analysis sees every body; blocks are enlarged to keep the
    body count small — total matmul FLOPs are blocking-independent.
    """
    B, S, Hq, D = q.shape
    _, T, Hkv, _ = k.shape
    assert Hq % Hkv == 0
    rep = Hq // Hkv
    if scale is None:
        scale = D ** -0.5
    if unroll:
        block_q = max(block_q, (S + 3) // 4)
        block_k = max(block_k, (T + 3) // 4)
    block_q = _divisor_block(S, block_q)
    block_k = _divisor_block(T, block_k)
    nq, nk = S // block_q, T // block_k

    # [n_blocks, B, Hkv, rep|1, block, D] layouts for scanning.
    qb = (q.reshape(B, nq, block_q, Hkv, rep, D)
          .transpose(1, 0, 3, 4, 2, 5))           # [nq, B, Hkv, rep, bq, D]
    kb = k.reshape(B, nk, block_k, Hkv, D).transpose(1, 0, 3, 2, 4)
    vb = v.reshape(B, nk, block_k, Hkv, D).transpose(1, 0, 3, 2, 4)

    qpos_base = jnp.arange(block_q) + q_offset
    kpos_base = jnp.arange(block_k)

    def q_step(_, qi_and_blk):
        qi, qblk = qi_and_blk
        qblk = qblk.astype(jnp.float32) * scale
        qpos = qpos_base + qi * block_q            # [bq]

        def kv_step(carry, ki_and_kv):
            m, l, acc = carry
            ki, kblk, vblk = ki_and_kv
            s = jnp.einsum("bgrqd,bgkd->bgrqk", qblk,
                           kblk.astype(jnp.float32))
            kpos = kpos_base + ki * block_k
            mask = jnp.ones((block_q, block_k), bool)
            if causal:
                mask &= kpos[None, :] <= qpos[:, None]
            if sliding_window is not None:
                mask &= kpos[None, :] > qpos[:, None] - sliding_window
            s = jnp.where(mask, s, NEG_INF)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1, keepdims=True))
            p = jnp.exp(s - m_new)
            corr = jnp.exp(m - m_new)
            l = l * corr + jnp.sum(p, axis=-1, keepdims=True)
            acc = acc * corr + jnp.einsum("bgrqk,bgkd->bgrqd", p,
                                          vblk.astype(jnp.float32))
            return (m_new, l, acc), None

        m0 = jnp.full((B, Hkv, rep, block_q, 1), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, Hkv, rep, block_q, 1), jnp.float32)
        a0 = jnp.zeros((B, Hkv, rep, block_q, D), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(
            kv_step, (m0, l0, a0), (jnp.arange(nk), kb, vb),
            unroll=True if unroll else 1)
        out = acc / jnp.maximum(l, 1e-30)
        return None, out.astype(q.dtype)

    _, ob = jax.lax.scan(q_step, None, (jnp.arange(nq), qb),
                         unroll=True if unroll else 1)
    # ob: [nq, B, Hkv, rep, bq, D] -> [B, S, Hq, D]
    out = ob.transpose(1, 0, 4, 2, 3, 5).reshape(B, S, Hq, D)
    return out


def flash_attention(q, k, v, *, causal: bool = True,
                    sliding_window: Optional[int] = None,
                    scale: Optional[float] = None,
                    q_offset: int = 0,
                    impl: str = "auto",
                    interpret: bool = False,
                    block_q: int = 512,
                    block_k: int = 512,
                    unroll: bool = False):
    """Attention entry point used by the model zoo.

    q [B,S,Hq,D]; k, v [B,T,Hkv,D] -> [B,S,Hq,D].
    """
    if impl == "auto":
        impl = _default_backend()
    if impl == "pallas":
        return flash_attention_pallas(
            q, k, v, causal=causal, sliding_window=sliding_window,
            scale=scale, q_offset=q_offset, interpret=interpret,
            block_q=min(block_q, 128), block_k=min(block_k, 128))
    if impl == "xla":
        return attention_xla(
            q, k, v, causal=causal, sliding_window=sliding_window,
            scale=scale, q_offset=q_offset, block_q=block_q,
            block_k=block_k, unroll=unroll)
    if impl == "naive":
        return attention_ref(q, k, v, causal=causal,
                             sliding_window=sliding_window, scale=scale,
                             q_offset=q_offset)
    raise ValueError(f"unknown impl {impl!r}")
