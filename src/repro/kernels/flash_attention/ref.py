"""Pure-jnp oracle for flash attention (naive materialised softmax).

Layout: q [B, S, Hq, D]; k, v [B, T, Hkv, D]; output [B, S, Hq, D].
GQA: Hq must be a multiple of Hkv; kv heads are shared across groups.
"""
from __future__ import annotations

from typing import Optional

import jax.numpy as jnp


def attention_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                  causal: bool = True,
                  sliding_window: Optional[int] = None,
                  scale: Optional[float] = None,
                  q_offset: int = 0) -> jnp.ndarray:
    """Naive attention. ``q_offset`` positions queries inside a longer KV
    (decode / chunked prefill): query i attends key t iff t <= i + q_offset.
    """
    B, S, Hq, D = q.shape
    _, T, Hkv, _ = k.shape
    assert Hq % Hkv == 0, (Hq, Hkv)
    rep = Hq // Hkv
    if scale is None:
        scale = D ** -0.5

    k = jnp.repeat(k, rep, axis=2)
    v = jnp.repeat(v, rep, axis=2)
    logits = jnp.einsum("bshd,bthd->bhst", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale

    qpos = jnp.arange(S)[:, None] + q_offset
    kpos = jnp.arange(T)[None, :]
    mask = jnp.ones((S, T), dtype=bool)
    if causal:
        mask &= kpos <= qpos
    if sliding_window is not None:
        mask &= kpos > qpos - sliding_window
    logits = jnp.where(mask[None, None], logits, -jnp.inf)
    probs = jnp.nan_to_num(jnp.exp(logits - jnp.max(logits, -1, keepdims=True)))
    probs = probs / jnp.maximum(probs.sum(-1, keepdims=True), 1e-30)
    out = jnp.einsum("bhst,bthd->bshd", probs, v.astype(jnp.float32))
    return out.astype(q.dtype)
