"""Pallas TPU flash-attention kernel (GQA, causal / sliding-window).

Target layout inside the kernel: heads-major ``[B, H, S, D]`` so each grid
step streams contiguous (block_q x D) / (block_k x D) tiles through VMEM.

Grid: ``(B, Hq, S // block_q, T // block_k)`` — the KV-block dimension is
innermost, i.e. sequential on TPU, so the online-softmax running state
(m, l, acc) lives in VMEM scratch and is revisited across KV steps.
GQA is expressed in the K/V BlockSpec index maps (``h // group``) so grouped
KV heads are never materialised ``rep`` times in HBM.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
                  scale: float, causal: bool,
                  sliding_window: Optional[int],
                  block_q: int, block_k: int,
                  num_k_blocks: int, q_offset: int):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q_start = qi * block_q + q_offset
    k_start = ki * block_k

    # Skip blocks that are fully masked out (above the causal diagonal or
    # entirely left of the sliding window).
    run = True
    if causal:
        run = k_start <= q_start + block_q - 1
    if sliding_window is not None:
        run = jnp.logical_and(
            run, k_start + block_k - 1 > q_start - sliding_window)

    @pl.when(run)
    def _step():
        q = q_ref[0, 0].astype(jnp.float32)        # [block_q, D]
        k = k_ref[0, 0].astype(jnp.float32)        # [block_k, D]
        v = v_ref[0, 0].astype(jnp.float32)        # [block_k, D]
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale  # [bq, bk]

        qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        mask = jnp.ones(s.shape, dtype=bool)
        if causal:
            mask &= kpos <= qpos
        if sliding_window is not None:
            mask &= kpos > qpos - sliding_window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[...]                         # [bq, 1]
        m_cur = jnp.max(s, axis=1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)                      # [bq, bk]
        corr = jnp.exp(m_prev - m_new)              # [bq, 1]
        l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=1, keepdims=True)
        acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(ki == num_k_blocks - 1)
    def _finalize():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "sliding_window", "scale", "block_q",
                     "block_k", "q_offset", "interpret"))
def flash_attention_pallas(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                           causal: bool = True,
                           sliding_window: Optional[int] = None,
                           scale: Optional[float] = None,
                           block_q: int = 128,
                           block_k: int = 128,
                           q_offset: int = 0,
                           interpret: bool = False) -> jnp.ndarray:
    """q [B,S,Hq,D], k/v [B,T,Hkv,D] -> [B,S,Hq,D]."""
    B, S, Hq, D = q.shape
    _, T, Hkv, _ = k.shape
    assert Hq % Hkv == 0
    group = Hq // Hkv
    if scale is None:
        scale = D ** -0.5
    block_q = min(block_q, S)
    block_k = min(block_k, T)
    assert S % block_q == 0 and T % block_k == 0, (S, T, block_q, block_k)
    num_k_blocks = T // block_k

    qh = q.transpose(0, 2, 1, 3)     # [B, Hq, S, D]
    kh = k.transpose(0, 2, 1, 3)     # [B, Hkv, T, D]
    vh = v.transpose(0, 2, 1, 3)

    grid = (B, Hq, S // block_q, num_k_blocks)
    kernel = functools.partial(
        _flash_kernel, scale=scale, causal=causal,
        sliding_window=sliding_window, block_q=block_q, block_k=block_k,
        num_k_blocks=num_k_blocks, q_offset=q_offset)

    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_q, D), lambda b, h, qi, ki: (b, h, qi, 0)),
            pl.BlockSpec((1, 1, block_k, D),
                         lambda b, h, qi, ki: (b, h // group, ki, 0)),
            pl.BlockSpec((1, 1, block_k, D),
                         lambda b, h, qi, ki: (b, h // group, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, D),
                               lambda b, h, qi, ki: (b, h, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Hq, S, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, D), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
        ],
        interpret=interpret,
    )(qh, kh, vh)
    return out.transpose(0, 2, 1, 3)
