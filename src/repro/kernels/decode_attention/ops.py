"""Public decode-attention op with backend dispatch + partial merging.

``decode_attention`` returns (out, lse) for one KV shard; ``merge_partials``
combines partials from sequence-sharded caches with LSE weighting. Under
pjit the merge is expressed with ordinary jnp ops so GSPMD emits the
all-reduce; under shard_map the caller psums the two merge accumulators.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.decode_attention.kernel import decode_attention_pallas
from repro.kernels.decode_attention.ref import decode_attention_ref

def _divisor_block(n: int, target: int) -> int:
    """Largest divisor of n that is <= target (keeps block loops exact)."""
    b = min(target, n)
    while n % b:
        b -= 1
    return b


@functools.partial(jax.jit,
                   static_argnames=("scale", "window", "block_k", "unroll"))
def _decode_xla(q, k, v, lengths, *, scale=None, window=None, block_k=1024,
                unroll=False):
    """Blockwise decode attention in pure XLA (scan over KV blocks)."""
    B, Hq, D = q.shape
    _, T, Hkv, _ = k.shape
    rep = Hq // Hkv
    if scale is None:
        scale = D ** -0.5
    if unroll:
        block_k = max(block_k, (T + 7) // 8)
    block_k = _divisor_block(T, block_k)
    nk = T // block_k

    qg = (q.reshape(B, Hkv, rep, D).astype(jnp.float32)) * scale
    kb = k.reshape(B, nk, block_k, Hkv, D).transpose(1, 0, 3, 2, 4)
    vb = v.reshape(B, nk, block_k, Hkv, D).transpose(1, 0, 3, 2, 4)

    def step(carry, kv):
        m, l, acc = carry
        ki, kblk, vblk = kv
        s = jnp.einsum("bgrd,bgkd->bgrk", qg, kblk.astype(jnp.float32))
        kpos = ki * block_k + jnp.arange(block_k)
        valid = kpos[None, :] < lengths[:, None]
        if window is not None:
            valid &= kpos[None, :] >= lengths[:, None] - window
        s = jnp.where(valid[:, None, None, :], s, -1e30)
        m_new = jnp.maximum(m, jnp.max(s, -1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m - m_new)
        l = l * corr + jnp.sum(p, -1, keepdims=True)
        acc = acc * corr + jnp.einsum("bgrk,bgkd->bgrd", p,
                                      vblk.astype(jnp.float32))
        return (m_new, l, acc), None

    m0 = jnp.full((B, Hkv, rep, 1), -1e30, jnp.float32)
    l0 = jnp.zeros((B, Hkv, rep, 1), jnp.float32)
    a0 = jnp.zeros((B, Hkv, rep, D), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(step, (m0, l0, a0),
                                  (jnp.arange(nk), kb, vb),
                                  unroll=True if unroll else 1)
    l = jnp.maximum(l, 1e-30)
    out = (acc / l).astype(q.dtype).reshape(B, Hq, D)
    lse = (m + jnp.log(l)).reshape(B, Hq)
    return out, lse


@functools.partial(jax.jit, static_argnames=("scale", "window"))
def _decode_oneshot(q, k, v, lengths, *, scale=None, window=None):
    """Unblocked grouped decode attention (GSPMD-friendly).

    No jnp.repeat and no reshape along the cache's sequence dim, so a
    sequence-sharded KV cache stays sharded: the [B,Hkv,rep,T] logits are
    computed per T-shard and the softmax reductions become psums. This is
    the default graph-level path; on TPU the Pallas kernel adds the VMEM
    block streaming per shard.
    """
    B, Hq, D = q.shape
    _, T, Hkv, _ = k.shape
    rep = Hq // Hkv
    if scale is None:
        scale = D ** -0.5
    qg = q.reshape(B, Hkv, rep, D).astype(jnp.float32) * scale
    s = jnp.einsum("bgrd,btgd->bgrt", qg, k.astype(jnp.float32))
    t = jnp.arange(T)[None, :]
    valid = t < lengths[:, None]
    if window is not None:
        valid &= t >= lengths[:, None] - window
    s = jnp.where(valid[:, None, None, :], s, -1e30)
    m = jnp.max(s, axis=-1, keepdims=True)
    p = jnp.exp(s - m)
    l = jnp.maximum(jnp.sum(p, axis=-1, keepdims=True), 1e-30)
    out = jnp.einsum("bgrt,btgd->bgrd", p / l, v.astype(jnp.float32))
    lse = (m + jnp.log(l))[..., 0]
    return (out.reshape(B, Hq, D).astype(q.dtype),
            lse.reshape(B, Hq))


def decode_attention(q, k, v, lengths, *, scale: Optional[float] = None,
                     window: Optional[int] = None, impl: str = "auto",
                     interpret: bool = False, block_k: int = 1024,
                     unroll: bool = False):
    """q [B,Hq,D]; cache k/v [B,T,Hkv,D]; lengths [B] -> (out, lse)."""
    if impl == "auto":
        impl = "pallas" if jax.default_backend() == "tpu" else "oneshot"
    if impl == "oneshot":
        return _decode_oneshot(q, k, v, lengths, scale=scale, window=window)
    if impl == "pallas":
        return decode_attention_pallas(
            q, k, v, lengths, scale=scale, window=window,
            block_k=min(block_k, 256), interpret=interpret)
    if impl == "xla":
        return _decode_xla(q, k, v, lengths, scale=scale, window=window,
                           block_k=block_k, unroll=unroll)
    if impl == "naive":
        return decode_attention_ref(q, k, v, lengths, scale=scale,
                                    window=window)
    raise ValueError(impl)


def merge_partials(outs, lses):
    """LSE-weighted merge of per-shard partial attentions.

    outs [S, B, H, D] and lses [S, B, H] stacked over shards ->
    (out [B,H,D]). Shards with no valid keys carry lse = -inf and drop out.
    """
    m = jnp.max(lses, axis=0, keepdims=True)
    w = jnp.exp(lses - m)                        # [S, B, H]
    denom = jnp.maximum(jnp.sum(w, axis=0), 1e-30)
    out = jnp.sum(outs.astype(jnp.float32) * w[..., None], axis=0) / denom[..., None]
    return out.astype(outs.dtype)
