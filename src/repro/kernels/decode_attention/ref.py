"""Oracle for single-token decode attention.

q [B, Hq, D] attends a KV cache k/v [B, T, Hkv, D] of which the first
``lengths[b]`` entries are valid. Returns (out [B, Hq, D], lse [B, Hq]) —
the log-sum-exp output makes the op composable across KV shards
(flash-decoding style merging).
"""
from __future__ import annotations

from typing import Optional

import jax.numpy as jnp


def decode_attention_ref(q, k, v, lengths, *, scale: Optional[float] = None,
                         window: Optional[int] = None):
    B, Hq, D = q.shape
    _, T, Hkv, _ = k.shape
    rep = Hq // Hkv
    if scale is None:
        scale = D ** -0.5
    k = jnp.repeat(k, rep, axis=2)
    v = jnp.repeat(v, rep, axis=2)
    s = jnp.einsum("bhd,bthd->bht", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    t = jnp.arange(T)[None, :]
    valid = t < lengths[:, None]
    if window is not None:
        valid &= t >= lengths[:, None] - window
    s = jnp.where(valid[:, None, :], s, -jnp.inf)
    m = jnp.max(s, axis=-1, keepdims=True)
    p = jnp.exp(s - m)
    l = jnp.sum(p, axis=-1, keepdims=True)
    out = jnp.einsum("bht,bthd->bhd", p / jnp.maximum(l, 1e-30),
                     v.astype(jnp.float32))
    lse = (m + jnp.log(jnp.maximum(l, 1e-30)))[..., 0]
    return out.astype(q.dtype), lse
