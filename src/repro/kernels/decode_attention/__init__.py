from repro.kernels.decode_attention.ops import decode_attention, merge_partials

__all__ = ["decode_attention", "merge_partials"]
