"""Pallas TPU flash-decoding kernel.

One new token per sequence attends a long KV cache. The cache is streamed
through VMEM in ``block_k`` tiles along the sequential innermost grid
dimension, with the online-softmax state in scratch. Emits (out, lse) so a
sequence-sharded cache can be combined with an LSE-weighted merge — the
TPU-native analogue of GPU flash-decoding split-K.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _decode_kernel(len_ref, q_ref, k_ref, v_ref, o_ref, lse_ref,
                   acc_ref, m_ref, l_ref, *,
                   scale: float, block_k: int, num_k_blocks: int,
                   window: Optional[int]):
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    length = len_ref[0]
    k_start = ki * block_k
    lo = length - window if window is not None else 0
    run = jnp.logical_and(k_start < length, k_start + block_k > lo)

    @pl.when(run)
    def _step():
        q = q_ref[0, 0].astype(jnp.float32)         # [rep, D]
        k = k_ref[0, 0].astype(jnp.float32)         # [block_k, D] (kv head g)
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale   # [Hq, block_k]
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        valid = kpos < length
        if window is not None:
            valid &= kpos >= length - window
        s = jnp.where(valid, s, NEG_INF)

        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=1, keepdims=True)
        acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(ki == num_k_blocks - 1)
    def _finalize():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l).astype(o_ref.dtype)
        lse_ref[0, 0] = (m_ref[...] + jnp.log(l))[:, 0].astype(lse_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("scale", "block_k", "window", "interpret"))
def decode_attention_pallas(q, k, v, lengths, *,
                            scale: Optional[float] = None,
                            block_k: int = 256,
                            window: Optional[int] = None,
                            interpret: bool = False):
    """q [B,Hq,D]; k/v [B,T,Hkv,D]; lengths [B] -> (out [B,Hq,D], lse [B,Hq]).

    GQA grid: (B, Hkv, T // block_k); each step handles one kv head's whole
    query-head group (rep = Hq // Hkv rows of q).
    """
    B, Hq, D = q.shape
    _, T, Hkv, _ = k.shape
    assert Hq % Hkv == 0
    rep = Hq // Hkv
    if scale is None:
        scale = D ** -0.5
    block_k = min(block_k, T)
    assert T % block_k == 0
    num_k_blocks = T // block_k

    qg = q.reshape(B, Hkv, rep, D)                  # group-major query heads
    kh = k.transpose(0, 2, 1, 3)                    # [B, Hkv, T, D]
    vh = v.transpose(0, 2, 1, 3)

    grid = (B, Hkv, num_k_blocks)
    kernel = functools.partial(
        _decode_kernel, scale=scale, block_k=block_k,
        num_k_blocks=num_k_blocks, window=window)

    out, lse = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1,), lambda b, g, ki: (b,),
                         memory_space=pltpu.SMEM),
            pl.BlockSpec((1, 1, rep, D), lambda b, g, ki: (b, g, 0, 0)),
            pl.BlockSpec((1, 1, block_k, D), lambda b, g, ki: (b, g, ki, 0)),
            pl.BlockSpec((1, 1, block_k, D), lambda b, g, ki: (b, g, ki, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, rep, D), lambda b, g, ki: (b, g, 0, 0)),
            pl.BlockSpec((1, 1, rep), lambda b, g, ki: (b, g, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, Hkv, rep, D), q.dtype),
            jax.ShapeDtypeStruct((B, Hkv, rep), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((rep, D), jnp.float32),
            pltpu.VMEM((rep, 1), jnp.float32),
            pltpu.VMEM((rep, 1), jnp.float32),
        ],
        interpret=interpret,
    )(lengths.astype(jnp.int32), qg, kh, vh)
    return out.reshape(B, Hq, D), lse.reshape(B, Hq)
