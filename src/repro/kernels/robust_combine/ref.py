"""Oracle for per-coordinate robust aggregation: jnp.sort + selection.

``robust_combine_ref`` is the ground truth the Pallas sorting-network
kernel (and its XLA fallback) are tested against: sort each coordinate's
C client values with ``jnp.sort`` (masked clients pushed past every
finite value) and reduce the sorted stack with the caller's
sorted-position weights. It is also the ``impl='sort'`` path — the
baseline the network implementations must beat.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.kernels.robust_combine.kernel import _MASKED_SENTINEL


def robust_combine_ref(x: jnp.ndarray, mask: jnp.ndarray,
                       w_row: jnp.ndarray) -> jnp.ndarray:
    """x [C, M]; mask [C]; w_row [C] (sorted-position weights) -> [M]."""
    xm = jnp.where(mask.astype(jnp.float32)[:, None] > 0.0,
                   x.astype(jnp.float32), _MASKED_SENTINEL)
    xs = jnp.sort(xm, axis=0)
    out = jnp.einsum("c,cm->m", w_row.astype(jnp.float32), xs)
    return out.astype(x.dtype)
