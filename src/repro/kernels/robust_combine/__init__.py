from repro.kernels.robust_combine.ops import (
    robust_combine, row_select_weights)

__all__ = ["robust_combine", "row_select_weights"]
