"""Public per-coordinate robust-combine ops (array- and pytree-level).

The op reduces a ``[C, M]`` stack of flattened client updates to one
``[M]`` combined update with a *per-coordinate order statistic* —
coordinate-wise trimmed mean or median — instead of a weighted sum. An
optional ``[C]`` mask gates which clients enter the statistic at all
(FedTest scores, participation sampling, or both).

Backend dispatch:

* ``pallas``  — the VMEM-tiled sorting-network kernel (TPU).
* ``network`` — the same Batcher odd-even merge network as vectorised
  XLA row min/max ops; the CPU/GPU fast path (beats ``jnp.sort`` by an
  order of magnitude for C <~ 32 because XLA fuses the ``O(C log^2 C)``
  elementwise exchanges into one pass over the stack instead of running
  a general sort).
* ``sort``    — the ``jnp.sort`` oracle (``ref.py``), kept as the
  correctness baseline and the slow path the benches compare against.

Both statistics reduce to one mechanism: sort each coordinate's C values
ascending (masked clients past every finite value), then dot the sorted
stack with a ``[C]`` *sorted-position* weight vector ``w_row`` computed
once per call by :func:`row_select_weights` — uniform over the kept
middle slice for the trimmed mean, 0.5/0.5 on the middle pair for the
median. ``w_row`` depends only on the [C] mask, so it is O(C) work and
the [C, M] stream stays pure min/max + one weighted reduction.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.robust_combine.kernel import (
    _MASKED_SENTINEL, _sort_rows, robust_combine_pallas)
from repro.kernels.robust_combine.ref import robust_combine_ref

MODES = ("trimmed_mean", "median")


def row_select_weights(mask: jnp.ndarray, *, mode: str = "trimmed_mean",
                       trim_fraction: float = 0.2) -> jnp.ndarray:
    """Sorted-position selection weights for a masked robust combine.

    ``mask`` [C] (>0 = client participates) -> ``w_row`` [C] over the
    *ascending-sorted* positions, masked clients occupying the tail:

    * ``trimmed_mean``: drop ``floor(trim_fraction * k)`` from each end
      of the k participating values, uniform over the rest. ``t`` is
      clamped so at least one value is always kept, which makes
      ``trim_fraction`` ~ 0.5 degrade gracefully toward the median
      instead of producing an empty slice.
    * ``median``: 0.5/0.5 on positions (k-1)//2 and k//2 (a single 1.0
      when k is odd).

    An **all-zero mask** (no participants — a statistic over nobody)
    yields all-zero weights, so the combined update degenerates to an
    exact zero vector (global model unchanged) instead of leaking the
    masked-row sentinel. Callers that want a different fallback (the
    round engine falls back to the full participation set) must handle
    the empty gate before calling in.
    """
    if mode not in MODES:
        raise ValueError(f"mode must be one of {MODES}, got {mode!r}")
    if mode == "trimmed_mean" and not 0.0 <= trim_fraction < 1.0:
        raise ValueError(f"trim_fraction in [0, 1), got {trim_fraction}")
    m = mask.astype(jnp.float32)
    c = m.shape[0]
    k_raw = jnp.round(m.sum()).astype(jnp.int32)
    nonempty = (k_raw > 0).astype(jnp.float32)
    k = jnp.maximum(k_raw, 1)
    idx = jnp.arange(c, dtype=jnp.int32)
    if mode == "median":
        lo, hi = (k - 1) // 2, k // 2
        w = 0.5 * (idx == lo) + 0.5 * (idx == hi)
        return (w * nonempty).astype(jnp.float32)
    t = jnp.floor(trim_fraction * k).astype(jnp.int32)
    t = jnp.minimum(t, (k - 1) // 2)          # always keep >= 1 value
    keep = k - 2 * t
    w = jnp.where((idx >= t) & (idx < k - t), 1.0 / keep, 0.0)
    return (w * nonempty).astype(jnp.float32)


def _network_combine(x: jnp.ndarray, mask: jnp.ndarray,
                     w_row: jnp.ndarray) -> jnp.ndarray:
    """XLA odd-even network: same schedule as the kernel, full-M rows."""
    c = x.shape[0]
    xm = jnp.where(mask.astype(jnp.float32)[:, None] > 0.0,
                   x.astype(jnp.float32), _MASKED_SENTINEL)
    rows = _sort_rows([xm[i] for i in range(c)], c)
    w = w_row.astype(jnp.float32)
    acc = rows[0] * w[0]
    for i in range(1, c):
        acc = acc + rows[i] * w[i]
    return acc.astype(x.dtype)


def robust_combine(x: jnp.ndarray, *, mask: jnp.ndarray = None,
                   mode: str = "trimmed_mean", trim_fraction: float = 0.2,
                   impl: str = "auto", block_m: int = 4096,
                   interpret: bool = False) -> jnp.ndarray:
    """x [C, M] client updates -> [M] per-coordinate robust combine.

    ``mask`` [C] (optional): clients with ``mask <= 0`` are excluded from
    the order statistic entirely. Pads M up to a block multiple for the
    Pallas path as needed.
    """
    C, M = x.shape
    if mask is None:
        mask = jnp.ones((C,), jnp.float32)
    w_row = row_select_weights(mask, mode=mode, trim_fraction=trim_fraction)
    if impl == "auto":
        impl = "pallas" if jax.default_backend() == "tpu" else "network"
    if impl == "sort":
        return robust_combine_ref(x, mask, w_row)
    if impl == "network":
        return _network_combine(x, mask, w_row)
    if impl != "pallas":
        raise ValueError(
            f"impl must be 'auto'|'pallas'|'network'|'sort', got {impl!r}")
    bm = min(block_m, max(M, 1))
    pad = (-M) % bm
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad)))
    out = robust_combine_pallas(x, mask, w_row, block_m=bm,
                                interpret=interpret)
    return out[:M]
