"""Pallas TPU kernel for per-coordinate robust aggregation (trimmed mean /
median over the client axis).

The server holds C client updates stacked as ``[C, M]`` (flattened params)
and needs an *order statistic* per coordinate — the defence evaluated by
the poisoning literature — instead of a weighted sum. Grid is 1-D over
``M // block_m``; each step streams a ``[C, block_m]`` tile through VMEM
and sorts the C rows on the VPU with a fixed-C **Batcher odd-even
mergesort network**: ``O(C log^2 C)`` compare-exchanges (63 at C=16, 191
at C=32), each a single ``minimum``/``maximum`` row op. That is a
handful of VPU cycles per element, so the kernel stays
memory-bandwidth-bound like the ``weighted_aggregate`` reduction — the
cheaper odd-even *transposition* schedule (C^2/2 exchanges) measurably
falls off the roofline already at C=16.

Masked clients (``mask[c] == 0``) are pushed past every finite value
before the sort, so they land in the tail rows of the sorted stack; the
caller encodes *which order statistics to keep* as a ``[C]`` row-weight
vector over sorted positions (``ops.row_select_weights``) and the kernel
finishes with one weighted reduction of the sorted rows.
"""
from __future__ import annotations

import functools
from typing import List, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Larger than any finite fp32 update coordinate, small enough that
# 0 * _MASKED_SENTINEL == 0 stays exact (never inf, so no 0*inf NaNs).
_MASKED_SENTINEL = 3.0e38


def oddeven_merge_pairs(c: int) -> List[Tuple[int, int]]:
    """Compare-exchange schedule of Batcher's odd-even mergesort.

    Sorts any ``c`` rows with ``O(c log^2 c)`` comparators (the arbitrary-n
    iterative form, validated against the 0-1 principle in the tests). The
    schedule is static Python, so both the Pallas kernel and the XLA
    fallback unroll it at trace time.
    """
    pairs = []
    p = 1
    while p < c:
        k = p
        while k >= 1:
            for j in range(k % p, c - k, 2 * k):
                for i in range(min(k, c - j - k)):
                    if (i + j) // (2 * p) == (i + j + k) // (2 * p):
                        pairs.append((i + j, i + j + k))
            k //= 2
        p *= 2
    return pairs


def _sort_rows(rows: List[jnp.ndarray], c: int) -> List[jnp.ndarray]:
    """Sorting network over a list of c row vectors (any trailing shape);
    shared by the Pallas kernel ([1, block_m] rows) and the XLA fallback
    ([M] rows) so the two paths cannot diverge."""
    for i, j in oddeven_merge_pairs(c):
        a, b = rows[i], rows[j]
        rows[i] = jnp.minimum(a, b)
        rows[j] = jnp.maximum(a, b)
    return rows


def _robust_kernel(mask_ref, wrow_ref, x_ref, o_ref):
    c = x_ref.shape[0]
    x = x_ref[...].astype(jnp.float32)            # [C, block_m]
    mask = mask_ref[...]                          # [C, 1]
    x = jnp.where(mask > 0.0, x, _MASKED_SENTINEL)
    rows = _sort_rows([x[i:i + 1] for i in range(c)], c)
    w = wrow_ref[...]                             # [C, 1] sorted-position wts
    acc = rows[0] * w[0:1]
    for i in range(1, c):
        acc = acc + rows[i] * w[i:i + 1]
    o_ref[...] = acc.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_m", "interpret"))
def robust_combine_pallas(x: jnp.ndarray, mask: jnp.ndarray,
                          w_row: jnp.ndarray, *, block_m: int = 4096,
                          interpret: bool = False) -> jnp.ndarray:
    """x [C, M] (M % block_m == 0); mask [C]; w_row [C] -> [M].

    ``w_row`` weighs *sorted positions* (ascending, masked rows last) —
    the trimmed-mean / median selection computed by the caller.
    """
    C, M = x.shape
    block_m = min(block_m, M)
    assert M % block_m == 0, (M, block_m)
    out = pl.pallas_call(
        _robust_kernel,
        grid=(M // block_m,),
        in_specs=[
            pl.BlockSpec((C, 1), lambda mi: (0, 0)),
            pl.BlockSpec((C, 1), lambda mi: (0, 0)),
            pl.BlockSpec((C, block_m), lambda mi: (0, mi)),
        ],
        out_specs=pl.BlockSpec((1, block_m), lambda mi: (0, mi)),
        out_shape=jax.ShapeDtypeStruct((1, M), x.dtype),
        interpret=interpret,
    )(mask.astype(jnp.float32).reshape(C, 1),
      w_row.astype(jnp.float32).reshape(C, 1), x)
    return out[0]
