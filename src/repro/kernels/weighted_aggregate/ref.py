"""Oracle for the FedTest server aggregation: out = sum_c w_c * x_c."""
from __future__ import annotations

import jax.numpy as jnp


def weighted_aggregate_ref(x: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    """x [C, M]; w [C] -> [M], fp32 accumulation, cast back to x.dtype."""
    acc = jnp.einsum("c,cm->m", w.astype(jnp.float32),
                     x.astype(jnp.float32))
    return acc.astype(x.dtype)
