"""Pallas TPU kernel for the FedTest server's score-weighted model reduction.

The server holds C client models stacked as ``[C, M]`` (flattened params)
and reduces them with score weights. Grid is 1-D over ``M // block_m``;
each step streams a ``[C, block_m]`` tile through VMEM and reduces it on
the VPU with fp32 accumulation. For C ~ 20 clients and bf16 models this is
bandwidth-bound — the tile shape keeps the working set
``C * block_m * itemsize`` well inside VMEM while using full 128-lane rows.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _wagg_kernel(w_ref, x_ref, o_ref):
    x = x_ref[...].astype(jnp.float32)        # [C, block_m]
    w = w_ref[...].astype(jnp.float32)        # [C, 1]
    o_ref[...] = jnp.sum(x * w, axis=0, keepdims=True).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_m", "interpret"))
def weighted_aggregate_pallas(x: jnp.ndarray, w: jnp.ndarray, *,
                              block_m: int = 4096,
                              interpret: bool = False) -> jnp.ndarray:
    """x [C, M] (M % block_m == 0); w [C] -> [M]."""
    C, M = x.shape
    block_m = min(block_m, M)
    assert M % block_m == 0, (M, block_m)
    out = pl.pallas_call(
        _wagg_kernel,
        grid=(M // block_m,),
        in_specs=[
            pl.BlockSpec((C, 1), lambda mi: (0, 0)),
            pl.BlockSpec((C, block_m), lambda mi: (0, mi)),
        ],
        out_specs=pl.BlockSpec((1, block_m), lambda mi: (0, mi)),
        out_shape=jax.ShapeDtypeStruct((1, M), x.dtype),
        interpret=interpret,
    )(w.reshape(C, 1), x)
    return out[0]
