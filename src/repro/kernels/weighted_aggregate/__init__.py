from repro.kernels.weighted_aggregate.ops import (
    weighted_aggregate, aggregate_pytree)

__all__ = ["weighted_aggregate", "aggregate_pytree"]
