"""Public weighted-aggregation ops (array- and pytree-level)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.weighted_aggregate.kernel import weighted_aggregate_pallas
from repro.kernels.weighted_aggregate.ref import weighted_aggregate_ref


def weighted_aggregate(x: jnp.ndarray, w: jnp.ndarray, *,
                       impl: str = "auto", block_m: int = 4096,
                       interpret: bool = False) -> jnp.ndarray:
    """x [C, M]; w [C] -> [M]. Pads M up to a block multiple as needed."""
    if impl == "auto":
        impl = "pallas" if jax.default_backend() == "tpu" else "naive"
    if impl == "naive":
        return weighted_aggregate_ref(x, w)
    C, M = x.shape
    bm = min(block_m, max(M, 1))
    pad = (-M) % bm
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad)))
    out = weighted_aggregate_pallas(x, w, block_m=bm, interpret=interpret)
    return out[:M]


def aggregate_pytree(stacked, w, *, impl: str = "auto",
                     interpret: bool = False):
    """Score-weighted reduction of a client-stacked pytree.

    ``stacked`` leaves carry a leading client axis [C, ...]; returns the
    aggregated pytree without that axis. This is the device-side form of
    the FedTest server step (Algorithm 1, line 14).
    """
    def _leaf(x):
        C = x.shape[0]
        flat = x.reshape(C, -1)
        return weighted_aggregate(flat, w, impl=impl,
                                  interpret=interpret).reshape(x.shape[1:])
    return jax.tree_util.tree_map(_leaf, stacked)
