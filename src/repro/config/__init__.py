"""Typed configuration system for the repro framework.

Four config families compose a run:

* :class:`ModelConfig`   — architecture hyper-parameters (one per assigned arch).
* :class:`FedConfig`     — the paper's federated-learning knobs (FedTest).
* :class:`TrainConfig`   — optimizer / schedule / step counts.
* :class:`MeshConfig`    — device-mesh shape and axis names.

plus :class:`InputShape`, the four assigned workload shapes.
"""
from repro.config.base import (
    FedConfig,
    InputShape,
    MeshConfig,
    ModelConfig,
    TrainConfig,
    INPUT_SHAPES,
    reduce_for_smoke,
)

__all__ = [
    "ModelConfig",
    "FedConfig",
    "TrainConfig",
    "MeshConfig",
    "InputShape",
    "INPUT_SHAPES",
    "reduce_for_smoke",
]
