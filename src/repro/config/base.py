"""Core config dataclasses.

Everything is a frozen dataclass so configs are hashable and safe to close
over in jitted functions. ``ModelConfig`` covers all six architecture
families via optional fields; family-specific validation lives in
``__post_init__``.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Mapping, Optional, Tuple


def _require(cond: bool, msg: str) -> None:
    if not cond:
        raise ValueError(msg)


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Architecture hyper-parameters.

    Families:
      * ``dense``  — decoder-only transformer (GQA, optional qk-norm / qkv-bias).
      * ``moe``    — decoder-only with per-layer top-k mixture-of-experts FFN.
      * ``ssm``    — attention-free Mamba2 (SSD) stack.
      * ``hybrid`` — Jamba-style Mamba+attention interleave with periodic MoE.
      * ``encdec`` — Whisper-style encoder-decoder (audio frontend stubbed).
      * ``vlm``    — decoder-only consuming stubbed patch embeddings + text.
      * ``cnn``    — the paper's own 3-conv/2-fc CIFAR classifier.
      * ``mlp``    — the paper's MNIST fully-connected classifier.
    """

    name: str
    family: str
    num_layers: int
    d_model: int
    num_heads: int = 0
    num_kv_heads: int = 0
    head_dim: int = 0
    d_ff: int = 0
    vocab_size: int = 0

    # --- attention details -------------------------------------------------
    qk_norm: bool = False
    qkv_bias: bool = False
    rope_theta: float = 1_000_000.0
    sliding_window: Optional[int] = None  # None = full causal attention
    max_position: int = 131_072

    # --- mixture of experts -------------------------------------------------
    num_experts: int = 0
    num_experts_per_tok: int = 0
    moe_every: int = 1          # a layer uses MoE FFN iff layer_idx % moe_every == moe_offset
    moe_offset: int = 0
    router_aux_coef: float = 0.01

    # --- state-space (Mamba2 / SSD) ------------------------------------------
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_conv_width: int = 4
    ssm_chunk: int = 256
    ssm_ngroups: int = 1

    # --- hybrid interleave (Jamba) -------------------------------------------
    attn_every: int = 0         # attention layer iff layer_idx % attn_every == attn_offset
    attn_offset: int = 0

    # --- encoder-decoder (Whisper) -------------------------------------------
    encoder_layers: int = 0
    encoder_seq: int = 0        # fixed 1500 mel-frame positions for whisper
    decoder_max_position: int = 0

    # --- modality frontend stub ----------------------------------------------
    frontend: Optional[str] = None  # 'audio' | 'vision' | None
    num_patches: int = 0            # vlm: image patch embeddings per sample

    # --- cnn (paper's model) ---------------------------------------------------
    image_size: int = 0
    image_channels: int = 0
    cnn_channels: Tuple[int, ...] = ()
    cnn_hidden: int = 0
    num_classes: int = 0

    # --- mlp (paper's MNIST model) ---------------------------------------------
    mlp_hidden: Tuple[int, ...] = ()

    # --- numerics / misc -------------------------------------------------------
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    dtype: str = "bfloat16"
    source: str = ""            # citation for the config (paper / model card)

    def __post_init__(self) -> None:
        _require(self.family in
                 ("dense", "moe", "ssm", "hybrid", "encdec", "vlm", "cnn",
                  "mlp"),
                 f"unknown family {self.family!r}")
        if self.family == "mlp":
            _require(len(self.mlp_hidden) > 0 and self.num_classes > 0
                     and self.image_size > 0,
                     f"{self.name}: mlp needs mlp_hidden, num_classes "
                     "and image_size")
        if self.family in ("dense", "moe", "encdec", "vlm", "hybrid"):
            _require(self.num_heads > 0 and self.num_kv_heads > 0,
                     f"{self.name}: attention archs need heads")
            _require(self.num_heads % self.num_kv_heads == 0,
                     f"{self.name}: num_heads must be divisible by num_kv_heads")
        if self.family in ("moe",):
            _require(self.num_experts > 0 and self.num_experts_per_tok > 0,
                     f"{self.name}: moe needs experts")
        if self.family == "ssm":
            _require(self.ssm_state > 0, f"{self.name}: ssm needs state size")
        if self.family == "hybrid":
            _require(self.attn_every > 0, f"{self.name}: hybrid needs attn_every")
        if self.family == "encdec":
            _require(self.encoder_layers > 0 and self.encoder_seq > 0,
                     f"{self.name}: encdec needs encoder dims")

    # ------------------------------------------------------------------ helpers
    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def has_moe(self) -> bool:
        return self.num_experts > 0

    @property
    def d_inner(self) -> int:
        """Mamba2 inner width."""
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim if self.ssm_state else 0

    def uses_attention(self, layer_idx: int) -> bool:
        if self.family == "ssm":
            return False
        if self.family == "hybrid":
            return layer_idx % self.attn_every == self.attn_offset
        return True

    def uses_moe(self, layer_idx: int) -> bool:
        if not self.has_moe:
            return False
        return layer_idx % self.moe_every == self.moe_offset

    def supports_long_context(self) -> bool:
        """True if the arch can serve a 524k-token KV without quadratic attn.

        SSM is trivially sub-quadratic; hybrid bounds attention; dense/moe/vlm
        run only via the sliding-window variant (applied by the launcher);
        encdec (whisper) cannot — its decoder has a hard 448-position ceiling.
        """
        return self.family != "encdec"

    def replace(self, **kw: Any) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    def param_count(self) -> int:
        """Analytic parameter count (exact for our implementation)."""
        from repro.models.params import count_params_analytic
        return count_params_analytic(self)

    def active_param_count(self) -> int:
        from repro.models.params import count_params_analytic
        return count_params_analytic(self, active_only=True)


@dataclasses.dataclass(frozen=True)
class InputShape:
    """One assigned workload shape."""
    name: str
    seq_len: int
    global_batch: int
    kind: str  # 'train' | 'prefill' | 'decode'

    def __post_init__(self) -> None:
        _require(self.kind in ("train", "prefill", "decode"), self.kind)


INPUT_SHAPES: Mapping[str, InputShape] = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}


def _freeze_kwargs(kw: Any) -> Tuple[Tuple[str, Any], ...]:
    """Normalise a strategy-kwargs mapping to a hashable sorted tuple."""
    if kw is None:
        return ()
    items = kw.items() if isinstance(kw, Mapping) else tuple(kw)
    out = []
    for k, v in sorted(items):
        if isinstance(v, list):
            v = tuple(v)
        out.append((str(k), v))
    return tuple(out)


@dataclasses.dataclass(frozen=True)
class FedConfig:
    """The paper's knobs (Sec. III, Algorithm 1).

    ``aggregator`` / ``attack`` / ``selector`` / ``coalition`` /
    ``fault`` are **registry names** resolved against
    :mod:`repro.strategies` (``AGGREGATORS`` / ``ATTACKS`` /
    ``SELECTORS`` / ``COALITIONS`` / ``FAULTS``); the ``*_kwargs``
    mappings are forwarded to the strategy constructor (stored as
    sorted tuples so the config stays frozen and hashable).

    ``fault`` names a per-round client-failure model (DESIGN.md §9):
    its survival mask is ANDed into the participation mask after
    selection, so a dropped client contributes zero weight, its score
    freezes, and its tester report row is masked — the exact
    non-sampled semantics, on every exchange backend.

    ``coalition`` names a coordinated multi-client adversary
    (DESIGN.md §7): ``coalition_size`` members (placed via
    ``coalition_kwargs``, same placement vocabulary as attacks) mount a
    coordinated model attack and/or rewrite their tester reports. The
    members are counted as malicious by the ``malicious_weight`` metric
    in union with the independent ``attack``'s set.
    """

    num_users: int = 20            # N
    num_testers: int = 5           # K, reselected every round (Alg.1 l.16)
    num_malicious: int = 0         # M
    rounds: int = 100              # n, max global iterations
    local_steps: int = 20          # SGD steps per user per round
    score_power: float = 4.0       # accuracy raised to this power (Sec. V-B)
    power_warmup_rounds: int = 2   # rounds at power=1 first (Sec. V-B idea)
    score_decay: float = 0.5       # weighted moving average: s <- (1-d)*a^p + d*s
    aggregator: str = "fedtest"    # repro.strategies.AGGREGATORS name
    aggregator_kwargs: Any = ()    # extra ctor kwargs for the aggregator
    attack: str = "random_weights"  # repro.strategies.ATTACKS name
    attack_kwargs: Any = ()        # e.g. placement='first', indices=(1, 3)
    attack_scale: float = 1.0
    selector: str = "rotating"     # repro.strategies.SELECTORS name
    selector_kwargs: Any = ()
    coalition: str = "none"        # repro.strategies.COALITIONS name
    coalition_kwargs: Any = ()     # e.g. boost_to=0.9, placement='first'
    coalition_size: int = 0        # coordinated members (DESIGN.md §7)
    fault: str = "none"            # repro.strategies.FAULTS name (§9)
    fault_kwargs: Any = ()         # e.g. deadline=2.0, placement='first'
    fault_rate: float = 0.1        # default drop rate offered to faults
    lying_testers: int = 0          # testers reporting fake accuracies (Sec. V-C)
    server_test_fraction: float = 0.1  # accuracy_based baseline's server test set
    participation: float = 1.0     # R/N; paper sets R = N
    crosstest_impl: str = "batched"  # cross-testing dispatch (DESIGN.md §10)
    compressor: str = "identity"   # repro.strategies.COMPRESSORS name (§12)
    compressor_kwargs: Any = ()    # e.g. k=0.05 (topk), chunk=256 (int8)
    # population tier (DESIGN.md §11): per-round cohort slot capacity.
    # 0 = dense (every backend materialises all N models); C > 0 runs
    # the round on the C sampled clients' gathered models only.
    cohort: int = 0
    seed: int = 0

    def __post_init__(self) -> None:
        _require(0 < self.num_testers <= self.num_users,
                 "need 0 < K <= N")
        _require(0 <= self.cohort <= self.num_users,
                 f"cohort={self.cohort} must be in [0, "
                 f"num_users={self.num_users}] (C > N gathers clients "
                 "that do not exist)")
        if 0 < self.cohort < self.num_users:
            _require(self.participation < 1.0,
                     "cohort < num_users requires participation < 1.0 "
                     "(with everyone sampled, cohort truncation would "
                     "bias toward low client indices); set "
                     "participation ≈ cohort/num_users")
        _require(self.num_malicious < self.num_users, "M < N")
        _require(self.coalition_size < self.num_users,
                 "coalition_size < N")
        _require(0.0 <= self.fault_rate < 1.0,
                 "fault_rate in [0, 1)")
        _require(self.crosstest_impl in ("batched", "reference"),
                 f"crosstest_impl must be 'batched'|'reference', "
                 f"got {self.crosstest_impl!r}")
        for f in ("aggregator_kwargs", "attack_kwargs", "selector_kwargs",
                  "coalition_kwargs", "fault_kwargs", "compressor_kwargs"):
            object.__setattr__(self, f, _freeze_kwargs(getattr(self, f)))
        # Validate names against the registries (KeyError lists the
        # registered names). Lazy import: repro.strategies never imports
        # repro.config, so this cannot cycle.
        from repro.strategies import (
            AGGREGATORS, ATTACKS, COALITIONS, COMPRESSORS, FAULTS,
            SELECTORS)
        AGGREGATORS.get(self.aggregator)
        ATTACKS.get(self.attack)
        SELECTORS.get(self.selector)
        COALITIONS.get(self.coalition)
        FAULTS.get(self.fault)
        COMPRESSORS.get(self.compressor)
        # a named coalition with no members — or members with no named
        # coalition — would silently deactivate: runs (and CI
        # suppression gates) would measure no adversary. Membership may
        # come from coalition_size or from coalition_kwargs size= /
        # indices=; all three forms get the same bounds checks.
        if self.coalition != "none":
            kw = dict(self.coalition_kwargs)
            idx = kw.get("indices") or ()
            members = (self.coalition_size or int(kw.get("size") or 0)
                       or len(idx))
            _require(members > 0,
                     f"coalition {self.coalition!r} needs members: set "
                     "coalition_size > 0 or pass size=/indices= in "
                     "coalition_kwargs")
            _require(members < self.num_users,
                     "coalition members < N")
            _require(all(0 <= int(i) < self.num_users for i in idx),
                     f"coalition indices {tuple(idx)} out of range for "
                     f"num_users={self.num_users}")
        else:
            _require(self.coalition_size == 0,
                     "coalition_size > 0 but coalition='none' — name "
                     "the coalition (e.g. coalition='mutual_boost')")

    def strategy_kwargs(self, field: str) -> dict:
        """``aggregator`` | ``attack`` | ``selector`` | ``coalition`` |
        ``fault`` kwargs as a dict."""
        return dict(getattr(self, field + "_kwargs"))


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    optimizer: str = "adamw"       # 'sgd' | 'momentum' | 'adam' | 'adamw'
    lr: float = 3e-4
    weight_decay: float = 0.1
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    momentum: float = 0.9
    schedule: str = "cosine"       # 'constant' | 'cosine' | 'linear_warmup_cosine'
    warmup_steps: int = 100
    total_steps: int = 1_000
    grad_clip: float = 1.0
    batch_size: int = 32
    remat: bool = True             # activation checkpointing over layer scan
    seed: int = 0


@dataclasses.dataclass(frozen=True)
class MeshConfig:
    shape: Tuple[int, ...] = (16, 16)
    axes: Tuple[str, ...] = ("data", "model")

    def __post_init__(self) -> None:
        _require(len(self.shape) == len(self.axes), "shape/axes mismatch")

    @property
    def num_devices(self) -> int:
        n = 1
        for s in self.shape:
            n *= s
        return n


def reduce_for_smoke(cfg: ModelConfig) -> ModelConfig:
    """Reduced variant of the same family for CPU smoke tests.

    Mandated bounds: <=2 layers, d_model <= 512, <= 4 experts.
    """
    kw: dict = dict(
        name=cfg.name + "-smoke",
        num_layers=min(cfg.num_layers, 2),
        d_model=min(cfg.d_model, 256),
        vocab_size=min(cfg.vocab_size, 512) if cfg.vocab_size else 0,
        max_position=4096,
    )
    if cfg.num_heads:
        heads = min(cfg.num_heads, 4)
        kv = min(cfg.num_kv_heads, heads)
        while heads % kv:
            kv -= 1
        kw.update(num_heads=heads, num_kv_heads=kv, head_dim=32)
    if cfg.d_ff:
        kw.update(d_ff=min(cfg.d_ff, 512))
    if cfg.num_experts:
        kw.update(num_experts=min(cfg.num_experts, 4),
                  num_experts_per_tok=min(cfg.num_experts_per_tok, 2))
    if cfg.ssm_state:
        kw.update(ssm_state=min(cfg.ssm_state, 16), ssm_head_dim=32,
                  ssm_chunk=32)
    if cfg.family == "hybrid":
        # keep one attention layer in the 2-layer smoke stack
        kw.update(attn_every=2, attn_offset=1, moe_every=cfg.moe_every)
    if cfg.family == "encdec":
        kw.update(encoder_layers=min(cfg.encoder_layers, 2), encoder_seq=64,
                  decoder_max_position=128)
    if cfg.family == "vlm":
        kw.update(num_patches=min(cfg.num_patches, 16))
    if cfg.family == "cnn":
        kw.update(cnn_channels=tuple(min(c, 16) for c in cfg.cnn_channels),
                  cnn_hidden=min(cfg.cnn_hidden, 64))
    if cfg.family == "mlp":
        kw.update(mlp_hidden=tuple(min(h, 64) for h in cfg.mlp_hidden))
    return cfg.replace(**kw)
