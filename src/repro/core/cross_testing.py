"""Cross-testing (the heart of FedTest, Fig. 3b) — and its fast path.

Each selected tester evaluates *every* client's model on the tester's own
local held-out data: K×N model evaluations per round, the dominant
per-round cost of the whole scheme. This module owns the three pieces of
the fast path (DESIGN.md §10):

* **dispatch model** — two interchangeable implementations of the
  ``[K, N]`` accuracy matrix: ``reference`` evaluates one client model at
  a time inside the tester vmap (N eval dispatches per tester — the
  parity oracle), ``batched`` stacks the client parameters and runs one
  fused ``[N, batch]`` forward per tester (a single dispatch via vmap
  over the model axis). The two are pinned **bitwise identical** by
  ``tests/test_crosstest.py`` on every backend.
* **kernel routing** — LM eval always goes through the
  ``flash_attention`` / ``ssd_scan`` kernel ops, never the naive
  reference oracle, even when the model handle was built with
  ``attn_impl='naive'`` for serving tests
  (:func:`kernel_route_model`).
* **eval-batch caching** — per-tester eval batches are reusable across
  rounds; the gather indices are a pure function of the run key and the
  round-schedule *bucket* (:func:`eval_batch_indices`), so the cache key
  is derived, never stashed — FL001 key discipline holds and the cached
  path is bit-insensitive to hit/miss.

On a pod the same computation is the ring schedule in
``repro.core.engine.backends.ring_cross_test`` (see DESIGN.md §3), whose
fast path overlaps each hop's eval with the next ``ppermute``.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Tuple

import jax
import jax.numpy as jnp

# the eval-batch stream's fold_in constant — disjoint from the RoundKeys
# constants (5/6/7 in repro.core.engine.program.round_keys) so adding the
# stream cannot perturb any committed trajectory
EVAL_BATCH_STREAM = 11

CROSSTEST_IMPLS = ("batched", "reference")


# ------------------------------------------------------------- kernel routing
def resolve_eval_impl() -> str:
    """The concrete kernel backend eval routes through on this host."""
    return "pallas" if jax.default_backend() == "tpu" else "xla"


def kernel_route_model(model):
    """Route a model handle's eval forward through the kernel ops.

    ``auto`` resolves to the host's kernel backend and ``naive`` — the
    small-shape test oracle — is upgraded to it: the K×N eval path must
    hit ``flash_attention`` / ``ssd_scan``, not the quadratic reference.
    Explicit ``pallas`` / ``xla`` choices are respected. CNN/MLP
    families have no kernel path and pass through unchanged.
    ``tests/test_crosstest_kernels.py`` pins the routed eval against the
    naive forward to tolerance on the bench shapes.
    """
    if model.cfg.family in ("cnn", "mlp"):
        return model
    impl = resolve_eval_impl()
    attn = impl if model.attn_impl in ("auto", "naive") else model.attn_impl
    ssm = impl if model.ssm_impl in ("auto", "naive") else model.ssm_impl
    if (attn, ssm) == (model.attn_impl, model.ssm_impl):
        return model
    return dataclasses.replace(model, attn_impl=attn, ssm_impl=ssm)


def make_eval_fn(model, *, route_kernels: bool = True) -> Callable:
    """Returns eval_fn(params, bx, by) -> accuracy in [0, 1].

    ``route_kernels`` (the default) sends LM forwards through the kernel
    ops via :func:`kernel_route_model`; pass ``False`` to evaluate with
    the model's own impl choices (the naive-oracle side of the
    kernel-consistency tests).
    """
    if route_kernels:
        model = kernel_route_model(model)
    if model.cfg.family in ("cnn", "mlp"):
        def eval_fn(params, bx, by):
            logits, _ = model.forward_train(params, {"images": bx})
            return jnp.mean((jnp.argmax(logits, -1) == by)
                            .astype(jnp.float32))
    else:
        def eval_fn(params, bx, by):
            logits, _ = model.forward_train(params, {"tokens": bx})
            valid = by != -1
            correct = (jnp.argmax(logits, -1) == by) & valid
            return correct.sum() / jnp.maximum(valid.sum(), 1)
    return eval_fn


# ------------------------------------------------------------ dispatch model
def cross_test_batched(eval_fn, stacked_params, tester_x, tester_y
                       ) -> jnp.ndarray:
    """One fused [N, batch] eval dispatch per tester (the fast path)."""
    def one_tester(bx, by):
        return jax.vmap(lambda p: eval_fn(p, bx, by))(stacked_params)

    return jax.vmap(one_tester)(tester_x, tester_y)     # [K, N]


def cross_test_reference(eval_fn, stacked_params, tester_x, tester_y
                         ) -> jnp.ndarray:
    """One eval dispatch per (tester, client) pair — the parity oracle.

    N sequential evals inside the tester vmap, exactly the per-client
    loop the batched path replaces; kept as the bitwise reference the
    fast path is pinned against (and as the honest baseline
    ``benchmarks/bench_crosstest.py`` measures speedups over).
    """
    n = jax.tree_util.tree_leaves(stacked_params)[0].shape[0]

    def one_tester(bx, by):
        accs = [eval_fn(jax.tree_util.tree_map(lambda l, c=c: l[c],
                                               stacked_params), bx, by)
                for c in range(n)]
        return jnp.stack(accs)

    return jax.vmap(one_tester)(tester_x, tester_y)     # [K, N]


def cross_test_accuracies(eval_fn, stacked_params, tester_x, tester_y,
                          *, impl: str = "batched") -> jnp.ndarray:
    """Accuracy matrix A[k, c] = acc of client c's model on tester k's data.

    stacked_params: leaves [N, ...]; tester_x/y: [K, batch, ...].
    ``impl`` picks the dispatch model (``batched`` | ``reference``,
    DESIGN.md §10); both produce the bitwise-identical matrix.
    """
    if impl == "batched":
        return cross_test_batched(eval_fn, stacked_params,
                                  tester_x, tester_y)
    if impl == "reference":
        return cross_test_reference(eval_fn, stacked_params,
                                    tester_x, tester_y)
    raise ValueError(
        f"crosstest impl must be one of {CROSSTEST_IMPLS}, got {impl!r}")


def cross_test_tiled(eval_fn, stacked_params, tester_x, tester_y, *,
                     block: int = 0, impl: str = "batched") -> jnp.ndarray:
    """Stream the accuracy matrix in [K, block] tiles over the model axis.

    The population tier's entry point (DESIGN.md §11): instead of one
    fused [K, C] dispatch whose live eval activations scale with the
    whole cohort, ``lax.map`` walks the cohort in blocks of ``block``
    models, bounding peak activation memory at [K, block] while the
    parameter stack stays gathered once. ``block <= 0`` (or >= C)
    degenerates to the single fused call. A ragged tail is wrap-padded
    with leading cohort rows and sliced off after the map — padding rows
    are recomputed work, never values that reach the caller, so the
    result is bitwise identical to the untiled matrix for every block
    size (pinned by ``tests/test_population.py``).
    """
    c = jax.tree_util.tree_leaves(stacked_params)[0].shape[0]
    if block <= 0 or block >= c:
        return cross_test_accuracies(eval_fn, stacked_params,
                                     tester_x, tester_y, impl=impl)
    num_blocks = -(-c // block)
    pad = num_blocks * block - c

    def to_blocks(t):
        if pad:
            t = jnp.concatenate([t, t[:pad]], axis=0)
        return t.reshape((num_blocks, block) + t.shape[1:])

    blocks = jax.tree_util.tree_map(to_blocks, stacked_params)
    acc = jax.lax.map(
        lambda blk: cross_test_accuracies(eval_fn, blk, tester_x,
                                          tester_y, impl=impl),
        blocks)                                         # [nb, K, block]
    k = acc.shape[1]
    return jnp.moveaxis(acc, 0, 1).reshape(k, num_blocks * block)[:, :c]


# --------------------------------------------------------- eval-batch caching
def eval_batch_indices(run_key, counts: jnp.ndarray, eval_batch: int,
                       bucket) -> jnp.ndarray:
    """[N, eval_batch] per-tester gather indices for one schedule bucket.

    The key is re-derived on every call — ``fold_in(run_key,
    EVAL_BATCH_STREAM)`` then ``fold_in(·, bucket)`` — so the indices are
    a pure function of (run key, bucket): rounds in the same bucket share
    a batch (the cache hit), a new bucket resamples (the miss), and no
    key is ever stashed across rounds (FL001, DESIGN.md §10). Works
    traced (bucket may be a scalar array inside jit/scan) and on the
    host.
    """
    k = jax.random.fold_in(
        jax.random.fold_in(run_key, EVAL_BATCH_STREAM), bucket)
    u = jax.random.uniform(k, (counts.shape[0], eval_batch))
    return (u * counts[:, None]).astype(jnp.int32)


def gather_eval_batches(xs, ys, idx) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Materialise [N, eval_batch, ...] tester batches from stacked data."""
    tx = jax.vmap(lambda x, i: x[i])(xs, idx)
    ty = jax.vmap(lambda y, i: y[i])(ys, idx)
    return tx, ty


def sampled_eval_batches(run_key, test_data, eval_batch: int, round_idx,
                         resample_every: int
                         ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """The round's tester eval batches under the resampling schedule.

    Pure function of (run key, round bucket) — the in-trace path the
    drivers use; :class:`EvalBatchCache` wraps it for host loops and must
    return bitwise-identical arrays (pinned by the hit/miss-insensitivity
    property test).
    """
    idx = eval_batch_indices(run_key, test_data.counts, eval_batch,
                             round_idx // resample_every)
    return gather_eval_batches(test_data.xs, test_data.ys, idx)


class EvalBatchCache:
    """Cross-round cache of materialised tester eval batches (host loops).

    The pod drivers and benches feed rounds from a host loop, so the
    per-tester eval batches would be regathered every round; this cache
    reuses them while the round stays in the same schedule bucket
    (``round_idx // resample_every``). The bucket — not a PRNG key — is
    the cache key: on a miss the indices are re-derived from the run key
    via :func:`eval_batch_indices`, so a cold cache, a warm cache and the
    in-trace :func:`sampled_eval_batches` all produce the same arrays.
    """

    def __init__(self, resample_every: int):
        if resample_every < 1:
            raise ValueError("resample_every must be >= 1")
        self.resample_every = resample_every
        self.hits = 0
        self.misses = 0
        self._bucket = None
        self._batches = None

    def get(self, run_key, test_data, eval_batch: int, round_idx: int
            ) -> Tuple[jnp.ndarray, jnp.ndarray]:
        bucket = int(round_idx) // self.resample_every
        if self._bucket == bucket and self._batches is not None:
            self.hits += 1
            return self._batches
        self.misses += 1
        idx = eval_batch_indices(run_key, test_data.counts, eval_batch,
                                 bucket)
        self._bucket = bucket
        self._batches = gather_eval_batches(test_data.xs, test_data.ys,
                                            idx)
        return self._batches
