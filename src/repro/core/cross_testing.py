"""Cross-testing (the heart of FedTest, Fig. 3b).

Each selected tester evaluates *every* client's model on the tester's own
local held-out data. On the local exchange backend this is a ``vmap``
over the client axis of the stacked params (N models evaluated in one
XLA call per tester); on a pod the same computation is the ring schedule
in ``repro.core.engine.backends.ring_cross_test`` (see DESIGN.md §3).
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp


def make_eval_fn(model) -> Callable:
    """Returns eval_fn(params, bx, by) -> accuracy in [0, 1]."""
    if model.cfg.family == "cnn":
        def eval_fn(params, bx, by):
            logits, _ = model.forward_train(params, {"images": bx})
            return jnp.mean((jnp.argmax(logits, -1) == by)
                            .astype(jnp.float32))
    else:
        def eval_fn(params, bx, by):
            logits, _ = model.forward_train(params, {"tokens": bx})
            valid = by != -1
            correct = (jnp.argmax(logits, -1) == by) & valid
            return correct.sum() / jnp.maximum(valid.sum(), 1)
    return eval_fn


def cross_test_accuracies(eval_fn, stacked_params, tester_x, tester_y
                          ) -> jnp.ndarray:
    """Accuracy matrix A[k, c] = acc of client c's model on tester k's data.

    stacked_params: leaves [N, ...]; tester_x/y: [K, batch, ...].
    """
    def one_tester(bx, by):
        return jax.vmap(lambda p: eval_fn(p, bx, by))(stacked_params)

    return jax.vmap(one_tester)(tester_x, tester_y)     # [K, N]
