"""Distributed FedTest round via ``shard_map`` — one client per mesh slice.

This is the datacenter mapping of the paper's D2D protocol (DESIGN.md §3):

* the ``clients`` mesh axis carries one FL client per slice;
* "users send models to testers over orthogonal RBs" becomes a
  **ring schedule**: ``lax.ppermute`` rotates the stacked client models
  around the ring, and at each of the N-1 hops every device evaluates the
  visiting model on its *own* local test shard. Each hop uses disjoint
  neighbour links — the ICI analogue of interference-free RB slots — and
  the memory high-water mark is 2x one model instead of the N-x blow-up of
  an all-gather (the paper-faithful alternative, kept for comparison in
  EXPERIMENTS.md §Perf);
* "testers upload accuracies, server aggregates" becomes a masked
  ``psum``: tester rows of the accuracy matrix are averaged, scores are
  updated replicated, and the weighted model aggregation is a single
  ``psum`` of ``w_c * params_c``.

The full adversarial scenario matrix runs here at strategy parity with
the single-host engine (DESIGN.md §2):

* **attacks** — ``FedConfig.attack`` resolves against the ``ATTACKS``
  registry exactly like the single-host round; the malicious placement
  mask is static host data, each device checks its own position along the
  clients axis and corrupts its locally trained params *before* the
  ring / all-gather exchange (``Attack.apply_local``), and the per-round
  attack key is folded from the round counter and the device index;
* **client sampling** — ``FedConfig.participation < 1`` masks the
  training scan (non-sampled slots revert to the global model), the
  tester ``psum`` (non-sampled testers report nothing) and the
  aggregation ``psum`` (weights renormalised over the sampled subset,
  with the same fallback formula as the single-host engine).

The same ``FedConfig`` drives this and the single-host engine; the
parity contract is exercised by ``tests/test_pod_parity.py``.
"""
from __future__ import annotations

import functools
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.config import FedConfig, TrainConfig
from repro.core.cross_testing import make_eval_fn
from repro.core.round import renormalize_over_subset
from repro.core.scoring import ScoreState
from repro.optim import make_optimizer
from repro.strategies.base import Aggregator, RoundContext, uses_combine
from repro.utils.pytree import tree_add_vector


def _shard_map(f, *, mesh, in_specs, out_specs):
    """jax.shard_map across jax versions (experimental pre-0.5)."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=False)
    from jax.experimental.shard_map import shard_map as sm
    return sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
              check_rep=False)


def _resolve_aggregator(fed: FedConfig, aggregator) -> Aggregator:
    if isinstance(aggregator, Aggregator):
        return aggregator
    from repro.core.round import aggregator_defaults
    from repro.strategies import AGGREGATORS
    return AGGREGATORS.build(aggregator or fed.aggregator,
                             fed.strategy_kwargs("aggregator"),
                             aggregator_defaults(fed))


def _resolve_attack(fed: FedConfig):
    from repro.strategies import ATTACKS
    return ATTACKS.build(fed.attack, fed.strategy_kwargs("attack"),
                         dict(num_malicious=fed.num_malicious,
                              scale=fed.attack_scale))


def _strategy_weights(agg: Aggregator, acc, scores, params, global_params,
                      axis: str, num_clients: int, counts=None,
                      part_mask=None, seed: int = 0, server_eval=None,
                      updates=None):
    """Replicated weight computation shared by both exchange schedules.

    ``acc`` is the already-combined [N] accuracy vector (tester reports
    masked by participation upstream), so the context carries it as a
    single-tester matrix with ``report_mask=None``. Aggregators that need
    client updates (krum / trimmed_mean / median, and every ``combine()``
    aggregator) trigger one all-gather of the *flattened* update — the
    same N-x memory cost as the all-gather exchange, so prefer those
    aggregators with ``--exchange allgather``, whose round body derives
    the matrix from the models it already gathered and passes it in as
    ``updates`` so nothing is gathered twice (EXPERIMENTS.md §Perf).
    ``counts`` are the per-client sample counts (static host data, closed
    over); without them fedavg degenerates to uniform weighting.

    The per-round strategy key is folded from ``PRNGKey(seed)`` and the
    round counter carried in ``ScoreState.rounds_seen``, so randomised
    strategies see a fresh key every round (and the same key for the same
    round across the ring / all-gather schedules).

    When ``part_mask`` is given ([N], replicated), non-sampled clients
    are forced to exactly zero weight and the simplex is renormalised
    over the sampled subset — the identical formula (including the
    uniform-over-subset fallback) as the single-host engine, so the two
    paths cannot drift on sampled-subset renormalisation.

    Returns ``(weights, new_scores, ctx)`` — the context carries the
    all-gathered ``[N, D]`` updates (replicated) for the combine path.
    """
    if updates is None and (agg.needs_updates or uses_combine(agg)):
        flat = jnp.concatenate([
            (p.astype(jnp.float32) - g.astype(jnp.float32)).ravel()
            for p, g in zip(jax.tree_util.tree_leaves(params),
                            jax.tree_util.tree_leaves(global_params))])
        updates = jax.lax.all_gather(flat, axis)             # [N, D]
    if counts is None:
        counts = jnp.ones((num_clients,), jnp.float32)
    ctx = RoundContext(
        acc_matrix=acc[None, :],
        tester_ids=jnp.arange(num_clients),
        scores=scores,
        counts=jnp.asarray(counts, jnp.float32),
        round_idx=scores.rounds_seen,
        key=jax.random.fold_in(jax.random.PRNGKey(seed),
                               scores.rounds_seen),
        updates=updates,
        server_eval=server_eval,
        participation=part_mask)
    new_scores = agg.update_scores(ctx)
    ctx = ctx._replace(scores=new_scores)
    weights = agg.weights(ctx)
    if part_mask is not None:
        weights = renormalize_over_subset(weights, part_mask)
    # stateless aggregators leave ScoreState untouched; advance the round
    # counter for them so ctx.round_idx / ctx.key vary across rounds
    if type(agg).update_scores is Aggregator.update_scores:
        new_scores = new_scores._replace(
            rounds_seen=new_scores.rounds_seen + 1)
    return weights, new_scores, ctx


def _aggregate_on_pod(agg: Aggregator, ctx: RoundContext, params,
                      global_params, weights, axis: str):
    """New global model: weighted psum, or the combine fast path.

    Combine aggregators run on the all-gathered ``[N, D]`` update matrix,
    which is replicated across the client axis after the gather — every
    device computes the identical combined update (the reduction-host
    computation, replicated), so the result needs no further collective.
    Participation reaches them through ``ctx.participation``: the client
    gate of the order statistic always intersects the sampled subset.
    """
    if uses_combine(agg):
        return tree_add_vector(global_params, agg.combine(ctx, ctx.updates))
    my_w = weights[jax.lax.axis_index(axis)]
    return jax.tree_util.tree_map(
        lambda x: jax.lax.psum(
            (x.astype(jnp.float32) * my_w), axis).astype(x.dtype),
        params)


def ring_cross_test(eval_fn, my_params, tx, ty, axis: str, num_clients: int):
    """Every device measures every client's model on its own test data.

    Returns acc_row [num_clients]: accuracy of client c's model on *my*
    local test shard. Implemented as N-1 ``ppermute`` hops around the ring
    (visiting models), so peak memory is own + visiting model.
    """
    my_idx = jax.lax.axis_index(axis)
    perm = [(i, (i + 1) % num_clients) for i in range(num_clients)]

    def hop(step, carry):
        visiting, acc_row = carry
        # who owned `visiting` before `step` hops reached me?
        owner = (my_idx - step) % num_clients
        acc = eval_fn(visiting, tx, ty)
        acc_row = acc_row.at[owner].set(acc)
        visiting = jax.lax.ppermute(visiting, axis, perm)
        return (visiting, acc_row)

    acc_row = jnp.zeros((num_clients,), jnp.float32)
    (_, acc_row) = jax.lax.fori_loop(
        0, num_clients, hop, (my_params, acc_row))
    return acc_row


def _make_pod_round(model, fed: FedConfig, train_cfg: TrainConfig, mesh,
                    axis: str, aggregator, counts, server_data,
                    exchange: str):
    """Shared builder behind both exchange schedules (DESIGN.md §3).

    Everything strategy-shaped is resolved here, pre-trace, exactly like
    the single-host engine: the jitted round closes over the aggregator,
    the attack (with its static malicious placement mask) and the static
    participation flag, so one scenario compiles to one fused program.
    """
    opt = make_optimizer(train_cfg)
    eval_fn = make_eval_fn(model)
    num_clients = mesh.shape[axis]
    agg = _resolve_aggregator(fed, aggregator)
    if agg.needs_server_eval and server_data is None:
        raise ValueError(
            f"aggregator {agg.name!r} needs a server-side eval set; pass "
            "server_data=(sx, sy) to the round builder (e.g. the "
            "FederatedDataset's server_x/server_y)")
    if fed.lying_testers:
        raise ValueError(
            "lying_testers (Sec. V-C) is single-host-only (DESIGN.md §3); "
            "the pod round would silently run honest testers — use "
            "repro.launch.train for that ablation")
    attack = _resolve_attack(fed)
    mal_idx = attack.malicious_indices(num_clients)
    mal_mask = attack.malicious_mask(num_clients)        # [N] static
    use_participation = fed.participation < 1.0
    seed = fed.seed

    def batchify(bx, by):
        if model.cfg.family == "cnn":
            return {"images": bx, "labels": by}
        return {"tokens": bx, "labels": by}

    def local_train(params, bx, by):
        opt_state = opt.init(params)

        def step(carry, xb_yb):
            params, opt_state = carry
            xb, yb = xb_yb
            (loss, _), grads = jax.value_and_grad(
                model.loss, has_aux=True)(params, batchify(xb, yb))
            params, opt_state = opt.update(grads, opt_state, params)
            return (params, opt_state), loss

        (params, _), losses = jax.lax.scan(step, (params, opt_state),
                                           (bx, by))
        return params, jnp.mean(losses)

    @functools.partial(
        _shard_map, mesh=mesh,
        in_specs=(P(), P(), P(axis), P(axis), P(axis), P(axis), P(axis),
                  P(axis)),
        out_specs=(P(), P(), P()))
    def round_fn(global_params, scores: ScoreState, bx, by, tx, ty,
                 tester_mask, part_mask):
        # shard_map gives per-client leading axes of size 1 — drop them
        bx, by = bx[0], by[0]
        tx, ty = tx[0], ty[0]
        my_mask = tester_mask[0]
        my_part = part_mask[0]
        my_idx = jax.lax.axis_index(axis)

        # 1-2. local training on my shard
        params, local_loss = local_train(global_params, bx, by)

        # 3. adversaries act per shard, before any model leaves the
        # device: the malicious placement mask is static, the per-round
        # key is folded from the round counter and my mesh position
        if mal_idx:
            atk_key = jax.random.fold_in(
                jax.random.fold_in(jax.random.PRNGKey(seed),
                                   scores.rounds_seen), my_idx)
            params = attack.apply_local(atk_key, params, global_params,
                                        my_idx, num_clients)

        # 3b. client sampling: a non-sampled client transmits nothing —
        # its slot reverts to the global model (so the ring circulates
        # the stale copy), it reports no accuracies (tester mask zeroed)
        # and it will get exactly zero aggregation weight below
        if use_participation:
            params = jax.tree_util.tree_map(
                lambda p, g: jnp.where(my_part > 0, p, g.astype(p.dtype)),
                params, global_params)
            my_mask = my_mask * my_part
            full_part = jax.lax.all_gather(my_part, axis)    # [N] replicated
        else:
            full_part = None

        # 4. cross-testing exchange (only tester rows count)
        pre_updates = None
        if exchange == "ring":
            acc_row = ring_cross_test(eval_fn, params, tx, ty, axis,
                                      num_clients)
        else:
            everyone = jax.tree_util.tree_map(
                lambda x: jax.lax.all_gather(x, axis), params)   # [N, ...]
            acc_row = jax.vmap(
                lambda p: eval_fn(p, tx, ty))(everyone)          # [N]
            if agg.needs_updates or uses_combine(agg):
                # the update matrix is derivable from the models already
                # gathered for cross-testing — don't all-gather twice
                pre_updates = jnp.concatenate([
                    (e.astype(jnp.float32)
                     - g.astype(jnp.float32)[None]).reshape(num_clients, -1)
                    for e, g in zip(
                        jax.tree_util.tree_leaves(everyone),
                        jax.tree_util.tree_leaves(global_params))], axis=1)

        # 5. combine tester reports: mean over the K *reporting* testers
        # via masked psum (participation already folded into the mask)
        k_total = jax.lax.psum(my_mask, axis)
        acc = jax.lax.psum(acc_row * my_mask, axis) / jnp.maximum(k_total, 1)

        # server-side eval (accuracy_based baseline): every device scores
        # its own model on the replicated server set, one all-gather
        # turns the scalars into the [N] vector the closure promises
        server_eval = None
        if agg.needs_server_eval:
            sx, sy = server_data
            my_server_acc = eval_fn(params, jnp.asarray(sx),
                                    jnp.asarray(sy))
            server_eval = (lambda a=my_server_acc:
                           jax.lax.all_gather(a, axis))

        # 6. replicated strategy weights (reports already masked)
        weights, new_scores, ctx = _strategy_weights(
            agg, acc, scores, params, global_params, axis, num_clients,
            counts=counts, part_mask=full_part, seed=seed,
            server_eval=server_eval, updates=pre_updates)

        # 7. weighted psum over the client axis, or the combine fast path
        new_global = _aggregate_on_pod(agg, ctx, params, global_params,
                                       weights, axis)

        # the malicious index set comes from the attack strategy, so the
        # metric stays correct for any placement of the attackers
        mal_w = (jnp.sum(weights * mal_mask) if mal_idx
                 else jnp.zeros(()))
        if use_participation:
            n_part = jax.lax.psum(my_part, axis)
            loss_mean = (jax.lax.psum(local_loss * my_part, axis)
                         / jnp.maximum(n_part, 1))
            rate = n_part / num_clients
        else:
            loss_mean = jax.lax.pmean(local_loss, axis)
            rate = jnp.ones(())
        metrics = {"local_loss": loss_mean,
                   "acc_mean": jnp.mean(acc),
                   "weights": weights,
                   "malicious_weight": mal_w,
                   "participation_rate": rate}
        return new_global, new_scores, metrics

    return round_fn


def make_distributed_round(model, fed: FedConfig, train_cfg: TrainConfig,
                           mesh, axis: str = "clients", aggregator=None,
                           counts=None, server_data=None):
    """Builds the jitted shard_map FedTest round for ``mesh[axis]`` clients.

    ``aggregator`` — registry name or :class:`Aggregator` instance;
    defaults to ``fed.aggregator``. The attack comes from ``fed.attack``
    (+ ``num_malicious`` / ``attack_scale`` / ``attack_kwargs``) and the
    participation fraction from ``fed.participation`` — both resolved
    once here, pre-trace, exactly like the single-host engine.
    ``server_data`` — optional ``(sx, sy)`` replicated server eval set,
    required only by ``needs_server_eval`` aggregators.

    Inputs (per call):
      global_params — replicated pytree
      scores        — ScoreState (replicated)
      bx, by        — [N, steps, batch, ...] client-sharded training batches
      tx, ty        — [N, eval_batch, ...]   client-sharded local test data
      tester_mask   — [N] f32 (K ones; rotating selection by the caller)
      part_mask     — [N] f32 participation mask (all ones when
                      ``fed.participation == 1``; see
                      ``repro.core.round.participation_mask``)

    Returns (new_global (replicated), new_scores, metrics).
    """
    return _make_pod_round(model, fed, train_cfg, mesh, axis, aggregator,
                           counts, server_data, exchange="ring")


def make_allgather_round(model, fed: FedConfig, train_cfg: TrainConfig,
                         mesh, axis: str = "clients", aggregator=None,
                         counts=None, server_data=None):
    """Paper-faithful alternative: all-gather every model to every tester
    (each user receives all models at once, as in the RB broadcast).
    Memory: N x model per device — kept as the EXPERIMENTS.md §Perf
    comparison baseline. Same signature and strategy surface as
    :func:`make_distributed_round`.
    """
    return _make_pod_round(model, fed, train_cfg, mesh, axis, aggregator,
                           counts, server_data, exchange="allgather")
