"""Distributed FedTest round via ``shard_map`` — one client per mesh slice.

This is the datacenter mapping of the paper's D2D protocol (DESIGN.md §3):

* the ``clients`` mesh axis carries one FL client per slice;
* "users send models to testers over orthogonal RBs" becomes a
  **ring schedule**: ``lax.ppermute`` rotates the stacked client models
  around the ring, and at each of the N-1 hops every device evaluates the
  visiting model on its *own* local test shard. Each hop uses disjoint
  neighbour links — the ICI analogue of interference-free RB slots — and
  the memory high-water mark is 2x one model instead of the N-x blow-up of
  an all-gather (the paper-faithful alternative, kept for comparison in
  EXPERIMENTS.md §Perf);
* "testers upload accuracies, server aggregates" becomes a masked
  ``psum``: tester rows of the accuracy matrix are averaged, scores are
  updated replicated, and the weighted model aggregation is a single
  ``psum`` of ``w_c * params_c``.

The same ``FedConfig`` drives this and the single-host engine.
"""
from __future__ import annotations

import functools
from typing import Any, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.config import FedConfig, TrainConfig
from repro.core.cross_testing import make_eval_fn
from repro.core.scoring import ScoreState
from repro.optim import make_optimizer
from repro.strategies.base import Aggregator, RoundContext, uses_combine
from repro.utils.pytree import tree_add_vector


def _shard_map(f, *, mesh, in_specs, out_specs):
    """jax.shard_map across jax versions (experimental pre-0.5)."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=False)
    from jax.experimental.shard_map import shard_map as sm
    return sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
              check_rep=False)


def _resolve_aggregator(fed: FedConfig, aggregator) -> Aggregator:
    if fed.participation < 1.0:
        raise ValueError(
            "participation < 1 (client sampling) is only implemented on "
            "the single-host engine; the pod path trains every client — "
            "see ROADMAP open items")
    if isinstance(aggregator, Aggregator):
        agg = aggregator
    else:
        from repro.core.round import aggregator_defaults
        from repro.strategies import AGGREGATORS
        agg = AGGREGATORS.build(aggregator or fed.aggregator,
                                fed.strategy_kwargs("aggregator"),
                                aggregator_defaults(fed))
    if agg.needs_server_eval:
        raise ValueError(
            f"aggregator {agg.name!r} needs a server-side eval set, which "
            "the pod path does not carry; use the single-host engine")
    return agg


def _strategy_weights(agg: Aggregator, acc, scores, params, global_params,
                      axis: str, num_clients: int, counts=None):
    """Replicated weight computation shared by both exchange schedules.

    ``acc`` is the already-combined [N] accuracy vector, so the context
    carries it as a single-tester matrix. Aggregators that need client
    updates (krum / trimmed_mean / median, and every ``combine()``
    aggregator) trigger one all-gather of the *flattened* update — the
    same N-x memory cost as the all-gather exchange, so prefer those
    aggregators with ``--exchange allgather``. ``counts`` are the
    per-client sample counts (static host data, closed over); without
    them fedavg degenerates to uniform weighting.

    Returns ``(weights, new_scores, ctx)`` — the context carries the
    all-gathered ``[N, D]`` updates (replicated) for the combine path.
    """
    updates = None
    if agg.needs_updates or uses_combine(agg):
        flat = jnp.concatenate([
            (p.astype(jnp.float32) - g.astype(jnp.float32)).ravel()
            for p, g in zip(jax.tree_util.tree_leaves(params),
                            jax.tree_util.tree_leaves(global_params))])
        updates = jax.lax.all_gather(flat, axis)             # [N, D]
    if counts is None:
        counts = jnp.ones((num_clients,), jnp.float32)
    ctx = RoundContext(
        acc_matrix=acc[None, :],
        tester_ids=jnp.arange(num_clients),
        scores=scores,
        counts=jnp.asarray(counts, jnp.float32),
        round_idx=scores.rounds_seen,
        key=jax.random.fold_in(jax.random.PRNGKey(0), scores.rounds_seen),
        updates=updates)
    new_scores = agg.update_scores(ctx)
    ctx = ctx._replace(scores=new_scores)
    weights = agg.weights(ctx)
    # stateless aggregators leave ScoreState untouched; advance the round
    # counter for them so ctx.round_idx / ctx.key vary across rounds
    if type(agg).update_scores is Aggregator.update_scores:
        new_scores = new_scores._replace(
            rounds_seen=new_scores.rounds_seen + 1)
    return weights, new_scores, ctx


def _aggregate_on_pod(agg: Aggregator, ctx: RoundContext, params,
                      global_params, weights, axis: str):
    """New global model: weighted psum, or the combine fast path.

    Combine aggregators run on the all-gathered ``[N, D]`` update matrix,
    which is replicated across the client axis after the gather — every
    device computes the identical combined update (the reduction-host
    computation, replicated), so the result needs no further collective.
    """
    if uses_combine(agg):
        return tree_add_vector(global_params, agg.combine(ctx, ctx.updates))
    my_w = weights[jax.lax.axis_index(axis)]
    return jax.tree_util.tree_map(
        lambda x: jax.lax.psum(
            (x.astype(jnp.float32) * my_w), axis).astype(x.dtype),
        params)


def ring_cross_test(eval_fn, my_params, tx, ty, axis: str, num_clients: int):
    """Every device measures every client's model on its own test data.

    Returns acc_row [num_clients]: accuracy of client c's model on *my*
    local test shard. Implemented as N-1 ``ppermute`` hops around the ring
    (visiting models), so peak memory is own + visiting model.
    """
    my_idx = jax.lax.axis_index(axis)
    perm = [(i, (i + 1) % num_clients) for i in range(num_clients)]

    def hop(step, carry):
        visiting, acc_row = carry
        # who owned `visiting` before `step` hops reached me?
        owner = (my_idx - step) % num_clients
        acc = eval_fn(visiting, tx, ty)
        acc_row = acc_row.at[owner].set(acc)
        visiting = jax.lax.ppermute(visiting, axis, perm)
        return (visiting, acc_row)

    acc_row = jnp.zeros((num_clients,), jnp.float32)
    (_, acc_row) = jax.lax.fori_loop(
        0, num_clients, hop, (my_params, acc_row))
    return acc_row


def make_distributed_round(model, fed: FedConfig, train_cfg: TrainConfig,
                           mesh, axis: str = "clients", aggregator=None,
                           counts=None):
    """Builds the jitted shard_map FedTest round for ``mesh[axis]`` clients.

    ``aggregator`` — registry name or :class:`Aggregator` instance;
    defaults to ``fed.aggregator``. Resolved once here, pre-trace, exactly
    like the single-host engine.

    Inputs (per call):
      global_params — replicated pytree
      scores        — ScoreState (replicated)
      round_idx     — i32
      bx, by        — [N, steps, batch, ...] client-sharded training batches
      tx, ty        — [N, eval_batch, ...]   client-sharded local test data
      tester_mask   — [N] f32 (K ones; rotating selection by the caller)

    Returns (new_global (replicated), new_scores, metrics).
    """
    opt = make_optimizer(train_cfg)
    eval_fn = make_eval_fn(model)
    num_clients = mesh.shape[axis]
    agg = _resolve_aggregator(fed, aggregator)

    def batchify(bx, by):
        if model.cfg.family == "cnn":
            return {"images": bx, "labels": by}
        return {"tokens": bx, "labels": by}

    def local_train(params, bx, by):
        opt_state = opt.init(params)

        def step(carry, xb_yb):
            params, opt_state = carry
            xb, yb = xb_yb
            (loss, _), grads = jax.value_and_grad(
                model.loss, has_aux=True)(params, batchify(xb, yb))
            params, opt_state = opt.update(grads, opt_state, params)
            return (params, opt_state), loss

        (params, _), losses = jax.lax.scan(step, (params, opt_state),
                                           (bx, by))
        return params, jnp.mean(losses)

    @functools.partial(
        _shard_map, mesh=mesh,
        in_specs=(P(), P(), P(axis), P(axis), P(axis), P(axis), P(axis)),
        out_specs=(P(), P(), P()))
    def round_fn(global_params, scores: ScoreState, bx, by, tx, ty,
                 tester_mask):
        # shard_map gives per-client leading axes of size 1 — drop them
        bx, by = bx[0], by[0]
        tx, ty = tx[0], ty[0]
        my_mask = tester_mask[0]

        # 1-2. local training on my shard
        params, local_loss = local_train(global_params, bx, by)

        # 4. ring cross-testing (only tester rows count)
        acc_row = ring_cross_test(eval_fn, params, tx, ty, axis,
                                  num_clients)

        # combine tester reports: mean over the K testers via masked psum
        k_total = jax.lax.psum(my_mask, axis)
        acc = jax.lax.psum(acc_row * my_mask, axis) / jnp.maximum(k_total, 1)

        # 6. replicated strategy weights (reports already masked)
        weights, new_scores, ctx = _strategy_weights(
            agg, acc, scores, params, global_params, axis, num_clients,
            counts=counts)

        # 7. weighted psum over the client axis, or the combine fast path
        new_global = _aggregate_on_pod(agg, ctx, params, global_params,
                                       weights, axis)

        metrics = {"local_loss": jax.lax.pmean(local_loss, axis),
                   "acc_mean": jnp.mean(acc),
                   "weights": weights}
        return new_global, new_scores, metrics

    return round_fn


def make_allgather_round(model, fed: FedConfig, train_cfg: TrainConfig,
                         mesh, axis: str = "clients", aggregator=None,
                         counts=None):
    """Paper-faithful alternative: all-gather every model to every tester
    (each user receives all models at once, as in the RB broadcast).
    Memory: N x model per device — kept as the §Perf comparison baseline.
    """
    opt = make_optimizer(train_cfg)
    eval_fn = make_eval_fn(model)
    num_clients = mesh.shape[axis]
    agg = _resolve_aggregator(fed, aggregator)

    def batchify(bx, by):
        if model.cfg.family == "cnn":
            return {"images": bx, "labels": by}
        return {"tokens": bx, "labels": by}

    @functools.partial(
        _shard_map, mesh=mesh,
        in_specs=(P(), P(), P(axis), P(axis), P(axis), P(axis), P(axis)),
        out_specs=(P(), P(), P()))
    def round_fn(global_params, scores, bx, by, tx, ty, tester_mask):
        bx, by = bx[0], by[0]
        tx, ty = tx[0], ty[0]
        my_mask = tester_mask[0]

        opt_state = opt.init(global_params)

        def step(carry, xb_yb):
            params, opt_state = carry
            xb, yb = xb_yb
            (loss, _), grads = jax.value_and_grad(
                model.loss, has_aux=True)(params, batchify(xb, yb))
            params, opt_state = opt.update(grads, opt_state, params)
            return (params, opt_state), loss

        (params, _), losses = jax.lax.scan(
            step, (global_params, opt_state), (bx, by))

        everyone = jax.tree_util.tree_map(
            lambda x: jax.lax.all_gather(x, axis), params)   # [N, ...]
        acc_row = jax.vmap(
            lambda p: eval_fn(p, tx, ty))(everyone)          # [N]

        k_total = jax.lax.psum(my_mask, axis)
        acc = jax.lax.psum(acc_row * my_mask, axis) / jnp.maximum(k_total, 1)
        weights, new_scores, ctx = _strategy_weights(
            agg, acc, scores, params, global_params, axis, num_clients,
            counts=counts)
        new_global = _aggregate_on_pod(agg, ctx, params, global_params,
                                       weights, axis)
        metrics = {"local_loss": jax.lax.pmean(jnp.mean(losses), axis),
                   "acc_mean": jnp.mean(acc),
                   "weights": weights}
        return new_global, new_scores, metrics

    return round_fn
