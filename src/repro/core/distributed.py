"""Compatibility shim — the pod round moved to :mod:`repro.core.engine`.

The ``shard_map`` FedTest round (one client per mesh slice; DESIGN.md §3)
used to be implemented here, duplicating the single-host engine's
strategy / participation / renormalisation logic. The ring and
all-gather exchanges are now
:class:`~repro.core.engine.backends.RingBackend` /
:class:`~repro.core.engine.backends.AllgatherBackend` driving the one
shared :class:`~repro.core.engine.program.RoundProgram`; this module
keeps the historical import surface for the pod round builders.
"""
from repro.core.engine.backends import (
    make_allgather_round, make_distributed_round, make_pod_round,
    ring_cross_test)

__all__ = [
    "make_allgather_round", "make_distributed_round", "make_pod_round",
    "ring_cross_test",
]
