"""Distributed FedTest round via ``shard_map`` — one client per mesh slice.

This is the datacenter mapping of the paper's D2D protocol (DESIGN.md §3):

* the ``clients`` mesh axis carries one FL client per slice;
* "users send models to testers over orthogonal RBs" becomes a
  **ring schedule**: ``lax.ppermute`` rotates the stacked client models
  around the ring, and at each of the N-1 hops every device evaluates the
  visiting model on its *own* local test shard. Each hop uses disjoint
  neighbour links — the ICI analogue of interference-free RB slots — and
  the memory high-water mark is 2x one model instead of the N-x blow-up of
  an all-gather (the paper-faithful alternative, kept for comparison in
  EXPERIMENTS.md §Perf);
* "testers upload accuracies, server aggregates" becomes a masked
  ``psum``: tester rows of the accuracy matrix are averaged, scores are
  updated replicated, and the weighted model aggregation is a single
  ``psum`` of ``w_c * params_c``.

The same ``FedConfig`` drives this and the single-host engine.
"""
from __future__ import annotations

import functools
from typing import Any, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.config import FedConfig, TrainConfig
from repro.core.cross_testing import make_eval_fn
from repro.core.scoring import ScoreState, score_weights, update_scores
from repro.optim import make_optimizer


def ring_cross_test(eval_fn, my_params, tx, ty, axis: str, num_clients: int):
    """Every device measures every client's model on its own test data.

    Returns acc_row [num_clients]: accuracy of client c's model on *my*
    local test shard. Implemented as N-1 ``ppermute`` hops around the ring
    (visiting models), so peak memory is own + visiting model.
    """
    my_idx = jax.lax.axis_index(axis)
    perm = [(i, (i + 1) % num_clients) for i in range(num_clients)]

    def hop(step, carry):
        visiting, acc_row = carry
        # who owned `visiting` before `step` hops reached me?
        owner = (my_idx - step) % num_clients
        acc = eval_fn(visiting, tx, ty)
        acc_row = acc_row.at[owner].set(acc)
        visiting = jax.lax.ppermute(visiting, axis, perm)
        return (visiting, acc_row)

    acc_row = jnp.zeros((num_clients,), jnp.float32)
    (_, acc_row) = jax.lax.fori_loop(
        0, num_clients, hop, (my_params, acc_row))
    return acc_row


def make_distributed_round(model, fed: FedConfig, train_cfg: TrainConfig,
                           mesh, axis: str = "clients"):
    """Builds the jitted shard_map FedTest round for ``mesh[axis]`` clients.

    Inputs (per call):
      global_params — replicated pytree
      scores        — ScoreState (replicated)
      round_idx     — i32
      bx, by        — [N, steps, batch, ...] client-sharded training batches
      tx, ty        — [N, eval_batch, ...]   client-sharded local test data
      tester_mask   — [N] f32 (K ones; rotating selection by the caller)

    Returns (new_global (replicated), new_scores, metrics).
    """
    opt = make_optimizer(train_cfg)
    eval_fn = make_eval_fn(model)
    num_clients = mesh.shape[axis]

    def batchify(bx, by):
        if model.cfg.family == "cnn":
            return {"images": bx, "labels": by}
        return {"tokens": bx, "labels": by}

    def local_train(params, bx, by):
        opt_state = opt.init(params)

        def step(carry, xb_yb):
            params, opt_state = carry
            xb, yb = xb_yb
            (loss, _), grads = jax.value_and_grad(
                model.loss, has_aux=True)(params, batchify(xb, yb))
            params, opt_state = opt.update(grads, opt_state, params)
            return (params, opt_state), loss

        (params, _), losses = jax.lax.scan(step, (params, opt_state),
                                           (bx, by))
        return params, jnp.mean(losses)

    @functools.partial(
        jax.shard_map, mesh=mesh,
        in_specs=(P(), P(), P(axis), P(axis), P(axis), P(axis), P(axis)),
        out_specs=(P(), P(), P()),
        check_vma=False)
    def round_fn(global_params, scores: ScoreState, bx, by, tx, ty,
                 tester_mask):
        # shard_map gives per-client leading axes of size 1 — drop them
        bx, by = bx[0], by[0]
        tx, ty = tx[0], ty[0]
        my_mask = tester_mask[0]
        my_idx = jax.lax.axis_index(axis)

        # 1-2. local training on my shard
        params, local_loss = local_train(global_params, bx, by)

        # 4. ring cross-testing (only tester rows count)
        acc_row = ring_cross_test(eval_fn, params, tx, ty, axis,
                                  num_clients)

        # combine tester reports: mean over the K testers via masked psum
        k_total = jax.lax.psum(my_mask, axis)
        acc = jax.lax.psum(acc_row * my_mask, axis) / jnp.maximum(k_total, 1)

        # 6. replicated score update + weights
        tester_ids = jnp.arange(num_clients)   # reports already masked
        new_scores = update_scores(scores, acc[None, :], tester_ids,
                                   power=fed.score_power,
                                   decay=fed.score_decay,
                                   power_warmup_rounds=
                                   fed.power_warmup_rounds)
        weights = score_weights(new_scores)

        # 7. weighted aggregation = one psum over the client axis
        my_w = weights[my_idx]
        new_global = jax.tree_util.tree_map(
            lambda x: jax.lax.psum(
                (x.astype(jnp.float32) * my_w), axis).astype(x.dtype),
            params)

        metrics = {"local_loss": jax.lax.pmean(local_loss, axis),
                   "acc_mean": jnp.mean(acc),
                   "weights": weights}
        return new_global, new_scores, metrics

    return round_fn


def make_allgather_round(model, fed: FedConfig, train_cfg: TrainConfig,
                         mesh, axis: str = "clients"):
    """Paper-faithful alternative: all-gather every model to every tester
    (each user receives all models at once, as in the RB broadcast).
    Memory: N x model per device — kept as the §Perf comparison baseline.
    """
    opt = make_optimizer(train_cfg)
    eval_fn = make_eval_fn(model)
    num_clients = mesh.shape[axis]

    def batchify(bx, by):
        if model.cfg.family == "cnn":
            return {"images": bx, "labels": by}
        return {"tokens": bx, "labels": by}

    @functools.partial(
        jax.shard_map, mesh=mesh,
        in_specs=(P(), P(), P(axis), P(axis), P(axis), P(axis), P(axis)),
        out_specs=(P(), P(), P()),
        check_vma=False)
    def round_fn(global_params, scores, bx, by, tx, ty, tester_mask):
        bx, by = bx[0], by[0]
        tx, ty = tx[0], ty[0]
        my_mask = tester_mask[0]
        my_idx = jax.lax.axis_index(axis)

        opt_state = opt.init(global_params)

        def step(carry, xb_yb):
            params, opt_state = carry
            xb, yb = xb_yb
            (loss, _), grads = jax.value_and_grad(
                model.loss, has_aux=True)(params, batchify(xb, yb))
            params, opt_state = opt.update(grads, opt_state, params)
            return (params, opt_state), loss

        (params, _), losses = jax.lax.scan(
            step, (global_params, opt_state), (bx, by))

        everyone = jax.tree_util.tree_map(
            lambda x: jax.lax.all_gather(x, axis), params)   # [N, ...]
        acc_row = jax.vmap(
            lambda p: eval_fn(p, tx, ty))(everyone)          # [N]

        k_total = jax.lax.psum(my_mask, axis)
        acc = jax.lax.psum(acc_row * my_mask, axis) / jnp.maximum(k_total, 1)
        new_scores = update_scores(scores, acc[None, :],
                                   jnp.arange(num_clients),
                                   power=fed.score_power,
                                   decay=fed.score_decay,
                                   power_warmup_rounds=
                                   fed.power_warmup_rounds)
        weights = score_weights(new_scores)
        new_global = jax.tree_util.tree_map(
            lambda x: jax.lax.psum(
                x.astype(jnp.float32) * weights[my_idx], axis).astype(x.dtype),
            params)
        metrics = {"local_loss": jax.lax.pmean(jnp.mean(losses), axis),
                   "acc_mean": jnp.mean(acc),
                   "weights": weights}
        return new_global, new_scores, metrics

    return round_fn
