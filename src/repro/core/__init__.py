"""FedTest — the paper's contribution (Sec. III, Algorithm 1).

* ``scoring``       — weighted-moving-average accuracy^p scores (Sec. III + V-B).
* ``aggregation``   — FedTest score-weighted aggregation + the two baselines
  the paper compares against (FedAvg, server-side accuracy-based).
* ``cross_testing`` — testers evaluate every client model on their own data.
* ``attacks``       — malicious-user model suite (paper: random weights).
* ``selection``     — rotating tester selection + orthogonal-RB schedule.
* ``engine``        — the unified federated round engine (Algorithm 1):
  one backend-agnostic ``RoundProgram`` (steps 1-7, owned once) behind
  pluggable exchange backends (local vmap / ring / allgather shard_map),
  whose aggregator / attack / tester-selection seams resolve by name
  through the ``repro.strategies`` registries. ``round`` and
  ``distributed`` remain as import shims over it.
"""
from repro.core.scoring import ScoreState, init_scores, update_scores, score_weights
from repro.core.aggregation import (
    fedavg_weights, accuracy_based_weights, aggregate_models)
from repro.core.attacks import apply_attacks, ATTACKS
from repro.core.cross_testing import (
    CROSSTEST_IMPLS, EvalBatchCache, cross_test_accuracies,
    cross_test_batched, cross_test_reference, cross_test_tiled,
    eval_batch_indices, kernel_route_model, make_eval_fn,
    sampled_eval_batches)
from repro.core.selection import select_testers, rb_schedule
from repro.core.engine import (
    FederatedTrainer, PopulationTrainer, RoundState, flat_update_dim,
    init_comp_state, resolve_compressor, resolve_strategies)

__all__ = [
    "ScoreState", "init_scores", "update_scores", "score_weights",
    "fedavg_weights", "accuracy_based_weights", "aggregate_models",
    "apply_attacks", "ATTACKS", "CROSSTEST_IMPLS", "EvalBatchCache",
    "cross_test_accuracies", "cross_test_batched", "cross_test_reference",
    "cross_test_tiled", "eval_batch_indices", "kernel_route_model",
    "make_eval_fn", "sampled_eval_batches",
    "select_testers", "rb_schedule", "FederatedTrainer",
    "PopulationTrainer", "RoundState", "flat_update_dim",
    "init_comp_state", "resolve_compressor", "resolve_strategies",
]
