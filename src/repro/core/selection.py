"""Tester selection + orthogonal resource-block schedule (Sec. III).

Algorithm 1 line 16 re-selects a *different* set of K testers each round.
The paper's collection phase assigns every user an orthogonal resource
block (RB); non-tester users transmit in the first N-K slots (testers
receive + evaluate concurrently, D2D), then testers transmit their model +
measured accuracies in the last K slots. ``rb_schedule`` materialises that
timetable — the simulation uses it for communication-cost accounting, and
it is the wireless analogue of the deterministic ring-permutation schedule
used on the pod (DESIGN.md §3).
"""
from __future__ import annotations

from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np


def select_testers(key, num_users: int, num_testers: int,
                   round_idx: int) -> jnp.ndarray:
    """Rotating K-subset; independent draw per round (Alg. 1 line 16).

    Drawn as ``top_k`` over i.i.d. uniforms — the top-K indices of an
    exchangeable continuous draw are a uniform ordered K-subset without
    replacement, the same distribution as ``permutation(k, N)[:K]``,
    at one PRNG pass + one top-k instead of the multi-pass sort
    ``jax.random.permutation`` runs (~67 ms vs ~1 ms at N = 10⁵ on CPU
    — the population tier's whole round budget,
    ``benchmarks/bench_population.py``).
    """
    k = jax.random.fold_in(key, round_idx)
    u = jax.random.uniform(k, (num_users,))
    _, ids = jax.lax.top_k(u, num_testers)
    return ids.astype(jnp.int32)


def rb_schedule(tester_ids: np.ndarray, num_users: int,
                model_bytes: int, acc_report_bytes: int = 4
                ) -> Dict[str, object]:
    """Orthogonal-RB timetable for one collection phase.

    Returns slot list [(slot_idx, user, payload_bytes, receivers)] plus
    totals. Non-testers transmit first (server + all testers receive);
    testers transmit last (their model + N accuracy scalars).
    """
    testers = set(int(t) for t in np.asarray(tester_ids))
    others = [u for u in range(num_users) if u not in testers]
    slots: List[Dict[str, object]] = []
    for i, u in enumerate(others):
        slots.append({"slot": i, "user": u, "bytes": model_bytes,
                      "receivers": ["server"] + sorted(testers)})
    for j, t in enumerate(sorted(testers)):
        payload = model_bytes + acc_report_bytes * num_users
        slots.append({"slot": len(others) + j, "user": t, "bytes": payload,
                      "receivers": ["server"]})
    uplink = sum(s["bytes"] for s in slots)
    return {"slots": slots, "num_slots": len(slots),
            "uplink_bytes": uplink,
            "broadcast_bytes": model_bytes,           # server -> all users
            "d2d_bytes": model_bytes * len(others) * len(testers)}
