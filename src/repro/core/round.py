"""The FedTest round engine (Algorithm 1).

One fused, jitted round (the step numbering below is the one DESIGN.md §2
documents and the pod path in :mod:`repro.core.distributed` mirrors):

  1.  broadcast the global model to all N users            (line 15 of prev round)
  2.  every user runs ``local_steps`` optimizer steps on its own shard (line 5)
  3.  malicious users swap in attacked models              (Sec. IV)
  4.  K testers evaluate all N models on their own data    (lines 6-9)
  5.  lying testers corrupt their reports                  (Sec. V-C ablation)
  6.  the server computes scores / weights                 (line 13)
  7.  score-weighted aggregation -> new global model       (line 14)

Local training is vectorised across clients with ``vmap`` (client axis =
leading axis of the stacked param pytree) — on a pod the same functions are
driven by ``shard_map`` with the client axis laid over ``data``
(``repro.launch.train``).

Steps 3, 4 and 6 are **pluggable**: the attack, tester-selection policy
and aggregator are looked up by name in :mod:`repro.strategies`
(``FedConfig.attack`` / ``.selector`` / ``.aggregator``) and resolved to
plain Python objects in ``__post_init__`` — *before* tracing — so jit
closes over static callables and one round compiles to one fused program
with no trace-time branching. ``FederatedTrainer.num_traces`` counts
retraces; steady-state training must keep it at 1.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.config import FedConfig, TrainConfig
from repro.core.aggregation import aggregate_models
from repro.core.cross_testing import cross_test_accuracies, make_eval_fn
from repro.core.scoring import ScoreState, init_scores
from repro.data.pipeline import FederatedDataset, sample_client_batches
from repro.optim import make_optimizer
from repro.strategies.base import RoundContext, uses_combine


class RoundState(NamedTuple):
    global_params: Any
    scores: ScoreState
    round_idx: jnp.ndarray
    key: jnp.ndarray


def participation_mask(key, num_users: int, participation: float
                       ) -> jnp.ndarray:
    """Per-round Bernoulli client-sampling mask ``[N]`` (1 = sampled).

    Falls back to everyone in the zero-participant corner so a round is
    always well defined. Both engines (and the pod driver / parity tests)
    share this one formula so the sampled subsets agree for equal keys.
    """
    bern = jax.random.bernoulli(key, participation, (num_users,))
    return jnp.where(jnp.any(bern), bern.astype(jnp.float32),
                     jnp.ones((num_users,), jnp.float32))


def renormalize_over_subset(weights: jnp.ndarray, part_mask: jnp.ndarray
                            ) -> jnp.ndarray:
    """Zero non-participants and renormalise the simplex over the subset.

    If the sampled subset got zero total weight, fall back to uniform
    over it. One formula, shared by both engines, so the sampled-subset
    renormalisation cannot drift between them (the parity test pins the
    resulting zero pattern and sums).
    """
    w = weights * part_mask
    total = jnp.sum(w)
    return jnp.where(total > 1e-12, w / jnp.maximum(total, 1e-12),
                     part_mask / jnp.sum(part_mask))


def aggregator_defaults(fed: FedConfig, use_trust: bool = False
                        ) -> Dict[str, Any]:
    """Engine-derived default kwargs offered to aggregator constructors.

    Each aggregator picks up only the fields its ``__init__`` accepts
    (``Registry.build`` filters by signature): ``fedtest`` takes the
    scoring knobs, ``krum`` takes ``num_byzantine`` (the defender's
    assumed f, defaulted to the scenario's ``num_malicious``), the rest
    need nothing.
    """
    return dict(score_power=fed.score_power,
                score_decay=fed.score_decay,
                power_warmup_rounds=fed.power_warmup_rounds,
                use_trust=use_trust,
                num_byzantine=fed.num_malicious)


def resolve_strategies(fed: FedConfig, use_trust: bool = False):
    """Name -> object resolution for (aggregator, attack, selector)."""
    # package import (not just .base) so the registries are populated
    from repro.strategies import AGGREGATORS, ATTACKS, SELECTORS
    agg = AGGREGATORS.build(fed.aggregator, fed.strategy_kwargs("aggregator"),
                            aggregator_defaults(fed, use_trust))
    atk = ATTACKS.build(fed.attack, fed.strategy_kwargs("attack"),
                        dict(num_malicious=fed.num_malicious,
                             scale=fed.attack_scale))
    sel = SELECTORS.build(fed.selector, fed.strategy_kwargs("selector"))
    return agg, atk, sel


@dataclasses.dataclass
class FederatedTrainer:
    model: Any                      # repro.models.Model
    fed: FedConfig
    train: TrainConfig
    agg_impl: str = "auto"
    eval_batch: int = 256
    use_trust: bool = False
    batch_builder: Optional[Callable] = None   # (bx, by) -> model batch

    def __post_init__(self):
        self.opt = make_optimizer(self.train)
        # strategy resolution happens once, pre-trace: the jitted round
        # closes over these objects as static callables.
        self.aggregator, self.attack, self.selector = resolve_strategies(
            self.fed, self.use_trust)
        # a non-None combine hook routes aggregation through the
        # per-coordinate fast path; both checks are static Python, so the
        # jitted round never branches on them at trace time.
        self._uses_combine = uses_combine(self.aggregator)
        self._needs_updates = (self.aggregator.needs_updates
                               or self._uses_combine)
        self._malicious_idx = self.attack.malicious_indices(
            self.fed.num_users)
        self._malicious_mask = self.attack.malicious_mask(self.fed.num_users)
        self.num_traces = 0
        self._round_fn = jax.jit(self._round)
        self._global_eval = jax.jit(self._global_eval_impl)

    # ------------------------------------------------------------------ init
    def init(self, key) -> RoundState:
        pk, rk = jax.random.split(key)
        params = self.model.init(pk)
        return RoundState(global_params=params,
                          scores=init_scores(self.fed.num_users),
                          round_idx=jnp.zeros((), jnp.int32),
                          key=rk)

    # ------------------------------------------------------------- internals
    def _batch(self, bx, by) -> Dict[str, jnp.ndarray]:
        if self.batch_builder is not None:
            return self.batch_builder(bx, by)
        if self.model.cfg.family == "cnn":
            return {"images": bx, "labels": by}
        return {"tokens": bx, "labels": by}

    def _local_train(self, params, bx, by):
        """One client's local phase: ``local_steps`` optimizer steps."""
        opt_state = self.opt.init(params)

        def step(carry, xb_yb):
            params, opt_state = carry
            xb, yb = xb_yb
            (loss, _), grads = jax.value_and_grad(
                self.model.loss, has_aux=True)(params, self._batch(xb, yb))
            params, opt_state = self.opt.update(grads, opt_state, params)
            return (params, opt_state), loss

        (params, _), losses = jax.lax.scan(step, (params, opt_state),
                                           (bx, by))
        return params, jnp.mean(losses)

    def _flat_updates(self, trained, global_params) -> jnp.ndarray:
        """[N, D] float32 matrix of flattened client updates."""
        def flat(stack, g):
            n = stack.shape[0]
            return (stack.astype(jnp.float32)
                    - g.astype(jnp.float32)[None]).reshape(n, -1)
        parts = jax.tree_util.tree_leaves(
            jax.tree_util.tree_map(flat, trained, global_params))
        return jnp.concatenate(parts, axis=1)

    def _round(self, state: RoundState, data: FederatedDataset
               ) -> Tuple[RoundState, Dict[str, jnp.ndarray]]:
        self.num_traces += 1        # python side-effect: runs per trace only
        fed = self.fed
        key = jax.random.fold_in(state.key, state.round_idx)
        k_batch, k_attack, k_test, k_lie = jax.random.split(key, 4)
        k_agg = jax.random.fold_in(key, 5)
        k_part = jax.random.fold_in(key, 6)

        # 0. client sampling (participation R/N < 1): Bernoulli per client.
        # Non-participants still train under vmap (uniform lockstep, SPMD
        # cannot skip them) but send nothing: their slot reverts to the
        # global model below and they get exactly zero aggregation weight.
        part_mask = None
        if fed.participation < 1.0:
            part_mask = participation_mask(k_part, fed.num_users,
                                           fed.participation)

        # 1-2. broadcast + vectorised local training
        stacked = jax.tree_util.tree_map(
            lambda x: jnp.broadcast_to(x[None], (fed.num_users,) + x.shape),
            state.global_params)
        bx, by = sample_client_batches(k_batch, data.train,
                                       fed.local_steps,
                                       self.train.batch_size)
        trained, local_loss = jax.vmap(self._local_train)(stacked, bx, by)

        # 3. adversaries act (strategy; malicious set can live anywhere)
        trained = self.attack.apply(k_attack, trained, state.global_params)

        # 3b. non-participants transmit nothing this round: whoever
        # evaluates their slot sees the stale global copy, exactly like
        # the pod path's masked training scan (DESIGN.md §3) — attacked
        # or not, an unsampled client's model never leaves the device.
        if part_mask is not None:
            trained = jax.tree_util.tree_map(
                lambda t, g: jnp.where(
                    part_mask.reshape((-1,) + (1,) * (t.ndim - 1)) > 0,
                    t, g[None].astype(t.dtype)),
                trained, state.global_params)

        # 4. selected testers measure accuracies on their own data
        tester_ids = self.selector.select(k_test, fed.num_users,
                                          fed.num_testers, state.round_idx)
        eval_fn = make_eval_fn(self.model)
        tx = data.test.xs[tester_ids, :self.eval_batch]
        ty = data.test.ys[tester_ids, :self.eval_batch]
        acc = cross_test_accuracies(
            lambda p, x, y: eval_fn(p, x, y), trained, tx, ty)   # [K, N]

        # 5. lying testers (Sec. V-C): users with id < lying_testers report
        # uniform random accuracies whenever they are selected to test.
        if fed.lying_testers:
            lies = jax.random.uniform(k_lie, acc.shape)
            liar_rows = (tester_ids < fed.lying_testers)[:, None]
            acc = jnp.where(liar_rows, lies, acc)

        # 6. weights via the aggregation strategy
        server_eval = None
        if self.aggregator.needs_server_eval:
            sx = data.server_x[:self.eval_batch]
            sy = data.server_y[:self.eval_batch]
            server_eval = lambda: jax.vmap(                      # noqa: E731
                lambda p: eval_fn(p, sx, sy))(trained)
        # the [N, D] update matrix is computed at most once per round and
        # shared between ctx.updates consumers and the combine fast path
        updates = (self._flat_updates(trained, state.global_params)
                   if self._needs_updates else None)
        ctx = RoundContext(acc_matrix=acc, tester_ids=tester_ids,
                           scores=state.scores, counts=data.train.counts,
                           round_idx=state.round_idx, key=k_agg,
                           updates=updates, server_eval=server_eval,
                           participation=part_mask,
                           report_mask=(part_mask[tester_ids]
                                        if part_mask is not None else None))
        scores = self.aggregator.update_scores(ctx)
        ctx = ctx._replace(scores=scores)
        weights = self.aggregator.weights(ctx)
        if part_mask is not None:
            weights = renormalize_over_subset(weights, part_mask)

        # 7. aggregation -> new global model: score-weighted sum, or the
        # per-coordinate combine fast path when the aggregator defines it
        combine_fn = ((lambda u: self.aggregator.combine(ctx, u))
                      if self._uses_combine else None)
        new_global = aggregate_models(trained, weights, impl=self.agg_impl,
                                      combine_fn=combine_fn, updates=updates,
                                      global_params=state.global_params)

        # the malicious index set comes from the attack strategy, so the
        # metric stays correct for any placement of the attackers.
        mal_w = (jnp.sum(weights * self._malicious_mask)
                 if self._malicious_idx else jnp.zeros(()))
        # losses of non-participants are discarded work (their training
        # never left the device) — the mean runs over the sampled subset,
        # matching the pod round's masked psum
        metrics = {
            "local_loss": (jnp.sum(local_loss * part_mask)
                           / jnp.maximum(jnp.sum(part_mask), 1)
                           if part_mask is not None
                           else jnp.mean(local_loss)),
            "acc_matrix_mean": jnp.mean(acc),
            "weights": weights,
            "malicious_weight": mal_w,
            "scores": scores.scores,
            "participation_rate": (jnp.mean(part_mask)
                                   if part_mask is not None
                                   else jnp.ones(())),
        }
        new_state = RoundState(global_params=new_global, scores=scores,
                               round_idx=state.round_idx + 1, key=state.key)
        return new_state, metrics

    def _global_eval_impl(self, params, gx, gy):
        eval_fn = make_eval_fn(self.model)
        return eval_fn(params, gx, gy)

    # ------------------------------------------------------------------- API
    def run_round(self, state: RoundState, data: FederatedDataset):
        return self._round_fn(state, data)

    def global_accuracy(self, state: RoundState, data: FederatedDataset,
                        max_samples: int = 2048) -> float:
        return float(self._global_eval(state.global_params,
                                       data.global_x[:max_samples],
                                       data.global_y[:max_samples]))

    def run(self, key, data: FederatedDataset, rounds: Optional[int] = None,
            eval_every: int = 1, verbose: bool = False):
        """Full training loop; returns (final_state, history dict)."""
        rounds = rounds if rounds is not None else self.fed.rounds
        state = self.init(key)
        history = {"round": [], "global_accuracy": [], "local_loss": [],
                   "malicious_weight": []}
        for r in range(rounds):
            state, metrics = self.run_round(state, data)
            if (r + 1) % eval_every == 0 or r == rounds - 1:
                ga = self.global_accuracy(state, data)
                history["round"].append(r + 1)
                history["global_accuracy"].append(ga)
                history["local_loss"].append(float(metrics["local_loss"]))
                history["malicious_weight"].append(
                    float(metrics["malicious_weight"]))
                if verbose:
                    print(f"round {r+1:4d}  acc={ga:.4f}  "
                          f"loss={float(metrics['local_loss']):.4f}  "
                          f"mal_w={float(metrics['malicious_weight']):.4f}")
        if rounds > 1 and self.num_traces > 1:
            raise RuntimeError(
                f"round engine retraced {self.num_traces}x over {rounds} "
                "rounds — strategy resolution must stay pre-trace")
        return state, history
