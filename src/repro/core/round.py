"""Compatibility shim — the round engine moved to :mod:`repro.core.engine`.

The FedTest round (Algorithm 1) used to be implemented here as the
single-host ``vmap`` engine, duplicating the pod path's strategy /
participation / renormalisation logic. Both now share one
backend-agnostic :class:`~repro.core.engine.program.RoundProgram`
(DESIGN.md §2); this module keeps the historical import surface for the
single-host driver only.
"""
from repro.core.engine.driver import FederatedTrainer, RoundState
from repro.core.engine.program import aggregator_defaults, resolve_strategies

__all__ = [
    "FederatedTrainer", "RoundState", "aggregator_defaults",
    "resolve_strategies",
]
