"""The FedTest round engine (Algorithm 1).

One fused, jitted round:

  1.  broadcast the global model to all N users            (line 15 of prev round)
  2.  every user runs ``local_steps`` optimizer steps on its own shard (line 5)
  3.  malicious users swap in attacked models              (Sec. IV)
  4.  K rotating testers evaluate all N models on their own data (lines 6-9)
  5.  lying testers corrupt their reports                  (Sec. V-C ablation)
  6.  the server computes scores / weights                 (line 13)
  7.  score-weighted aggregation -> new global model       (line 14)

Local training is vectorised across clients with ``vmap`` (client axis =
leading axis of the stacked param pytree) — on a pod the same functions are
driven by ``shard_map`` with the client axis laid over ``data``
(``repro.launch.train``).

Baselines (``aggregator=`` in FedConfig): ``fedavg`` weighs by sample
counts; ``accuracy_based`` weighs by accuracy on the *server's* held-out
set (the scheme FedTest improves upon — Fig. 3a).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.config import FedConfig, TrainConfig
from repro.core.aggregation import (
    accuracy_based_weights, aggregate_models, fedavg_weights)
from repro.core.attacks import apply_attacks
from repro.core.cross_testing import cross_test_accuracies, make_eval_fn
from repro.core.scoring import (
    ScoreState, init_scores, score_weights, update_scores,
    update_tester_trust)
from repro.core.selection import select_testers
from repro.data.pipeline import FederatedDataset, sample_client_batches
from repro.optim import make_optimizer


class RoundState(NamedTuple):
    global_params: Any
    scores: ScoreState
    round_idx: jnp.ndarray
    key: jnp.ndarray


@dataclasses.dataclass
class FederatedTrainer:
    model: Any                      # repro.models.Model
    fed: FedConfig
    train: TrainConfig
    agg_impl: str = "auto"
    eval_batch: int = 256
    use_trust: bool = False
    batch_builder: Optional[Callable] = None   # (bx, by) -> model batch

    def __post_init__(self):
        self.opt = make_optimizer(self.train)
        self._round_fn = jax.jit(self._round)
        self._global_eval = jax.jit(self._global_eval_impl)

    # ------------------------------------------------------------------ init
    def init(self, key) -> RoundState:
        pk, rk = jax.random.split(key)
        params = self.model.init(pk)
        return RoundState(global_params=params,
                          scores=init_scores(self.fed.num_users),
                          round_idx=jnp.zeros((), jnp.int32),
                          key=rk)

    # ------------------------------------------------------------- internals
    def _batch(self, bx, by) -> Dict[str, jnp.ndarray]:
        if self.batch_builder is not None:
            return self.batch_builder(bx, by)
        if self.model.cfg.family == "cnn":
            return {"images": bx, "labels": by}
        return {"tokens": bx, "labels": by}

    def _local_train(self, params, bx, by):
        """One client's local phase: ``local_steps`` optimizer steps."""
        opt_state = self.opt.init(params)

        def step(carry, xb_yb):
            params, opt_state = carry
            xb, yb = xb_yb
            (loss, _), grads = jax.value_and_grad(
                self.model.loss, has_aux=True)(params, self._batch(xb, yb))
            params, opt_state = self.opt.update(grads, opt_state, params)
            return (params, opt_state), loss

        (params, _), losses = jax.lax.scan(step, (params, opt_state),
                                           (bx, by))
        return params, jnp.mean(losses)

    def _round(self, state: RoundState, data: FederatedDataset
               ) -> Tuple[RoundState, Dict[str, jnp.ndarray]]:
        fed = self.fed
        key = jax.random.fold_in(state.key, state.round_idx)
        k_batch, k_attack, k_test, k_lie = jax.random.split(key, 4)

        # 1-2. broadcast + vectorised local training
        stacked = jax.tree_util.tree_map(
            lambda x: jnp.broadcast_to(x[None], (fed.num_users,) + x.shape),
            state.global_params)
        bx, by = sample_client_batches(k_batch, data.train,
                                       fed.local_steps,
                                       self.train.batch_size)
        trained, local_loss = jax.vmap(self._local_train)(stacked, bx, by)

        # 3. adversaries act
        trained = apply_attacks(k_attack, trained, state.global_params,
                                num_malicious=fed.num_malicious,
                                attack=fed.attack, scale=fed.attack_scale)

        # 4. rotating testers measure accuracies on their own data
        tester_ids = select_testers(k_test, fed.num_users, fed.num_testers,
                                    state.round_idx)
        eval_fn = make_eval_fn(self.model)
        tx = data.test.xs[tester_ids, :self.eval_batch]
        ty = data.test.ys[tester_ids, :self.eval_batch]
        acc = cross_test_accuracies(
            lambda p, x, y: eval_fn(p, x, y), trained, tx, ty)   # [K, N]

        # 5. lying testers (Sec. V-C): users with id < lying_testers report
        # uniform random accuracies whenever they are selected to test.
        if fed.lying_testers:
            lies = jax.random.uniform(k_lie, acc.shape)
            liar_rows = (tester_ids < fed.lying_testers)[:, None]
            acc = jnp.where(liar_rows, lies, acc)

        # 6. weights per aggregator
        scores = state.scores
        if fed.aggregator == "fedtest":
            if self.use_trust:
                scores = update_tester_trust(scores, acc, tester_ids)
            scores = update_scores(scores, acc, tester_ids,
                                   power=fed.score_power,
                                   decay=fed.score_decay,
                                   use_trust=self.use_trust,
                                   power_warmup_rounds=
                                   fed.power_warmup_rounds)
            weights = score_weights(scores)
        elif fed.aggregator == "fedavg":
            weights = fedavg_weights(data.train.counts)
        elif fed.aggregator == "accuracy_based":
            sx = data.server_x[:self.eval_batch]
            sy = data.server_y[:self.eval_batch]
            server_acc = jax.vmap(lambda p: eval_fn(p, sx, sy))(trained)
            weights = accuracy_based_weights(server_acc)
        else:
            raise ValueError(fed.aggregator)

        # 7. score-weighted aggregation -> new global model
        new_global = aggregate_models(trained, weights, impl=self.agg_impl)

        metrics = {
            "local_loss": jnp.mean(local_loss),
            "acc_matrix_mean": jnp.mean(acc),
            "weights": weights,
            "malicious_weight": jnp.sum(
                weights[fed.num_users - fed.num_malicious:])
            if fed.num_malicious else jnp.zeros(()),
            "scores": scores.scores,
        }
        new_state = RoundState(global_params=new_global, scores=scores,
                               round_idx=state.round_idx + 1, key=state.key)
        return new_state, metrics

    def _global_eval_impl(self, params, gx, gy):
        eval_fn = make_eval_fn(self.model)
        return eval_fn(params, gx, gy)

    # ------------------------------------------------------------------- API
    def run_round(self, state: RoundState, data: FederatedDataset):
        return self._round_fn(state, data)

    def global_accuracy(self, state: RoundState, data: FederatedDataset,
                        max_samples: int = 2048) -> float:
        return float(self._global_eval(state.global_params,
                                       data.global_x[:max_samples],
                                       data.global_y[:max_samples]))

    def run(self, key, data: FederatedDataset, rounds: Optional[int] = None,
            eval_every: int = 1, verbose: bool = False):
        """Full training loop; returns (final_state, history dict)."""
        rounds = rounds if rounds is not None else self.fed.rounds
        state = self.init(key)
        history = {"round": [], "global_accuracy": [], "local_loss": [],
                   "malicious_weight": []}
        for r in range(rounds):
            state, metrics = self.run_round(state, data)
            if (r + 1) % eval_every == 0 or r == rounds - 1:
                ga = self.global_accuracy(state, data)
                history["round"].append(r + 1)
                history["global_accuracy"].append(ga)
                history["local_loss"].append(float(metrics["local_loss"]))
                history["malicious_weight"].append(
                    float(metrics["malicious_weight"]))
                if verbose:
                    print(f"round {r+1:4d}  acc={ga:.4f}  "
                          f"loss={float(metrics['local_loss']):.4f}  "
                          f"mal_w={float(metrics['malicious_weight']):.4f}")
        return state, history
