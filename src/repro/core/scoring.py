"""FedTest scoring (paper Sec. III + research direction V-B).

The server converts tester-measured accuracies into per-client scores with
a *weighted moving average over rounds* — "the recent accuracies are
weighted more than the old ones" — and raises accuracy to a power
(``score_power``; the paper found 4 works well: "the calculated scores are
better if the power is increased [to] 4"). The power amplifies strong
models and crushes the near-random accuracies produced by malicious users.

    s_c(t) = decay * s_c(t-1) + (1 - decay) * mean_k A[k, c]^p

Aggregation weights are the normalised scores. Tester reports can be
weighted by tester trust (research direction V-C).
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp


class ScoreState(NamedTuple):
    scores: jnp.ndarray          # [N] moving-average accuracy^p
    rounds_seen: jnp.ndarray     # scalar i32
    tester_trust: jnp.ndarray    # [N] moving agreement score (V-C)


def init_scores(num_users: int) -> ScoreState:
    return ScoreState(scores=jnp.zeros((num_users,), jnp.float32),
                      rounds_seen=jnp.zeros((), jnp.int32),
                      tester_trust=jnp.ones((num_users,), jnp.float32))


def _consensus_median(acc_matrix: jnp.ndarray,
                      row_mask: Optional[jnp.ndarray] = None
                      ) -> jnp.ndarray:
    """Per-client median over the (reporting) tester rows — the one
    consensus formula shared by report clipping and tester trust, so the
    two defences cannot drift on what "the consensus" means. All-masked
    columns yield NaN; callers pick their own degenerate-corner
    convention."""
    if row_mask is None:
        return jnp.median(acc_matrix, axis=0)
    return jnp.nanmedian(
        jnp.where(row_mask[:, None] > 0, acc_matrix, jnp.nan), axis=0)


def clip_reports_to_consensus(acc_matrix: jnp.ndarray, clip: float,
                              row_mask: Optional[jnp.ndarray] = None
                              ) -> jnp.ndarray:
    """Winsorise tester reports against the per-client consensus median.

    Every report is clamped into ``[median_c - clip, median_c + clip]``
    where ``median_c`` is the per-client median over the (reporting)
    tester rows. This bounds the per-round influence of *any* report-
    space attack — a ``mutual_boost`` coalition's 1.0-boost / 0.0-smear
    rows (DESIGN.md §7) move a client's combined accuracy by at most
    ``clip * liar_fraction`` — and is exact for honest reports, which
    sit near the consensus anyway. Robust while liars stay a minority of
    the round's committee (the median flips once they are not)."""
    median = _consensus_median(acc_matrix, row_mask)
    if row_mask is not None:
        median = jnp.nan_to_num(median)     # nobody reported: clamp to 0
    return jnp.clip(acc_matrix, median[None, :] - clip,
                    median[None, :] + clip)


def combine_tester_reports(acc_matrix: jnp.ndarray,
                           tester_ids: jnp.ndarray,
                           trust: Optional[jnp.ndarray] = None,
                           row_mask: Optional[jnp.ndarray] = None,
                           clip: Optional[float] = None
                           ) -> jnp.ndarray:
    """acc_matrix [K, N] (accuracy of client c measured by tester k) ->
    per-client accuracy [N]. Optionally trust-weighted (Sec. V-C) and
    winsorised against the consensus median (``clip``, DESIGN.md §7).

    ``row_mask`` [K] zeroes reports from testers that did not participate
    this round (client sampling): the mean runs over the reporting subset
    only — the single-host analogue of the pod path's participation-masked
    tester ``psum`` — and degrades to all-zero accuracies when nobody
    reported (matching the pod's ``0 / max(k, 1)`` convention)."""
    if clip is not None and clip > 0.0:
        acc_matrix = clip_reports_to_consensus(acc_matrix, clip, row_mask)
    if trust is None and row_mask is None:
        return jnp.mean(acc_matrix, axis=0)
    k = acc_matrix.shape[0]
    w = jnp.ones((k,), jnp.float32) if trust is None else trust[tester_ids]
    if row_mask is not None:
        w = w * row_mask
    total = jnp.sum(w)
    combined = jnp.einsum("k,kn->n", w / jnp.maximum(total, 1e-9),
                          acc_matrix)
    return jnp.where(total > 0.0, combined, jnp.zeros_like(combined))


def update_tester_trust(state: ScoreState, acc_matrix: jnp.ndarray,
                        tester_ids: jnp.ndarray,
                        decay: float = 0.8,
                        row_mask: Optional[jnp.ndarray] = None
                        ) -> ScoreState:
    """Research direction V-C: testers whose reports deviate from the
    consensus median lose trust, so lying testers get down-weighted.

    ``row_mask`` [K] excludes non-reporting testers (client sampling)
    from both the consensus median and the trust update — a report that
    was never sent can neither shift the consensus nor move its sender's
    trust."""
    median = _consensus_median(acc_matrix, row_mask)               # [N]
    dev = jnp.mean(jnp.abs(acc_matrix - median[None, :]), axis=1)  # [K]
    agreement = jnp.exp(-4.0 * dev)
    updated = (decay * state.tester_trust[tester_ids]
               + (1 - decay) * agreement)
    if row_mask is not None:
        updated = jnp.where(row_mask > 0, updated,
                            state.tester_trust[tester_ids])
    new_trust = state.tester_trust.at[tester_ids].set(updated)
    return state._replace(tester_trust=new_trust)


def update_scores(state: ScoreState, acc_matrix: jnp.ndarray,
                  tester_ids: jnp.ndarray, *, power: float = 4.0,
                  decay: float = 0.5, use_trust: bool = False,
                  power_warmup_rounds: int = 2,
                  row_mask: Optional[jnp.ndarray] = None,
                  client_mask: Optional[jnp.ndarray] = None,
                  report_clip: Optional[float] = None) -> ScoreState:
    """One round of Algorithm 1 line 13: ``FL server calculates the scores``.

    ``power_warmup_rounds``: rounds scored with exponent 1 before switching
    to ``power``. In the cold-start regime every honest model is near
    chance, and accuracy^4 amplifies *evaluation luck* — a random-weight
    adversary can win the whole aggregation weight in round 1 and lock the
    federation into a degenerate fixed point (observed on the MNIST-like
    set; EXPERIMENTS.md §Paper-validation). The paper itself proposes
    treating the exponent as "a variable, subject to periodic adjustments"
    (Sec. V-B); this is the minimal such schedule.

    ``report_clip``: winsorise reports against the per-client consensus
    median before combining (:func:`clip_reports_to_consensus`) —
    bounded-influence reporting against coordinated lying testers
    (DESIGN.md §7).

    ``client_mask`` [N] freezes the moving average of unmasked clients:
    under client sampling a non-participant transmits nothing, so what the
    testers measured in its slot is the stale global copy — no evidence
    about the client itself. Its score carries over unchanged (in
    particular, a suppressed attacker stays suppressed while it sits
    out). Both engines pass the round's participation mask here."""
    acc = combine_tester_reports(
        acc_matrix, tester_ids,
        trust=state.tester_trust if use_trust else None,
        row_mask=row_mask, clip=report_clip)
    eff_power = jnp.where(state.rounds_seen < power_warmup_rounds,
                          1.0, power)
    powered = jnp.clip(acc, 0.0, 1.0) ** eff_power
    first = state.rounds_seen == 0
    new = jnp.where(first, powered,
                    decay * state.scores + (1.0 - decay) * powered)
    if client_mask is not None:
        new = jnp.where(client_mask > 0, new, state.scores)
    return state._replace(scores=new, rounds_seen=state.rounds_seen + 1)


def score_weights(state: ScoreState) -> jnp.ndarray:
    """Aggregation weights (Algorithm 1 line 14)."""
    s = jnp.maximum(state.scores, 0.0)
    total = jnp.sum(s)
    n = s.shape[0]
    return jnp.where(total > 1e-12, s / jnp.maximum(total, 1e-12),
                     jnp.full_like(s, 1.0 / n))
