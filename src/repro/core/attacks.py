"""Malicious-user model suite.

The paper's attack (Sec. IV): "some users send random weights to the
server". We additionally implement standard poisoning attacks for the
robustness ablations: sign-flip (gradient ascent) and scaled-update
(model-replacement-style magnification), plus lying testers (Sec. V-C)
handled in the round engine.

``apply_attacks`` operates on the client-stacked param pytree; malicious
clients are the *last M* client slots (a fixed, known set for evaluation —
the defence, of course, does not use this knowledge).

Both round engines go through ``repro.strategies.ATTACKS``, which wraps
the per-client corruption primitives below and supports arbitrary
placement of the malicious set; this module stays the primitive layer.
The single-host engine applies them across the stacked ``[N, ...]``
client axis (``Attack.apply``); the pod path applies the same primitives
per shard_map shard, each device corrupting its own trained params before
the ring / all-gather exchange (``Attack.apply_local``, DESIGN.md §3) —
so a given key-free (attack, placement, scale) corrupts identically on
either engine; key-consuming attacks (``random_weights``) draw from
engine-specific key schedules, so their corruptions agree in
distribution but not bitwise.
"""
from __future__ import annotations

from typing import Callable, Dict

import jax
import jax.numpy as jnp

from repro.utils import key_iter


def _random_weights(key, trained, reference, scale):
    """Paper's attack: replace the model with random weights of the same
    magnitude statistics as the trained model."""
    leaves, treedef = jax.tree_util.tree_flatten(trained)
    ks = list(jax.random.split(key, len(leaves)))
    new = []
    for k, leaf in zip(ks, leaves):
        std = jnp.std(leaf.astype(jnp.float32)) + 1e-6
        new.append((jax.random.normal(k, leaf.shape, jnp.float32)
                    * std * scale).astype(leaf.dtype))
    return jax.tree_util.tree_unflatten(treedef, new)


def _sign_flip(key, trained, reference, scale):
    """Send global - scale * (trained - global): a gradient-ascent update."""
    return jax.tree_util.tree_map(
        lambda g, t: (g.astype(jnp.float32) - scale
                      * (t.astype(jnp.float32) - g.astype(jnp.float32))
                      ).astype(t.dtype),
        reference, trained)


def _scaled_update(key, trained, reference, scale):
    """Magnify the local update by ``scale`` (model replacement)."""
    return jax.tree_util.tree_map(
        lambda g, t: (g.astype(jnp.float32) + scale
                      * (t.astype(jnp.float32) - g.astype(jnp.float32))
                      ).astype(t.dtype),
        reference, trained)


ATTACKS: Dict[str, Callable] = {
    "random_weights": _random_weights,
    "sign_flip": _sign_flip,
    "scaled_update": _scaled_update,
    "none": lambda key, trained, reference, scale: trained,
}


def apply_attacks(key, stacked_params, global_params, *,
                  num_malicious: int, attack: str = "random_weights",
                  scale: float = 1.0):
    """Replace the last ``num_malicious`` clients' models with attacked ones."""
    if num_malicious == 0 or attack == "none":
        return stacked_params
    fn = ATTACKS[attack]
    N = jax.tree_util.tree_leaves(stacked_params)[0].shape[0]
    ks = key_iter(key)

    def client(c):
        trained = jax.tree_util.tree_map(lambda a: a[c], stacked_params)
        return fn(next(ks), trained, global_params, scale)

    attacked = [client(c) for c in range(N - num_malicious, N)]

    def merge(stack, *bad_leaves):
        out = stack
        for i, bl in enumerate(bad_leaves):
            out = out.at[N - num_malicious + i].set(bl)
        return out

    return jax.tree_util.tree_map(
        lambda stack, *bads: merge(stack, *bads), stacked_params, *attacked)
