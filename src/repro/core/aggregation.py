"""Model aggregation primitives.

Every aggregation scheme reduces a client-stacked param pytree with a
``[N]`` weight simplex; *how* the weights are produced is a registered
strategy (``repro.strategies.AGGREGATORS``). The paper's three schemes:

* **FedTest** — normalised moving-average accuracy^p scores
  (``repro.core.scoring``), accuracies measured by peer testers.
* **FedAvg** [McMahan et al.] — weights proportional to client sample
  counts (Fig. 1 of the paper).
* **Accuracy-based** [TiFL-style, ref 2] — weights proportional to each
  model's accuracy on the *server's* held-out test set.

The reduction itself runs through the ``weighted_aggregate`` Pallas kernel
on TPU (``impl='pallas'``) or its jnp oracle elsewhere.

A second fast path exists for aggregators that cannot be expressed as a
weighted sum: a ``combine_fn`` mapping the ``[N, D]`` flattened client
update matrix to one ``[D]`` combined update (per-coordinate trimmed
mean / median via the ``robust_combine`` sorting-network kernel). The
combined update is scattered back onto the global param pytree in one
fused unflatten-and-add.
"""
from __future__ import annotations

from typing import Callable, Optional

import jax.numpy as jnp

from repro.kernels.weighted_aggregate import aggregate_pytree
from repro.utils.pytree import tree_add_vector


def fedavg_weights(sample_counts: jnp.ndarray) -> jnp.ndarray:
    c = sample_counts.astype(jnp.float32)
    return c / jnp.maximum(c.sum(), 1e-9)


def accuracy_based_weights(server_accuracies: jnp.ndarray,
                           power: float = 1.0) -> jnp.ndarray:
    a = jnp.clip(server_accuracies.astype(jnp.float32), 0.0, 1.0) ** power
    total = jnp.sum(a)
    n = a.shape[0]
    return jnp.where(total > 1e-12, a / jnp.maximum(total, 1e-12),
                     jnp.full_like(a, 1.0 / n))


def aggregate_models(stacked_params, weights: jnp.ndarray, *,
                     impl: str = "auto",
                     combine_fn: Optional[Callable] = None,
                     updates: Optional[jnp.ndarray] = None,
                     global_params=None):
    """Algorithm 1 line 14: server-side model aggregation.

    ``stacked_params``: pytree whose leaves have a leading client axis.

    Default (``combine_fn is None``): the weighted-sum fast path —
    reduce ``stacked_params`` with the ``[N]`` ``weights`` simplex.

    Combine path: ``combine_fn`` maps the already-flattened ``[N, D]``
    ``updates`` matrix (trained - global, the engine computes it at most
    once per round) to a ``[D]`` combined update, which is unflattened
    onto ``global_params`` in one pass; ``weights`` is ignored.
    """
    if combine_fn is None:
        return aggregate_pytree(stacked_params, weights, impl=impl)
    if updates is None or global_params is None:
        raise ValueError(
            "combine_fn aggregation needs the [N, D] updates matrix and "
            "the global params pytree")
    return tree_add_vector(global_params, combine_fn(updates))
