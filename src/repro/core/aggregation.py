"""Model aggregation primitives.

Every aggregation scheme reduces a client-stacked param pytree with a
``[N]`` weight simplex; *how* the weights are produced is a registered
strategy (``repro.strategies.AGGREGATORS``). The paper's three schemes:

* **FedTest** — normalised moving-average accuracy^p scores
  (``repro.core.scoring``), accuracies measured by peer testers.
* **FedAvg** [McMahan et al.] — weights proportional to client sample
  counts (Fig. 1 of the paper).
* **Accuracy-based** [TiFL-style, ref 2] — weights proportional to each
  model's accuracy on the *server's* held-out test set.

The reduction itself runs through the ``weighted_aggregate`` Pallas kernel
on TPU (``impl='pallas'``) or its jnp oracle elsewhere.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.kernels.weighted_aggregate import aggregate_pytree


def fedavg_weights(sample_counts: jnp.ndarray) -> jnp.ndarray:
    c = sample_counts.astype(jnp.float32)
    return c / jnp.maximum(c.sum(), 1e-9)


def accuracy_based_weights(server_accuracies: jnp.ndarray,
                           power: float = 1.0) -> jnp.ndarray:
    a = jnp.clip(server_accuracies.astype(jnp.float32), 0.0, 1.0) ** power
    total = jnp.sum(a)
    n = a.shape[0]
    return jnp.where(total > 1e-12, a / jnp.maximum(total, 1e-12),
                     jnp.full_like(a, 1.0 / n))


def aggregate_models(stacked_params, weights: jnp.ndarray, *,
                     impl: str = "auto"):
    """Algorithm 1 line 14: score-weighted model aggregation.

    ``stacked_params``: pytree whose leaves have a leading client axis.
    """
    return aggregate_pytree(stacked_params, weights, impl=impl)
