"""Exchange backends: the topology-specific third of the round engine.

The :class:`~repro.core.engine.program.RoundProgram` owns the round's
semantics; an :class:`ExchangeBackend` supplies only the mechanics that
differ by topology (DESIGN.md §3):

* ``local``     — single host: the N client models are a stacked
  ``[N, ...]`` param pytree, local training is ``vmap`` over the client
  axis, cross-testing is ``vmap`` over the stack, aggregation is the
  fused weighted sum (the ``weighted_aggregate`` Pallas kernel on TPU).
* ``ring``      — one client per device along a mesh axis under
  ``shard_map``; cross-testing rotates the models with ``lax.ppermute``
  (N-1 hops, peak memory 2x one model), the datacenter analogue of the
  paper's orthogonal-RB D2D exchange.
* ``allgather`` — the paper-faithful broadcast: every device receives
  every model at once (N-x memory), kept as the EXPERIMENTS.md §Perf
  comparison baseline; aggregators that need the ``[N, D]`` update
  matrix reuse the gathered models, so nothing is exchanged twice.

Every backend returns *replicated* ``[N]`` / ``[K, N]`` arrays to the
program (per-client losses, the accuracy matrix, flattened updates);
the pod backends replicate via ``all_gather`` and reduce the weighted
sum with one ``psum``. That contract is what lets the equivalence
matrix (``tests/test_pod_parity.py``) pin all three backends
bit-identical on weights, scores and malicious-weight trajectories.
"""
from __future__ import annotations

import functools
from typing import Any, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.config import FedConfig, TrainConfig
from repro.core.cross_testing import CROSSTEST_IMPLS, cross_test_accuracies
from repro.core.engine.program import RoundProgram, round_keys
from repro.kernels.weighted_aggregate import aggregate_pytree
from repro.utils.pytree import tree_add_vector


def _shard_map(f, *, mesh, in_specs, out_specs):
    """jax.shard_map across jax versions (experimental pre-0.5)."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=False)
    from jax.experimental.shard_map import shard_map as sm
    return sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
              check_rep=False)


def _flatten_updates(stacked, global_params) -> jnp.ndarray:
    """[N, D] float32 matrix of flattened client updates."""
    def flat(stack, g):
        n = stack.shape[0]
        return (stack.astype(jnp.float32)
                - g.astype(jnp.float32)[None]).reshape(n, -1)
    parts = jax.tree_util.tree_leaves(
        jax.tree_util.tree_map(flat, stacked, global_params))
    return jnp.concatenate(parts, axis=1)


class ExchangeBackend:
    """Protocol between :class:`RoundProgram` and a topology.

    ``models`` is an opaque handle the program never inspects — a
    stacked pytree on the local backend, one device's pytree inside a
    ``shard_map`` body on the pod backends. Replicated arrays cross the
    seam; model pytrees only round-trip through these methods.
    """

    name = "base"

    def train(self, local_train, global_params, bx, by
              ) -> Tuple[Any, jnp.ndarray]:
        """Broadcast + local phase -> (models, per-client losses [N])."""
        raise NotImplementedError

    def apply_attack(self, attack, key, models, global_params, actx):
        """Step 3: corrupt the malicious clients' models."""
        raise NotImplementedError

    def mask_models(self, models, global_params, part_mask):
        """Step 3b: revert non-participants' slots to the global model."""
        raise NotImplementedError

    def cross_test(self, eval_fn, models, tx, ty, tester_ids
                   ) -> Tuple[jnp.ndarray, Any]:
        """Step 4: replicated accuracy matrix [K, N] (+ reuse cache)."""
        raise NotImplementedError

    def updates(self, models, global_params, cache) -> jnp.ndarray:
        """Replicated [N, D] float32 flattened update matrix."""
        raise NotImplementedError

    def server_eval(self, eval_fn, models, sx, sy):
        """() -> [N] accuracies of every model on the server's set."""
        raise NotImplementedError

    def weighted_sum(self, models, weights, global_params, impl):
        """Step 7 weights path: sum_c w_c * model_c -> new global."""
        raise NotImplementedError

    def compress_exchange(self, compressor, models, global_params,
                          comp_state, part_mask):
        """Step 3c (DESIGN.md §12): encode each participating client's
        flat update with error feedback, reconstruct the models every
        consumer sees from the decoded payloads. Returns
        ``(models, payloads, decoded, new_comp_state)`` — payloads /
        decoded in the backend's client layout (stacked ``[N, ...]``
        locally, this device's row on the pod), ``new_comp_state``
        replicated ``[N, D]``."""
        raise NotImplementedError

    def compressed_sum(self, compressor, payloads, decoded, weights,
                       models, impl):
        """Step 7 compressed weights path: ``sum_c w_c * decoded_c``
        in update space -> flat ``[D]`` f32 aggregated update.
        ``models`` rides along for backends whose client layout needs
        remapping the replicated [N] weights (the population cohort)."""
        raise NotImplementedError


class LocalBackend(ExchangeBackend):
    """Single-host vmap backend: clients stacked on a leading [N] axis."""

    name = "local"

    def __init__(self, num_users: int, crosstest_impl: str = "batched"):
        if crosstest_impl not in CROSSTEST_IMPLS:
            raise ValueError(f"crosstest_impl must be one of "
                             f"{CROSSTEST_IMPLS}, got {crosstest_impl!r}")
        self.num_users = num_users
        self.crosstest_impl = crosstest_impl

    def train(self, local_train, global_params, bx, by):
        stacked = jax.tree_util.tree_map(
            lambda x: jnp.broadcast_to(x[None],
                                       (self.num_users,) + x.shape),
            global_params)
        return jax.vmap(local_train)(stacked, bx, by)

    def apply_attack(self, attack, key, models, global_params, actx):
        return attack.apply(key, models, global_params, actx)

    def mask_models(self, models, global_params, part_mask):
        return jax.tree_util.tree_map(
            lambda t, g: jnp.where(
                part_mask.reshape((-1,) + (1,) * (t.ndim - 1)) > 0,
                t, g[None].astype(t.dtype)),
            models, global_params)

    def cross_test(self, eval_fn, models, tx, ty, tester_ids):
        acc = cross_test_accuracies(
            lambda p, x, y: eval_fn(p, x, y), models,
            tx[tester_ids], ty[tester_ids],
            impl=self.crosstest_impl)                        # [K, N]
        return acc, None

    def updates(self, models, global_params, cache):
        return _flatten_updates(models, global_params)

    def server_eval(self, eval_fn, models, sx, sy):
        return lambda: jax.vmap(lambda p: eval_fn(p, sx, sy))(models)

    def weighted_sum(self, models, weights, global_params, impl):
        return aggregate_pytree(models, weights, impl=impl)

    def compress_exchange(self, compressor, models, global_params,
                          comp_state, part_mask):
        updates = _flatten_updates(models, global_params)       # [N, D]
        payloads, new_state = jax.vmap(compressor.encode)(comp_state,
                                                          updates)
        decoded = jax.vmap(compressor.decode)(payloads)         # [N, D]
        if part_mask is not None:
            # a masked client transmitted nothing: its error buffer
            # must not be flushed and its decoded update is exactly 0,
            # so the reconstructed slot is bitwise the stale global
            keep = (part_mask > 0)[:, None]
            new_state = jnp.where(keep, new_state, comp_state)
            decoded = jnp.where(keep, decoded, 0.0)
        models = jax.vmap(
            lambda v: tree_add_vector(global_params, v))(decoded)
        return models, payloads, decoded, new_state

    def compressed_sum(self, compressor, payloads, decoded, weights,
                       models, impl):
        return compressor.aggregate(payloads, decoded, weights, impl)


def ring_cross_test(eval_fn, my_params, tx, ty, axis: str, num_clients: int,
                    impl: str = "batched"):
    """Every device measures every client's model on its own test data.

    Returns acc_row [num_clients]: accuracy of client c's model on *my*
    local test shard. Implemented as N-1 ``ppermute`` hops around the ring
    (visiting models), so peak memory is own + visiting model.

    ``impl`` picks the hop schedule (DESIGN.md §10): ``reference`` runs
    eval-then-permute (the historical serial hop); ``batched`` issues the
    next ``ppermute`` *before* the eval so the collective overlaps with
    the hop's compute. Both read the identical pre-permute ``visiting``
    value — the dataflow is unchanged, only the issue order — so the two
    schedules are bit-identical (pinned by ``tests/test_crosstest.py``).
    """
    my_idx = jax.lax.axis_index(axis)
    perm = [(i, (i + 1) % num_clients) for i in range(num_clients)]
    overlap = impl == "batched"

    def hop(step, carry):
        visiting, acc_row = carry
        # who owned `visiting` before `step` hops reached me?
        owner = (my_idx - step) % num_clients
        if overlap:
            nxt = jax.lax.ppermute(visiting, axis, perm)
        acc = eval_fn(visiting, tx, ty)
        acc_row = acc_row.at[owner].set(acc)
        if not overlap:
            nxt = jax.lax.ppermute(visiting, axis, perm)
        return (nxt, acc_row)

    acc_row = jnp.zeros((num_clients,), jnp.float32)
    (_, acc_row) = jax.lax.fori_loop(
        0, num_clients, hop, (my_params, acc_row))
    return acc_row


class PodBackend(ExchangeBackend):
    """Shared shard_map mechanics: one client per slice of ``axis``.

    Subclasses differ only in the cross-testing exchange (how a tester
    sees the other clients' models) and in whether the gathered models
    can be reused for the update matrix.
    """

    name = "pod"

    def __init__(self, axis: str, num_clients: int,
                 crosstest_impl: str = "batched"):
        if crosstest_impl not in CROSSTEST_IMPLS:
            raise ValueError(f"crosstest_impl must be one of "
                             f"{CROSSTEST_IMPLS}, got {crosstest_impl!r}")
        self.axis = axis
        self.num_clients = num_clients
        self.crosstest_impl = crosstest_impl

    def train(self, local_train, global_params, bx, by):
        params, loss = local_train(global_params, bx, by)
        return params, jax.lax.all_gather(loss, self.axis)      # [N]

    def apply_attack(self, attack, key, models, global_params, actx):
        my_idx = jax.lax.axis_index(self.axis)
        return attack.apply_local(key, models, global_params, my_idx,
                                  self.num_clients, actx)

    def mask_models(self, models, global_params, part_mask):
        my_part = part_mask[jax.lax.axis_index(self.axis)]
        return jax.tree_util.tree_map(
            lambda p, g: jnp.where(my_part > 0, p, g.astype(p.dtype)),
            models, global_params)

    def _acc_matrix(self, acc_row, tester_ids):
        """[N] own row -> replicated [K, N] tester rows.

        One small all-gather (N^2 floats) replicates the full matrix so
        the program scores it with exactly the single-host code path —
        the drift-proofing trade the pod makes for N extra rows.
        """
        full = jax.lax.all_gather(acc_row, self.axis)           # [N, N]
        return full[tester_ids]                                 # [K, N]

    def updates(self, models, global_params, cache):
        if cache is not None:       # all-gathered models: derive, don't
            return _flatten_updates(cache, global_params)   # gather twice
        flat = jnp.concatenate([
            (p.astype(jnp.float32) - g.astype(jnp.float32)).ravel()
            for p, g in zip(jax.tree_util.tree_leaves(models),
                            jax.tree_util.tree_leaves(global_params))])
        return jax.lax.all_gather(flat, self.axis)              # [N, D]

    def server_eval(self, eval_fn, models, sx, sy):
        my_acc = eval_fn(models, sx, sy)
        return lambda: jax.lax.all_gather(my_acc, self.axis)    # [N]

    def weighted_sum(self, models, weights, global_params, impl):
        my_w = weights[jax.lax.axis_index(self.axis)]
        return jax.tree_util.tree_map(
            lambda x: jax.lax.psum(
                (x.astype(jnp.float32) * my_w), self.axis).astype(x.dtype),
            models)

    def compress_exchange(self, compressor, models, global_params,
                          comp_state, part_mask):
        my_idx = jax.lax.axis_index(self.axis)
        update = jnp.concatenate([
            (p.astype(jnp.float32) - g.astype(jnp.float32)).ravel()
            for p, g in zip(jax.tree_util.tree_leaves(models),
                            jax.tree_util.tree_leaves(global_params))])
        payload, new_row = compressor.encode(comp_state[my_idx], update)
        decoded = compressor.decode(payload)
        if part_mask is not None:
            keep = part_mask[my_idx] > 0
            new_row = jnp.where(keep, new_row, comp_state[my_idx])
            decoded = jnp.where(keep, decoded, 0.0)
        # replicate the new buffer: each device contributes exactly its
        # own row (everything else is zero), so the psum writes every
        # row exactly once — x + 0 is bitwise x, no f32 drift
        contrib = jnp.zeros_like(comp_state).at[my_idx].set(new_row)
        new_state = jax.lax.psum(contrib, self.axis)
        models = tree_add_vector(global_params, decoded)
        return models, payload, decoded, new_state

    def compressed_sum(self, compressor, payloads, decoded, weights,
                       models, impl):
        my_w = weights[jax.lax.axis_index(self.axis)]
        return jax.lax.psum(decoded * my_w, self.axis)


class RingBackend(PodBackend):
    """Ring exchange: ``ppermute`` hops, peak memory own + visiting."""

    name = "ring"

    def cross_test(self, eval_fn, models, tx, ty, tester_ids):
        acc_row = ring_cross_test(eval_fn, models, tx, ty, self.axis,
                                  self.num_clients,
                                  impl=self.crosstest_impl)
        return self._acc_matrix(acc_row, tester_ids), None


class AllgatherBackend(PodBackend):
    """Paper-faithful exchange: every tester receives all models at once
    (the RB broadcast); N-x memory, kept as the EXPERIMENTS.md §Perf
    baseline. The gathered stack is cached for the update matrix."""

    name = "allgather"

    def cross_test(self, eval_fn, models, tx, ty, tester_ids):
        everyone = jax.tree_util.tree_map(
            lambda x: jax.lax.all_gather(x, self.axis), models)  # [N, ...]
        if self.crosstest_impl == "batched":
            # one fused [N, batch] forward over the gathered stack
            acc_row = jax.vmap(lambda p: eval_fn(p, tx, ty))(everyone)
        else:
            # reference: N sequential per-client eval dispatches
            acc_row = jnp.stack([
                eval_fn(jax.tree_util.tree_map(lambda l, c=c: l[c],
                                               everyone), tx, ty)
                for c in range(self.num_clients)])
        return self._acc_matrix(acc_row, tester_ids), everyone


# --------------------------------------------------------------- builders
def make_pod_round(model, fed: FedConfig, train_cfg: TrainConfig, mesh,
                   axis: str = "clients", aggregator=None, counts=None,
                   server_data=None, exchange: str = "ring",
                   crosstest_impl: str = None):
    """Builds the shard_map FedTest round for ``mesh[axis]`` clients.

    The returned function runs the *same* :class:`RoundProgram` as the
    local backend — resolved here, pre-trace — under ``shard_map``:

      round_fn(global_params, scores, bx, by, tx, ty, key, round_idx)
        -> (new_global (replicated), new_scores, metrics)

    With a compressed exchange configured (``fed.compressor`` other
    than ``'identity'``, DESIGN.md §12) the signature grows the
    replicated ``[N, D]`` error-feedback buffer — a static build-time
    decision, so uncompressed callers are untouched:

      round_fn(global_params, scores, comp, bx, by, tx, ty, key,
               round_idx)
        -> (new_global, new_scores, new_comp (replicated), metrics)

    ``key`` is the round's base key (``fold_in(run_key, round)``; the
    program derives the :class:`RoundKeys` bundle, the tester set and
    the participation mask from it exactly like the local driver does),
    ``bx, by`` are ``[N, steps, batch, ...]`` client-sharded training
    batches and ``tx, ty`` ``[N, eval_batch, ...]`` client-sharded local
    test shards. ``aggregator`` — registry name or
    :class:`~repro.strategies.base.Aggregator` instance; defaults to
    ``fed.aggregator``. ``counts`` are the per-client sample counts
    (static host data, closed over); without them fedavg degenerates to
    uniform weighting. ``server_data`` — optional ``(sx, sy)`` replicated
    server eval set, required only by ``needs_server_eval`` aggregators.
    ``crosstest_impl`` — cross-testing dispatch model (DESIGN.md §10);
    defaults to ``fed.crosstest_impl``.
    """
    if exchange not in ("ring", "allgather"):
        raise ValueError(f"exchange must be 'ring'|'allgather', "
                         f"got {exchange!r}")
    crosstest_impl = crosstest_impl or getattr(fed, "crosstest_impl",
                                               "batched")
    if crosstest_impl not in CROSSTEST_IMPLS:
        raise ValueError(f"crosstest_impl must be one of "
                         f"{CROSSTEST_IMPLS}, got {crosstest_impl!r}")
    num_clients = mesh.shape[axis]
    if fed.num_users != num_clients:
        raise ValueError(
            f"FedConfig.num_users={fed.num_users} but mesh[{axis!r}] has "
            f"{num_clients} slices — the pod pins one client per device "
            "(refit presets with repro.configs.scenario_for_pod)")
    program = RoundProgram(model, fed, train_cfg, aggregator=aggregator)
    if program.aggregator.needs_server_eval and server_data is None:
        raise ValueError(
            f"aggregator {program.aggregator.name!r} needs a server-side "
            "eval set; pass server_data=(sx, sy) to the round builder "
            "(e.g. the FederatedDataset's server_x/server_y)")
    counts_arr = (jnp.asarray(counts, jnp.float32) if counts is not None
                  else jnp.ones((num_clients,), jnp.float32))
    server = (None if server_data is None else
              (jnp.asarray(server_data[0]), jnp.asarray(server_data[1])))
    backend_cls = RingBackend if exchange == "ring" else AllgatherBackend

    if program.use_compression:
        @functools.partial(
            _shard_map, mesh=mesh,
            in_specs=(P(), P(), P(), P(axis), P(axis), P(axis), P(axis),
                      P(), P()),
            out_specs=(P(), P(), P(), P()))
        def round_fn(global_params, scores, comp, bx, by, tx, ty, key,
                     round_idx):
            bx, by = bx[0], by[0]
            tx, ty = tx[0], ty[0]
            backend = backend_cls(axis, num_clients, crosstest_impl)
            keys = round_keys(key)
            tester_ids, part_mask = program.select_round(
                keys, round_idx, scores=scores.scores)
            return program.run(backend, global_params, scores, bx=bx,
                               by=by, tx=tx, ty=ty,
                               tester_ids=tester_ids,
                               part_mask=part_mask, keys=keys,
                               round_idx=round_idx, counts=counts_arr,
                               server_data=server, comp_state=comp)

        return round_fn

    @functools.partial(
        _shard_map, mesh=mesh,
        in_specs=(P(), P(), P(axis), P(axis), P(axis), P(axis), P(), P()),
        out_specs=(P(), P(), P()))
    def round_fn(global_params, scores, bx, by, tx, ty, key, round_idx):
        # shard_map gives per-client leading axes of size 1 — drop them
        bx, by = bx[0], by[0]
        tx, ty = tx[0], ty[0]
        backend = backend_cls(axis, num_clients, crosstest_impl)
        keys = round_keys(key)
        tester_ids, part_mask = program.select_round(keys, round_idx,
                                                     scores=scores.scores)
        new_global, new_scores, _, metrics = program.run(
            backend, global_params, scores, bx=bx, by=by, tx=tx, ty=ty,
            tester_ids=tester_ids, part_mask=part_mask, keys=keys,
            round_idx=round_idx, counts=counts_arr, server_data=server)
        return new_global, new_scores, metrics

    return round_fn


def make_distributed_round(model, fed: FedConfig, train_cfg: TrainConfig,
                           mesh, axis: str = "clients", aggregator=None,
                           counts=None, server_data=None):
    """Ring-exchange pod round (see :func:`make_pod_round`)."""
    return make_pod_round(model, fed, train_cfg, mesh, axis, aggregator,
                          counts, server_data, exchange="ring")


def make_allgather_round(model, fed: FedConfig, train_cfg: TrainConfig,
                         mesh, axis: str = "clients", aggregator=None,
                         counts=None, server_data=None):
    """All-gather-exchange pod round (see :func:`make_pod_round`)."""
    return make_pod_round(model, fed, train_cfg, mesh, axis, aggregator,
                          counts, server_data, exchange="allgather")
