"""The backend-agnostic FedTest round program (Algorithm 1).

One fused round, owned exactly once (the step numbering below is the one
DESIGN.md §2 documents):

  1.  broadcast the global model to all N users            (line 15 of prev round)
  2.  every user runs ``local_steps`` optimizer steps on its own shard (line 5)
  3.  malicious users swap in attacked models              (Sec. IV)
  4.  K testers evaluate all N models on their own data    (lines 6-9)
  5.  lying testers corrupt their reports                  (Sec. V-C ablation)
  6.  the server computes scores / weights                 (line 13)
  7.  score-weighted aggregation -> new global model       (line 14)

:class:`RoundProgram` implements every step once and is parameterised by
an :class:`~repro.core.engine.backends.ExchangeBackend` that supplies
only what is genuinely topology-specific — how the N client models are
materialised (a stacked ``[N, ...]`` pytree under ``vmap``, or one model
per device under ``shard_map``), how testers see other clients' models
(vmap / ring hops / all-gather), and how per-device partials reduce
(identity / psum). Everything semantic — the participation mask, the
attack application and its :class:`AttackContext`, lying testers, the
score update (including score freezing for non-participants), the
sampled-subset renormalisation, the metrics — lives here, so the three
backends cannot drift (the equivalence matrix in
``tests/test_pod_parity.py`` pins them bit-identical).

The contract that makes this possible: the backend hands the program
*replicated* ``[N]``- / ``[K, N]``-indexed arrays (accuracy matrix,
per-client losses, flattened updates) and the program manipulates only
those plus opaque model handles it routes back through backend methods.

Steps 3, 4 and 6 are **pluggable**: the attack, tester-selection policy
and aggregator are looked up by name in :mod:`repro.strategies`
(``FedConfig.attack`` / ``.selector`` / ``.aggregator``) and resolved to
plain Python objects in the program constructor — *before* tracing — so
jit closes over static callables and one round compiles to one fused
program with no trace-time branching.

Coordinated adversaries (``FedConfig.coalition``, DESIGN.md §7) hook the
same two seams: the coalition's model attack composes into step 3
(:meth:`Coalition.compose` unions the malicious set, so the
``malicious_weight`` metric reports the coalition's aggregate weight)
and its report transform runs as step 5b on the replicated accuracy
matrix — shared code on every backend, so the three exchange backends
stay bit-identical under coalition attacks too.

Client failures (``FedConfig.fault``, DESIGN.md §9) enter as step 2b: a
:class:`~repro.strategies.base.Fault` model turns the round schedule's
``keys.fault`` stream into a ``[N]`` survival mask that is ANDed into
the participation mask after selection (:func:`compose_fault_mask`) —
dropped clients inherit the non-sampled semantics wholesale, and the
round emits a ``dropped_fraction`` metric.
"""
from __future__ import annotations

from typing import Any, Callable, Dict, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.config import FedConfig, TrainConfig
from repro.core.cross_testing import make_eval_fn
from repro.core.scoring import score_weights
from repro.optim import make_optimizer
from repro.strategies.base import (
    Aggregator, AttackContext, RoundContext, uses_combine)
from repro.utils.pytree import tree_add_vector


class RoundKeys(NamedTuple):
    """The per-round PRNG key bundle, one derivation for every driver.

    ``round_keys`` is the exact schedule the historical single-host
    engine used (``split(key, 4)`` then ``fold_in(key, 5)`` /
    ``fold_in(key, 6)``), so replaying a round on another backend — or
    from a host loop, as the pod driver and the parity tests do — means
    deriving this bundle from the same base key, nothing more.
    """

    batch: jnp.ndarray      # client batch sampling
    attack: jnp.ndarray     # base attack key (per-client fold downstream)
    test: jnp.ndarray       # tester selection
    lie: jnp.ndarray        # lying testers' fake reports
    agg: jnp.ndarray        # randomised aggregation strategies
    part: jnp.ndarray       # participation (client-sampling) mask
    fault: jnp.ndarray      # client-failure (fault-injection) mask


def round_keys(key) -> RoundKeys:
    """Derive the :class:`RoundKeys` bundle from a round's base key.

    New streams extend the bundle with further ``fold_in`` constants
    (``fault`` = 7) so the historical streams — and therefore every
    committed trajectory — stay bit-identical.
    """
    k_batch, k_attack, k_test, k_lie = jax.random.split(key, 4)
    return RoundKeys(batch=k_batch, attack=k_attack, test=k_test, lie=k_lie,
                     agg=jax.random.fold_in(key, 5),
                     part=jax.random.fold_in(key, 6),
                     fault=jax.random.fold_in(key, 7))


def participation_mask(key, num_users: int, participation: float
                       ) -> jnp.ndarray:
    """Per-round Bernoulli client-sampling mask ``[N]`` (1 = sampled).

    Falls back to everyone in the zero-participant corner so a round is
    always well defined. Every backend gets the mask from this one
    formula via :meth:`RoundProgram.select_round`, so the sampled
    subsets agree bit-exactly for equal keys.
    """
    bern = jax.random.bernoulli(key, participation, (num_users,))
    return jnp.where(jnp.any(bern), bern.astype(jnp.float32),
                     jnp.ones((num_users,), jnp.float32))


def compose_fault_mask(part_mask: jnp.ndarray, alive: jnp.ndarray
                       ) -> jnp.ndarray:
    """AND the fault survival mask into the participation mask (§2b).

    A dropped client is indistinguishable from a non-sampled one — it
    transmitted nothing — so the composed mask feeds the existing
    non-sampled machinery unchanged. If *every* selected client dropped,
    the faults are ignored for the round (the round must stay well
    defined; mirrors :func:`participation_mask`'s zero-participant
    fallback). One formula, applied once in :meth:`RoundProgram.run`,
    so local/ring/allgather stay bit-identical under faults.
    """
    combined = part_mask * alive
    return jnp.where(jnp.sum(combined) > 0, combined, part_mask)


def renormalize_over_subset(weights: jnp.ndarray, part_mask: jnp.ndarray
                            ) -> jnp.ndarray:
    """Zero non-participants and renormalise the simplex over the subset.

    If the sampled subset got zero total weight, fall back to uniform
    over it. One formula, applied once in :meth:`RoundProgram.run`, so
    the sampled-subset renormalisation cannot drift between backends
    (the equivalence matrix pins the resulting zero pattern and sums).
    """
    w = weights * part_mask
    total = jnp.sum(w)
    return jnp.where(total > 1e-12, w / jnp.maximum(total, 1e-12),
                     part_mask / jnp.sum(part_mask))


def aggregator_defaults(fed: FedConfig, use_trust: bool = False
                        ) -> Dict[str, Any]:
    """Engine-derived default kwargs offered to aggregator constructors.

    Each aggregator picks up only the fields its ``__init__`` accepts
    (``Registry.build`` filters by signature): ``fedtest`` takes the
    scoring knobs, ``krum`` takes ``num_byzantine`` (the defender's
    assumed f, defaulted to the scenario's ``num_malicious``), the rest
    need nothing.
    """
    return dict(score_power=fed.score_power,
                score_decay=fed.score_decay,
                power_warmup_rounds=fed.power_warmup_rounds,
                use_trust=use_trust,
                num_byzantine=fed.num_malicious)


def resolve_strategies(fed: FedConfig, use_trust: bool = False,
                       aggregator=None):
    """Name -> object resolution for (aggregator, attack, selector).

    ``aggregator`` — optional override: a registry name or an already
    constructed :class:`Aggregator` instance (the pod builders accept
    both); defaults to ``fed.aggregator``.
    """
    # package import (not just .base) so the registries are populated
    from repro.strategies import AGGREGATORS, ATTACKS, SELECTORS
    if isinstance(aggregator, Aggregator):
        agg = aggregator
    else:
        agg = AGGREGATORS.build(aggregator or fed.aggregator,
                                fed.strategy_kwargs("aggregator"),
                                aggregator_defaults(fed, use_trust))
    atk = ATTACKS.build(fed.attack, fed.strategy_kwargs("attack"),
                        dict(num_malicious=fed.num_malicious,
                             scale=fed.attack_scale))
    # seed default: schedule-based selectors (coverage) derive their
    # per-cycle shuffle from the run seed, not a fixed key
    sel = SELECTORS.build(fed.selector, fed.strategy_kwargs("selector"),
                          dict(seed=fed.seed))
    return agg, atk, sel


def resolve_fault(fed: FedConfig):
    """Name -> object resolution for ``fed.fault`` (DESIGN.md §9).

    ``rate`` defaults to ``fed.fault_rate`` (silently dropped when the
    fault model's constructor does not accept it — ``targeted`` and
    ``straggler_deadline`` have their own knobs).
    """
    from repro.strategies import FAULTS
    return FAULTS.build(fed.fault, fed.strategy_kwargs("fault"),
                        dict(rate=fed.fault_rate))


def resolve_coalition(fed: FedConfig):
    """Name -> object resolution for ``fed.coalition`` (DESIGN.md §7).

    ``size`` defaults to ``fed.coalition_size`` and the total model-
    attack ``scale`` to ``fed.attack_scale`` (each silently dropped when
    the coalition's constructor does not accept it).
    """
    from repro.strategies import COALITIONS
    return COALITIONS.build(fed.coalition,
                            fed.strategy_kwargs("coalition"),
                            dict(size=fed.coalition_size,
                                 scale=fed.attack_scale))


def flat_update_dim(model) -> int:
    """Static width D of the flattened update vector.

    Matches ``_flatten_updates``'s layout (leaf order, full ravel) by
    construction — both walk the same param pytree — and is derived
    abstractly (``eval_shape``), so no model is ever materialised at
    build time.
    """
    import math
    shapes = jax.eval_shape(model.init,
                            jax.ShapeDtypeStruct((2,), jnp.uint32))
    return sum(math.prod(leaf.shape) or 1
               for leaf in jax.tree_util.tree_leaves(shapes))


def resolve_compressor(fed: FedConfig, model):
    """Name -> object resolution for ``fed.compressor`` (DESIGN.md §12).

    The engine injects the static flat update width ``dim`` so payload
    shapes (top-k count, chunk grid, factor ranks) are fixed at build
    time and the traced round stays retrace-free.
    """
    from repro.strategies import COMPRESSORS
    return COMPRESSORS.build(fed.compressor,
                             fed.strategy_kwargs("compressor"),
                             dict(dim=flat_update_dim(model)))


def init_comp_state(fed: FedConfig, model):
    """Initial ``[N, D]`` error-feedback buffer; ``None`` when the
    exchange is uncompressed (the seam is statically disabled, so the
    state is an empty pytree that costs nothing to thread)."""
    if fed.compressor == "identity":
        return None
    return resolve_compressor(fed, model).init_state(fed.num_users)


class RoundProgram:
    """Steps 1-7 of the FedTest round, once, for every exchange backend.

    Everything pluggable or derivable is resolved here, pre-trace: the
    strategy objects, the optimizer, the (single, shared) eval function,
    the static malicious placement, and the combine-fast-path flags. A
    jitted round closes over this object; ``FederatedTrainer.num_traces``
    and its pod analogue count retraces — steady-state training must
    keep one trace per compiled driver.
    """

    def __init__(self, model, fed: FedConfig, train_cfg: TrainConfig, *,
                 use_trust: bool = False, agg_impl: str = "auto",
                 batch_builder: Optional[Callable] = None,
                 aggregator=None):
        self.model = model
        self.fed = fed
        self.train_cfg = train_cfg
        self.agg_impl = agg_impl
        self.batch_builder = batch_builder
        self.opt = make_optimizer(train_cfg)
        # one eval fn, built once, shared by cross-testing, server-side
        # eval and the drivers' global-accuracy closures
        self.eval_fn = make_eval_fn(model)
        self.aggregator, self.attack, self.selector = resolve_strategies(
            fed, use_trust, aggregator=aggregator)
        # legacy selectors predate the scores= keyword — inspect once,
        # pre-trace, and only forward scores to policies that take it
        import inspect
        self._selector_takes_scores = ("scores" in inspect.signature(
            self.selector.select).parameters)
        # coordinated adversaries (DESIGN.md §7): the coalition's model
        # attack composes into the attack seam (member ∪ malicious set),
        # its report transform runs as step 5b; both resolved pre-trace.
        self.coalition = resolve_coalition(fed)
        self.coalition_active = self.coalition.active
        if self.coalition_active:
            self.attack = self.coalition.compose(self.attack,
                                                 fed.num_users)
        # a non-None combine hook routes aggregation through the
        # per-coordinate fast path; both checks are static Python, so the
        # jitted round never branches on them at trace time.
        self.uses_combine = uses_combine(self.aggregator)
        self.needs_updates = (self.aggregator.needs_updates
                              or self.uses_combine)
        self.malicious_idx = self.attack.malicious_indices(fed.num_users)
        self.malicious_mask = self.attack.malicious_mask(fed.num_users)
        self.use_participation = fed.participation < 1.0
        # fault injection (DESIGN.md §9): resolved pre-trace like every
        # strategy; the static flag keeps honest rounds branch-free.
        self.fault = resolve_fault(fed)
        self.use_faults = fed.fault != "none"
        # compressed exchange (DESIGN.md §12): 'identity' statically
        # disables the seam — the default round is byte-identical to the
        # uncompressed engine, not merely equivalent (reconstructing
        # g + (m - g) in f32 would not be bitwise m).
        self.use_compression = fed.compressor != "identity"
        self.compressor = (resolve_compressor(fed, model)
                           if self.use_compression else None)

    # ---------------------------------------------------------- local phase
    def batchify(self, bx, by) -> Dict[str, jnp.ndarray]:
        if self.batch_builder is not None:
            return self.batch_builder(bx, by)
        if self.model.cfg.family in ("cnn", "mlp"):
            return {"images": bx, "labels": by}
        return {"tokens": bx, "labels": by}

    def local_train(self, params, bx, by):
        """One client's local phase: ``local_steps`` optimizer steps.

        Backends drive this per client — ``vmap`` over the stacked axis
        on the local backend, directly on each device's shard on the pod
        backends — so the local-training math is shared by construction.
        """
        opt_state = self.opt.init(params)

        def step(carry, xb_yb):
            params, opt_state = carry
            xb, yb = xb_yb
            (loss, _), grads = jax.value_and_grad(
                self.model.loss, has_aux=True)(params,
                                               self.batchify(xb, yb))
            params, opt_state = self.opt.update(grads, opt_state, params)
            return (params, opt_state), loss

        (params, _), losses = jax.lax.scan(step, (params, opt_state),
                                           (bx, by))
        return params, jnp.mean(losses)

    # ------------------------------------------------------- round plumbing
    def select_round(self, keys: RoundKeys, round_idx, scores=None):
        """Per-round tester ids [K] and participation mask [N].

        Shared by every driver (traced on both engines), so tester sets
        and sampled subsets agree bit-exactly for equal keys. ``scores``
        is the ``[N]`` moving-average score vector entering the round —
        replicated on every backend — consumed by score-aware selectors
        (``score_weighted``); score-oblivious policies ignore it. The
        mask is all-ones when ``participation == 1`` — :meth:`run`
        branches on the static config flag, never on the mask values.
        """
        fed = self.fed
        if self._selector_takes_scores:
            tester_ids = self.selector.select(keys.test, fed.num_users,
                                              fed.num_testers, round_idx,
                                              scores=scores)
        else:
            tester_ids = self.selector.select(keys.test, fed.num_users,
                                              fed.num_testers, round_idx)
        if self.use_participation:
            part_mask = participation_mask(keys.part, fed.num_users,
                                           fed.participation)
        else:
            part_mask = jnp.ones((fed.num_users,), jnp.float32)
        return tester_ids, part_mask

    # ------------------------------------------------------------ the round
    def run(self, backend, global_params, scores, *, bx, by, tx, ty,
            tester_ids, part_mask, keys: RoundKeys, round_idx, counts,
            server_data=None, comp_state=None):
        """One FedTest round on ``backend``; steps 1-7, owned here.

        ``bx, by`` are the round's training batches and ``tx, ty`` the
        local test shards, in the backend's client layout (stacked
        ``[N, ...]`` locally, per-device slices under ``shard_map``).
        ``tester_ids`` / ``part_mask`` come from :meth:`select_round`,
        ``keys`` from :func:`round_keys`. ``comp_state`` is the
        replicated ``[N, D]`` error-feedback buffer when the exchange is
        compressed (DESIGN.md §12), ``None`` otherwise. Returns
        ``(new_global, new_scores, new_comp_state, metrics)`` — all
        replicated (``new_comp_state`` is ``None`` when uncompressed).
        """
        fed = self.fed
        pmask = part_mask if self.use_participation else None

        # 2b. fault injection (DESIGN.md §9): the survival mask from the
        # round schedule's keys.fault stream is ANDed into the
        # participation mask *after* selection — a dropped client is a
        # non-sampled client from here on (zero weight, frozen score,
        # masked tester row), so every downstream path is shared code.
        dropped_fraction = jnp.zeros(())
        if self.use_faults:
            alive = self.fault.mask(keys.fault, fed.num_users, round_idx)
            effective = compose_fault_mask(part_mask, alive)
            dropped_fraction = ((jnp.sum(part_mask) - jnp.sum(effective))
                                / jnp.maximum(jnp.sum(part_mask), 1.0))
            pmask = effective

        # 1-2. broadcast + local training; losses come back as a
        # replicated [N] vector whatever the backend topology
        models, local_loss = backend.train(self.local_train, global_params,
                                           bx, by)

        # 3. adversaries act (strategy; malicious set can live anywhere).
        # The AttackContext exposes the cross-testing signal *entering*
        # the round — the scores and the aggregation weights they imply —
        # so adaptive attacks can react to being suppressed.
        actx = AttackContext(scores=scores.scores,
                             weights=score_weights(scores),
                             round_idx=round_idx)
        models = backend.apply_attack(self.attack, keys.attack, models,
                                      global_params, actx)

        # 3b. non-participants transmit nothing this round: whoever
        # evaluates their slot sees the stale global copy — attacked or
        # not, an unsampled client's model never leaves the device.
        if pmask is not None:
            models = backend.mask_models(models, global_params, pmask)

        # 3c. compressed exchange (DESIGN.md §12): each participating
        # client encodes its flat update (with error feedback banked in
        # comp_state) and every consumer from here on — cross-testing,
        # scoring, aggregation — sees only the decoded reconstruction,
        # so all backends stay bit-identical by construction. A masked
        # client transmits nothing: its buffer is untouched and its
        # decoded update is exactly zero (slot == stale global, the 3b
        # semantics).
        new_comp_state = comp_state
        comp_payloads = comp_decoded = None
        if self.use_compression:
            models, comp_payloads, comp_decoded, new_comp_state = (
                backend.compress_exchange(self.compressor, models,
                                          global_params, comp_state,
                                          pmask))

        # 4. the round's testers measure accuracies on their own data.
        # The backend returns the replicated [K, N] matrix A[k, c] (and
        # an opaque cache, e.g. the all-gathered models, that
        # ``backend.updates`` may reuse so nothing is exchanged twice).
        acc, cache = backend.cross_test(self.eval_fn, models, tx, ty,
                                        tester_ids)

        # 5. lying testers (Sec. V-C): users with id < lying_testers
        # report uniform random accuracies whenever selected to test.
        # The matrix is replicated, so this works on every backend.
        if fed.lying_testers:
            lies = jax.random.uniform(keys.lie, acc.shape)
            liar_rows = (tester_ids < fed.lying_testers)[:, None]
            acc = jnp.where(liar_rows, lies, acc)

        # 5b. coalition report-space attack (DESIGN.md §7): members
        # selected as testers rewrite their rows of the replicated
        # matrix (mutual boost + targeted defamation driven by the
        # AttackContext scores). Replicated matrix -> shared code ->
        # bit-identical on every backend.
        if self.coalition_active:
            acc = self.coalition.transform_reports(
                jax.random.fold_in(keys.lie, 1), acc, tester_ids, actx)

        # 6. weights via the aggregation strategy
        server_eval = None
        if self.aggregator.needs_server_eval:
            if server_data is None:
                raise ValueError(
                    f"aggregator {self.aggregator.name!r} needs a "
                    "server-side eval set; pass server_data=(sx, sy)")
            sx, sy = server_data
            server_eval = backend.server_eval(self.eval_fn, models, sx, sy)
        # the [N, D] update matrix is materialised at most once per round
        # and shared between ctx.updates consumers and the combine path
        updates = (backend.updates(models, global_params, cache)
                   if self.needs_updates else None)
        ctx = RoundContext(acc_matrix=acc, tester_ids=tester_ids,
                           scores=scores, counts=counts,
                           round_idx=round_idx, key=keys.agg,
                           updates=updates, server_eval=server_eval,
                           participation=pmask,
                           report_mask=(pmask[tester_ids]
                                        if pmask is not None else None))
        # non-sampled clients' scores freeze inside update_scores
        # (client_mask=ctx.participation): no evidence about an absent
        # client — a suppressed attacker stays suppressed while it sits
        # out. One code path for every backend.
        new_scores = self.aggregator.update_scores(ctx)
        ctx = ctx._replace(scores=new_scores)
        weights = self.aggregator.weights(ctx)
        if pmask is not None:
            weights = renormalize_over_subset(weights, pmask)

        # 7. aggregation -> new global model: the per-coordinate combine
        # fast path runs replicated on the [N, D] matrix (identical on
        # every backend); the weights path reduces through the backend
        # (fused weighted sum locally, one psum on the pod).
        if self.uses_combine:
            new_global = tree_add_vector(
                global_params, self.aggregator.combine(ctx, updates))
        elif self.use_compression:
            # compressed weights path: aggregate in *update space* from
            # the wire representation (the fused dequant_aggregate
            # kernel for int8 — the f32 [C, D] stack never hits HBM),
            # then one tree_add_vector back into model space. Same
            # formula on every backend (local kernel == pod psum, the
            # §3 replication contract).
            new_global = tree_add_vector(
                global_params,
                backend.compressed_sum(self.compressor, comp_payloads,
                                       comp_decoded, weights, models,
                                       self.agg_impl))
        else:
            new_global = backend.weighted_sum(models, weights,
                                              global_params, self.agg_impl)

        # the malicious index set comes from the attack strategy, so the
        # metric stays correct for any placement of the attackers.
        mal_w = (jnp.sum(weights * self.malicious_mask)
                 if self.malicious_idx else jnp.zeros(()))
        # losses of non-participants are discarded work (their training
        # never left the device) — the mean runs over the sampled subset
        metrics = {
            "local_loss": (jnp.sum(local_loss * pmask)
                           / jnp.maximum(jnp.sum(pmask), 1)
                           if pmask is not None
                           else jnp.mean(local_loss)),
            "acc_matrix_mean": jnp.mean(acc),
            "weights": weights,
            "malicious_weight": mal_w,
            "scores": new_scores.scores,
            "participation_rate": (jnp.mean(pmask)
                                   if pmask is not None
                                   else jnp.ones(())),
            # fraction of *selected* clients lost to faults this round
            # (0 under fault='none'; DESIGN.md §9)
            "dropped_fraction": dropped_fraction,
        }
        return new_global, new_scores, new_comp_state, metrics
