"""Population tier: cohort-sampled rounds that never replicate [N, D].

The dense backends materialise all N client models per device — the
O(N) replication wall (``benchmarks/bench_comm.py`` prices it at
(N−1)×model for ring and N×model peak for allgather). This tier rides
the observation that a FedTest round only ever *computes* on the
sampled cohort: per-round Bernoulli sampling (the existing
``participation_mask``) selects C ≪ N clients, and every non-sampled
client already has fully-defined free semantics — zero aggregation
weight (``renormalize_over_subset``), frozen score
(``update_scores``'s ``client_mask``), masked tester row, and a
cross-test column that equals the *global* model's accuracy (a
non-participant transmits nothing, so whoever evaluates its slot sees
the stale global copy — exactly what ``mask_models`` produces on the
dense backends).

So the round runs on a gathered ``[C, ...]`` model stack
(:class:`CohortModels`) while population state stays a dense ``[N]``
``ScoreState`` that only the cohort's rows touch:

* **gather**  — ``cohort_from_mask`` turns the round's participation
  mask into cohort slot indices; training batches and the model stack
  are gathered to ``[C]``, never broadcast to ``[N]``.
* **compute** — the unchanged :class:`RoundProgram` drives
  :class:`PopulationBackend`: vmapped local training / per-slot
  attacks over ``[C]``, cross-testing streamed through
  :func:`~repro.core.cross_testing.cross_test_tiled` in
  ``[K, block_C]`` tiles, aggregation as a fused weighted sum over the
  cohort stack (bitwise equal to the full-population sum because every
  other summand has weight exactly 0).
* **scatter** — cohort columns are scattered into a global-accuracy
  base matrix and cohort losses into zeros, reconstructing the full
  replicated ``[K, N]`` / ``[N]`` arrays the program scores — bitwise
  identical to the dense ``local`` backend (``tests/test_population.py``
  pins weights, scores, trust and malicious_weight), so convergence
  *and* adversarial suppression carry over by construction, at
  per-round cost flat in N (``benchmarks/bench_population.py``).

Sharding: with a ``mesh``, the cohort axis is annotated with
``with_sharding_constraint`` so GSPMD splits the [C] stack, batches and
eval tiles across a ``clients`` mesh axis — the multi-device smoke in
CI. Cross-device reductions are not bitwise-stable, so the parity
matrix runs unsharded; the sharded path is gated on suppression
(``--assert-malicious-below``), not bit-equality. DESIGN.md §11.
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core.cross_testing import CROSSTEST_IMPLS, cross_test_tiled
from repro.core.engine.backends import ExchangeBackend, _flatten_updates
from repro.core.engine.driver import FederatedTrainer, RoundState
from repro.core.engine.program import round_keys
from repro.kernels.weighted_aggregate import aggregate_pytree
from repro.utils.pytree import tree_add_vector


def cohort_from_mask(part_mask: jnp.ndarray, capacity: int
                     ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Round participation mask [N] -> cohort plan.

    Returns ``(idx, valid, eff_mask)``:

    * ``idx [capacity]`` — population indices of the sampled clients in
      ascending order, padded with the sentinel ``N`` for unfilled
      slots (static shape: the cohort buffer is a fixed ``capacity``
      wide so the round compiles once).
    * ``valid [capacity]`` — 1.0 where the slot holds a real client.
    * ``eff_mask [N]`` — the mask actually honoured this round: when
      the Bernoulli draw oversubscribes the buffer, clients beyond the
      first ``capacity`` sampled (in index order) are truncated back to
      non-sampled — they keep the full non-sampled semantics (zero
      weight, frozen score, masked tester row), exactly as if the
      coin had come up tails. When the draw fits, ``eff_mask`` is
      bitwise ``part_mask``, which is what the small-N parity matrix
      relies on.
    """
    n = part_mask.shape[0]
    ids = jnp.where(part_mask > 0, jnp.arange(n, dtype=jnp.int32),
                    jnp.int32(n))
    idx = jnp.sort(ids)[:capacity]
    valid = (idx < n).astype(jnp.float32)
    kept = (jnp.cumsum(part_mask) <= capacity).astype(part_mask.dtype)
    return idx, valid, part_mask * kept


class CohortModels(NamedTuple):
    """The population tier's opaque model handle: a [C] gathered stack.

    ``idx`` maps cohort slots to population indices (sentinel N =
    unfilled slot), ``valid`` flags real slots, ``global_ref`` is the
    round's broadcast source — the value every non-cohort column of the
    accuracy matrix must report.
    """

    stack: Any              # param pytree, leaves [C, ...]
    idx: jnp.ndarray        # [C] int32 population index (N = unfilled)
    valid: jnp.ndarray      # [C] float32 1/0
    global_ref: Any         # unstacked global params


class PopulationBackend(ExchangeBackend):
    """Cohort-gather exchange: compute on [C], report as [N] / [K, N].

    The :class:`RoundProgram` contract is unchanged — replicated
    population-indexed arrays cross the seam, model pytrees stay
    opaque — so every semantic step (attacks, lying testers,
    coalitions, scoring, trust) is byte-for-byte the shared code path.
    ``tx``/``ty`` arrive pre-gathered to the K tester rows (the
    population driver holds no [N, eval_batch] test stack), which is
    why ``cross_test`` ignores ``tester_ids``.
    """

    name = "population"

    def __init__(self, num_users: int, capacity: int,
                 crosstest_impl: str = "batched", *, block: int = 0,
                 mesh=None, axis: str = "clients"):
        if crosstest_impl not in CROSSTEST_IMPLS:
            raise ValueError(f"crosstest_impl must be one of "
                             f"{CROSSTEST_IMPLS}, got {crosstest_impl!r}")
        if not 1 <= capacity <= num_users:
            raise ValueError(
                f"cohort capacity must be in [1, num_users={num_users}], "
                f"got {capacity}")
        self.num_users = num_users
        self.capacity = capacity
        self.crosstest_impl = crosstest_impl
        self.block = block
        self.mesh = mesh
        self.axis = axis

    # --------------------------------------------------------- sharding
    def _constrain(self, tree):
        """Annotate leading-[C] leaves for GSPMD cohort sharding."""
        if self.mesh is None:
            return tree
        s = NamedSharding(self.mesh, P(self.axis))
        return jax.tree_util.tree_map(
            lambda t: jax.lax.with_sharding_constraint(t, s), tree)

    # --------------------------------------------------- backend protocol
    def train(self, local_train, global_params, bx, by):
        # the driver packs the cohort plan with the gathered batches:
        # bx = (idx [C], valid [C], cohort batches [C, steps, batch, ...])
        idx, valid, cx = bx
        stack = jax.tree_util.tree_map(
            lambda x: jnp.broadcast_to(x[None],
                                       (self.capacity,) + x.shape),
            global_params)
        stack = self._constrain(stack)
        cx, cy = self._constrain(cx), self._constrain(by)
        stack, loss = jax.vmap(local_train)(stack, cx, cy)
        # non-cohort losses report 0 — they are zero-masked by the
        # program's sampled-subset mean anyway, so the metric matches
        # the dense path bitwise
        losses = jnp.zeros((self.num_users,), loss.dtype
                           ).at[idx].set(loss, mode="drop")
        return CohortModels(stack, idx, valid, global_params), losses

    def _safe_idx(self, models: CohortModels) -> jnp.ndarray:
        # clamp sentinel slots to a real index for gathers; their
        # results never escape (zero weight / dropped scatters)
        return jnp.minimum(models.idx, self.num_users - 1)

    def apply_attack(self, attack, key, models, global_params, actx):
        safe = self._safe_idx(models)
        stack = jax.vmap(
            lambda p, c: attack.apply_local(key, p, global_params, c,
                                            self.num_users, actx)
        )(models.stack, safe)
        return models._replace(stack=self._constrain(stack))

    def mask_models(self, models, global_params, part_mask):
        my_part = part_mask[self._safe_idx(models)]
        stack = jax.tree_util.tree_map(
            lambda t, g: jnp.where(
                my_part.reshape((-1,) + (1,) * (t.ndim - 1)) > 0,
                t, g[None].astype(t.dtype)),
            models.stack, global_params)
        return models._replace(stack=self._constrain(stack))

    def cross_test(self, eval_fn, models, tx, ty, tester_ids):
        acc_c = cross_test_tiled(eval_fn, models.stack, tx, ty,
                                 block=self.block,
                                 impl=self.crosstest_impl)       # [K, C]
        # non-cohort columns: a client that transmitted nothing is seen
        # as the stale global copy, so its column is the tester's
        # accuracy on the *global* model — the same value the dense
        # backends produce for masked slots (vmap-vs-plain eval is
        # bitwise stable; pinned by tests/test_population.py). The full
        # [K, N] matrix is therefore bit-identical to the dense path,
        # and everything downstream of it (lies, coalition transforms,
        # scores, trust) is shared code on identical inputs.
        base = jax.vmap(lambda x, y: eval_fn(models.global_ref, x, y)
                        )(tx, ty)                                # [K]
        acc = jnp.broadcast_to(base[:, None],
                               (base.shape[0], self.num_users))
        acc = acc.at[:, models.idx].set(acc_c, mode="drop")
        return acc, None

    def updates(self, models, global_params, cache):
        raise NotImplementedError(
            "the population tier refuses to materialise the [N, D] "
            "update matrix — aggregators that need it (krum, "
            "trimmed_mean, median, the robust combine fast path) ARE "
            "the O(N) replication wall this tier exists to break. Use "
            "a score-weighted aggregator (fedtest/fedavg/...) or the "
            "dense backends.")

    def server_eval(self, eval_fn, models, sx, sy):
        def thunk():
            accs = jax.vmap(lambda p: eval_fn(p, sx, sy))(models.stack)
            base = eval_fn(models.global_ref, sx, sy)
            out = jnp.full((self.num_users,), base, accs.dtype)
            return out.at[models.idx].set(accs, mode="drop")
        return thunk

    def weighted_sum(self, models, weights, global_params, impl):
        # weights is the renormalised [N] simplex with exact zeros
        # outside the (effective) cohort, so summing over the gathered
        # stack is bitwise the full-population sum; sentinel slots are
        # zeroed by `valid` (their gathered weight is a real client's).
        w = weights[self._safe_idx(models)] * models.valid
        return aggregate_pytree(models.stack, w, impl=impl)

    def compress_exchange(self, compressor, models, global_params,
                          comp_state, part_mask):
        # the error-feedback buffer stays population-dense [N, D] (it
        # is per-client *state*, like scores — only the cohort's rows
        # are gathered, encoded and scattered back each round;
        # DESIGN.md §12 documents the memory trade)
        safe = self._safe_idx(models)
        updates = _flatten_updates(models.stack, global_params)  # [C, D]
        state_rows = comp_state[safe]                            # [C, D]
        payloads, new_rows = jax.vmap(compressor.encode)(state_rows,
                                                         updates)
        decoded = jax.vmap(compressor.decode)(payloads)          # [C, D]
        eff = models.valid * (part_mask[safe]
                              if part_mask is not None else 1.0)
        keep = (eff > 0)[:, None]
        # masked / sentinel slots transmitted nothing: buffer rows stay
        # (scattering the gathered row back is a bitwise no-op) and the
        # decoded update is exactly zero
        new_rows = jnp.where(keep, new_rows, state_rows)
        decoded = jnp.where(keep, decoded, 0.0)
        new_state = comp_state.at[models.idx].set(new_rows, mode="drop")
        stack = jax.vmap(
            lambda v: tree_add_vector(global_params, v))(decoded)
        return (models._replace(stack=self._constrain(stack)),
                payloads, decoded, new_state)

    def compressed_sum(self, compressor, payloads, decoded, weights,
                       models, impl):
        # same zero-outside-cohort argument as weighted_sum: the [N]
        # simplex gathered to the cohort rows loses only exact-zero
        # summands
        w = weights[self._safe_idx(models)] * models.valid
        return compressor.aggregate(payloads, decoded, w, impl)


@dataclasses.dataclass
class PopulationTrainer(FederatedTrainer):
    """Single-host driver for the population tier (DESIGN.md §11).

    A :class:`FederatedTrainer` whose round body gathers the sampled
    cohort before the program runs: the full-population Bernoulli draw
    and batch-index draw are unchanged (same ``RoundKeys`` streams, so
    trajectories are comparable with the dense driver bit-for-bit at
    small N), but only the cohort's rows of the batch data are ever
    materialised. Population state — ``ScoreState``, the PRNG schedule,
    the round index — stays the dense :class:`RoundState`, so
    checkpointing, manifests and bit-identical resume are inherited
    wholesale from the durable-service machinery (DESIGN.md §9).

    ``cohort`` (0 = ``fed.cohort``, else override) is the static slot
    capacity; ``crosstest_block`` streams tester eval in
    ``[K, block_C]`` tiles; ``mesh`` shards the cohort axis via GSPMD.
    Data comes from a population provider
    (:class:`repro.data.population.DensePopulationData` /
    :class:`~repro.data.population.SyntheticPopulation`) rather than a
    materialised :class:`FederatedDataset`.
    """

    cohort: int = 0
    crosstest_block: int = 0
    mesh: Any = None
    # At C ≪ N a population-wide tester is almost never in the cohort,
    # so every report row is participation-masked and the cohort's
    # scores degenerate to zero (uniform-over-cohort aggregation — no
    # suppression). This opt-in remaps the selector's tester ids onto
    # cohort members (slot = selected id mod cohort size), recruiting
    # the round's testing committee from the active cohort. Off by
    # default: the remap changes which clients test, so it would break
    # the bitwise small-N parity with the dense selector semantics.
    testers_from_cohort: bool = False

    def __post_init__(self):
        self.capacity = self.cohort or self.fed.cohort or self.fed.num_users
        if not 1 <= self.capacity <= self.fed.num_users:
            raise ValueError(
                f"cohort={self.capacity} must be in [1, "
                f"num_users={self.fed.num_users}]")
        if self.capacity < self.fed.num_users and self.fed.participation >= 1:
            raise ValueError(
                "cohort < num_users requires participation < 1.0 — with "
                "everyone sampled every round, truncation to the cohort "
                "buffer would silently bias toward low client indices. "
                "Set FedConfig.participation ≈ cohort/num_users.")
        if self.eval_resample_every:
            raise ValueError(
                "eval_resample_every is a dense-driver feature (it draws "
                "[N, eval_batch] gather indices); the population tier "
                "gathers tester rows directly")
        super().__post_init__()
        if self.program.needs_updates:
            raise ValueError(
                f"aggregator {self.program.aggregator.name!r} needs the "
                "[N, D] update matrix — the population tier refuses it "
                "(that matrix is the replication wall). Use a "
                "score-weighted aggregator or the dense backends.")

    def _make_backend(self, impl: str):
        return PopulationBackend(self.fed.num_users, self.capacity, impl,
                                 block=self.crosstest_block,
                                 mesh=self.mesh)

    def _round_body(self, state: RoundState, data):
        self.num_traces += 1
        fed = self.fed
        keys = round_keys(jax.random.fold_in(state.key, state.round_idx))
        tester_ids, part_mask = self.program.select_round(
            keys, state.round_idx, scores=state.scores.scores)
        idx, valid, eff_mask = cohort_from_mask(part_mask, self.capacity)
        if self.testers_from_cohort:
            pop_count = jnp.maximum(jnp.sum(valid).astype(jnp.int32), 1)
            tester_ids = jnp.minimum(idx[tester_ids % pop_count],
                                     fed.num_users - 1)
        safe = jnp.minimum(idx, fed.num_users - 1)
        # the dense engine's exact batch-index draw
        # (data.pipeline.sample_client_batches), gathered down to the
        # cohort rows: the uniform draw stays [N, steps, batch] (cheap —
        # floats, not images) so keys.batch produces bit-identical
        # per-client indices, but only O(C) batch *data* is gathered.
        counts = data.train_counts
        u = jax.random.uniform(keys.batch,
                               (fed.num_users, fed.local_steps,
                                self.train.batch_size))
        bidx = (u * counts[:, None, None]).astype(jnp.int32)[safe]
        cx, cy = data.cohort_train(safe)
        bx = jax.vmap(lambda x, i: x[i])(cx, bidx)
        by = jax.vmap(lambda y, i: y[i])(cy, bidx)
        tx, ty = data.tester_batches(tester_ids, self.eval_batch)
        new_global, new_scores, new_comp, metrics = self.program.run(
            self.backend, state.global_params, state.scores,
            bx=(idx, valid, bx), by=by, tx=tx, ty=ty,
            tester_ids=tester_ids, part_mask=eff_mask, keys=keys,
            round_idx=state.round_idx, counts=counts,
            server_data=data.server_batch(self.eval_batch),
            comp_state=state.comp_state)
        new_state = RoundState(global_params=new_global, scores=new_scores,
                               round_idx=state.round_idx + 1,
                               key=state.key, comp_state=new_comp)
        return new_state, metrics
