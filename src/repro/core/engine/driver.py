"""Single-host drivers for the unified round engine.

:class:`FederatedTrainer` drives the backend-agnostic
:class:`~repro.core.engine.program.RoundProgram` on the
:class:`~repro.core.engine.backends.LocalBackend` (clients vectorised
with ``vmap``). Two compiled drivers share one round body:

* the **single-round driver** (``run_round``) — one jitted round per
  call, the interactive / test path;
* the **scanned multi-round driver** — ``lax.scan`` over
  ``rounds_per_call`` rounds with donated state buffers, so steady-state
  training dispatches one fused program per chunk instead of one per
  round (``benchmarks/bench_convergence.py`` measures the per-round
  dispatch amortisation; DESIGN.md §2 documents the driver).

Both drivers trace the round body exactly once; ``num_traces`` counts
body traces and ``run`` raises when any compiled driver retraces.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.config import FedConfig, TrainConfig
from repro.core.engine.backends import LocalBackend
from repro.core.engine.program import RoundProgram, round_keys
from repro.core.scoring import ScoreState, init_scores
from repro.data.pipeline import FederatedDataset, sample_client_batches


class RoundState(NamedTuple):
    global_params: Any
    scores: ScoreState
    round_idx: jnp.ndarray
    key: jnp.ndarray


@dataclasses.dataclass
class FederatedTrainer:
    model: Any                      # repro.models.Model
    fed: FedConfig
    train: TrainConfig
    agg_impl: str = "auto"
    eval_batch: int = 256
    use_trust: bool = False
    batch_builder: Optional[Callable] = None   # (bx, by) -> model batch
    rounds_per_call: int = 1        # >1 routes run() through lax.scan

    def __post_init__(self):
        # the program resolves every strategy once, pre-trace (the jitted
        # drivers close over it), and builds the one shared eval fn
        self.program = RoundProgram(
            self.model, self.fed, self.train, use_trust=self.use_trust,
            agg_impl=self.agg_impl, batch_builder=self.batch_builder)
        self.backend = LocalBackend(self.fed.num_users)
        # strategy handles (public API, also used by tests/benchmarks)
        self.opt = self.program.opt
        self.aggregator = self.program.aggregator
        self.attack = self.program.attack
        self.selector = self.program.selector
        self.coalition = self.program.coalition
        self.num_traces = 0
        self._round_fn = jax.jit(self._round_body)
        # the scanned driver donates the carried RoundState so XLA can
        # reuse the global-model and score buffers across chunks
        self._scan_fn = (jax.jit(self._multi_round, donate_argnums=0)
                         if self.rounds_per_call > 1 else None)
        self._global_eval = jax.jit(self._global_eval_impl)

    # ------------------------------------------------------------------ init
    def init(self, key) -> RoundState:
        pk, rk = jax.random.split(key)
        params = self.model.init(pk)
        return RoundState(global_params=params,
                          scores=init_scores(self.fed.num_users),
                          round_idx=jnp.zeros((), jnp.int32),
                          key=rk)

    # ------------------------------------------------------------- internals
    def _round_body(self, state: RoundState, data: FederatedDataset):
        self.num_traces += 1        # python side-effect: runs per trace only
        fed = self.fed
        keys = round_keys(jax.random.fold_in(state.key, state.round_idx))
        tester_ids, part_mask = self.program.select_round(
            keys, state.round_idx, scores=state.scores.scores)
        bx, by = sample_client_batches(keys.batch, data.train,
                                       fed.local_steps,
                                       self.train.batch_size)
        new_global, new_scores, metrics = self.program.run(
            self.backend, state.global_params, state.scores,
            bx=bx, by=by,
            tx=data.test.xs[:, :self.eval_batch],
            ty=data.test.ys[:, :self.eval_batch],
            tester_ids=tester_ids, part_mask=part_mask, keys=keys,
            round_idx=state.round_idx, counts=data.train.counts,
            server_data=(data.server_x[:self.eval_batch],
                         data.server_y[:self.eval_batch]))
        new_state = RoundState(global_params=new_global, scores=new_scores,
                               round_idx=state.round_idx + 1,
                               key=state.key)
        return new_state, metrics

    def _multi_round(self, state: RoundState, data: FederatedDataset):
        """``rounds_per_call`` rounds as one fused scanned program."""
        def body(s, _):
            return self._round_body(s, data)
        return jax.lax.scan(body, state, None,
                            length=self.rounds_per_call)

    def _global_eval_impl(self, params, gx, gy):
        return self.program.eval_fn(params, gx, gy)

    # ------------------------------------------------------------------- API
    def run_round(self, state: RoundState, data: FederatedDataset):
        return self._round_fn(state, data)

    def global_accuracy(self, state: RoundState, data: FederatedDataset,
                        max_samples: int = 2048) -> float:
        return float(self._global_eval(state.global_params,
                                       data.global_x[:max_samples],
                                       data.global_y[:max_samples]))

    def run(self, key, data: FederatedDataset, rounds: Optional[int] = None,
            eval_every: int = 1, verbose: bool = False):
        """Full training loop; returns (final_state, history dict).

        With ``rounds_per_call > 1`` the steady state runs through the
        scanned driver — per-round scalar metrics still cover every
        round (the scan stacks them), global accuracy is evaluated at
        driver-call boundaries. A remainder of ``rounds %
        rounds_per_call`` rounds falls back to the single-round driver
        (a second compiled program, still one trace each).
        """
        rounds = rounds if rounds is not None else self.fed.rounds
        state = self.init(key)
        history = {"round": [], "global_accuracy": [], "local_loss": [],
                   "malicious_weight": []}
        programs_used = set()
        done = 0
        while done < rounds:
            if (self._scan_fn is not None
                    and rounds - done >= self.rounds_per_call):
                state, chunk = self._scan_fn(state, data)
                programs_used.add("scan")
                step = self.rounds_per_call
                metrics = {k: v[-1] for k, v in chunk.items()}
            else:
                state, metrics = self._round_fn(state, data)
                programs_used.add("single")
                step = 1
            done += step
            if done % eval_every == 0 or done >= rounds or step > 1:
                ga = self.global_accuracy(state, data)
                history["round"].append(done)
                history["global_accuracy"].append(ga)
                history["local_loss"].append(float(metrics["local_loss"]))
                history["malicious_weight"].append(
                    float(metrics["malicious_weight"]))
                if verbose:
                    print(f"round {done:4d}  acc={ga:.4f}  "
                          f"loss={float(metrics['local_loss']):.4f}  "
                          f"mal_w={float(metrics['malicious_weight']):.4f}")
        if rounds > 1 and self.num_traces > max(1, len(programs_used)):
            raise RuntimeError(
                f"round engine retraced: {self.num_traces} body traces "
                f"over {rounds} rounds across {len(programs_used)} "
                "compiled driver(s) — strategy resolution must stay "
                "pre-trace")
        return state, history
