"""Single-host drivers for the unified round engine.

:class:`FederatedTrainer` drives the backend-agnostic
:class:`~repro.core.engine.program.RoundProgram` on the
:class:`~repro.core.engine.backends.LocalBackend` (clients vectorised
with ``vmap``). Two compiled drivers share one round body:

* the **single-round driver** (``run_round``) — one jitted round per
  call, the interactive / test path;
* the **scanned multi-round driver** — ``lax.scan`` over
  ``rounds_per_call`` rounds with donated state buffers, so steady-state
  training dispatches one fused program per chunk instead of one per
  round (``benchmarks/bench_convergence.py`` measures the per-round
  dispatch amortisation; DESIGN.md §2 documents the driver).

Both drivers trace the round body exactly once; ``num_traces`` counts
body traces and ``run`` raises when any compiled driver retraces.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.manifest import check_manifest, run_manifest
from repro.config import FedConfig, TrainConfig
from repro.core.cross_testing import sampled_eval_batches
from repro.core.engine.backends import LocalBackend
from repro.core.engine.program import RoundProgram, round_keys
from repro.core.scoring import ScoreState, init_scores
from repro.data.pipeline import FederatedDataset, sample_client_batches


class RoundState(NamedTuple):
    global_params: Any
    scores: ScoreState
    round_idx: jnp.ndarray
    key: jnp.ndarray
    # per-client [N, D] error-feedback buffer of the compressed
    # exchange (DESIGN.md §12); None — an empty pytree node that
    # threads through scan/checkpoint for free — when uncompressed.
    # Defaulted so uncompressed constructions stay source-compatible.
    comp_state: Any = None


@dataclasses.dataclass
class FederatedTrainer:
    model: Any                      # repro.models.Model
    fed: FedConfig
    train: TrainConfig
    agg_impl: str = "auto"
    eval_batch: int = 256
    use_trust: bool = False
    batch_builder: Optional[Callable] = None   # (bx, by) -> model batch
    rounds_per_call: int = 1        # >1 routes run() through lax.scan
    crosstest_impl: Optional[str] = None  # None -> fed.crosstest_impl
    # 0 keeps the legacy fixed eval prefix (first eval_batch test rows,
    # every round); r > 0 draws schedule-keyed per-tester eval batches
    # that resample every r rounds (DESIGN.md §10)
    eval_resample_every: int = 0

    def __post_init__(self):
        # the program resolves every strategy once, pre-trace (the jitted
        # drivers close over it), and builds the one shared eval fn
        self.program = RoundProgram(
            self.model, self.fed, self.train, use_trust=self.use_trust,
            agg_impl=self.agg_impl, batch_builder=self.batch_builder)
        impl = self.crosstest_impl or getattr(self.fed, "crosstest_impl",
                                              "batched")
        self.backend = self._make_backend(impl)
        # strategy handles (public API, also used by tests/benchmarks)
        self.opt = self.program.opt
        self.aggregator = self.program.aggregator
        self.attack = self.program.attack
        self.selector = self.program.selector
        self.coalition = self.program.coalition
        self.num_traces = 0
        self._round_fn = jax.jit(self._round_body)
        # the scanned driver donates the carried RoundState so XLA can
        # reuse the global-model and score buffers across chunks
        self._scan_fn = (jax.jit(self._multi_round, donate_argnums=0)
                         if self.rounds_per_call > 1 else None)
        self._global_eval = jax.jit(self._global_eval_impl)

    def _make_backend(self, impl: str):
        """Backend factory hook — the population tier overrides this."""
        return LocalBackend(self.fed.num_users, impl)

    # ------------------------------------------------------------------ init
    def init(self, key) -> RoundState:
        pk, rk = jax.random.split(key)
        params = self.model.init(pk)
        comp = (self.program.compressor.init_state(self.fed.num_users)
                if self.program.use_compression else None)
        return RoundState(global_params=params,
                          scores=init_scores(self.fed.num_users),
                          round_idx=jnp.zeros((), jnp.int32),
                          key=rk, comp_state=comp)

    # -------------------------------------------------------- durability
    def manifest(self):
        """Resume-compatibility fingerprint for this trainer's run
        (DESIGN.md §9); stored next to checkpoints and checked by
        ``restore_checkpoint``."""
        return run_manifest(self.model.cfg, self.fed, self.train,
                            use_trust=self.use_trust)

    def state_template(self) -> RoundState:
        """Abstract (shape/dtype-only) RoundState — the template
        ``load_pytree`` restores into. Built via ``eval_shape`` so no
        params are materialised and no PRNG key is consumed."""
        abstract_key = jax.ShapeDtypeStruct((2,), jnp.uint32)
        return jax.eval_shape(self.init, abstract_key)

    def state_dict(self, state: RoundState) -> dict:
        """Host-side (numpy) copy of the complete round state — global
        params, ScoreState (incl. tester trust), round_idx, PRNG key."""
        return {k: jax.tree_util.tree_map(np.asarray, v)
                for k, v in state._asdict().items()}

    def load_state(self, state_dict: dict) -> RoundState:
        """Rebuild a device RoundState from ``state_dict``, casting to
        this trainer's template dtypes; refuses shape mismatches."""
        tmpl = self.state_template()

        def cast(t, leaf):
            leaf = jnp.asarray(leaf)
            if tuple(leaf.shape) != tuple(t.shape):
                raise ValueError(
                    f"state leaf shape {leaf.shape} != template "
                    f"{t.shape} — state from a different run?")
            return leaf.astype(t.dtype)

        # comp_state is absent from pre-§12 state dicts; its default
        # (None) is only valid when this trainer runs uncompressed
        loaded = RoundState(**{k: state_dict[k] for k in tmpl._fields
                               if k in state_dict})
        return jax.tree_util.tree_map(cast, tmpl, loaded)

    def save_checkpoint(self, mgr, state: RoundState,
                        step: Optional[int] = None) -> str:
        """Atomically persist ``state`` (at its own round_idx unless
        ``step`` overrides) plus the run manifest."""
        step = int(state.round_idx) if step is None else int(step)
        return mgr.save(step, state, manifest=self.manifest())

    def restore_checkpoint(self, mgr, step: Optional[int] = None):
        """Restore ``(state, step)`` from the newest loadable
        checkpoint, refusing a manifest mismatch (different config or
        architecture) before touching any arrays."""
        saved = mgr.read_manifest()
        if saved is not None:
            check_manifest(saved, self.manifest())
        return mgr.restore_with_step(self.state_template(), step)

    # ------------------------------------------------------------- internals
    def _round_body(self, state: RoundState, data: FederatedDataset):
        self.num_traces += 1        # python side-effect: runs per trace only
        fed = self.fed
        keys = round_keys(jax.random.fold_in(state.key, state.round_idx))
        tester_ids, part_mask = self.program.select_round(
            keys, state.round_idx, scores=state.scores.scores)
        bx, by = sample_client_batches(keys.batch, data.train,
                                       fed.local_steps,
                                       self.train.batch_size)
        if self.eval_resample_every > 0:
            # schedule-keyed eval batches: a pure function of the carried
            # run key and the round bucket, derived in-trace — nothing is
            # stashed, so resume stays bit-identical (DESIGN.md §10)
            tx, ty = sampled_eval_batches(
                state.key, data.test, self.eval_batch, state.round_idx,
                self.eval_resample_every)
        else:
            tx = data.test.xs[:, :self.eval_batch]
            ty = data.test.ys[:, :self.eval_batch]
        new_global, new_scores, new_comp, metrics = self.program.run(
            self.backend, state.global_params, state.scores,
            bx=bx, by=by, tx=tx, ty=ty,
            tester_ids=tester_ids, part_mask=part_mask, keys=keys,
            round_idx=state.round_idx, counts=data.train.counts,
            server_data=(data.server_x[:self.eval_batch],
                         data.server_y[:self.eval_batch]),
            comp_state=state.comp_state)
        new_state = RoundState(global_params=new_global, scores=new_scores,
                               round_idx=state.round_idx + 1,
                               key=state.key, comp_state=new_comp)
        return new_state, metrics

    def _multi_round(self, state: RoundState, data: FederatedDataset):
        """``rounds_per_call`` rounds as one fused scanned program."""
        def body(s, _):
            return self._round_body(s, data)
        return jax.lax.scan(body, state, None,
                            length=self.rounds_per_call)

    def _global_eval_impl(self, params, gx, gy):
        return self.program.eval_fn(params, gx, gy)

    # ------------------------------------------------------------------- API
    def run_round(self, state: RoundState, data: FederatedDataset):
        return self._round_fn(state, data)

    def global_accuracy(self, state: RoundState, data: FederatedDataset,
                        max_samples: int = 2048) -> float:
        return float(self._global_eval(state.global_params,
                                       data.global_x[:max_samples],
                                       data.global_y[:max_samples]))

    def run(self, key, data: FederatedDataset, rounds: Optional[int] = None,
            eval_every: int = 1, verbose: bool = False,
            state: Optional[RoundState] = None, ckpt=None,
            should_stop: Optional[Callable[[], bool]] = None):
        """Full training loop; returns (final_state, history dict).

        With ``rounds_per_call > 1`` the steady state runs through the
        scanned driver — per-round scalar metrics still cover every
        round (the scan stacks them), global accuracy is evaluated at
        driver-call boundaries. A remainder of ``rounds %
        rounds_per_call`` rounds falls back to the single-round driver
        (a second compiled program, still one trace each).

        Durability (DESIGN.md §9): pass ``state`` (e.g. from
        ``restore_checkpoint``) to resume — ``rounds`` is the *total*
        target, so a state at round k runs k..rounds and the result is
        bit-identical to an uninterrupted run (the round body re-derives
        every key from the carried ``state.key`` and ``round_idx``).
        ``ckpt`` is a :class:`~repro.checkpoint.CheckpointManager` whose
        ``save_every`` cadence is honoured at driver-call boundaries;
        ``should_stop()`` is polled between driver calls so a signal
        handler can end the loop cleanly (the caller saves the returned
        state).
        """
        rounds = rounds if rounds is not None else self.fed.rounds
        if state is None:
            state = self.init(key)
        history = {"round": [], "global_accuracy": [], "local_loss": [],
                   "malicious_weight": []}
        programs_used = set()
        done = int(state.round_idx)
        if ckpt is not None and ckpt.read_manifest() is None:
            ckpt.write_manifest(self.manifest())
        while done < rounds:
            if should_stop is not None and should_stop():
                break
            if (self._scan_fn is not None
                    and rounds - done >= self.rounds_per_call):
                state, chunk = self._scan_fn(state, data)
                programs_used.add("scan")
                step = self.rounds_per_call
                metrics = {k: v[-1] for k, v in chunk.items()}
            else:
                state, metrics = self._round_fn(state, data)
                programs_used.add("single")
                step = 1
            done += step
            if ckpt is not None:
                ckpt.maybe_save(done, state)
            if done % eval_every == 0 or done >= rounds or step > 1:
                ga = self.global_accuracy(state, data)
                history["round"].append(done)
                history["global_accuracy"].append(ga)
                history["local_loss"].append(float(metrics["local_loss"]))
                history["malicious_weight"].append(
                    float(metrics["malicious_weight"]))
                if verbose:
                    print(f"round {done:4d}  acc={ga:.4f}  "
                          f"loss={float(metrics['local_loss']):.4f}  "
                          f"mal_w={float(metrics['malicious_weight']):.4f}")
        if rounds > 1 and self.num_traces > max(1, len(programs_used)):
            raise RuntimeError(
                f"round engine retraced: {self.num_traces} body traces "
                f"over {rounds} rounds across {len(programs_used)} "
                "compiled driver(s) — strategy resolution must stay "
                "pre-trace")
        return state, history
