"""The unified FedTest round engine (DESIGN.md §2 and §3).

One backend-agnostic :class:`RoundProgram` owns the round's semantics —
participation mask, attack application (through :class:`AttackContext`),
lying testers, score update, subset renormalisation, metrics — exactly
once; three :class:`ExchangeBackend` implementations supply the
topology-specific mechanics:

* ``local``     — single-host ``vmap`` over a stacked client axis
  (driven by :class:`FederatedTrainer`, including the scanned
  multi-round driver);
* ``ring``      — one client per device under ``shard_map``,
  cross-testing via ``ppermute`` hops (``make_distributed_round``);
* ``allgather`` — the paper-faithful broadcast exchange
  (``make_allgather_round``).

``tests/test_pod_parity.py`` pins the three backends bit-identical on
weights, scores and malicious-weight trajectories across the
attack x participation matrix.

Above the dense backends sits the **population tier** (DESIGN.md §11):
:class:`PopulationBackend` / :class:`PopulationTrainer` run the same
``RoundProgram`` on a gathered [C]-cohort model stack with dense [N]
score state — per-round cost flat in N, pinned bit-identical to the
``local`` backend at small N (``tests/test_population.py``).
"""
from repro.core.engine.backends import (
    AllgatherBackend, ExchangeBackend, LocalBackend, PodBackend,
    RingBackend, make_allgather_round, make_distributed_round,
    make_pod_round, ring_cross_test)
from repro.core.engine.driver import FederatedTrainer, RoundState
from repro.core.engine.population import (
    CohortModels, PopulationBackend, PopulationTrainer, cohort_from_mask)
from repro.core.engine.program import (
    RoundKeys, RoundProgram, aggregator_defaults, compose_fault_mask,
    flat_update_dim, init_comp_state, participation_mask,
    renormalize_over_subset, resolve_coalition, resolve_compressor,
    resolve_fault, resolve_strategies, round_keys)

__all__ = [
    "AllgatherBackend", "CohortModels", "ExchangeBackend",
    "FederatedTrainer", "LocalBackend", "PodBackend",
    "PopulationBackend", "PopulationTrainer", "RingBackend", "RoundKeys",
    "RoundProgram", "RoundState", "aggregator_defaults",
    "cohort_from_mask", "compose_fault_mask", "flat_update_dim",
    "init_comp_state", "make_allgather_round", "make_distributed_round",
    "make_pod_round", "participation_mask", "renormalize_over_subset",
    "resolve_coalition", "resolve_compressor", "resolve_fault",
    "resolve_strategies", "ring_cross_test", "round_keys",
]
