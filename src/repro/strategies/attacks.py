"""Registered malicious-client strategies.

Each attack corrupts the models of its malicious index set after local
training (round engine step 3). The corruption primitives are shared with
:mod:`repro.core.attacks`; the registry layer adds arbitrary *placement*
of the malicious set (``placement='last'|'first'|'spread'`` or explicit
``indices=(...)``) so nothing in the engine assumes attackers sit in the
last client slots.

* ``none``             — honest run (also what ``num_malicious=0`` means).
* ``random_weights``   — the paper's attack (Sec. IV): send random weights
  with the trained model's per-leaf magnitude statistics.
* ``sign_flip``        — gradient-ascent update ``g - scale*(t - g)``.
* ``label_flip_proxy`` — update-space proxy for label-flipping data
  poisoning: training on flipped labels drives the model *against* the
  true loss, which to first order is the sign-flipped update, sent at
  unit scale so magnitude statistics look honest.
* ``scaled_update``    — model-replacement magnification
  ``g + scale*(t - g)`` [Bagdasaryan et al.].
* ``adaptive_scale``   — adaptive attacker exploiting the cross-testing
  signal: corrupts (sign-flip at ``scale``) only while its *own*
  aggregation weight — read from the round's :class:`AttackContext` —
  stays above ``weight_threshold / N``; once FedTest suppresses it, it
  sends the honest update to farm its score back up, then re-attacks.
* ``scaled_collusion`` — sybil-split poisoning (DESIGN.md §7): the
  malicious set jointly mounts one sign-flip poison of total magnitude
  ``scale`` and each member sends its ``1/split`` share, staying under
  per-client magnitude thresholds while the coalition's aggregate keeps
  the full scale. The ``sybil_split`` / ``full_collusion`` coalitions
  build this attack over their member set.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.attacks import _random_weights, _scaled_update, _sign_flip
from repro.strategies.base import ATTACKS, Attack, register


@register(ATTACKS, "none")
class NoAttack(Attack):
    """Honest federation — identity on the stacked models.

    Reports an empty malicious set even when ``num_malicious`` is set, so
    the engine's ``malicious_weight`` metric reads 0 for honest runs.
    """

    def malicious_indices(self, num_users):
        return ()

    # identity fast-path; the inherited apply_local routes through the
    # (also identity) corrupt(), so the two paths agree by construction
    def apply(self, key, stacked_params, global_params, ctx=None):  # fedlint: disable=FL004
        return stacked_params

    def corrupt(self, key, trained, global_params, ctx=None,
                client_idx=None):
        return trained


@register(ATTACKS, "random_weights")
class RandomWeights(Attack):
    """Paper Sec. IV: malicious users send random weights."""

    def corrupt(self, key, trained, global_params, ctx=None,
                client_idx=None):
        return _random_weights(key, trained, global_params, self.scale)


@register(ATTACKS, "sign_flip")
class SignFlip(Attack):
    """Gradient-ascent update: ``global - scale * (trained - global)``."""

    def corrupt(self, key, trained, global_params, ctx=None,
                client_idx=None):
        return _sign_flip(key, trained, global_params, self.scale)


@register(ATTACKS, "label_flip_proxy")
class LabelFlipProxy(Attack):
    """Label-flipping poisoning, approximated in update space.

    A client training on permuted labels ascends the true loss, so its
    update points opposite the honest direction with honest magnitude —
    i.e. a sign-flipped update at fixed unit scale (``scale`` is ignored
    to keep the magnitude statistics indistinguishable from honest
    clients, which is what makes label flipping hard for norm-based
    defences to spot).
    """

    def __init__(self, *, num_malicious: int = 0, scale: float = 1.0,
                 placement: str = "last", indices=None):
        super().__init__(num_malicious=num_malicious, scale=1.0,
                         placement=placement, indices=indices)

    def corrupt(self, key, trained, global_params, ctx=None,
                client_idx=None):
        return _sign_flip(key, trained, global_params, 1.0)


@register(ATTACKS, "adaptive_scale")
class AdaptiveScale(Attack):
    """Adaptive attacker that exploits the cross-testing signal.

    The FedTest defence pays a client by its moving-average score; a
    rational attacker therefore corrupts only while the federation is
    still buying its update. Each round the malicious client reads its
    own implied aggregation weight from the :class:`AttackContext`
    (``ctx.weights[client_idx]``): at or above ``weight_threshold / N``
    (i.e. the given fraction of the uniform share) it sends the
    sign-flipped update at ``scale``; below it, it sends the *honest*
    trained update so the testers rebuild its score — then strikes
    again. This is the ROADMAP's "adaptive attacks that exploit the
    cross-testing signal" beachhead, expressed once through the unified
    engine seam (DESIGN.md §2) so it runs identically on every exchange
    backend. Without a context (legacy callers) it degrades to an
    unconditional sign-flip.
    """

    def __init__(self, *, num_malicious: int = 0, scale: float = 4.0,
                 weight_threshold: float = 0.5, placement: str = "last",
                 indices=None):
        super().__init__(num_malicious=num_malicious, scale=scale,
                         placement=placement, indices=indices)
        if not 0.0 <= weight_threshold:
            raise ValueError(
                f"weight_threshold must be >= 0, got {weight_threshold}")
        self.weight_threshold = float(weight_threshold)

    def corrupt(self, key, trained, global_params, ctx=None,
                client_idx=None):
        bad = _sign_flip(key, trained, global_params, self.scale)
        if ctx is None or client_idx is None:
            return bad
        my_weight = ctx.weights[client_idx]
        engaged = my_weight >= self.weight_threshold / ctx.num_users
        return jax.tree_util.tree_map(
            lambda t, b: jnp.where(engaged, b.astype(t.dtype), t),
            trained, bad)


@register(ATTACKS, "scaled_collusion")
class ScaledCollusion(Attack):
    """Sybil-split model poisoning (DESIGN.md §7).

    Each malicious client sends ``g − (scale/split)·(t − g)`` — its even
    share of one full-scale sign-flip poison. ``split`` defaults to the
    malicious-set size, so ``--attack scaled_collusion --malicious 4
    --attack-scale 8`` means "4 sybils splitting a scale-8 poison": no
    single update deviates more than a scale-2 attacker's would, but the
    coalition's aggregate contribution reconstructs the full attack. The
    ``sybil_split`` / ``full_collusion`` coalitions instantiate this
    attack over their member set.
    """

    def __init__(self, *, num_malicious: int = 0, scale: float = 8.0,
                 placement: str = "last", indices=None,
                 split: int = 0):
        super().__init__(num_malicious=num_malicious, scale=scale,
                         placement=placement, indices=indices)
        if split < 0:
            raise ValueError(f"split must be >= 0, got {split}")
        self.split = int(split) if split else max(1, self.num_malicious)

    def corrupt(self, key, trained, global_params, ctx=None,
                client_idx=None):
        return _sign_flip(key, trained, global_params,
                          self.scale / self.split)


@register(ATTACKS, "scaled_update")
class ScaledUpdate(Attack):
    """Model replacement: magnify the local update by ``scale``
    (``FedConfig.attack_scale``; >1 to actually attack)."""

    def corrupt(self, key, trained, global_params, ctx=None,
                client_idx=None):
        return _scaled_update(key, trained, global_params, self.scale)
