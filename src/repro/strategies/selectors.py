"""Registered tester-selection policies (Algorithm 1 line 16).

* ``rotating``    — independent random K-subset per round (the paper's
  scheme; a fresh draw keyed on the round index).
* ``round_robin`` — deterministic contiguous blocks walking the client
  ring, so every client testers exactly once per N/K rounds (the
  orthogonal-RB schedule's deterministic analogue, DESIGN.md §3).
* ``fixed``       — a pinned tester committee (defaults to clients
  0..K-1, or an explicit ``indices`` tuple) — the ablation where
  compromised fixed testers matter most.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.selection import select_testers
from repro.strategies.base import SELECTORS, Selector, register


@register(SELECTORS, "rotating")
class Rotating(Selector):
    """Random K-subset, redrawn each round (Alg. 1 line 16)."""

    def select(self, key, num_users, num_testers, round_idx):
        return select_testers(key, num_users, num_testers, round_idx)


@register(SELECTORS, "round_robin")
class RoundRobin(Selector):
    """Deterministic block rotation: round r tests clients
    ``(r*K + 0..K-1) mod N``."""

    def select(self, key, num_users, num_testers, round_idx):
        start = (round_idx * num_testers) % num_users
        return (start + jnp.arange(num_testers)) % num_users


@register(SELECTORS, "fixed")
class Fixed(Selector):
    """A pinned tester committee."""

    def __init__(self, *, indices: Optional[Tuple[int, ...]] = None):
        self.indices = (tuple(int(i) for i in indices)
                        if indices is not None else None)

    def select(self, key, num_users, num_testers, round_idx):
        if self.indices is not None:
            if len(self.indices) != num_testers:
                raise ValueError(
                    f"fixed selector got {len(self.indices)} indices but "
                    f"num_testers={num_testers}")
            return jnp.asarray(self.indices, jnp.int32)
        return jnp.arange(num_testers, dtype=jnp.int32)
