"""Registered tester-selection policies (Algorithm 1 line 16).

How the K testers are drawn is a defence knob in its own right —
DESIGN.md §7 analyses how each policy changes a coalition's expected
liar-row count per round:

* ``rotating``       — independent random K-subset per round (the
  paper's scheme; a fresh draw keyed on the round index).
* ``uniform``        — alias of ``rotating`` under the taxonomy name
  (every client equally likely to tester, every round independent).
* ``round_robin``    — deterministic contiguous blocks walking the
  client ring, so every client testers exactly once per N/K rounds (the
  orthogonal-RB schedule's deterministic analogue, DESIGN.md §3).
* ``coverage``       — randomised coverage schedule: a per-cycle
  permutation of the clients is consumed in K-blocks, so every client
  testers within ``ceil(N/K)`` rounds (like ``round_robin``) but a
  coalition cannot predict *which* future round it will hold tester
  rows (unlike ``round_robin``; DESIGN.md §7).
* ``score_weighted`` — Gumbel-top-k draw without replacement with
  probabilities proportional to the moving-average scores entering the
  round: clients the federation currently trusts test more often. Under
  coalition attacks this is double-edged — it concentrates tester rows
  on honest leaders while they lead, but rewards a coalition that has
  successfully boosted itself (measured by the coalition sweep,
  EXPERIMENTS.md §Coalition-sweep).
* ``fixed``          — a pinned tester committee (defaults to clients
  0..K-1, or an explicit ``indices`` tuple) — the ablation where
  compromised fixed testers matter most.

Every policy is a traced function of ``(key, round_idx, scores)`` — no
Python branching on round state — so rounds never retrace and the three
exchange backends derive bit-identical tester sets from equal keys
(``RoundProgram.select_round`` threads the replicated scores).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.selection import select_testers
from repro.strategies.base import SELECTORS, Selector, register


@register(SELECTORS, "rotating")
class Rotating(Selector):
    """Random K-subset, redrawn each round (Alg. 1 line 16)."""

    def select(self, key, num_users, num_testers, round_idx, *,
               scores=None):
        return select_testers(key, num_users, num_testers, round_idx)


@register(SELECTORS, "uniform")
class UniformDraw(Rotating):
    """Alias of ``rotating`` under the DESIGN.md §7 taxonomy name."""


@register(SELECTORS, "round_robin")
class RoundRobin(Selector):
    """Deterministic block rotation: round r tests clients
    ``(r*K + 0..K-1) mod N``."""

    def select(self, key, num_users, num_testers, round_idx, *,
               scores=None):
        start = (round_idx * num_testers) % num_users
        return (start + jnp.arange(num_testers)) % num_users


@register(SELECTORS, "coverage")
class Coverage(Selector):
    """Randomised coverage: shuffled round-robin, unpredictable to a
    coalition.

    Each cycle of ``ceil(N/K)`` rounds consumes one permutation of the
    client ids in contiguous K-blocks, so every client testers at least
    once per cycle; the permutation is redrawn per cycle from a key
    folded with the cycle index (seeded by the static ``seed``, *not*
    the per-round key, which differs every round), so future tester
    sets stay unpredictable without sacrificing the coverage guarantee
    (DESIGN.md §7).
    """

    def __init__(self, *, seed: int = 0):
        self.seed = int(seed)

    def select(self, key, num_users, num_testers, round_idx, *,
               scores=None):
        cycle_len = -(-num_users // num_testers)        # ceil(N/K)
        cycle = round_idx // cycle_len
        phase = round_idx % cycle_len
        perm = jax.random.permutation(
            jax.random.fold_in(jax.random.PRNGKey(self.seed), cycle),
            num_users)
        start = phase * num_testers
        return perm[(start + jnp.arange(num_testers)) % num_users]


@register(SELECTORS, "score_weighted")
class ScoreWeighted(Selector):
    """Trust-proportional testers: P(c testers) ∝ scores[c] + eps.

    A Gumbel-top-k draw — ``top_k(log p + Gumbel noise, K)`` samples K
    ids *without replacement* with probabilities proportional to ``p``
    under jit, no rejection loop. Before any scores exist (the all-zero
    init) the draw degrades to uniform via ``eps``. The coalition sweep
    (EXPERIMENTS.md §Coalition-sweep) measures how this policy shifts
    suppression under ``mutual_boost``.
    """

    def __init__(self, *, eps: float = 1e-3):
        if eps <= 0.0:
            raise ValueError(f"eps must be > 0, got {eps}")
        self.eps = float(eps)

    def select(self, key, num_users, num_testers, round_idx, *,
               scores=None):
        if scores is None:
            p = jnp.ones((num_users,), jnp.float32)
        else:
            p = jnp.maximum(scores.astype(jnp.float32), 0.0) + self.eps
        gumbel = -jnp.log(-jnp.log(
            jax.random.uniform(key, (num_users,), minval=1e-12,
                               maxval=1.0)))
        _, ids = jax.lax.top_k(jnp.log(p) + gumbel, num_testers)
        return ids.astype(jnp.int32)


@register(SELECTORS, "fixed")
class Fixed(Selector):
    """A pinned tester committee."""

    def __init__(self, *, indices: Optional[Tuple[int, ...]] = None):
        self.indices = (tuple(int(i) for i in indices)
                        if indices is not None else None)

    def select(self, key, num_users, num_testers, round_idx, *,
               scores=None):
        if self.indices is not None:
            if len(self.indices) != num_testers:
                raise ValueError(
                    f"fixed selector got {len(self.indices)} indices but "
                    f"num_testers={num_testers}")
            return jnp.asarray(self.indices, jnp.int32)
        return jnp.arange(num_testers, dtype=jnp.int32)
