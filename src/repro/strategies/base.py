"""Strategy registry machinery + the round-context protocol.

The FedTest round engine is a single fused, jitted program; everything a
strategy could vary — how aggregation weights are produced, how malicious
clients corrupt their models, how testers are selected — is resolved to a
plain Python object *before* tracing, so jit closes over static callables
and the round never branches on strategy names at trace time.

Three registries live in :mod:`repro.strategies`:

* ``AGGREGATORS`` — :class:`Aggregator`: ``weights(ctx) -> [N]`` simplex.
* ``ATTACKS``     — :class:`Attack`: corrupt malicious clients' models.
* ``SELECTORS``   — :class:`Selector`: pick the K tester ids per round.

Register a new strategy with the decorator::

    from repro.strategies import AGGREGATORS, Aggregator, register

    @register(AGGREGATORS, "uniform")
    class Uniform(Aggregator):
        def weights(self, ctx):
            n = ctx.counts.shape[0]
            return jnp.full((n,), 1.0 / n)

and select it by name: ``FedConfig(aggregator="uniform")``.
"""
from __future__ import annotations

import inspect
from typing import Any, Callable, Dict, NamedTuple, Optional, Tuple

import jax.numpy as jnp


class AttackContext(NamedTuple):
    """Frozen per-round view handed to attack strategies (step 3).

    Built by the round engine *before* corruption so adaptive attacks can
    react to the cross-testing signal: ``scores`` are the moving-average
    scores entering the round and ``weights`` the aggregation weights
    those scores imply (``score_weights``), i.e. what each client would
    have been paid had the round ended now. A malicious client reads its
    own entry (``weights[client_idx]``) to decide whether its corruption
    is still being bought. All fields are traced arrays; ``None`` context
    (legacy callers) must keep every attack functional.
    """

    scores: jnp.ndarray                # [N] moving-average scores (pre-round)
    weights: jnp.ndarray               # [N] implied aggregation weights
    round_idx: jnp.ndarray             # scalar i32

    @property
    def num_users(self) -> int:
        return self.weights.shape[0]


class RoundContext(NamedTuple):
    """Frozen per-round view handed to aggregation strategies.

    Built inside the traced round, so array fields are tracers; the
    closures are bound at trace time. Unused fields cost nothing — XLA
    dead-code-eliminates whatever a strategy does not touch.
    """

    acc_matrix: jnp.ndarray            # [K, N] tester-measured accuracies
    tester_ids: jnp.ndarray            # [K] ids of this round's testers
    scores: Any                        # ScoreState (moving-average scores)
    counts: jnp.ndarray                # [N] per-client sample counts
    round_idx: jnp.ndarray             # scalar i32
    key: jnp.ndarray                   # per-round PRNG key for the strategy
    # [N, D] float32 flattened client updates (trained - global), present
    # only when the resolved aggregator sets ``needs_updates`` or defines
    # ``combine`` (the engine materialises the matrix at most once).
    updates: Optional[jnp.ndarray] = None
    # () -> [N] accuracies of every client model on the *server's* held-out
    # set; present only when the aggregator sets ``needs_server_eval``.
    server_eval: Optional[Callable[[], jnp.ndarray]] = None
    # [N] 0/1 participation mask when FedConfig.participation < 1 samples
    # a client subset this round; None means everyone participates.
    participation: Optional[jnp.ndarray] = None
    # [K] 0/1 mask over the *rows* of ``acc_matrix``: which of this
    # round's testers actually reported (non-sampled testers transmit
    # nothing). The engine sets it to ``participation[tester_ids]`` on
    # every backend — the accuracy matrix is replicated before the
    # context is built, never pre-masked (DESIGN.md §2) — and leaves it
    # ``None`` under full participation.
    report_mask: Optional[jnp.ndarray] = None

    @property
    def num_users(self) -> int:
        return self.counts.shape[0]


class Registry:
    """Name -> strategy-class registry with decorator registration."""

    def __init__(self, kind: str):
        self.kind = kind
        self._entries: Dict[str, Callable] = {}

    def register(self, name: str, entry: Callable) -> Callable:
        if name in self._entries:
            raise ValueError(
                f"{self.kind} {name!r} is already registered "
                f"({self._entries[name]!r})")
        self._entries[name] = entry
        return entry

    def names(self) -> Tuple[str, ...]:
        return tuple(sorted(self._entries))

    def __contains__(self, name: str) -> bool:
        return name in self._entries

    def get(self, name: str) -> Callable:
        try:
            return self._entries[name]
        except KeyError:
            raise KeyError(
                f"unknown {self.kind} {name!r}; registered {self.kind}s: "
                f"{list(self.names())}") from None

    def build(self, name: str, kwargs: Optional[Dict[str, Any]] = None,
              defaults: Optional[Dict[str, Any]] = None) -> Any:
        """Instantiate ``name`` with ``kwargs`` (strict) + ``defaults``.

        ``defaults`` are engine-derived (FedConfig fields) and silently
        dropped when the strategy does not accept them; ``kwargs`` come
        from the user and must all be accepted.
        """
        cls = self.get(name)
        kwargs = dict(kwargs or {})
        params = inspect.signature(cls).parameters
        has_var_kw = any(p.kind is inspect.Parameter.VAR_KEYWORD
                         for p in params.values())
        merged = dict(kwargs)
        for k, v in (defaults or {}).items():
            if k not in merged and (has_var_kw or k in params):
                merged[k] = v
        if not has_var_kw:
            bad = [k for k in kwargs if k not in params]
            if bad:
                raise TypeError(
                    f"{self.kind} {name!r} got unexpected kwargs {bad}; "
                    f"accepted: {sorted(p for p in params if p != 'self')}")
        return cls(**merged)


def register(registry: Registry, name: str) -> Callable:
    """``@register(AGGREGATORS, "my_agg")`` class/function decorator."""
    def deco(entry: Callable) -> Callable:
        registry.register(name, entry)
        if hasattr(entry, "name") or inspect.isclass(entry):
            try:
                entry.name = name
            except (AttributeError, TypeError):
                pass
        return entry
    return deco


class Aggregator:
    """Turns a :class:`RoundContext` into an aggregated model update.

    Two aggregation fast paths, both one fused jitted program:

    * **weights path** (default): ``weights(ctx)`` returns a ``[N]``
      simplex vector (non-negative, sums to 1) — the fused weighted-sum
      aggregation (the Pallas ``weighted_aggregate`` kernel on TPU)
      consumes it unchanged.
    * **combine path**: aggregators that cannot be expressed as a weighted
      sum (per-coordinate trimmed mean / median) override
      ``combine(ctx, updates)`` — ``updates`` is the ``[N, D]`` float32
      matrix of flattened client updates and the return value is the
      ``[D]`` combined update, applied as ``global + unflatten(combined)``
      (the Pallas ``robust_combine`` kernel on TPU). ``combine`` left as
      ``None`` keeps the weights path. Combine aggregators must still
      implement ``weights`` (the engine uses it only for reporting, e.g.
      the ``malicious_weight`` metric — typically the normalised client
      gate mask).

    ``update_scores(ctx)`` lets stateful schemes (FedTest's moving
    average) evolve the ``ScoreState`` carried in the round state; the
    engine calls it first and hands the *updated* scores back via
    ``ctx.scores`` before calling ``weights`` / ``combine``.
    """

    name = "base"
    needs_updates = False       # engine materialises ctx.updates [N, D]
    needs_server_eval = False   # engine binds ctx.server_eval closure
    # optional hook: (ctx, updates [N, D]) -> [D] combined update; a
    # non-None value routes the round through the combine fast path.
    combine = None

    def update_scores(self, ctx: RoundContext):
        return ctx.scores

    def weights(self, ctx: RoundContext) -> jnp.ndarray:
        raise NotImplementedError

    def __repr__(self) -> str:
        return f"<aggregator {self.name}>"


def uses_combine(aggregator: "Aggregator") -> bool:
    """True when ``aggregator`` routes through the combine fast path.

    The one place the ``combine is None`` convention is inspected — both
    round engines (single-host and pod) call this, so the two paths
    cannot drift on what counts as a combine aggregator.
    """
    return getattr(aggregator, "combine", None) is not None


def normalize_placement(size: int, placement: str,
                        indices: Optional[Tuple[int, ...]]
                        ) -> Tuple[int, str, Optional[Tuple[int, ...]]]:
    """Validate and normalise a (size, placement, indices) ctor triple.

    Shared by :class:`Attack` and
    :class:`~repro.strategies.coalition.Coalition` so the two halves of
    the adversary model (DESIGN.md §7) accept exactly the same placement
    vocabulary. Explicit ``indices`` win and define the size.
    """
    if indices is not None:
        indices = tuple(int(i) for i in indices)
        size = len(indices)
    if placement not in ("last", "first", "spread"):
        raise ValueError(
            f"placement must be 'last'|'first'|'spread', got "
            f"{placement!r}")
    return int(size), placement, indices


def resolve_placement(num_users: int, size: int, placement: str = "last",
                      indices: Optional[Tuple[int, ...]] = None
                      ) -> Tuple[int, ...]:
    """Static client-index set for a named placement.

    The one placement formula shared by :class:`Attack` (the malicious
    set) and :class:`~repro.strategies.coalition.Coalition` (the member
    set, DESIGN.md §7), so an attack and a coalition configured with the
    same (size, placement) always name the same clients.
    """
    if indices is not None:
        return tuple(int(i) for i in indices)
    if size == 0:
        return ()
    if placement == "first":
        return tuple(range(size))
    if placement == "spread":
        stride = max(1, num_users // size)
        return tuple(sorted(set(
            min(i * stride, num_users - 1) for i in range(size))))
    return tuple(range(num_users - size, num_users))


def placement_mask(num_users: int, indices: Tuple[int, ...]
                   ) -> jnp.ndarray:
    """0/1 float mask [N] for a static client-index set."""
    mask = [0.0] * num_users
    for i in indices:
        mask[i] = 1.0
    return jnp.asarray(mask, jnp.float32)


class Attack:
    """Corrupts the malicious clients' models after local training.

    The malicious *index set* is static Python data (``malicious_indices``)
    so both the corruption and the ``malicious_weight`` metric stay correct
    for any placement — last slots, first slots, or an explicit set.
    """

    name = "base"

    def __init__(self, *, num_malicious: int = 0, scale: float = 1.0,
                 placement: str = "last",
                 indices: Optional[Tuple[int, ...]] = None):
        self.num_malicious, self.placement, self._indices = \
            normalize_placement(num_malicious, placement, indices)
        self.scale = float(scale)

    def malicious_indices(self, num_users: int) -> Tuple[int, ...]:
        """Static malicious id set (evaluation-side knowledge only)."""
        return resolve_placement(num_users, self.num_malicious,
                                 self.placement, self._indices)

    def malicious_mask(self, num_users: int) -> jnp.ndarray:
        return placement_mask(num_users, self.malicious_indices(num_users))

    def corrupt(self, key, trained, global_params, ctx=None,
                client_idx=None):
        """Produce one malicious client's model (pytree -> pytree).

        ``ctx`` is the round's :class:`AttackContext` (``None`` from
        legacy callers) and ``client_idx`` the corrupting client's index
        (static int on the stacked path, traced under SPMD) — adaptive
        attacks read their own score / weight through them; oblivious
        attacks ignore both.
        """
        raise NotImplementedError

    def apply(self, key, stacked_params, global_params, ctx=None):
        """Swap corrupted models into the malicious slots of the stack.

        The per-client key is ``fold_in(key, client_idx)`` — the same
        derivation :meth:`apply_local` uses per shard, so a key-consuming
        attack corrupts client ``c`` bit-identically on every exchange
        backend given the same round key.
        """
        import jax
        leaves = jax.tree_util.tree_leaves(stacked_params)
        if not leaves:
            return stacked_params
        num_users = leaves[0].shape[0]
        idx = self.malicious_indices(num_users)
        if not idx:
            return stacked_params
        bad = []
        for c in idx:
            trained = jax.tree_util.tree_map(lambda a, _c=c: a[_c],
                                             stacked_params)
            bad.append(self.corrupt(jax.random.fold_in(key, c), trained,
                                    global_params, ctx, c))

        def merge(stack, *bad_leaves):
            for c, bl in zip(idx, bad_leaves):
                stack = stack.at[c].set(bl)
            return stack

        return jax.tree_util.tree_map(merge, stacked_params, *bad)

    def apply_local(self, key, params, global_params, client_idx,
                    num_users: int, ctx=None):
        """Per-shard attack application — the pod backends' step 3.

        ``params`` is ONE client's pytree (no stacked client axis, the
        layout inside a ``shard_map`` body) and ``client_idx`` the traced
        mesh position along the clients axis. The malicious set is still
        the static ``malicious_indices`` placement, but *which device* is
        malicious is only known as a traced index under SPMD, so the
        corrupted model is computed unconditionally and selected with
        ``where`` — honest devices pay one corruption's worth of (cheap,
        elementwise) compute and keep their trained params bit-exactly.
        The per-client key folds ``client_idx`` exactly like :meth:`apply`
        folds the stacked slot, so the two paths corrupt bit-identically.
        """
        idx = self.malicious_indices(num_users)
        if not idx:
            return params
        import jax
        is_mal = self.malicious_mask(num_users)[client_idx] > 0
        bad = self.corrupt(jax.random.fold_in(key, client_idx), params,
                           global_params, ctx, client_idx)
        return jax.tree_util.tree_map(
            lambda t, b: jnp.where(is_mal, b.astype(t.dtype), t),
            params, bad)

    def __repr__(self) -> str:
        return (f"<attack {self.name} m={self.num_malicious} "
                f"placement={self.placement}>")


class Fault:
    """Per-round client-failure model (DESIGN.md §9).

    ``mask(key, num_users, round_idx)`` returns the ``[N]`` 0/1 float
    *survival* mask — 1 means the client completes the round, 0 means it
    crashed, timed out, or was partitioned away mid-round. The engine
    ANDs this mask into the participation mask *after* selection
    (:meth:`RoundProgram.run` step 2b), so a dropped client inherits the
    exact non-sampled semantics the score-freezing machinery already
    defines: zero aggregation weight, a frozen score, and a masked
    report row if it was this round's tester.

    ``key`` is the round schedule's ``keys.fault`` stream
    (``RoundKeys``), so fault patterns replay bit-identically on every
    exchange backend — never draw from a fresh ``PRNGKey`` here (FL001).
    Deterministic models (``targeted``) may ignore the key but must
    remain traced functions of ``round_idx`` (no Python branching on
    traced values).
    """

    name = "base"

    def mask(self, key, num_users: int, round_idx) -> jnp.ndarray:
        raise NotImplementedError

    def __repr__(self) -> str:
        return f"<fault {self.name}>"


class Selector:
    """Picks the K tester ids for a round.

    ``scores`` (keyword-only, ``None`` from legacy callers) carries the
    ``[N]`` moving-average scores *entering* the round — the engine
    threads them through :meth:`RoundProgram.select_round` on every
    backend, so score-aware policies (``score_weighted``,
    DESIGN.md §7) see the identical replicated signal and stay
    bit-identical across backends. Score-oblivious policies ignore it.
    """

    name = "base"

    def select(self, key, num_users: int, num_testers: int,
               round_idx, *, scores=None) -> jnp.ndarray:
        raise NotImplementedError

    def __repr__(self) -> str:
        return f"<selector {self.name}>"


AGGREGATORS = Registry("aggregator")
ATTACKS = Registry("attack")
SELECTORS = Registry("selector")
COALITIONS = Registry("coalition")
FAULTS = Registry("fault")
