"""Pluggable update compressors: the wire format of the exchange.

Federated rounds ship client *updates* (``model - global``) instead of
full models once a compressor other than ``identity`` is configured
(``FedConfig.compressor``). The engine threads one seam through
:class:`repro.core.engine.RoundProgram` between the attack step and the
exchange (DESIGN.md §12): each participating client flattens its update
to the ``[D]`` f32 vector of ``_flatten_updates``, encodes it, and every
downstream consumer — cross-testing, scoring, aggregation — sees only
the *decoded* reconstruction, so all backends stay bit-identical to
each other by construction.

Every compressor exposes::

    payload, new_state = comp.encode(state_row, update)   # [D] f32 in
    update_hat         = comp.decode(payload)             # [D] f32 out

``state_row`` is the client's persistent error-feedback buffer (``[D]``
f32, all-zero at init): ``encode`` compresses the *compensated* update
``update + state`` and banks the residual, so the sum of decoded
payloads telescopes to the sum of raw updates over rounds
(``tests/test_compressors.py`` pins the invariant). The stacked
``[N, D]`` buffer lives in ``RoundState.comp_state`` — checkpointed,
manifest-guarded, and restored bit-identically (DESIGN.md §9).

All compressors are deterministic, key-free functions of their inputs
(FL001: no PRNG streams are consumed), built through the same
:class:`~repro.strategies.base.Registry` protocol as every other
strategy; the engine injects ``dim`` (the flat update width) as a
build default.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.strategies.base import Registry, register

COMPRESSORS = Registry("compressor")


def _as_f32_vector(update):
    update = jnp.asarray(update)
    if update.ndim != 1:
        raise ValueError(
            f"compressors operate on flat [D] update vectors, got "
            f"shape {update.shape}")
    return update.astype(jnp.float32)


class Compressor:
    """Encode/decode one client's flat ``[D]`` f32 update.

    Subclasses implement :meth:`_compress` (lossy projection to a
    payload pytree) and :meth:`decode`; the error-feedback banking in
    :meth:`encode` is shared. ``dim`` is the static flat width — the
    engine injects it at build time so payload shapes are trace-static.
    """

    name = "base"
    #: identity ships the exact update — no error ever accumulates
    lossless = False

    def __init__(self, dim: int):
        self.dim = int(dim)
        if self.dim <= 0:
            raise ValueError(f"dim must be positive, got {dim}")

    # ----------------------------------------------------------- state
    def init_state(self, num_users: int) -> jnp.ndarray:
        """All-zero ``[N, D]`` f32 error-feedback buffer."""
        return jnp.zeros((int(num_users), self.dim), jnp.float32)

    # ------------------------------------------------------ wire format
    def _compress(self, compensated: jnp.ndarray):
        """Lossy projection of one compensated ``[D]`` update."""
        raise NotImplementedError

    def encode(self, state_row, update):
        """``(payload, new_state_row)`` with error feedback banked."""
        compensated = _as_f32_vector(update) + _as_f32_vector(state_row)
        payload = self._compress(compensated)
        new_state = compensated - self.decode(payload)
        return payload, new_state

    def decode(self, payload) -> jnp.ndarray:
        """Reconstruct the ``[D]`` f32 update from a payload."""
        raise NotImplementedError

    # ------------------------------------------------------ aggregation
    def aggregate(self, payloads, decoded, weights, impl: str = "auto"):
        """Weighted sum of decoded updates: ``[C, D] x [C] -> [D]``.

        The default routes through the ``weighted_aggregate`` kernel
        ops; ``int8`` overrides it with the fused ``dequant_aggregate``
        kernel that never materialises the dequantised ``[C, D]`` stack
        (DESIGN.md §12).
        """
        from repro.kernels.weighted_aggregate import weighted_aggregate
        return weighted_aggregate(decoded, weights, impl=impl)

    # ---------------------------------------------------------- costing
    def payload_bytes(self, payload) -> int:
        """Measured wire bytes of one client's concrete payload."""
        return int(sum(int(leaf.nbytes)
                       for leaf in jax.tree_util.tree_leaves(payload)))

    def __repr__(self) -> str:
        return f"<compressor {self.name} dim={self.dim}>"


@register(COMPRESSORS, "identity")
class Identity(Compressor):
    """Dense f32 exchange — the uncompressed baseline.

    ``identity`` exists so the property suite can pin the seam's
    algebra (zero residual, exact round-trip); the engine never threads
    it — ``compressor='identity'`` statically disables the seam so the
    default path stays byte-identical to the pre-compression engine.
    """

    lossless = True

    def _compress(self, compensated):
        return {"dense": compensated}

    def decode(self, payload):
        return jnp.asarray(payload["dense"], jnp.float32)


@register(COMPRESSORS, "topk")
class TopK(Compressor):
    """Top-k magnitude sparsification with error feedback.

    Ships the ``k`` largest-|value| coordinates of the compensated
    update as ``(values f32, indices i32)``; everything else stays in
    the error buffer and re-competes next round. ``k`` may be a
    fraction (``0.05`` -> 5% of ``dim``) or an absolute count.
    """

    def __init__(self, dim: int, k: float = 0.05):
        super().__init__(dim)
        k = float(k)
        if k <= 0:
            raise ValueError(f"k must be positive, got {k}")
        self.k = max(1, int(round(k * self.dim))) if k < 1.0 else int(k)
        self.k = min(self.k, self.dim)

    def _compress(self, compensated):
        _, idx = jax.lax.top_k(jnp.abs(compensated), self.k)
        idx = idx.astype(jnp.int32)
        return {"values": compensated[idx], "indices": idx}

    def decode(self, payload):
        return (jnp.zeros((self.dim,), jnp.float32)
                .at[payload["indices"]].set(
                    jnp.asarray(payload["values"], jnp.float32)))


@register(COMPRESSORS, "int8")
class Int8(Compressor):
    """Per-chunk absmax-scaled int8 quantisation with error feedback.

    The compensated update is padded to a multiple of ``chunk`` and
    quantised per chunk: ``scale = max|chunk| / 127`` (floored away
    from zero so all-zero chunks stay exact), ``q = round(x / scale)``
    clipped to ``[-127, 127]``. The payload is ``(q int8 [D_pad],
    scales f32 [D_pad / chunk])`` — ~3.9x smaller than dense f32 at
    the default chunk. Aggregation routes through the fused
    ``dequant_aggregate`` Pallas kernel so the f32 ``[C, D]`` stack is
    never materialised in HBM (DESIGN.md §12).
    """

    def __init__(self, dim: int, chunk: int = 256):
        super().__init__(dim)
        self.chunk = int(chunk)
        if self.chunk <= 0:
            raise ValueError(f"chunk must be positive, got {chunk}")
        self.padded_dim = ((self.dim + self.chunk - 1)
                          // self.chunk) * self.chunk
        self.num_chunks = self.padded_dim // self.chunk

    def _compress(self, compensated):
        x = jnp.pad(compensated, (0, self.padded_dim - self.dim))
        chunks = x.reshape(self.num_chunks, self.chunk)
        absmax = jnp.max(jnp.abs(chunks), axis=1)
        scales = jnp.maximum(absmax / 127.0, 1e-12).astype(jnp.float32)
        q = jnp.clip(jnp.round(chunks / scales[:, None]), -127, 127)
        return {"q": q.astype(jnp.int8).reshape(-1), "scales": scales}

    def decode(self, payload):
        q = jnp.asarray(payload["q"], jnp.float32)
        scales = jnp.asarray(payload["scales"], jnp.float32)
        dec = (q.reshape(self.num_chunks, self.chunk)
               * scales[:, None]).reshape(-1)
        return dec[:self.dim]

    def aggregate(self, payloads, decoded, weights, impl: str = "auto"):
        from repro.kernels.dequant_aggregate import dequant_aggregate
        out = dequant_aggregate(weights, payloads["scales"],
                                payloads["q"], chunk=self.chunk,
                                impl=impl)
        return out[:self.dim]


@register(COMPRESSORS, "lowrank")
class LowRank(Compressor):
    """Rank-r delta factorisation (LoRA-style wire format).

    The compensated ``[D]`` update is reshaped to a near-square
    ``[a, b]`` matrix and projected onto its top-``rank`` subspace by
    ``iters`` rounds of QR subspace iteration from a *deterministic*
    cosine-ramp start (no PRNG stream — FL001-clean). The payload is
    ``(U [a, rank] f32, V [b, rank] f32)``; ``decode`` returns
    ``(U @ V^T).ravel()``. Residual mass stays in the error buffer, so
    directions the subspace misses are retried in later rounds.
    """

    def __init__(self, dim: int, rank: int = 4, iters: int = 2):
        super().__init__(dim)
        self.rank = int(rank)
        self.iters = int(iters)
        if self.rank <= 0:
            raise ValueError(f"rank must be positive, got {rank}")
        if self.iters < 1:
            raise ValueError(f"iters must be >= 1, got {iters}")
        a = max(1, int(math.sqrt(self.dim)))
        self.rows = a
        self.cols = (self.dim + a - 1) // a
        self.rank = min(self.rank, self.rows, self.cols)

    def _seed_basis(self) -> jnp.ndarray:
        """Deterministic full-column-rank ``[cols, rank]`` start."""
        i = jnp.arange(self.cols, dtype=jnp.float32)[:, None]
        j = jnp.arange(self.rank, dtype=jnp.float32)[None, :]
        return jnp.cos(0.5 + i * (j + 1.0) * 0.618)

    def _compress(self, compensated):
        pad = self.rows * self.cols - self.dim
        mat = jnp.pad(compensated, (0, pad)).reshape(self.rows,
                                                     self.cols)
        v, _ = jnp.linalg.qr(self._seed_basis())
        for _ in range(self.iters):
            u, _ = jnp.linalg.qr(mat @ v)
            v, _ = jnp.linalg.qr(mat.T @ u)
        return {"u": (mat @ v).astype(jnp.float32),
                "v": v.astype(jnp.float32)}

    def decode(self, payload):
        u = jnp.asarray(payload["u"], jnp.float32)
        v = jnp.asarray(payload["v"], jnp.float32)
        return (u @ v.T).reshape(-1)[:self.dim]
