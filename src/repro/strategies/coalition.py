"""Coalition adversaries: coordinated multi-client attack strategies.

A :class:`Coalition` binds a static set of client indices (the *members*,
placed like an attack's malicious set — ``size`` + ``placement`` or an
explicit ``indices`` tuple) to up to two coordinated behaviours, the two
attack surfaces of the DESIGN.md §7 taxonomy:

* a **model-space attack** — :meth:`Coalition.model_attack` returns an
  :class:`~repro.strategies.base.Attack` applied to the members (step 3
  of the round). ``sybil_split`` uses the registered
  ``scaled_collusion`` attack: the members split one large poisoned
  update so each member's individual deviation stays ``1/|C|`` of the
  full-scale poison — under ``adaptive_scale``-style weight/magnitude
  thresholds — while the coalition's *aggregate* contribution keeps the
  full scale.
* a **report-space attack** — :meth:`Coalition.transform_reports`
  rewrites the replicated ``[K, N]`` accuracy matrix *after*
  cross-testing (step 5b). ``mutual_boost`` generalises the independent
  ``lying_testers`` flag into the masked-matrix transform of
  DESIGN.md §7: member rows report ``boost_to`` for every member and
  ``deflate_to`` for the ``deflate_top`` top-scoring honest clients
  (targets read from the round's :class:`AttackContext` scores), leaving
  every other entry untouched. Because every backend replicates the
  accuracy matrix before scoring, the transform is literally shared code
  and the three exchange backends stay bit-identical
  (``tests/test_pod_parity.py``).

The engine resolves ``FedConfig.coalition`` against :data:`COALITIONS`
once, pre-trace, and composes the coalition with the independent
``FedConfig.attack`` via :meth:`Coalition.compose`: the malicious index
set becomes the *union* of the attack's set and the members (so the
``malicious_weight`` metric reports the coalition's aggregate weight),
and the coalition's model attack takes precedence on members. A
sitting-out coalition gains nothing from client sampling: score freezing
(DESIGN.md §2a) carries a suppressed member's score unchanged through the
rounds it skips.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.strategies.base import (
    ATTACKS, Attack, AttackContext, COALITIONS, normalize_placement,
    placement_mask, register, resolve_placement)


class Coalition:
    """A coordinated set of clients (DESIGN.md §7).

    Subclasses override :meth:`model_attack` (coordinated model-space
    corruption, an :class:`Attack` over the members) and / or
    :meth:`transform_reports` (coordinated report-space corruption of the
    replicated accuracy matrix). The base class is the inactive
    coalition: no members, no behaviour.
    """

    name = "base"

    def __init__(self, *, size: int = 0, placement: str = "last",
                 indices: Optional[Tuple[int, ...]] = None):
        self.size, self.placement, self._indices = normalize_placement(
            size, placement, indices)

    # ------------------------------------------------------------ membership
    def members(self, num_users: int) -> Tuple[int, ...]:
        """Static member id set (same placement formula as attacks)."""
        return resolve_placement(num_users, self.size, self.placement,
                                 self._indices)

    def member_mask(self, num_users: int) -> jnp.ndarray:
        return placement_mask(num_users, self.members(num_users))

    @property
    def active(self) -> bool:
        return self.size > 0

    # ------------------------------------------------------------ behaviours
    def model_attack(self) -> Optional[Attack]:
        """Coordinated model-space attack over the members, or ``None``."""
        return None

    def transform_reports(self, key, acc: jnp.ndarray,
                          tester_ids: jnp.ndarray,
                          ctx: AttackContext) -> jnp.ndarray:
        """Report-space attack on the replicated ``[K, N]`` matrix.

        Called once per round (step 5b), after honest cross-testing and
        the legacy ``lying_testers`` noise, with the round's
        :class:`AttackContext` so the lie can target the current scores.
        Identity by default.
        """
        return acc

    # ----------------------------------------------------------- composition
    def compose(self, base_attack: Attack, num_users: int) -> Attack:
        """Fold this coalition into the round's attack seam.

        Returns ``base_attack`` unchanged when the coalition is inactive;
        otherwise a :class:`CoalitionAttack` whose malicious set is the
        union of the base attack's set and the members — members count
        toward ``malicious_weight`` even for report-space-only coalitions
        (a lying tester is malicious whether or not it also poisons its
        model).
        """
        if not self.active:
            return base_attack
        return CoalitionAttack(self, base_attack, num_users)

    def __repr__(self) -> str:
        return (f"<coalition {self.name} size={self.size} "
                f"placement={self.placement}>")


class CoalitionAttack(Attack):
    """The composed attack seam: coalition members ∪ independent attackers.

    ``corrupt`` routes each client to the right corruption — the
    coalition's model attack on members (when it defines one), the base
    attack on its own malicious set otherwise — selected with masks over
    the (possibly traced, under SPMD) ``client_idx``, so the inherited
    ``apply`` / ``apply_local`` machinery keeps the stacked and per-shard
    paths bit-identical (DESIGN.md §7). Members of a report-space-only
    coalition keep their honest trained model but still count as
    malicious for the ``malicious_weight`` metric.
    """

    name = "coalition"

    def __init__(self, coalition: Coalition, base_attack: Attack,
                 num_users: int):
        self.coalition = coalition
        self.base = base_attack
        self.coal_attack = coalition.model_attack()
        self.num_users = int(num_users)
        # Attack bookkeeping fields (repr / legacy introspection only;
        # malicious_indices is overridden below)
        union = self.malicious_indices(num_users)
        self.num_malicious = len(union)
        self.scale = base_attack.scale
        self.placement = base_attack.placement
        self._indices = union

    def malicious_indices(self, num_users: int) -> Tuple[int, ...]:
        # re-resolved per queried size (the base-class contract): the
        # union of the base attack's placement and the member set
        return tuple(sorted(
            set(self.base.malicious_indices(num_users))
            | set(self.coalition.members(num_users))))

    def corrupt(self, key, trained, global_params, ctx=None,
                client_idx=None):
        if client_idx is None:
            # legacy callers without a client identity cannot be routed
            # through the member masks — degrade to the unconditional
            # coordinated corruption (adaptive_scale's precedent)
            primary = self.coal_attack or self.base
            return primary.corrupt(key, trained, global_params, ctx, None)
        n = self.num_users
        out = trained
        coal_mask = self.coalition.member_mask(n)
        if self.coal_attack is not None:
            bad = self.coal_attack.corrupt(key, trained, global_params,
                                           ctx, client_idx)
            in_coal = coal_mask[client_idx] > 0
            out = jax.tree_util.tree_map(
                lambda t, b: jnp.where(in_coal, b.astype(t.dtype), t),
                out, bad)
        if self.base.malicious_indices(n):
            # same key as the coalition corruption above is deliberate:
            # the two masks are made disjoint below, so no client ever
            # sees both streams — reuse cannot correlate anything.
            bad = self.base.corrupt(key, trained, global_params, ctx,  # fedlint: disable=FL001
                                    client_idx)
            in_base = self.base.malicious_mask(n)[client_idx] > 0
            if self.coal_attack is not None:
                # the coalition's coordinated corruption takes precedence
                # on members that sit in both sets
                in_base = in_base & ~(coal_mask[client_idx] > 0)
            out = jax.tree_util.tree_map(
                lambda t, b: jnp.where(in_base, b.astype(t.dtype), t),
                out, bad)
        return out

    def __repr__(self) -> str:
        return (f"<attack coalition {self.coalition.name} "
                f"base={self.base.name} union={self._indices}>")


@register(COALITIONS, "none")
class NoCoalition(Coalition):
    """No coordination — the independent-adversary default."""

    def members(self, num_users: int) -> Tuple[int, ...]:
        return ()

    @property
    def active(self) -> bool:
        return False


@register(COALITIONS, "mutual_boost")
class MutualBoost(Coalition):
    """Colluding testers: boost each other, defame the honest leaders.

    The report-space coalition of DESIGN.md §7 — whenever a member is
    selected as a tester, its row of the replicated accuracy matrix is
    rewritten by the masked-matrix transform

        A'[k, c] = (1 − m_k) · A[k, c]
                 + m_k · (C_c · boost_to
                          + H_c · deflate_to
                          + (1 − C_c − H_c) · A[k, c])

    where ``m = C[tester_ids]`` flags member rows, ``C`` is the member
    mask and ``H`` the ``deflate_top`` top-scoring *honest* clients by
    the scores entering the round (read from the ``AttackContext``, so
    the defamation tracks whoever FedTest currently trusts most;
    ``deflate_top=0`` is the boost-only ablation, ``None`` defaults to
    the coalition size). This generalises the independent
    ``lying_testers`` flag (uniform-noise rows) into coordinated,
    targeted lying.
    """

    def __init__(self, *, size: int = 0, placement: str = "last",
                 indices: Optional[Tuple[int, ...]] = None,
                 boost_to: float = 1.0, deflate_to: float = 0.0,
                 deflate_top: Optional[int] = None):
        super().__init__(size=size, placement=placement, indices=indices)
        if not 0.0 <= deflate_to <= boost_to <= 1.0:
            raise ValueError(
                f"need 0 <= deflate_to <= boost_to <= 1, got "
                f"deflate_to={deflate_to}, boost_to={boost_to}")
        self.boost_to = float(boost_to)
        self.deflate_to = float(deflate_to)
        if deflate_top is not None and deflate_top < 0:
            raise ValueError(
                f"deflate_top must be >= 0 (0 = boost-only), got "
                f"{deflate_top}")
        self.deflate_top = (None if deflate_top is None
                            else int(deflate_top))

    def transform_reports(self, key, acc, tester_ids, ctx):
        n = acc.shape[1]
        member = self.member_mask(n)                            # C [N]
        liar_rows = member[tester_ids] > 0                      # m [K]
        # deflate_top=0 is the boost-only ablation (no defamation)
        top = self.deflate_top if self.deflate_top is not None else self.size
        top = min(top, n)
        lied = acc
        if top > 0:
            # top-scoring honest clients by the scores entering the
            # round; members are excluded — no self-defamation
            honest_scores = jnp.where(member > 0, -jnp.inf, ctx.scores)
            _, idx = jax.lax.top_k(honest_scores, top)
            target = jnp.zeros((n,), jnp.float32).at[idx].set(1.0)  # H [N]
            lied = jnp.where(target[None, :] > 0, self.deflate_to, lied)
        lied = jnp.where(member[None, :] > 0, self.boost_to, lied)
        return jnp.where(liar_rows[:, None], lied, acc)


class _SybilModelAttack:
    """Mixin supplying the split-scale coordinated model attack."""

    def model_attack(self) -> Attack:
        return ATTACKS.build(
            "scaled_collusion",
            dict(num_malicious=self.size, placement=self.placement,
                 indices=self._indices, scale=self.scale,
                 split=max(1, self.size)))


@register(COALITIONS, "sybil_split")
class SybilSplit(_SybilModelAttack, Coalition):
    """Sybil-split model poisoning (DESIGN.md §7).

    The members jointly mount one full-scale sign-flip poison of total
    magnitude ``scale`` and split it evenly: each member sends
    ``g − (scale/|C|)·(t − g)``, so no single update exceeds ``1/|C|`` of
    the poison — under per-client magnitude / weight thresholds — while
    the sum over the coalition reconstructs the full attack. Implemented
    through the registered ``scaled_collusion`` attack, so the same
    corruption is drivable standalone via ``--attack scaled_collusion``.
    """

    def __init__(self, *, size: int = 0, placement: str = "last",
                 indices: Optional[Tuple[int, ...]] = None,
                 scale: float = 8.0):
        super().__init__(size=size, placement=placement, indices=indices)
        self.scale = float(scale)


@register(COALITIONS, "full_collusion")
class FullCollusion(_SybilModelAttack, MutualBoost):
    """The combined worst case: sybil-split poisoning + mutual boosting.

    Members corrupt their models with the split-scale poison *and*
    rewrite their tester rows with the ``mutual_boost`` transform —
    every coordinated behaviour of DESIGN.md §7 at once.
    """

    def __init__(self, *, size: int = 0, placement: str = "last",
                 indices: Optional[Tuple[int, ...]] = None,
                 scale: float = 8.0, boost_to: float = 1.0,
                 deflate_to: float = 0.0,
                 deflate_top: Optional[int] = None):
        super().__init__(size=size, placement=placement, indices=indices,
                         boost_to=boost_to, deflate_to=deflate_to,
                         deflate_top=deflate_top)
        self.scale = float(scale)
