"""Registered client-failure models (DESIGN.md §9).

Production federations lose clients mid-round — crashes, network
partitions, stragglers past the server's deadline. The FL evaluation
literature treats dropout / partial participation as a first-class
evaluation axis, and FedTest specifically must keep its *defence state*
(scores, trust) coherent under failures: a client that dropped this
round transmitted nothing, so the testers measured the stale global copy
in its slot — no evidence about the client itself.

Each fault model produces a per-round ``[N]`` 0/1 *survival* mask that
the engine ANDs into the participation mask after selection
(:meth:`~repro.core.engine.program.RoundProgram.run`); the existing
non-sampled semantics then do all the work — zero aggregation weight,
frozen score, masked tester row — identically on every exchange backend
(the parity matrix in ``tests/test_pod_parity.py`` pins a ``dropout``
case bit-identical across local/ring/allgather).

* ``none``                — no failures (what ``FedConfig.fault``
  defaults to).
* ``dropout``             — i.i.d. per-round Bernoulli failures: each
  client independently fails with probability ``rate``.
* ``straggler_deadline``  — heterogeneous-speed model: client ``c``'s
  round latency is ``mean_c * jitter`` where ``mean_c`` ramps linearly
  from 1 to ``1 + spread`` across the client index (a deterministic
  speed rank) and ``jitter`` is per-round Exponential(1) noise from the
  round schedule; clients whose latency exceeds ``deadline`` are
  treated as dropped (the server aggregates without waiting).
* ``targeted``            — placement-aware adversarial drops (a DoS /
  partition on specific clients): the placed index set —
  ``placement='last'|'first'|'spread'`` or explicit ``indices=``, the
  same vocabulary attacks and coalitions use — is dropped every round
  from ``start_round`` on. Pointing it at the scenario's honest
  top-scorers models an attacker silencing the testers that would
  convict it.

All masks derive from the round schedule's ``keys.fault`` stream
(``RoundKeys``; FL001 pins this in ``tests/fedlint_fixtures/``), so a
resumed run replays the identical failure pattern and the three exchange
backends agree bit-exactly.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.strategies.base import (
    FAULTS, Fault, normalize_placement, placement_mask, register,
    resolve_placement)


@register(FAULTS, "none")
class NoFault(Fault):
    """Every client survives every round."""

    def mask(self, key, num_users, round_idx):
        return jnp.ones((num_users,), jnp.float32)


@register(FAULTS, "dropout")
class Dropout(Fault):
    """I.i.d. per-round Bernoulli client failures at ``rate``."""

    def __init__(self, *, rate: float = 0.1):
        if not 0.0 <= rate < 1.0:
            raise ValueError(f"dropout rate in [0, 1), got {rate}")
        self.rate = float(rate)

    def mask(self, key, num_users, round_idx):
        alive = jax.random.bernoulli(key, 1.0 - self.rate, (num_users,))
        return alive.astype(jnp.float32)


@register(FAULTS, "straggler_deadline")
class StragglerDeadline(Fault):
    """Clients slower than ``deadline`` this round are dropped.

    Latency model: ``mean_c * jitter_c`` with ``mean_c = 1 + spread *
    c / (N - 1)`` (client index as deterministic speed rank — client 0
    is the fastest, client N-1 the slowest) and ``jitter_c`` per-round
    i.i.d. Exponential(1). With the defaults (``deadline=2.5``,
    ``spread=1.0``) the fastest client misses ~8% of rounds and the
    slowest ~29% — persistent, asymmetric dropout, which is what
    distinguishes a straggler population from i.i.d. ``dropout``.
    """

    def __init__(self, *, deadline: float = 2.5, spread: float = 1.0):
        if deadline <= 0.0:
            raise ValueError(f"deadline must be > 0, got {deadline}")
        if spread < 0.0:
            raise ValueError(f"spread must be >= 0, got {spread}")
        self.deadline = float(deadline)
        self.spread = float(spread)

    def mask(self, key, num_users, round_idx):
        rank = jnp.arange(num_users, dtype=jnp.float32)
        mean = 1.0 + self.spread * rank / jnp.maximum(num_users - 1, 1)
        jitter = jax.random.exponential(key, (num_users,))
        latency = mean * jitter
        return (latency <= self.deadline).astype(jnp.float32)


@register(FAULTS, "targeted")
class Targeted(Fault):
    """Placement-aware drops: the placed set fails every round from
    ``start_round`` on (an adversarial partition / DoS)."""

    def __init__(self, *, size: int = 0, placement: str = "last",
                 indices: Optional[Tuple[int, ...]] = None,
                 start_round: int = 0):
        self.size, self.placement, self._indices = normalize_placement(
            size, placement, indices)
        if start_round < 0:
            raise ValueError(
                f"start_round must be >= 0, got {start_round}")
        self.start_round = int(start_round)

    def target_indices(self, num_users: int) -> Tuple[int, ...]:
        return resolve_placement(num_users, self.size, self.placement,
                                 self._indices)

    def mask(self, key, num_users, round_idx):
        dropped = placement_mask(num_users,
                                 self.target_indices(num_users))
        active = (round_idx >= self.start_round).astype(jnp.float32)
        return 1.0 - dropped * active
