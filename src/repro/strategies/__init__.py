"""Pluggable strategy registries for the FedTest round engine.

The round engine (:mod:`repro.core.engine`) is parameterised by three
strategy families, each selected **by name** through :class:`FedConfig`
and resolved to plain Python objects before jit tracing:

* :data:`AGGREGATORS` — how tester reports / client updates become the
  ``[N]`` aggregation-weight simplex (``fedtest``, ``fedavg``,
  ``accuracy_based``, ``krum``, ``trimmed_mean``, ``median``,
  ``uniform``).
* :data:`ATTACKS` — how malicious clients corrupt their models
  (``none``, ``random_weights``, ``sign_flip``, ``label_flip_proxy``,
  ``scaled_update``, ``adaptive_scale``), with arbitrary placement of
  the malicious set; each corruption receives the round's
  :class:`AttackContext` so adaptive attacks can read the
  cross-testing signal.
* :data:`SELECTORS` — which K clients tester each round (``rotating``
  / ``uniform``, ``round_robin``, ``coverage``, ``score_weighted``,
  ``fixed``).
* :data:`COALITIONS` — coordinated multi-client adversaries
  (``none``, ``mutual_boost``, ``sybil_split``, ``full_collusion``):
  a :class:`Coalition` binds a member set to a coordinated model
  attack and/or a report-matrix transform (DESIGN.md §7).
* :data:`FAULTS` — per-round client-failure models (``none``,
  ``dropout``, ``straggler_deadline``, ``targeted``): a :class:`Fault`
  produces the round's ``[N]`` survival mask, ANDed into the
  participation mask after selection so dropped clients inherit the
  non-sampled semantics — zero weight, frozen score, masked tester row
  (DESIGN.md §9).
* :data:`COMPRESSORS` — the exchange wire format (``identity``,
  ``topk``, ``int8``, ``lowrank``): a :class:`Compressor` encodes each
  participating client's flat update (with a persistent per-client
  error-feedback buffer in ``RoundState.comp_state``) and every
  backend consumes only the decoded reconstruction (DESIGN.md §12).

Adding a strategy is one file anywhere that runs::

    from repro.strategies import AGGREGATORS, Aggregator, register

    @register(AGGREGATORS, "mine")
    class Mine(Aggregator):
        def weights(self, ctx):
            ...

See README.md §"Writing a strategy".
"""
from repro.strategies.base import (
    AGGREGATORS, ATTACKS, COALITIONS, FAULTS, SELECTORS,
    Aggregator, Attack, AttackContext, Fault, Registry, RoundContext,
    Selector, register, resolve_placement, uses_combine)
# importing the submodules populates the registries
from repro.strategies import aggregators as _aggregators  # noqa: F401
from repro.strategies import attacks as _attacks          # noqa: F401
from repro.strategies import faults as _faults            # noqa: F401
from repro.strategies import selectors as _selectors      # noqa: F401
from repro.strategies.coalition import Coalition, CoalitionAttack
from repro.strategies.compressors import COMPRESSORS, Compressor

__all__ = [
    "AGGREGATORS", "ATTACKS", "COALITIONS", "COMPRESSORS", "FAULTS",
    "SELECTORS", "Aggregator", "Attack", "AttackContext", "Coalition",
    "CoalitionAttack", "Compressor", "Fault", "Selector", "Registry",
    "RoundContext", "register", "resolve_placement", "uses_combine",
]
